//! Train the learned elementwise latency models (Fig. 5 flow): collect
//! measurements per the paper's protocol, train HGBR per operator,
//! evaluate on unseen sizes, compare against the linear baseline, and
//! persist the models.
//!
//! Run with: `cargo run --release --example train_elementwise`

use scalesim_tpu::experiments::fig5;
use scalesim_tpu::frontend::classify::EwKind;
use scalesim_tpu::learned::{featurize, HgbrParams};
use scalesim_tpu::tpu::TpuV4Model;

fn main() -> anyhow::Result<()> {
    let mut hw = TpuV4Model::new(42);
    let out_dir = std::path::Path::new("artifacts/learned");
    std::fs::create_dir_all(out_dir)?;

    println!("collecting measurements + training (paper protocol: log-uniform");
    println!("sizes to ~16M elements, multiple factorizations, 2^n boundaries,");
    println!("median-of-5 measurements, train/test split on UNSEEN sizes)\n");

    for op in [EwKind::Add, EwKind::Maximum, EwKind::Multiply] {
        let eval = fig5::eval_operator(&mut hw, op, 1500, 5, 42, &HgbrParams::default());
        println!(
            "{:<9} R2={:.4}  medAE={:.2}us  medRE={:.2}%  (trees={}, train n={}, test n={})",
            op.name(),
            eval.metrics.r2,
            eval.metrics.median_abs_err,
            eval.metrics.median_rel_err_pct,
            eval.model.num_trees(),
            eval.train_size,
            eval.metrics.n
        );
        println!(
            "          linear baseline: R2={:.4} medRE={:.2}%  (the paper's motivation for trees)",
            eval.linear_baseline.r2, eval.linear_baseline.median_rel_err_pct
        );

        let top: Vec<String> = eval
            .model
            .ranked_features()
            .into_iter()
            .take(4)
            .map(|(n, v)| format!("{n} {:.0}%", v * 100.0))
            .collect();
        println!("          top features: {}", top.join(", "));

        let path = out_dir.join(format!("{}.json", op.name()));
        eval.model.save(&path)?;

        // Demonstrate inference on a few fresh shapes.
        for dims in [vec![8, 128], vec![1000, 1000], vec![4096, 4096]] {
            let t = eval.model.predict(&featurize(&dims));
            println!("          predict {dims:?} -> {t:.2} us");
        }
        println!("          saved {}", path.display());
    }
    Ok(())
}
