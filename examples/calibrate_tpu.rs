//! The full Fig. 2 calibration flow against a selectable hardware
//! backend, with Fig. 4 held-out validation — the "ground the simulator
//! in measurements" workflow.
//!
//! Run with:
//!   cargo run --release --example calibrate_tpu              # device model
//!   cargo run --release --example calibrate_tpu -- pjrt      # real PJRT runs

use scalesim_tpu::experiments::{assets, fig2, fig4};
use scalesim_tpu::device::DeviceSpec;
use scalesim_tpu::scalesim::ScaleConfig;
use scalesim_tpu::tpu::{Hardware, PjrtHardware, TpuV4Model};

fn main() -> anyhow::Result<()> {
    let backend = std::env::args().nth(1).unwrap_or_else(|| "model".into());
    let config = ScaleConfig::tpu_v4();

    match backend.as_str() {
        "pjrt" => {
            // Real executions are slow; use the reduced calibration set.
            let mut hw = PjrtHardware::new()?;
            println!("calibrating against real PJRT executions ({})...", hw.name());
            let est = assets::build_estimator_fast(&mut hw, &DeviceSpec::tpu_v4(), 3, 42);
            for (regime, m) in &est.calibration.metrics {
                println!("  {regime}: {m}");
            }
            assets::save_assets(std::path::Path::new("artifacts/assets_pjrt"), &est)?;
            println!("saved to artifacts/assets_pjrt/");
        }
        _ => {
            let mut hw = TpuV4Model::new(42);
            let f2 = fig2::run(&mut hw, &config, 5);
            println!("{}", fig2::render(&f2, hw.name()));

            println!("\nheld-out validation (Fig. 4):");
            let f4 = fig4::run(&mut hw, &config, &f2.calibration, 5);
            println!(
                "  R2 = {:.3}  MAPE = {:.1}%  (n = {})",
                f4.overall.r2,
                f4.overall.mape_pct,
                f4.overall.n
            );
            for (regime, mape) in &f4.per_regime_mape {
                println!("    {regime}: MAPE {mape:.1}%");
            }
        }
    }
    Ok(())
}
