//! Quickstart: simulate a GEMM on the TPU-v4 config, calibrate a
//! cycle→time mapping against the device model, and print the latency
//! estimate — the 60-second tour of the public API.
//!
//! Run with: `cargo run --release --example quickstart`

use scalesim_tpu::calibrate::Regime;
use scalesim_tpu::experiments::fig2;
use scalesim_tpu::scalesim::{simulate_gemm, Dataflow, GemmShape, ScaleConfig};
use scalesim_tpu::tpu::{Hardware, TpuV4Model};

fn main() {
    // 1. A SCALE-Sim architecture config: one TPU-v4-like 128x128 MXU.
    let config = ScaleConfig::tpu_v4();
    println!(
        "config: {} ({}x{} array, {} dataflow, {} MHz)\n",
        config.name, config.array_rows, config.array_cols, config.dataflow, config.freq_mhz
    );

    // 2. Simulate GEMMs across the paper's three regimes.
    for g in [
        GemmShape::new(64, 64, 64),
        GemmShape::new(512, 512, 512),
        GemmShape::new(2048, 2048, 2048),
    ] {
        let r = simulate_gemm(&config, g);
        println!(
            "{g}  [{}]\n  cycles={} (compute {} + stall {} + fill {})  util={:.1}%  folds={}",
            Regime::of_gemm(&g),
            r.total_cycles(),
            r.compute_cycles,
            r.stall_cycles,
            r.initial_fill_cycles,
            r.utilisation * 100.0,
            r.num_folds,
        );
    }

    // 3. Dataflows are first-class: compare OS/WS/IS on a skewed shape.
    println!("\ndataflow comparison on GEMM 4096x256x256:");
    let g = GemmShape::new(4096, 256, 256);
    for df in [
        Dataflow::OutputStationary,
        Dataflow::WeightStationary,
        Dataflow::InputStationary,
    ] {
        let mut c = config.clone();
        c.dataflow = df;
        let r = simulate_gemm(&c, g);
        println!("  {df}: {} cycles, util {:.1}%", r.total_cycles(), r.utilisation * 100.0);
    }

    // 4. Calibrate cycles -> wall-clock against the measurement backend
    //    (the synthetic TPU-v4 device model; swap in PjrtHardware to
    //    calibrate against real executions).
    println!("\ncalibrating cycle->time mapping (Fig. 2 sweep)...");
    let mut hw = TpuV4Model::new(42);
    let f2 = fig2::run(&mut hw, &config, 5);
    for p in &f2.panels {
        println!(
            "  {}: t = {:.3e} * cycles + {:.2} us   (R2 = {:.4}, n = {})",
            p.regime, p.fit.alpha, p.fit.beta, p.metrics.r2, p.metrics.n
        );
    }

    // 5. Report calibrated latency for a fresh shape.
    let g = GemmShape::new(700, 900, 1100);
    let r = simulate_gemm(&config, g);
    let est_us = f2.calibration.cycles_to_us(&g, r.total_cycles());
    let measured = hw.gemm_latency_us(g);
    println!(
        "\n{g}: {} cycles -> estimated {est_us:.2} us (device measured {measured:.2} us, {:+.1}% error)",
        r.total_cycles(),
        100.0 * (est_us - measured) / measured
    );
}
