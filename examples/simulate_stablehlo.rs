//! Simulate a compiler-emitted StableHLO module end to end — the paper's
//! headline workflow (Fig. 1): JAX program → StableHLO → parse → classify
//! → route systolic ops to SCALE-Sim, elementwise ops to learned models →
//! whole-model latency.
//!
//! Requires `make artifacts` (python/compile/aot.py) to have produced
//! `artifacts/*.stablehlo.txt`. Run with:
//! `cargo run --release --example simulate_stablehlo [-- path/to/module.stablehlo.txt]`

use std::path::PathBuf;

use scalesim_tpu::device::DeviceSpec;
use scalesim_tpu::experiments::assets;
use scalesim_tpu::frontend::{classify, parse_module, OpClass};
use scalesim_tpu::report::Table;
use scalesim_tpu::tpu::TpuV4Model;

fn main() -> anyhow::Result<()> {
    let path = std::env::args()
        .nth(1)
        .unwrap_or_else(|| "artifacts/mlp_b32.stablehlo.txt".to_string());
    let text = std::fs::read_to_string(&path).map_err(|e| {
        anyhow::anyhow!("{path}: {e} — run `make artifacts` first, or pass a module path")
    })?;

    // Parse + classification census.
    let module = parse_module(&text)?;
    let func = module.entry().expect("entry function");
    println!(
        "module @{} — {} ops, {} args, {} results",
        module.name,
        func.ops.len(),
        func.arg_types.len(),
        func.result_types.len()
    );
    let mut census: std::collections::BTreeMap<&'static str, usize> = Default::default();
    for op in &func.ops {
        let tag = match classify(op) {
            OpClass::SystolicGemm { .. } => "systolic-gemm",
            OpClass::SystolicConv { .. } => "systolic-conv",
            OpClass::Elementwise { .. } => "elementwise",
            OpClass::Reduction { .. } => "reduction",
            OpClass::DataMovement { .. } => "data-movement",
            OpClass::Collective { .. } => "collective",
            OpClass::Free => "free",
            OpClass::Unmodeled { .. } => "unmodeled",
        };
        *census.entry(tag).or_default() += 1;
    }
    println!("classification census: {census:?}\n");

    // Build (or load cached) modeling assets, then estimate.
    let device = DeviceSpec::tpu_v4();
    let mut hw = TpuV4Model::new(42);
    let est = assets::load_or_build(
        &PathBuf::from("artifacts/assets"),
        &mut hw,
        &device,
        1200,
        3,
        42,
    )?;
    let report = est.estimate_module(&module);

    let mut t = Table::new(&["#", "op", "source", "latency us", "note"]);
    for op in &report.ops {
        t.row(&[
            op.index.to_string(),
            op.op_name.clone(),
            op.source.tag().to_string(),
            format!("{:.3}", op.latency_us),
            op.note.chars().take(40).collect(),
        ]);
    }
    println!("{}", t.markdown());
    println!(
        "\nestimated whole-model latency: {:.2} us\n  systolic {:.2} us | elementwise {:.2} us | other {:.2} us | coverage {:.0}%",
        report.total_us,
        report.systolic_us,
        report.elementwise_us,
        report.other_us,
        report.coverage() * 100.0
    );
    Ok(())
}
