//! Whole-model design-space sweep — the "architectural exploration" use
//! case the paper positions SCALE-Sim for: sweep batch size / sequence
//! length for the bundled model topologies, reporting calibrated latency,
//! utilisation, energy, and the dense-vs-2:4-sparse trade-off.
//!
//! Run with: `cargo run --release --example model_sweep`

use scalesim_tpu::experiments::fig2;
use scalesim_tpu::report::Table;
use scalesim_tpu::scalesim::{
    estimate_energy, simulate_gemm, simulate_sparse, EnergyParams, ScaleConfig, Sparsity,
};
use scalesim_tpu::tpu::TpuV4Model;
use scalesim_tpu::workloads::models;

fn main() {
    let config = ScaleConfig::tpu_v4();
    let energy_params = EnergyParams::default();

    // Calibrate once so the sweep reports wall-clock, not just cycles.
    let mut hw = TpuV4Model::new(42);
    let calibration = fig2::run(&mut hw, &config, 3).calibration;

    // --- MLP batch sweep ---
    println!("MLP 784-512-256-10: batch-size sweep\n");
    let mut t = Table::new(&[
        "batch",
        "cycles",
        "latency us",
        "avg util %",
        "energy uJ",
        "2:4-sparse speedup",
    ]);
    for batch in [1usize, 8, 32, 128, 512] {
        let topo = models::mlp(batch);
        let mut cycles = 0u64;
        let mut latency = 0.0f64;
        let mut util_sum = 0.0f64;
        let mut energy = 0.0f64;
        let mut sparse_cycles = 0u64;
        for layer in &topo.layers {
            let g = layer.as_gemm();
            let r = simulate_gemm(&config, g);
            cycles += r.total_cycles();
            latency += calibration.cycles_to_us(&g, r.total_cycles());
            util_sum += r.utilisation;
            energy += estimate_energy(&energy_params, &r).total_uj();
            sparse_cycles +=
                simulate_sparse(&config, g, Sparsity::two_four_weights()).effective_cycles;
        }
        t.row(&[
            batch.to_string(),
            cycles.to_string(),
            format!("{latency:.1}"),
            format!("{:.1}", 100.0 * util_sum / topo.layers.len() as f64),
            format!("{energy:.1}"),
            format!("{:.2}x", cycles as f64 / sparse_cycles as f64),
        ]);
    }
    println!("{}", t.markdown());

    // --- Transformer sequence-length sweep ---
    println!("\ntransformer block (d_model=512, heads=8): sequence-length sweep\n");
    let mut t = Table::new(&["seq", "cycles", "latency us", "GEMM count", "energy uJ"]);
    for seq in [64usize, 128, 256, 512, 1024] {
        let topo = models::transformer_block(seq, 512, 8);
        let mut cycles = 0u64;
        let mut latency = 0.0;
        let mut energy = 0.0;
        for layer in &topo.layers {
            let g = layer.as_gemm();
            let r = simulate_gemm(&config, g);
            cycles += r.total_cycles();
            latency += calibration.cycles_to_us(&g, r.total_cycles());
            energy += estimate_energy(&energy_params, &r).total_uj();
        }
        t.row(&[
            seq.to_string(),
            cycles.to_string(),
            format!("{latency:.1}"),
            topo.layers.len().to_string(),
            format!("{energy:.1}"),
        ]);
    }
    println!("{}", t.markdown());

    // --- ResNet stem across dataflows ---
    println!("\nResNet-18 topology (topologies/resnet18_stem.csv): dataflow comparison\n");
    let csv = std::fs::read_to_string("topologies/resnet18_stem.csv")
        .unwrap_or_else(|_| models::resnet_stem_csv().to_string());
    let topo = scalesim_tpu::scalesim::Topology::parse_csv("resnet", &csv).unwrap();
    let mut t = Table::new(&["dataflow", "total cycles", "total energy uJ"]);
    for df in ["os", "ws", "is"] {
        let mut c = config.clone();
        c.dataflow = scalesim_tpu::scalesim::Dataflow::parse(df).unwrap();
        let mut cycles = 0u64;
        let mut energy = 0.0;
        for layer in &topo.layers {
            let r = simulate_gemm(&c, layer.as_gemm());
            cycles += r.total_cycles();
            energy += estimate_energy(&energy_params, &r).total_uj();
        }
        t.row(&[
            df.to_uppercase(),
            cycles.to_string(),
            format!("{energy:.0}"),
        ]);
    }
    println!("{}", t.markdown());
}
