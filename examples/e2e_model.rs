//! END-TO-END driver: proves all layers compose on real workloads.
//!
//! For every AOT workload built by `make artifacts`:
//!
//!  1. the L1/L2 Pallas+JAX executable (`<name>.hlo.txt`) is loaded and
//!     **executed** on the PJRT CPU client, timed (median-of-N) — the
//!     *measured* latency;
//!  2. the compiler-view StableHLO (`<name>.stablehlo.txt`) is parsed,
//!     classified and routed through SCALE-Sim + the learned models,
//!     with the cycle→time calibration built against the *same* PJRT
//!     backend — the *predicted* latency;
//!  3. predicted vs measured are compared per workload.
//!
//! This is the paper's whole pipeline (Fig. 1) with the loop closed on
//! real executions. Results are recorded in EXPERIMENTS.md §E2E.
//!
//! Run with: `cargo run --release --example e2e_model`

use std::path::Path;

use scalesim_tpu::coordinator::Estimator;
use scalesim_tpu::device::DeviceSpec;
use scalesim_tpu::experiments::assets;
use scalesim_tpu::frontend::parse_module;
use scalesim_tpu::report::Table;
use scalesim_tpu::runtime::{f32_literal, Literal, Runtime};
use scalesim_tpu::tpu::PjrtHardware;
use scalesim_tpu::util::stats;

const WORKLOADS: [&str; 5] = [
    "gemm_m512_k512_n512",
    "gemm_m128_k256_n512",
    "ew_add_1024x1024",
    "ew_relu_1024x1024",
    "mlp_b32",
];
// The transformer block exercises the parser/estimator too, but its
// interpret-mode Pallas HLO is slow on CPU; it is included when
// E2E_FULL=1.
const EXTRA: [&str; 1] = ["transformer_s128_d256_h4"];

fn main() -> anyhow::Result<()> {
    let artifacts = Path::new("artifacts");
    if !artifacts.join("BUILD_STAMP").exists() {
        anyhow::bail!("no artifacts found — run `make artifacts` first");
    }

    // --- Calibrate against the same backend we will measure on. ---
    println!("[1/3] calibrating SCALE-Sim against real PJRT executions...");
    let assets_dir = artifacts.join("assets_pjrt");
    let est: Estimator = if assets_dir.join("calibration.json").exists() {
        println!("      (cached: {})", assets_dir.display());
        assets::load_assets(&assets_dir)?
    } else {
        let mut hw = PjrtHardware::new()?;
        let est = assets::build_estimator_fast(&mut hw, &DeviceSpec::tpu_v4(), 3, 42);
        assets::save_assets(&assets_dir, &est)?;
        est
    };
    for (regime, m) in &est.calibration.metrics {
        println!("      {regime}: {m}");
    }

    // --- Run + predict each workload. ---
    println!("\n[2/3] executing workloads on PJRT and predicting via the simulator...");
    let runtime = Runtime::cpu()?;
    let mut rows = Vec::new();
    let full = std::env::var("E2E_FULL").as_deref() == Ok("1");
    let names: Vec<&str> = WORKLOADS
        .iter()
        .chain(if full { EXTRA.iter() } else { [].iter() })
        .copied()
        .collect();

    for name in names {
        let stablehlo_path = artifacts.join(format!("{name}.stablehlo.txt"));
        let hlo_path = artifacts.join(format!("{name}.hlo.txt"));
        if !stablehlo_path.exists() || !hlo_path.exists() {
            println!("      skipping {name} (artifact missing)");
            continue;
        }

        // Predicted: parse the compiler's StableHLO, route through models.
        let module = parse_module(&std::fs::read_to_string(&stablehlo_path)?)?;
        let report = est.estimate_module(&module);

        // Measured: execute the Pallas-path HLO on PJRT.
        let exe = runtime.compile_file(&hlo_path)?;
        let inputs: Vec<Literal> = module
            .entry()
            .expect("entry fn")
            .arg_types
            .iter()
            .enumerate()
            .map(|(i, t)| f32_literal(&t.dims, move |j| ((i + j) % 13) as f32 * 0.1 - 0.6))
            .collect::<anyhow::Result<_>>()?;
        let times = exe.time_us(&inputs, 2, 7)?;
        let measured = stats::median(&times);

        let err_pct = 100.0 * (report.total_us - measured) / measured;
        rows.push((
            name.to_string(),
            module.entry().unwrap().ops.len(),
            report.total_us,
            measured,
            err_pct,
            report.coverage() * 100.0,
        ));
        println!(
            "      {name}: predicted {:.1} us, measured {:.1} us ({:+.0}%)",
            report.total_us, measured, err_pct
        );
    }

    // --- Summary. ---
    println!("\n[3/3] summary (predicted = StableHLO->SCALE-Sim+learned, measured = PJRT):\n");
    let mut t = Table::new(&[
        "workload",
        "ops",
        "predicted us",
        "measured us",
        "error %",
        "coverage %",
    ]);
    for (name, ops, pred, meas, err, cov) in &rows {
        t.row(&[
            name.clone(),
            ops.to_string(),
            format!("{pred:.1}"),
            format!("{meas:.1}"),
            format!("{err:+.0}"),
            format!("{cov:.0}"),
        ]);
    }
    println!("{}", t.markdown());

    let errs: Vec<f64> = rows.iter().map(|r| r.4.abs()).collect();
    if !errs.is_empty() {
        println!(
            "median |error| = {:.1}%  (n = {})",
            stats::median(&errs),
            errs.len()
        );
    }
    println!("\nNOTE: measured numbers are PJRT *CPU* executions of the Pallas");
    println!("interpret-mode HLO — the substitution documented in DESIGN.md;");
    println!("the pipeline (measure -> calibrate -> parse -> route -> predict)");
    println!("is exactly the paper's, closed end-to-end on real executions.");
    Ok(())
}
