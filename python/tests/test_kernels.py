"""L1 correctness: every Pallas kernel against its pure-jnp oracle.

This is the core build-time correctness signal: the same kernels are
lowered into the AOT artifacts the Rust runtime executes, so agreement
here certifies the numbers the whole stack produces.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import elementwise_pallas as ew
from compile.kernels import matmul_pallas as mm
from compile.kernels import ref

KEY = jax.random.PRNGKey(1234)


def rand(shape, dtype=jnp.float32, key=KEY):
    return jax.random.normal(key, shape, jnp.float32).astype(dtype)


def tol(dtype):
    return dict(rtol=2e-2, atol=2e-1) if dtype == jnp.bfloat16 else dict(rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# matmul
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("m,k,n", [
    (8, 8, 8),
    (128, 128, 128),       # exactly one MXU tile
    (256, 128, 384),       # multi-tile, tile-aligned
    (96, 160, 224),        # ragged: forces divisor fallback
    (1, 784, 512),         # vector-matrix
    (33, 7, 129),          # awkward primes
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_matmul_matches_ref(m, k, n, dtype):
    x = rand((m, k), dtype)
    y = rand((k, n), dtype, key=jax.random.PRNGKey(99))
    out = mm.matmul(x, y)
    assert out.dtype == dtype
    assert out.shape == (m, n)
    np.testing.assert_allclose(
        np.asarray(out, np.float32),
        np.asarray(ref.matmul_ref(x, y), np.float32),
        **tol(dtype),
    )


@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(1, 96),
    k=st.integers(1, 96),
    n=st.integers(1, 96),
    seed=st.integers(0, 2**31 - 1),
)
def test_matmul_hypothesis_shapes(m, k, n, seed):
    """Property: the kernel agrees with the oracle on arbitrary shapes."""
    key = jax.random.PRNGKey(seed)
    kx, ky = jax.random.split(key)
    x = jax.random.normal(kx, (m, k), jnp.float32)
    y = jax.random.normal(ky, (k, n), jnp.float32)
    np.testing.assert_allclose(
        np.asarray(mm.matmul(x, y)),
        np.asarray(ref.matmul_ref(x, y)),
        rtol=2e-4,
        atol=2e-4,
    )


def test_matmul_tile_helper():
    assert mm._pick_tile(256, 128) == 128
    assert mm._pick_tile(96, 128) == 96
    assert mm._pick_tile(97, 128) == 97   # prime: single tile
    assert mm._pick_tile(160, 128) == 80  # largest divisor <= 128


def test_matmul_vmem_budget():
    # One double-buffered 128^3 step must fit comfortably in 16 MiB VMEM.
    assert mm.matmul_vmem_bytes() == 3 * 128 * 128 * 2 * 2
    assert mm.matmul_vmem_bytes() < 16 * 1024 * 1024


# ---------------------------------------------------------------------------
# elementwise
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("shape", [(8, 128), (256, 384), (100, 50), (1, 1)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_add_matches_ref(shape, dtype):
    x = rand(shape, dtype)
    y = rand(shape, dtype, key=jax.random.PRNGKey(7))
    np.testing.assert_allclose(
        np.asarray(ew.add(x, y), np.float32),
        np.asarray(ref.add_ref(x, y), np.float32),
        **tol(dtype),
    )


@pytest.mark.parametrize("shape", [(8, 128), (512, 512), (33, 65)])
def test_relu_matches_ref(shape):
    x = rand(shape)
    out = ew.relu(x)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref.relu_ref(x)))
    assert (np.asarray(out) >= 0).all()


@settings(max_examples=20, deadline=None)
@given(
    rows=st.integers(1, 300),
    cols=st.integers(1, 300),
    seed=st.integers(0, 2**31 - 1),
)
def test_elementwise_hypothesis(rows, cols, seed):
    key = jax.random.PRNGKey(seed)
    ka, kb = jax.random.split(key)
    x = jax.random.normal(ka, (rows, cols), jnp.float32)
    y = jax.random.normal(kb, (rows, cols), jnp.float32)
    np.testing.assert_allclose(np.asarray(ew.add(x, y)), np.asarray(x + y), rtol=1e-6)
    np.testing.assert_allclose(
        np.asarray(ew.relu(x)), np.asarray(ref.relu_ref(x)), rtol=1e-6
    )


@pytest.mark.parametrize("shape", [(32, 512), (128, 256), (7, 13)])
def test_bias_relu_matches_ref(shape):
    x = rand(shape)
    b = rand((shape[1],), key=jax.random.PRNGKey(3))
    np.testing.assert_allclose(
        np.asarray(ew.bias_relu(x, b)),
        np.asarray(ref.bias_relu_ref(x, b)),
        rtol=1e-5,
        atol=1e-5,
    )


def test_kernels_preserve_dtype():
    for dtype in (jnp.float32, jnp.bfloat16):
        x = rand((16, 128), dtype)
        assert ew.add(x, x).dtype == dtype
        assert ew.relu(x).dtype == dtype


# ---------------------------------------------------------------------------
# softmax
# ---------------------------------------------------------------------------

from compile.kernels import softmax_pallas as sm  # noqa: E402


@pytest.mark.parametrize("shape", [(8, 128), (64, 512), (13, 77), (1, 1)])
def test_softmax_matches_ref(shape):
    x = rand(shape, key=jax.random.PRNGKey(21)) * 5.0
    out = sm.softmax(x)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(ref.softmax_ref(x)), rtol=1e-5, atol=1e-6
    )
    # Rows sum to one.
    np.testing.assert_allclose(np.asarray(out).sum(axis=-1), 1.0, rtol=1e-5)


def test_softmax_numerically_stable():
    # Large logits must not overflow.
    x = jnp.full((8, 128), 1.0e4, jnp.float32)
    out = sm.softmax(x)
    assert np.isfinite(np.asarray(out)).all()
    np.testing.assert_allclose(np.asarray(out), 1.0 / 128.0, rtol=1e-5)


@settings(max_examples=15, deadline=None)
@given(rows=st.integers(1, 64), cols=st.integers(1, 256), seed=st.integers(0, 2**31 - 1))
def test_softmax_hypothesis(rows, cols, seed):
    x = jax.random.normal(jax.random.PRNGKey(seed), (rows, cols), jnp.float32)
    np.testing.assert_allclose(
        np.asarray(sm.softmax(x)), np.asarray(ref.softmax_ref(x)), rtol=1e-5, atol=1e-6
    )
