"""L2 correctness: the Pallas-backed models against their jnp oracles,
and registry integrity (both lowering paths of every workload agree)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.kernels import ref

KEY = jax.random.PRNGKey(0)


def test_mlp_matches_ref():
    params = model.mlp_params(KEY)
    x = jax.random.normal(jax.random.PRNGKey(5), (32, model.MLP_DIMS[0]), jnp.float32)
    out = model.mlp(x, params)
    expected = model.mlp_ref_apply(x, params)
    assert out.shape == (32, model.MLP_DIMS[-1])
    np.testing.assert_allclose(np.asarray(out), np.asarray(expected), rtol=5e-4, atol=5e-4)


def test_mlp_batch_sizes():
    params = model.mlp_params(KEY)
    for b in (1, 8, 57):
        x = jax.random.normal(jax.random.PRNGKey(b), (b, model.MLP_DIMS[0]), jnp.float32)
        out = model.mlp(x, params)
        assert out.shape == (b, 10)
        assert np.isfinite(np.asarray(out)).all()


def test_transformer_block_matches_ref():
    params = model.transformer_params(KEY, d_model=128, heads=4)
    x = jax.random.normal(jax.random.PRNGKey(9), (64, 128), jnp.float32)
    out = model.transformer_block(x, params)
    expected = ref.transformer_block_ref(x, params)
    assert out.shape == x.shape
    np.testing.assert_allclose(np.asarray(out), np.asarray(expected), rtol=1e-3, atol=1e-3)


def test_transformer_residual_structure():
    """Zeroing the projection weights must reduce the block to identity +
    FFN bias terms — a structural sanity check on the residual wiring."""
    params = model.transformer_params(KEY, d_model=64, heads=2)
    params = dict(params)
    params["w_out"] = jnp.zeros_like(params["w_out"])
    params["w_down"] = jnp.zeros_like(params["w_down"])
    x = jax.random.normal(jax.random.PRNGKey(2), (16, 64), jnp.float32)
    out = model.transformer_block(x, params)
    expected = x + params["b_down"]
    np.testing.assert_allclose(np.asarray(out), np.asarray(expected), rtol=1e-5, atol=1e-5)


def test_registry_paths_agree():
    """Every workload's Pallas path and ref path compute the same function
    (this is what legitimises lowering stablehlo from ref and hlo from
    Pallas in aot.py)."""
    for name, (pallas_fn, ref_fn, shapes) in model.registry().items():
        inputs = [
            jax.random.normal(jax.random.PRNGKey(i), s.shape, jnp.float32).astype(s.dtype)
            for i, s in enumerate(shapes)
        ]
        got = pallas_fn(*inputs)[0]
        want = ref_fn(*inputs)[0]
        np.testing.assert_allclose(
            np.asarray(got, np.float32),
            np.asarray(want, np.float32),
            rtol=2e-3,
            atol=2e-3,
            err_msg=name,
        )


def test_registry_covers_paper_workloads():
    names = set(model.registry().keys())
    assert any(n.startswith("gemm_") for n in names)
    assert "mlp_b32" in names
    assert "transformer_s128_d256_h4" in names
    assert any(n.startswith("ew_add") for n in names)
    assert any(n.startswith("ew_relu") for n in names)


@pytest.mark.parametrize("d_model,heads", [(64, 1), (128, 8), (256, 4)])
def test_transformer_head_configs(d_model, heads):
    params = model.transformer_params(KEY, d_model=d_model, heads=heads)
    x = jax.random.normal(jax.random.PRNGKey(1), (32, d_model), jnp.float32)
    out = model.transformer_block(x, params)
    assert out.shape == (32, d_model)
    assert np.isfinite(np.asarray(out)).all()
