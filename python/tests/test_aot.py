"""AOT pipeline: both artifact flavours are emitted and have the expected
structure (classifiable StableHLO for the simulator, parseable HLO text
for the PJRT runtime)."""

import pathlib
import tempfile

from compile import aot, model
import jax


def test_stablehlo_text_has_classifiable_ops():
    _, ref_fn, shapes = model.registry()["gemm_m128_k256_n512"]
    text = aot.to_stablehlo_text(jax.jit(ref_fn).lower(*shapes))
    assert "stablehlo.dot_general" in text
    assert "tensor<128x256xf32>" in text
    assert "func.func public @main" in text


def test_hlo_text_loadable_format():
    pallas_fn, _, shapes = model.registry()["gemm_m128_k256_n512"]
    text = aot.to_hlo_text(jax.jit(pallas_fn).lower(*shapes))
    assert text.startswith("HloModule")
    # return_tuple=True: the root computation returns a tuple.
    assert "ROOT" in text


def test_build_subset(tmp_path=None):
    tmp = pathlib.Path(tempfile.mkdtemp(prefix="scalesim_aot_test"))
    written = aot.build_all(tmp, names=["ew_add_1024x1024"])
    assert written == ["ew_add_1024x1024"]
    st = (tmp / "ew_add_1024x1024.stablehlo.txt").read_text()
    hlo = (tmp / "ew_add_1024x1024.hlo.txt").read_text()
    assert "stablehlo.add" in st
    assert hlo.startswith("HloModule")
    assert (tmp / "BUILD_STAMP").read_text().strip() == "ew_add_1024x1024"


def test_mlp_stablehlo_mentions_all_layers():
    _, ref_fn, shapes = model.registry()["mlp_b32"]
    text = aot.to_stablehlo_text(jax.jit(ref_fn).lower(*shapes))
    # Three matmuls and two ReLUs in the standard lowering.
    assert text.count("stablehlo.dot_general") == 3
    assert text.count("stablehlo.maximum") >= 2
