"""L2: JAX workload definitions (the paper's compute graphs).

Every workload here is authored in JAX, calls the L1 Pallas kernels for
its systolic hot-spots, and is lowered ONCE by aot.py into:

  * ``*.stablehlo.txt`` — the simulator's input (frontend/ parses it);
  * ``*.hlo.txt``       — the runtime's executable (runtime/ runs it).

Python never runs on the request path; these functions exist only at
build time.
"""

import jax
import jax.numpy as jnp

from .kernels import elementwise_pallas as ew
from .kernels import matmul_pallas as mm
from .kernels import ref
from .kernels import softmax_pallas as sm

# ---------------------------------------------------------------------------
# Plain GEMM workloads (Fig. 2 / Fig. 4 kernels)
# ---------------------------------------------------------------------------


def gemm(x, y):
    """The systolic micro-benchmark: one tiled-Pallas GEMM."""
    return mm.matmul(x, y)


def gemm_shapes(m, k, n, dtype=jnp.float32):
    return (
        jax.ShapeDtypeStruct((m, k), dtype),
        jax.ShapeDtypeStruct((k, n), dtype),
    )


# ---------------------------------------------------------------------------
# Elementwise workloads (Fig. 3 / Fig. 5 kernels)
# ---------------------------------------------------------------------------


def ew_add(x, y):
    return ew.add(x, y)


def ew_relu(x):
    return ew.relu(x)


# ---------------------------------------------------------------------------
# MLP (whole-model workload #1)
# ---------------------------------------------------------------------------

MLP_DIMS = (784, 512, 256, 10)


def mlp_params(key, dtype=jnp.float32):
    """He-initialised parameters for the 784-512-256-10 MLP."""
    ks = jax.random.split(key, 3)
    d = MLP_DIMS
    scale = lambda fan_in: (2.0 / fan_in) ** 0.5
    return {
        "w1": jax.random.normal(ks[0], (d[0], d[1]), dtype) * scale(d[0]),
        "b1": jnp.zeros((d[1],), dtype),
        "w2": jax.random.normal(ks[1], (d[1], d[2]), dtype) * scale(d[1]),
        "b2": jnp.zeros((d[2],), dtype),
        "w3": jax.random.normal(ks[2], (d[2], d[3]), dtype) * scale(d[2]),
        "b3": jnp.zeros((d[3],), dtype),
    }


def mlp(x, params):
    """3-layer MLP: Pallas GEMMs + fused Pallas bias+ReLU epilogues."""
    h1 = ew.bias_relu(mm.matmul(x, params["w1"]), params["b1"])
    h2 = ew.bias_relu(mm.matmul(h1, params["w2"]), params["b2"])
    return mm.matmul(h2, params["w3"]) + params["b3"]


def mlp_ref_apply(x, params):
    """Oracle MLP (pure jnp) with the same parameter pytree."""
    return ref.mlp_ref(
        x,
        params["w1"], params["b1"],
        params["w2"], params["b2"],
        params["w3"], params["b3"],
    )


# ---------------------------------------------------------------------------
# Transformer block (whole-model workload #2)
# ---------------------------------------------------------------------------


def transformer_params(key, d_model=256, heads=4, dtype=jnp.float32):
    ks = jax.random.split(key, 4)
    scale = lambda fan_in: (2.0 / fan_in) ** 0.5
    d_ff = 4 * d_model
    return {
        "heads": heads,
        "ln1_g": jnp.ones((d_model,), dtype),
        "ln1_b": jnp.zeros((d_model,), dtype),
        "w_qkv": jax.random.normal(ks[0], (d_model, 3 * d_model), dtype) * scale(d_model),
        "w_out": jax.random.normal(ks[1], (d_model, d_model), dtype) * scale(d_model),
        "ln2_g": jnp.ones((d_model,), dtype),
        "ln2_b": jnp.zeros((d_model,), dtype),
        "w_up": jax.random.normal(ks[2], (d_model, d_ff), dtype) * scale(d_model),
        "b_up": jnp.zeros((d_ff,), dtype),
        "w_down": jax.random.normal(ks[3], (d_ff, d_model), dtype) * scale(d_ff),
        "b_down": jnp.zeros((d_model,), dtype),
    }


def transformer_block(x, params):
    """Pre-LN transformer block with Pallas GEMMs on the hot matmuls.

    The attention score/value matmuls run per head at (seq, d_head)
    granularity — exactly the batched GEMMs the frontend classifies from
    dot_general batching dims.
    """
    _, d_model = x.shape
    heads = params["heads"]
    d_head = d_model // heads

    h = ref.layernorm_ref(x, params["ln1_g"], params["ln1_b"])
    qkv = mm.matmul(h, params["w_qkv"])
    q, k, v = jnp.split(qkv, 3, axis=-1)

    outs = []
    for i in range(heads):
        sl = slice(i * d_head, (i + 1) * d_head)
        qi, ki, vi = q[:, sl], k[:, sl], v[:, sl]
        scale = jnp.asarray(1.0 / (d_head ** 0.5), dtype=x.dtype)
        scores = mm.matmul(qi, ki.T) * scale
        outs.append(mm.matmul(sm.softmax(scores), vi))
    attn = jnp.concatenate(outs, axis=-1)
    x = x + mm.matmul(attn, params["w_out"])

    h = ref.layernorm_ref(x, params["ln2_g"], params["ln2_b"])
    up = ew.relu(mm.matmul(h, params["w_up"]) + params["b_up"])
    return x + mm.matmul(up, params["w_down"]) + params["b_down"]


# ---------------------------------------------------------------------------
# Workload registry used by aot.py
# ---------------------------------------------------------------------------


def transformer_block_ref_apply(x, params):
    return ref.transformer_block_ref(x, params)


def registry(key=None):
    """name -> (pallas_fn, ref_fn, example ShapeDtypeStructs).

    ``pallas_fn`` is the execution path (hand-tiled Pallas kernels) and is
    lowered to the ``*.hlo.txt`` runtime artifact. ``ref_fn`` is the
    standard jnp lowering — the compiler's own view of the model — and is
    lowered to the ``*.stablehlo.txt`` simulator input (dot_general /
    add / maximum ops the frontend classifies). Both compute the same
    function; pytest asserts they agree numerically.
    """
    key = key if key is not None else jax.random.PRNGKey(0)

    mlp_p = mlp_params(key)
    tf_p = transformer_params(key, d_model=256, heads=4)

    workloads = {}
    for m, k, n in [(512, 512, 512), (128, 256, 512)]:
        workloads[f"gemm_m{m}_k{k}_n{n}"] = (
            lambda x, y: (gemm(x, y),),
            lambda x, y: (ref.matmul_ref(x, y),),
            gemm_shapes(m, k, n),
        )

    workloads["mlp_b32"] = (
        lambda x: (mlp(x, mlp_p),),
        lambda x: (mlp_ref_apply(x, mlp_p),),
        (jax.ShapeDtypeStruct((32, MLP_DIMS[0]), jnp.float32),),
    )

    workloads["transformer_s128_d256_h4"] = (
        lambda x: (transformer_block(x, tf_p),),
        lambda x: (ref.transformer_block_ref(x, tf_p),),
        (jax.ShapeDtypeStruct((128, 256), jnp.float32),),
    )

    workloads["ew_add_1024x1024"] = (
        lambda x, y: (ew_add(x, y),),
        lambda x, y: (ref.add_ref(x, y),),
        (
            jax.ShapeDtypeStruct((1024, 1024), jnp.float32),
            jax.ShapeDtypeStruct((1024, 1024), jnp.float32),
        ),
    )

    workloads["ew_relu_1024x1024"] = (
        lambda x: (ew_relu(x),),
        lambda x: (ref.relu_ref(x),),
        (jax.ShapeDtypeStruct((1024, 1024), jnp.float32),),
    )

    return workloads
