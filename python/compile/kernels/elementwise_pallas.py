"""L1: blocked elementwise Pallas kernels (VPU-path ops).

The non-systolic operators the paper's learned models cover. Blocks are
(8, 128)-aligned — the TPU vector-lane tile — so the BlockSpecs express
the same layout the VPU model in rust/src/tpu/vpu.rs assumes.
"""

import os

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# (8, 128)-aligned VPU blocks; SCALESIM_AOT_TILE scales them up for the
# CPU-PJRT artifact builds where interpret-mode grid steps dominate.
_SCALE = max(1, int(os.environ.get("SCALESIM_AOT_TILE", "128")) // 128)
BLOCK_ROWS = 256 * _SCALE   # multiple of 8 sublanes
BLOCK_COLS = 128 * _SCALE   # whole lane tiles


def _add_kernel(x_ref, y_ref, o_ref):
    o_ref[...] = x_ref[...] + y_ref[...]


def _relu_kernel(x_ref, o_ref):
    x = x_ref[...]
    o_ref[...] = jnp.maximum(x, jnp.zeros_like(x))


def _bias_relu_kernel(x_ref, b_ref, o_ref):
    x = x_ref[...] + b_ref[...]
    o_ref[...] = jnp.maximum(x, jnp.zeros_like(x))


def _pick(dim: int, tile: int) -> int:
    t = min(dim, tile)
    while dim % t != 0:
        t -= 1
    return t


def _grid_2d(shape):
    rows, cols = shape
    br = _pick(rows, BLOCK_ROWS)
    bc = _pick(cols, BLOCK_COLS)
    return (rows // br, cols // bc), (br, bc)


@jax.jit
def add(x, y):
    """Elementwise x + y over a 2-D tensor, blocked for VMEM."""
    assert x.shape == y.shape and x.ndim == 2
    grid, (br, bc) = _grid_2d(x.shape)
    spec = pl.BlockSpec((br, bc), lambda i, j: (i, j))
    return pl.pallas_call(
        _add_kernel,
        grid=grid,
        in_specs=[spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=True,
    )(x, y)


@jax.jit
def relu(x):
    """Elementwise max(x, 0) over a 2-D tensor."""
    assert x.ndim == 2
    grid, (br, bc) = _grid_2d(x.shape)
    spec = pl.BlockSpec((br, bc), lambda i, j: (i, j))
    return pl.pallas_call(
        _relu_kernel,
        grid=grid,
        in_specs=[spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=True,
    )(x)


@jax.jit
def bias_relu(x, b):
    """Fused bias-add + ReLU: the MLP layer epilogue, one VMEM pass.

    ``b`` is broadcast along rows (bias per output feature).
    """
    assert x.ndim == 2 and b.shape == (x.shape[1],)
    grid, (br, bc) = _grid_2d(x.shape)
    spec = pl.BlockSpec((br, bc), lambda i, j: (i, j))
    bspec = pl.BlockSpec((1, bc), lambda i, j: (0, j))
    return pl.pallas_call(
        _bias_relu_kernel,
        grid=grid,
        in_specs=[spec, bspec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=True,
    )(x, b.reshape(1, -1))
