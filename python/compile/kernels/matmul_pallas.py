"""L1: tiled GEMM Pallas kernel (the paper's systolic hot-spot).

The kernel expresses exactly the schedule SCALE-Sim's weight-stationary
model (and the TPU v4 MXU) assumes: 128x128 output tiles, a K-loop that
accumulates partial products tile by tile, and BlockSpecs describing the
HBM->VMEM movement per grid step.

VMEM budget per grid step (bf16): bm*bk + bk*bn + bm*bn words
 = 3 * 128^2 * 2 B = 96 KiB  <<  16 MiB/core, leaving room for
double-buffering (see DESIGN.md section Perf for the roofline estimate).

``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls, so the kernel lowers to plain HLO; on a real TPU the same
code object compiles to the MXU.
"""

import functools
import os

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# MXU-aligned default tile sizes. On a real TPU 128 matches the MXU; for
# the CPU-PJRT artifacts the interpret-mode grid dominates runtime, so
# `SCALESIM_AOT_TILE` lets aot.py build with larger tiles (512 cuts the
# 512^3 GEMM from 34.6 ms to 2.5 ms on CPU — EXPERIMENTS.md section Perf L1).
TILE_M = int(os.environ.get("SCALESIM_AOT_TILE", "128"))
TILE_N = TILE_M
TILE_K = TILE_M


def _matmul_kernel(x_ref, y_ref, o_ref, *, nk: int):
    """One (i, j, k) grid step: accumulate x_tile @ y_tile into o_tile.

    Grid iteration order is row-major, so for a fixed output tile (i, j)
    the k steps run consecutively: initialise on k == 0, accumulate after.
    """
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...].astype(jnp.float32)
    y = y_ref[...].astype(jnp.float32)
    o_ref[...] += jnp.dot(x, y).astype(o_ref.dtype)
    _ = nk  # nk kept for symmetry with flush-style kernels


def _pick_tile(dim: int, tile: int) -> int:
    """Largest divisor of ``dim`` that is <= tile (shape-agnostic tiling)."""
    t = min(dim, tile)
    while dim % t != 0:
        t -= 1
    return t


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk"))
def matmul(x, y, *, bm: int = TILE_M, bn: int = TILE_N, bk: int = TILE_K):
    """C[M,N] = A[M,K] @ B[K,N] via the tiled Pallas kernel.

    Tile sizes self-adjust to divide the problem (ragged shapes fall back
    to smaller divisors, mirroring SCALE-Sim's ragged folds).
    """
    m, k = x.shape
    k2, n = y.shape
    assert k == k2, f"contraction mismatch {k} vs {k2}"

    bm = _pick_tile(m, bm)
    bn = _pick_tile(n, bn)
    bk = _pick_tile(k, bk)
    nk = k // bk

    # Accumulate in float32 (MXU-style) regardless of input dtype; cast
    # back once at the end so bf16 inputs don't round between K tiles.
    out_f32 = pl.pallas_call(
        functools.partial(_matmul_kernel, nk=nk),
        grid=(m // bm, n // bn, nk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(x, y)
    return out_f32.astype(x.dtype)


def matmul_vmem_bytes(bm: int = TILE_M, bn: int = TILE_N, bk: int = TILE_K,
                      dtype_bytes: int = 2, double_buffered: bool = True) -> int:
    """Static VMEM footprint of one grid step (perf-analysis helper)."""
    words = bm * bk + bk * bn + bm * bn
    factor = 2 if double_buffered else 1
    return words * dtype_bytes * factor
