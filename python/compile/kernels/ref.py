"""Pure-jnp oracles for the Pallas kernels.

These are the correctness references: every Pallas kernel in this package
is pytest-checked against the corresponding function here (see
python/tests/test_kernels.py). They are also the "un-fused baseline" used
by the L2 model tests.
"""

import jax.numpy as jnp


def matmul_ref(x, y):
    """C = A @ B in float32 accumulation, cast back to the input dtype."""
    acc = jnp.matmul(x.astype(jnp.float32), y.astype(jnp.float32))
    return acc.astype(x.dtype)


def add_ref(x, y):
    return x + y


def relu_ref(x):
    return jnp.maximum(x, jnp.zeros_like(x))


def bias_relu_ref(x, b):
    """Fused bias + ReLU (the MLP's per-layer epilogue)."""
    return relu_ref(x + b)


def mlp_ref(x, w1, b1, w2, b2, w3, b3):
    """3-layer MLP with ReLU activations (logits output, no softmax)."""
    h1 = bias_relu_ref(matmul_ref(x, w1), b1)
    h2 = bias_relu_ref(matmul_ref(h1, w2), b2)
    return matmul_ref(h2, w3) + b3


def layernorm_ref(x, gamma, beta, eps=1e-5):
    mean = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mean) / jnp.sqrt(var + eps) * gamma + beta


def softmax_ref(x):
    x = x - jnp.max(x, axis=-1, keepdims=True)
    e = jnp.exp(x)
    return e / jnp.sum(e, axis=-1, keepdims=True)


def attention_ref(q, k, v):
    """Single-head scaled dot-product attention over (seq, d_head)."""
    d = q.shape[-1]
    scale = jnp.asarray(1.0 / (d ** 0.5), dtype=q.dtype)
    scores = matmul_ref(q, k.T) * scale
    return matmul_ref(softmax_ref(scores), v)


def transformer_block_ref(x, params):
    """Pre-LN transformer block: LN -> MHA -> residual -> LN -> FFN -> residual.

    ``params`` is the dict produced by model.transformer_params.
    """
    _, d_model = x.shape
    heads = params["heads"]
    d_head = d_model // heads

    h = layernorm_ref(x, params["ln1_g"], params["ln1_b"])
    qkv = matmul_ref(h, params["w_qkv"])  # (seq, 3*d_model)
    q, k, v = jnp.split(qkv, 3, axis=-1)

    outs = []
    for i in range(heads):
        sl = slice(i * d_head, (i + 1) * d_head)
        outs.append(attention_ref(q[:, sl], k[:, sl], v[:, sl]))
    attn = jnp.concatenate(outs, axis=-1)
    x = x + matmul_ref(attn, params["w_out"])

    h = layernorm_ref(x, params["ln2_g"], params["ln2_b"])
    up = relu_ref(matmul_ref(h, params["w_up"]) + params["b_up"])
    return x + matmul_ref(up, params["w_down"]) + params["b_down"]
