"""L1: row-wise softmax Pallas kernel.

The attention score normalisation of the transformer workload. Each grid
step holds a block of rows with the *full* row in VMEM (numerically
stable three-pass softmax: max, exp-sum, divide — fused into one kernel
so scores stream through VMEM once instead of four times for the naive
max/sub/exp/div op chain).

VMEM per step: BLOCK_ROWS x row_len words — for attention rows up to 4k
f32 that is <= 4 MiB, comfortably inside a TPU core's VMEM.
"""

import os

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_SCALE = max(1, int(os.environ.get("SCALESIM_AOT_TILE", "128")) // 128)
BLOCK_ROWS = 8 * _SCALE


def _softmax_kernel(x_ref, o_ref):
    x = x_ref[...].astype(jnp.float32)
    m = jnp.max(x, axis=-1, keepdims=True)
    e = jnp.exp(x - m)
    s = jnp.sum(e, axis=-1, keepdims=True)
    o_ref[...] = (e / s).astype(o_ref.dtype)


def _pick(dim: int, tile: int) -> int:
    t = min(dim, tile)
    while dim % t != 0:
        t -= 1
    return t


@jax.jit
def softmax(x):
    """Row-wise softmax over the last dim of a 2-D tensor."""
    assert x.ndim == 2
    rows, cols = x.shape
    br = _pick(rows, BLOCK_ROWS)
    return pl.pallas_call(
        _softmax_kernel,
        grid=(rows // br,),
        in_specs=[pl.BlockSpec((br, cols), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((br, cols), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=True,
    )(x)
