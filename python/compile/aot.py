"""AOT lowering: JAX workloads -> StableHLO text + HLO text artifacts.

Run once via ``make artifacts``; Python never executes on the request
path. Two artifacts per workload:

  artifacts/<name>.stablehlo.txt   simulator INPUT (frontend/ parses it)
  artifacts/<name>.hlo.txt         runtime EXECUTABLE (PJRT loads it)

HLO *text* — NOT ``HloModuleProto.serialize()`` — is the interchange
format: jax >= 0.5 emits protos with 64-bit instruction ids which the
crate's xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text
parser reassigns ids and round-trips cleanly. Lowered with
``return_tuple=True`` so the Rust side unwraps a 1-tuple (see
/opt/xla-example/README.md).
"""

import argparse
import pathlib

import jax
from jax._src.lib import xla_client as xc

from . import model


def to_stablehlo_text(lowered) -> str:
    return str(lowered.compiler_ir("stablehlo"))


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # print_large_constants=True: the default HLO printer ELIDES big
    # literals as `constant({...})`, which the text parser silently reads
    # back as zeros — the embedded weights must survive the text round
    # trip.
    return comp.as_hlo_text(print_large_constants=True)


def build_all(out_dir: pathlib.Path, names=None) -> list[str]:
    out_dir.mkdir(parents=True, exist_ok=True)
    written = []
    for name, (pallas_fn, ref_fn, shapes) in model.registry().items():
        if names and name not in names:
            continue
        # Simulator input: the compiler's standard lowering (dot_general,
        # add, maximum — what the frontend classifies).
        stablehlo = to_stablehlo_text(jax.jit(ref_fn).lower(*shapes))
        # Runtime executable: the hand-tiled Pallas path.
        hlo = to_hlo_text(jax.jit(pallas_fn).lower(*shapes))
        (out_dir / f"{name}.stablehlo.txt").write_text(stablehlo)
        (out_dir / f"{name}.hlo.txt").write_text(hlo)
        written.append(name)
        print(f"  {name}: stablehlo {len(stablehlo)} B, hlo {len(hlo)} B")
    # Build stamp consumed by the Makefile.
    (out_dir / "BUILD_STAMP").write_text("\n".join(written) + "\n")
    return written


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--out-dir", default="../artifacts")
    parser.add_argument("--only", nargs="*", default=None,
                        help="subset of workload names to build")
    args = parser.parse_args()
    written = build_all(pathlib.Path(args.out_dir), args.only)
    print(f"built {len(written)} workloads -> {args.out_dir}")


if __name__ == "__main__":
    main()
