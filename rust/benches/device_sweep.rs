//! Benchmark: per-module estimate throughput across the device presets.
//!
//! The DeviceSpec refactor threads a device through the estimator's hot
//! path (fingerprint in every cache key, the elementwise transfer
//! scale), so this bench guards against per-op spec-lookup overhead
//! creeping in: it measures warm-cache module estimates per second on
//! each preset, plus the cold-cache retarget cost, over the checked-in
//! BERT-layer fixture. `harness = false` like the other benches (no
//! criterion in the offline registry). Run via
//! `cargo bench --bench device_sweep` or `make bench-devices`.

use std::time::Instant;

use scalesim_tpu::calibrate::fit_regime_calibration;
use scalesim_tpu::coordinator::Estimator;
use scalesim_tpu::device::DeviceSpec;
use scalesim_tpu::frontend::parse_module;
use scalesim_tpu::scalesim::{GemmShape, ScaleConfig};

const BERT: &str = include_str!("../tests/fixtures/bert_layer.mlir");

fn estimator() -> Estimator {
    let mut obs = Vec::new();
    for d in [32usize, 64, 96, 128, 256, 512, 1024, 2048, 4096] {
        let g = GemmShape::new(d, d, d);
        obs.push((g, (d * d) as u64, (d * d) as f64 * 1e-3 + 1.0));
    }
    Estimator::new(ScaleConfig::tpu_v4(), fit_regime_calibration(&obs).unwrap())
}

fn main() {
    let base = estimator();
    let module = parse_module(BERT).expect("bert fixture parses");
    let ops = module.entry().map(|f| f.ops.len()).unwrap_or(0);
    let iters = 2_000usize;

    for spec in DeviceSpec::presets() {
        // Retarget + first (cold) walk: what one new device costs.
        let t0 = Instant::now();
        let est = base.retarget(&spec);
        let cold = est.estimate_module(&module);
        let cold_us = t0.elapsed().as_secs_f64() * 1e6;

        // Warm walks: the serve steady state (shared cache, all hits).
        let t1 = Instant::now();
        let mut checksum = 0.0f64;
        for _ in 0..iters {
            checksum += est.estimate_module(&module).total_us;
        }
        let dt = t1.elapsed().as_secs_f64();
        println!(
            "device {} ({ops} ops): cold {cold_us:.0} us, warm {:.1} us/estimate, {:.0} estimates/s (total {:.2} us, checksum {checksum:.1})",
            spec.name,
            dt * 1e6 / iters as f64,
            iters as f64 / dt,
            cold.total_us,
        );
    }

    // All presets share the base cache: entries must accumulate per
    // device, never alias (4 devices x same shapes).
    let stats = base.cache.stats();
    println!(
        "shared cache after sweep: {} entries, {} hits, {} misses ({:.1}% hit rate)",
        stats.entries,
        stats.hits,
        stats.misses,
        stats.hit_rate() * 100.0
    );
}
