//! Benchmark: batched estimator core vs the scalar per-op walk.
//!
//! Measures whole-module estimation throughput on the bert_layer fixture
//! four ways — scalar vs batched, cache-cold (memoisation disabled) vs
//! cache-warm — plus the pre-lowered [`OpTable`] reuse path, asserting
//! bit-identical totals between every pair before reporting. Results are
//! published to `BENCH_estimator.json` at the repo root together with an
//! FNV-1a fingerprint of this source file; `cargo bench --bench
//! estimator_batch -- --check` re-reads the file and fails when it is
//! missing or stale against the source (the CI freshness gate).
//! `harness = false` like the other benches (no criterion in the offline
//! registry). Run via `make bench-estimator`; the headline speedup is
//! recorded in EXPERIMENTS.md §Perf Batched estimator.

use std::time::Instant;

use scalesim_tpu::coordinator::{Estimator, ModelEstimate, OpTable};
use scalesim_tpu::device::DeviceSpec;
use scalesim_tpu::frontend::{parse_module, ModuleInfo};
use scalesim_tpu::sweep::sweep_estimator;
use scalesim_tpu::util::json::Json;

const SOURCE: &str = include_str!("estimator_batch.rs");
const FIXTURE: &str = include_str!("../tests/fixtures/bert_layer.mlir");

const COLD_ITERS: usize = 300;
const WARM_ITERS: usize = 3000;

fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

fn source_fingerprint() -> String {
    format!("{:016x}", fnv1a(SOURCE.as_bytes()))
}

fn bench_json_path() -> std::path::PathBuf {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("../BENCH_estimator.json")
}

/// `--check`: the published numbers must exist and match this source.
fn check_published() {
    let path = bench_json_path();
    let text = std::fs::read_to_string(&path).unwrap_or_else(|e| {
        panic!(
            "BENCH_estimator.json missing at {} ({e}); run `make bench-estimator`",
            path.display()
        )
    });
    let json = Json::parse(&text).expect("BENCH_estimator.json is not valid JSON");
    let published = json
        .get("source_fingerprint")
        .and_then(Json::as_str)
        .expect("BENCH_estimator.json lacks source_fingerprint");
    let current = source_fingerprint();
    assert_eq!(
        published,
        current,
        "BENCH_estimator.json is stale: published fingerprint {published} != \
         bench source {current}; re-run `make bench-estimator` and commit the result"
    );
    println!(
        "BENCH_estimator.json is fresh (source fingerprint {current}, \
         speedup_warm {})",
        json.get("speedup_warm").and_then(Json::as_f64).unwrap_or(0.0)
    );
}

fn assert_identical(a: &ModelEstimate, b: &ModelEstimate, what: &str) {
    assert_eq!(
        a.total_us.to_bits(),
        b.total_us.to_bits(),
        "{what}: totals diverge"
    );
    assert_eq!(a.ops.len(), b.ops.len(), "{what}: row counts diverge");
    for (x, y) in a.ops.iter().zip(&b.ops) {
        assert_eq!(
            x.latency_us.to_bits(),
            y.latency_us.to_bits(),
            "{what}: row {} diverges",
            x.op_name
        );
    }
}

/// (seconds total, last estimate) for `iters` runs of `f`.
fn time<F: FnMut() -> ModelEstimate>(iters: usize, mut f: F) -> (f64, ModelEstimate) {
    let mut last = f(); // warm-up run, also primes the cache when enabled
    let t0 = Instant::now();
    for _ in 0..iters {
        last = f();
    }
    (t0.elapsed().as_secs_f64(), last)
}

struct Scenario {
    name: &'static str,
    seconds: f64,
    iters: usize,
}

impl Scenario {
    fn per_module_us(&self) -> f64 {
        self.seconds * 1e6 / self.iters as f64
    }
    fn modules_per_sec(&self) -> f64 {
        self.iters as f64 / self.seconds
    }
}

fn run_bench() {
    let module: ModuleInfo = parse_module(FIXTURE).expect("bert_layer fixture parses");
    let est: Estimator = sweep_estimator(&DeviceSpec::tpu_v4());
    let ops = est.estimate_module(&module).ops.len();
    println!("== batched estimator core: bert_layer ({ops} rows) ==");

    // Cache-cold: memoisation off, every op re-simulated every time.
    est.cache.set_enabled(false);
    let (scalar_cold_s, scalar_cold) = time(COLD_ITERS, || est.estimate_module_scalar(&module));
    let (batched_cold_s, batched_cold) = time(COLD_ITERS, || est.estimate_module(&module));
    assert_identical(&scalar_cold, &batched_cold, "cold scalar vs batched");

    // Cache-warm: memoisation on, the warm-up run inside time() primes it.
    est.cache.set_enabled(true);
    let (scalar_warm_s, scalar_warm) = time(WARM_ITERS, || est.estimate_module_scalar(&module));
    let (batched_warm_s, batched_warm) = time(WARM_ITERS, || est.estimate_module(&module));
    assert_identical(&scalar_warm, &batched_warm, "warm scalar vs batched");
    assert_identical(&scalar_cold, &scalar_warm, "cold vs warm");

    // Pre-lowered table reuse: classify/dedup once, estimate many times.
    let table: OpTable<'_> = est.lower_module(&module);
    let (table_warm_s, table_warm) = time(WARM_ITERS, || est.estimate_table(&table));
    assert_identical(&scalar_warm, &table_warm, "warm scalar vs table");

    let scenarios = [
        Scenario { name: "scalar_cold", seconds: scalar_cold_s, iters: COLD_ITERS },
        Scenario { name: "batched_cold", seconds: batched_cold_s, iters: COLD_ITERS },
        Scenario { name: "scalar_warm", seconds: scalar_warm_s, iters: WARM_ITERS },
        Scenario { name: "batched_warm", seconds: batched_warm_s, iters: WARM_ITERS },
        Scenario { name: "table_warm", seconds: table_warm_s, iters: WARM_ITERS },
    ];
    for s in &scenarios {
        println!(
            "  {:<13} {:>9.1} µs/module  ({:>8.0} modules/s)",
            s.name,
            s.per_module_us(),
            s.modules_per_sec()
        );
    }
    let speedup_cold = scalar_cold_s / batched_cold_s;
    let speedup_warm = scalar_warm_s / batched_warm_s;
    let speedup_table = scalar_warm_s / table_warm_s;
    println!(
        "  speedup: cold {speedup_cold:.2}x, warm {speedup_warm:.2}x, \
         pre-lowered table {speedup_table:.2}x"
    );

    let mut o = Json::obj();
    o.set("bench", Json::Str("estimator_batch".into()))
        .set("module", Json::Str("bert_layer".into()))
        .set("rows", Json::Num(ops as f64))
        .set("cold_iters", Json::Num(COLD_ITERS as f64))
        .set("warm_iters", Json::Num(WARM_ITERS as f64))
        .set("speedup_cold", Json::Num(speedup_cold))
        .set("speedup_warm", Json::Num(speedup_warm))
        .set("speedup_table", Json::Num(speedup_table))
        .set("source_fingerprint", Json::Str(source_fingerprint()));
    let mut per = Json::obj();
    for s in &scenarios {
        let mut sj = Json::obj();
        sj.set("per_module_us", Json::Num(s.per_module_us()))
            .set("modules_per_sec", Json::Num(s.modules_per_sec()));
        per.set(s.name, sj);
    }
    o.set("scenarios", per);

    let path = bench_json_path();
    std::fs::write(&path, format!("{}\n", o.dump())).expect("writing BENCH_estimator.json");
    println!("wrote {}", path.display());
}

fn main() {
    if std::env::args().any(|a| a == "--check") {
        check_published();
    } else {
        run_bench();
    }
}
