//! Benchmark: dependence-graph scheduling throughput.
//!
//! The scheduler sits on the serve hot path (every single-chip module
//! request now also answers a scheduled total), so its per-module cost
//! matters. With a warm shape cache the estimator lookups are O(1), and
//! the headline number is schedules/second over (a) the checked-in
//! BERT-layer fixture and (b) a synthetic 1000-op chain-with-diamonds
//! module. `harness = false` like benches/paper.rs (no criterion in the
//! offline registry). Run via `cargo bench --bench schedule` or
//! `make bench-schedule`.

use std::time::Instant;

use scalesim_tpu::calibrate::fit_regime_calibration;
use scalesim_tpu::coordinator::Estimator;
use scalesim_tpu::frontend::{parse_module, ModuleInfo};
use scalesim_tpu::graph::{schedule_estimate, EngineConfig};
use scalesim_tpu::scalesim::{GemmShape, ScaleConfig};

const BERT: &str = include_str!("../tests/fixtures/bert_layer.mlir");

fn estimator() -> Estimator {
    let mut obs = Vec::new();
    for d in [32usize, 64, 96, 128, 256, 512, 1024, 2048, 4096] {
        let g = GemmShape::new(d, d, d);
        obs.push((g, (d * d) as u64, (d * d) as f64 * 1e-3 + 1.0));
    }
    Estimator::new(ScaleConfig::tpu_v4(), fit_regime_calibration(&obs).unwrap())
}

/// A deep synthetic module: alternating elementwise ops and periodic
/// dots, each op consuming the previous result plus a two-back value
/// (so the DAG has both a long chain and cross-links).
fn synthetic_module(n_ops: usize) -> String {
    let mut body = String::new();
    let mut prev = "a".to_string();
    let mut prev2 = "b".to_string();
    for i in 0..n_ops {
        let op = match i % 4 {
            0 => format!(
                "    %v{i} = stablehlo.add %{prev}, %{prev2} : tensor<256x256xf32>\n"
            ),
            1 => format!(
                "    %v{i} = stablehlo.multiply %{prev}, %{prev2} : tensor<256x256xf32>\n"
            ),
            2 => format!(
                "    %v{i} = stablehlo.transpose %{prev}, dims = [1, 0] : (tensor<256x256xf32>) -> tensor<256x256xf32>\n"
            ),
            _ => format!(
                "    %v{i} = stablehlo.dot_general %{prev}, %{prev2}, contracting_dims = [1] x [0] : (tensor<256x256xf32>, tensor<256x256xf32>) -> tensor<256x256xf32>\n"
            ),
        };
        body.push_str(&op);
        prev2 = prev;
        prev = format!("v{i}");
    }
    format!(
        "module @synthetic {{\n  func.func @main(%a: tensor<256x256xf32>, %b: tensor<256x256xf32>) -> tensor<256x256xf32> {{\n{body}    return %{prev} : tensor<256x256xf32>\n  }}\n}}"
    )
}

fn bench_module(est: &Estimator, module: &ModuleInfo, label: &str, iters: usize) {
    // One estimation walk up front; the loop then measures pure
    // scheduling (DAG build + placement + analyses), which is what the
    // serve path pays per request once the shape cache is warm.
    let report = est.estimate_module(module);
    for config in [EngineConfig::Serialized, EngineConfig::ComputeIci, EngineConfig::Tpu] {
        let t0 = Instant::now();
        let mut checksum = 0.0f64;
        for _ in 0..iters {
            checksum += schedule_estimate(module, &report, config).makespan_us;
        }
        let dt = t0.elapsed().as_secs_f64();
        println!(
            "schedule {label} ({} ops, {}): {:.1} us/schedule, {:.0} schedules/s (checksum {checksum:.1})",
            module.entry().map(|f| f.ops.len()).unwrap_or(0),
            config.name(),
            dt * 1e6 / iters as f64,
            iters as f64 / dt,
        );
    }
}

fn main() {
    let est = estimator();

    let bert = parse_module(BERT).expect("bert fixture parses");
    bench_module(&est, &bert, "bert_layer", 5_000);

    let text = synthetic_module(1_000);
    let big = parse_module(&text).expect("synthetic module parses");
    bench_module(&est, &big, "synthetic_1k", 200);
}
