//! Schedule-template reuse micro-benchmark: from-scratch prompt-length
//! re-costing (module rewrite + full pipeline rebuild per length)
//! versus one captured [`ScheduleTemplate`] replayed per length.
//!
//! This is the core loop behind `PhaseModel::prefill_us` and therefore
//! the `bench-llm` serving throughput. The bench asserts bit-identity
//! between the two paths before reporting the speedup, so a regression
//! in exactness fails loudly here as well as in the invariant suite.
//! Compiled by the CI "Benches compile" step; run manually with
//! `cargo bench --bench llm_reuse`.

use std::time::Instant;

use scalesim_tpu::device::DeviceSpec;
use scalesim_tpu::frontend::parse_module;
use scalesim_tpu::graph::{EngineConfig, ScheduleTemplate};
use scalesim_tpu::inference::{rewrite_seq, sequence_dim};
use scalesim_tpu::memory::{schedule_module_memory, MemoryConfig};
use scalesim_tpu::sweep::sweep_estimator;

const FIXTURE: &str = include_str!("../tests/fixtures/decoder_block.mlir");
const PROMPTS: &[usize] = &[1, 16, 32, 64, 96, 128, 192, 256, 384, 512, 768, 1024];
const ITERS: usize = 20;

fn main() {
    let module = parse_module(FIXTURE).expect("fixture parses");
    let spec = DeviceSpec::preset("tpu-v4").expect("registered preset");
    let est = sweep_estimator(&spec);
    let engine = EngineConfig::for_device(est.device());
    let memory = MemoryConfig::new(est.hbm_bytes_per_us(), Some(est.device().vmem_bytes));
    let seq = sequence_dim(&module).expect("fixture has a sequence extent");

    // From scratch: clone-and-rewrite the module, then re-classify,
    // re-estimate, re-build the DAG and re-expand the timeline — per
    // prompt length, every iteration.
    let start = Instant::now();
    let mut scratch_sum = 0.0_f64;
    for _ in 0..ITERS {
        for &p in PROMPTS {
            let m = rewrite_seq(&module, seq, p);
            scratch_sum += schedule_module_memory(&est, &m, engine, &memory).makespan_us();
        }
    }
    let scratch_s = start.elapsed().as_secs_f64();

    // Template: capture once, replay per prompt length (shape-column
    // rewrite + one batched estimate + one schedule replay).
    let template = ScheduleTemplate::capture(&module, engine, memory).expect("template captures");
    let start = Instant::now();
    let mut reuse_sum = 0.0_f64;
    for _ in 0..ITERS {
        for &p in PROMPTS {
            reuse_sum += template.recost_seq(&est, seq, p).makespan_us();
        }
    }
    let reuse_s = start.elapsed().as_secs_f64();

    assert_eq!(
        scratch_sum.to_bits(),
        reuse_sum.to_bits(),
        "template re-cost drifted from the from-scratch pipeline"
    );

    let n = (ITERS * PROMPTS.len()) as f64;
    println!(
        "llm_reuse: {} prompt lengths x {ITERS} iters on {} ({} leaf ops)",
        PROMPTS.len(),
        spec.name,
        template.leaf_count()
    );
    println!(
        "  from-scratch: {:>10.1} recosts/s  ({scratch_s:.3}s)",
        n / scratch_s
    );
    println!(
        "  template:     {:>10.1} recosts/s  ({reuse_s:.3}s, {} replays)",
        n / reuse_s,
        template.template_hits()
    );
    println!("  speedup: {:.2}x (bit-identical results)", scratch_s / reuse_s);
}
