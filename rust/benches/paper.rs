//! Benchmark harness: regenerates every paper table/figure headline and
//! times the hot paths, plus the ablations DESIGN.md calls out.
//!
//! The offline registry has no criterion, so this is a `harness = false`
//! binary with its own timing loop (warmup + median-of-N). Run via
//! `cargo bench` or `cargo bench -- <filter>`.

use std::time::Instant;

use scalesim_tpu::calibrate::{fit_global, fit_regime_calibration, Regime};
use scalesim_tpu::coordinator::{serve_lines, Estimator};
use scalesim_tpu::experiments::{fig2, fig3, fig4, fig5};
use scalesim_tpu::frontend::{parse_module, EwKind};
use scalesim_tpu::learned::{feature_names, featurize, Hgbr, HgbrParams};
use scalesim_tpu::scalesim::{
    simulate_gemm, simulate_partitioned, Dataflow, GemmShape, PartitionAxis, ScaleConfig,
};
use scalesim_tpu::tpu::TpuV4Model;
use scalesim_tpu::util::stats;

/// Time `f` with warmup; report median / p10 / p90 over `reps`.
fn bench<F: FnMut()>(name: &str, reps: usize, mut f: F) {
    for _ in 0..3.min(reps) {
        f();
    }
    let mut times = Vec::with_capacity(reps);
    for _ in 0..reps {
        let t0 = Instant::now();
        f();
        times.push(t0.elapsed().as_secs_f64() * 1e6);
    }
    let med = stats::median(&times);
    let p10 = stats::percentile(&times, 10.0);
    let p90 = stats::percentile(&times, 90.0);
    let rate = if med > 0.0 { 1e6 / med } else { f64::INFINITY };
    println!("  {name:<52} {med:>10.2} us/iter  (p10 {p10:.2}, p90 {p90:.2})  {rate:>10.0}/s");
}

fn filter_match(filter: &Option<String>, section: &str) -> bool {
    match filter {
        Some(f) => section.contains(f.as_str()),
        None => true,
    }
}

fn main() {
    // `cargo bench -- <filter>` passes the filter after a `--bench` flag
    // soup; just take the first non-flag arg.
    let filter: Option<String> = std::env::args().skip(1).find(|a| !a.starts_with("--"));
    let config = ScaleConfig::tpu_v4();

    if filter_match(&filter, "hotpath") {
        println!("== hotpath: core simulator kernels ==");
        let small = GemmShape::new(64, 64, 64);
        let medium = GemmShape::new(512, 512, 512);
        let large = GemmShape::new(4096, 4096, 4096);
        bench("simulate_gemm small (64^3)", 2000, || {
            std::hint::black_box(simulate_gemm(&config, small));
        });
        bench("simulate_gemm medium (512^3)", 2000, || {
            std::hint::black_box(simulate_gemm(&config, medium));
        });
        bench("simulate_gemm large (4096^3)", 2000, || {
            std::hint::black_box(simulate_gemm(&config, large));
        });
        bench("simulate_partitioned 4 cores (4096^3)", 1000, || {
            std::hint::black_box(simulate_partitioned(&config, large, 4, PartitionAxis::M));
        });

        let mlp_text = std::fs::read_to_string("artifacts/mlp_b32.stablehlo.txt").ok();
        if let Some(text) = &mlp_text {
            let mb = text.len() as f64 / 1e6;
            let t0 = Instant::now();
            let mut n = 0;
            while t0.elapsed().as_secs_f64() < 2.0 {
                std::hint::black_box(parse_module(text).unwrap());
                n += 1;
            }
            let per = t0.elapsed().as_secs_f64() / n as f64;
            println!(
                "  parse_module mlp ({:.1} MB)                           {:>10.2} us/iter  {:>8.1} MB/s",
                mb,
                per * 1e6,
                mb / per
            );
        } else {
            println!("  (artifacts missing — run `make artifacts` for parser benches)");
        }

        // HGBR inference.
        let mut hw = TpuV4Model::new(1);
        let ds = fig5::collect_dataset(&mut hw, EwKind::Add, 400, 1, 7);
        let (rows, y) = ds.features_targets();
        let model = Hgbr::fit(&rows, &y, &feature_names(), &HgbrParams::default());
        let row = featurize(&[777, 333]);
        bench("hgbr predict (tree walk)", 20000, || {
            std::hint::black_box(model.predict(&row));
        });
        let compiled = model.compile();
        bench("hgbr predict (compiled, flat SoA)", 20000, || {
            std::hint::black_box(compiled.predict(&row));
        });
        bench("featurize", 20000, || {
            std::hint::black_box(featurize(&[12, 345, 678]));
        });
    }

    if filter_match(&filter, "coordinator") {
        println!("\n== coordinator: batch service throughput ==");
        let mut hw = TpuV4Model::new(1);
        let f2 = fig2::run(&mut hw, &config, 1);
        let est = std::sync::Arc::new(Estimator::new(config.clone(), f2.calibration));
        let lines: Vec<String> = (0..256)
            .map(|i| {
                format!(
                    r#"{{"type":"gemm","m":{},"k":{},"n":{}}}"#,
                    128 + i % 512,
                    128 + (i * 3) % 512,
                    128 + (i * 7) % 512
                )
            })
            .collect();
        for workers in [1usize, 4, 8] {
            let est = est.clone();
            let lines = lines.clone();
            bench(&format!("serve 256 gemm requests ({workers} workers)"), 30, || {
                std::hint::black_box(serve_lines(est.clone(), &lines, workers));
            });
        }

        // Heavier per-item work (a full module estimate each): where the
        // pool's parallelism actually pays.
        let module_text = r#"
module @w { func.func @main(%a: tensor<512x784xf32>, %w1: tensor<784x512xf32>, %w2: tensor<512x256xf32>) -> tensor<512x256xf32> {
  %0 = stablehlo.dot_general %a, %w1, contracting_dims = [1] x [0] : (tensor<512x784xf32>, tensor<784x512xf32>) -> tensor<512x512xf32>
  %1 = stablehlo.maximum %0, %0 : tensor<512x512xf32>
  %2 = stablehlo.dot_general %1, %w2, contracting_dims = [1] x [0] : (tensor<512x512xf32>, tensor<512x256xf32>) -> tensor<512x256xf32>
  return %2 : tensor<512x256xf32>
} }"#;
        let modules: Vec<String> = (0..64).map(|_| module_text.to_string()).collect();
        for workers in [1usize, 4, 8] {
            let est2 = est.clone();
            bench(
                &format!("estimate 64 parsed modules ({workers} workers)"),
                20,
                || {
                    let out = scalesim_tpu::coordinator::parallel_map(&modules, workers, |text| {
                        let m = parse_module(text).unwrap();
                        est2.estimate_module(&m).total_us
                    });
                    std::hint::black_box(out);
                },
            );
        }
    }

    if filter_match(&filter, "table1") {
        println!("\n== table1 ==");
        println!("{}", scalesim_tpu::experiments::table1::render());
    }

    if filter_match(&filter, "fig2") {
        println!("\n== fig2: per-regime calibration (headline) ==");
        let mut hw = TpuV4Model::new(42);
        let t0 = Instant::now();
        let r = fig2::run(&mut hw, &config, 5);
        for p in &r.panels {
            println!(
                "  {}: R2={:.4} alpha={:.3e} beta={:.2} n={}",
                p.regime, p.metrics.r2, p.fit.alpha, p.fit.beta, p.metrics.n
            );
        }
        println!("  paper: R2 ~0.79 small, >0.97 medium/large");
        println!("  [fig2 regenerated in {:.2}s]", t0.elapsed().as_secs_f64());
    }

    if filter_match(&filter, "fig3") {
        println!("\n== fig3: elementwise sweeps (headline) ==");
        let mut hw = TpuV4Model::new(42);
        let t0 = Instant::now();
        let r = fig3::run(&mut hw, 5);
        println!(
            "  1D pearson r = {:.4}, 2D pearson r = {:.4}, same-size spread = {:.2}%",
            r.linearity_1d,
            r.linearity_2d,
            r.max_same_size_spread * 100.0
        );
        println!("  paper: near-linear scaling with minor shape-dependent fluctuations");
        println!("  [fig3 regenerated in {:.2}s]", t0.elapsed().as_secs_f64());
    }

    if filter_match(&filter, "fig4") {
        println!("\n== fig4: held-out cycle-to-latency accuracy (headline) ==");
        let mut hw = TpuV4Model::new(42);
        let t0 = Instant::now();
        let f2 = fig2::run(&mut hw, &config, 5);
        let r = fig4::run(&mut hw, &config, &f2.calibration, 5);
        println!(
            "  R2 = {:.3} (paper 0.893), MAPE = {:.1}% (paper 32.2%)",
            r.overall.r2, r.overall.mape_pct
        );
        for (regime, mape) in &r.per_regime_mape {
            println!("    {regime}: MAPE {mape:.1}%");
        }
        println!("  [fig4 regenerated in {:.2}s]", t0.elapsed().as_secs_f64());
    }

    if filter_match(&filter, "fig5") {
        println!("\n== fig5: learned elementwise models (headline) ==");
        let mut hw = TpuV4Model::new(42);
        let t0 = Instant::now();
        let r = fig5::run(&mut hw, 1200, 5, 42);
        for e in &r.evals {
            println!(
                "  {:<8}: R2={:.4} medAE={:.2}us medRE={:.2}%   (linear baseline medRE={:.2}%)",
                e.op.name(),
                e.metrics.r2,
                e.metrics.median_abs_err,
                e.metrics.median_rel_err_pct,
                e.linear_baseline.median_rel_err_pct
            );
        }
        println!("  paper: add R2=0.9973 medRE=1.78%; relu R2=0.9980 medRE=2.55%");
        println!("  [fig5 regenerated in {:.2}s]", t0.elapsed().as_secs_f64());
    }

    if filter_match(&filter, "ablation") {
        println!("\n== ablations (DESIGN.md) ==");

        // (a) Dataflow choice. NOTE: the fig2 sweep is symmetric under
        // dim permutations, where OS/WS/IS tie by construction — so the
        // ablation runs on *asymmetric* real-model layers (ResNet stem via
        // im2col + transformer block GEMMs), where the choice matters.
        println!("  dataflow ablation (total cycles, resnet-stem + transformer):");
        let topo_r = scalesim_tpu::scalesim::Topology::parse_csv(
            "resnet_stem",
            scalesim_tpu::workloads::models::resnet_stem_csv(),
        )
        .unwrap();
        let topo_t = scalesim_tpu::workloads::models::transformer_block(512, 512, 8);
        for df in [
            Dataflow::OutputStationary,
            Dataflow::WeightStationary,
            Dataflow::InputStationary,
        ] {
            let mut c = config.clone();
            c.dataflow = df;
            let total: u64 = topo_r
                .layers
                .iter()
                .chain(topo_t.layers.iter())
                .map(|l| simulate_gemm(&c, l.as_gemm()).total_cycles())
                .sum();
            println!("    {df}: {total} cycles");
        }

        // (b) Per-regime vs single global cycle->time regression.
        let mut hw = TpuV4Model::new(42);
        let mut obs = Vec::new();
        for regime in Regime::ALL {
            for o in fig2::observe_regime(&mut hw, &config, regime, 5) {
                obs.push((o.gemm, o.cycles, o.measured_us));
            }
        }
        let per_regime = fit_regime_calibration(&obs).unwrap();
        let global = fit_global(&obs).unwrap();
        let truth: Vec<f64> = obs.iter().map(|o| o.2).collect();
        let pred_pr: Vec<f64> = obs
            .iter()
            .map(|(g, c, _)| per_regime.cycles_to_us(g, *c))
            .collect();
        let pred_gl: Vec<f64> = obs.iter().map(|(_, c, _)| global.predict(*c as f64)).collect();
        println!(
            "  regression ablation: per-regime MAPE {:.1}% vs global MAPE {:.1}%",
            stats::mape(&truth, &pred_pr),
            stats::mape(&truth, &pred_gl)
        );

        // (c) Feature ablation: size-only vs size+shape features.
        let ds = fig5::collect_dataset(&mut hw, EwKind::Add, 900, 3, 13);
        let (train, test) = ds.split_by_unseen_sizes(0.8, 99);
        let (rows_full, y) = train.features_targets();
        let rows_size_only: Vec<Vec<f64>> = rows_full.iter().map(|r| r[..2].to_vec()).collect();
        let m_full = Hgbr::fit(&rows_full, &y, &feature_names(), &HgbrParams::default());
        let m_size = Hgbr::fit(
            &rows_size_only,
            &y,
            &["num_elements", "log2_elements"],
            &HgbrParams::default(),
        );
        let truth: Vec<f64> = test.samples.iter().map(|s| s.latency_us).collect();
        let pf: Vec<f64> = test
            .samples
            .iter()
            .map(|s| m_full.predict(&featurize(&s.dims)))
            .collect();
        let ps: Vec<f64> = test
            .samples
            .iter()
            .map(|s| m_size.predict(&featurize(&s.dims)[..2]))
            .collect();
        println!(
            "  feature ablation: size+shape medRE {:.2}% vs size-only medRE {:.2}%",
            stats::median_rel_error(&truth, &pf),
            stats::median_rel_error(&truth, &ps)
        );
    }

    println!("\nbenches complete.");
}
