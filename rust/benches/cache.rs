//! Benchmark: the sharded shape cache on repeated-shape traffic.
//!
//! The streaming service's workload is dominated by shape repetition
//! (many models share layer dimensions), so the headline number is
//! estimate throughput on a request mix with a small shape vocabulary,
//! cached vs uncached. `harness = false` like benches/paper.rs (no
//! criterion in the offline registry). Run via `cargo bench --bench
//! cache`; results are recorded in EXPERIMENTS.md §Perf Cache.

use std::sync::Arc;
use std::time::Instant;

use scalesim_tpu::calibrate::fit_regime_calibration;
use scalesim_tpu::coordinator::{serve_stream, Estimator, StreamOptions};
use scalesim_tpu::frontend::classify::OpClass;
use scalesim_tpu::scalesim::{GemmShape, ScaleConfig};

fn estimator() -> Arc<Estimator> {
    let mut obs = Vec::new();
    for d in [32usize, 64, 96, 128, 256, 512, 1024, 2048, 4096] {
        let g = GemmShape::new(d, d, d);
        obs.push((g, (d * d) as u64, (d * d) as f64 * 1e-3 + 1.0));
    }
    Arc::new(Estimator::new(
        ScaleConfig::tpu_v4(),
        fit_regime_calibration(&obs).unwrap(),
    ))
}

/// Transformer-ish shape vocabulary: a few dozen distinct GEMMs that
/// every request re-draws from.
fn vocabulary() -> Vec<GemmShape> {
    let mut v = Vec::new();
    for seq in [128usize, 512, 2048] {
        for d in [768usize, 1024, 4096] {
            v.push(GemmShape::new(seq, d, d));
            v.push(GemmShape::new(seq, d, 4 * d));
            v.push(GemmShape::new(seq, 4 * d, d));
        }
    }
    v
}

/// Estimate-layer throughput: raw estimate_op calls, no JSON.
fn bench_estimate_layer(reqs: usize) {
    let vocab = vocabulary();
    let classes: Vec<OpClass> = (0..reqs)
        .map(|i| OpClass::SystolicGemm {
            gemm: vocab[i % vocab.len()],
            count: 1,
        })
        .collect();

    let run = |est: &Estimator| -> (f64, f64) {
        let t0 = Instant::now();
        let mut checksum = 0.0f64;
        for c in &classes {
            checksum += est.estimate_op(0, "dot", c).latency_us;
        }
        (t0.elapsed().as_secs_f64(), checksum)
    };

    let est = estimator();
    est.cache.set_enabled(false);
    let (uncached_s, sum_u) = run(&est);

    est.cache.set_enabled(true);
    let (_prime_s, _) = run(&est); // first pass fills the 27 entries
    let (cached_s, sum_c) = run(&est);

    assert_eq!(sum_u.to_bits(), sum_c.to_bits(), "cached != uncached");
    let stats = est.cache.stats();
    println!(
        "  estimate layer, {reqs} requests over {} shapes:",
        vocabulary().len()
    );
    println!(
        "    uncached: {:>8.1} ms  ({:>9.0} req/s)",
        uncached_s * 1e3,
        reqs as f64 / uncached_s
    );
    println!(
        "    cached:   {:>8.1} ms  ({:>9.0} req/s)   speedup {:.1}x",
        cached_s * 1e3,
        reqs as f64 / cached_s,
        uncached_s / cached_s
    );
    println!(
        "    cache: {} hits / {} misses ({} entries)",
        stats.hits, stats.misses, stats.entries
    );
}

/// End-to-end streaming throughput: JSONL in, JSONL out, worker pool,
/// reorder buffer — the `scalesim-tpu serve` hot path.
fn bench_serve_stream(reqs: usize) {
    let vocab = vocabulary();
    let mut input = String::new();
    for i in 0..reqs {
        let g = vocab[i % vocab.len()];
        input.push_str(&format!(
            "{{\"type\":\"gemm\",\"m\":{},\"k\":{},\"n\":{}}}\n",
            g.m, g.k, g.n
        ));
    }
    let opts = StreamOptions {
        workers: 8,
        queue_cap: 64,
    };

    let run = |est: Arc<Estimator>| -> (f64, Vec<u8>) {
        let mut out = Vec::with_capacity(reqs * 64);
        let t0 = Instant::now();
        serve_stream(est, input.as_bytes(), &mut out, &opts).expect("serve");
        (t0.elapsed().as_secs_f64(), out)
    };

    let uncached_est = estimator();
    uncached_est.cache.set_enabled(false);
    let (uncached_s, out_u) = run(Arc::clone(&uncached_est));

    let cached_est = estimator();
    let (cached_s, out_c) = run(Arc::clone(&cached_est));

    assert_eq!(out_u, out_c, "stream outputs must be identical");
    println!("  serve_stream (8 workers), {reqs} JSONL requests:");
    println!(
        "    uncached: {:>8.1} ms  ({:>9.0} req/s)",
        uncached_s * 1e3,
        reqs as f64 / uncached_s
    );
    println!(
        "    cached:   {:>8.1} ms  ({:>9.0} req/s)   speedup {:.1}x",
        cached_s * 1e3,
        reqs as f64 / cached_s,
        uncached_s / cached_s
    );
}

fn main() {
    println!("== shape cache: repeated-shape estimate throughput ==");
    bench_estimate_layer(100_000);
    println!();
    bench_serve_stream(50_000);
}
