//! Compile-time stand-in for the `xla` PJRT bindings crate.
//!
//! The offline build registry does not carry the `xla` crate, so by
//! default the runtime layer compiles against this stub, which mirrors
//! the exact API subset that [`crate::runtime::client`] and
//! [`crate::tpu::pjrt_hw`] use. The only reachable constructor,
//! [`PjRtClient::cpu`], fails with a clear error, so every `--hardware
//! pjrt` path degrades to a clean runtime error instead of a link
//! failure. Building with `--features pjrt` (plus a vendored `xla`
//! crate) swaps the real bindings back in — see DESIGN.md
//! §Hardware-substitution.

use std::fmt;
use std::path::Path;

/// Error type standing in for the bindings' error. Implements
/// `std::error::Error` so `anyhow::Context` works on stub results.
#[derive(Debug, Clone)]
pub struct XlaError(pub String);

impl fmt::Display for XlaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for XlaError {}

fn unavailable<T>() -> Result<T, XlaError> {
    Err(XlaError(
        "PJRT support not compiled in (offline build without the `xla` crate); \
         rebuild with `--features pjrt` — see DESIGN.md §Hardware-substitution"
            .to_string(),
    ))
}

/// Stub PJRT client: construction always fails, so the `&self` methods
/// below are unreachable — they exist only to type-check the call sites.
pub struct PjRtClient {
    _priv: (),
}

impl PjRtClient {
    /// Always fails offline: PJRT is behind the `pjrt` feature.
    pub fn cpu() -> Result<PjRtClient, XlaError> {
        unavailable()
    }

    /// Unreachable on the stub.
    pub fn platform_name(&self) -> String {
        unreachable!("stub PjRtClient cannot be constructed")
    }

    /// Unreachable on the stub.
    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, XlaError> {
        unreachable!("stub PjRtClient cannot be constructed")
    }
}

/// Stub compiled executable (never constructible offline).
pub struct PjRtLoadedExecutable {
    _priv: (),
}

impl PjRtLoadedExecutable {
    /// Unreachable on the stub.
    pub fn execute<T>(&self, _inputs: &[T]) -> Result<Vec<Vec<PjRtBuffer>>, XlaError> {
        unreachable!("stub PjRtLoadedExecutable cannot be constructed")
    }
}

/// Stub device buffer (never constructible offline).
pub struct PjRtBuffer {
    _priv: (),
}

impl PjRtBuffer {
    /// Unreachable on the stub.
    pub fn to_literal_sync(&self) -> Result<Literal, XlaError> {
        unreachable!("stub PjRtBuffer cannot be constructed")
    }
}

/// Stub HLO module proto: parsing always fails (no HLO parser offline).
pub struct HloModuleProto {
    _priv: (),
}

impl HloModuleProto {
    /// Always fails offline (no HLO parser).
    pub fn parse_and_return_unverified_module(_text: &[u8]) -> Result<HloModuleProto, XlaError> {
        unavailable()
    }

    /// Always fails offline (no HLO parser).
    pub fn from_text_file<P: AsRef<Path>>(_path: P) -> Result<HloModuleProto, XlaError> {
        unavailable()
    }
}

/// Stub XLA computation handle.
pub struct XlaComputation {
    _priv: (),
}

impl XlaComputation {
    /// Wrap a (stub) proto; trivially constructible.
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _priv: () }
    }
}

/// Stub literal: carries only its shape so host-side construction
/// (`f32_literal`) still works; device-side accessors fail cleanly.
#[derive(Debug, Clone)]
pub struct Literal {
    dims: Vec<i64>,
}

impl Literal {
    /// Host-side 1-D literal (shape only).
    pub fn vec1(data: &[f32]) -> Literal {
        Literal {
            dims: vec![data.len() as i64],
        }
    }

    /// Reshape the carried dims (host-side only).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal, XlaError> {
        Ok(Literal {
            dims: dims.to_vec(),
        })
    }

    /// Always fails offline.
    pub fn shape(&self) -> Result<Shape, XlaError> {
        unavailable()
    }

    /// Always fails offline.
    pub fn to_tuple1(self) -> Result<Literal, XlaError> {
        unavailable()
    }

    /// Always fails offline.
    pub fn to_vec<T>(&self) -> Result<Vec<T>, XlaError> {
        let _ = &self.dims;
        unavailable()
    }
}

/// Stub shape mirror of the `xla` crate's type.
#[derive(Debug, Clone)]
pub enum Shape {
    /// A tuple of sub-shapes.
    Tuple(Vec<Shape>),
    /// A dense array.
    Array,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_construction_fails_with_guidance() {
        let err = PjRtClient::cpu().err().expect("stub must fail");
        let msg = format!("{err}");
        assert!(msg.contains("--features pjrt"), "{msg}");
    }

    #[test]
    fn literal_reshape_roundtrip() {
        let lit = Literal::vec1(&[1.0, 2.0, 3.0, 4.0]);
        let r = lit.reshape(&[2, 2]).unwrap();
        assert_eq!(r.dims, vec![2, 2]);
        assert!(r.clone().to_tuple1().is_err());
        assert!(r.to_vec::<f32>().is_err());
    }
}
