//! PJRT runtime: load AOT-compiled HLO artifacts (or synthesised HLO
//! text), compile them once, and execute them from the Rust request path.
//!
//! This wraps the `xla` bindings crate (stubbed offline — see
//! `super::xla_stub` and DESIGN.md §Hardware-substitution):
//! `PjRtClient::cpu()` → `HloModuleProto` (text parser — jax ≥ 0.5 protos
//! are not loadable on xla_extension 0.5.1, see python/compile/aot.py) →
//! `client.compile` → `execute`.

use std::time::Instant;

use anyhow::{Context, Result};

// Without the `pjrt` feature the offline stub stands in for the real
// bindings; the code below is identical either way.
#[cfg(not(feature = "pjrt"))]
use super::xla_stub as xla;

/// The literal type used for runtime inputs/outputs, re-exported so
/// callers never name the backend crate directly.
pub type Literal = xla::Literal;

/// A PJRT client plus compile/execute helpers.
pub struct Runtime {
    client: xla::PjRtClient,
}

/// One compiled executable.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    /// Artifact stem (e.g. `gemm_512`).
    pub name: String,
}

impl Runtime {
    /// Create a CPU PJRT client.
    pub fn cpu() -> Result<Runtime> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime { client })
    }

    /// PJRT platform name (e.g. `cpu`).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile an HLO-text module from a string.
    pub fn compile_text(&self, name: &str, hlo_text: &str) -> Result<Executable> {
        let proto = xla::HloModuleProto::parse_and_return_unverified_module(hlo_text.as_bytes())
            .with_context(|| format!("parsing HLO text for '{name}'"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling '{name}'"))?;
        Ok(Executable {
            exe,
            name: name.to_string(),
        })
    }

    /// Compile an HLO-text module from a file (an AOT artifact).
    pub fn compile_file(&self, path: &std::path::Path) -> Result<Executable> {
        let proto = xla::HloModuleProto::from_text_file(path)
            .with_context(|| format!("loading HLO artifact {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))?;
        Ok(Executable {
            exe,
            name: path
                .file_stem()
                .map(|s| s.to_string_lossy().into_owned())
                .unwrap_or_else(|| "artifact".to_string()),
        })
    }
}

/// Build an f32 literal of the given shape filled with a simple pattern.
pub fn f32_literal(dims: &[usize], fill: impl Fn(usize) -> f32) -> Result<xla::Literal> {
    let n: usize = dims.iter().product::<usize>().max(1);
    let data: Vec<f32> = (0..n).map(fill).collect();
    let lit = xla::Literal::vec1(&data);
    if dims.is_empty() {
        // Rank-0: reshape to scalar.
        return lit.reshape(&[]).context("reshape to scalar");
    }
    let dims_i64: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
    lit.reshape(&dims_i64).context("reshape literal")
}

impl Executable {
    /// Execute with the given inputs; returns the raw output literals.
    pub fn run(&self, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let bufs = self
            .exe
            .execute::<xla::Literal>(inputs)
            .with_context(|| format!("executing '{}'", self.name))?;
        let mut outs = Vec::new();
        for b in &bufs[0] {
            outs.push(b.to_literal_sync()?);
        }
        Ok(outs)
    }

    /// Execute once and return the first output as a f32 vec, unwrapping a
    /// 1-tuple if the module was lowered with `return_tuple=True`.
    pub fn run_f32(&self, inputs: &[xla::Literal]) -> Result<Vec<f32>> {
        let outs = self.run(inputs)?;
        let first = outs
            .into_iter()
            .next()
            .context("executable produced no outputs")?;
        let is_tuple = matches!(first.shape(), Ok(xla::Shape::Tuple(_)));
        if is_tuple {
            Ok(first.to_tuple1()?.to_vec::<f32>()?)
        } else {
            Ok(first.to_vec::<f32>()?)
        }
    }

    /// Time the executable: `warmup` unmeasured runs, then `reps` measured
    /// runs; returns per-run latencies in microseconds.
    pub fn time_us(&self, inputs: &[xla::Literal], warmup: usize, reps: usize) -> Result<Vec<f64>> {
        for _ in 0..warmup {
            let bufs = self.exe.execute::<xla::Literal>(inputs)?;
            // Force completion.
            let _ = bufs[0][0].to_literal_sync()?;
        }
        let mut times = Vec::with_capacity(reps);
        for _ in 0..reps {
            let start = Instant::now();
            let bufs = self.exe.execute::<xla::Literal>(inputs)?;
            let _ = bufs[0][0].to_literal_sync()?;
            times.push(start.elapsed().as_secs_f64() * 1e6);
        }
        Ok(times)
    }
}

// These tests execute real kernels, so they only run with the real
// bindings compiled in.
#[cfg(all(test, feature = "pjrt"))]
mod tests {
    use super::*;
    use crate::runtime::hlo_gen;
    use crate::util::stats;

    // The xla client is !Send (Rc internally), so each test builds its own.
    fn runtime() -> Runtime {
        Runtime::cpu().expect("PJRT CPU client")
    }

    #[test]
    fn gemm_numerics() {
        let rt = runtime();
        let exe = rt.compile_text("gemm", &hlo_gen::gemm_hlo(2, 2, 2)).unwrap();
        // A = [[1,2],[3,4]], B = I.
        let a = f32_literal(&[2, 2], |i| (i + 1) as f32).unwrap();
        let b = f32_literal(&[2, 2], |i| if i == 0 || i == 3 { 1.0 } else { 0.0 }).unwrap();
        let out = exe.run_f32(&[a, b]).unwrap();
        assert_eq!(out, vec![1.0, 2.0, 3.0, 4.0]);
    }

    #[test]
    fn elementwise_add_numerics() {
        let rt = runtime();
        let exe = rt
            .compile_text("add", &hlo_gen::binary_ew_hlo("add", &[2, 3]))
            .unwrap();
        let a = f32_literal(&[2, 3], |i| i as f32).unwrap();
        let b = f32_literal(&[2, 3], |_| 10.0).unwrap();
        let out = exe.run_f32(&[a, b]).unwrap();
        assert_eq!(out, vec![10.0, 11.0, 12.0, 13.0, 14.0, 15.0]);
    }

    #[test]
    fn relu_numerics() {
        let rt = runtime();
        let exe = rt.compile_text("relu", &hlo_gen::relu_hlo(&[4])).unwrap();
        let a = f32_literal(&[4], |i| i as f32 - 2.0).unwrap();
        let out = exe.run_f32(&[a]).unwrap();
        assert_eq!(out, vec![0.0, 0.0, 0.0, 1.0]);
    }

    #[test]
    fn timing_returns_positive_medians() {
        let rt = runtime();
        let exe = rt
            .compile_text("add", &hlo_gen::binary_ew_hlo("add", &[64, 64]))
            .unwrap();
        let a = f32_literal(&[64, 64], |i| i as f32).unwrap();
        let b = f32_literal(&[64, 64], |i| i as f32).unwrap();
        let times = exe.time_us(&[a, b], 2, 5).unwrap();
        assert_eq!(times.len(), 5);
        assert!(stats::median(&times) > 0.0);
    }

    #[test]
    fn bad_hlo_fails_cleanly() {
        let rt = runtime();
        assert!(rt.compile_text("bad", "this is not hlo").is_err());
    }
}
