//! PJRT runtime layer: loads AOT-compiled HLO artifacts (built once by
//! `make artifacts` via python/compile/aot.py) and executes them on the
//! PJRT CPU client. Python is never on this path.

pub mod client;
pub mod hlo_gen;
#[cfg(not(feature = "pjrt"))]
pub mod xla_stub;

pub use client::{f32_literal, Executable, Literal, Runtime};
