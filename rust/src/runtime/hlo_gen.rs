//! HLO-text synthesis for micro-kernels.
//!
//! The PJRT measurement backend ([`crate::tpu::pjrt_hw`]) needs one
//! executable per (op, shape) point in a sweep. Rather than round-tripping
//! through Python for every shape, we synthesise the (tiny) HLO text
//! directly — the same text format the AOT artifacts use, parsed by the
//! same `HloModuleProto::parse_and_return_unverified_module` entry point.

/// Render a dims list as the HLO shape suffix: `[128,256]` (empty for
/// scalars).
fn dims_str(dims: &[usize]) -> String {
    let inner = dims
        .iter()
        .map(|d| d.to_string())
        .collect::<Vec<_>>()
        .join(",");
    format!("[{inner}]")
}

/// HLO module computing `C = A×B` for f32 matrices.
pub fn gemm_hlo(m: usize, k: usize, n: usize) -> String {
    format!(
        "HloModule gemm_m{m}_k{k}_n{n}\n\n\
         ENTRY main {{\n  \
           a = f32[{m},{k}] parameter(0)\n  \
           b = f32[{k},{n}] parameter(1)\n  \
           ROOT dot = f32[{m},{n}] dot(a, b), lhs_contracting_dims={{1}}, rhs_contracting_dims={{0}}\n\
         }}\n"
    )
}

/// HLO module for a binary elementwise op (`add`, `multiply`, `subtract`,
/// `maximum`, `minimum`, `divide`) over f32 tensors of shape `dims`.
pub fn binary_ew_hlo(op: &str, dims: &[usize]) -> String {
    let d = dims_str(dims);
    format!(
        "HloModule ew_{op}\n\n\
         ENTRY main {{\n  \
           a = f32{d} parameter(0)\n  \
           b = f32{d} parameter(1)\n  \
           ROOT r = f32{d} {op}(a, b)\n\
         }}\n"
    )
}

/// HLO module for ReLU (`maximum(x, 0)`) over f32 tensors of shape `dims`.
pub fn relu_hlo(dims: &[usize]) -> String {
    let d = dims_str(dims);
    format!(
        "HloModule ew_relu\n\n\
         ENTRY main {{\n  \
           a = f32{d} parameter(0)\n  \
           zero = f32[] constant(0)\n  \
           zeros = f32{d} broadcast(zero), dimensions={{}}\n  \
           ROOT r = f32{d} maximum(a, zeros)\n\
         }}\n"
    )
}

/// HLO module for a unary elementwise op (`exponential`, `tanh`, `negate`,
/// `abs`, `sqrt`, `rsqrt`, `log`, `logistic`).
pub fn unary_ew_hlo(op: &str, dims: &[usize]) -> String {
    let d = dims_str(dims);
    format!(
        "HloModule ew_{op}\n\n\
         ENTRY main {{\n  \
           a = f32{d} parameter(0)\n  \
           ROOT r = f32{d} {op}(a)\n\
         }}\n"
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gemm_text_shape() {
        let t = gemm_hlo(128, 256, 512);
        assert!(t.contains("f32[128,256] parameter(0)"));
        assert!(t.contains("f32[256,512] parameter(1)"));
        assert!(t.contains("ROOT dot = f32[128,512]"));
        assert!(t.contains("lhs_contracting_dims={1}"));
    }

    #[test]
    fn binary_text() {
        let t = binary_ew_hlo("add", &[64, 32]);
        assert!(t.contains("ROOT r = f32[64,32] add(a, b)"));
    }

    #[test]
    fn scalar_dims() {
        let t = binary_ew_hlo("multiply", &[]);
        assert!(t.contains("f32[] parameter(0)"));
    }

    #[test]
    fn relu_has_broadcast_zero() {
        let t = relu_hlo(&[8, 128]);
        assert!(t.contains("constant(0)"));
        assert!(t.contains("broadcast(zero)"));
        assert!(t.contains("maximum(a, zeros)"));
    }
}
