//! Minimal leveled logger writing to stderr.
//!
//! Level is controlled by `SCALESIM_LOG` (error|warn|info|debug|trace) or
//! programmatically via [`set_level`]. Keeping this std-only avoids pulling
//! a logging facade into the offline build.

use std::sync::atomic::{AtomicU8, Ordering};

/// Log severity, most to least severe.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    /// Unrecoverable problems.
    Error = 0,
    /// Suspicious but survivable conditions.
    Warn = 1,
    /// High-level progress (the default).
    Info = 2,
    /// Developer detail.
    Debug = 3,
    /// Per-iteration firehose.
    Trace = 4,
}

static LEVEL: AtomicU8 = AtomicU8::new(255); // 255 = uninitialised

fn init_from_env() -> u8 {
    let lvl = match std::env::var("SCALESIM_LOG").as_deref() {
        Ok("error") => Level::Error,
        Ok("warn") => Level::Warn,
        Ok("debug") => Level::Debug,
        Ok("trace") => Level::Trace,
        _ => Level::Info,
    } as u8;
    LEVEL.store(lvl, Ordering::Relaxed);
    lvl
}

/// Override the log level programmatically (wins over `SCALESIM_LOG`).
pub fn set_level(level: Level) {
    LEVEL.store(level as u8, Ordering::Relaxed);
}

/// Is `level` currently emitted? Initialises from the environment on first call.
pub fn enabled(level: Level) -> bool {
    let mut cur = LEVEL.load(Ordering::Relaxed);
    if cur == 255 {
        cur = init_from_env();
    }
    (level as u8) <= cur
}

/// Write one message to stderr if `level` is enabled (use the `log_*` macros).
pub fn log(level: Level, module: &str, msg: std::fmt::Arguments<'_>) {
    if enabled(level) {
        let tag = match level {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        };
        eprintln!("[{tag}] {module}: {msg}");
    }
}

/// Log at info level with `format!` syntax.
#[macro_export]
macro_rules! log_info {
    ($($arg:tt)*) => {
        $crate::util::logging::log(
            $crate::util::logging::Level::Info,
            module_path!(),
            format_args!($($arg)*),
        )
    };
}

/// Log at warn level with `format!` syntax.
#[macro_export]
macro_rules! log_warn {
    ($($arg:tt)*) => {
        $crate::util::logging::log(
            $crate::util::logging::Level::Warn,
            module_path!(),
            format_args!($($arg)*),
        )
    };
}

/// Log at debug level with `format!` syntax.
#[macro_export]
macro_rules! log_debug {
    ($($arg:tt)*) => {
        $crate::util::logging::log(
            $crate::util::logging::Level::Debug,
            module_path!(),
            format_args!($($arg)*),
        )
    };
}

/// Log at error level with `format!` syntax.
#[macro_export]
macro_rules! log_error {
    ($($arg:tt)*) => {
        $crate::util::logging::log(
            $crate::util::logging::Level::Error,
            module_path!(),
            format_args!($($arg)*),
        )
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_ordering() {
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Trace);
        assert!(enabled(Level::Debug));
    }
}
