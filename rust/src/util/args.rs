//! Tiny command-line argument parser (the offline registry has no `clap`).
//!
//! Grammar: `prog <subcommand> [--flag] [--key value] [positional...]`.
//! Flags may be given as `--key=value` or `--key value`. Unknown keys are
//! collected and reported by `finish()` so every binary gets consistent
//! error messages and `--help` behaviour.

use std::collections::BTreeMap;

/// Parsed command line: subcommand, positionals and `--key value` pairs.
#[derive(Debug, Clone)]
pub struct Args {
    /// First bare word (e.g. `simulate`).
    pub subcommand: Option<String>,
    /// Bare words after the subcommand.
    pub positional: Vec<String>,
    kv: BTreeMap<String, String>,
    consumed: std::cell::RefCell<Vec<String>>,
}

impl Args {
    /// Parse from `std::env::args()` (skipping argv[0]).
    pub fn from_env() -> Args {
        Self::parse(std::env::args().skip(1))
    }

    /// Parse an explicit argument iterator (tests and embedding).
    pub fn parse<I: IntoIterator<Item = String>>(iter: I) -> Args {
        let mut subcommand = None;
        let mut positional = Vec::new();
        let mut kv = BTreeMap::new();
        let mut items = iter.into_iter().peekable();
        while let Some(arg) = items.next() {
            if let Some(stripped) = arg.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    kv.insert(k.to_string(), v.to_string());
                } else if items
                    .peek()
                    .map(|next| !next.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = items.next().unwrap();
                    kv.insert(stripped.to_string(), v);
                } else {
                    // Bare flag.
                    kv.insert(stripped.to_string(), "true".to_string());
                }
            } else if subcommand.is_none() && positional.is_empty() {
                subcommand = Some(arg);
            } else {
                positional.push(arg);
            }
        }
        Args {
            subcommand,
            positional,
            kv,
            consumed: std::cell::RefCell::new(Vec::new()),
        }
    }

    fn mark(&self, key: &str) {
        self.consumed.borrow_mut().push(key.to_string());
    }

    /// Was `--key` provided (with or without a value)?
    pub fn has(&self, key: &str) -> bool {
        self.mark(key);
        self.kv.contains_key(key)
    }

    /// The raw value of `--key`, if provided.
    pub fn get(&self, key: &str) -> Option<&str> {
        self.mark(key);
        self.kv.get(key).map(String::as_str)
    }

    /// String value of `--key`, or `default`.
    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    /// True when `--key` was given as a bare flag (or `=true`).
    pub fn flag(&self, key: &str) -> bool {
        self.get(key).map(|v| v != "false").unwrap_or(false)
    }

    /// Integer value of `--key`, or `default`; panics on a non-integer.
    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        match self.get(key) {
            Some(v) => v
                .parse()
                .unwrap_or_else(|_| panic!("--{key} expects an integer, got '{v}'")),
            None => default,
        }
    }

    /// u64 value of `--key`, or `default`; panics on a non-integer.
    pub fn u64_or(&self, key: &str, default: u64) -> u64 {
        match self.get(key) {
            Some(v) => v
                .parse()
                .unwrap_or_else(|_| panic!("--{key} expects an integer, got '{v}'")),
            None => default,
        }
    }

    /// Float value of `--key`, or `default`; panics on a non-number.
    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        match self.get(key) {
            Some(v) => v
                .parse()
                .unwrap_or_else(|_| panic!("--{key} expects a number, got '{v}'")),
            None => default,
        }
    }

    /// Return the list of provided-but-never-queried keys (likely typos).
    pub fn unknown_keys(&self) -> Vec<String> {
        let consumed = self.consumed.borrow();
        self.kv
            .keys()
            .filter(|k| !consumed.contains(k))
            .cloned()
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(parts: &[&str]) -> Args {
        Args::parse(parts.iter().map(|s| s.to_string()))
    }

    #[test]
    fn subcommand_and_positional() {
        let a = args(&["fig2", "out.csv"]);
        assert_eq!(a.subcommand.as_deref(), Some("fig2"));
        assert_eq!(a.positional, vec!["out.csv"]);
    }

    #[test]
    fn key_value_forms() {
        let a = args(&["sim", "--m", "128", "--n=256", "--verbose"]);
        assert_eq!(a.usize_or("m", 0), 128);
        assert_eq!(a.usize_or("n", 0), 256);
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn defaults() {
        let a = args(&["sim"]);
        assert_eq!(a.usize_or("m", 7), 7);
        assert_eq!(a.f64_or("lr", 0.5), 0.5);
        assert_eq!(a.str_or("mode", "ws"), "ws");
    }

    #[test]
    fn unknown_keys_tracked() {
        let a = args(&["sim", "--good", "1", "--typo", "2"]);
        let _ = a.get("good");
        assert_eq!(a.unknown_keys(), vec!["typo".to_string()]);
    }

    #[test]
    fn flag_followed_by_flag() {
        let a = args(&["x", "--a", "--b", "3"]);
        assert!(a.flag("a"));
        assert_eq!(a.usize_or("b", 0), 3);
    }
}
