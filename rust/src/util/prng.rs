//! Deterministic pseudo-random number generation.
//!
//! The offline crate registry has no `rand`, so we carry our own small,
//! well-known generators: SplitMix64 for seeding and xoshiro256++ for the
//! main stream. Both are reproducible across platforms, which matters for
//! the synthetic TPU-v4 device model (`crate::tpu`) where the "hardware
//! noise" must be replayable in tests and benches.

/// SplitMix64 — used to expand a single `u64` seed into xoshiro state.
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    /// Seed the mixer.
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    /// Next 64 pseudo-random bits.
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256++ — the workhorse generator.
#[derive(Debug, Clone)]
pub struct Prng {
    s: [u64; 4],
    /// Cached second normal deviate from Box–Muller.
    gauss_spare: Option<f64>,
}

impl Prng {
    /// Seed the generator. Any seed (including 0) is valid.
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
            gauss_spare: None,
        }
    }

    /// Derive an independent stream for a labelled sub-component.
    /// Uses an FNV-1a hash of the label mixed into the seed so different
    /// labels give decorrelated streams.
    pub fn fork(&mut self, label: &str) -> Prng {
        let mut h: u64 = 0xcbf2_9ce4_8422_2325;
        for b in label.bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x100_0000_01b3);
        }
        Prng::new(self.next_u64() ^ h)
    }

    /// Next 64 pseudo-random bits of the main stream.
    pub fn next_u64(&mut self) -> u64 {
        let result = (self.s[0].wrapping_add(self.s[3]))
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    pub fn uniform(&mut self) -> f64 {
        // 53 high bits -> double in [0,1)
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    pub fn uniform_range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in [lo, hi] (inclusive).
    pub fn int_range(&mut self, lo: i64, hi: i64) -> i64 {
        debug_assert!(lo <= hi);
        let span = (hi - lo) as u64 + 1;
        lo + (self.next_u64() % span) as i64
    }

    /// Uniform usize in [0, n).
    pub fn index(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Standard normal via Box–Muller (with spare caching).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.gauss_spare.take() {
            return z;
        }
        // Avoid u == 0 for the log.
        let u = loop {
            let u = self.uniform();
            if u > 1e-300 {
                break u;
            }
        };
        let v = self.uniform();
        let r = (-2.0 * u.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * v;
        self.gauss_spare = Some(r * theta.sin());
        r * theta.cos()
    }

    /// Normal with given mean/stddev.
    pub fn normal_ms(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Lognormal multiplicative factor with median 1 and shape sigma:
    /// exp(N(0, sigma)). Used for run-to-run hardware noise.
    pub fn lognormal_factor(&mut self, sigma: f64) -> f64 {
        (self.normal() * sigma).exp()
    }

    /// Log-uniform in [lo, hi] (both > 0): uniform in log space.
    pub fn log_uniform(&mut self, lo: f64, hi: f64) -> f64 {
        debug_assert!(lo > 0.0 && hi >= lo);
        (self.uniform_range(lo.ln(), hi.ln())).exp()
    }

    /// Pick a random element of a slice.
    pub fn choice<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.index(xs.len())]
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.index(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from [0, n) (k <= n).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        debug_assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        // Partial Fisher–Yates: only the first k positions matter.
        for i in 0..k {
            let j = i + self.index(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }
}

/// Stable 64-bit hash of arbitrary bytes (FNV-1a). Used to derive
/// deterministic per-shape effects in the device model ("compiler tiling
/// decisions" keyed by shape).
pub fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    h
}

/// Hash a list of integers (e.g. a tensor shape or GEMM dims).
pub fn hash_dims(dims: &[usize]) -> u64 {
    let mut bytes = Vec::with_capacity(dims.len() * 8);
    for &d in dims {
        bytes.extend_from_slice(&(d as u64).to_le_bytes());
    }
    fnv1a(&bytes)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Prng::new(42);
        let mut b = Prng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Prng::new(1);
        let mut b = Prng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn uniform_bounds_and_mean() {
        let mut p = Prng::new(7);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let u = p.uniform();
            assert!((0.0..1.0).contains(&u));
            sum += u;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean}");
    }

    #[test]
    fn normal_moments() {
        let mut p = Prng::new(11);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| p.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.03, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn int_range_inclusive() {
        let mut p = Prng::new(3);
        let mut seen_lo = false;
        let mut seen_hi = false;
        for _ in 0..1000 {
            let v = p.int_range(-2, 2);
            assert!((-2..=2).contains(&v));
            seen_lo |= v == -2;
            seen_hi |= v == 2;
        }
        assert!(seen_lo && seen_hi);
    }

    #[test]
    fn log_uniform_in_range() {
        let mut p = Prng::new(5);
        for _ in 0..1000 {
            let v = p.log_uniform(16.0, 16_000_000.0);
            assert!(v >= 16.0 && v <= 16_000_000.0);
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut p = Prng::new(9);
        let mut xs: Vec<usize> = (0..50).collect();
        p.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct() {
        let mut p = Prng::new(13);
        let idx = p.sample_indices(100, 30);
        assert_eq!(idx.len(), 30);
        let mut s = idx.clone();
        s.sort_unstable();
        s.dedup();
        assert_eq!(s.len(), 30);
    }

    #[test]
    fn fork_decorrelates() {
        let mut p = Prng::new(21);
        let mut a = p.fork("a");
        let mut b = p.fork("b");
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn hash_dims_stable() {
        assert_eq!(hash_dims(&[1, 2, 3]), hash_dims(&[1, 2, 3]));
        assert_ne!(hash_dims(&[1, 2, 3]), hash_dims(&[3, 2, 1]));
    }
}
