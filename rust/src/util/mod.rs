//! Std-only infrastructure: PRNG, stats, JSON, CLI args, logging.
//!
//! The offline crate registry only carries the `xla` crate's dependency
//! closure (no serde, rand, clap, tokio or criterion), so this module
//! provides the small, fully-tested equivalents the rest of the crate
//! builds on.

pub mod args;
pub mod json;
pub mod logging;
pub mod prng;
pub mod stats;
