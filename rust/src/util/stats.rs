//! Descriptive statistics and regression-quality metrics.
//!
//! These are the metrics the paper reports for every figure: R², RMSE,
//! MAE, MAPE, and median absolute / relative error.

/// Arithmetic mean. Returns 0.0 on empty input.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population variance.
pub fn variance(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64
}

/// Population standard deviation.
pub fn stddev(xs: &[f64]) -> f64 {
    variance(xs).sqrt()
}

/// Median (interpolated for even lengths). Returns 0.0 on empty input.
pub fn median(xs: &[f64]) -> f64 {
    percentile(xs, 50.0)
}

/// Linear-interpolated percentile, p in [0, 100].
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = (p / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        let w = rank - lo as f64;
        v[lo] * (1.0 - w) + v[hi] * w
    }
}

/// Min/max helpers that ignore NaN-free assumption violations gracefully.
pub fn min(xs: &[f64]) -> f64 {
    xs.iter().copied().fold(f64::INFINITY, f64::min)
}

/// Maximum, `-inf` on empty input.
pub fn max(xs: &[f64]) -> f64 {
    xs.iter().copied().fold(f64::NEG_INFINITY, f64::max)
}

/// Coefficient of determination of predictions vs truth:
/// R² = 1 - SS_res / SS_tot.
pub fn r2(truth: &[f64], pred: &[f64]) -> f64 {
    assert_eq!(truth.len(), pred.len());
    if truth.is_empty() {
        return 0.0;
    }
    let m = mean(truth);
    let ss_tot: f64 = truth.iter().map(|y| (y - m) * (y - m)).sum();
    let ss_res: f64 = truth
        .iter()
        .zip(pred)
        .map(|(y, p)| (y - p) * (y - p))
        .sum();
    if ss_tot == 0.0 {
        // Constant truth: perfect iff residuals are zero.
        return if ss_res == 0.0 { 1.0 } else { 0.0 };
    }
    1.0 - ss_res / ss_tot
}

/// Root mean squared error.
pub fn rmse(truth: &[f64], pred: &[f64]) -> f64 {
    assert_eq!(truth.len(), pred.len());
    if truth.is_empty() {
        return 0.0;
    }
    let mse: f64 = truth
        .iter()
        .zip(pred)
        .map(|(y, p)| (y - p) * (y - p))
        .sum::<f64>()
        / truth.len() as f64;
    mse.sqrt()
}

/// Mean absolute error.
pub fn mae(truth: &[f64], pred: &[f64]) -> f64 {
    assert_eq!(truth.len(), pred.len());
    if truth.is_empty() {
        return 0.0;
    }
    truth
        .iter()
        .zip(pred)
        .map(|(y, p)| (y - p).abs())
        .sum::<f64>()
        / truth.len() as f64
}

/// Mean absolute percentage error, in percent. Skips zero-truth points.
pub fn mape(truth: &[f64], pred: &[f64]) -> f64 {
    assert_eq!(truth.len(), pred.len());
    let mut total = 0.0;
    let mut n = 0usize;
    for (y, p) in truth.iter().zip(pred) {
        if y.abs() > 0.0 {
            total += ((y - p) / y).abs();
            n += 1;
        }
    }
    if n == 0 {
        0.0
    } else {
        100.0 * total / n as f64
    }
}

/// Median absolute error (the paper's headline elementwise metric).
pub fn median_abs_error(truth: &[f64], pred: &[f64]) -> f64 {
    assert_eq!(truth.len(), pred.len());
    let errs: Vec<f64> = truth.iter().zip(pred).map(|(y, p)| (y - p).abs()).collect();
    median(&errs)
}

/// Median relative error, in percent. Skips zero-truth points.
pub fn median_rel_error(truth: &[f64], pred: &[f64]) -> f64 {
    assert_eq!(truth.len(), pred.len());
    let errs: Vec<f64> = truth
        .iter()
        .zip(pred)
        .filter(|(y, _)| y.abs() > 0.0)
        .map(|(y, p)| 100.0 * ((y - p) / y).abs())
        .collect();
    median(&errs)
}

/// Pearson correlation coefficient.
pub fn pearson(xs: &[f64], ys: &[f64]) -> f64 {
    assert_eq!(xs.len(), ys.len());
    if xs.len() < 2 {
        return 0.0;
    }
    let mx = mean(xs);
    let my = mean(ys);
    let mut sxy = 0.0;
    let mut sxx = 0.0;
    let mut syy = 0.0;
    for (x, y) in xs.iter().zip(ys) {
        sxy += (x - mx) * (y - my);
        sxx += (x - mx) * (x - mx);
        syy += (y - my) * (y - my);
    }
    if sxx == 0.0 || syy == 0.0 {
        return 0.0;
    }
    sxy / (sxx.sqrt() * syy.sqrt())
}

/// A bundle of every fit metric the paper reports, computed in one pass.
#[derive(Debug, Clone, PartialEq)]
pub struct FitMetrics {
    /// Number of (truth, prediction) pairs.
    pub n: usize,
    /// Coefficient of determination.
    pub r2: f64,
    /// Root-mean-square error.
    pub rmse: f64,
    /// Mean absolute error.
    pub mae: f64,
    /// Mean absolute percentage error.
    pub mape_pct: f64,
    /// Median absolute error.
    pub median_abs_err: f64,
    /// Median relative error, percent.
    pub median_rel_err_pct: f64,
}

impl FitMetrics {
    /// Compute every metric over parallel truth/prediction slices.
    pub fn compute(truth: &[f64], pred: &[f64]) -> Self {
        Self {
            n: truth.len(),
            r2: r2(truth, pred),
            rmse: rmse(truth, pred),
            mae: mae(truth, pred),
            mape_pct: mape(truth, pred),
            median_abs_err: median_abs_error(truth, pred),
            median_rel_err_pct: median_rel_error(truth, pred),
        }
    }
}

impl std::fmt::Display for FitMetrics {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "n={} R2={:.4} RMSE={:.4} MAE={:.4} MAPE={:.2}% medAE={:.4} medRE={:.2}%",
            self.n,
            self.r2,
            self.rmse,
            self.mae,
            self.mape_pct,
            self.median_abs_err,
            self.median_rel_err_pct
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_median_basic() {
        assert_eq!(mean(&[1.0, 2.0, 3.0]), 2.0);
        assert_eq!(median(&[3.0, 1.0, 2.0]), 2.0);
        assert_eq!(median(&[4.0, 1.0, 2.0, 3.0]), 2.5);
        assert_eq!(mean(&[]), 0.0);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [0.0, 10.0];
        assert_eq!(percentile(&xs, 0.0), 0.0);
        assert_eq!(percentile(&xs, 100.0), 10.0);
        assert!((percentile(&xs, 25.0) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn r2_perfect_and_mean_predictor() {
        let y = [1.0, 2.0, 3.0, 4.0];
        assert!((r2(&y, &y) - 1.0).abs() < 1e-12);
        let mean_pred = [2.5; 4];
        assert!(r2(&y, &mean_pred).abs() < 1e-12);
    }

    #[test]
    fn r2_constant_truth() {
        let y = [2.0, 2.0];
        assert_eq!(r2(&y, &[2.0, 2.0]), 1.0);
        assert_eq!(r2(&y, &[1.0, 3.0]), 0.0);
    }

    #[test]
    fn error_metrics() {
        let y = [10.0, 20.0];
        let p = [12.0, 16.0];
        assert!((mae(&y, &p) - 3.0).abs() < 1e-12);
        assert!((rmse(&y, &p) - (10.0f64).sqrt()).abs() < 1e-12);
        assert!((mape(&y, &p) - 20.0).abs() < 1e-9); // (20% + 20%) / 2
        assert!((median_abs_error(&y, &p) - 3.0).abs() < 1e-12);
        assert!((median_rel_error(&y, &p) - 20.0).abs() < 1e-9);
    }

    #[test]
    fn pearson_signs() {
        let x = [1.0, 2.0, 3.0, 4.0];
        let y_up = [2.0, 4.0, 6.0, 8.0];
        let y_down = [8.0, 6.0, 4.0, 2.0];
        assert!((pearson(&x, &y_up) - 1.0).abs() < 1e-12);
        assert!((pearson(&x, &y_down) + 1.0).abs() < 1e-12);
    }

    #[test]
    fn fit_metrics_display() {
        let m = FitMetrics::compute(&[1.0, 2.0], &[1.0, 2.0]);
        assert_eq!(m.n, 2);
        assert!(m.r2 > 0.999);
        let s = format!("{m}");
        assert!(s.contains("R2=1.0000"));
    }
}
