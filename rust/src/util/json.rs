//! Minimal JSON value model, parser and pretty-printer.
//!
//! The offline registry carries no `serde`/`serde_json`, so persistence
//! (trained HGBR models, calibration parameters, simulator configs,
//! experiment dumps) is implemented on this small, fully tested module.
//! It supports the complete JSON grammar except `\u` surrogate pairs are
//! passed through unvalidated.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Objects use a BTreeMap so output is deterministic.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    /// `null`.
    Null,
    /// `true` / `false`.
    Bool(bool),
    /// Any number (all JSON numbers are f64 here).
    Num(f64),
    /// A string.
    Str(String),
    /// An array.
    Arr(Vec<Json>),
    /// An object with deterministic key order.
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// An empty object (build with [`Json::set`]).
    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    /// Insert into an object; panics if self is not an object.
    pub fn set(&mut self, key: &str, val: Json) -> &mut Self {
        match self {
            Json::Obj(m) => {
                m.insert(key.to_string(), val);
            }
            _ => panic!("Json::set on non-object"),
        }
        self
    }

    /// Object field lookup; `None` on non-objects.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// The number value, if this is a number.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// The number value truncated to usize, if this is a number.
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    /// The string value, if this is a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// The bool value, if this is a bool.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// The elements, if this is an array.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Required-field accessors returning descriptive errors.
    pub fn req_f64(&self, key: &str) -> Result<f64, JsonError> {
        self.get(key)
            .and_then(Json::as_f64)
            .ok_or_else(|| JsonError::new(format!("missing/invalid number field '{key}'")))
    }

    /// Required string field.
    pub fn req_str(&self, key: &str) -> Result<&str, JsonError> {
        self.get(key)
            .and_then(Json::as_str)
            .ok_or_else(|| JsonError::new(format!("missing/invalid string field '{key}'")))
    }

    /// Required non-negative integer field: rejects negatives and
    /// fractional values instead of silently truncating them.
    pub fn req_usize(&self, key: &str) -> Result<usize, JsonError> {
        let n = self.req_f64(key)?;
        if !n.is_finite() || n < 0.0 || n.fract() != 0.0 || n > u64::MAX as f64 {
            return Err(JsonError::new(format!(
                "field '{key}' must be a non-negative integer, got {n}"
            )));
        }
        Ok(n as usize)
    }

    /// Required array field.
    pub fn req_arr(&self, key: &str) -> Result<&[Json], JsonError> {
        self.get(key)
            .and_then(Json::as_arr)
            .ok_or_else(|| JsonError::new(format!("missing/invalid array field '{key}'")))
    }

    /// Required array-of-numbers field.
    pub fn num_arr(&self, key: &str) -> Result<Vec<f64>, JsonError> {
        let arr = self.req_arr(key)?;
        arr.iter()
            .map(|v| {
                v.as_f64()
                    .ok_or_else(|| JsonError::new(format!("non-number in array '{key}'")))
            })
            .collect()
    }

    /// An array from a float slice.
    pub fn from_f64s(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }

    /// An array from an integer slice.
    pub fn from_usizes(xs: &[usize]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x as f64)).collect())
    }

    /// Compact serialization.
    pub fn dump(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Pretty serialization with 2-space indent.
    pub fn pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => write_num(out, *n),
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    item.write(out, indent, depth + 1);
                }
                if !items.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(map) => {
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !map.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push('}');
            }
        }
    }

    /// Parse a JSON document.
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            bytes: text.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters after JSON value"));
        }
        Ok(v)
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_num(out: &mut String, n: f64) {
    if n.is_nan() || n.is_infinite() {
        // JSON has no NaN/Inf; persist as null (reader treats as missing).
        out.push_str("null");
    } else if n == n.trunc() && n.abs() < 1e15 {
        out.push_str(&format!("{}", n as i64));
    } else {
        // 17 significant digits round-trips f64 exactly.
        out.push_str(&format!("{n:.17e}"));
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Error with byte offset for diagnostics.
#[derive(Debug, Clone)]
pub struct JsonError {
    /// Human-readable description.
    pub msg: String,
}

impl JsonError {
    /// Wrap a message.
    pub fn new(msg: impl Into<String>) -> Self {
        Self { msg: msg.into() }
    }
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error: {}", self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError::new(format!("{msg} at byte {}", self.pos))
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while let Some(b) = self.peek() {
            if b == b' ' || b == b'\t' || b == b'\n' || b == b'\r' {
                self.pos += 1;
            } else {
                break;
            }
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(b) if b == b'-' || b.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            let b = self.peek().ok_or_else(|| self.err("unterminated string"))?;
            self.pos += 1;
            match b {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.pos += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            if self.pos + 4 > self.bytes.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.bytes[self.pos..self.pos + 4])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            self.pos += 4;
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                        }
                        _ => return Err(self.err("unknown escape")),
                    }
                }
                _ => {
                    // Re-decode UTF-8 starting at pos-1.
                    let start = self.pos - 1;
                    let ch_len = utf8_len(b);
                    let end = start + ch_len;
                    if end > self.bytes.len() {
                        return Err(self.err("truncated utf-8"));
                    }
                    let st = std::str::from_utf8(&self.bytes[start..end])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    s.push_str(st);
                    self.pos = end;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while let Some(b) = self.peek() {
            if b.is_ascii_digit() || b == b'.' || b == b'e' || b == b'E' || b == b'+' || b == b'-' {
                self.pos += 1;
            } else {
                break;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("invalid number"))
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

fn utf8_len(first: u8) -> usize {
    if first < 0x80 {
        1
    } else if first < 0xE0 {
        2
    } else if first < 0xF0 {
        3
    } else {
        4
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for text in ["null", "true", "false", "0", "-1", "3.5"] {
            let v = Json::parse(text).unwrap();
            let v2 = Json::parse(&v.dump()).unwrap();
            assert_eq!(v, v2);
        }
    }

    #[test]
    fn roundtrip_float_exact() {
        let x = 0.1234567890123456789_f64;
        let v = Json::Num(x);
        let v2 = Json::parse(&v.dump()).unwrap();
        assert_eq!(v2.as_f64().unwrap(), x);
    }

    #[test]
    fn nested_structures() {
        let text = r#"{"a": [1, 2, {"b": "x\ny", "c": null}], "d": true}"#;
        let v = Json::parse(text).unwrap();
        assert_eq!(v.get("d"), Some(&Json::Bool(true)));
        let arr = v.req_arr("a").unwrap();
        assert_eq!(arr[0].as_f64(), Some(1.0));
        assert_eq!(arr[2].req_str("b").unwrap(), "x\ny");
        let v2 = Json::parse(&v.dump()).unwrap();
        assert_eq!(v, v2);
        let v3 = Json::parse(&v.pretty()).unwrap();
        assert_eq!(v, v3);
    }

    #[test]
    fn unicode_string() {
        let v = Json::parse(r#""héllo é ✓""#).unwrap();
        assert_eq!(v, Json::Str("héllo é ✓".to_string()));
        let v2 = Json::parse(&v.dump()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn errors_reported() {
        assert!(Json::parse("").is_err());
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("\"abc").is_err());
    }

    #[test]
    fn builder_and_accessors() {
        let mut o = Json::obj();
        o.set("name", Json::Str("hgbr".into()))
            .set("lr", Json::Num(0.1))
            .set("dims", Json::from_usizes(&[128, 256]));
        assert_eq!(o.req_str("name").unwrap(), "hgbr");
        assert_eq!(o.req_f64("lr").unwrap(), 0.1);
        assert_eq!(o.num_arr("dims").unwrap(), vec![128.0, 256.0]);
        assert!(o.req_f64("missing").is_err());
    }

    #[test]
    fn nan_serializes_as_null() {
        assert_eq!(Json::Num(f64::NAN).dump(), "null");
    }

    #[test]
    fn req_usize_rejects_non_integers() {
        let v = Json::parse(r#"{"a": 12, "b": -3, "c": 2.5, "d": "x"}"#).unwrap();
        assert_eq!(v.req_usize("a").unwrap(), 12);
        assert!(v.req_usize("b").is_err());
        assert!(v.req_usize("c").is_err());
        assert!(v.req_usize("d").is_err());
        assert!(v.req_usize("missing").is_err());
    }
}
