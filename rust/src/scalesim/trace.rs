//! Fold-schedule trace emission.
//!
//! Upstream SCALE-Sim's signature output is its cycle-accurate operand
//! trace; at our fold granularity the equivalent is the *fold schedule*:
//! one record per fold with start/end cycles, geometry, operand demand
//! and stall attribution. The trace reconstructs exactly the totals of
//! [`SimReport`] (asserted by tests) and exports to CSV for external
//! tooling.

use super::config::ScaleConfig;
use super::dataflow::compute_model;
use super::memory::memory_model;
use super::topology::GemmShape;

/// One scheduled fold.
#[derive(Debug, Clone, PartialEq)]
pub struct FoldRecord {
    /// Fold sequence number.
    pub index: u64,
    /// Cycle the fold starts computing.
    pub start_cycle: u64,
    /// Cycle the fold finishes.
    pub end_cycle: u64,
    /// Array rows occupied.
    pub rows_used: usize,
    /// Array columns occupied.
    pub cols_used: usize,
    /// Activation stream length.
    pub stream_len: usize,
    /// Prefetch stall cycles charged to the fold.
    pub stall_cycles: u64,
}

/// The fold schedule of one GEMM.
#[derive(Debug, Clone)]
pub struct FoldTrace {
    /// The traced GEMM.
    pub gemm: GemmShape,
    /// Per-fold records in execution order.
    pub records: Vec<FoldRecord>,
    /// Total cycles including fill and stalls.
    pub total_cycles: u64,
}

/// Maximum folds fully expanded; beyond this the trace is truncated (the
/// totals still cover the whole run).
pub const MAX_EXPANDED_FOLDS: u64 = 100_000;

/// Build the fold schedule for `gemm` under `config`.
pub fn trace_gemm(config: &ScaleConfig, gemm: GemmShape) -> FoldTrace {
    let compute = compute_model(config, gemm);
    let memory = memory_model(config, gemm, &compute);

    // Distribute stalls evenly across the folds of each class, mirroring
    // the memory model's per-class arithmetic.
    let mut records = Vec::new();
    let mut cycle = memory.initial_fill_cycles;
    let mut index = 0u64;
    let mut truncated = false;

    for (fold, count) in &compute.fold_classes {
        // Stall per fold of this class (recompute as the model does).
        let per_fold_cycles = fold.total_cycles();
        for i in 0..*count {
            if index >= MAX_EXPANDED_FOLDS {
                truncated = true;
                break;
            }
            // First fold overall carries no steady-state stall (its
            // prefetch was the initial fill).
            let stall = if index == 0 {
                0
            } else {
                per_class_stall(config, fold, per_fold_cycles)
            };
            let start = cycle;
            let end = start + per_fold_cycles + stall;
            records.push(FoldRecord {
                index,
                start_cycle: start,
                end_cycle: end,
                rows_used: fold.rows_used,
                cols_used: fold.cols_used,
                stream_len: fold.stream_len,
                stall_cycles: stall,
            });
            cycle = end;
            index += 1;
            let _ = i;
        }
        if truncated {
            break;
        }
    }

    let total_cycles = memory.initial_fill_cycles + compute.compute_cycles + memory.stall_cycles;
    FoldTrace {
        gemm,
        records,
        total_cycles,
    }
}

fn per_class_stall(
    config: &ScaleConfig,
    fold: &super::dataflow::FoldCost,
    per_fold_cycles: u64,
) -> u64 {
    // Mirror memory::fold_demand + stall computation for one fold.
    use super::config::Dataflow;
    let r = fold.rows_used as f64;
    let c = fold.cols_used as f64;
    let t = fold.stream_len as f64;
    let (if_w, fl_w, of_w) = match config.dataflow {
        Dataflow::OutputStationary => (r * t, t * c, r * c),
        Dataflow::WeightStationary => (t * r, r * c, t * c),
        Dataflow::InputStationary => (r * c, t * r, c * t),
    };
    let t_read = (if_w / config.ifmap_dram_bw)
        .ceil()
        .max((fl_w / config.filter_dram_bw).ceil()) as u64;
    let t_write = (of_w / config.ofmap_dram_bw).ceil() as u64;
    t_read.max(t_write).saturating_sub(per_fold_cycles)
}

impl FoldTrace {
    /// CSV export: one row per fold.
    pub fn to_csv(&self) -> String {
        let mut out =
            String::from("fold,start_cycle,end_cycle,rows,cols,stream,stall_cycles\n");
        for r in &self.records {
            out.push_str(&format!(
                "{},{},{},{},{},{},{}\n",
                r.index,
                r.start_cycle,
                r.end_cycle,
                r.rows_used,
                r.cols_used,
                r.stream_len,
                r.stall_cycles
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scalesim::simulate_gemm;

    #[test]
    fn trace_totals_match_report() {
        let cfg = ScaleConfig::tpu_v4();
        for g in [
            GemmShape::new(128, 128, 128),
            GemmShape::new(700, 300, 500),
            GemmShape::new(64, 64, 64),
        ] {
            let trace = trace_gemm(&cfg, g);
            let report = simulate_gemm(&cfg, g);
            assert_eq!(trace.total_cycles, report.total_cycles(), "{g}");
            // Full expansion for these sizes: last fold ends at total.
            let last = trace.records.last().unwrap();
            assert_eq!(last.end_cycle, report.total_cycles(), "{g}");
            assert_eq!(trace.records.len(), report.num_folds, "{g}");
        }
    }

    #[test]
    fn folds_are_contiguous_and_ordered() {
        let cfg = ScaleConfig::tpu_v4();
        let trace = trace_gemm(&cfg, GemmShape::new(513, 257, 385));
        let mut prev_end = trace.records[0].start_cycle;
        for r in &trace.records {
            assert_eq!(r.start_cycle, prev_end);
            assert!(r.end_cycle > r.start_cycle);
            prev_end = r.end_cycle;
        }
    }

    #[test]
    fn huge_gemm_truncates_but_totals_hold() {
        let mut cfg = ScaleConfig::tpu_v4();
        cfg.array_rows = 8;
        cfg.array_cols = 8;
        let g = GemmShape::new(8192, 4096, 8192); // >1M folds
        let trace = trace_gemm(&cfg, g);
        assert_eq!(trace.records.len() as u64, MAX_EXPANDED_FOLDS);
        assert_eq!(
            trace.total_cycles,
            simulate_gemm(&cfg, g).total_cycles()
        );
    }

    #[test]
    fn csv_export() {
        let cfg = ScaleConfig::tpu_v4();
        let trace = trace_gemm(&cfg, GemmShape::new(256, 256, 256));
        let csv = trace.to_csv();
        assert_eq!(csv.lines().count(), 1 + trace.records.len());
        assert!(csv.starts_with("fold,start_cycle"));
    }
}
