//! Detailed DRAM interface model (SCALE-Sim v3 integrates Ramulator; this
//! is the analytical equivalent — "Ramulator-lite").
//!
//! The flat words/cycle bandwidths in [`super::config::ScaleConfig`]
//! assume perfectly streaming traffic. Real DRAM delivers that bandwidth
//! only on row-buffer hits; row misses pay tRP+tRCD-class penalties. This
//! module derives *effective* per-stream bandwidth from access pattern
//! granularity (contiguous run length per request) and device timing, and
//! can refine a [`SimReport`]'s stall estimate accordingly.

use super::config::ScaleConfig;
use super::report::SimReport;
use super::topology::GemmShape;

/// DRAM device/channel timing parameters (DDR4-3200-class defaults,
/// normalised to core cycles at the config clock).
#[derive(Debug, Clone, PartialEq)]
pub struct DramParams {
    /// Peak words/cycle per stream on row hits (matches ScaleConfig bw).
    pub peak_words_per_cycle: f64,
    /// Row-buffer (page) size in words.
    pub row_words: usize,
    /// Core cycles lost per row activation (precharge + activate).
    pub row_miss_penalty_cycles: f64,
    /// Fraction of row switches hidden by bank-level parallelism (0..1).
    pub bank_parallel_hide: f64,
}

impl Default for DramParams {
    fn default() -> Self {
        DramParams {
            peak_words_per_cycle: 256.0,
            // 2 KiB page at 2 B/word.
            row_words: 1024,
            // ~45 ns at ~1 GHz core clock.
            row_miss_penalty_cycles: 45.0,
            // HBM-class interfaces have dozens of banks/channels; with
            // streaming engines nearly all activations overlap transfer.
            bank_parallel_hide: 0.99,
        }
    }
}

impl DramParams {
    /// Effective bandwidth (words/cycle) for a stream whose contiguous
    /// run length is `run_words`: each run of rows pays an exposed
    /// activation penalty amortised over the run.
    pub fn effective_bandwidth(&self, run_words: usize) -> f64 {
        let run = run_words.max(1) as f64;
        // Rows touched per run (at least one activation per run — runs
        // are non-contiguous with each other by definition).
        let rows = (run / self.row_words as f64).ceil();
        let exposed = rows * self.row_miss_penalty_cycles * (1.0 - self.bank_parallel_hide);
        let transfer = run / self.peak_words_per_cycle;
        run / (transfer + exposed)
    }

    /// Efficiency vs peak for a run length.
    pub fn efficiency(&self, run_words: usize) -> f64 {
        self.effective_bandwidth(run_words) / self.peak_words_per_cycle
    }
}

/// Contiguous run lengths (words per access burst) of the three operand
/// streams for a GEMM under the config's dataflow, assuming row-major A,
/// B, C in DRAM.
///
/// * A is streamed row by row: runs of K words.
/// * B tiles are fetched row by row of the tile: runs of `min(N, array)`.
/// * C is written row by row: runs of N words.
pub fn stream_runs(config: &ScaleConfig, gemm: GemmShape) -> (usize, usize, usize) {
    let a_run = gemm.k;
    let b_run = gemm.n.min(config.array_cols);
    let c_run = gemm.n;
    (a_run, b_run, c_run)
}

/// A refined report: stall cycles recomputed with effective bandwidths.
#[derive(Debug, Clone)]
pub struct DramRefinedReport {
    /// The unrefined simulation.
    pub base: SimReport,
    /// A-stream bandwidth efficiency vs peak, in (0, 1].
    pub a_efficiency: f64,
    /// B-stream bandwidth efficiency vs peak, in (0, 1].
    pub b_efficiency: f64,
    /// C-stream bandwidth efficiency vs peak, in (0, 1].
    pub c_efficiency: f64,
    /// Total cycles re-simulated with effective bandwidths.
    pub refined_total_cycles: u64,
}

impl DramRefinedReport {
    /// Extra cycles attributable to row-buffer behaviour.
    pub fn dram_detail_penalty(&self) -> u64 {
        self.refined_total_cycles
            .saturating_sub(self.base.total_cycles())
    }
}

/// Re-simulate `gemm` with per-stream effective bandwidths derived from
/// the DRAM model, producing a refined total-cycle count.
pub fn refine(config: &ScaleConfig, params: &DramParams, gemm: GemmShape) -> DramRefinedReport {
    let (a_run, b_run, c_run) = stream_runs(config, gemm);
    let a_eff = params.efficiency(a_run);
    let b_eff = params.efficiency(b_run);
    let c_eff = params.efficiency(c_run);

    let mut refined_config = config.clone();
    refined_config.ifmap_dram_bw = (config.ifmap_dram_bw * a_eff).max(1e-3);
    refined_config.filter_dram_bw = (config.filter_dram_bw * b_eff).max(1e-3);
    refined_config.ofmap_dram_bw = (config.ofmap_dram_bw * c_eff).max(1e-3);

    let base = super::gemm::simulate_gemm(config, gemm);
    let refined = super::gemm::simulate_gemm(&refined_config, gemm);

    DramRefinedReport {
        base,
        a_efficiency: a_eff,
        b_efficiency: b_eff,
        c_efficiency: c_eff,
        refined_total_cycles: refined.total_cycles(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn long_runs_reach_peak() {
        let p = DramParams::default();
        // A whole-row run: one activation amortised over 1024 words.
        let eff = p.efficiency(1024 * 64);
        assert!(eff > 0.85, "eff {eff}");
    }

    #[test]
    fn short_runs_degrade() {
        let p = DramParams::default();
        let short = p.efficiency(32);
        let long = p.efficiency(4096);
        assert!(short < long);
        assert!(short < 0.5, "short-run efficiency {short}");
    }

    #[test]
    fn efficiency_monotone_in_run_length() {
        let p = DramParams::default();
        let mut prev = 0.0;
        for run in [16usize, 64, 256, 1024, 8192] {
            let e = p.efficiency(run);
            assert!(e >= prev, "run {run}");
            prev = e;
        }
    }

    #[test]
    fn refine_never_speeds_up() {
        let config = ScaleConfig::tpu_v4();
        let p = DramParams::default();
        for g in [
            GemmShape::new(128, 128, 128),
            GemmShape::new(1024, 64, 2048),
            GemmShape::new(4096, 4096, 32),
        ] {
            let r = refine(&config, &p, g);
            assert!(
                r.refined_total_cycles >= r.base.total_cycles(),
                "{g}: {} < {}",
                r.refined_total_cycles,
                r.base.total_cycles()
            );
        }
    }

    #[test]
    fn skinny_k_hurts_a_stream() {
        // Short A runs (K = 32) degrade the A stream badly; wide K is fine.
        let config = ScaleConfig::tpu_v4();
        let p = DramParams::default();
        let skinny = refine(&config, &p, GemmShape::new(2048, 32, 2048));
        let wide = refine(&config, &p, GemmShape::new(2048, 2048, 2048));
        assert!(skinny.a_efficiency < wide.a_efficiency);
    }
}
