//! Simulator architecture configuration (the SCALE-Sim `[architecture]`
//! section, rebuilt as a typed struct).
//!
//! A [`ScaleConfig`] describes one systolic core: the MAC-array geometry,
//! the three SRAM operand buffers (ifmap / filter / ofmap, each double
//! buffered), the DRAM interface bandwidths, the dataflow, and the clock.
//! Presets are provided for the configurations the paper uses — most
//! importantly [`ScaleConfig::tpu_v4`], the 128×128 MXU-like setup used
//! for all validation experiments.

use crate::util::json::{Json, JsonError};

/// Which operand is held stationary in the array.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Dataflow {
    /// Output stationary: each PE accumulates one output element.
    OutputStationary,
    /// Weight stationary: filter values pinned, inputs stream through.
    WeightStationary,
    /// Input stationary: ifmap values pinned, weights stream through.
    InputStationary,
}

impl Dataflow {
    /// Parse `os` / `ws` / `is`.
    pub fn parse(s: &str) -> Option<Dataflow> {
        match s.to_ascii_lowercase().as_str() {
            "os" | "output_stationary" => Some(Dataflow::OutputStationary),
            "ws" | "weight_stationary" => Some(Dataflow::WeightStationary),
            "is" | "input_stationary" => Some(Dataflow::InputStationary),
            _ => None,
        }
    }

    /// The two-letter dataflow code.
    pub fn short(&self) -> &'static str {
        match self {
            Dataflow::OutputStationary => "OS",
            Dataflow::WeightStationary => "WS",
            Dataflow::InputStationary => "IS",
        }
    }
}

impl std::fmt::Display for Dataflow {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.short())
    }
}

/// One systolic core's architecture parameters.
#[derive(Debug, Clone, PartialEq)]
pub struct ScaleConfig {
    /// Human-readable config name (shows up in reports).
    pub name: String,
    /// MAC array rows (S_R).
    pub array_rows: usize,
    /// MAC array columns (S_C).
    pub array_cols: usize,
    /// IFMAP SRAM capacity in KiB (total; the sim double-buffers it).
    pub ifmap_sram_kb: usize,
    /// Filter SRAM capacity in KiB.
    pub filter_sram_kb: usize,
    /// OFMAP SRAM capacity in KiB.
    pub ofmap_sram_kb: usize,
    /// Dataflow (OS / WS / IS).
    pub dataflow: Dataflow,
    /// DRAM read bandwidth for ifmap operands, words/cycle.
    pub ifmap_dram_bw: f64,
    /// DRAM read bandwidth for filter operands, words/cycle.
    pub filter_dram_bw: f64,
    /// DRAM write bandwidth for ofmap results, words/cycle.
    pub ofmap_dram_bw: f64,
    /// Bytes per operand word (2 for bf16).
    pub word_bytes: usize,
    /// Core clock in MHz (used only to express cycles as time).
    pub freq_mhz: f64,
}

impl ScaleConfig {
    /// TPU v4-like configuration: one 128×128 MXU, bf16 operands,
    /// 940 MHz clock, generous on-chip buffering (TPU v4 has 128 MiB CMEM;
    /// we give each operand buffer a large slice so medium shapes are
    /// single-pass, as on real hardware).
    pub fn tpu_v4() -> ScaleConfig {
        ScaleConfig {
            name: "tpu_v4_mxu".to_string(),
            array_rows: 128,
            array_cols: 128,
            ifmap_sram_kb: 8 * 1024,
            filter_sram_kb: 8 * 1024,
            ofmap_sram_kb: 8 * 1024,
            dataflow: Dataflow::WeightStationary,
            // ~1.2 TB/s HBM at 940 MHz and 2-byte words ≈ 640 words/cycle
            // aggregate; split across the three operand streams.
            ifmap_dram_bw: 256.0,
            filter_dram_bw: 256.0,
            ofmap_dram_bw: 128.0,
            word_bytes: 2,
            freq_mhz: 940.0,
        }
    }

    /// A small Eyeriss-like config, used in tests to exercise folding.
    pub fn eyeriss_like() -> ScaleConfig {
        ScaleConfig {
            name: "eyeriss_like".to_string(),
            array_rows: 12,
            array_cols: 14,
            ifmap_sram_kb: 108,
            filter_sram_kb: 108,
            ofmap_sram_kb: 108,
            dataflow: Dataflow::OutputStationary,
            ifmap_dram_bw: 4.0,
            filter_dram_bw: 4.0,
            ofmap_dram_bw: 4.0,
            word_bytes: 2,
            freq_mhz: 200.0,
        }
    }

    /// TPU v1-like 256×256 array (for ablations).
    pub fn tpu_v1_like() -> ScaleConfig {
        ScaleConfig {
            name: "tpu_v1_like".to_string(),
            array_rows: 256,
            array_cols: 256,
            ifmap_sram_kb: 12 * 1024,
            filter_sram_kb: 12 * 1024,
            ofmap_sram_kb: 4 * 1024,
            dataflow: Dataflow::WeightStationary,
            ifmap_dram_bw: 64.0,
            filter_dram_bw: 64.0,
            ofmap_dram_bw: 32.0,
            word_bytes: 1,
            freq_mhz: 700.0,
        }
    }

    /// Words that fit in one half of a double-buffered SRAM.
    pub fn half_buffer_words(&self, sram_kb: usize) -> usize {
        (sram_kb * 1024) / (2 * self.word_bytes)
    }

    /// Words per ifmap SRAM half-buffer.
    pub fn ifmap_half_words(&self) -> usize {
        self.half_buffer_words(self.ifmap_sram_kb)
    }

    /// Words per filter SRAM half-buffer.
    pub fn filter_half_words(&self) -> usize {
        self.half_buffer_words(self.filter_sram_kb)
    }

    /// Words per ofmap SRAM half-buffer.
    pub fn ofmap_half_words(&self) -> usize {
        self.half_buffer_words(self.ofmap_sram_kb)
    }

    /// Seconds per cycle at the configured clock.
    pub fn cycle_time_s(&self) -> f64 {
        1.0 / (self.freq_mhz * 1e6)
    }

    /// Peak MACs/cycle of the array.
    pub fn peak_macs_per_cycle(&self) -> f64 {
        (self.array_rows * self.array_cols) as f64
    }

    /// Validate invariants; returns a list of problems (empty = ok).
    pub fn validate(&self) -> Vec<String> {
        let mut problems = Vec::new();
        if self.array_rows == 0 || self.array_cols == 0 {
            problems.push("array dimensions must be positive".to_string());
        }
        if self.ifmap_sram_kb == 0 || self.filter_sram_kb == 0 || self.ofmap_sram_kb == 0 {
            problems.push("SRAM sizes must be positive".to_string());
        }
        if self.ifmap_dram_bw <= 0.0 || self.filter_dram_bw <= 0.0 || self.ofmap_dram_bw <= 0.0 {
            problems.push("DRAM bandwidths must be positive".to_string());
        }
        if self.word_bytes == 0 {
            problems.push("word_bytes must be positive".to_string());
        }
        if self.freq_mhz <= 0.0 {
            problems.push("freq_mhz must be positive".to_string());
        }
        problems
    }

    /// Serialize for the asset files.
    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("name", Json::Str(self.name.clone()))
            .set("array_rows", Json::Num(self.array_rows as f64))
            .set("array_cols", Json::Num(self.array_cols as f64))
            .set("ifmap_sram_kb", Json::Num(self.ifmap_sram_kb as f64))
            .set("filter_sram_kb", Json::Num(self.filter_sram_kb as f64))
            .set("ofmap_sram_kb", Json::Num(self.ofmap_sram_kb as f64))
            .set("dataflow", Json::Str(self.dataflow.short().to_string()))
            .set("ifmap_dram_bw", Json::Num(self.ifmap_dram_bw))
            .set("filter_dram_bw", Json::Num(self.filter_dram_bw))
            .set("ofmap_dram_bw", Json::Num(self.ofmap_dram_bw))
            .set("word_bytes", Json::Num(self.word_bytes as f64))
            .set("freq_mhz", Json::Num(self.freq_mhz));
        o
    }

    /// Deserialize from the asset files.
    pub fn from_json(j: &Json) -> Result<ScaleConfig, JsonError> {
        Ok(ScaleConfig {
            name: j.req_str("name")?.to_string(),
            array_rows: j.req_f64("array_rows")? as usize,
            array_cols: j.req_f64("array_cols")? as usize,
            ifmap_sram_kb: j.req_f64("ifmap_sram_kb")? as usize,
            filter_sram_kb: j.req_f64("filter_sram_kb")? as usize,
            ofmap_sram_kb: j.req_f64("ofmap_sram_kb")? as usize,
            dataflow: Dataflow::parse(j.req_str("dataflow")?)
                .ok_or_else(|| JsonError::new("bad dataflow"))?,
            ifmap_dram_bw: j.req_f64("ifmap_dram_bw")?,
            filter_dram_bw: j.req_f64("filter_dram_bw")?,
            ofmap_dram_bw: j.req_f64("ofmap_dram_bw")?,
            word_bytes: j.req_f64("word_bytes")? as usize,
            freq_mhz: j.req_f64("freq_mhz")?,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dataflow_parse() {
        assert_eq!(Dataflow::parse("ws"), Some(Dataflow::WeightStationary));
        assert_eq!(Dataflow::parse("OS"), Some(Dataflow::OutputStationary));
        assert_eq!(Dataflow::parse("input_stationary"), Some(Dataflow::InputStationary));
        assert_eq!(Dataflow::parse("xx"), None);
    }

    #[test]
    fn tpu_v4_preset_valid() {
        let c = ScaleConfig::tpu_v4();
        assert!(c.validate().is_empty());
        assert_eq!(c.array_rows, 128);
        assert_eq!(c.array_cols, 128);
        // bf16: half of 8 MiB = 4 MiB = 2M words
        assert_eq!(c.ifmap_half_words(), 2 * 1024 * 1024);
        assert!((c.cycle_time_s() - 1.0 / 940e6).abs() < 1e-18);
    }

    #[test]
    fn json_roundtrip() {
        let c = ScaleConfig::eyeriss_like();
        let j = c.to_json();
        let c2 = ScaleConfig::from_json(&j).unwrap();
        assert_eq!(c, c2);
    }

    #[test]
    fn validation_catches_problems() {
        let mut c = ScaleConfig::tpu_v4();
        c.array_rows = 0;
        c.freq_mhz = -1.0;
        let problems = c.validate();
        assert_eq!(problems.len(), 2);
    }
}
