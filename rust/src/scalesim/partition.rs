//! Multi-core spatio-temporal partitioning (SCALE-Sim v3 feature).
//!
//! A GEMM can be sharded across `P` systolic cores along M (row-parallel)
//! or N (column-parallel); each core simulates its shard independently and
//! the ensemble finishes when the slowest shard finishes. This module is
//! used by the ablation benches and by the coordinator's multi-core mode.

use super::config::ScaleConfig;
use super::gemm::simulate_gemm;
use super::report::SimReport;
use super::topology::GemmShape;

/// Which GEMM dimension is split across cores.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PartitionAxis {
    /// Split the M (output rows) dimension.
    M,
    /// Split the N (output columns) dimension.
    N,
}

impl std::fmt::Display for PartitionAxis {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            PartitionAxis::M => f.write_str("M"),
            PartitionAxis::N => f.write_str("N"),
        }
    }
}

/// Result of a multi-core run.
#[derive(Debug, Clone)]
pub struct PartitionedReport {
    /// Axis the GEMM was split along.
    pub axis: PartitionAxis,
    /// Cores the work was split across.
    pub num_cores: usize,
    /// Per-core shard reports (cores with an empty shard are omitted).
    pub shards: Vec<SimReport>,
    /// Makespan: cycles until the slowest core finishes.
    pub makespan_cycles: u64,
}

impl PartitionedReport {
    /// Aggregate DRAM traffic across all cores.
    pub fn total_dram_words(&self) -> u64 {
        self.shards.iter().map(|s| s.total_dram_words()).sum()
    }

    /// Parallel speedup vs. a single-core run of the full GEMM.
    pub fn speedup_vs(&self, single: &SimReport) -> f64 {
        if self.makespan_cycles == 0 {
            return 0.0;
        }
        single.total_cycles() as f64 / self.makespan_cycles as f64
    }
}

/// Split `dim` into `parts` near-equal chunks (first chunks get the
/// remainder). Empty chunks are not produced when parts > dim.
pub fn split_dim(dim: usize, parts: usize) -> Vec<usize> {
    assert!(parts > 0);
    let parts = parts.min(dim.max(1));
    let base = dim / parts;
    let rem = dim % parts;
    (0..parts)
        .map(|i| base + usize::from(i < rem))
        .filter(|&c| c > 0)
        .collect()
}

/// Simulate `gemm` sharded across `num_cores` cores along `axis`.
pub fn simulate_partitioned(
    config: &ScaleConfig,
    gemm: GemmShape,
    num_cores: usize,
    axis: PartitionAxis,
) -> PartitionedReport {
    assert!(num_cores > 0);
    let chunks = match axis {
        PartitionAxis::M => split_dim(gemm.m, num_cores),
        PartitionAxis::N => split_dim(gemm.n, num_cores),
    };
    let shards: Vec<SimReport> = chunks
        .iter()
        .map(|&c| {
            let shard = match axis {
                PartitionAxis::M => GemmShape::new(c, gemm.k, gemm.n),
                PartitionAxis::N => GemmShape::new(gemm.m, gemm.k, c),
            };
            simulate_gemm(config, shard)
        })
        .collect();
    let makespan_cycles = shards.iter().map(|s| s.total_cycles()).max().unwrap_or(0);
    PartitionedReport {
        axis,
        num_cores,
        shards,
        makespan_cycles,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn split_dim_properties() {
        assert_eq!(split_dim(10, 3), vec![4, 3, 3]);
        assert_eq!(split_dim(9, 3), vec![3, 3, 3]);
        assert_eq!(split_dim(2, 4), vec![1, 1]); // no empty shards
        assert_eq!(split_dim(0, 2), Vec::<usize>::new());
        // Sum is preserved.
        for dim in [1usize, 7, 127, 4096] {
            for parts in [1usize, 2, 3, 8] {
                assert_eq!(split_dim(dim, parts).iter().sum::<usize>(), dim);
            }
        }
    }

    #[test]
    fn partitioning_speeds_up_large_gemm() {
        let c = ScaleConfig::tpu_v4();
        let g = GemmShape::new(4096, 1024, 1024);
        let single = simulate_gemm(&c, g);
        let quad = simulate_partitioned(&c, g, 4, PartitionAxis::M);
        let speedup = quad.speedup_vs(&single);
        assert!(speedup > 2.0, "speedup {speedup}");
        assert!(speedup <= 4.5, "speedup {speedup}");
    }

    #[test]
    fn makespan_is_max_shard() {
        let c = ScaleConfig::tpu_v4();
        let g = GemmShape::new(100, 256, 256); // uneven split over 3
        let p = simulate_partitioned(&c, g, 3, PartitionAxis::M);
        let max = p.shards.iter().map(|s| s.total_cycles()).max().unwrap();
        assert_eq!(p.makespan_cycles, max);
    }

    #[test]
    fn axis_matters_for_skewed_shapes() {
        let c = ScaleConfig::tpu_v4();
        let g = GemmShape::new(8192, 512, 128); // tall-skinny: split M better
        let pm = simulate_partitioned(&c, g, 4, PartitionAxis::M);
        let pn = simulate_partitioned(&c, g, 4, PartitionAxis::N);
        assert!(pm.makespan_cycles < pn.makespan_cycles);
    }

    #[test]
    fn work_conserved_across_shards() {
        let c = ScaleConfig::tpu_v4();
        let g = GemmShape::new(1000, 300, 700);
        let p = simulate_partitioned(&c, g, 5, PartitionAxis::N);
        let shard_macs: u64 = p.shards.iter().map(|s| s.gemm.macs()).sum();
        assert_eq!(shard_macs, g.macs());
    }
}
