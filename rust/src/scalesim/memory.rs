//! SRAM double-buffering and DRAM bandwidth stall model.
//!
//! SCALE-Sim models each operand SRAM as a double buffer: while one half
//! feeds the array, the other half is prefetched from DRAM. A fold stalls
//! when its operands have not finished prefetching by the time the
//! previous fold's compute completes. We simulate this fold-by-fold (fold
//! classes are expanded lazily, so a 4096³ GEMM is still cheap) instead of
//! generating per-cycle address traces; the resulting stall counts match
//! the trace model whenever accesses are streaming, which systolic GEMM
//! operands are.
//!
//! Demand per fold depends on the dataflow:
//!
//! * **WS** — stationary: a tile of B (rows×cols words); streamed: `M`
//!   rows of A (stream_len × rows_used words); drained: stream_len ×
//!   cols_used words of C (only on the last K-fold of an output tile;
//!   partial sums otherwise spill to the ofmap SRAM).
//! * **OS** — streamed: K × rows_used words of A and K × cols_used words
//!   of B per fold; drained: rows_used × cols_used words of C.
//! * **IS** — stationary: a tile of Aᵀ; streamed: N columns of B; drained:
//!   stream_len × rows? (mirror of WS).

use super::config::{Dataflow, ScaleConfig};
use super::dataflow::{ComputeModel, FoldCost};
use super::topology::GemmShape;

/// DRAM traffic and stall summary for one GEMM execution.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct MemoryModel {
    /// Words read from DRAM for the A / ifmap operand.
    pub ifmap_dram_reads: u64,
    /// Words read from DRAM for the B / filter operand.
    pub filter_dram_reads: u64,
    /// Words written to DRAM for the C / ofmap operand.
    pub ofmap_dram_writes: u64,
    /// Stall cycles waiting on operand prefetch.
    pub stall_cycles: u64,
    /// Cycles of the initial (non-overlappable) prefetch.
    pub initial_fill_cycles: u64,
    /// True if each fold's working set fits one SRAM half-buffer.
    pub fits_on_chip: bool,
}

impl MemoryModel {
    /// Total DRAM traffic in words (reads + writes).
    pub fn total_dram_words(&self) -> u64 {
        self.ifmap_dram_reads + self.filter_dram_reads + self.ofmap_dram_writes
    }
}

/// Per-fold operand demand in words.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
struct FoldDemand {
    ifmap_words: u64,
    filter_words: u64,
    ofmap_words: u64,
}

fn fold_demand(dataflow: Dataflow, fold: &FoldCost) -> FoldDemand {
    let r = fold.rows_used as u64;
    let c = fold.cols_used as u64;
    let t = fold.stream_len as u64;
    match dataflow {
        // OS: A tile is rows×K, B tile is K×cols, C tile is rows×cols.
        Dataflow::OutputStationary => FoldDemand {
            ifmap_words: r * t,
            filter_words: t * c,
            ofmap_words: r * c,
        },
        // WS: stationary B tile rows×cols (K-rows × N-cols), streamed A is
        // T(M) × rows(K) words, produced C is T(M) × cols(N) words.
        Dataflow::WeightStationary => FoldDemand {
            ifmap_words: t * r,
            filter_words: r * c,
            ofmap_words: t * c,
        },
        // IS: stationary A tile rows(K)×cols(M), streamed B is T(N) ×
        // rows(K), produced C is cols(M) × T(N).
        Dataflow::InputStationary => FoldDemand {
            ifmap_words: r * c,
            filter_words: t * r,
            ofmap_words: c * t,
        },
    }
}

/// Simulate the double-buffered prefetch pipeline over the fold sequence.
///
/// The fold classes of [`ComputeModel`] are walked with multiplicity; all
/// folds in a class are identical, so per-class arithmetic replaces the
/// per-fold loop when the class is homogeneous (O(#classes), not
/// O(#folds)).
pub fn memory_model(
    config: &ScaleConfig,
    _gemm: GemmShape,
    compute: &ComputeModel,
) -> MemoryModel {
    let mut out = MemoryModel {
        fits_on_chip: true,
        ..Default::default()
    };

    // Read bandwidth is shared per-operand (SCALE-Sim models separate
    // interfaces); prefetch time of a fold is the max over operands.
    let read_time = |d: &FoldDemand| -> u64 {
        let t_if = (d.ifmap_words as f64 / config.ifmap_dram_bw).ceil() as u64;
        let t_fl = (d.filter_words as f64 / config.filter_dram_bw).ceil() as u64;
        t_if.max(t_fl)
    };
    let write_time =
        |d: &FoldDemand| -> u64 { (d.ofmap_words as f64 / config.ofmap_dram_bw).ceil() as u64 };

    // Half-buffer capacities in words.
    let if_half = config.ifmap_half_words() as u64;
    let fl_half = config.filter_half_words() as u64;
    let of_half = config.ofmap_half_words() as u64;

    let mut first = true;
    for (fold, count) in &compute.fold_classes {
        let demand = fold_demand(config.dataflow, fold);
        out.ifmap_dram_reads += demand.ifmap_words * count;
        out.filter_dram_reads += demand.filter_words * count;
        out.ofmap_dram_writes += demand.ofmap_words * count;
        if demand.ifmap_words > if_half
            || demand.filter_words > fl_half
            || demand.ofmap_words > of_half
        {
            out.fits_on_chip = false;
        }

        let t_read = read_time(&demand);
        let t_write = write_time(&demand);
        let t_compute = fold.total_cycles();

        let mut remaining = *count;
        if first {
            // The very first fold's prefetch cannot be hidden.
            out.initial_fill_cycles = t_read;
            first = false;
            remaining -= 1;
        }
        // Steady state: the next fold's prefetch (and the previous fold's
        // writeback) overlap the current fold's compute. Stall per fold is
        // the shortfall of compute time vs. the slower of read/write.
        let t_mem = t_read.max(t_write);
        let stall_per_fold = t_mem.saturating_sub(t_compute);
        out.stall_cycles += stall_per_fold * remaining;
    }

    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scalesim::dataflow::compute_model;

    fn cfg(df: Dataflow, bw: f64) -> ScaleConfig {
        let mut c = ScaleConfig::tpu_v4();
        c.array_rows = 8;
        c.array_cols = 8;
        c.dataflow = df;
        c.ifmap_dram_bw = bw;
        c.filter_dram_bw = bw;
        c.ofmap_dram_bw = bw;
        c
    }

    #[test]
    fn traffic_counts_ws_single_fold() {
        let c = cfg(Dataflow::WeightStationary, 100.0);
        let g = GemmShape::new(16, 8, 8); // K=8 rows, N=8 cols, stream M=16
        let cm = compute_model(&c, g);
        let mm = memory_model(&c, g, &cm);
        assert_eq!(mm.filter_dram_reads, 64); // full B
        assert_eq!(mm.ifmap_dram_reads, 128); // full A
        assert_eq!(mm.ofmap_dram_writes, 128); // full C
        assert!(mm.fits_on_chip);
    }

    #[test]
    fn traffic_counts_os_reuse() {
        // OS refetches A per column-fold and B per row-fold.
        let c = cfg(Dataflow::OutputStationary, 100.0);
        let g = GemmShape::new(16, 4, 16); // fold grid (2, 2)
        let cm = compute_model(&c, g);
        let mm = memory_model(&c, g, &cm);
        // A words = M*K = 64, streamed once per col fold (2) = 128.
        assert_eq!(mm.ifmap_dram_reads, 128);
        // B words = K*N = 64, once per row fold (2) = 128.
        assert_eq!(mm.filter_dram_reads, 128);
        // C written exactly once.
        assert_eq!(mm.ofmap_dram_writes, 256);
    }

    #[test]
    fn high_bandwidth_no_stall() {
        let c = cfg(Dataflow::WeightStationary, 1000.0);
        let g = GemmShape::new(64, 64, 64);
        let cm = compute_model(&c, g);
        let mm = memory_model(&c, g, &cm);
        assert_eq!(mm.stall_cycles, 0);
        assert!(mm.initial_fill_cycles > 0);
    }

    #[test]
    fn low_bandwidth_stalls() {
        let lo = cfg(Dataflow::WeightStationary, 0.5);
        let hi = cfg(Dataflow::WeightStationary, 64.0);
        let g = GemmShape::new(64, 64, 64);
        let stall_lo = memory_model(&lo, g, &compute_model(&lo, g)).stall_cycles;
        let stall_hi = memory_model(&hi, g, &compute_model(&hi, g)).stall_cycles;
        assert!(stall_lo > stall_hi);
        assert!(stall_lo > 0);
    }

    #[test]
    fn oversized_fold_flagged() {
        let mut c = cfg(Dataflow::WeightStationary, 10.0);
        c.ifmap_sram_kb = 1; // 256 words per half at 2B words
        let g = GemmShape::new(1024, 8, 8); // A stream demand = 1024*8 words
        let cm = compute_model(&c, g);
        let mm = memory_model(&c, g, &cm);
        assert!(!mm.fits_on_chip);
    }

    #[test]
    fn dram_words_scale_with_folds() {
        let c = cfg(Dataflow::WeightStationary, 10.0);
        let small = GemmShape::new(32, 32, 32);
        let big = GemmShape::new(64, 64, 64);
        let t_small =
            memory_model(&c, small, &compute_model(&c, small)).total_dram_words();
        let t_big = memory_model(&c, big, &compute_model(&c, big)).total_dram_words();
        assert!(t_big > t_small * 4); // superlinear growth from refetch
    }
}
