//! Per-dataflow systolic compute-cycle models.
//!
//! These follow the SCALE-Sim analytical model: a GEMM C[M,N] = A[M,K] ×
//! B[K,N] is executed on an S_R × S_C array as a sequence of *folds*; each
//! fold processes the largest sub-problem the array can hold under the
//! chosen dataflow, and costs a pipeline-fill skew, a streaming phase and a
//! drain skew. Compute cycles here assume perfect operand supply; memory
//! stalls are layered on by [`crate::scalesim::memory`].
//!
//! Mapping conventions (matching SCALE-Sim):
//!
//! * **Output stationary (OS)** — the array holds an S_R × S_C tile of C.
//!   Rows of A enter from the left, columns of B from the top, partial sums
//!   stay in place. Folds: ⌈M/S_R⌉ · ⌈N/S_C⌉, each streaming K terms.
//! * **Weight stationary (WS)** — an S_R × S_C tile of B (K rows × N cols)
//!   is pinned; A streams through. Folds: ⌈K/S_R⌉ · ⌈N/S_C⌉, each
//!   streaming M rows of A.
//! * **Input stationary (IS)** — an S_R × S_C tile of Aᵀ (K rows × M cols)
//!   is pinned; B streams through. Folds: ⌈K/S_R⌉ · ⌈M/S_C⌉, each
//!   streaming N columns of B.

use super::config::{Dataflow, ScaleConfig};
use super::topology::GemmShape;

/// Ceiling division.
pub fn ceil_div(a: usize, b: usize) -> usize {
    debug_assert!(b > 0);
    a.div_ceil(b)
}

/// One fold's geometry and cost under a dataflow.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FoldCost {
    /// Rows of the array actually occupied this fold.
    pub rows_used: usize,
    /// Columns of the array actually occupied this fold.
    pub cols_used: usize,
    /// Streaming length (K for OS, M for WS, N for IS).
    pub stream_len: usize,
    /// Cycles to set up the stationary operand (0 for OS).
    pub load_cycles: u64,
    /// Cycles for the streaming + skew phases.
    pub stream_cycles: u64,
}

impl FoldCost {
    /// Cycles of one fold including stalls.
    pub fn total_cycles(&self) -> u64 {
        self.load_cycles + self.stream_cycles
    }

    /// Fraction of the array occupied (mapping efficiency of this fold).
    pub fn occupancy(&self, config: &ScaleConfig) -> f64 {
        (self.rows_used * self.cols_used) as f64
            / (config.array_rows * config.array_cols) as f64
    }
}

/// Aggregate compute-phase result for one GEMM.
#[derive(Debug, Clone, PartialEq)]
pub struct ComputeModel {
    /// Dataflow the model was built for.
    pub dataflow: Dataflow,
    /// Fold grid (row folds, col folds).
    pub fold_grid: (usize, usize),
    /// Total folds.
    pub num_folds: usize,
    /// Pure compute cycles, assuming no memory stalls.
    pub compute_cycles: u64,
    /// Average mapping efficiency: occupied PE-cycles / total PE-cycles in
    /// the *streaming* phases (SCALE-Sim's "mapping efficiency").
    pub mapping_efficiency: f64,
    /// Overall compute utilisation: useful MACs / (PEs × compute_cycles).
    pub compute_utilisation: f64,
    /// Per-fold costs in execution order. For large fold counts only the
    /// distinct fold geometries are stored with multiplicities.
    pub fold_classes: Vec<(FoldCost, u64)>,
}

/// Compute the fold decomposition and cycle cost of `gemm` on `config`.
///
/// Folds with identical geometry are collapsed into classes (a 4096³ GEMM
/// has millions of folds but at most 4 distinct geometries: interior,
/// ragged-right, ragged-bottom, corner).
pub fn compute_model(config: &ScaleConfig, gemm: GemmShape) -> ComputeModel {
    assert!(gemm.valid(), "GEMM dims must be positive: {gemm}");
    let (sr, sc) = (config.array_rows, config.array_cols);

    // Dimension mapped across rows / cols / stream, per dataflow.
    let (row_dim, col_dim, stream_dim) = match config.dataflow {
        Dataflow::OutputStationary => (gemm.m, gemm.n, gemm.k),
        Dataflow::WeightStationary => (gemm.k, gemm.n, gemm.m),
        Dataflow::InputStationary => (gemm.k, gemm.m, gemm.n),
    };

    let row_folds = ceil_div(row_dim, sr);
    let col_folds = ceil_div(col_dim, sc);
    let num_folds = row_folds * col_folds;

    // Ragged edge sizes.
    let last_rows = row_dim - (row_folds - 1) * sr;
    let last_cols = col_dim - (col_folds - 1) * sc;

    // The four geometry classes and their multiplicities.
    let mut classes: Vec<((usize, usize), u64)> = Vec::with_capacity(4);
    let interior = ((row_folds - 1) * (col_folds - 1)) as u64;
    if interior > 0 {
        classes.push(((sr, sc), interior));
    }
    // Last grid row (ragged rows, full columns), excluding the corner.
    let bottom = (col_folds - 1) as u64;
    if bottom > 0 {
        classes.push(((last_rows, sc), bottom));
    }
    // Last grid column (full rows, ragged columns), excluding the corner.
    let right = (row_folds - 1) as u64;
    if right > 0 {
        classes.push(((sr, last_cols), right));
    }
    classes.push(((last_rows, last_cols), 1));

    let mut compute_cycles = 0u64;
    let mut occupied_pe_cycles = 0.0f64;
    let mut fold_classes = Vec::with_capacity(classes.len());
    for ((rows_used, cols_used), count) in classes {
        let cost = fold_cost(config, rows_used, cols_used, stream_dim);
        compute_cycles += cost.total_cycles() * count;
        occupied_pe_cycles +=
            (rows_used * cols_used) as f64 * cost.total_cycles() as f64 * count as f64;
        fold_classes.push((cost, count));
    }

    let total_pe_cycles = config.peak_macs_per_cycle() * compute_cycles as f64;
    let mapping_efficiency = if total_pe_cycles > 0.0 {
        occupied_pe_cycles / total_pe_cycles
    } else {
        0.0
    };
    let compute_utilisation = if total_pe_cycles > 0.0 {
        gemm.macs() as f64 / total_pe_cycles
    } else {
        0.0
    };

    ComputeModel {
        dataflow: config.dataflow,
        fold_grid: (row_folds, col_folds),
        num_folds,
        compute_cycles,
        mapping_efficiency,
        compute_utilisation,
        fold_classes,
    }
}

/// Cycle cost of one fold with `rows_used × cols_used` active PEs and a
/// streaming dimension of `stream_len`.
fn fold_cost(
    config: &ScaleConfig,
    rows_used: usize,
    cols_used: usize,
    stream_len: usize,
) -> FoldCost {
    let (r, c, t) = (rows_used as u64, cols_used as u64, stream_len as u64);
    match config.dataflow {
        // OS (SCALE-Sim v1 eq.): 2·S_R + S_C + T − 2 per fold — fill the
        // array diagonally (S_R), stream T partial-sum terms, then shift
        // results out (S_R) while the column skew (S_C) drains.
        Dataflow::OutputStationary => FoldCost {
            rows_used,
            cols_used,
            stream_len,
            load_cycles: 0,
            stream_cycles: 2 * r + c + t - 2,
        },
        // WS: load weights row-by-row (S_R cycles), then stream T = M rows
        // of A through; first result after S_R + S_C − 1, last after
        // S_R + S_C + T − 2 ⇒ stream phase costs S_R + S_C + T − 2.
        Dataflow::WeightStationary => FoldCost {
            rows_used,
            cols_used,
            stream_len,
            load_cycles: r,
            stream_cycles: r + c + t - 2,
        },
        // IS mirrors WS with A and B swapped.
        Dataflow::InputStationary => FoldCost {
            rows_used,
            cols_used,
            stream_len,
            load_cycles: r,
            stream_cycles: r + c + t - 2,
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(df: Dataflow) -> ScaleConfig {
        let mut c = ScaleConfig::tpu_v4();
        c.array_rows = 8;
        c.array_cols = 8;
        c.dataflow = df;
        c
    }

    #[test]
    fn os_single_fold_formula() {
        let c = cfg(Dataflow::OutputStationary);
        let m = compute_model(&c, GemmShape::new(8, 16, 8));
        assert_eq!(m.num_folds, 1);
        // 2*8 + 8 + 16 - 2 = 38
        assert_eq!(m.compute_cycles, 38);
        assert!((m.mapping_efficiency - 1.0).abs() < 1e-12);
    }

    #[test]
    fn ws_single_fold_formula() {
        let c = cfg(Dataflow::WeightStationary);
        let m = compute_model(&c, GemmShape::new(16, 8, 8));
        assert_eq!(m.num_folds, 1);
        // load 8 + (8 + 8 + 16 - 2) = 38
        assert_eq!(m.compute_cycles, 38);
    }

    #[test]
    fn is_single_fold_formula() {
        let c = cfg(Dataflow::InputStationary);
        // IS: rows = K, cols = M, stream = N
        let m = compute_model(&c, GemmShape::new(8, 8, 16));
        assert_eq!(m.num_folds, 1);
        assert_eq!(m.compute_cycles, 8 + (8 + 8 + 16 - 2));
    }

    #[test]
    fn fold_counts_by_dataflow() {
        let g = GemmShape::new(20, 17, 9);
        let m_os = compute_model(&cfg(Dataflow::OutputStationary), g);
        assert_eq!(m_os.fold_grid, (3, 2)); // ceil(20/8), ceil(9/8)
        let m_ws = compute_model(&cfg(Dataflow::WeightStationary), g);
        assert_eq!(m_ws.fold_grid, (3, 2)); // ceil(17/8), ceil(9/8)
        let m_is = compute_model(&cfg(Dataflow::InputStationary), g);
        assert_eq!(m_is.fold_grid, (3, 3)); // ceil(17/8), ceil(20/8)
    }

    #[test]
    fn fold_class_multiplicities_sum() {
        let g = GemmShape::new(100, 50, 60);
        for df in [
            Dataflow::OutputStationary,
            Dataflow::WeightStationary,
            Dataflow::InputStationary,
        ] {
            let m = compute_model(&cfg(df), g);
            let total: u64 = m.fold_classes.iter().map(|(_, n)| n).sum();
            assert_eq!(total, m.num_folds as u64, "{df}");
        }
    }

    #[test]
    fn ragged_fold_occupancy() {
        let c = cfg(Dataflow::OutputStationary);
        // 12x12 outputs on an 8x8 array: folds (2,2); corner fold is 4x4.
        let m = compute_model(&c, GemmShape::new(12, 16, 12));
        assert_eq!(m.num_folds, 4);
        assert!(m.mapping_efficiency < 1.0);
        assert!(m.mapping_efficiency > 0.5);
        let corner = m
            .fold_classes
            .iter()
            .find(|(f, _)| f.rows_used == 4 && f.cols_used == 4)
            .expect("corner fold");
        assert!((corner.0.occupancy(&c) - 0.25).abs() < 1e-12);
    }

    #[test]
    fn utilisation_improves_with_size() {
        let c = ScaleConfig::tpu_v4(); // 128x128 WS
        let small = compute_model(&c, GemmShape::new(32, 32, 32));
        let medium = compute_model(&c, GemmShape::new(512, 512, 512));
        let large = compute_model(&c, GemmShape::new(4096, 4096, 4096));
        assert!(small.compute_utilisation < medium.compute_utilisation);
        assert!(medium.compute_utilisation < large.compute_utilisation);
        assert!(large.compute_utilisation > 0.9);
    }

    #[test]
    fn cycles_monotone_in_each_dim() {
        let c = ScaleConfig::tpu_v4();
        let base = compute_model(&c, GemmShape::new(256, 256, 256)).compute_cycles;
        for g in [
            GemmShape::new(512, 256, 256),
            GemmShape::new(256, 512, 256),
            GemmShape::new(256, 256, 512),
        ] {
            assert!(compute_model(&c, g).compute_cycles > base, "{g}");
        }
    }

    #[test]
    fn macs_conserved_in_utilisation() {
        // utilisation * PEs * cycles must equal MACs exactly.
        let c = cfg(Dataflow::WeightStationary);
        let g = GemmShape::new(30, 23, 17);
        let m = compute_model(&c, g);
        let macs = m.compute_utilisation * c.peak_macs_per_cycle() * m.compute_cycles as f64;
        assert!((macs - g.macs() as f64).abs() < 1e-6);
    }
}
