//! Simulation result types.

use super::config::{Dataflow, ScaleConfig};
use super::topology::GemmShape;
use crate::util::json::Json;

/// Full result of simulating one GEMM (or one conv via im2col) on one core.
#[derive(Debug, Clone, PartialEq)]
pub struct SimReport {
    /// Architecture config the run used.
    pub config_name: String,
    /// Dataflow the run used.
    pub dataflow: Dataflow,
    /// The simulated GEMM.
    pub gemm: GemmShape,
    /// Pure compute cycles (fills, streams, drains; no stalls).
    pub compute_cycles: u64,
    /// Stall cycles from DRAM bandwidth shortfall.
    pub stall_cycles: u64,
    /// Non-overlapped initial prefetch cycles.
    pub initial_fill_cycles: u64,
    /// Folds executed.
    pub num_folds: usize,
    /// Occupied-PE fraction during compute.
    pub mapping_efficiency: f64,
    /// Useful MACs / (PEs × total cycles).
    pub utilisation: f64,
    /// DRAM traffic in words.
    pub ifmap_dram_reads: u64,
    /// Words read from DRAM for the filter operand.
    pub filter_dram_reads: u64,
    /// Words written to DRAM for the result.
    pub ofmap_dram_writes: u64,
    /// Whether every fold's working set fit a half buffer.
    pub fits_on_chip: bool,
    /// Clock used for the time estimate, MHz.
    pub freq_mhz: f64,
}

impl SimReport {
    /// Total cycles: initial fill + compute + stalls.
    pub fn total_cycles(&self) -> u64 {
        self.initial_fill_cycles + self.compute_cycles + self.stall_cycles
    }

    /// Uncalibrated time estimate: cycles at the configured clock.
    pub fn raw_time_s(&self) -> f64 {
        self.total_cycles() as f64 / (self.freq_mhz * 1e6)
    }

    /// Wall time at the config clock (no calibration), µs.
    pub fn raw_time_us(&self) -> f64 {
        self.raw_time_s() * 1e6
    }

    /// Total DRAM traffic in words.
    pub fn total_dram_words(&self) -> u64 {
        self.ifmap_dram_reads + self.filter_dram_reads + self.ofmap_dram_writes
    }

    /// Achieved DRAM bandwidth, words/cycle.
    pub fn achieved_bw_words_per_cycle(&self) -> f64 {
        if self.total_cycles() == 0 {
            return 0.0;
        }
        self.total_dram_words() as f64 / self.total_cycles() as f64
    }

    /// Effective TFLOP/s at the configured clock (2 flops per MAC).
    pub fn effective_tflops(&self, config: &ScaleConfig) -> f64 {
        let secs = self.raw_time_s();
        if secs == 0.0 {
            return 0.0;
        }
        let _ = config;
        2.0 * self.gemm.macs() as f64 / secs / 1e12
    }

    /// Serialize the report.
    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("config", Json::Str(self.config_name.clone()))
            .set("dataflow", Json::Str(self.dataflow.short().into()))
            .set("m", Json::Num(self.gemm.m as f64))
            .set("k", Json::Num(self.gemm.k as f64))
            .set("n", Json::Num(self.gemm.n as f64))
            .set("compute_cycles", Json::Num(self.compute_cycles as f64))
            .set("stall_cycles", Json::Num(self.stall_cycles as f64))
            .set(
                "initial_fill_cycles",
                Json::Num(self.initial_fill_cycles as f64),
            )
            .set("total_cycles", Json::Num(self.total_cycles() as f64))
            .set("num_folds", Json::Num(self.num_folds as f64))
            .set("mapping_efficiency", Json::Num(self.mapping_efficiency))
            .set("utilisation", Json::Num(self.utilisation))
            .set("ifmap_dram_reads", Json::Num(self.ifmap_dram_reads as f64))
            .set(
                "filter_dram_reads",
                Json::Num(self.filter_dram_reads as f64),
            )
            .set(
                "ofmap_dram_writes",
                Json::Num(self.ofmap_dram_writes as f64),
            )
            .set("fits_on_chip", Json::Bool(self.fits_on_chip))
            .set("raw_time_us", Json::Num(self.raw_time_us()));
        o
    }
}

impl std::fmt::Display for SimReport {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{} [{}] cycles={} (compute={} stall={} fill={}) folds={} util={:.1}% map_eff={:.1}% dram={}w time={:.2}us",
            self.gemm,
            self.dataflow,
            self.total_cycles(),
            self.compute_cycles,
            self.stall_cycles,
            self.initial_fill_cycles,
            self.num_folds,
            self.utilisation * 100.0,
            self.mapping_efficiency * 100.0,
            self.total_dram_words(),
            self.raw_time_us(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn report() -> SimReport {
        SimReport {
            config_name: "t".into(),
            dataflow: Dataflow::WeightStationary,
            gemm: GemmShape::new(128, 128, 128),
            compute_cycles: 1000,
            stall_cycles: 100,
            initial_fill_cycles: 10,
            num_folds: 1,
            mapping_efficiency: 1.0,
            utilisation: 0.8,
            ifmap_dram_reads: 16384,
            filter_dram_reads: 16384,
            ofmap_dram_writes: 16384,
            fits_on_chip: true,
            freq_mhz: 1000.0,
        }
    }

    #[test]
    fn totals() {
        let r = report();
        assert_eq!(r.total_cycles(), 1110);
        assert!((r.raw_time_us() - 1.11).abs() < 1e-9);
        assert_eq!(r.total_dram_words(), 3 * 16384);
    }

    #[test]
    fn json_has_fields() {
        let j = report().to_json();
        assert_eq!(j.req_f64("total_cycles").unwrap(), 1110.0);
        assert_eq!(j.req_str("dataflow").unwrap(), "WS");
    }
}
