//! Workload topology descriptors: GEMM and convolution layers, plus the
//! legacy SCALE-Sim CSV topology format and the im2col lowering that turns
//! a convolution into a GEMM.

use anyhow::{bail, Context, Result};

/// A GEMM workload C[M,N] = A[M,K] × B[K,N].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct GemmShape {
    /// Rows of A / C.
    pub m: usize,
    /// Contraction (columns of A, rows of B).
    pub k: usize,
    /// Columns of B / C.
    pub n: usize,
}

impl GemmShape {
    /// A GEMM of `m x k` times `k x n`.
    pub fn new(m: usize, k: usize, n: usize) -> GemmShape {
        GemmShape { m, k, n }
    }

    /// Total multiply-accumulate operations.
    pub fn macs(&self) -> u64 {
        self.m as u64 * self.k as u64 * self.n as u64
    }

    /// Operand word counts (A, B, C).
    pub fn a_words(&self) -> u64 {
        self.m as u64 * self.k as u64
    }

    /// Words in the B operand.
    pub fn b_words(&self) -> u64 {
        self.k as u64 * self.n as u64
    }

    /// Words in the C result.
    pub fn c_words(&self) -> u64 {
        self.m as u64 * self.n as u64
    }

    /// All dimensions positive.
    pub fn valid(&self) -> bool {
        self.m > 0 && self.k > 0 && self.n > 0
    }
}

impl std::fmt::Display for GemmShape {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "GEMM {}x{}x{} (MxKxN)", self.m, self.k, self.n)
    }
}

/// A 2D convolution layer in the classic SCALE-Sim topology format.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ConvLayer {
    /// Layer name from the CSV.
    pub name: String,
    /// Input feature-map height.
    pub ifmap_h: usize,
    /// Input feature-map width.
    pub ifmap_w: usize,
    /// Filter height.
    pub filter_h: usize,
    /// Filter width.
    pub filter_w: usize,
    /// Input channels.
    pub channels: usize,
    /// Output channels (filter count).
    pub num_filters: usize,
    /// Vertical stride.
    pub stride_h: usize,
    /// Horizontal stride.
    pub stride_w: usize,
}

impl ConvLayer {
    /// Output feature-map height (valid padding, as SCALE-Sim assumes).
    pub fn out_h(&self) -> usize {
        if self.ifmap_h < self.filter_h {
            0
        } else {
            (self.ifmap_h - self.filter_h) / self.stride_h + 1
        }
    }

    /// Output feature-map width.
    pub fn out_w(&self) -> usize {
        if self.ifmap_w < self.filter_w {
            0
        } else {
            (self.ifmap_w - self.filter_w) / self.stride_w + 1
        }
    }

    /// im2col lowering: each output pixel is a GEMM row, each filter a
    /// column, and the contraction runs over the filter window × channels.
    ///
    ///   M = out_h · out_w
    ///   K = filter_h · filter_w · channels
    ///   N = num_filters
    pub fn to_gemm(&self) -> GemmShape {
        GemmShape {
            m: self.out_h() * self.out_w(),
            k: self.filter_h * self.filter_w * self.channels,
            n: self.num_filters,
        }
    }

    /// Total MACs for the convolution (equals the im2col GEMM's MACs).
    pub fn macs(&self) -> u64 {
        self.to_gemm().macs()
    }

    /// Reject degenerate dimensions with a descriptive error.
    pub fn validate(&self) -> Result<()> {
        if self.ifmap_h == 0 || self.ifmap_w == 0 {
            bail!("layer {}: ifmap dims must be positive", self.name);
        }
        if self.filter_h == 0 || self.filter_w == 0 {
            bail!("layer {}: filter dims must be positive", self.name);
        }
        if self.filter_h > self.ifmap_h || self.filter_w > self.ifmap_w {
            bail!("layer {}: filter larger than ifmap", self.name);
        }
        if self.channels == 0 || self.num_filters == 0 {
            bail!("layer {}: channels/filters must be positive", self.name);
        }
        if self.stride_h == 0 || self.stride_w == 0 {
            bail!("layer {}: strides must be positive", self.name);
        }
        Ok(())
    }
}

/// A workload layer: either a raw GEMM or a convolution.
#[derive(Debug, Clone, PartialEq)]
pub enum Layer {
    /// A dense GEMM layer.
    Gemm {
        /// Layer name from the CSV.
        name: String,
        /// The GEMM dimensions.
        shape: GemmShape,
    },
    /// A 2-D convolution layer.
    Conv(ConvLayer),
}

impl Layer {
    /// The layer's name (either kind).
    pub fn name(&self) -> &str {
        match self {
            Layer::Gemm { name, .. } => name,
            Layer::Conv(c) => &c.name,
        }
    }

    /// The GEMM this layer maps to on the systolic array.
    pub fn as_gemm(&self) -> GemmShape {
        match self {
            Layer::Gemm { shape, .. } => *shape,
            Layer::Conv(c) => c.to_gemm(),
        }
    }
}

/// A named sequence of layers (one network).
#[derive(Debug, Clone, PartialEq, Default)]
pub struct Topology {
    /// Workload name (CSV stem).
    pub name: String,
    /// Layers in execution order.
    pub layers: Vec<Layer>,
}

impl Topology {
    /// Parse the legacy SCALE-Sim CSV topology format.
    ///
    /// Conv rows: `name, ifmap_h, ifmap_w, filt_h, filt_w, channels,
    /// num_filters, stride,` — GEMM rows (v3 "gemm" topologies):
    /// `name, M, K, N,`. A header line is skipped if present.
    pub fn parse_csv(name: &str, text: &str) -> Result<Topology> {
        let mut layers = Vec::new();
        let mut header_allowed = true;
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let cells: Vec<&str> = line
                .split(',')
                .map(str::trim)
                .filter(|c| !c.is_empty())
                .collect();
            if cells.is_empty() {
                continue;
            }
            // One header row may lead the file (second cell not numeric);
            // later non-numeric rows are data errors, not headers.
            if cells.len() >= 2 && cells[1].parse::<usize>().is_err() {
                if header_allowed {
                    header_allowed = false;
                    continue;
                }
                bail!("line {}: non-numeric cell '{}'", lineno + 1, cells[1]);
            }
            header_allowed = false;
            let parse = |i: usize| -> Result<usize> {
                cells
                    .get(i)
                    .with_context(|| format!("line {}: missing column {}", lineno + 1, i))?
                    .parse::<usize>()
                    .with_context(|| format!("line {}: bad integer '{}'", lineno + 1, cells[i]))
            };
            match cells.len() {
                4 => {
                    let shape = GemmShape::new(parse(1)?, parse(2)?, parse(3)?);
                    if !shape.valid() {
                        bail!("line {}: GEMM dims must be positive", lineno + 1);
                    }
                    layers.push(Layer::Gemm {
                        name: cells[0].to_string(),
                        shape,
                    });
                }
                8 | 9 => {
                    let layer = ConvLayer {
                        name: cells[0].to_string(),
                        ifmap_h: parse(1)?,
                        ifmap_w: parse(2)?,
                        filter_h: parse(3)?,
                        filter_w: parse(4)?,
                        channels: parse(5)?,
                        num_filters: parse(6)?,
                        stride_h: parse(7)?,
                        stride_w: if cells.len() == 9 { parse(8)? } else { parse(7)? },
                    };
                    layer.validate()?;
                    layers.push(Layer::Conv(layer));
                }
                nc => bail!("line {}: expected 4 (GEMM) or 8/9 (conv) columns, got {nc}", lineno + 1),
            }
        }
        Ok(Topology {
            name: name.to_string(),
            layers,
        })
    }

    /// Total multiply-accumulates across all layers.
    pub fn total_macs(&self) -> u64 {
        self.layers.iter().map(|l| l.as_gemm().macs()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gemm_counts() {
        let g = GemmShape::new(4, 5, 6);
        assert_eq!(g.macs(), 120);
        assert_eq!(g.a_words(), 20);
        assert_eq!(g.b_words(), 30);
        assert_eq!(g.c_words(), 24);
    }

    #[test]
    fn conv_output_dims_and_im2col() {
        // Classic 3x3 stride-1 conv on 32x32x16 with 64 filters.
        let c = ConvLayer {
            name: "c1".into(),
            ifmap_h: 32,
            ifmap_w: 32,
            filter_h: 3,
            filter_w: 3,
            channels: 16,
            num_filters: 64,
            stride_h: 1,
            stride_w: 1,
        };
        assert_eq!(c.out_h(), 30);
        assert_eq!(c.out_w(), 30);
        let g = c.to_gemm();
        assert_eq!(g, GemmShape::new(900, 144, 64));
        assert_eq!(c.macs(), 900 * 144 * 64);
    }

    #[test]
    fn conv_strided() {
        let c = ConvLayer {
            name: "c2".into(),
            ifmap_h: 224,
            ifmap_w: 224,
            filter_h: 7,
            filter_w: 7,
            channels: 3,
            num_filters: 64,
            stride_h: 2,
            stride_w: 2,
        };
        // (224-7)/2+1 = 109
        assert_eq!(c.out_h(), 109);
        assert_eq!(c.out_w(), 109);
    }

    #[test]
    fn csv_conv_rows() {
        let text = "Layer name, IFMAP H, IFMAP W, Filt H, Filt W, Channels, Num Filters, Stride,\n\
                    conv1, 224, 224, 7, 7, 3, 64, 2,\n\
                    conv2, 56, 56, 3, 3, 64, 64, 1,\n";
        let topo = Topology::parse_csv("resnet_head", text).unwrap();
        assert_eq!(topo.layers.len(), 2);
        assert_eq!(topo.layers[0].name(), "conv1");
        match &topo.layers[1] {
            Layer::Conv(c) => assert_eq!(c.channels, 64),
            _ => panic!("expected conv"),
        }
    }

    #[test]
    fn csv_gemm_rows() {
        let text = "name, M, K, N\nffn1, 512, 768, 3072,\n";
        let topo = Topology::parse_csv("ffn", text).unwrap();
        assert_eq!(topo.layers.len(), 1);
        assert_eq!(topo.layers[0].as_gemm(), GemmShape::new(512, 768, 3072));
    }

    #[test]
    fn csv_bad_rows_fail() {
        assert!(Topology::parse_csv("x", "a, 1, 2\n").is_err()); // 3 cols
        assert!(Topology::parse_csv("x", "a, 0, 2, 3\n").is_err()); // zero dim
        assert!(Topology::parse_csv("x", "c, 8, 8, 9, 9, 1, 1, 1,\n").is_err()); // filter > ifmap
    }

    #[test]
    fn topology_total_macs() {
        let topo = Topology {
            name: "t".into(),
            layers: vec![
                Layer::Gemm {
                    name: "g1".into(),
                    shape: GemmShape::new(2, 3, 4),
                },
                Layer::Gemm {
                    name: "g2".into(),
                    shape: GemmShape::new(1, 1, 1),
                },
            ],
        };
        assert_eq!(topo.total_macs(), 25);
    }
}
