//! SCALE-Sim v3 substrate: a cycle-accurate systolic-array simulator,
//! rebuilt in Rust.
//!
//! The paper validates and extends SCALE-Sim v3; since we build everything
//! from scratch, this module *is* our SCALE-Sim: architecture configs
//! ([`config`]), workload topologies ([`topology`]), per-dataflow systolic
//! compute models ([`dataflow`]), the SRAM/DRAM double-buffer stall model
//! ([`memory`]), GEMM and convolution drivers ([`gemm`], [`conv`]),
//! multi-core partitioning ([`partition`]) and result types ([`report`]).
//!
//! Fidelity note: instead of emitting per-cycle operand address traces (as
//! upstream SCALE-Sim does) we walk the fold sequence with per-fold operand
//! demand and a bandwidth-rate DRAM model. For streaming systolic GEMM
//! operands the two agree on stall counts, and the fold-class collapse
//! keeps a 4096³ GEMM simulation at microseconds instead of minutes.

pub mod config;
pub mod conv;
pub mod dataflow;
pub mod dram;
pub mod energy;
pub mod gemm;
pub mod memory;
pub mod partition;
pub mod report;
pub mod sparse;
pub mod trace;
pub mod topology;

pub use config::{Dataflow, ScaleConfig};
pub use conv::{simulate_conv, simulate_topology, LayerReport};
pub use gemm::simulate_gemm;
pub use partition::{simulate_partitioned, PartitionAxis};
pub use dram::{refine as refine_dram, DramParams};
pub use energy::{estimate as estimate_energy, EnergyParams, EnergyReport};
pub use report::SimReport;
pub use sparse::{simulate_sparse, Sparsity};
pub use trace::{trace_gemm, FoldTrace};
pub use topology::{ConvLayer, GemmShape, Layer, Topology};
