//! Convolution simulation via im2col lowering, and whole-topology runs.

use super::config::ScaleConfig;
use super::gemm::simulate_gemm;
use super::report::SimReport;
use super::topology::{ConvLayer, Layer, Topology};

/// Simulate a convolution layer by lowering to its im2col GEMM, exactly as
/// SCALE-Sim maps convolutions onto the array.
pub fn simulate_conv(config: &ScaleConfig, conv: &ConvLayer) -> SimReport {
    simulate_gemm(config, conv.to_gemm())
}

/// Per-layer result of a topology run.
#[derive(Debug, Clone)]
pub struct LayerReport {
    /// Name of the simulated layer.
    pub layer_name: String,
    /// Its simulation report.
    pub report: SimReport,
}

/// Simulate every layer of a topology sequentially on one core.
pub fn simulate_topology(config: &ScaleConfig, topo: &Topology) -> Vec<LayerReport> {
    topo.layers
        .iter()
        .map(|layer| LayerReport {
            layer_name: layer.name().to_string(),
            report: match layer {
                Layer::Gemm { shape, .. } => simulate_gemm(config, *shape),
                Layer::Conv(c) => simulate_conv(config, c),
            },
        })
        .collect()
}

/// Total cycles across a topology run.
pub fn topology_total_cycles(reports: &[LayerReport]) -> u64 {
    reports.iter().map(|r| r.report.total_cycles()).sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scalesim::topology::GemmShape;

    fn conv(ih: usize, fh: usize, c: usize, nf: usize, s: usize) -> ConvLayer {
        ConvLayer {
            name: "conv".into(),
            ifmap_h: ih,
            ifmap_w: ih,
            filter_h: fh,
            filter_w: fh,
            channels: c,
            num_filters: nf,
            stride_h: s,
            stride_w: s,
        }
    }

    #[test]
    fn conv_equals_its_gemm() {
        let cfg = ScaleConfig::tpu_v4();
        let layer = conv(56, 3, 64, 128, 1);
        let via_conv = simulate_conv(&cfg, &layer);
        let via_gemm = simulate_gemm(&cfg, layer.to_gemm());
        assert_eq!(via_conv.total_cycles(), via_gemm.total_cycles());
    }

    #[test]
    fn stride_reduces_cycles() {
        let cfg = ScaleConfig::tpu_v4();
        let s1 = simulate_conv(&cfg, &conv(112, 3, 64, 64, 1));
        let s2 = simulate_conv(&cfg, &conv(112, 3, 64, 64, 2));
        assert!(s2.total_cycles() < s1.total_cycles());
    }

    #[test]
    fn topology_run_sums() {
        let cfg = ScaleConfig::tpu_v4();
        let topo = Topology {
            name: "mini".into(),
            layers: vec![
                Layer::Conv(conv(32, 3, 16, 32, 1)),
                Layer::Gemm {
                    name: "fc".into(),
                    shape: GemmShape::new(1, 512, 10),
                },
            ],
        };
        let reports = simulate_topology(&cfg, &topo);
        assert_eq!(reports.len(), 2);
        assert_eq!(
            topology_total_cycles(&reports),
            reports[0].report.total_cycles() + reports[1].report.total_cycles()
        );
    }
}
