//! Sparse GEMM support (SCALE-Sim v3 lists sparse matrix multiplication
//! among its extensions).
//!
//! Model: structured sparsity with density d ∈ (0, 1] on either operand.
//! The array skips zero-operand MACs at `gating` efficiency (1.0 = ideal
//! clock-gating: skipped MACs cost no time; 0.0 = dense timing, energy
//! savings only). Operand fetch traffic shrinks with the stored density
//! (compressed formats), while the produced output stays dense.

use super::config::ScaleConfig;
use super::gemm::simulate_gemm;
use super::report::SimReport;
use super::topology::GemmShape;

/// Sparsity descriptor for one GEMM.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Sparsity {
    /// Fraction of nonzeros in A (1.0 = dense).
    pub a_density: f64,
    /// Fraction of nonzeros in B.
    pub b_density: f64,
    /// Fraction of the skippable time actually saved (0..1).
    pub gating_efficiency: f64,
}

impl Sparsity {
    /// Fully dense operands (no gating win).
    pub fn dense() -> Sparsity {
        Sparsity {
            a_density: 1.0,
            b_density: 1.0,
            gating_efficiency: 0.0,
        }
    }

    /// 2:4 structured sparsity on the weight operand, ideal gating.
    pub fn two_four_weights() -> Sparsity {
        Sparsity {
            a_density: 1.0,
            b_density: 0.5,
            gating_efficiency: 1.0,
        }
    }

    /// Densities and efficiency all within (0, 1].
    pub fn validate(&self) -> bool {
        (0.0..=1.0).contains(&self.gating_efficiency)
            && self.a_density > 0.0
            && self.a_density <= 1.0
            && self.b_density > 0.0
            && self.b_density <= 1.0
    }

    /// Fraction of MACs with both operands nonzero (independence
    /// assumption).
    pub fn effective_mac_fraction(&self) -> f64 {
        self.a_density * self.b_density
    }
}

/// Sparse simulation result: the dense report plus sparse-adjusted
/// totals.
#[derive(Debug, Clone)]
pub struct SparseReport {
    /// The dense baseline simulation.
    pub dense: SimReport,
    /// Sparsity pattern applied.
    pub sparsity: Sparsity,
    /// Cycles after gating savings.
    pub effective_cycles: u64,
    /// MACs actually performed.
    pub effective_macs: u64,
    /// DRAM words after compressed operand storage.
    pub effective_dram_words: u64,
}

impl SparseReport {
    /// Dense cycles over effective cycles.
    pub fn speedup(&self) -> f64 {
        if self.effective_cycles == 0 {
            return 0.0;
        }
        self.dense.total_cycles() as f64 / self.effective_cycles as f64
    }
}

/// Simulate a GEMM with sparsity on top of the dense fold model.
pub fn simulate_sparse(config: &ScaleConfig, gemm: GemmShape, sp: Sparsity) -> SparseReport {
    assert!(sp.validate(), "invalid sparsity {sp:?}");
    let dense = simulate_gemm(config, gemm);

    // Compute time: only the streaming phases shrink (fills/drains and
    // stalls are structural). Approximate the streaming share by the
    // compute fraction attributable to MACs.
    let mac_fraction = sp.effective_mac_fraction();
    let saveable = dense.compute_cycles as f64;
    let saved = saveable * (1.0 - mac_fraction) * sp.gating_efficiency;
    let effective_cycles =
        (dense.total_cycles() as f64 - saved).max(1.0).round() as u64;

    let effective_macs = (gemm.macs() as f64 * mac_fraction).round() as u64;
    let effective_dram_words = ((dense.ifmap_dram_reads as f64 * sp.a_density)
        + (dense.filter_dram_reads as f64 * sp.b_density)
        + dense.ofmap_dram_writes as f64)
        .round() as u64;

    SparseReport {
        dense,
        sparsity: sp,
        effective_cycles,
        effective_macs,
        effective_dram_words,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> ScaleConfig {
        ScaleConfig::tpu_v4()
    }

    #[test]
    fn dense_sparsity_is_identity() {
        let g = GemmShape::new(512, 512, 512);
        let r = simulate_sparse(&cfg(), g, Sparsity::dense());
        assert_eq!(r.effective_cycles, r.dense.total_cycles());
        assert_eq!(r.effective_macs, g.macs());
        assert!((r.speedup() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn two_four_weights_speedup() {
        let g = GemmShape::new(1024, 1024, 1024);
        let r = simulate_sparse(&cfg(), g, Sparsity::two_four_weights());
        // 50% of MACs skipped with ideal gating on a compute-dominated
        // GEMM → between 1.3x and 2x.
        let s = r.speedup();
        assert!(s > 1.3 && s <= 2.0, "speedup {s}");
        assert_eq!(r.effective_macs, g.macs() / 2);
        // B traffic halves, A and C unchanged.
        assert!(r.effective_dram_words < r.dense.total_dram_words());
    }

    #[test]
    fn gating_efficiency_interpolates() {
        let g = GemmShape::new(512, 512, 512);
        let mk = |e| {
            simulate_sparse(
                &cfg(),
                g,
                Sparsity {
                    a_density: 0.5,
                    b_density: 0.5,
                    gating_efficiency: e,
                },
            )
            .effective_cycles
        };
        let none = mk(0.0);
        let half = mk(0.5);
        let full = mk(1.0);
        assert!(full < half && half < none);
        assert_eq!(none, simulate_gemm(&cfg(), g).total_cycles());
    }

    #[test]
    #[should_panic]
    fn invalid_density_rejected() {
        simulate_sparse(
            &cfg(),
            GemmShape::new(8, 8, 8),
            Sparsity {
                a_density: 0.0,
                b_density: 1.0,
                gating_efficiency: 1.0,
            },
        );
    }
}
