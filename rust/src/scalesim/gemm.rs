//! Top-level GEMM simulation: compute model + memory model → [`SimReport`].

use super::config::ScaleConfig;
use super::dataflow::compute_model;
use super::memory::memory_model;
use super::report::SimReport;
use super::topology::GemmShape;

/// Simulate one GEMM on one systolic core.
///
/// This is the function every other layer of the system calls: the paper's
/// Fig. 2 sweep, the StableHLO router, the coordinator, and the benches.
pub fn simulate_gemm(config: &ScaleConfig, gemm: GemmShape) -> SimReport {
    let compute = compute_model(config, gemm);
    let memory = memory_model(config, gemm, &compute);

    let total_cycles = memory.initial_fill_cycles + compute.compute_cycles + memory.stall_cycles;
    let utilisation = if total_cycles > 0 {
        gemm.macs() as f64 / (config.peak_macs_per_cycle() * total_cycles as f64)
    } else {
        0.0
    };

    SimReport {
        config_name: config.name.clone(),
        dataflow: config.dataflow,
        gemm,
        compute_cycles: compute.compute_cycles,
        stall_cycles: memory.stall_cycles,
        initial_fill_cycles: memory.initial_fill_cycles,
        num_folds: compute.num_folds,
        mapping_efficiency: compute.mapping_efficiency,
        utilisation,
        ifmap_dram_reads: memory.ifmap_dram_reads,
        filter_dram_reads: memory.filter_dram_reads,
        ofmap_dram_writes: memory.ofmap_dram_writes,
        fits_on_chip: memory.fits_on_chip,
        freq_mhz: config.freq_mhz,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scalesim::config::Dataflow;

    #[test]
    fn report_consistency() {
        let c = ScaleConfig::tpu_v4();
        let r = simulate_gemm(&c, GemmShape::new(512, 512, 512));
        assert_eq!(
            r.total_cycles(),
            r.compute_cycles + r.stall_cycles + r.initial_fill_cycles
        );
        assert!(r.utilisation > 0.0 && r.utilisation <= 1.0);
        assert!(r.mapping_efficiency > 0.0 && r.mapping_efficiency <= 1.0);
        assert!(r.fits_on_chip);
    }

    #[test]
    fn bigger_gemm_more_cycles() {
        let c = ScaleConfig::tpu_v4();
        let small = simulate_gemm(&c, GemmShape::new(128, 128, 128));
        let large = simulate_gemm(&c, GemmShape::new(1024, 1024, 1024));
        assert!(large.total_cycles() > small.total_cycles());
        // Cube of 8x linear size => ~512x MACs; cycles should grow
        // between 64x (per-dim scaling may amortise) and 2048x.
        let ratio = large.total_cycles() as f64 / small.total_cycles() as f64;
        assert!(ratio > 64.0 && ratio < 2048.0, "ratio {ratio}");
    }

    #[test]
    fn dataflow_changes_cycles() {
        let mut c = ScaleConfig::tpu_v4();
        let g = GemmShape::new(1024, 128, 128);
        c.dataflow = Dataflow::WeightStationary;
        let ws = simulate_gemm(&c, g);
        c.dataflow = Dataflow::OutputStationary;
        let os = simulate_gemm(&c, g);
        // Tall-skinny GEMM: OS folds 8x over M while WS streams M in one
        // fold; WS should be clearly faster.
        assert!(ws.total_cycles() < os.total_cycles());
    }

    #[test]
    fn degenerate_dims() {
        let c = ScaleConfig::tpu_v4();
        for g in [
            GemmShape::new(1, 1, 1),
            GemmShape::new(1, 4096, 1),
            GemmShape::new(4096, 1, 1),
            GemmShape::new(1, 1, 4096),
        ] {
            let r = simulate_gemm(&c, g);
            assert!(r.total_cycles() > 0, "{g}");
            assert!(r.utilisation <= 1.0, "{g}");
        }
    }

    #[test]
    fn paper_regimes_increasing_utilisation() {
        // The three regimes of the paper (small/medium/large) should show
        // increasing utilisation on the 128x128 array.
        let c = ScaleConfig::tpu_v4();
        let small = simulate_gemm(&c, GemmShape::new(64, 64, 64));
        let medium = simulate_gemm(&c, GemmShape::new(512, 512, 512));
        let large = simulate_gemm(&c, GemmShape::new(2048, 2048, 2048));
        assert!(small.utilisation < medium.utilisation);
        assert!(medium.utilisation < large.utilisation);
    }
}
