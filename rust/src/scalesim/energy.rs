//! Energy estimation (SCALE-Sim v3's Accelergy integration, rebuilt as an
//! event-count × per-event-energy model).
//!
//! Counts come from the simulator: MACs from the workload, SRAM traffic
//! from the staged operand words, DRAM traffic from the memory model.
//! Per-event energies default to 45 nm Accelergy-style values (scaled for
//! bf16 words); all constants are overridable for technology studies.

use super::report::SimReport;
use crate::util::json::Json;

/// Per-event energy constants, picojoules.
#[derive(Debug, Clone, PartialEq)]
pub struct EnergyParams {
    /// One bf16 MAC in the systolic array.
    pub mac_pj: f64,
    /// One word read from an operand SRAM into the array.
    pub sram_read_pj: f64,
    /// One word written to an operand SRAM.
    pub sram_write_pj: f64,
    /// One word transferred to/from DRAM.
    pub dram_word_pj: f64,
    /// Static leakage per cycle for the whole core.
    pub leakage_pj_per_cycle: f64,
}

impl Default for EnergyParams {
    fn default() -> Self {
        // 45nm-class numbers per 16-bit word (Accelergy/Eyeriss-lineage
        // estimates): MAC ≈ 0.5 pJ, SRAM ≈ 5 pJ, DRAM ≈ 400 pJ.
        EnergyParams {
            mac_pj: 0.5,
            sram_read_pj: 5.0,
            sram_write_pj: 5.5,
            dram_word_pj: 400.0,
            leakage_pj_per_cycle: 50.0,
        }
    }
}

/// Energy breakdown for one simulated GEMM.
#[derive(Debug, Clone, PartialEq)]
pub struct EnergyReport {
    /// Compute (MAC array) energy, µJ.
    pub mac_uj: f64,
    /// On-chip SRAM access energy, µJ.
    pub sram_uj: f64,
    /// DRAM access energy, µJ.
    pub dram_uj: f64,
    /// Static leakage over the runtime, µJ.
    pub leakage_uj: f64,
}

impl EnergyReport {
    /// Total energy, µJ.
    pub fn total_uj(&self) -> f64 {
        self.mac_uj + self.sram_uj + self.dram_uj + self.leakage_uj
    }

    /// Fraction of energy spent on data movement (SRAM + DRAM).
    pub fn data_movement_fraction(&self) -> f64 {
        let total = self.total_uj();
        if total == 0.0 {
            return 0.0;
        }
        (self.sram_uj + self.dram_uj) / total
    }

    /// Effective TOPS/W at the report's latency (2 ops per MAC).
    pub fn tops_per_watt(&self, report: &SimReport) -> f64 {
        let joules = self.total_uj() * 1e-6;
        if joules == 0.0 {
            return 0.0;
        }
        let ops = 2.0 * report.gemm.macs() as f64;
        ops / joules / 1e12
    }

    /// Serialize the breakdown.
    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("mac_uj", Json::Num(self.mac_uj))
            .set("sram_uj", Json::Num(self.sram_uj))
            .set("dram_uj", Json::Num(self.dram_uj))
            .set("leakage_uj", Json::Num(self.leakage_uj))
            .set("total_uj", Json::Num(self.total_uj()));
        o
    }
}

/// Estimate energy for a simulated GEMM.
///
/// SRAM events: every DRAM-staged word is read once from SRAM into the
/// array (reads), and every produced/spilled output word is written once
/// (writes) — the stationarity reuse happens inside the PE registers,
/// which the MAC energy already covers.
pub fn estimate(params: &EnergyParams, report: &SimReport) -> EnergyReport {
    let macs = report.gemm.macs() as f64;
    let sram_reads = (report.ifmap_dram_reads + report.filter_dram_reads) as f64;
    let sram_writes = report.ofmap_dram_writes as f64;
    let dram_words = report.total_dram_words() as f64;
    let cycles = report.total_cycles() as f64;

    EnergyReport {
        mac_uj: macs * params.mac_pj * 1e-6,
        sram_uj: (sram_reads * params.sram_read_pj + sram_writes * params.sram_write_pj) * 1e-6,
        dram_uj: dram_words * params.dram_word_pj * 1e-6,
        leakage_uj: cycles * params.leakage_pj_per_cycle * 1e-6,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scalesim::{simulate_gemm, Dataflow, GemmShape, ScaleConfig};

    fn report(g: GemmShape) -> SimReport {
        simulate_gemm(&ScaleConfig::tpu_v4(), g)
    }

    #[test]
    fn energy_scales_with_work() {
        let p = EnergyParams::default();
        let small = estimate(&p, &report(GemmShape::new(128, 128, 128)));
        let large = estimate(&p, &report(GemmShape::new(1024, 1024, 1024)));
        assert!(large.total_uj() > small.total_uj() * 100.0);
        // MAC energy is exactly proportional to MACs.
        let ratio = large.mac_uj / small.mac_uj;
        assert!((ratio - 512.0).abs() < 1e-6, "ratio {ratio}");
    }

    #[test]
    fn dram_dominates_data_movement_for_low_reuse() {
        // OS on a K-skinny GEMM refetches operands heavily.
        let mut c = ScaleConfig::tpu_v4();
        c.dataflow = Dataflow::OutputStationary;
        let r = simulate_gemm(&c, GemmShape::new(4096, 32, 4096));
        let e = estimate(&EnergyParams::default(), &r);
        assert!(e.data_movement_fraction() > 0.5);
        assert!(e.dram_uj > e.sram_uj);
    }

    #[test]
    fn tops_per_watt_in_sane_band() {
        // Large well-utilised GEMM at these constants should land in the
        // 0.1–10 TOPS/W band typical of dense 16-bit accelerators.
        let e = estimate(&EnergyParams::default(), &report(GemmShape::new(2048, 2048, 2048)));
        let tw = e.tops_per_watt(&report(GemmShape::new(2048, 2048, 2048)));
        assert!(tw > 0.1 && tw < 10.0, "TOPS/W {tw}");
    }

    #[test]
    fn dataflow_changes_energy_not_macs() {
        let g = GemmShape::new(2048, 256, 1024);
        let p = EnergyParams::default();
        let mut c = ScaleConfig::tpu_v4();
        c.dataflow = Dataflow::WeightStationary;
        let ws = estimate(&p, &simulate_gemm(&c, g));
        c.dataflow = Dataflow::OutputStationary;
        let os = estimate(&p, &simulate_gemm(&c, g));
        assert!((ws.mac_uj - os.mac_uj).abs() < 1e-12);
        assert_ne!(ws.dram_uj, os.dram_uj);
    }

    #[test]
    fn json_export() {
        let e = estimate(&EnergyParams::default(), &report(GemmShape::new(64, 64, 64)));
        let j = e.to_json();
        assert!(j.req_f64("total_uj").unwrap() > 0.0);
    }
}
