//! Measurement substrate: the "TPU v4" the experiments measure against.
//!
//! Two interchangeable [`traits::Hardware`] backends (see DESIGN.md
//! §Hardware-substitution):
//!
//! * [`model::TpuV4Model`] — synthetic TPU-v4 device model (default);
//!   deterministic physics + per-shape compiler effects + run-to-run
//!   noise, built to reproduce the paper's three GEMM regimes and the
//!   elementwise scaling/fluctuation structure.
//! * [`pjrt_hw::PjrtHardware`] — times real kernel executions on the PJRT
//!   CPU client via [`crate::runtime`].

pub mod model;
pub mod pjrt_hw;
pub mod traits;
pub mod vpu;

pub use model::{MxuParams, TpuV4Model};
pub use pjrt_hw::PjrtHardware;
pub use traits::{measure_ew_median, measure_gemm_batch_median, measure_gemm_median, Hardware};
pub use vpu::VpuParams;
