//! PJRT-backed measurement backend: times *real* kernel executions on the
//! PJRT CPU client. The numbers are CPU-shaped rather than TPU-shaped,
//! but the entire measure → calibrate → predict pipeline is identical to
//! the synthetic backend, which is the point: `--hardware pjrt` re-runs
//! any experiment against genuine executions.

use std::collections::HashMap;

use anyhow::Result;

use crate::frontend::classify::EwKind;
use crate::runtime::{f32_literal, hlo_gen, Executable, Literal, Runtime};
use crate::scalesim::topology::GemmShape;

use super::traits::Hardware;

/// Keys for the executable cache.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
enum KernelKey {
    Gemm(GemmShape),
    Ew(EwKind, Vec<usize>),
}

/// Hardware backend that compiles+caches micro-kernels via PJRT and times
/// their execution.
pub struct PjrtHardware {
    runtime: Runtime,
    cache: HashMap<KernelKey, (Executable, Vec<Literal>)>,
    /// Warmup runs per fresh executable.
    pub warmup: usize,
}

impl PjrtHardware {
    /// Connect to the PJRT CPU client (fails cleanly without the `pjrt` feature).
    pub fn new() -> Result<PjrtHardware> {
        Ok(PjrtHardware {
            runtime: Runtime::cpu()?,
            cache: HashMap::new(),
            warmup: 1,
        })
    }

    fn ensure_gemm(&mut self, g: GemmShape) -> Result<&(Executable, Vec<Literal>)> {
        let key = KernelKey::Gemm(g);
        if !self.cache.contains_key(&key) {
            let exe = self
                .runtime
                .compile_text(&format!("gemm_{g}"), &hlo_gen::gemm_hlo(g.m, g.k, g.n))?;
            let a = f32_literal(&[g.m, g.k], |i| ((i % 7) as f32) * 0.25)?;
            let b = f32_literal(&[g.k, g.n], |i| ((i % 5) as f32) * 0.5)?;
            let _ = exe.time_us(&[a.clone(), b.clone()], self.warmup, 1)?;
            self.cache.insert(key.clone(), (exe, vec![a, b]));
        }
        Ok(self.cache.get(&key).unwrap())
    }

    fn ensure_ew(
        &mut self,
        kind: EwKind,
        dims: &[usize],
    ) -> Result<&(Executable, Vec<Literal>)> {
        let key = KernelKey::Ew(kind, dims.to_vec());
        if !self.cache.contains_key(&key) {
            let (text, nargs) = match kind {
                EwKind::Add => (hlo_gen::binary_ew_hlo("add", dims), 2),
                EwKind::Subtract => (hlo_gen::binary_ew_hlo("subtract", dims), 2),
                EwKind::Multiply => (hlo_gen::binary_ew_hlo("multiply", dims), 2),
                EwKind::Divide => (hlo_gen::binary_ew_hlo("divide", dims), 2),
                EwKind::Minimum => (hlo_gen::binary_ew_hlo("minimum", dims), 2),
                // ReLU: maximum against broadcast zero (like the compiler).
                EwKind::Maximum => (hlo_gen::relu_hlo(dims), 1),
                EwKind::Exp => (hlo_gen::unary_ew_hlo("exponential", dims), 1),
                EwKind::Tanh => (hlo_gen::unary_ew_hlo("tanh", dims), 1),
                EwKind::Sqrt => (hlo_gen::unary_ew_hlo("sqrt", dims), 1),
                EwKind::Rsqrt => (hlo_gen::unary_ew_hlo("rsqrt", dims), 1),
                EwKind::Log => (hlo_gen::unary_ew_hlo("log", dims), 1),
                EwKind::Negate => (hlo_gen::unary_ew_hlo("negate", dims), 1),
                EwKind::Abs => (hlo_gen::unary_ew_hlo("abs", dims), 1),
                _ => (hlo_gen::binary_ew_hlo("add", dims), 2),
            };
            let exe = self
                .runtime
                .compile_text(&format!("ew_{}", kind.name()), &text)?;
            let mut inputs = Vec::new();
            for a in 0..nargs {
                inputs.push(f32_literal(dims, move |i| {
                    ((i + a) % 11) as f32 * 0.125 + 0.5
                })?);
            }
            let _ = exe.time_us(&inputs, self.warmup, 1)?;
            self.cache.insert(key.clone(), (exe, inputs));
        }
        Ok(self.cache.get(&key).unwrap())
    }
}

impl Hardware for PjrtHardware {
    fn name(&self) -> &str {
        "pjrt_cpu"
    }

    fn gemm_latency_us(&mut self, gemm: GemmShape) -> f64 {
        match self.ensure_gemm(gemm) {
            Ok((exe, inputs)) => exe
                .time_us(inputs, 0, 1)
                .map(|t| t[0])
                .unwrap_or(f64::NAN),
            Err(e) => {
                crate::log_warn!("pjrt gemm {gemm} failed: {e:#}");
                f64::NAN
            }
        }
    }

    fn elementwise_latency_us(&mut self, kind: EwKind, dims: &[usize]) -> f64 {
        match self.ensure_ew(kind, dims) {
            Ok((exe, inputs)) => exe
                .time_us(inputs, 0, 1)
                .map(|t| t[0])
                .unwrap_or(f64::NAN),
            Err(e) => {
                crate::log_warn!("pjrt ew {} {dims:?} failed: {e:#}", kind.name());
                f64::NAN
            }
        }
    }
}

// Real-execution tests: only meaningful with the real bindings.
#[cfg(all(test, feature = "pjrt"))]
mod tests {
    use super::*;
    use crate::tpu::traits::{measure_ew_median, measure_gemm_median};

    #[test]
    fn measures_real_kernels() {
        let mut hw = PjrtHardware::new().expect("PJRT client");
        let t_small = measure_gemm_median(&mut hw, GemmShape::new(32, 32, 32), 3);
        let t_big = measure_gemm_median(&mut hw, GemmShape::new(256, 256, 256), 3);
        assert!(t_small.is_finite() && t_small > 0.0);
        assert!(t_big > t_small * 0.5, "big {t_big} small {t_small}");

        let t_ew = measure_ew_median(&mut hw, EwKind::Add, &[256, 256], 3);
        assert!(t_ew.is_finite() && t_ew > 0.0);
    }

    #[test]
    fn cache_makes_repeat_measurements_cheap() {
        let mut hw = PjrtHardware::new().expect("PJRT client");
        let g = GemmShape::new(64, 64, 64);
        let _ = hw.gemm_latency_us(g); // compile + run
        let start = std::time::Instant::now();
        for _ in 0..5 {
            let _ = hw.gemm_latency_us(g); // cached
        }
        assert!(start.elapsed().as_secs_f64() < 1.0);
    }
}
