//! The measurement-backend abstraction.
//!
//! The paper measures kernels on a real TPU v4. We cannot (repro band
//! 0/5), so every experiment talks to a [`Hardware`] trait with two
//! implementations: the synthetic TPU-v4 device model
//! ([`super::model::TpuV4Model`], default — paper-shaped numbers) and the
//! PJRT-backed harness ([`super::pjrt_hw::PjrtHardware`], real executions
//! on the CPU plugin). See DESIGN.md §Hardware-substitution.

use crate::frontend::classify::EwKind;
use crate::scalesim::topology::GemmShape;
use crate::util::stats;

/// A device we can measure kernel latencies on. One call = one kernel
/// execution (including run-to-run noise for the synthetic backend).
pub trait Hardware {
    /// Backend identifier for reports (e.g. `tpu_v4_model`, `pjrt_cpu`).
    fn name(&self) -> &str;

    /// Latency of one GEMM kernel execution, microseconds. On-chip
    /// execution only (the paper excludes HBM-to-core staging).
    fn gemm_latency_us(&mut self, gemm: GemmShape) -> f64;

    /// Latency of one elementwise kernel execution over a bf16 tensor of
    /// shape `dims`, microseconds.
    fn elementwise_latency_us(&mut self, kind: EwKind, dims: &[usize]) -> f64;
}

/// Median-of-N measurement, the paper's noise-reduction protocol
/// ("latency is measured multiple times and we use the median").
pub fn measure_gemm_median(hw: &mut dyn Hardware, gemm: GemmShape, reps: usize) -> f64 {
    let times: Vec<f64> = (0..reps.max(1)).map(|_| hw.gemm_latency_us(gemm)).collect();
    stats::median(&times)
}

/// Median-of-N elementwise measurement.
pub fn measure_ew_median(
    hw: &mut dyn Hardware,
    kind: EwKind,
    dims: &[usize],
    reps: usize,
) -> f64 {
    let times: Vec<f64> = (0..reps.max(1))
        .map(|_| hw.elementwise_latency_us(kind, dims))
        .collect();
    stats::median(&times)
}

#[cfg(test)]
mod tests {
    use super::*;

    struct Fake {
        seq: Vec<f64>,
        i: usize,
    }

    impl Hardware for Fake {
        fn name(&self) -> &str {
            "fake"
        }
        fn gemm_latency_us(&mut self, _g: GemmShape) -> f64 {
            let v = self.seq[self.i % self.seq.len()];
            self.i += 1;
            v
        }
        fn elementwise_latency_us(&mut self, _k: EwKind, _d: &[usize]) -> f64 {
            self.gemm_latency_us(GemmShape::new(1, 1, 1))
        }
    }

    #[test]
    fn median_measurement_rejects_outliers() {
        let mut hw = Fake {
            seq: vec![10.0, 11.0, 100.0, 10.5, 10.2],
            i: 0,
        };
        let med = measure_gemm_median(&mut hw, GemmShape::new(2, 2, 2), 5);
        assert!((med - 10.5).abs() < 1e-12);
    }
}
