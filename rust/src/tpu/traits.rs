//! The measurement-backend abstraction.
//!
//! The paper measures kernels on a real TPU v4. We cannot (repro band
//! 0/5), so every experiment talks to a [`Hardware`] trait with two
//! implementations: the synthetic TPU-v4 device model
//! ([`super::model::TpuV4Model`], default — paper-shaped numbers) and the
//! PJRT-backed harness ([`super::pjrt_hw::PjrtHardware`], real executions
//! on the CPU plugin). See DESIGN.md §Hardware-substitution.

use crate::frontend::classify::EwKind;
use crate::scalesim::topology::GemmShape;
use crate::util::stats;

/// A device we can measure kernel latencies on. One call = one kernel
/// execution (including run-to-run noise for the synthetic backend).
pub trait Hardware {
    /// Backend identifier for reports (e.g. `tpu_v4_model`, `pjrt_cpu`).
    fn name(&self) -> &str;

    /// Latency of one GEMM kernel execution, microseconds. On-chip
    /// execution only (the paper excludes HBM-to-core staging).
    fn gemm_latency_us(&mut self, gemm: GemmShape) -> f64;

    /// Latency of one elementwise kernel execution over a bf16 tensor of
    /// shape `dims`, microseconds.
    fn elementwise_latency_us(&mut self, kind: EwKind, dims: &[usize]) -> f64;
}

/// Median-of-N measurement, the paper's noise-reduction protocol
/// ("latency is measured multiple times and we use the median").
pub fn measure_gemm_median(hw: &mut dyn Hardware, gemm: GemmShape, reps: usize) -> f64 {
    let times: Vec<f64> = (0..reps.max(1)).map(|_| hw.gemm_latency_us(gemm)).collect();
    stats::median(&times)
}

/// Median-of-N measurement over a whole batch of GEMMs, one result per
/// input shape in input order. Interleaves the repetitions across the
/// batch (shape 0 rep 0, shape 1 rep 0, ..., shape 0 rep 1, ...) so
/// slow drift in the backend spreads evenly over every shape instead of
/// biasing the later ones — the batched counterpart of
/// [`measure_gemm_median`], used by the `sweep --measure` harness.
pub fn measure_gemm_batch_median(
    hw: &mut dyn Hardware,
    gemms: &[GemmShape],
    reps: usize,
) -> Vec<f64> {
    let reps = reps.max(1);
    let mut samples: Vec<Vec<f64>> = vec![Vec::with_capacity(reps); gemms.len()];
    for _ in 0..reps {
        for (i, &gemm) in gemms.iter().enumerate() {
            samples[i].push(hw.gemm_latency_us(gemm));
        }
    }
    samples.iter().map(|times| stats::median(times)).collect()
}

/// Median-of-N elementwise measurement.
pub fn measure_ew_median(
    hw: &mut dyn Hardware,
    kind: EwKind,
    dims: &[usize],
    reps: usize,
) -> f64 {
    let times: Vec<f64> = (0..reps.max(1))
        .map(|_| hw.elementwise_latency_us(kind, dims))
        .collect();
    stats::median(&times)
}

#[cfg(test)]
mod tests {
    use super::*;

    struct Fake {
        seq: Vec<f64>,
        i: usize,
    }

    impl Hardware for Fake {
        fn name(&self) -> &str {
            "fake"
        }
        fn gemm_latency_us(&mut self, _g: GemmShape) -> f64 {
            let v = self.seq[self.i % self.seq.len()];
            self.i += 1;
            v
        }
        fn elementwise_latency_us(&mut self, _k: EwKind, _d: &[usize]) -> f64 {
            self.gemm_latency_us(GemmShape::new(1, 1, 1))
        }
    }

    #[test]
    fn batch_median_matches_per_shape_median() {
        // Deterministic backend: latency is a pure function of the shape,
        // so the interleaved batch median must equal the scalar median.
        struct Pure;
        impl Hardware for Pure {
            fn name(&self) -> &str {
                "pure"
            }
            fn gemm_latency_us(&mut self, g: GemmShape) -> f64 {
                (g.m * g.k * g.n) as f64 * 1e-6
            }
            fn elementwise_latency_us(&mut self, _k: EwKind, _d: &[usize]) -> f64 {
                0.0
            }
        }
        let gemms = vec![
            GemmShape::new(8, 8, 8),
            GemmShape::new(16, 4, 32),
            GemmShape::new(2, 128, 2),
        ];
        let mut hw = Pure;
        let batch = measure_gemm_batch_median(&mut hw, &gemms, 3);
        assert_eq!(batch.len(), gemms.len());
        for (b, &g) in batch.iter().zip(&gemms) {
            let scalar = measure_gemm_median(&mut hw, g, 3);
            assert!((b - scalar).abs() < 1e-12);
        }
    }

    #[test]
    fn median_measurement_rejects_outliers() {
        let mut hw = Fake {
            seq: vec![10.0, 11.0, 100.0, 10.5, 10.2],
            i: 0,
        };
        let med = measure_gemm_median(&mut hw, GemmShape::new(2, 2, 2), 5);
        assert!((med - 10.5).abs() < 1e-12);
    }
}
