//! VPU (vector processing unit) latency model — the elementwise path of
//! the synthetic TPU-v4 device.
//!
//! Structure (all constants in [`VpuParams`]):
//!
//! * **Layout padding.** bf16 tensors tile as (8 sublanes × 128 lanes);
//!   the minor dim pads to 128, the second-minor to 8. Shapes with the
//!   same element count but different factorizations pad differently —
//!   the shape-dependent fluctuation the paper's learned model captures.
//! * **Compute.** Effective VPU throughput ramps from
//!   `min_elems_per_cycle` for small tensors (issue/loop-overhead bound)
//!   to `max_elems_per_cycle` for large ones (fully pipelined), with a
//!   power-law ramp — producing the smooth-but-nonlinear scaling that
//!   favours trees over a single linear model.
//! * **Memory.** Elementwise ops are HBM-bound at large sizes:
//!   `streams × padded bytes / HBM bandwidth`.
//! * **Fixed overhead** per kernel launch, dominating small tensors
//!   (where the paper sees its largest absolute errors).
//! * **Alignment effects.** Unaligned minor dims pay a masking penalty;
//!   a per-shape deterministic jitter stands in for compiler scheduling
//!   choices keyed to exact shapes.

use crate::frontend::classify::EwKind;
use crate::util::prng::hash_dims;

/// VPU model constants. Derive a non-reference device's constants with
/// [`DeviceSpec::vpu_params`](crate::device::DeviceSpec::vpu_params).
#[derive(Debug, Clone, PartialEq)]
pub struct VpuParams {
    /// VPU clock, GHz.
    pub clock_ghz: f64,
    /// HBM bandwidth in bytes/µs (1.2e6 ≈ 1.2 TB/s).
    pub hbm_bytes_per_us: f64,
    /// Kernel launch overhead, µs.
    pub launch_overhead_us: f64,
    /// Elements/cycle at the small-tensor end.
    pub min_elems_per_cycle: f64,
    /// Elements/cycle fully pipelined.
    pub max_elems_per_cycle: f64,
    /// Element count where the throughput ramp starts.
    pub ramp_start_elems: f64,
    /// Ramp exponent.
    pub ramp_power: f64,
    /// Relative penalty for an unaligned minor dim.
    pub misalignment_penalty: f64,
    /// Cap on the layout padding-waste factor (shape effects are slight).
    pub padding_waste_cap: f64,
    /// Amplitude of the deterministic per-shape jitter.
    pub shape_jitter: f64,
    /// Bytes per element (bf16 = 2).
    pub bytes_per_elem: f64,
}

impl Default for VpuParams {
    fn default() -> Self {
        VpuParams {
            clock_ghz: 0.940,
            hbm_bytes_per_us: 1.2e6,
            launch_overhead_us: 0.8,
            min_elems_per_cycle: 4.0,
            max_elems_per_cycle: 256.0,
            ramp_start_elems: 524_288.0,
            ramp_power: 0.9,
            misalignment_penalty: 0.04,
            padding_waste_cap: 0.10,
            shape_jitter: 0.012,
            bytes_per_elem: 2.0, // bf16
        }
    }
}

/// Padded element count under (8, 128) tiling.
///
/// Rank ≥ 2: the minor dim pads to 128 lanes and the *product* of the
/// remaining dims to 8 sublanes (XLA flattens the majors into sublane
/// rows). Rank-1 tensors are laid out across full (8×128) tiles, i.e.
/// padded to the next multiple of 1024. Scalars occupy one tile.
pub fn padded_elements(dims: &[usize]) -> u64 {
    // XLA canonicalises away size-1 dims before choosing a layout.
    let dims: Vec<u64> = dims.iter().filter(|&&d| d > 1).map(|&d| d as u64).collect();
    match dims.len() {
        0 => 8 * 128,
        1 => dims[0].div_ceil(8 * 128) * (8 * 128),
        _ => {
            let minor = *dims.last().unwrap();
            let rows: u64 = dims[..dims.len() - 1].iter().product();
            rows.div_ceil(8) * 8 * minor.div_ceil(128) * 128
        }
    }
}

/// Memory streams (reads + writes) per element for an op kind.
pub fn streams(kind: EwKind) -> f64 {
    match kind {
        // Binary: two reads, one write.
        EwKind::Add
        | EwKind::Subtract
        | EwKind::Multiply
        | EwKind::Divide
        | EwKind::Minimum
        | EwKind::Power
        | EwKind::Compare => 3.0,
        // ReLU lowered as max(x, broadcast 0): one read, one write.
        EwKind::Maximum => 2.0,
        // Select: three reads, one write.
        EwKind::Select => 4.0,
        // Unary.
        EwKind::Exp
        | EwKind::Tanh
        | EwKind::Logistic
        | EwKind::Rsqrt
        | EwKind::Sqrt
        | EwKind::Log
        | EwKind::Negate
        | EwKind::Abs
        | EwKind::Convert
        | EwKind::Other => 2.0,
    }
}

/// Relative ALU cost per element.
pub fn op_cost(kind: EwKind) -> f64 {
    match kind {
        EwKind::Add | EwKind::Subtract | EwKind::Multiply | EwKind::Negate | EwKind::Abs => 1.0,
        // Comparison + select micro-ops.
        EwKind::Maximum | EwKind::Minimum | EwKind::Compare | EwKind::Select => 1.15,
        EwKind::Convert => 1.1,
        EwKind::Divide | EwKind::Sqrt | EwKind::Rsqrt => 1.6,
        EwKind::Exp | EwKind::Log | EwKind::Tanh | EwKind::Logistic | EwKind::Power => 2.2,
        EwKind::Other => 1.2,
    }
}

/// Noise-free elementwise latency, µs (the caller applies run-to-run
/// noise). Deterministic in (kind, dims).
pub fn latency_us(params: &VpuParams, kind: EwKind, dims: &[usize]) -> f64 {
    let elems: u64 = dims.iter().map(|&d| d as u64).product::<u64>().max(1);
    let n = elems as f64;

    // Throughput: constant (issue-bound) below `ramp_start_elems`, so
    // latency is *linear in size* across the paper's Fig. 3 sweeps; above
    // it the kernel pipelines and effective throughput ramps up (a
    // near-linear power 0.9), bending the curve toward the HBM roofline
    // at the ~16M-element end of the training range.
    let ramp = (n / params.ramp_start_elems).max(1.0);
    let elems_per_cycle = (params.min_elems_per_cycle * ramp.powf(params.ramp_power))
        .clamp(params.min_elems_per_cycle, params.max_elems_per_cycle);
    let cycles = n * op_cost(kind) / elems_per_cycle;
    let compute_us = cycles / (params.clock_ghz * 1e3);

    // HBM roofline on the tensor footprint.
    let bytes = n * params.bytes_per_elem * streams(kind);
    let mem_us = bytes / params.hbm_bytes_per_us;

    // Shape effects are *slight*, as the paper observes: a capped layout
    // padding-waste factor (VMEM tiles process some dead lanes), a minor-
    // dim misalignment penalty, and a small per-shape scheduling jitter.
    let padded = padded_elements(dims) as f64;
    let waste = (padded / n).clamp(1.0, 1.0 + params.padding_waste_cap);
    let minor = dims.last().copied().unwrap_or(1);
    let mis = if minor % 128 != 0 && !dims.is_empty() {
        1.0 + params.misalignment_penalty
    } else {
        1.0
    };
    let h = hash_dims(dims);
    let jitter = 1.0 + params.shape_jitter * ((h >> 16) as f64 / (1u64 << 48) as f64 - 0.5) * 2.0;

    params.launch_overhead_us + compute_us.max(mem_us) * waste * mis * jitter
}

#[cfg(test)]
mod tests {
    use super::*;

    fn p() -> VpuParams {
        VpuParams::default()
    }

    #[test]
    fn padding_rules() {
        // 1-D: padded to whole (8x128) tiles.
        assert_eq!(padded_elements(&[128]), 1024);
        assert_eq!(padded_elements(&[1024]), 1024);
        assert_eq!(padded_elements(&[1025]), 2048);
        // 2-D: minor to 128 lanes, rows to 8 sublanes.
        assert_eq!(padded_elements(&[8, 128]), 1024);
        assert_eq!(padded_elements(&[9, 128]), 16 * 128);
        assert_eq!(padded_elements(&[8, 100]), 1024);
        // Majors flatten into rows.
        assert_eq!(padded_elements(&[2, 8, 128]), 16 * 128);
        // Size-1 dims are canonicalised away.
        assert_eq!(padded_elements(&[1, 1, 1024]), 1024);
        assert_eq!(padded_elements(&[1024, 1]), 1024);
        assert_eq!(padded_elements(&[]), 1024);
    }

    #[test]
    fn latency_monotone_in_size() {
        let mut prev = 0.0;
        for n in [1024usize, 8192, 65_536, 1 << 20, 1 << 24] {
            let t = latency_us(&p(), EwKind::Add, &[n / 128, 128]);
            assert!(t > prev, "n={n} t={t} prev={prev}");
            prev = t;
        }
    }

    #[test]
    fn large_tensors_approach_roofline() {
        // 16M elements: latency must stay above the HBM roofline and
        // within a small multiple of it (pipelined regime).
        let dims = [16 * 1024, 1024];
        let t = latency_us(&p(), EwKind::Add, &dims);
        let bytes = (16.0 * 1024.0 * 1024.0) * 2.0 * 3.0;
        let roofline = bytes / p().hbm_bytes_per_us;
        assert!(t >= roofline, "t={t} roofline={roofline}");
        assert!(t < roofline * 4.0, "t={t} roofline={roofline}");
    }

    #[test]
    fn same_size_different_shape_differs() {
        let a = latency_us(&p(), EwKind::Add, &[1 << 16]);
        let b = latency_us(&p(), EwKind::Add, &[256, 256]);
        let c = latency_us(&p(), EwKind::Add, &[512, 128]);
        assert!((a - b).abs() > 1e-9 || (b - c).abs() > 1e-9);
    }

    #[test]
    fn misalignment_costs() {
        let aligned = latency_us(&p(), EwKind::Add, &[1024, 128]);
        let unaligned = latency_us(&p(), EwKind::Add, &[1024, 127]);
        // Same padded footprint, but the unaligned minor pays the penalty
        // (modulo the ±3% shape jitter).
        assert!(unaligned > aligned * 0.98, "{unaligned} vs {aligned}");
    }

    #[test]
    fn relu_and_add_differ_but_same_scale() {
        // ReLU (compare+select, 2 streams) and add (1 ALU op, 3 streams)
        // land at the same order of magnitude but not identical cost.
        let dims = [16 * 1024, 1024];
        let relu = latency_us(&p(), EwKind::Maximum, &dims);
        let add = latency_us(&p(), EwKind::Add, &dims);
        assert!((relu - add).abs() > 1e-9);
        assert!(relu > add * 0.5 && relu < add * 2.0, "relu {relu} add {add}");
    }

    #[test]
    fn transcendental_more_expensive_compute() {
        let dims = [64, 128]; // small: compute-visible
        let add = latency_us(&p(), EwKind::Add, &dims);
        let exp = latency_us(&p(), EwKind::Exp, &dims);
        assert!(exp > add);
    }

    #[test]
    fn deterministic() {
        let a = latency_us(&p(), EwKind::Add, &[77, 33]);
        let b = latency_us(&p(), EwKind::Add, &[77, 33]);
        assert_eq!(a, b);
    }
}
