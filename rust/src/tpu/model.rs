//! Synthetic TPU-v4 device model — the hardware substitute for the
//! paper's measurements (DESIGN.md §Hardware-substitution).
//!
//! GEMM path: a 128×128 bf16 MXU at 940 MHz.
//!
//! * Weight tiles are 128×128; activations stream in (8,128)-padded rows.
//!   Per weight tile: load (128 cycles, overlapped in steady state) +
//!   `M_pad` streaming cycles + pipeline fill/drain (≈ 2×128). The fill/
//!   drain term dominates the *small* regime — exactly the paper's
//!   description of that regime.
//! * HBM roofline on the operand footprint caps throughput for
//!   bandwidth-starved shapes.
//! * In the *large* regime the XLA compiler's tiling/layout choices add a
//!   deterministic per-shape factor (hash-keyed), reproducing the extra
//!   variance the paper attributes to "compiler tiling decisions, layout
//!   transformations, and limits on memory bandwidth".
//! * A fixed dispatch overhead plus lognormal run-to-run noise completes
//!   the measurement model; the regression harness takes medians exactly
//!   like the paper.

use crate::device::DeviceSpec;
use crate::frontend::classify::EwKind;
use crate::scalesim::topology::GemmShape;
use crate::util::prng::{hash_dims, Prng};

use super::traits::Hardware;
use super::vpu::{latency_us as vpu_latency_us, VpuParams};

/// GEMM-path constants.
#[derive(Debug, Clone, PartialEq)]
pub struct MxuParams {
    /// MXU clock, GHz.
    pub clock_ghz: f64,
    /// Systolic array side.
    pub array: usize,
    /// Activation row granularity (sublane padding).
    pub row_pad: usize,
    /// Pipeline fill+drain cycles per weight tile.
    pub fill_drain_cycles: f64,
    /// Weight-tile load cycles (non-overlapped fraction).
    pub tile_load_cycles: f64,
    /// Fixed kernel dispatch overhead, µs.
    pub dispatch_overhead_us: f64,
    /// Per-shape overhead scatter amplitude, µs.
    pub overhead_jitter_us: f64,
    /// HBM bandwidth, bytes/µs.
    pub hbm_bytes_per_us: f64,
    /// Bytes per element (bf16 = 2).
    pub bytes_per_elem: f64,
    /// Amplitude of the large-regime compiler-tiling factor.
    pub tiling_jitter_large: f64,
    /// Amplitude of the medium-regime fusion-choice factor.
    pub tiling_jitter_medium: f64,
    /// Amplitude of the per-shape scheduling jitter (all regimes).
    pub shape_jitter: f64,
    /// Lognormal run-to-run noise sigma.
    pub noise_sigma: f64,
}

impl Default for MxuParams {
    fn default() -> Self {
        MxuParams {
            clock_ghz: 0.940,
            array: 128,
            row_pad: 8,
            fill_drain_cycles: 256.0,
            tile_load_cycles: 32.0,
            dispatch_overhead_us: 2.0,
            overhead_jitter_us: 0.15,
            hbm_bytes_per_us: 1.2e6,
            bytes_per_elem: 2.0,
            tiling_jitter_large: 0.10,
            tiling_jitter_medium: 0.12,
            shape_jitter: 0.05,
            noise_sigma: 0.015,
        }
    }
}

/// The synthetic device: MXU + VPU + noise stream. Despite the
/// historical name it can stand in for any [`DeviceSpec`]: the v4
/// defaults are just the reference preset's derivation.
pub struct TpuV4Model {
    /// GEMM-path constants.
    pub mxu: MxuParams,
    /// Elementwise-path constants.
    pub vpu: VpuParams,
    name: String,
    prng: Prng,
}

impl TpuV4Model {
    /// A device with the default (TPU v4 reference) constants and a
    /// seeded noise stream.
    pub fn new(seed: u64) -> TpuV4Model {
        TpuV4Model {
            mxu: MxuParams::default(),
            vpu: VpuParams::default(),
            name: "tpu_v4_model".to_string(),
            prng: Prng::new(seed),
        }
    }

    /// A synthetic device with constants derived from `spec`
    /// ([`DeviceSpec::mxu_params`] / [`DeviceSpec::vpu_params`]).
    /// Bit-identical to [`TpuV4Model::new`] for the reference preset —
    /// including the reported backend name.
    pub fn for_device(spec: &DeviceSpec, seed: u64) -> TpuV4Model {
        TpuV4Model {
            mxu: spec.mxu_params(),
            vpu: spec.vpu_params(),
            name: format!("{}_model", spec.name.replace('-', "_")),
            prng: Prng::new(seed),
        }
    }

    /// Noise-free GEMM kernel time, µs. Deterministic in the shape.
    pub fn gemm_latency_noise_free_us(&self, g: GemmShape) -> f64 {
        let p = &self.mxu;
        let kt = g.k.div_ceil(p.array) as f64;
        let nt = g.n.div_ceil(p.array) as f64;
        let m_pad = g.m.div_ceil(p.row_pad) as f64 * p.row_pad as f64;
        // Average occupied rows/cols per weight tile (ragged edges pull
        // the mean below the full 128).
        let k_used = g.k as f64 / kt;
        let n_used = g.n as f64 / nt;

        // Compute: per weight tile, stream M_pad activation rows through
        // a pipeline whose fill/drain skew tracks the occupied rows+cols.
        let per_tile = m_pad + k_used + n_used + p.fill_drain_cycles + p.tile_load_cycles;
        let cycles = kt * nt * per_tile;
        let compute_us = cycles / (p.clock_ghz * 1e3);

        // HBM roofline over operand + result footprints.
        let bytes =
            (g.a_words() + g.b_words() + g.c_words()) as f64 * p.bytes_per_elem;
        let mem_us = bytes / p.hbm_bytes_per_us;

        // Per-shape compiler effects (deterministic, hash-keyed): the
        // large regime pays an extra tiling/layout factor (the paper's
        // "compiler tiling decisions"), the medium regime a smaller
        // fusion-choice factor — which is what keeps its Fig. 2 R² near
        // but not at 1, and drives Fig. 4's mid-range deviations.
        let h = hash_dims(&[g.m, g.k, g.n]);
        let frac = (h >> 16) as f64 / (1u64 << 48) as f64; // [0, 1)
        let maxdim = g.m.max(g.k).max(g.n);
        let tiling = if maxdim > 1024 {
            1.0 + p.tiling_jitter_large * frac
        } else if maxdim > 128 {
            1.0 + p.tiling_jitter_medium * frac
        } else {
            1.0
        };
        let jitter = 1.0 + p.shape_jitter * (((h >> 8) & 0xffff) as f64 / 65536.0 - 0.5) * 2.0;

        // Dispatch overhead with a per-shape component: at small sizes
        // this scatter is what limits the paper's small-regime R² (0.79).
        let frac2 = ((h >> 32) & 0xffff) as f64 / 65536.0;
        let overhead = p.dispatch_overhead_us + p.overhead_jitter_us * frac2;

        overhead + compute_us.max(mem_us) * tiling * jitter
    }

    /// Noise-free elementwise kernel time, µs.
    pub fn ew_latency_noise_free_us(&self, kind: EwKind, dims: &[usize]) -> f64 {
        vpu_latency_us(&self.vpu, kind, dims)
    }
}

impl Hardware for TpuV4Model {
    fn name(&self) -> &str {
        &self.name
    }

    fn gemm_latency_us(&mut self, gemm: GemmShape) -> f64 {
        let t = self.gemm_latency_noise_free_us(gemm);
        t * self.prng.lognormal_factor(self.mxu.noise_sigma)
    }

    fn elementwise_latency_us(&mut self, kind: EwKind, dims: &[usize]) -> f64 {
        let t = self.ew_latency_noise_free_us(kind, dims);
        // Elementwise kernels are shorter; relative noise is a bit higher.
        t * self.prng.lognormal_factor(self.mxu.noise_sigma * 1.4)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scalesim::{simulate_gemm, ScaleConfig};
    use crate::util::stats;

    #[test]
    fn gemm_latency_monotone_in_each_dim() {
        let hw = TpuV4Model::new(1);
        let base = hw.gemm_latency_noise_free_us(GemmShape::new(512, 512, 512));
        for g in [
            GemmShape::new(2048, 512, 512),
            GemmShape::new(512, 2048, 512),
            GemmShape::new(512, 512, 2048),
        ] {
            // Jitter is ±5%, growth is ≥ ~3x: strictly larger.
            assert!(hw.gemm_latency_noise_free_us(g) > base, "{g}");
        }
    }

    #[test]
    fn small_regime_overhead_dominated() {
        let hw = TpuV4Model::new(1);
        let t = hw.gemm_latency_noise_free_us(GemmShape::new(32, 32, 32));
        assert!(t > hw.mxu.dispatch_overhead_us);
        assert!(t < hw.mxu.dispatch_overhead_us * 2.0);
    }

    #[test]
    fn large_gemm_sensible_tflops() {
        // 4096^3 bf16 on a 128x128 MXU @940MHz: peak = 2*128*128*0.94e9
        //  ≈ 30.8 TFLOP/s. The model should land within [25%, 100%] of peak.
        let hw = TpuV4Model::new(1);
        let g = GemmShape::new(4096, 4096, 4096);
        let t_us = hw.gemm_latency_noise_free_us(g);
        let tflops = 2.0 * g.macs() as f64 / (t_us * 1e-6) / 1e12;
        assert!(tflops > 7.0 && tflops < 31.0, "tflops {tflops}");
    }

    #[test]
    fn noise_is_small_and_multiplicative() {
        let mut hw = TpuV4Model::new(7);
        let g = GemmShape::new(512, 512, 512);
        let clean = hw.gemm_latency_noise_free_us(g);
        let samples: Vec<f64> = (0..200).map(|_| hw.gemm_latency_us(g)).collect();
        let med = stats::median(&samples);
        assert!((med / clean - 1.0).abs() < 0.01, "median drift");
        let spread = stats::stddev(&samples) / med;
        assert!(spread > 0.005 && spread < 0.05, "spread {spread}");
    }

    #[test]
    fn correlates_with_scalesim_cycles_medium() {
        // The core premise of Fig. 2: simulated cycles and device latency
        // are strongly linearly related in the medium regime.
        let hw = TpuV4Model::new(1);
        let cfg = ScaleConfig::tpu_v4();
        let mut cycles = Vec::new();
        let mut times = Vec::new();
        for d in (128..=1024).step_by(128) {
            let g = GemmShape::new(d, 512, 512);
            cycles.push(simulate_gemm(&cfg, g).total_cycles() as f64);
            times.push(hw.gemm_latency_noise_free_us(g));
        }
        let r = stats::pearson(&cycles, &times);
        assert!(r > 0.97, "pearson {r}");
    }

    #[test]
    fn for_device_reference_is_bit_identical_to_default() {
        let mut a = TpuV4Model::new(5);
        let mut b = TpuV4Model::for_device(&DeviceSpec::tpu_v4(), 5);
        assert_eq!(a.mxu, b.mxu);
        assert_eq!(a.vpu, b.vpu);
        assert_eq!(a.name(), "tpu_v4_model");
        assert_eq!(b.name(), "tpu_v4_model");
        let g = GemmShape::new(384, 256, 512);
        assert_eq!(a.gemm_latency_us(g).to_bits(), b.gemm_latency_us(g).to_bits());
    }

    #[test]
    fn for_device_scales_with_the_spec() {
        // Starve the HBM to 1 GB/s: the roofline takes over and the
        // same GEMM slows down by orders of magnitude.
        let mut starved = DeviceSpec::tpu_v4();
        starved.name = "starved".into();
        starved.hbm_gbps = 1.0;
        let hw = TpuV4Model::for_device(&starved, 1);
        let base = TpuV4Model::new(1);
        let g = GemmShape::new(512, 512, 512);
        assert!(
            hw.gemm_latency_noise_free_us(g) > 10.0 * base.gemm_latency_noise_free_us(g),
            "bandwidth starvation did not slow the roofline"
        );
        assert_eq!(hw.name, "starved_model");
    }

    #[test]
    fn deterministic_given_seed() {
        let mut a = TpuV4Model::new(9);
        let mut b = TpuV4Model::new(9);
        let g = GemmShape::new(256, 256, 256);
        for _ in 0..10 {
            assert_eq!(a.gemm_latency_us(g), b.gemm_latency_us(g));
        }
    }
}
