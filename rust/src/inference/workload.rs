//! Deterministic seeded serving workloads — no wall clock.
//!
//! A workload is a sorted stream of [`RequestSpec`]s (arrival time,
//! prompt length, output length) drawn from a seeded [`crate::util::Prng`].
//! Same config → same stream, bit for bit, which is what lets
//! `tests/llm_invariants.rs` assert exact (epsilon-free) properties
//! over "random" streams.

use crate::util::prng::Prng;

/// Workload generator knobs.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct WorkloadConfig {
    /// Number of requests in the stream.
    pub requests: usize,
    /// PRNG seed — the only source of randomness.
    pub seed: u64,
    /// Inclusive prompt-length range, tokens.
    pub prompt_len: (usize, usize),
    /// Inclusive output-length range, tokens (includes the first token
    /// produced by prefill).
    pub output_len: (usize, usize),
    /// Mean inter-arrival gap, µs (uniform on `[0, 2·mean)`).
    pub mean_gap_us: f64,
}

impl Default for WorkloadConfig {
    fn default() -> WorkloadConfig {
        WorkloadConfig {
            requests: 16,
            seed: 42,
            prompt_len: (32, 256),
            output_len: (8, 64),
            mean_gap_us: 200.0,
        }
    }
}

/// One request in the arrival stream.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RequestSpec {
    /// Stream index (also the trace lane and the KV-cache id).
    pub id: usize,
    /// Arrival time, µs from stream start.
    pub arrival_us: f64,
    /// Prompt length, tokens.
    pub prompt: usize,
    /// Output length, tokens (≥ 1; the first is emitted by prefill).
    pub output: usize,
}

/// Generate the arrival stream for `config`. Arrivals are cumulative
/// sums of non-negative gaps, so the stream is sorted by construction;
/// prompt and output lengths are clamped to at least one token.
pub fn generate_workload(config: &WorkloadConfig) -> Vec<RequestSpec> {
    let mut rng = Prng::new(config.seed).fork("llm-workload");
    let (plo, phi) = config.prompt_len;
    let (olo, ohi) = config.output_len;
    let mut t = 0.0_f64;
    let mut out = Vec::with_capacity(config.requests);
    for id in 0..config.requests {
        if id > 0 {
            t += rng.uniform() * 2.0 * config.mean_gap_us.max(0.0);
        }
        let prompt = rng.int_range(plo.min(phi) as i64, phi.max(plo) as i64) as usize;
        let output = rng.int_range(olo.min(ohi) as i64, ohi.max(olo) as i64) as usize;
        out.push(RequestSpec {
            id,
            arrival_us: t,
            prompt: prompt.max(1),
            output: output.max(1),
        });
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_and_sorted() {
        let cfg = WorkloadConfig::default();
        let a = generate_workload(&cfg);
        let b = generate_workload(&cfg);
        assert_eq!(a, b, "same seed, same stream");
        assert_eq!(a.len(), cfg.requests);
        assert_eq!(a[0].arrival_us, 0.0, "first request arrives at t=0");
        for w in a.windows(2) {
            assert!(w[0].arrival_us <= w[1].arrival_us);
        }
        for r in &a {
            assert!(r.prompt >= cfg.prompt_len.0 && r.prompt <= cfg.prompt_len.1);
            assert!(r.output >= cfg.output_len.0 && r.output <= cfg.output_len.1);
        }
    }

    #[test]
    fn seed_changes_the_stream() {
        let a = generate_workload(&WorkloadConfig::default());
        let b = generate_workload(&WorkloadConfig {
            seed: 43,
            ..WorkloadConfig::default()
        });
        assert_ne!(a, b);
    }
}
