//! The decode lowering: rewrite a module's sequence extent.
//!
//! A decoder block is the *same program* in prefill and decode — only
//! the sequence extent differs (the full prompt vs the one new token).
//! Rather than maintain two fixtures, the simulator rewrites every
//! tensor dimension equal to the module's sequence extent, so the
//! op list, SSA structure, dimension-number attributes and op *classes*
//! are untouched and only the shapes change. `tests/frontend_golden.rs`
//! pins this: the decode lowering must classify identically to prefill,
//! with only seq-derived extents rewritten.
//!
//! The sequence extent itself follows the activation convention every
//! checked-in fixture uses: the leading dimension of the entry
//! function's first argument (`%x: tensor<SEQ x D_MODEL x bf16>`).

use crate::frontend::opinfo::{FuncInfo, ModuleInfo, OpInfo};
use crate::frontend::types::TensorType;

/// The module's sequence extent: the leading dimension of the entry
/// function's first argument. `None` when there is no entry function,
/// no arguments, or the first argument is a scalar.
pub fn sequence_dim(module: &ModuleInfo) -> Option<usize> {
    module
        .entry()
        .and_then(|f| f.arg_types.first())
        .and_then(|t| t.dims.first())
        .copied()
}

/// Rewrite every dimension equal to `from` in `t` to `to`. The
/// per-type primitive behind [`rewrite_seq`]; exposed so the schedule
/// template ([`crate::graph::reuse`]) can re-derive per-value byte
/// footprints for a new prompt length with the exact same arithmetic
/// as a full module rewrite.
pub fn rewrite_type(t: &TensorType, from: usize, to: usize) -> TensorType {
    TensorType::new(
        t.dims
            .iter()
            .map(|&d| if d == from { to } else { d })
            .collect(),
        t.dtype,
    )
}

/// Clone one op with its operand and result types run through
/// [`rewrite_type`]. [`rewrite_seq`] is exactly this applied to every
/// op of every function, so re-classifying `rewrite_op(op, from, to)`
/// is bit-identical to classifying the op inside the rewritten module —
/// the property the schedule template's re-cost path relies on.
pub fn rewrite_op(op: &OpInfo, from: usize, to: usize) -> OpInfo {
    let mut op = op.clone();
    for t in op.operand_types.iter_mut() {
        *t = rewrite_type(t, from, to);
    }
    for t in op.result_types.iter_mut() {
        *t = rewrite_type(t, from, to);
    }
    op
}

/// Clone `module` with every tensor dimension equal to `from` rewritten
/// to `to` — in function signatures, operand types and result types.
/// Dimension-number attributes (`dot_dims`, `dims = [...]`) are
/// *indices*, not extents, so they are preserved verbatim and stay
/// valid. A no-op clone when `from == to`.
pub fn rewrite_seq(module: &ModuleInfo, from: usize, to: usize) -> ModuleInfo {
    if from == to {
        return module.clone();
    }
    ModuleInfo {
        name: module.name.clone(),
        funcs: module
            .funcs
            .iter()
            .map(|f| FuncInfo {
                name: f.name.clone(),
                arg_types: f
                    .arg_types
                    .iter()
                    .map(|t| rewrite_type(t, from, to))
                    .collect(),
                result_types: f
                    .result_types
                    .iter()
                    .map(|t| rewrite_type(t, from, to))
                    .collect(),
                ops: f.ops.iter().map(|op| rewrite_op(op, from, to)).collect(),
            })
            .collect(),
    }
}

/// The decode-phase variant of `module`: the sequence extent rewritten
/// to 1 (one new token per request per step), turning full-sequence
/// GEMMs into GEMV-shaped ops. Returns the module unchanged (cloned)
/// when no sequence extent can be inferred.
pub fn lower_decode(module: &ModuleInfo) -> ModuleInfo {
    match sequence_dim(module) {
        Some(seq) if seq > 1 => rewrite_seq(module, seq, 1),
        _ => module.clone(),
    }
}

/// Infer the attention head layout `(kv_heads, head_dim)` from the
/// module: the first reshape from a rank-2 `[seq, d]` activation to a
/// rank-3 `[seq, h, hd]` with `h * hd == d` is the head split. `None`
/// when the module has no such reshape (e.g. a plain MLP).
pub fn infer_heads(module: &ModuleInfo) -> Option<(usize, usize)> {
    let seq = sequence_dim(module)?;
    let f = module.entry()?;
    for op in &f.ops {
        if op.short_name() != "reshape" {
            continue;
        }
        let (Some(inp), Some(out)) = (op.operand_types.first(), op.result_types.first()) else {
            continue;
        };
        if inp.rank() == 2
            && out.rank() == 3
            && inp.dims[0] == seq
            && out.dims[0] == seq
            && out.dims[1] * out.dims[2] == inp.dims[1]
        {
            return Some((out.dims[1], out.dims[2]));
        }
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::frontend::parse_module;

    const TINY: &str = r#"
module @tiny {
  func.func public @main(%x: tensor<64x32xbf16>, %w: tensor<32x32xbf16>) -> (tensor<64x32xbf16>) {
    %y = stablehlo.dot_general %x, %w, contracting_dims = [1] x [0] : (tensor<64x32xbf16>, tensor<32x32xbf16>) -> tensor<64x32xbf16>
    %h = stablehlo.reshape %y : (tensor<64x32xbf16>) -> tensor<64x4x8xbf16>
    %z = stablehlo.reshape %h : (tensor<64x4x8xbf16>) -> tensor<64x32xbf16>
    return %z : tensor<64x32xbf16>
  }
}
"#;

    #[test]
    fn sequence_dim_is_leading_arg_dim() {
        let m = parse_module(TINY).unwrap();
        assert_eq!(sequence_dim(&m), Some(64));
    }

    #[test]
    fn rewrite_changes_only_the_matching_extent() {
        let m = parse_module(TINY).unwrap();
        let d = rewrite_seq(&m, 64, 1);
        let f = d.entry().unwrap();
        assert_eq!(f.arg_types[0].dims, vec![1, 32]);
        assert_eq!(f.arg_types[1].dims, vec![32, 32], "weights untouched");
        assert_eq!(f.ops[0].result_types[0].dims, vec![1, 32]);
        assert_eq!(f.ops[1].result_types[0].dims, vec![1, 4, 8]);
        // Same op list, same names, same attribute structure.
        let orig = m.entry().unwrap();
        assert_eq!(f.ops.len(), orig.ops.len());
        for (a, b) in orig.ops.iter().zip(&f.ops) {
            assert_eq!(a.op_name, b.op_name);
            assert_eq!(a.dot_dims, b.dot_dims);
            assert_eq!(a.int_attrs, b.int_attrs);
        }
    }

    #[test]
    fn rewrite_identity_when_from_equals_to() {
        let m = parse_module(TINY).unwrap();
        assert_eq!(rewrite_seq(&m, 64, 64), m);
    }

    #[test]
    fn decode_lowering_shrinks_seq_to_one() {
        let m = parse_module(TINY).unwrap();
        let d = lower_decode(&m);
        assert_eq!(sequence_dim(&d), Some(1));
    }

    #[test]
    fn head_split_inferred_from_reshape() {
        let m = parse_module(TINY).unwrap();
        assert_eq!(infer_heads(&m), Some((4, 8)));
    }
}
