//! Request-level LLM inference serving simulator.
//!
//! Answers the question the module estimator cannot: *what does a
//! decoder block cost under a serving workload* — a stream of requests,
//! each with a prompt to prefill and tokens to decode, sharing one chip
//! through continuous batching. The simulator is composed entirely from
//! existing layers:
//!
//! * [`lower`] — the decode lowering: rewrite the module's sequence
//!   extent so the *same* program describes both phases (full-sequence
//!   prefill GEMMs vs batch×1-token GEMV-shaped decode ops);
//! * [`phase`] — the two-phase cost model: each phase runs through the
//!   dependence-graph scheduler + memory-aware DMA timeline
//!   ([`crate::memory::schedule_module_memory`]), and the per-phase
//!   roofline verdict is pinned by a golden fixture per device preset;
//! * [`kv`] — KV-cache accounting: per-request
//!   `2 · layers · kv_heads · head_dim · seq · dtype` bytes threaded
//!   through the [`crate::memory::ResidencyTracker`] as *pinned,
//!   growing* values, so decode step cost reflects resident-set
//!   pressure and spills to HBM when KV outgrows the on-chip budget;
//! * [`workload`] — the deterministic seeded arrival stream (prompt /
//!   output length distributions, arrival gaps — no wall clock);
//! * [`sim`] — the continuous-batching event loop admitting prefills
//!   into running decode batches, reporting tokens/sec, TTFT, TPOT and
//!   per-request latency percentiles per [`crate::device::DeviceSpec`];
//! * [`bench`] — the `bench-llm` harness publishing `BENCH_llm.json`
//!   (FNV source fingerprint, freshness-gated in CI like
//!   `BENCH_serve.json`).
//!
//! Exact invariants (zero epsilons, property-tested in
//! `tests/llm_invariants.rs` across all device presets):
//!
//! * a single-request stream is *bit-identical* to running prefill then
//!   decode standalone;
//! * TTFT `<=` completion time, and both are monotone under a later
//!   arrival of the same request;
//! * continuous-batching makespan `<=` the serialized (batch = 1) run
//!   when KV fits on chip;
//! * tokens/sec never exceeds the decode roofline bound
//!   `max_batch / decode_step_us`;
//! * KV values are pinned — the tracker never evicts one — and spill
//!   accounting is identically zero when the working set fits.

pub mod bench;
pub mod kv;
pub mod lower;
pub mod phase;
pub mod sim;
pub mod workload;

pub use bench::{check_published, run_llm_bench, LlmBenchOptions, LlmBenchReport};
pub use kv::{KvCache, KvCacheSpec};
pub use lower::{lower_decode, rewrite_op, rewrite_seq, rewrite_type, sequence_dim};
pub use phase::{phase_csv, phase_csv_workers, PhaseModel, PREFILL_CACHE_CAP};
pub use sim::{simulate, standalone_request, LlmReport, RequestResult, SimConfig};
pub use workload::{generate_workload, RequestSpec, WorkloadConfig};
