//! The two-phase decoder-block cost model.
//!
//! One module, two schedules. The prefill phase runs the module at the
//! request's prompt length through the dependence-graph scheduler plus
//! the memory-aware DMA timeline ([`schedule_module_memory`]) — full
//! sequence GEMMs. The decode phase runs the *same* module lowered to
//! sequence extent 1 ([`super::lower::lower_decode`]) — GEMV-shaped ops
//! whose arithmetic intensity collapses, shifting the cost balance
//! toward DMA traffic. Both phases inherit the device's engine config
//! and on-chip buffer budget, so phase costs and roofline verdicts are
//! pure functions of (module, device); the checked-in golden
//! `tests/fixtures/llm_phases.csv` pins both per preset.

use std::collections::HashMap;

use crate::coordinator::Estimator;
use crate::device::{DeviceSpec, PRESET_NAMES};
use crate::frontend::opinfo::ModuleInfo;
use crate::graph::EngineConfig;
use crate::memory::{schedule_module_memory, MemoryConfig, MemorySchedule};
use crate::sweep::sweep_estimator;

use super::kv::KvCacheSpec;
use super::lower::{rewrite_seq, sequence_dim};

/// Per-phase schedules for one (module, device) pair, with a memoized
/// prefill cost per prompt length.
pub struct PhaseModel {
    module: ModuleInfo,
    seq: usize,
    engine: EngineConfig,
    memory: MemoryConfig,
    prefill: MemorySchedule,
    decode: MemorySchedule,
    prefill_cache: HashMap<usize, f64>,
}

impl PhaseModel {
    /// Build both phase schedules for `module` on the estimator's
    /// device. `None` when the module has no entry function or no
    /// sequence extent to rewrite.
    pub fn new(est: &Estimator, module: &ModuleInfo) -> Option<PhaseModel> {
        let seq = sequence_dim(module)?;
        module.entry()?;
        let engine = EngineConfig::for_device(est.device());
        let memory = MemoryConfig::new(est.hbm_bytes_per_us(), Some(est.device().vmem_bytes));
        let prefill = schedule_module_memory(est, module, engine, &memory);
        let decode_module = rewrite_seq(module, seq, 1);
        let decode = schedule_module_memory(est, &decode_module, engine, &memory);
        let mut prefill_cache = HashMap::new();
        prefill_cache.insert(seq, prefill.makespan_us());
        Some(PhaseModel {
            module: module.clone(),
            seq,
            engine,
            memory,
            prefill,
            decode,
            prefill_cache,
        })
    }

    /// The module's native sequence extent (the fixture's prompt length).
    pub fn seq(&self) -> usize {
        self.seq
    }

    /// The device's memory config (HBM rate + on-chip budget) — the
    /// simulator charges KV spill traffic at this rate.
    pub fn memory_config(&self) -> &MemoryConfig {
        &self.memory
    }

    /// Prefill cost for a prompt of `prompt` tokens: the module with
    /// its sequence extent rewritten to `prompt`, scheduled through the
    /// memory timeline. Memoized — repeated prompt lengths re-use the
    /// schedule, so streams with duplicate lengths stay cheap.
    pub fn prefill_us(&mut self, est: &Estimator, prompt: usize) -> f64 {
        let prompt = prompt.max(1);
        if let Some(&us) = self.prefill_cache.get(&prompt) {
            return us;
        }
        let m = rewrite_seq(&self.module, self.seq, prompt);
        let us = schedule_module_memory(est, &m, self.engine, &self.memory).makespan_us();
        self.prefill_cache.insert(prompt, us);
        us
    }

    /// One decode step for the whole batch: the sequence-1 lowering's
    /// memory-aware makespan (KV spill traffic is charged on top by the
    /// simulator, per request, per step).
    pub fn decode_step_us(&self) -> f64 {
        self.decode.makespan_us()
    }

    /// Roofline verdict for the native-length prefill schedule
    /// (`"compute-bound"` / `"bandwidth-bound"` / `"balanced"`).
    pub fn prefill_verdict(&self) -> String {
        self.prefill.roofline.verdict().to_string()
    }

    /// Roofline verdict for the decode schedule.
    pub fn decode_verdict(&self) -> String {
        self.decode.roofline.verdict().to_string()
    }

    /// The native-length prefill schedule (trace emission, goldens).
    pub fn prefill_schedule(&self) -> &MemorySchedule {
        &self.prefill
    }

    /// The decode schedule.
    pub fn decode_schedule(&self) -> &MemorySchedule {
        &self.decode
    }
}

/// Per-preset phase table for `module`, as CSV. Uses the deterministic
/// sweep estimator (pure function of spec + module, no calibration
/// assets), so the output is byte-stable — `tests/fixtures/llm_phases.csv`
/// pins it for the decoder-block fixture, same idiom as
/// `sweep_small_tpu-v4.csv`.
pub fn phase_csv(module: &ModuleInfo) -> String {
    let mut out = String::from(
        "device,seq,prefill_us,prefill_verdict,decode_us,decode_verdict,kv_bytes_per_token\n",
    );
    for name in PRESET_NAMES {
        let spec = DeviceSpec::preset(name).expect("registered preset");
        let est = sweep_estimator(&spec);
        let Some(phase) = PhaseModel::new(&est, module) else {
            continue;
        };
        let kv = KvCacheSpec::infer(module, 1)
            .map(|s| s.bytes_per_token())
            .unwrap_or(0);
        out.push_str(&format!(
            "{},{},{:.6},{},{:.6},{},{}\n",
            name,
            phase.seq(),
            phase.prefill.makespan_us(),
            phase.prefill_verdict(),
            phase.decode_step_us(),
            phase.decode_verdict(),
            kv,
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::frontend::parse_module;

    const FIXTURE: &str = include_str!("../../tests/fixtures/decoder_block.mlir");

    #[test]
    fn prefill_dominates_decode() {
        let module = parse_module(FIXTURE).unwrap();
        let spec = DeviceSpec::preset("tpu-v4").unwrap();
        let est = sweep_estimator(&spec);
        let mut phase = PhaseModel::new(&est, &module).unwrap();
        assert_eq!(phase.seq(), 256);
        let p = phase.prefill_us(&est, 256);
        let d = phase.decode_step_us();
        assert!(p > d, "full-sequence prefill must cost more: {p} vs {d}");
        assert!(d > 0.0);
    }

    #[test]
    fn prefill_memoizes_and_scales_with_prompt() {
        let module = parse_module(FIXTURE).unwrap();
        let spec = DeviceSpec::preset("tpu-v5e").unwrap();
        let est = sweep_estimator(&spec);
        let mut phase = PhaseModel::new(&est, &module).unwrap();
        let a = phase.prefill_us(&est, 64);
        let b = phase.prefill_us(&est, 64);
        assert_eq!(a.to_bits(), b.to_bits(), "memoized value must be exact");
        let long = phase.prefill_us(&est, 256);
        assert!(long > a, "longer prompts cost more: {long} vs {a}");
    }

    #[test]
    fn phase_csv_covers_all_presets() {
        let module = parse_module(FIXTURE).unwrap();
        let csv = phase_csv(&module);
        let lines: Vec<&str> = csv.trim_end().lines().collect();
        assert_eq!(lines.len(), 1 + PRESET_NAMES.len());
        for (name, line) in PRESET_NAMES.iter().zip(&lines[1..]) {
            assert!(line.starts_with(&format!("{name},256,")), "{line}");
        }
        // Stable across calls (byte-identical — the golden fixture
        // relies on this).
        assert_eq!(csv, phase_csv(&module));
    }
}
