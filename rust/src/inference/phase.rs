//! The two-phase decoder-block cost model.
//!
//! One module, two schedules. The prefill phase runs the module at the
//! request's prompt length through the dependence-graph scheduler plus
//! the memory-aware DMA timeline — full sequence GEMMs. The decode
//! phase runs the *same* module lowered to sequence extent 1
//! ([`super::lower::lower_decode`]) — GEMV-shaped ops whose arithmetic
//! intensity collapses, shifting the cost balance toward DMA traffic.
//! Both phases inherit the device's engine config and on-chip buffer
//! budget, so phase costs and roofline verdicts are pure functions of
//! (module, device); the checked-in golden
//! `tests/fixtures/llm_phases.csv` pins both per preset.
//!
//! Both phases are priced through one [`ScheduleTemplate`] captured at
//! construction: a prompt-length re-cost is a per-leaf shape-column
//! rewrite + one batched estimate + one schedule replay
//! ([`ScheduleTemplate::recost_seq`]) — no module clone, no re-parse,
//! no graph rebuild — and is bit-identical to the from-scratch
//! pipeline (pinned in `tests/reuse_invariants.rs`).

use std::collections::HashMap;
use std::sync::Arc;

use crate::coordinator::{default_workers, parallel_map, Estimator, ShardedCache};
use crate::device::{DeviceSpec, PRESET_NAMES};
use crate::frontend::opinfo::ModuleInfo;
use crate::graph::{EngineConfig, ScheduleTemplate};
use crate::memory::{MemoryConfig, MemorySchedule};
use crate::sweep::sweep_estimator;

use super::kv::KvCacheSpec;
use super::lower::sequence_dim;

/// Capacity of the per-model prefill memoization cache: distinct prompt
/// lengths retained before least-recently-used eviction. 512 prompt
/// lengths × 16 B per entry keeps the cache under ~10 KiB while
/// covering far more distinct lengths than any checked-in workload
/// generates; evictions are counted and surfaced in
/// [`crate::inference::LlmReport`].
pub const PREFILL_CACHE_CAP: usize = 512;

/// A bounded LRU memo of prompt length → prefill makespan. Hits refresh
/// recency; inserting at capacity evicts the least-recently-used length
/// and bumps the eviction counter. Eviction only costs a re-cost replay
/// on a later re-miss — values are pure functions of the key, so
/// correctness never depends on residency.
struct PrefillCache {
    cap: usize,
    map: HashMap<usize, f64>,
    /// Keys from least- to most-recently used.
    order: Vec<usize>,
    evictions: u64,
}

impl PrefillCache {
    fn new(cap: usize) -> PrefillCache {
        PrefillCache {
            cap: cap.max(1),
            map: HashMap::new(),
            order: Vec::new(),
            evictions: 0,
        }
    }

    fn get(&mut self, prompt: usize) -> Option<f64> {
        let us = *self.map.get(&prompt)?;
        if let Some(pos) = self.order.iter().position(|&k| k == prompt) {
            self.order.remove(pos);
            self.order.push(prompt);
        }
        Some(us)
    }

    fn insert(&mut self, prompt: usize, us: f64) {
        if self.map.contains_key(&prompt) {
            if let Some(pos) = self.order.iter().position(|&k| k == prompt) {
                self.order.remove(pos);
            }
            self.map.insert(prompt, us);
            self.order.push(prompt);
            return;
        }
        if self.map.len() >= self.cap {
            let lru = self.order.remove(0);
            self.map.remove(&lru);
            self.evictions += 1;
        }
        self.map.insert(prompt, us);
        self.order.push(prompt);
    }
}

/// Per-phase schedules for one (module, device) pair, backed by a
/// build-once [`ScheduleTemplate`] and a bounded per-prompt-length memo
/// ([`PREFILL_CACHE_CAP`]).
pub struct PhaseModel {
    template: ScheduleTemplate,
    seq: usize,
    prefill: MemorySchedule,
    decode: MemorySchedule,
    prefill_cache: PrefillCache,
}

impl PhaseModel {
    /// Build both phase schedules for `module` on the estimator's
    /// device. `None` when the module has no entry function or no
    /// sequence extent to rewrite.
    pub fn new(est: &Estimator, module: &ModuleInfo) -> Option<PhaseModel> {
        let seq = sequence_dim(module)?;
        let engine = EngineConfig::for_device(est.device());
        let memory = MemoryConfig::new(est.hbm_bytes_per_us(), Some(est.device().vmem_bytes));
        let template = ScheduleTemplate::capture(module, engine, memory)?;
        let prefill = template.recost_native(est);
        let decode = template.recost_seq(est, seq, 1);
        let mut prefill_cache = PrefillCache::new(PREFILL_CACHE_CAP);
        prefill_cache.insert(seq, prefill.makespan_us());
        Some(PhaseModel {
            template,
            seq,
            prefill,
            decode,
            prefill_cache,
        })
    }

    /// The module's native sequence extent (the fixture's prompt length).
    pub fn seq(&self) -> usize {
        self.seq
    }

    /// The device's memory config (HBM rate + on-chip budget) — the
    /// simulator charges KV spill traffic at this rate.
    pub fn memory_config(&self) -> &MemoryConfig {
        self.template.memory_config()
    }

    /// Prefill cost for a prompt of `prompt` tokens: the schedule
    /// template re-costed at the rewritten sequence extent
    /// ([`ScheduleTemplate::recost_seq`]). Memoized per prompt length in
    /// a bounded LRU ([`PREFILL_CACHE_CAP`]), so streams with duplicate
    /// lengths skip even the replay.
    pub fn prefill_us(&mut self, est: &Estimator, prompt: usize) -> f64 {
        let prompt = prompt.max(1);
        if let Some(us) = self.prefill_cache.get(prompt) {
            return us;
        }
        let us = self.template.recost_seq(est, self.seq, prompt).makespan_us();
        self.prefill_cache.insert(prompt, us);
        us
    }

    /// One decode step for the whole batch: the sequence-1 lowering's
    /// memory-aware makespan (KV spill traffic is charged on top by the
    /// simulator, per request, per step).
    pub fn decode_step_us(&self) -> f64 {
        self.decode.makespan_us()
    }

    /// Roofline verdict for the native-length prefill schedule
    /// (`"compute-bound"` / `"bandwidth-bound"` / `"balanced"`).
    pub fn prefill_verdict(&self) -> String {
        self.prefill.roofline.verdict().to_string()
    }

    /// Roofline verdict for the decode schedule.
    pub fn decode_verdict(&self) -> String {
        self.decode.roofline.verdict().to_string()
    }

    /// The native-length prefill schedule (trace emission, goldens).
    pub fn prefill_schedule(&self) -> &MemorySchedule {
        &self.prefill
    }

    /// The decode schedule.
    pub fn decode_schedule(&self) -> &MemorySchedule {
        &self.decode
    }

    /// Completed template re-cost replays (both construction schedules
    /// and every memo miss go through the template).
    pub fn template_hits(&self) -> u64 {
        self.template.template_hits()
    }

    /// Prompt lengths evicted from the bounded prefill memo so far.
    pub fn prefill_cache_evictions(&self) -> u64 {
        self.prefill_cache.evictions
    }
}

/// One preset's CSV row (header excluded); `None` when the module has
/// no phase structure on that device.
fn phase_row(module: &ModuleInfo, name: &str, cache: &Arc<ShardedCache>) -> Option<String> {
    let spec = DeviceSpec::preset(name).expect("registered preset");
    let est = sweep_estimator(&spec).with_shared_cache(cache.clone());
    let phase = PhaseModel::new(&est, module)?;
    let kv = KvCacheSpec::infer(module, 1)
        .map(|s| s.bytes_per_token())
        .unwrap_or(0);
    Some(format!(
        "{},{},{:.6},{},{:.6},{},{}\n",
        name,
        phase.seq(),
        phase.prefill_schedule().makespan_us(),
        phase.prefill_verdict(),
        phase.decode_step_us(),
        phase.decode_verdict(),
        kv,
    ))
}

/// Per-preset phase table for `module`, as CSV. Uses the deterministic
/// sweep estimator (pure function of spec + module, no calibration
/// assets), so the output is byte-stable — `tests/fixtures/llm_phases.csv`
/// pins it for the decoder-block fixture, same idiom as
/// `sweep_small_tpu-v4.csv`. Presets are priced concurrently (one
/// worker per preset, sharing one shape cache); the joined output is
/// byte-identical to the serial walk — see [`phase_csv_workers`].
pub fn phase_csv(module: &ModuleInfo) -> String {
    phase_csv_workers(module, default_workers())
}

/// [`phase_csv`] with an explicit worker count (`workers == 1` runs the
/// plain serial loop on the caller's thread). Output is byte-identical
/// for every worker count: rows are computed independently per preset,
/// cached cost values are pure functions of their shape keys (so cache
/// sharing cannot perturb them), and rows join in preset order.
pub fn phase_csv_workers(module: &ModuleInfo, workers: usize) -> String {
    let shared = Arc::new(ShardedCache::new());
    let rows = parallel_map(&PRESET_NAMES, workers, |name| {
        phase_row(module, name, &shared)
    });
    let mut out = String::from(
        "device,seq,prefill_us,prefill_verdict,decode_us,decode_verdict,kv_bytes_per_token\n",
    );
    for row in rows.into_iter().flatten() {
        out.push_str(&row);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::frontend::parse_module;

    const FIXTURE: &str = include_str!("../../tests/fixtures/decoder_block.mlir");

    #[test]
    fn prefill_dominates_decode() {
        let module = parse_module(FIXTURE).unwrap();
        let spec = DeviceSpec::preset("tpu-v4").unwrap();
        let est = sweep_estimator(&spec);
        let mut phase = PhaseModel::new(&est, &module).unwrap();
        assert_eq!(phase.seq(), 256);
        let p = phase.prefill_us(&est, 256);
        let d = phase.decode_step_us();
        assert!(p > d, "full-sequence prefill must cost more: {p} vs {d}");
        assert!(d > 0.0);
        assert!(
            phase.template_hits() >= 2,
            "construction replays both phases through the template"
        );
    }

    #[test]
    fn prefill_memoizes_and_scales_with_prompt() {
        let module = parse_module(FIXTURE).unwrap();
        let spec = DeviceSpec::preset("tpu-v5e").unwrap();
        let est = sweep_estimator(&spec);
        let mut phase = PhaseModel::new(&est, &module).unwrap();
        let a = phase.prefill_us(&est, 64);
        let b = phase.prefill_us(&est, 64);
        assert_eq!(a.to_bits(), b.to_bits(), "memoized value must be exact");
        let long = phase.prefill_us(&est, 256);
        assert!(long > a, "longer prompts cost more: {long} vs {a}");
        assert_eq!(phase.prefill_cache_evictions(), 0);
    }

    #[test]
    fn phase_csv_covers_all_presets() {
        let module = parse_module(FIXTURE).unwrap();
        let csv = phase_csv(&module);
        let lines: Vec<&str> = csv.trim_end().lines().collect();
        assert_eq!(lines.len(), 1 + PRESET_NAMES.len());
        for (name, line) in PRESET_NAMES.iter().zip(&lines[1..]) {
            assert!(line.starts_with(&format!("{name},256,")), "{line}");
        }
        // Stable across calls (byte-identical — the golden fixture
        // relies on this).
        assert_eq!(csv, phase_csv(&module));
    }

    #[test]
    fn phase_csv_parallel_matches_serial() {
        let module = parse_module(FIXTURE).unwrap();
        assert_eq!(
            phase_csv_workers(&module, 1),
            phase_csv_workers(&module, 4),
            "fan-out must be byte-identical to the serial walk"
        );
    }

    #[test]
    fn prefill_cache_evicts_least_recently_used() {
        let mut cache = PrefillCache::new(2);
        cache.insert(8, 1.0);
        cache.insert(16, 2.0);
        assert_eq!(cache.get(8), Some(1.0)); // refresh 8 → 16 is LRU
        cache.insert(32, 3.0);
        assert_eq!(cache.evictions, 1);
        assert_eq!(cache.get(16), None, "16 was least recently used");
        assert_eq!(cache.get(8), Some(1.0));
        assert_eq!(cache.get(32), Some(3.0));
        // Re-inserting an existing key never evicts.
        cache.insert(8, 1.5);
        assert_eq!(cache.evictions, 1);
        assert_eq!(cache.get(8), Some(1.5));
    }
}
