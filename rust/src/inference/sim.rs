//! The continuous-batching serving event loop.
//!
//! A single simulated clock advances over three kinds of work: admit a
//! waiting prefill (the whole chip runs one full-sequence pass, which
//! emits the request's first token), run one decode step for the whole
//! running batch (every in-flight request produces one token; requests
//! whose KV cache could not stay resident pay HBM spill traffic on
//! top), or jump to the next arrival when the system is idle. Prefills
//! are admitted *between* decode steps of the running batch — that is
//! continuous batching, as opposed to draining the batch first.
//!
//! Determinism is load-bearing: the loop is seeded-workload in, pure
//! float arithmetic through, and the float operations on the clock are
//! ordered identically to [`standalone_request`], which is what makes
//! the single-request bit-identity invariant in
//! `tests/llm_invariants.rs` hold with zero epsilons.

use std::collections::VecDeque;

use crate::coordinator::Estimator;
use crate::obs::TraceEvent;
use crate::util::json::Json;
use crate::util::stats;

use super::kv::{KvCache, KvCacheSpec};
use super::phase::PhaseModel;
use super::workload::RequestSpec;

/// Simulator knobs (the workload itself comes from
/// [`super::workload::generate_workload`]).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SimConfig {
    /// Maximum in-flight (decoding) requests.
    pub max_batch: usize,
    /// On-chip budget for the KV working set, bytes (`None` =
    /// unbounded). The CLI defaults this to the device's VMEM size.
    pub kv_capacity: Option<u64>,
}

impl Default for SimConfig {
    fn default() -> SimConfig {
        SimConfig {
            max_batch: 8,
            kv_capacity: None,
        }
    }
}

/// Per-request outcome.
#[derive(Debug, Clone, PartialEq)]
pub struct RequestResult {
    /// Stream index.
    pub id: usize,
    /// Arrival time, µs.
    pub arrival_us: f64,
    /// Prompt length, tokens.
    pub prompt: usize,
    /// Output length, tokens.
    pub output: usize,
    /// When the request's prefill started, µs.
    pub prefill_start_us: f64,
    /// When the first token was emitted (prefill end), µs.
    pub first_token_us: f64,
    /// When the last token was emitted, µs.
    pub completion_us: f64,
    /// Time to first token: `first_token_us - arrival_us`.
    pub ttft_us: f64,
    /// End-to-end latency: `completion_us - arrival_us`.
    pub latency_us: f64,
    /// Time per output token after the first:
    /// `(completion_us - first_token_us) / (output - 1)` (0 for
    /// single-token outputs).
    pub tpot_us: f64,
    /// Decode steps this request ran with its KV cache spilled to HBM.
    pub spill_steps: usize,
}

impl RequestResult {
    /// JSON row (the `llm --json` `requests` array).
    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("id", Json::Num(self.id as f64))
            .set("arrival_us", Json::Num(self.arrival_us))
            .set("prompt", Json::Num(self.prompt as f64))
            .set("output", Json::Num(self.output as f64))
            .set("prefill_start_us", Json::Num(self.prefill_start_us))
            .set("first_token_us", Json::Num(self.first_token_us))
            .set("completion_us", Json::Num(self.completion_us))
            .set("ttft_us", Json::Num(self.ttft_us))
            .set("latency_us", Json::Num(self.latency_us))
            .set("tpot_us", Json::Num(self.tpot_us))
            .set("spill_steps", Json::Num(self.spill_steps as f64));
        o
    }
}

/// The serving report: per-request results plus stream-level metrics.
#[derive(Debug, Clone)]
pub struct LlmReport {
    /// Module name.
    pub module: String,
    /// Device name.
    pub device: String,
    /// Batch limit the run used.
    pub max_batch: usize,
    /// Per-request outcomes, in stream order.
    pub requests: Vec<RequestResult>,
    /// Completion time of the last request, µs.
    pub makespan_us: f64,
    /// Total tokens emitted (prefill first tokens + decode tokens).
    pub total_tokens: usize,
    /// `1e6 · total_tokens / makespan_us`.
    pub tokens_per_sec: f64,
    /// The decode roofline bound: `1e6 · max_batch / decode_step_us`.
    /// Measured throughput can never exceed this.
    pub roofline_tokens_per_sec: f64,
    /// Native-length prefill cost, µs, and its roofline verdict.
    pub prefill_us: f64,
    /// Prefill roofline verdict (pinned per preset by the golden CSV).
    pub prefill_verdict: String,
    /// Whole-batch decode step cost, µs.
    pub decode_step_us: f64,
    /// Decode roofline verdict (pinned per preset by the golden CSV).
    pub decode_verdict: String,
    /// KV bytes appended per token per request.
    pub kv_bytes_per_token: u64,
    /// Peak resident KV bytes over the run.
    pub kv_peak_bytes: u64,
    /// KV placements refused for lack of on-chip room.
    pub kv_spill_events: usize,
    /// Bytes served from HBM across those refusals.
    pub kv_spilled_bytes: u64,
    /// KV evictions — structurally always 0 (every placement pins the
    /// whole active set).
    pub kv_evictions: usize,
    /// Decode steps executed.
    pub decode_steps: usize,
    /// Schedule-template re-cost replays the phase model performed
    /// (construction + every distinct prompt length; see
    /// [`crate::graph::ScheduleTemplate`]).
    pub template_hits: u64,
    /// Prompt lengths evicted from the phase model's bounded prefill
    /// memo ([`super::phase::PREFILL_CACHE_CAP`]).
    pub prefill_cache_evictions: u64,
}

fn kv_id(id: usize) -> String {
    format!("kv:{id}")
}

struct Active {
    spec: RequestSpec,
    ctx: usize,
    left: usize,
    prefill_start_us: f64,
    first_token_us: f64,
    spill_steps: usize,
}

fn finish(a: &Active, completion_us: f64) -> RequestResult {
    let r = &a.spec;
    RequestResult {
        id: r.id,
        arrival_us: r.arrival_us,
        prompt: r.prompt,
        output: r.output,
        prefill_start_us: a.prefill_start_us,
        first_token_us: a.first_token_us,
        completion_us,
        ttft_us: a.first_token_us - r.arrival_us,
        latency_us: completion_us - r.arrival_us,
        tpot_us: if r.output > 1 {
            (completion_us - a.first_token_us) / (r.output - 1) as f64
        } else {
            0.0
        },
        spill_steps: a.spill_steps,
    }
}

/// Run the continuous-batching loop over `workload` (sorted by
/// arrival). Returns the full report; per-request results stay in
/// stream order.
pub fn simulate(
    est: &Estimator,
    phase: &mut PhaseModel,
    kv_spec: &KvCacheSpec,
    workload: &[RequestSpec],
    config: &SimConfig,
) -> LlmReport {
    let max_batch = config.max_batch.max(1);
    let mut kvc = KvCache::new(config.kv_capacity);
    let mut t = 0.0_f64;
    let mut next = 0usize;
    let mut waiting: VecDeque<RequestSpec> = VecDeque::new();
    let mut running: Vec<Active> = Vec::new();
    let mut done: Vec<RequestResult> = Vec::new();
    let mut decode_steps = 0usize;
    let mut kv_peak = 0u64;

    loop {
        while next < workload.len() && workload[next].arrival_us <= t {
            waiting.push_back(workload[next]);
            next += 1;
        }
        if running.len() < max_batch && !waiting.is_empty() {
            // Admit one prefill into the running batch.
            let r = waiting.pop_front().expect("non-empty");
            let prefill_start_us = t;
            let cost = phase.prefill_us(est, r.prompt);
            t = t + cost;
            kvc.place(&kv_id(r.id), kv_spec.bytes_at(r.prompt));
            kv_peak = kv_peak.max(kvc.resident_bytes());
            let a = Active {
                spec: r,
                ctx: r.prompt,
                left: r.output.saturating_sub(1),
                prefill_start_us,
                first_token_us: t,
                spill_steps: 0,
            };
            if a.left == 0 {
                kvc.release(&kv_id(r.id));
                done.push(finish(&a, t));
            } else {
                running.push(a);
            }
            continue;
        }
        if !running.is_empty() {
            // One decode step for the whole batch; spilled KV pays HBM
            // traffic on top of the step's schedule.
            let mut cost = phase.decode_step_us();
            for a in running.iter_mut() {
                a.ctx += 1;
                let bytes = kv_spec.bytes_at(a.ctx);
                if !kvc.place(&kv_id(a.spec.id), bytes) {
                    cost += phase.memory_config().transfer_us(bytes);
                    a.spill_steps += 1;
                }
            }
            kv_peak = kv_peak.max(kvc.resident_bytes());
            t = t + cost;
            decode_steps += 1;
            let mut i = 0;
            while i < running.len() {
                running[i].left -= 1;
                if running[i].left == 0 {
                    let a = running.remove(i);
                    kvc.release(&kv_id(a.spec.id));
                    done.push(finish(&a, t));
                } else {
                    i += 1;
                }
            }
            continue;
        }
        if next < workload.len() {
            t = t.max(workload[next].arrival_us);
            continue;
        }
        break;
    }

    done.sort_by_key(|r| r.id);
    let total_tokens: usize = done.iter().map(|r| r.output).sum();
    let makespan_us = done.iter().map(|r| r.completion_us).fold(0.0_f64, f64::max);
    let decode_step_us = phase.decode_step_us();
    LlmReport {
        module: String::new(),
        device: est.device().name.clone(),
        max_batch,
        makespan_us,
        total_tokens,
        tokens_per_sec: if makespan_us > 0.0 {
            1e6 * total_tokens as f64 / makespan_us
        } else {
            0.0
        },
        roofline_tokens_per_sec: 1e6 * max_batch as f64 / decode_step_us,
        prefill_us: phase.prefill_us(est, phase.seq()),
        prefill_verdict: phase.prefill_verdict(),
        decode_step_us,
        decode_verdict: phase.decode_verdict(),
        kv_bytes_per_token: kv_spec.bytes_per_token(),
        kv_peak_bytes: kv_peak,
        kv_spill_events: kvc.spill_events,
        kv_spilled_bytes: kvc.spilled_bytes,
        kv_evictions: kvc.stats().evictions,
        decode_steps,
        template_hits: phase.template_hits(),
        prefill_cache_evictions: phase.prefill_cache_evictions(),
        requests: done,
    }
}

/// Run one request standalone — prefill then decode, no batching, a
/// fresh KV working set — with the clock's float operations ordered
/// exactly as [`simulate`] orders them. A single-request stream must be
/// bit-identical to this.
pub fn standalone_request(
    est: &Estimator,
    phase: &mut PhaseModel,
    kv_spec: &KvCacheSpec,
    r: &RequestSpec,
    kv_capacity: Option<u64>,
) -> RequestResult {
    let mut kvc = KvCache::new(kv_capacity);
    let mut t = 0.0_f64;
    t = t.max(r.arrival_us);
    let prefill_start_us = t;
    let cost = phase.prefill_us(est, r.prompt);
    t = t + cost;
    kvc.place(&kv_id(r.id), kv_spec.bytes_at(r.prompt));
    let first_token_us = t;
    let mut a = Active {
        spec: *r,
        ctx: r.prompt,
        left: r.output.saturating_sub(1),
        prefill_start_us,
        first_token_us,
        spill_steps: 0,
    };
    while a.left > 0 {
        let mut cost = phase.decode_step_us();
        a.ctx += 1;
        let bytes = kv_spec.bytes_at(a.ctx);
        if !kvc.place(&kv_id(r.id), bytes) {
            cost += phase.memory_config().transfer_us(bytes);
            a.spill_steps += 1;
        }
        t = t + cost;
        a.left -= 1;
    }
    kvc.release(&kv_id(r.id));
    finish(&a, t)
}

impl LlmReport {
    /// Percentile over a per-request metric, nearest-rank on the sorted
    /// values (bench_serve idiom) — exact, no interpolation.
    fn pct(&self, q: f64, f: impl Fn(&RequestResult) -> f64) -> f64 {
        let mut xs: Vec<f64> = self.requests.iter().map(f).collect();
        if xs.is_empty() {
            return 0.0;
        }
        xs.sort_by(|a, b| a.partial_cmp(b).expect("finite"));
        let idx = ((xs.len() - 1) as f64 * q).round() as usize;
        xs[idx]
    }

    /// Median TTFT, µs.
    pub fn ttft_p50_us(&self) -> f64 {
        self.pct(0.50, |r| r.ttft_us)
    }

    /// 95th-percentile TTFT, µs.
    pub fn ttft_p95_us(&self) -> f64 {
        self.pct(0.95, |r| r.ttft_us)
    }

    /// Worst TTFT, µs.
    pub fn ttft_max_us(&self) -> f64 {
        self.pct(1.0, |r| r.ttft_us)
    }

    /// Median end-to-end latency, µs.
    pub fn latency_p50_us(&self) -> f64 {
        self.pct(0.50, |r| r.latency_us)
    }

    /// 95th-percentile latency, µs.
    pub fn latency_p95_us(&self) -> f64 {
        self.pct(0.95, |r| r.latency_us)
    }

    /// 99th-percentile latency, µs.
    pub fn latency_p99_us(&self) -> f64 {
        self.pct(0.99, |r| r.latency_us)
    }

    /// Mean time per output token across requests, µs.
    pub fn tpot_mean_us(&self) -> f64 {
        stats::mean(&self.requests.iter().map(|r| r.tpot_us).collect::<Vec<_>>())
    }

    /// Stream-level summary (serve responses, `compare --llm` rows).
    pub fn summary_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("module", Json::Str(self.module.clone()))
            .set("device", Json::Str(self.device.clone()))
            .set("max_batch", Json::Num(self.max_batch as f64))
            .set("requests", Json::Num(self.requests.len() as f64))
            .set("total_tokens", Json::Num(self.total_tokens as f64))
            .set("makespan_us", Json::Num(self.makespan_us))
            .set("tokens_per_sec", Json::Num(self.tokens_per_sec))
            .set(
                "roofline_tokens_per_sec",
                Json::Num(self.roofline_tokens_per_sec),
            )
            .set("prefill_us", Json::Num(self.prefill_us))
            .set("prefill_verdict", Json::Str(self.prefill_verdict.clone()))
            .set("decode_step_us", Json::Num(self.decode_step_us))
            .set("decode_verdict", Json::Str(self.decode_verdict.clone()))
            .set("decode_steps", Json::Num(self.decode_steps as f64))
            .set("ttft_p50_us", Json::Num(self.ttft_p50_us()))
            .set("ttft_p95_us", Json::Num(self.ttft_p95_us()))
            .set("ttft_max_us", Json::Num(self.ttft_max_us()))
            .set("latency_p50_us", Json::Num(self.latency_p50_us()))
            .set("latency_p95_us", Json::Num(self.latency_p95_us()))
            .set("latency_p99_us", Json::Num(self.latency_p99_us()))
            .set("tpot_mean_us", Json::Num(self.tpot_mean_us()))
            .set("kv_bytes_per_token", Json::Num(self.kv_bytes_per_token as f64))
            .set("kv_peak_bytes", Json::Num(self.kv_peak_bytes as f64))
            .set("kv_spill_events", Json::Num(self.kv_spill_events as f64))
            .set("kv_spilled_bytes", Json::Num(self.kv_spilled_bytes as f64))
            .set("kv_evictions", Json::Num(self.kv_evictions as f64))
            .set("template_hits", Json::Num(self.template_hits as f64))
            .set(
                "prefill_cache_evictions",
                Json::Num(self.prefill_cache_evictions as f64),
            );
        o
    }

    /// Full JSON payload (`llm --json`): the summary plus the
    /// per-request array.
    pub fn to_json(&self) -> Json {
        let mut o = self.summary_json();
        o.set(
            "requests_detail",
            Json::Arr(self.requests.iter().map(|r| r.to_json()).collect()),
        );
        o
    }

    /// Human-readable report.
    pub fn render(&self) -> String {
        let mut s = String::new();
        s.push_str(&format!(
            "llm serve: {} on {} — {} requests, max batch {}\n",
            self.module,
            self.device,
            self.requests.len(),
            self.max_batch
        ));
        s.push_str(&format!(
            "  phases: prefill {:.3} us ({}), decode step {:.3} us ({})\n",
            self.prefill_us, self.prefill_verdict, self.decode_step_us, self.decode_verdict
        ));
        s.push_str(&format!(
            "  throughput: {:.1} tokens/s ({} tokens / {:.3} us; roofline bound {:.1})\n",
            self.tokens_per_sec, self.total_tokens, self.makespan_us, self.roofline_tokens_per_sec
        ));
        s.push_str(&format!(
            "  ttft: p50 {:.3} us, p95 {:.3} us, max {:.3} us\n",
            self.ttft_p50_us(),
            self.ttft_p95_us(),
            self.ttft_max_us()
        ));
        s.push_str(&format!(
            "  latency: p50 {:.3} us, p95 {:.3} us, p99 {:.3} us; tpot mean {:.3} us\n",
            self.latency_p50_us(),
            self.latency_p95_us(),
            self.latency_p99_us(),
            self.tpot_mean_us()
        ));
        s.push_str(&format!(
            "  kv: {} B/token, peak {} B, spills {} ({} B), evictions {}\n",
            self.kv_bytes_per_token,
            self.kv_peak_bytes,
            self.kv_spill_events,
            self.kv_spilled_bytes,
            self.kv_evictions
        ));
        s.push_str(&format!(
            "  reuse: {} template replays, {} prefill memo evictions\n",
            self.template_hits, self.prefill_cache_evictions
        ));
        s
    }

    /// Chrome-trace timeline: one lane (thread) per request with
    /// queued / prefill / decode slices, loadable next to the module
    /// traces in `chrome://tracing` / Perfetto.
    pub fn trace_events(&self) -> Vec<TraceEvent> {
        let pid = 1u64;
        let mut evs = vec![TraceEvent::process_name(pid, "llm-serve")];
        for r in &self.requests {
            let tid = r.id as u64 + 1;
            evs.push(TraceEvent::thread_name(pid, tid, &format!("req-{}", r.id)));
            if r.prefill_start_us > r.arrival_us {
                evs.push(TraceEvent::complete(
                    "queued",
                    "llm",
                    r.arrival_us,
                    r.prefill_start_us - r.arrival_us,
                    pid,
                    tid,
                ));
            }
            evs.push(
                TraceEvent::complete(
                    "prefill",
                    "llm",
                    r.prefill_start_us,
                    r.first_token_us - r.prefill_start_us,
                    pid,
                    tid,
                )
                .arg("prompt", Json::Num(r.prompt as f64)),
            );
            if r.completion_us > r.first_token_us {
                evs.push(
                    TraceEvent::complete(
                        "decode",
                        "llm",
                        r.first_token_us,
                        r.completion_us - r.first_token_us,
                        pid,
                        tid,
                    )
                    .arg("tokens", Json::Num(r.output as f64))
                    .arg("spill_steps", Json::Num(r.spill_steps as f64)),
                );
            }
        }
        evs
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::DeviceSpec;
    use crate::frontend::parse_module;
    use crate::sweep::sweep_estimator;

    use super::super::workload::{generate_workload, WorkloadConfig};

    const FIXTURE: &str = include_str!("../../tests/fixtures/decoder_block.mlir");

    fn setup(device: &str) -> (crate::coordinator::Estimator, PhaseModel, KvCacheSpec) {
        let spec = DeviceSpec::preset(device).unwrap();
        let est = sweep_estimator(&spec);
        let module = parse_module(FIXTURE).unwrap();
        let phase = PhaseModel::new(&est, &module).unwrap();
        let kv = KvCacheSpec::infer(&module, 1).unwrap();
        (est, phase, kv)
    }

    #[test]
    fn stream_completes_every_request() {
        let (est, mut phase, kv) = setup("tpu-v4");
        let wl = generate_workload(&WorkloadConfig::default());
        let report = simulate(&est, &mut phase, &kv, &wl, &SimConfig::default());
        assert_eq!(report.requests.len(), wl.len());
        assert!(report.tokens_per_sec > 0.0);
        assert_eq!(report.kv_evictions, 0);
        for (r, w) in report.requests.iter().zip(&wl) {
            assert_eq!(r.id, w.id);
            assert!(r.first_token_us >= w.arrival_us);
            assert!(r.completion_us >= r.first_token_us);
        }
    }

    #[test]
    fn single_request_matches_standalone_bitwise() {
        let (est, mut phase, kv) = setup("tpu-v5e");
        let wl = generate_workload(&WorkloadConfig {
            requests: 1,
            ..WorkloadConfig::default()
        });
        let cfg = SimConfig::default();
        let report = simulate(&est, &mut phase, &kv, &wl, &cfg);
        let solo = standalone_request(&est, &mut phase, &kv, &wl[0], cfg.kv_capacity);
        assert_eq!(report.requests[0], solo);
    }

    #[test]
    fn tokens_per_sec_respects_roofline() {
        let (est, mut phase, kv) = setup("tpu-v5p");
        let wl = generate_workload(&WorkloadConfig {
            requests: 32,
            mean_gap_us: 0.0,
            ..WorkloadConfig::default()
        });
        let report = simulate(&est, &mut phase, &kv, &wl, &SimConfig::default());
        assert!(report.tokens_per_sec <= report.roofline_tokens_per_sec);
    }

    #[test]
    fn tight_kv_budget_spills_but_never_evicts() {
        let (est, mut phase, kv) = setup("tpu-v4");
        let wl = generate_workload(&WorkloadConfig::default());
        let cfg = SimConfig {
            max_batch: 8,
            kv_capacity: Some(kv.bytes_at(64)),
        };
        let report = simulate(&est, &mut phase, &kv, &wl, &cfg);
        assert!(report.kv_spill_events > 0, "tiny budget must spill");
        assert_eq!(report.kv_evictions, 0, "pinned KV never evicts");
        assert_eq!(report.requests.len(), wl.len(), "spills still complete");
    }

    #[test]
    fn trace_has_one_lane_per_request() {
        let (est, mut phase, kv) = setup("tpu-v4");
        let wl = generate_workload(&WorkloadConfig {
            requests: 4,
            ..WorkloadConfig::default()
        });
        let report = simulate(&est, &mut phase, &kv, &wl, &SimConfig::default());
        let evs = report.trace_events();
        let lanes = evs.iter().filter(|e| e.name == "thread_name").count();
        assert_eq!(lanes, 4);
        assert!(evs.iter().any(|e| e.name == "prefill"));
        assert!(evs.iter().any(|e| e.name == "decode"));
    }
}
