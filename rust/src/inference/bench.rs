//! `bench-llm`: the serving-simulator benchmark and its freshness gate.
//!
//! Runs the decoder-block fixture through the continuous-batching
//! simulator on every device preset with a fixed seeded workload, and
//! reports per-preset tokens/sec, TTFT and TPOT plus the simulator's
//! own wall-clock throughput (simulated requests per wall second).
//!
//! `--publish` writes `BENCH_llm.json` at the repo root stamped with an
//! FNV-1a fingerprint of this source file *and* the fixture; `--check`
//! re-reads it and fails when missing or stale — the same freshness
//! idiom as `BENCH_serve.json` / `BENCH_estimator.json`, wired into
//! `make check` and CI.

use std::sync::Arc;
use std::time::Instant;

use anyhow::{bail, Context, Result};

use crate::coordinator::{default_workers, parallel_map, ShardedCache};
use crate::device::{DeviceSpec, PRESET_NAMES};
use crate::frontend::parse_module;
use crate::sweep::sweep_estimator;
use crate::util::json::Json;

use super::kv::KvCacheSpec;
use super::phase::PhaseModel;
use super::sim::{simulate, SimConfig};
use super::workload::{generate_workload, WorkloadConfig};

const SOURCE: &str = include_str!("bench.rs");
const FIXTURE: &str = include_str!("../../tests/fixtures/decoder_block.mlir");

fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Fingerprint of this source file plus the decoder-block fixture,
/// stamped into `BENCH_llm.json`.
pub fn source_fingerprint() -> String {
    let mut h = fnv1a(SOURCE.as_bytes());
    h ^= fnv1a(FIXTURE.as_bytes());
    format!("{h:016x}")
}

/// `BENCH_llm.json` at the repo root.
pub fn bench_json_path() -> std::path::PathBuf {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("../BENCH_llm.json")
}

/// Knobs for [`run_llm_bench`].
#[derive(Debug, Clone, Copy)]
pub struct LlmBenchOptions {
    /// Requests in the seeded stream.
    pub requests: usize,
    /// Workload seed.
    pub seed: u64,
    /// Continuous-batching limit.
    pub max_batch: usize,
    /// Worker threads for the preset fan-out (`0` = auto-detect).
    /// Results are byte-identical for every worker count — presets are
    /// independent simulations joined in preset order.
    pub workers: usize,
}

impl Default for LlmBenchOptions {
    fn default() -> LlmBenchOptions {
        LlmBenchOptions {
            requests: 64,
            seed: 42,
            max_batch: 8,
            workers: 0,
        }
    }
}

/// One preset's serving metrics.
#[derive(Debug, Clone)]
pub struct LlmBenchRow {
    /// Device preset name.
    pub device: String,
    /// Simulated serving throughput.
    pub tokens_per_sec: f64,
    /// Median time to first token, µs.
    pub ttft_p50_us: f64,
    /// Mean time per output token, µs.
    pub tpot_mean_us: f64,
    /// Stream makespan, µs.
    pub makespan_us: f64,
    /// KV placements that had to serve from HBM.
    pub kv_spill_events: usize,
}

/// The published benchmark report.
#[derive(Debug, Clone)]
pub struct LlmBenchReport {
    /// Options the run used.
    pub options: LlmBenchOptions,
    /// Per-preset rows, in [`PRESET_NAMES`] order.
    pub rows: Vec<LlmBenchRow>,
    /// Wall-clock seconds for the whole sweep.
    pub elapsed_s: f64,
    /// Simulated requests per wall second (the bench axis: simulator
    /// speed itself).
    pub sim_requests_per_sec: f64,
    /// Schedule-template re-cost replays across all presets (the reuse
    /// path doing the work the from-scratch pipeline used to redo).
    pub template_hits: u64,
}

impl LlmBenchReport {
    /// Human-readable table.
    pub fn render(&self) -> String {
        let mut s = format!(
            "bench-llm: {} requests, seed {}, max batch {} — {:.3}s wall ({:.0} sim req/s)\n",
            self.options.requests,
            self.options.seed,
            self.options.max_batch,
            self.elapsed_s,
            self.sim_requests_per_sec
        );
        for r in &self.rows {
            s.push_str(&format!(
                "  {:>16}  {:>12.1} tok/s  ttft p50 {:>12.3} us  tpot {:>10.3} us  spills {}\n",
                r.device, r.tokens_per_sec, r.ttft_p50_us, r.tpot_mean_us, r.kv_spill_events
            ));
        }
        s.push_str(&format!(
            "  reuse: {} schedule-template replays across presets\n",
            self.template_hits
        ));
        s
    }

    /// The `BENCH_llm.json` payload.
    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("bench", Json::Str("llm".into()))
            .set("requests", Json::Num(self.options.requests as f64))
            .set("seed", Json::Num(self.options.seed as f64))
            .set("max_batch", Json::Num(self.options.max_batch as f64))
            .set("elapsed_s", Json::Num(self.elapsed_s))
            .set("sim_requests_per_sec", Json::Num(self.sim_requests_per_sec))
            .set("template_hits", Json::Num(self.template_hits as f64))
            .set("source_fingerprint", Json::Str(source_fingerprint()));
        let rows: Vec<Json> = self
            .rows
            .iter()
            .map(|r| {
                let mut row = Json::obj();
                row.set("device", Json::Str(r.device.clone()))
                    .set("tokens_per_sec", Json::Num(r.tokens_per_sec))
                    .set("ttft_p50_us", Json::Num(r.ttft_p50_us))
                    .set("tpot_mean_us", Json::Num(r.tpot_mean_us))
                    .set("makespan_us", Json::Num(r.makespan_us))
                    .set("kv_spill_events", Json::Num(r.kv_spill_events as f64));
                row
            })
            .collect();
        o.set("devices", Json::Arr(rows));
        o
    }

    /// Write `BENCH_llm.json` at the repo root.
    pub fn publish(&self) -> Result<()> {
        let path = bench_json_path();
        std::fs::write(&path, self.to_json().dump() + "\n")
            .with_context(|| format!("writing {}", path.display()))?;
        println!("published {}", path.display());
        Ok(())
    }
}

/// Run the fixed decoder-block serving sweep over every preset.
///
/// Presets fan out over [`parallel_map`] (one independent simulation
/// per worker, all sharing one shape cache — cached cost values are
/// pure functions of their device-fingerprinted keys, so sharing never
/// perturbs a row) and join in [`PRESET_NAMES`] order; the device rows
/// are byte-identical to the serial walk for every worker count.
pub fn run_llm_bench(options: &LlmBenchOptions) -> Result<LlmBenchReport> {
    let module = parse_module(FIXTURE).context("parsing decoder_block fixture")?;
    let workload = generate_workload(&WorkloadConfig {
        requests: options.requests,
        seed: options.seed,
        ..WorkloadConfig::default()
    });
    let workers = if options.workers == 0 {
        default_workers()
    } else {
        options.workers
    };
    let shared = Arc::new(ShardedCache::new());
    let start = Instant::now();
    let results = parallel_map(&PRESET_NAMES, workers, |name| -> Result<(LlmBenchRow, u64)> {
        let name: &str = name;
        let spec = DeviceSpec::preset(name).expect("registered preset");
        let est = sweep_estimator(&spec).with_shared_cache(Arc::clone(&shared));
        let mut phase = PhaseModel::new(&est, &module)
            .ok_or_else(|| anyhow::anyhow!("fixture has no sequence extent"))?;
        let kv = KvCacheSpec::infer(&module, 1)
            .ok_or_else(|| anyhow::anyhow!("fixture has no KV shape"))?;
        let cfg = SimConfig {
            max_batch: options.max_batch,
            kv_capacity: Some(spec.vmem_bytes),
        };
        let report = simulate(&est, &mut phase, &kv, &workload, &cfg);
        let row = LlmBenchRow {
            device: name.to_string(),
            tokens_per_sec: report.tokens_per_sec,
            ttft_p50_us: report.ttft_p50_us(),
            tpot_mean_us: report.tpot_mean_us(),
            makespan_us: report.makespan_us,
            kv_spill_events: report.kv_spill_events,
        };
        Ok((row, report.template_hits))
    });
    let elapsed_s = start.elapsed().as_secs_f64();
    let mut rows = Vec::with_capacity(results.len());
    let mut template_hits = 0u64;
    for result in results {
        let (row, hits) = result?;
        rows.push(row);
        template_hits += hits;
    }
    let total = options.requests * PRESET_NAMES.len();
    Ok(LlmBenchReport {
        options: *options,
        rows,
        elapsed_s,
        sim_requests_per_sec: if elapsed_s > 0.0 {
            total as f64 / elapsed_s
        } else {
            0.0
        },
        template_hits,
    })
}

/// Fail when `BENCH_llm.json` is missing or stale against this source
/// file + fixture (the `make check` / CI freshness gate).
pub fn check_published() -> Result<()> {
    let path = bench_json_path();
    let text = std::fs::read_to_string(&path).with_context(|| {
        format!(
            "BENCH_llm.json missing at {}; run `make bench-llm`",
            path.display()
        )
    })?;
    let json = Json::parse(&text).map_err(|e| anyhow::anyhow!("BENCH_llm.json: {e}"))?;
    let published = json
        .get("source_fingerprint")
        .and_then(Json::as_str)
        .ok_or_else(|| anyhow::anyhow!("BENCH_llm.json lacks source_fingerprint"))?;
    let current = source_fingerprint();
    if published != current {
        bail!(
            "BENCH_llm.json is stale: published fingerprint {published} != bench source \
             {current}; re-run `make bench-llm` and commit the result"
        );
    }
    println!(
        "BENCH_llm.json is fresh (source fingerprint {current}, {} devices)",
        json.get("devices")
            .and_then(Json::as_arr)
            .map(|a| a.len())
            .unwrap_or(0)
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fingerprint_is_stable_hex() {
        let a = source_fingerprint();
        assert_eq!(a.len(), 16);
        assert_eq!(a, source_fingerprint());
    }

    #[test]
    fn bench_runs_all_presets() {
        let report = run_llm_bench(&LlmBenchOptions {
            requests: 4,
            ..LlmBenchOptions::default()
        })
        .unwrap();
        assert_eq!(report.rows.len(), PRESET_NAMES.len());
        for row in &report.rows {
            assert!(row.tokens_per_sec > 0.0, "{}", row.device);
            assert!(row.ttft_p50_us > 0.0);
        }
        assert!(
            report.template_hits > 0,
            "the serving path must run through the schedule template"
        );
        let j = report.to_json();
        assert_eq!(j.req_str("source_fingerprint").unwrap(), source_fingerprint());
    }
}
