//! KV-cache accounting: pinned, growing per-request buffers.
//!
//! Each decoding request holds a key/value cache of
//! `2 · layers · kv_heads · head_dim · seq · dtype` bytes that grows by
//! one token per decode step. The cache is threaded through the
//! existing [`ResidencyTracker`] as a *pinned* value: every placement
//! pins the whole active KV set, so the tracker can never evict one
//! request's cache to make room for another's — when the working set
//! outgrows the on-chip budget the placement is *refused* instead, the
//! request's cache lives in HBM for that step, and the decode step pays
//! the spill traffic. `tests/llm_invariants.rs` pins the consequences:
//! KV evictions are identically zero always, and spill accounting is
//! identically zero whenever the working set fits.

use crate::frontend::opinfo::ModuleInfo;
use crate::frontend::types::DType;
use crate::memory::{ResidencyStats, ResidencyTracker};

use super::lower::{infer_heads, sequence_dim};

/// The shape of one request's KV cache.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct KvCacheSpec {
    /// Decoder layers sharing the cache (the module usually describes
    /// one block; a full model multiplies by its depth).
    pub layers: usize,
    /// KV heads (equals query heads without grouped-query attention).
    pub kv_heads: usize,
    /// Per-head dimension.
    pub head_dim: usize,
    /// Bytes per cached element.
    pub dtype_bytes: u64,
}

impl KvCacheSpec {
    /// Infer the cache shape from the module: head split from the first
    /// `[seq, d] -> [seq, h, hd]` reshape, dtype from the activation
    /// argument. Falls back to one "head" of the full model dimension
    /// when the module has no head-split reshape.
    pub fn infer(module: &ModuleInfo, layers: usize) -> Option<KvCacheSpec> {
        let f = module.entry()?;
        let act = f.arg_types.first()?;
        let (kv_heads, head_dim) = match infer_heads(module) {
            Some(hh) => hh,
            None => {
                let d = if act.rank() >= 2 {
                    act.dims[1]
                } else {
                    *act.dims.first()?
                };
                (1, d)
            }
        };
        // The sequence extent must exist for the phase model anyway.
        sequence_dim(module)?;
        Some(KvCacheSpec {
            layers: layers.max(1),
            kv_heads,
            head_dim,
            dtype_bytes: act.dtype.bytes() as u64,
        })
    }

    /// A spec with explicit parameters (CLI overrides).
    pub fn new(layers: usize, kv_heads: usize, head_dim: usize, dtype: DType) -> KvCacheSpec {
        KvCacheSpec {
            layers: layers.max(1),
            kv_heads,
            head_dim,
            dtype_bytes: dtype.bytes() as u64,
        }
    }

    /// Bytes per cached token: `2 · layers · kv_heads · head_dim · dtype`.
    pub fn bytes_per_token(&self) -> u64 {
        2 * self.layers as u64 * self.kv_heads as u64 * self.head_dim as u64 * self.dtype_bytes
    }

    /// One request's cache footprint at context length `seq`.
    pub fn bytes_at(&self, seq: usize) -> u64 {
        self.bytes_per_token() * seq as u64
    }
}

/// The simulator's KV working set: a [`ResidencyTracker`] whose entries
/// are always pinned, plus spill accounting.
#[derive(Debug, Clone)]
pub struct KvCache {
    tracker: ResidencyTracker,
    /// Active request ids, in admission order — the pinned set passed
    /// to every placement.
    ids: Vec<String>,
    /// Placements refused because the working set outgrew the budget
    /// (the request's KV serves from HBM for that step).
    pub spill_events: usize,
    /// Bytes that had to serve from HBM across those events.
    pub spilled_bytes: u64,
}

impl KvCache {
    /// A working set bounded to `capacity` bytes (`None` = unbounded).
    pub fn new(capacity: Option<u64>) -> KvCache {
        KvCache {
            tracker: ResidencyTracker::new(capacity),
            ids: Vec::new(),
            spill_events: 0,
            spilled_bytes: 0,
        }
    }

    /// Place (or grow) request `id`'s cache to `bytes`. Growth is a
    /// remove + insert because the tracker keys footprint at insertion;
    /// the insert pins every active cache, so it can refuse but never
    /// evict. Returns true when the cache is resident on chip after the
    /// call; false records one spill event.
    pub fn place(&mut self, id: &str, bytes: u64) -> bool {
        if self.tracker.contains(id) {
            self.tracker.remove(id);
        }
        if !self.ids.iter().any(|x| x == id) {
            self.ids.push(id.to_string());
        }
        let out = self.tracker.insert(id, bytes, true, &self.ids);
        debug_assert!(out.evicted.is_empty(), "pinned KV must never evict");
        if !out.inserted {
            self.spill_events += 1;
            self.spilled_bytes += bytes;
        }
        out.inserted
    }

    /// Drop a finished request's cache and unpin it.
    pub fn release(&mut self, id: &str) {
        self.tracker.remove(id);
        self.ids.retain(|x| x != id);
    }

    /// Lifetime tracker counters (evictions must stay 0).
    pub fn stats(&self) -> ResidencyStats {
        self.tracker.stats()
    }

    /// Resident KV bytes right now.
    pub fn resident_bytes(&self) -> u64 {
        self.tracker.resident_bytes()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> KvCacheSpec {
        KvCacheSpec::new(1, 8, 128, DType::Bf16)
    }

    #[test]
    fn bytes_formula() {
        let s = spec();
        assert_eq!(s.bytes_per_token(), 2 * 8 * 128 * 2);
        assert_eq!(s.bytes_at(10), 10 * 2 * 8 * 128 * 2);
    }

    #[test]
    fn growth_never_evicts_a_peer() {
        let s = spec();
        let mut kv = KvCache::new(Some(s.bytes_at(12)));
        assert!(kv.place("kv:0", s.bytes_at(4)));
        assert!(kv.place("kv:1", s.bytes_at(4)));
        // Growing request 0 past the remaining room is refused, not
        // satisfied by evicting request 1.
        assert!(!kv.place("kv:0", s.bytes_at(9)));
        assert_eq!(kv.spill_events, 1);
        assert_eq!(kv.spilled_bytes, s.bytes_at(9));
        assert_eq!(kv.stats().evictions, 0);
        // Request 1 is still resident and can still grow within budget.
        assert!(kv.place("kv:1", s.bytes_at(5)));
        // Releasing request 1 frees room for request 0 again.
        kv.release("kv:1");
        assert!(kv.place("kv:0", s.bytes_at(9)));
        assert_eq!(kv.stats().evictions, 0);
    }

    #[test]
    fn unbounded_never_spills() {
        let s = spec();
        let mut kv = KvCache::new(None);
        for i in 0..64 {
            assert!(kv.place(&format!("kv:{i}"), s.bytes_at(1024)));
        }
        assert_eq!(kv.spill_events, 0);
        assert_eq!(kv.stats().evictions, 0);
    }
}
