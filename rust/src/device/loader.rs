//! Device-file loading: a dependency-free TOML-subset parser plus the
//! JSON fallback, and the `--device <name|file>` resolution rule.
//!
//! The grammar covers exactly what `rust/devices/*.toml` uses (and what
//! a user-authored device file needs): `# comments`, `[section]`
//! headers, and `key = value` pairs where a value is a number, a
//! `"quoted string"`, or `true`/`false`. Unknown sections or keys are
//! hard errors — a typoed `hbm_gpbs` must not silently leave the
//! reference value in place. Keys that are *absent* inherit the
//! [`DeviceSpec::tpu_v4`] reference value, so a file only needs to spell
//! out what differs.

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::scalesim::Dataflow;
use crate::util::json::Json;

use super::spec::{DeviceSpec, TopologyKind, PRESET_NAMES};

/// Strip a `# comment` (outside of double quotes) and surrounding
/// whitespace from one line.
fn strip_comment(line: &str) -> &str {
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return line[..i].trim(),
            _ => {}
        }
    }
    line.trim()
}

/// Parse one TOML value: `"string"`, `true`/`false`, or a number
/// (returned as the raw token; the field applier knows the type).
fn unquote(value: &str) -> Result<String> {
    let v = value.trim();
    if let Some(inner) = v.strip_prefix('"') {
        let Some(inner) = inner.strip_suffix('"') else {
            bail!("unterminated string '{v}'");
        };
        return Ok(inner.to_string());
    }
    Ok(v.to_string())
}

/// Apply one `section.key = value` triple onto the spec being built.
fn apply(spec: &mut DeviceSpec, section: &str, key: &str, value: &str) -> Result<()> {
    let sval = unquote(value)?;
    let as_f64 = || -> Result<f64> {
        sval.parse::<f64>()
            .with_context(|| format!("'{key}' expects a number, got '{value}'"))
    };
    let as_usize = || -> Result<usize> {
        sval.parse::<usize>()
            .with_context(|| format!("'{key}' expects an integer, got '{value}'"))
    };
    match (section, key) {
        ("", "name") => spec.name = sval,
        ("", "description") => spec.description = sval,
        ("systolic", "array_rows") => spec.array_rows = as_usize()?,
        ("systolic", "array_cols") => spec.array_cols = as_usize()?,
        ("systolic", "dataflow") => {
            spec.dataflow =
                Dataflow::parse(&sval).with_context(|| format!("bad dataflow '{sval}'"))?;
        }
        ("systolic", "ifmap_sram_kb") => spec.ifmap_sram_kb = as_usize()?,
        ("systolic", "filter_sram_kb") => spec.filter_sram_kb = as_usize()?,
        ("systolic", "ofmap_sram_kb") => spec.ofmap_sram_kb = as_usize()?,
        ("systolic", "ifmap_dram_bw") => spec.ifmap_dram_bw = as_f64()?,
        ("systolic", "filter_dram_bw") => spec.filter_dram_bw = as_f64()?,
        ("systolic", "ofmap_dram_bw") => spec.ofmap_dram_bw = as_f64()?,
        ("systolic", "word_bytes") => spec.word_bytes = as_usize()?,
        ("systolic", "clock_mhz") => spec.clock_mhz = as_f64()?,
        ("vector", "elems_per_cycle") => spec.vpu_elems_per_cycle = as_f64()?,
        ("memory", "hbm_gbps") => spec.hbm_gbps = as_f64()?,
        ("memory", "vmem_mib") => {
            let mib = as_f64()?;
            // The f64 -> u64 cast would silently saturate a negative
            // value to 0 (a zero residency buffer), so reject it here.
            if !(mib.is_finite() && mib >= 0.0) {
                bail!("'vmem_mib' must be non-negative, got {mib}");
            }
            spec.vmem_bytes = (mib * 1024.0 * 1024.0) as u64;
        }
        ("memory", "vmem_bytes") => spec.vmem_bytes = as_usize()? as u64,
        ("memory", "dma_engines") => spec.dma_engines = as_usize()?,
        ("ici", "link_gbps") => spec.ici_link_gbps = as_f64()?,
        ("ici", "hop_latency_us") => spec.ici_hop_latency_us = as_f64()?,
        ("ici", "topology") => {
            spec.ici_topology = TopologyKind::parse(&sval)
                .with_context(|| format!("bad topology '{sval}' (ring|torus)"))?;
        }
        ("latency", "dispatch_overhead_us") => spec.dispatch_overhead_us = as_f64()?,
        _ => {
            let at = if section.is_empty() {
                key.to_string()
            } else {
                format!("{section}.{key}")
            };
            bail!("unknown device-file key '{at}'");
        }
    }
    Ok(())
}

/// Parse a device file in the TOML subset. Unspecified keys inherit the
/// [`DeviceSpec::tpu_v4`] reference values; `name` is mandatory.
///
/// ```
/// use scalesim_tpu::device::parse_device_toml;
///
/// let spec = parse_device_toml(
///     "name = \"half-bandwidth\"\n[memory]\nhbm_gbps = 600.0\n",
/// )
/// .unwrap();
/// assert_eq!(spec.name, "half-bandwidth");
/// assert_eq!(spec.hbm_gbps, 600.0);
/// assert_eq!(spec.array_rows, 128); // inherited from the reference
/// ```
pub fn parse_device_toml(text: &str) -> Result<DeviceSpec> {
    let mut spec = DeviceSpec::tpu_v4();
    spec.name = String::new();
    spec.description = String::new();
    let mut section = String::new();
    for (lineno, raw) in text.lines().enumerate() {
        let line = strip_comment(raw);
        if line.is_empty() {
            continue;
        }
        if let Some(inner) = line.strip_prefix('[') {
            let Some(inner) = inner.strip_suffix(']') else {
                bail!("line {}: unterminated section header '{line}'", lineno + 1);
            };
            section = inner.trim().to_string();
            continue;
        }
        let Some((key, value)) = line.split_once('=') else {
            bail!("line {}: expected 'key = value', got '{line}'", lineno + 1);
        };
        apply(&mut spec, &section, key.trim(), value.trim())
            .with_context(|| format!("line {}", lineno + 1))?;
    }
    if spec.name.is_empty() {
        bail!("device file must set 'name'");
    }
    spec.validate()?;
    Ok(spec)
}

/// Every key the flat JSON device schema accepts (the
/// [`DeviceSpec::to_json`] field set).
const JSON_KEYS: [&str; 21] = [
    "name",
    "description",
    "array_rows",
    "array_cols",
    "dataflow",
    "ifmap_sram_kb",
    "filter_sram_kb",
    "ofmap_sram_kb",
    "ifmap_dram_bw",
    "filter_dram_bw",
    "ofmap_dram_bw",
    "word_bytes",
    "clock_mhz",
    "vpu_elems_per_cycle",
    "hbm_gbps",
    "vmem_bytes",
    "dma_engines",
    "ici_link_gbps",
    "ici_hop_latency_us",
    "ici_topology",
    "dispatch_overhead_us",
];

/// Load a device file, sniffing the format: content starting with `{`
/// parses as the flat JSON schema, everything else as TOML. Both
/// formats reject unknown keys — a typoed `hbm_gpbs` must not silently
/// leave the reference value in place.
pub fn load_device_file(path: &Path) -> Result<DeviceSpec> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading device file {}", path.display()))?;
    let spec = if text.trim_start().starts_with('{') {
        let j = Json::parse(&text).map_err(|e| anyhow::anyhow!("{e}"))?;
        if let Json::Obj(pairs) = &j {
            for key in pairs.keys() {
                if !JSON_KEYS.contains(&key.as_str()) {
                    bail!(
                        "unknown device-file key '{key}' in {}",
                        path.display()
                    );
                }
            }
        }
        let spec = DeviceSpec::from_json(&j).map_err(|e| anyhow::anyhow!("{e}"))?;
        spec.validate()?;
        spec
    } else {
        parse_device_toml(&text)?
    };
    Ok(spec)
}

/// Resolve a `--device` argument: a preset name first, else a path to a
/// device file.
pub fn resolve_device(arg: &str) -> Result<DeviceSpec> {
    if let Some(spec) = DeviceSpec::preset(arg) {
        return Ok(spec);
    }
    let path = Path::new(arg);
    if path.exists() {
        return load_device_file(path);
    }
    bail!(
        "unknown device '{arg}' (presets: {}; or pass a .toml/.json device file)",
        PRESET_NAMES.join(", ")
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn full_toml_roundtrips_a_preset() {
        let text = r#"
# TPU v5e preset, spelled out in full.
name = "tpu-v5e"
description = "d"

[systolic]
array_rows = 128
array_cols = 128
dataflow = "ws"
ifmap_sram_kb = 4096
filter_sram_kb = 4096
ofmap_sram_kb = 4096
ifmap_dram_bw = 176.0
filter_dram_bw = 176.0
ofmap_dram_bw = 88.0
word_bytes = 2
clock_mhz = 940.0

[vector]
elems_per_cycle = 128.0

[memory]
hbm_gbps = 819.0
vmem_mib = 16.0
dma_engines = 1

[ici]
link_gbps = 50.0
hop_latency_us = 1.0
topology = "torus"

[latency]
dispatch_overhead_us = 1.5
"#;
        let spec = parse_device_toml(text).unwrap();
        assert_eq!(spec.fingerprint(), DeviceSpec::tpu_v5e().fingerprint());
    }

    #[test]
    fn sparse_toml_inherits_reference_values() {
        let spec = parse_device_toml("name = \"mini\"\n[memory]\nhbm_gbps = 600 # half\n")
            .unwrap();
        assert_eq!(spec.name, "mini");
        assert_eq!(spec.hbm_gbps, 600.0);
        assert_eq!(spec.vmem_bytes, DeviceSpec::tpu_v4().vmem_bytes);
        assert_eq!(spec.clock_mhz, 940.0);
    }

    #[test]
    fn errors_are_loud() {
        // Missing name.
        assert!(parse_device_toml("[memory]\nhbm_gbps = 600\n").is_err());
        // Typoed key.
        let err = parse_device_toml("name = \"x\"\n[memory]\nhbm_gpbs = 600\n")
            .unwrap_err()
            .to_string();
        assert!(err.contains("line 3"), "{err}");
        // Wrong type.
        assert!(parse_device_toml("name = \"x\"\n[systolic]\narray_rows = \"wide\"\n").is_err());
        // Invalid resulting spec.
        assert!(parse_device_toml("name = \"x\"\n[memory]\nhbm_gbps = 0\n").is_err());
        // A negative VMEM must not saturate to a zero-byte buffer.
        assert!(parse_device_toml("name = \"x\"\n[memory]\nvmem_mib = -8\n").is_err());
        // Garbage line.
        assert!(parse_device_toml("name = \"x\"\nwhat is this\n").is_err());
        // Unterminated section.
        assert!(parse_device_toml("name = \"x\"\n[memory\n").is_err());
    }

    #[test]
    fn comments_inside_strings_survive() {
        let spec = parse_device_toml("name = \"has#hash\"\n").unwrap();
        assert_eq!(spec.name, "has#hash");
    }

    #[test]
    fn resolve_prefers_presets_then_files() {
        assert_eq!(resolve_device("tpu-v5p").unwrap().name, "tpu-v5p");
        assert!(resolve_device("no-such-device").is_err());
        let dir = std::env::temp_dir().join("scalesim_device_loader_test");
        std::fs::create_dir_all(&dir).unwrap();
        let toml_path = dir.join("custom.toml");
        std::fs::write(&toml_path, "name = \"custom\"\n[ici]\nlink_gbps = 10\n").unwrap();
        let spec = resolve_device(toml_path.to_str().unwrap()).unwrap();
        assert_eq!(spec.name, "custom");
        assert_eq!(spec.ici_link_gbps, 10.0);
        // JSON files load through the same entry point.
        let json_path = dir.join("custom.json");
        std::fs::write(&json_path, r#"{"name":"jdev","hbm_gbps":700}"#).unwrap();
        let spec = load_device_file(&json_path).unwrap();
        assert_eq!(spec.name, "jdev");
        assert_eq!(spec.hbm_gbps, 700.0);
        // JSON typos are hard errors, same as TOML.
        let typo_path = dir.join("typo.json");
        std::fs::write(&typo_path, r#"{"name":"jdev","hbm_gpbs":700}"#).unwrap();
        let err = load_device_file(&typo_path).unwrap_err().to_string();
        assert!(err.contains("hbm_gpbs"), "{err}");
        std::fs::remove_dir_all(&dir).ok();
    }
}
