//! The unified device-model layer.
//!
//! One [`DeviceSpec`] owns *every* hardware parameter the simulator
//! reads — systolic-array geometry and dataflow, MXU/VPU rates, HBM
//! bandwidth, on-chip buffer budget, DMA engines, ICI topology / link
//! bandwidth / hop latency, and the cycle→latency mapping priors — and
//! every subsystem derives its private config from it:
//!
//! * [`DeviceSpec::scale_config`] → the SCALE-Sim architecture config
//!   ([`crate::scalesim::ScaleConfig`]),
//! * [`DeviceSpec::memory_config`] → the DMA-timeline bandwidth/buffer
//!   ([`crate::memory::MemoryConfig`]),
//! * [`DeviceSpec::slice_config`] → the multi-chip ICI wiring
//!   ([`crate::distributed::SliceConfig`]),
//! * [`DeviceSpec::mxu_params`] / [`DeviceSpec::vpu_params`] → the
//!   synthetic measurement substrate ([`crate::tpu::TpuV4Model`]),
//! * [`DeviceSpec::transfer_calibration`] / [`DeviceSpec::ew_scale`] →
//!   the estimator's retargeting rules
//!   ([`crate::coordinator::Estimator::retarget`]).
//!
//! Four presets ship in the registry (`tpu-v4` — the reference that
//! reproduces the historical hard-coded constants bit for bit —
//! `tpu-v5e`, `tpu-v5p`, `generic-256x256`), and user-defined devices
//! load from TOML or JSON files ([`load_device_file`]); the checked-in
//! preset files live under `rust/devices/`. See DESIGN.md §Device model
//! for the schema and the override-precedence rules.

mod loader;
mod spec;

pub use loader::{load_device_file, parse_device_toml, resolve_device};
pub use spec::{DeviceSpec, TopologyKind, PRESET_NAMES};
