//! The [`DeviceSpec`] itself: every hardware parameter the simulator
//! reads, in one struct, plus the preset registry and the derivation
//! methods that hand each subsystem its private config.
//!
//! Derivations are *exact* for the reference preset: deriving a
//! [`ScaleConfig`], [`MemoryConfig`], [`MxuParams`] or [`VpuParams`]
//! from [`DeviceSpec::tpu_v4`] reproduces the historical hard-coded
//! constants bit for bit (tested in `tests/device_spec.rs`), so the
//! refactor cannot perturb any existing estimate.

use anyhow::{bail, Result};

use crate::calibrate::{LinearFit, RegimeCalibration};
use crate::distributed::ici::{IciTopology, SliceConfig};
use crate::memory::MemoryConfig;
use crate::scalesim::{Dataflow, ScaleConfig};
use crate::tpu::{MxuParams, VpuParams};
use crate::util::json::{Json, JsonError};

/// Names of the built-in device presets, in registry order.
pub const PRESET_NAMES: [&str; 4] = ["tpu-v4", "tpu-v5e", "tpu-v5p", "generic-256x256"];

/// Which ICI wiring a device defaults to when the caller does not pick a
/// topology explicitly (the chip count is only known per run, so a torus
/// default auto-factors into a near-square grid at slice-build time).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum TopologyKind {
    /// One bidirectional ring over all chips.
    Ring,
    /// A near-square 2-D torus ([`IciTopology::torus`]).
    Torus,
}

impl TopologyKind {
    /// Lowercase kind name (device files, tables).
    pub fn name(&self) -> &'static str {
        match self {
            TopologyKind::Ring => "ring",
            TopologyKind::Torus => "torus",
        }
    }

    /// Parse `ring` / `torus`.
    pub fn parse(s: &str) -> Option<TopologyKind> {
        match s {
            "ring" => Some(TopologyKind::Ring),
            "torus" | "torus2d" | "2d" => Some(TopologyKind::Torus),
            _ => None,
        }
    }
}

impl std::fmt::Display for TopologyKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// One accelerator device model: systolic array, vector unit, memory
/// system, interconnect and the latency-mapping priors, all in one
/// place. Everything downstream ([`ScaleConfig`], [`MemoryConfig`],
/// [`SliceConfig`], [`MxuParams`], [`VpuParams`], the estimator's
/// calibration transfer) is *derived* from a spec.
#[derive(Debug, Clone, PartialEq)]
pub struct DeviceSpec {
    /// Registry/display name (`tpu-v4`, or whatever a device file says).
    pub name: String,
    /// One-line human description for tables.
    pub description: String,
    /// Systolic MAC-array rows (S_R).
    pub array_rows: usize,
    /// Systolic MAC-array columns (S_C).
    pub array_cols: usize,
    /// Dataflow the array runs (OS / WS / IS).
    pub dataflow: Dataflow,
    /// IFMAP SRAM capacity, KiB (double-buffered by the simulator).
    pub ifmap_sram_kb: usize,
    /// Filter SRAM capacity, KiB.
    pub filter_sram_kb: usize,
    /// OFMAP SRAM capacity, KiB.
    pub ofmap_sram_kb: usize,
    /// DRAM read bandwidth for ifmap operands, words/cycle.
    pub ifmap_dram_bw: f64,
    /// DRAM read bandwidth for filter operands, words/cycle.
    pub filter_dram_bw: f64,
    /// DRAM write bandwidth for ofmap results, words/cycle.
    pub ofmap_dram_bw: f64,
    /// Bytes per operand word (2 for bf16).
    pub word_bytes: usize,
    /// Core clock, MHz (the MXU and VPU share it in this model).
    pub clock_mhz: f64,
    /// Peak vector-unit throughput, elements/cycle (fully pipelined).
    pub vpu_elems_per_cycle: f64,
    /// HBM bandwidth, GB/s (1 GB/s = 1000 bytes/µs).
    pub hbm_gbps: f64,
    /// On-chip residency buffer (VMEM) for the memory timeline, bytes.
    pub vmem_bytes: u64,
    /// DMA engines moving HBM traffic concurrently with compute. A
    /// device with zero dedicated engines serializes explicit data
    /// movement onto its compute lane (see
    /// [`EngineConfig::for_device`](crate::graph::EngineConfig::for_device)).
    pub dma_engines: usize,
    /// Per-ICI-link bandwidth, GB/s.
    pub ici_link_gbps: f64,
    /// Per-ICI-hop latency (the alpha term), µs.
    pub ici_hop_latency_us: f64,
    /// Default link wiring when the caller does not pick one.
    pub ici_topology: TopologyKind,
    /// Fixed kernel dispatch overhead, µs — the intercept prior of the
    /// cycle-to-latency mapping (the slope prior is `1 / clock`).
    pub dispatch_overhead_us: f64,
}

impl DeviceSpec {
    /// The reference preset: reproduces every historical hard-coded
    /// constant ([`ScaleConfig::tpu_v4`], [`MxuParams::default`],
    /// [`VpuParams::default`], [`MemoryConfig::tpu_v4`], the ICI
    /// defaults) bit for bit.
    pub fn tpu_v4() -> DeviceSpec {
        DeviceSpec {
            name: "tpu-v4".to_string(),
            description: "128x128 MXU @ 940 MHz, 1.2 TB/s HBM, 32 MiB VMEM (reference)"
                .to_string(),
            array_rows: 128,
            array_cols: 128,
            dataflow: Dataflow::WeightStationary,
            ifmap_sram_kb: 8 * 1024,
            filter_sram_kb: 8 * 1024,
            ofmap_sram_kb: 8 * 1024,
            ifmap_dram_bw: 256.0,
            filter_dram_bw: 256.0,
            ofmap_dram_bw: 128.0,
            word_bytes: 2,
            clock_mhz: 940.0,
            vpu_elems_per_cycle: 256.0,
            hbm_gbps: 1200.0,
            vmem_bytes: 32 * 1024 * 1024,
            dma_engines: 1,
            ici_link_gbps: 100.0,
            ici_hop_latency_us: 1.0,
            ici_topology: TopologyKind::Ring,
            dispatch_overhead_us: 2.0,
        }
    }

    /// TPU v5e-like efficiency part: same 128x128 array, leaner memory
    /// system (819 GB/s HBM, 16 MiB VMEM), slimmer ICI links, torus
    /// wiring by default.
    pub fn tpu_v5e() -> DeviceSpec {
        DeviceSpec {
            name: "tpu-v5e".to_string(),
            description: "128x128 MXU @ 940 MHz, 819 GB/s HBM, 16 MiB VMEM (efficiency)"
                .to_string(),
            array_rows: 128,
            array_cols: 128,
            dataflow: Dataflow::WeightStationary,
            ifmap_sram_kb: 4 * 1024,
            filter_sram_kb: 4 * 1024,
            ofmap_sram_kb: 4 * 1024,
            ifmap_dram_bw: 176.0,
            filter_dram_bw: 176.0,
            ofmap_dram_bw: 88.0,
            word_bytes: 2,
            clock_mhz: 940.0,
            vpu_elems_per_cycle: 128.0,
            hbm_gbps: 819.0,
            vmem_bytes: 16 * 1024 * 1024,
            dma_engines: 1,
            ici_link_gbps: 50.0,
            ici_hop_latency_us: 1.0,
            ici_topology: TopologyKind::Torus,
            dispatch_overhead_us: 1.5,
        }
    }

    /// TPU v5p-like performance part: faster clock, 2.77 TB/s HBM,
    /// bigger buffers, fat torus links.
    pub fn tpu_v5p() -> DeviceSpec {
        DeviceSpec {
            name: "tpu-v5p".to_string(),
            description: "128x128 MXU @ 1.1 GHz, 2.77 TB/s HBM, 64 MiB VMEM (performance)"
                .to_string(),
            array_rows: 128,
            array_cols: 128,
            dataflow: Dataflow::WeightStationary,
            ifmap_sram_kb: 12 * 1024,
            filter_sram_kb: 12 * 1024,
            ofmap_sram_kb: 12 * 1024,
            ifmap_dram_bw: 512.0,
            filter_dram_bw: 512.0,
            ofmap_dram_bw: 256.0,
            word_bytes: 2,
            clock_mhz: 1100.0,
            vpu_elems_per_cycle: 512.0,
            hbm_gbps: 2765.0,
            vmem_bytes: 64 * 1024 * 1024,
            dma_engines: 2,
            ici_link_gbps: 200.0,
            ici_hop_latency_us: 0.75,
            ici_topology: TopologyKind::Torus,
            dispatch_overhead_us: 2.0,
        }
    }

    /// A generic TPU-v1-style 256x256 systolic part: big array, slow
    /// clock, modest memory system. The "what if" scenario preset.
    pub fn generic_256x256() -> DeviceSpec {
        DeviceSpec {
            name: "generic-256x256".to_string(),
            description: "generic 256x256 systolic array @ 700 MHz, 600 GB/s HBM".to_string(),
            array_rows: 256,
            array_cols: 256,
            dataflow: Dataflow::WeightStationary,
            ifmap_sram_kb: 8 * 1024,
            filter_sram_kb: 8 * 1024,
            ofmap_sram_kb: 8 * 1024,
            ifmap_dram_bw: 128.0,
            filter_dram_bw: 128.0,
            ofmap_dram_bw: 64.0,
            word_bytes: 2,
            clock_mhz: 700.0,
            vpu_elems_per_cycle: 256.0,
            hbm_gbps: 600.0,
            vmem_bytes: 24 * 1024 * 1024,
            dma_engines: 1,
            ici_link_gbps: 25.0,
            ici_hop_latency_us: 2.0,
            ici_topology: TopologyKind::Ring,
            dispatch_overhead_us: 3.0,
        }
    }

    /// Look up a built-in preset by name.
    pub fn preset(name: &str) -> Option<DeviceSpec> {
        match name {
            "tpu-v4" => Some(DeviceSpec::tpu_v4()),
            "tpu-v5e" => Some(DeviceSpec::tpu_v5e()),
            "tpu-v5p" => Some(DeviceSpec::tpu_v5p()),
            "generic-256x256" => Some(DeviceSpec::generic_256x256()),
            _ => None,
        }
    }

    /// Every built-in preset, in [`PRESET_NAMES`] order.
    pub fn presets() -> Vec<DeviceSpec> {
        PRESET_NAMES
            .iter()
            .map(|n| DeviceSpec::preset(n).expect("registered preset"))
            .collect()
    }

    /// HBM bandwidth in the memory timeline's unit, bytes/µs.
    pub fn hbm_bytes_per_us(&self) -> f64 {
        self.hbm_gbps * 1e3
    }

    /// Core clock in GHz (`clock_mhz / 1e3`; exact for the presets).
    pub fn clock_ghz(&self) -> f64 {
        self.clock_mhz / 1e3
    }

    /// Reject non-positive / non-finite parameters before they poison a
    /// simulation (a zero bandwidth would make DMA costs infinite).
    pub fn validate(&self) -> Result<()> {
        if self.name.is_empty() {
            bail!("device needs a name");
        }
        if self.array_rows == 0 || self.array_cols == 0 {
            bail!("device '{}': array dims must be positive", self.name);
        }
        if self.ifmap_sram_kb == 0 || self.filter_sram_kb == 0 || self.ofmap_sram_kb == 0 {
            bail!("device '{}': SRAM sizes must be positive", self.name);
        }
        if self.word_bytes == 0 {
            bail!("device '{}': word_bytes must be positive", self.name);
        }
        for (what, v) in [
            ("ifmap_dram_bw", self.ifmap_dram_bw),
            ("filter_dram_bw", self.filter_dram_bw),
            ("ofmap_dram_bw", self.ofmap_dram_bw),
            ("clock_mhz", self.clock_mhz),
            ("vpu_elems_per_cycle", self.vpu_elems_per_cycle),
            ("hbm_gbps", self.hbm_gbps),
            ("ici_link_gbps", self.ici_link_gbps),
        ] {
            if !(v.is_finite() && v > 0.0) {
                bail!("device '{}': {what} must be positive, got {v}", self.name);
            }
        }
        if !(self.ici_hop_latency_us.is_finite() && self.ici_hop_latency_us >= 0.0) {
            bail!(
                "device '{}': ici_hop_latency_us must be non-negative",
                self.name
            );
        }
        if !(self.dispatch_overhead_us.is_finite() && self.dispatch_overhead_us > 0.0) {
            bail!(
                "device '{}': dispatch_overhead_us must be positive",
                self.name
            );
        }
        Ok(())
    }

    /// Derive the SCALE-Sim architecture config (the systolic-simulation
    /// input). Bit-identical to [`ScaleConfig::tpu_v4`] for the
    /// reference preset.
    pub fn scale_config(&self) -> ScaleConfig {
        ScaleConfig {
            name: format!("{}_mxu", self.name.replace('-', "_")),
            array_rows: self.array_rows,
            array_cols: self.array_cols,
            ifmap_sram_kb: self.ifmap_sram_kb,
            filter_sram_kb: self.filter_sram_kb,
            ofmap_sram_kb: self.ofmap_sram_kb,
            dataflow: self.dataflow,
            ifmap_dram_bw: self.ifmap_dram_bw,
            filter_dram_bw: self.filter_dram_bw,
            ofmap_dram_bw: self.ofmap_dram_bw,
            word_bytes: self.word_bytes,
            freq_mhz: self.clock_mhz,
        }
    }

    /// Derive the memory timeline's bandwidth + residency-buffer config.
    /// Bit-identical to [`MemoryConfig::tpu_v4`] for the reference
    /// preset.
    pub fn memory_config(&self) -> MemoryConfig {
        MemoryConfig::new(self.hbm_bytes_per_us(), Some(self.vmem_bytes))
    }

    /// The concrete ICI wiring for a slice of `chips` chips under this
    /// device's default topology kind.
    pub fn default_topology(&self, chips: usize) -> IciTopology {
        match self.ici_topology {
            TopologyKind::Ring => IciTopology::Ring,
            TopologyKind::Torus => IciTopology::torus(chips),
        }
    }

    /// Derive a validated slice config for `chips` chips, wiring them
    /// with `topology` (or this device's default when `None`).
    pub fn slice_config(
        &self,
        chips: usize,
        topology: Option<IciTopology>,
    ) -> Result<SliceConfig> {
        let slice = SliceConfig {
            chips,
            topology: topology.unwrap_or_else(|| self.default_topology(chips)),
            link_gbps: self.ici_link_gbps,
            hop_latency_us: self.ici_hop_latency_us,
        };
        slice.validate()?;
        Ok(slice)
    }

    /// Derive the synthetic device model's GEMM-path constants.
    /// Field-identical to [`MxuParams::default`] for the reference
    /// preset.
    pub fn mxu_params(&self) -> MxuParams {
        MxuParams {
            clock_ghz: self.clock_ghz(),
            array: self.array_rows,
            dispatch_overhead_us: self.dispatch_overhead_us,
            hbm_bytes_per_us: self.hbm_bytes_per_us(),
            bytes_per_elem: self.word_bytes as f64,
            ..MxuParams::default()
        }
    }

    /// Derive the synthetic device model's elementwise-path constants.
    /// Field-identical to [`VpuParams::default`] for the reference
    /// preset.
    pub fn vpu_params(&self) -> VpuParams {
        VpuParams {
            clock_ghz: self.clock_ghz(),
            hbm_bytes_per_us: self.hbm_bytes_per_us(),
            max_elems_per_cycle: self.vpu_elems_per_cycle,
            bytes_per_elem: self.word_bytes as f64,
            ..VpuParams::default()
        }
    }

    /// Transfer a cycle→time calibration fitted on device `from` onto
    /// this device: the slope scales with the clock ratio (same cycles,
    /// different cycle time) and the intercept with the dispatch-
    /// overhead ratio. When both ratios are exactly 1 the input is
    /// returned unchanged, so retargeting a spec onto itself is
    /// bit-identical.
    pub fn transfer_calibration(
        &self,
        from: &DeviceSpec,
        base: &RegimeCalibration,
    ) -> RegimeCalibration {
        let slope_scale = from.clock_mhz / self.clock_mhz;
        let intercept_scale = self.dispatch_overhead_us / from.dispatch_overhead_us;
        if slope_scale == 1.0 && intercept_scale == 1.0 {
            return base.clone();
        }
        let scale = |f: &LinearFit| LinearFit {
            alpha: f.alpha * slope_scale,
            beta: f.beta * intercept_scale,
        };
        RegimeCalibration {
            small: scale(&base.small),
            medium: scale(&base.medium),
            large: scale(&base.large),
            metrics: base.metrics.clone(),
        }
    }

    /// Latency multiplier for learned elementwise models trained on
    /// device `from`: elementwise kernels are roofline-limited by the
    /// slower of the vector unit and HBM, so the transfer takes the
    /// larger of the two rate ratios. Exactly 1 when `from` is this
    /// device.
    pub fn ew_scale(&self, from: &DeviceSpec) -> f64 {
        let hbm = from.hbm_gbps / self.hbm_gbps;
        let vpu = (from.vpu_elems_per_cycle * from.clock_mhz)
            / (self.vpu_elems_per_cycle * self.clock_mhz);
        hbm.max(vpu)
    }

    /// A stable 64-bit identity of every *numeric* parameter (name and
    /// description excluded: two specs with identical hardware cost the
    /// same and may share cache entries). The basis of the estimator's
    /// cache fingerprint — every
    /// [`ShapeKey`](crate::coordinator::ShapeKey) carries it (mixed
    /// with the active config), so estimators for different devices can
    /// share one cache without aliasing.
    pub fn fingerprint(&self) -> u64 {
        let mut h: u64 = 0xcbf2_9ce4_8422_2325;
        let mut put = |v: u64| {
            for b in v.to_le_bytes() {
                h = (h ^ b as u64).wrapping_mul(0x0000_0100_0000_01b3);
            }
        };
        put(self.array_rows as u64);
        put(self.array_cols as u64);
        put(match self.dataflow {
            Dataflow::OutputStationary => 0,
            Dataflow::WeightStationary => 1,
            Dataflow::InputStationary => 2,
        });
        put(self.ifmap_sram_kb as u64);
        put(self.filter_sram_kb as u64);
        put(self.ofmap_sram_kb as u64);
        put(self.ifmap_dram_bw.to_bits());
        put(self.filter_dram_bw.to_bits());
        put(self.ofmap_dram_bw.to_bits());
        put(self.word_bytes as u64);
        put(self.clock_mhz.to_bits());
        put(self.vpu_elems_per_cycle.to_bits());
        put(self.hbm_gbps.to_bits());
        put(self.vmem_bytes);
        put(self.dma_engines as u64);
        put(self.ici_link_gbps.to_bits());
        put(self.ici_hop_latency_us.to_bits());
        put(match self.ici_topology {
            TopologyKind::Ring => 0,
            TopologyKind::Torus => 1,
        });
        put(self.dispatch_overhead_us.to_bits());
        h
    }

    /// Serialize the full spec (device files, `--json` payloads).
    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("name", Json::Str(self.name.clone()))
            .set("description", Json::Str(self.description.clone()))
            .set("array_rows", Json::Num(self.array_rows as f64))
            .set("array_cols", Json::Num(self.array_cols as f64))
            .set("dataflow", Json::Str(self.dataflow.short().to_lowercase()))
            .set("ifmap_sram_kb", Json::Num(self.ifmap_sram_kb as f64))
            .set("filter_sram_kb", Json::Num(self.filter_sram_kb as f64))
            .set("ofmap_sram_kb", Json::Num(self.ofmap_sram_kb as f64))
            .set("ifmap_dram_bw", Json::Num(self.ifmap_dram_bw))
            .set("filter_dram_bw", Json::Num(self.filter_dram_bw))
            .set("ofmap_dram_bw", Json::Num(self.ofmap_dram_bw))
            .set("word_bytes", Json::Num(self.word_bytes as f64))
            .set("clock_mhz", Json::Num(self.clock_mhz))
            .set("vpu_elems_per_cycle", Json::Num(self.vpu_elems_per_cycle))
            .set("hbm_gbps", Json::Num(self.hbm_gbps))
            .set("vmem_bytes", Json::Num(self.vmem_bytes as f64))
            .set("dma_engines", Json::Num(self.dma_engines as f64))
            .set("ici_link_gbps", Json::Num(self.ici_link_gbps))
            .set("ici_hop_latency_us", Json::Num(self.ici_hop_latency_us))
            .set("ici_topology", Json::Str(self.ici_topology.name().to_string()))
            .set("dispatch_overhead_us", Json::Num(self.dispatch_overhead_us));
        o
    }

    /// Deserialize a spec from the flat JSON schema [`Self::to_json`]
    /// emits. Only `name` is required; every other key defaults to the
    /// [`DeviceSpec::tpu_v4`] reference value, mirroring the TOML loader.
    pub fn from_json(j: &Json) -> Result<DeviceSpec, JsonError> {
        let mut spec = DeviceSpec::tpu_v4();
        spec.name = j.req_str("name")?.to_string();
        spec.description = match j.get("description") {
            Some(v) => v
                .as_str()
                .ok_or_else(|| JsonError::new("description must be a string"))?
                .to_string(),
            None => String::new(),
        };
        let f64_or = |key: &str, default: f64| -> Result<f64, JsonError> {
            match j.get(key) {
                Some(v) => v
                    .as_f64()
                    .ok_or_else(|| JsonError::new(format!("{key} must be a number"))),
                None => Ok(default),
            }
        };
        let usize_or = |key: &str, default: usize| -> Result<usize, JsonError> {
            match j.get(key) {
                Some(v) => v
                    .as_usize()
                    .ok_or_else(|| JsonError::new(format!("{key} must be an integer"))),
                None => Ok(default),
            }
        };
        spec.array_rows = usize_or("array_rows", spec.array_rows)?;
        spec.array_cols = usize_or("array_cols", spec.array_cols)?;
        if let Some(v) = j.get("dataflow") {
            let s = v
                .as_str()
                .ok_or_else(|| JsonError::new("dataflow must be a string"))?;
            spec.dataflow =
                Dataflow::parse(s).ok_or_else(|| JsonError::new("bad dataflow (os|ws|is)"))?;
        }
        spec.ifmap_sram_kb = usize_or("ifmap_sram_kb", spec.ifmap_sram_kb)?;
        spec.filter_sram_kb = usize_or("filter_sram_kb", spec.filter_sram_kb)?;
        spec.ofmap_sram_kb = usize_or("ofmap_sram_kb", spec.ofmap_sram_kb)?;
        spec.ifmap_dram_bw = f64_or("ifmap_dram_bw", spec.ifmap_dram_bw)?;
        spec.filter_dram_bw = f64_or("filter_dram_bw", spec.filter_dram_bw)?;
        spec.ofmap_dram_bw = f64_or("ofmap_dram_bw", spec.ofmap_dram_bw)?;
        spec.word_bytes = usize_or("word_bytes", spec.word_bytes)?;
        spec.clock_mhz = f64_or("clock_mhz", spec.clock_mhz)?;
        spec.vpu_elems_per_cycle = f64_or("vpu_elems_per_cycle", spec.vpu_elems_per_cycle)?;
        spec.hbm_gbps = f64_or("hbm_gbps", spec.hbm_gbps)?;
        let vmem = f64_or("vmem_bytes", spec.vmem_bytes as f64)?;
        if !(vmem.is_finite() && vmem >= 0.0) {
            return Err(JsonError::new("vmem_bytes must be non-negative"));
        }
        spec.vmem_bytes = vmem as u64;
        spec.dma_engines = usize_or("dma_engines", spec.dma_engines)?;
        spec.ici_link_gbps = f64_or("ici_link_gbps", spec.ici_link_gbps)?;
        spec.ici_hop_latency_us = f64_or("ici_hop_latency_us", spec.ici_hop_latency_us)?;
        if let Some(v) = j.get("ici_topology") {
            let s = v
                .as_str()
                .ok_or_else(|| JsonError::new("ici_topology must be a string"))?;
            spec.ici_topology = TopologyKind::parse(s)
                .ok_or_else(|| JsonError::new("bad ici_topology (ring|torus)"))?;
        }
        spec.dispatch_overhead_us = f64_or("dispatch_overhead_us", spec.dispatch_overhead_us)?;
        Ok(spec)
    }
}

impl std::fmt::Display for DeviceSpec {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{}: {}x{} {} @ {:.0} MHz, {:.0} GB/s HBM, {:.0} MiB VMEM, ICI {:.0} GB/s/link ({})",
            self.name,
            self.array_rows,
            self.array_cols,
            self.dataflow,
            self.clock_mhz,
            self.hbm_gbps,
            self.vmem_bytes as f64 / (1024.0 * 1024.0),
            self.ici_link_gbps,
            self.ici_topology,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reference_preset_reproduces_hardcoded_configs() {
        let v4 = DeviceSpec::tpu_v4();
        assert_eq!(v4.scale_config(), ScaleConfig::tpu_v4());
        assert_eq!(v4.memory_config(), MemoryConfig::tpu_v4());
        assert_eq!(v4.mxu_params(), MxuParams::default());
        assert_eq!(v4.vpu_params(), VpuParams::default());
        let slice = v4.slice_config(4, None).unwrap();
        assert_eq!(slice, SliceConfig::ring(4, 100.0));
        // The derived clocks are exact, not merely close.
        assert_eq!(v4.clock_ghz().to_bits(), 0.94f64.to_bits());
        assert_eq!(v4.hbm_bytes_per_us().to_bits(), 1.2e6f64.to_bits());
    }

    #[test]
    fn presets_are_registered_valid_and_distinct() {
        let specs = DeviceSpec::presets();
        assert_eq!(specs.len(), PRESET_NAMES.len());
        let mut fps = Vec::new();
        for s in &specs {
            s.validate().unwrap();
            assert!(DeviceSpec::preset(&s.name).is_some());
            fps.push(s.fingerprint());
        }
        fps.sort_unstable();
        fps.dedup();
        assert_eq!(fps.len(), specs.len(), "fingerprint collision");
        assert!(DeviceSpec::preset("tpu-v9").is_none());
    }

    #[test]
    fn fingerprint_ignores_name_but_not_hardware() {
        let a = DeviceSpec::tpu_v4();
        let mut b = a.clone();
        b.name = "renamed".into();
        b.description = "other".into();
        assert_eq!(a.fingerprint(), b.fingerprint());
        b.hbm_gbps = 1201.0;
        assert_ne!(a.fingerprint(), b.fingerprint());
    }

    #[test]
    fn self_transfer_is_identity() {
        let v4 = DeviceSpec::tpu_v4();
        let cal = RegimeCalibration {
            small: LinearFit { alpha: 1e-3, beta: 2.0 },
            medium: LinearFit { alpha: 2e-3, beta: 1.0 },
            large: LinearFit { alpha: 3e-3, beta: 0.5 },
            metrics: Vec::new(),
        };
        let out = v4.transfer_calibration(&v4, &cal);
        assert_eq!(out.small, cal.small);
        assert_eq!(out.large, cal.large);
        assert_eq!(v4.ew_scale(&v4).to_bits(), 1.0f64.to_bits());
    }

    #[test]
    fn transfer_scales_with_clock_and_overhead() {
        let v4 = DeviceSpec::tpu_v4();
        let mut fast = v4.clone();
        fast.clock_mhz = 1880.0; // 2x clock
        fast.dispatch_overhead_us = 1.0; // half the overhead
        let cal = RegimeCalibration {
            small: LinearFit { alpha: 1.0, beta: 2.0 },
            medium: LinearFit { alpha: 1.0, beta: 2.0 },
            large: LinearFit { alpha: 1.0, beta: 2.0 },
            metrics: Vec::new(),
        };
        let out = fast.transfer_calibration(&v4, &cal);
        assert!((out.small.alpha - 0.5).abs() < 1e-12);
        assert!((out.small.beta - 1.0).abs() < 1e-12);
        // A device slower on both axes scales elementwise latency up.
        let v5e = DeviceSpec::tpu_v5e();
        assert!(v5e.ew_scale(&v4) > 1.0);
        // A device faster on both axes scales it down.
        let v5p = DeviceSpec::tpu_v5p();
        assert!(v5p.ew_scale(&v4) < 1.0);
    }

    #[test]
    fn json_roundtrip_and_defaults() {
        for spec in DeviceSpec::presets() {
            let back = DeviceSpec::from_json(&spec.to_json()).unwrap();
            assert_eq!(spec, back);
            assert_eq!(spec.fingerprint(), back.fingerprint());
        }
        // Partial JSON inherits the reference values.
        let j = Json::parse(r#"{"name":"mini","hbm_gbps":600}"#).unwrap();
        let spec = DeviceSpec::from_json(&j).unwrap();
        assert_eq!(spec.name, "mini");
        assert_eq!(spec.hbm_gbps, 600.0);
        assert_eq!(spec.array_rows, 128);
        assert!(DeviceSpec::from_json(&Json::parse("{}").unwrap()).is_err());
    }

    #[test]
    fn validate_rejects_nonsense() {
        let mut bad = DeviceSpec::tpu_v4();
        bad.hbm_gbps = 0.0;
        assert!(bad.validate().is_err());
        let mut bad = DeviceSpec::tpu_v4();
        bad.array_rows = 0;
        assert!(bad.validate().is_err());
        let mut bad = DeviceSpec::tpu_v4();
        bad.clock_mhz = f64::NAN;
        assert!(bad.validate().is_err());
        let mut bad = DeviceSpec::tpu_v4();
        bad.ici_hop_latency_us = -1.0;
        assert!(bad.validate().is_err());
    }

    #[test]
    fn torus_default_topology_factors_by_chip_count() {
        let v5e = DeviceSpec::tpu_v5e();
        assert_eq!(
            v5e.default_topology(16),
            IciTopology::Torus2D { x: 4, y: 4 }
        );
        let slice = v5e.slice_config(8, None).unwrap();
        assert_eq!(slice.topology, IciTopology::Torus2D { x: 2, y: 4 });
        assert_eq!(slice.link_gbps, 50.0);
        // An explicit topology overrides the device default.
        let ring = v5e.slice_config(8, Some(IciTopology::Ring)).unwrap();
        assert_eq!(ring.topology, IciTopology::Ring);
    }
}
