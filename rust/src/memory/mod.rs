//! Memory-aware DMA timeline: HBM traffic modeling with tensor
//! residency.
//!
//! The dependence-graph scheduler ([`crate::graph`]) overlaps compute
//! across engines but only places *explicit* data-movement ops on the
//! DMA engine; the HBM bytes behind every GEMM and elementwise op are
//! invisible to it. This subsystem makes that traffic first-class:
//!
//! * [`residency`] — the bounded on-chip tensor buffer with LRU
//!   eviction ([`ResidencyTracker`]): values consumed by their SSA
//!   successors while still resident skip the re-fetch;
//! * [`timeline`] — the DMA expansion ([`DmaTimeline`]): every op grows
//!   DMA-in / compute / DMA-out sub-nodes, cold operands pay
//!   `bytes / hbm_bytes_per_us` on the DMA engine, and the expanded
//!   node list goes through the *existing* list scheduler. The result
//!   ([`MemorySchedule`]) carries per-op traffic rows, residency stats
//!   and a compute-vs-bandwidth roofline
//!   ([`crate::graph::RooflineSummary`]).
//!
//! Exact invariants (property-tested in `tests/memory_model.rs` over
//! random DAGs and every checked-in `.mlir` fixture):
//!
//! * compute-only makespan `<=` memory-aware makespan `<=`
//!   compute + total cold traffic serialized
//!   ([`MemorySchedule::serialized_bound_us`]);
//! * [`MemoryConfig::infinite`] reproduces the compute-only schedule
//!   bit for bit;
//! * a zero-byte buffer never hits, and no buffer out-hits the
//!   unbounded one.

pub mod residency;
pub mod timeline;

pub use residency::{Evicted, InsertOutcome, ResidencyStats, ResidencyTracker};
pub use timeline::{
    schedule_estimate_memory, schedule_module_memory, DmaTimeline, FetchDma, MemoryConfig,
    MemorySchedule, MemoryStats, OpMemory, RetireDma, TimelineOpShape, TimelineShape, ValueShape,
};
