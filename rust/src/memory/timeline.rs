//! The memory-aware DMA timeline: expand every scheduled op into
//! DMA-in / compute / DMA-out sub-nodes and place them with the
//! existing list scheduler.
//!
//! The plain scheduler (`crate::graph::schedule`) only puts *explicit*
//! data-movement ops on the DMA engine — the HBM bytes behind every
//! GEMM and elementwise op never appear on the timeline, so scheduled
//! makespans are compute-optimistic. This module closes that gap:
//!
//! * each op's *cold* operands (not resident on chip) pay
//!   `bytes / hbm_bytes_per_us` on the DMA engine before the op can
//!   start;
//! * operands that are still resident from their SSA producer skip the
//!   re-fetch entirely ([`ResidencyTracker`]: a bounded buffer with LRU
//!   eviction);
//! * results enter the buffer dirty; evictions and spills pay the
//!   write-back, and `return` escapes its operands to HBM.
//!
//! Exact invariants (property-tested in `tests/memory_model.rs`; they
//! follow from the monotonicity of `max`/`+` on non-negative floats, so
//! they hold bit-for-bit, not within an epsilon):
//!
//! * compute-only makespan `<=` memory-aware makespan `<=`
//!   [`MemorySchedule::serialized_bound_us`] (every compute *and* cold
//!   transfer run back to back);
//! * [`MemoryConfig::infinite`] (unbounded buffer, infinite bandwidth)
//!   reproduces the compute-only schedule **bit-identically** — all DMA
//!   sub-nodes collapse to zero-width nodes that occupy no engine;
//! * residency hits are bounded by the unbounded-buffer hit count, and
//!   a zero-byte buffer can never hit.

use std::collections::HashMap;

use crate::coordinator::estimator::{Estimator, ModelEstimate};
use crate::frontend::classify::classify;
use crate::frontend::opinfo::{FuncInfo, ModuleInfo, OpInfo};
use crate::frontend::types::TensorType;
use crate::graph::analysis::{finish_schedule, op_bound, ModuleSchedule, RooflineSummary};
use crate::graph::schedule::is_inlined_call;
use crate::graph::{DepGraph, Engine, EngineConfig, SchedNode};
use crate::obs::TraceEvent;
use crate::tpu::MxuParams;
use crate::util::json::Json;

use super::residency::ResidencyTracker;

/// HBM bandwidth and on-chip buffer budget for the DMA timeline.
///
/// ```
/// use scalesim_tpu::memory::MemoryConfig;
///
/// let m = MemoryConfig::tpu_v4();
/// // ~1 us to move 1.2 MB at the TPU-v4 model's 1.2e6 bytes/us.
/// assert!((m.transfer_us(1_200_000) - 1.0).abs() < 1e-9);
///
/// // The infinite config moves any payload in zero time: this is the
/// // configuration that reproduces the compute-only schedule exactly.
/// assert_eq!(MemoryConfig::infinite().transfer_us(u64::MAX), 0.0);
/// ```
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct MemoryConfig {
    /// HBM bandwidth in bytes per microsecond (`f64::INFINITY` makes
    /// every transfer free).
    pub hbm_bytes_per_us: f64,
    /// On-chip residency buffer in bytes; `None` is unbounded.
    pub buffer_bytes: Option<u64>,
}

impl MemoryConfig {
    /// Default residency buffer: 32 MiB (TPU-v4-class VMEM).
    pub const DEFAULT_BUFFER_BYTES: u64 = 32 * 1024 * 1024;

    /// A config from explicit bandwidth and buffer size.
    pub fn new(hbm_bytes_per_us: f64, buffer_bytes: Option<u64>) -> MemoryConfig {
        MemoryConfig {
            hbm_bytes_per_us,
            buffer_bytes,
        }
    }

    /// The TPU-v4 device-model constants: the same HBM bandwidth the
    /// synthetic device's roofline uses
    /// ([`MxuParams::hbm_bytes_per_us`]) and the default 32 MiB buffer.
    /// Equal to `MemoryConfig::for_device(&DeviceSpec::tpu_v4())`
    /// (tested in `tests/device_spec.rs`).
    pub fn tpu_v4() -> MemoryConfig {
        MemoryConfig::new(
            MxuParams::default().hbm_bytes_per_us,
            Some(Self::DEFAULT_BUFFER_BYTES),
        )
    }

    /// Derive the bandwidth + residency-buffer config from a device
    /// spec (delegates to
    /// [`DeviceSpec::memory_config`](crate::device::DeviceSpec::memory_config)).
    pub fn for_device(spec: &crate::device::DeviceSpec) -> MemoryConfig {
        spec.memory_config()
    }

    /// The default buffer with a caller-supplied bandwidth (used by the
    /// service so the timeline shares the estimator's HBM constant).
    pub fn for_bandwidth(hbm_bytes_per_us: f64) -> MemoryConfig {
        MemoryConfig::new(hbm_bytes_per_us, Some(Self::DEFAULT_BUFFER_BYTES))
    }

    /// Unbounded buffer and infinite bandwidth: every DMA sub-node is
    /// zero-width, so the schedule is bit-identical to the compute-only
    /// one (tested).
    pub fn infinite() -> MemoryConfig {
        MemoryConfig::new(f64::INFINITY, None)
    }

    /// Time to move `bytes` over HBM, µs. Pure `bytes / bandwidth` — no
    /// fixed overhead, so infinite bandwidth is exactly zero cost.
    pub fn transfer_us(&self, bytes: u64) -> f64 {
        bytes as f64 / self.hbm_bytes_per_us
    }
}

/// Aggregate traffic/residency counters for one timeline build.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct MemoryStats {
    /// Operand accesses answered from the residency buffer.
    pub hits: usize,
    /// Operand accesses that paid an HBM fetch.
    pub cold_fetches: usize,
    /// Bytes fetched cold from HBM.
    pub cold_bytes: u64,
    /// Write-backs to HBM (dirty evictions, spills, escapes).
    pub writebacks: usize,
    /// Bytes written back to HBM.
    pub writeback_bytes: u64,
    /// Values evicted from the residency buffer.
    pub evictions: usize,
    /// High-water mark of resident bytes.
    pub peak_resident_bytes: u64,
}

/// Per-value bookkeeping inside [`DmaTimeline`].
#[derive(Debug, Clone)]
struct ValueState {
    /// Byte footprint (per chip).
    bytes: u64,
    /// Remaining consumers (drops to zero at the last use).
    uses: usize,
    /// Node after which the value is available on chip.
    chip_node: Option<usize>,
    /// Node after which HBM holds the value (`None` for function
    /// arguments, which live in HBM from the start).
    hbm_node: Option<usize>,
    /// On-chip copy is newer than HBM.
    dirty: bool,
}

/// Inbound-DMA expansion of one op.
#[derive(Debug, Clone, Default)]
pub struct FetchDma {
    /// The fetch node pushed for this op, if it moved any bytes.
    pub node: Option<usize>,
    /// Producer nodes of operands that were resident (extra compute
    /// dependences: data must be on chip before the op reads it).
    pub hit_preds: Vec<usize>,
    /// Time of the fetch node, µs (cold fetches plus any eviction
    /// write-backs they forced).
    pub dma_us: f64,
    /// Bytes fetched cold.
    pub cold_bytes: u64,
    /// Write-back bytes folded into this fetch (dirty evictions).
    pub writeback_bytes: u64,
    /// Operand accesses that missed.
    pub cold_fetches: usize,
    /// Operand accesses answered on chip.
    pub hits: usize,
}

/// Outbound-DMA expansion of one op.
#[derive(Debug, Clone, Default)]
pub struct RetireDma {
    /// The write-back node pushed for this op, if it moved any bytes.
    pub node: Option<usize>,
    /// Time of the write-back node, µs.
    pub dma_us: f64,
    /// Bytes written back (spills, dirty evictions, escapes).
    pub bytes: u64,
}

/// The shared DMA-expansion engine: walks a function in program order,
/// tracks tensor residency, and pushes DMA sub-nodes onto a scheduler
/// node list. [`schedule_estimate_memory`] drives it for single-chip
/// schedules; the distributed slice walker threads it through each
/// per-chip timeline.
#[derive(Debug)]
pub struct DmaTimeline {
    config: MemoryConfig,
    tracker: ResidencyTracker,
    values: HashMap<String, ValueState>,
    stats: MemoryStats,
}

fn dedup_operands(op: &OpInfo) -> Vec<String> {
    let mut v: Vec<String> = Vec::new();
    for o in &op.operands {
        if !v.iter().any(|x| x == o) {
            v.push(o.clone());
        }
    }
    v
}

/// Append a pred id unless already present (shared with the distributed
/// walker so the dedup rule cannot drift).
pub(crate) fn push_unique(v: &mut Vec<usize>, n: usize) {
    if !v.contains(&n) {
        v.push(n);
    }
}

/// Per-chip shard of a tensor footprint (leading-axis SPMD split).
fn shard_bytes(bytes: u64, chips: usize) -> u64 {
    if chips <= 1 {
        bytes
    } else {
        bytes.div_ceil(chips as u64)
    }
}

/// A borrowed, pre-deduplicated view of one op — exactly the data the
/// DMA expansion reads. The public [`DmaTimeline::fetch`] /
/// [`DmaTimeline::retire`] build one from an [`OpInfo`] on the fly; the
/// captured [`TimelineShape`] stores the same data once, so the
/// price-many replay drives the *identical* walk without re-deriving
/// it. Both paths run the same `*_view` bodies, which is what makes the
/// replay bit-identical by construction rather than by coincidence.
struct OpView<'a> {
    /// Index of the source op within its function.
    index: usize,
    /// Display name of the op.
    op_name: &'a str,
    /// True for the function's `return` op.
    is_return: bool,
    /// Operands, deduplicated in first-occurrence order.
    operands: &'a [String],
    /// Result SSA ids.
    results: &'a [String],
}

impl DmaTimeline {
    /// Prime a timeline over `func`: registers every SSA value's byte
    /// footprint (divided across `chips` for SPMD slices) and consumer
    /// count, so dead values free their buffer space at their last use.
    pub fn new(config: MemoryConfig, func: &FuncInfo, chips: usize) -> DmaTimeline {
        let mut values: HashMap<String, ValueState> = HashMap::new();
        for op in &func.ops {
            for (k, r) in op.results.iter().enumerate() {
                let bytes = op.result_types.get(k).map(|t| t.size_bytes()).unwrap_or(0);
                values.insert(
                    r.clone(),
                    ValueState {
                        bytes: shard_bytes(bytes, chips),
                        uses: 0,
                        chip_node: None,
                        hbm_node: None,
                        dirty: false,
                    },
                );
            }
        }
        for op in &func.ops {
            let mut seen: Vec<&str> = Vec::new();
            for (k, operand) in op.operands.iter().enumerate() {
                if seen.contains(&operand.as_str()) {
                    continue;
                }
                seen.push(operand.as_str());
                let state = values.entry(operand.clone()).or_insert_with(|| {
                    // Unknown producer: a function argument living in HBM.
                    let bytes = op
                        .operand_types
                        .get(k)
                        .or_else(|| op.operand_types.first())
                        .map(|t| t.size_bytes())
                        .unwrap_or(0);
                    ValueState {
                        bytes: shard_bytes(bytes, chips),
                        uses: 0,
                        chip_node: None,
                        hbm_node: None,
                        dirty: false,
                    }
                });
                state.uses += 1;
            }
        }
        DmaTimeline::from_values(config, values)
    }

    /// A timeline over a pre-registered value map — the price-many
    /// replay path: [`TimelineShape`] captures the registration walk
    /// once, and the caller re-derives only the per-value byte
    /// footprints for each re-cost.
    fn from_values(
        config: MemoryConfig,
        values: HashMap<String, ValueState>,
    ) -> DmaTimeline {
        DmaTimeline {
            config,
            tracker: ResidencyTracker::new(config.buffer_bytes),
            values,
            stats: MemoryStats::default(),
        }
    }

    /// Expand the inbound side of `op` (call in program order, before
    /// pushing the op's compute node): cold operands pay an HBM fetch on
    /// the DMA engine, resident operands only contribute a dependence.
    /// At most one node is pushed; it is zero-width (no engine) when the
    /// transfer is free.
    pub fn fetch(&mut self, op: &OpInfo, nodes: &mut Vec<SchedNode>) -> FetchDma {
        let operands = dedup_operands(op);
        self.fetch_view(
            &OpView {
                index: op.index,
                op_name: &op.op_name,
                is_return: op.short_name() == "return",
                operands: &operands,
                results: &op.results,
            },
            nodes,
        )
    }

    /// [`DmaTimeline::fetch`] over a pre-built view (shared with the
    /// price-many replay).
    fn fetch_view(&mut self, op: &OpView<'_>, nodes: &mut Vec<SchedNode>) -> FetchDma {
        let mut out = FetchDma::default();
        let operands = op.operands;
        let mut fetch_preds: Vec<usize> = Vec::new();
        let mut cold_ids: Vec<String> = Vec::new();
        let mut written_back: Vec<String> = Vec::new();

        for id in operands {
            let Some((bytes, chip_node, hbm_node)) = self
                .values
                .get(id.as_str())
                .map(|v| (v.bytes, v.chip_node, v.hbm_node))
            else {
                continue;
            };
            if bytes == 0 {
                continue;
            }
            if self.tracker.access(id) {
                out.hits += 1;
                self.stats.hits += 1;
                if let Some(n) = chip_node {
                    push_unique(&mut out.hit_preds, n);
                }
            } else {
                out.cold_fetches += 1;
                out.cold_bytes += bytes;
                self.stats.cold_fetches += 1;
                self.stats.cold_bytes += bytes;
                if let Some(h) = hbm_node {
                    push_unique(&mut fetch_preds, h);
                }
                let outcome = self.tracker.insert(id, bytes, false, operands);
                if outcome.inserted {
                    cold_ids.push(id.clone());
                }
                for ev in outcome.evicted {
                    let Some(st) = self.values.get_mut(&ev.id) else {
                        continue;
                    };
                    if ev.dirty {
                        out.writeback_bytes += ev.bytes;
                        self.stats.writebacks += 1;
                        self.stats.writeback_bytes += ev.bytes;
                        if let Some(c) = st.chip_node {
                            push_unique(&mut fetch_preds, c);
                        }
                        st.dirty = false;
                        written_back.push(ev.id);
                    }
                }
            }
        }

        let total_bytes = out.cold_bytes + out.writeback_bytes;
        if total_bytes > 0 {
            let cost = self.config.transfer_us(total_bytes);
            let node_id = nodes.len();
            nodes.push(SchedNode {
                index: op.index,
                op_name: format!("{}.dma_in", op.op_name),
                engine: if cost > 0.0 { Some(Engine::Dma) } else { None },
                cost_us: cost,
                preds: fetch_preds,
                source: "dma",
                note: format!(
                    "fetch {} B ({} cold / {} resident)",
                    out.cold_bytes, out.cold_fetches, out.hits
                ),
            });
            for id in &cold_ids {
                if let Some(v) = self.values.get_mut(id.as_str()) {
                    v.chip_node = Some(node_id);
                }
            }
            for id in &written_back {
                if let Some(v) = self.values.get_mut(id.as_str()) {
                    v.hbm_node = Some(node_id);
                }
            }
            out.dma_us = cost;
            out.node = Some(node_id);
        }
        out
    }

    /// Expand the outbound side of `op` after its availability node
    /// `avail` was pushed: results enter the buffer dirty, spills and
    /// dirty evictions pay a write-back, dead operands free their space,
    /// and `return` escapes its resident operands to HBM.
    pub fn retire(&mut self, op: &OpInfo, avail: usize, nodes: &mut Vec<SchedNode>) -> RetireDma {
        let operands = dedup_operands(op);
        self.retire_view(
            &OpView {
                index: op.index,
                op_name: &op.op_name,
                is_return: op.short_name() == "return",
                operands: &operands,
                results: &op.results,
            },
            avail,
            nodes,
        )
    }

    /// [`DmaTimeline::retire`] over a pre-built view (shared with the
    /// price-many replay).
    fn retire_view(
        &mut self,
        op: &OpView<'_>,
        avail: usize,
        nodes: &mut Vec<SchedNode>,
    ) -> RetireDma {
        let mut out = RetireDma::default();
        let operands = op.operands;
        let mut preds: Vec<usize> = vec![avail];
        let mut bytes: u64 = 0;
        let mut hbm_updates: Vec<String> = Vec::new();

        // `return` escapes its operands: dirty resident results must
        // land in HBM. Non-resident operands were already written back.
        if op.is_return {
            for id in operands {
                let Some((vbytes, dirty, chip_node)) = self
                    .values
                    .get(id.as_str())
                    .map(|v| (v.bytes, v.dirty, v.chip_node))
                else {
                    continue;
                };
                if vbytes > 0 && dirty && self.tracker.contains(id) {
                    bytes += vbytes;
                    self.stats.writebacks += 1;
                    self.stats.writeback_bytes += vbytes;
                    if let Some(c) = chip_node {
                        push_unique(&mut preds, c);
                    }
                    hbm_updates.push(id.clone());
                }
            }
        }

        // Release operands: the last consumer drops a dead value on the
        // spot, freeing buffer space without a write-back.
        for id in operands {
            if let Some(v) = self.values.get_mut(id.as_str()) {
                v.uses = v.uses.saturating_sub(1);
                if v.uses == 0 {
                    self.tracker.remove(id);
                }
            }
        }

        // Results enter the buffer dirty. A result that cannot fit
        // spills straight to HBM; dirty values its insertion evicts owe
        // their write-back here too.
        for r in op.results {
            let Some((rbytes, uses)) = self.values.get(r.as_str()).map(|v| (v.bytes, v.uses))
            else {
                continue;
            };
            if rbytes == 0 || uses == 0 {
                continue; // dead or zero-footprint: never materialized
            }
            let outcome = self.tracker.insert(r, rbytes, true, op.results);
            if outcome.inserted {
                if let Some(v) = self.values.get_mut(r.as_str()) {
                    v.chip_node = Some(avail);
                    v.dirty = true;
                }
                for ev in outcome.evicted {
                    let Some(st) = self.values.get_mut(&ev.id) else {
                        continue;
                    };
                    if ev.dirty {
                        bytes += ev.bytes;
                        self.stats.writebacks += 1;
                        self.stats.writeback_bytes += ev.bytes;
                        if let Some(c) = st.chip_node {
                            push_unique(&mut preds, c);
                        }
                        st.dirty = false;
                        hbm_updates.push(ev.id);
                    }
                }
            } else {
                // Spill: stream the result straight to HBM.
                bytes += rbytes;
                self.stats.writebacks += 1;
                self.stats.writeback_bytes += rbytes;
                if let Some(v) = self.values.get_mut(r.as_str()) {
                    v.dirty = false;
                }
                hbm_updates.push(r.clone());
            }
        }

        if bytes > 0 {
            let cost = self.config.transfer_us(bytes);
            let node_id = nodes.len();
            nodes.push(SchedNode {
                index: op.index,
                op_name: format!("{}.dma_out", op.op_name),
                engine: if cost > 0.0 { Some(Engine::Dma) } else { None },
                cost_us: cost,
                preds,
                source: "dma",
                note: format!("write back {bytes} B"),
            });
            for id in &hbm_updates {
                if let Some(v) = self.values.get_mut(id.as_str()) {
                    v.hbm_node = Some(node_id);
                }
            }
            out.dma_us = cost;
            out.node = Some(node_id);
        }
        out.bytes = bytes;
        out
    }

    /// Traffic and residency counters accumulated so far.
    pub fn stats(&self) -> MemoryStats {
        let t = self.tracker.stats();
        MemoryStats {
            evictions: t.evictions,
            peak_resident_bytes: t.peak_resident_bytes,
            ..self.stats
        }
    }
}

/// One entry-function op of a captured [`TimelineShape`].
#[derive(Debug, Clone)]
pub struct TimelineOpShape {
    /// Index of the source op within its function.
    pub index: usize,
    /// Display name of the op.
    pub op_name: String,
    /// True for the `return` op (no fetch; its retire step escapes
    /// dirty results to HBM).
    pub is_return: bool,
    /// True when the op is an inlinable `call` (rides the compute lane
    /// as one folded row).
    pub inlined_call: bool,
    /// Operands, deduplicated in first-occurrence order.
    pub operands: Vec<String>,
    /// Result SSA ids.
    pub results: Vec<String>,
    /// SSA predecessor ops (entry-function positions, from
    /// [`DepGraph`]).
    pub preds: Vec<usize>,
}

/// One registered SSA value of a captured [`TimelineShape`].
#[derive(Debug, Clone)]
pub struct ValueShape {
    /// SSA id.
    pub id: String,
    /// Tensor type the byte footprint derives from (`None` when the
    /// value appears without a type — priced at zero bytes, exactly as
    /// the from-scratch registration does).
    pub ty: Option<TensorType>,
    /// Consumer count (the last use frees the value's buffer space).
    pub uses: usize,
}

/// The expand-once half of the memory timeline: everything about a
/// module's entry function that does **not** depend on per-op costs or
/// tensor extents — op order, deduplicated operand/result id lists, SSA
/// predecessor edges, and the value-registration sequence of
/// [`DmaTimeline::new`]. Capture it once, then the price-many replay
/// (driven by [`crate::graph::reuse::ScheduleTemplate`]) re-runs it
/// over new per-op costs and byte footprints; `schedule_estimate_memory`
/// is itself capture + one replay, so the two paths cannot drift.
#[derive(Debug, Clone)]
pub struct TimelineShape {
    /// Module name for the assembled schedule.
    pub module_name: String,
    /// Entry-function ops in program order.
    pub ops: Vec<TimelineOpShape>,
    /// Registered values: results in program order first, then
    /// argument-like operands in first-use order — mirroring the two
    /// registration passes of [`DmaTimeline::new`] exactly.
    pub values: Vec<ValueShape>,
}

impl TimelineShape {
    /// Capture the cost- and extent-invariant structure of `module`'s
    /// entry function. `None` when the module has no entry function.
    pub fn capture(module: &ModuleInfo) -> Option<TimelineShape> {
        let func = module.entry()?;
        let graph = DepGraph::build(func);
        let ops = func
            .ops
            .iter()
            .enumerate()
            .map(|(i, op)| TimelineOpShape {
                index: op.index,
                op_name: op.op_name.clone(),
                is_return: op.short_name() == "return",
                inlined_call: is_inlined_call(op),
                operands: dedup_operands(op),
                results: op.results.clone(),
                preds: graph.preds[i].clone(),
            })
            .collect();

        // Mirror the two registration passes of `DmaTimeline::new`:
        // results first (a re-defined id keeps its *last* type, exactly
        // like the insert-overwrite there), then per-op first uses —
        // unknown producers are HBM-resident arguments typed from the
        // using op (positional type, falling back to the op's first).
        let mut slot: HashMap<&str, usize> = HashMap::new();
        let mut values: Vec<ValueShape> = Vec::new();
        for op in &func.ops {
            for (k, r) in op.results.iter().enumerate() {
                let ty = op.result_types.get(k).cloned();
                match slot.get(r.as_str()) {
                    Some(&s) => values[s].ty = ty,
                    None => {
                        slot.insert(r.as_str(), values.len());
                        values.push(ValueShape {
                            id: r.clone(),
                            ty,
                            uses: 0,
                        });
                    }
                }
            }
        }
        for op in &func.ops {
            let mut seen: Vec<&str> = Vec::new();
            for (k, operand) in op.operands.iter().enumerate() {
                if seen.contains(&operand.as_str()) {
                    continue;
                }
                seen.push(operand.as_str());
                let s = match slot.get(operand.as_str()) {
                    Some(&s) => s,
                    None => {
                        let ty = op
                            .operand_types
                            .get(k)
                            .or_else(|| op.operand_types.first())
                            .cloned();
                        let s = values.len();
                        slot.insert(operand.as_str(), s);
                        values.push(ValueShape {
                            id: operand.clone(),
                            ty,
                            uses: 0,
                        });
                        s
                    }
                };
                values[s].uses += 1;
            }
        }
        Some(TimelineShape {
            module_name: module.name.clone(),
            ops,
            values,
        })
    }

    /// The native per-value byte column: each registered value's
    /// footprint at the captured extents (the identity re-cost). A
    /// sequence rewrite maps [`ValueShape::ty`] through
    /// [`crate::inference::rewrite_type`] instead.
    pub fn native_bytes(&self) -> Vec<u64> {
        self.values
            .iter()
            .map(|v| v.ty.as_ref().map(|t| t.size_bytes()).unwrap_or(0))
            .collect()
    }
}

/// Engine routing for an inlined `call` op: the folded sub-estimate
/// rides the compute lane (shared between the from-scratch walk and the
/// template replay so the routing cannot drift).
pub(crate) fn call_engine(config: EngineConfig) -> Option<Engine> {
    Some(match config {
        EngineConfig::Serialized => Engine::Unified,
        _ => Engine::Mxu,
    })
}

/// The price-many half: replay a captured [`TimelineShape`] over new
/// per-op cost rows, engine assignments and per-value byte footprints.
/// `rows` and `engines` align 1:1 with `shape.ops`; `bytes` aligns with
/// `shape.values`. This is the *same* walk [`schedule_estimate_memory`]
/// runs — that function is capture + one replay — so a template re-cost
/// is bit-identical to a from-scratch build by construction.
pub(crate) fn price_shape(
    shape: &TimelineShape,
    rows: &[crate::coordinator::OpEstimate],
    engines: &[Option<Engine>],
    config: EngineConfig,
    memory: &MemoryConfig,
    bytes: &[u64],
) -> MemorySchedule {
    debug_assert_eq!(shape.ops.len(), rows.len());
    debug_assert_eq!(shape.ops.len(), engines.len());
    debug_assert_eq!(shape.values.len(), bytes.len());
    let mut values: HashMap<String, ValueState> = HashMap::new();
    for (v, &b) in shape.values.iter().zip(bytes) {
        values.insert(
            v.id.clone(),
            ValueState {
                bytes: b,
                uses: v.uses,
                chip_node: None,
                hbm_node: None,
                dirty: false,
            },
        );
    }
    let mut dma = DmaTimeline::from_values(*memory, values);
    let mut nodes: Vec<SchedNode> = Vec::with_capacity(shape.ops.len() * 2);
    let mut provider: Vec<usize> = Vec::with_capacity(shape.ops.len());
    struct Plan {
        fetch: FetchDma,
        main: usize,
        retire: RetireDma,
    }
    let mut plans: Vec<Plan> = Vec::with_capacity(shape.ops.len());

    for ((sop, row), engine) in shape.ops.iter().zip(rows).zip(engines) {
        let view = OpView {
            index: sop.index,
            op_name: &sop.op_name,
            is_return: sop.is_return,
            operands: &sop.operands,
            results: &sop.results,
        };
        // `return` reads nothing on chip — its retire step escapes any
        // still-dirty results to HBM instead.
        let fetch = if sop.is_return {
            FetchDma::default()
        } else {
            dma.fetch_view(&view, &mut nodes)
        };
        let mut preds: Vec<usize> = Vec::new();
        for &p in &sop.preds {
            push_unique(&mut preds, provider[p]);
        }
        for &n in &fetch.hit_preds {
            push_unique(&mut preds, n);
        }
        if let Some(n) = fetch.node {
            push_unique(&mut preds, n);
        }
        let main = nodes.len();
        nodes.push(SchedNode {
            index: row.index,
            op_name: row.op_name.clone(),
            engine: *engine,
            cost_us: row.latency_us,
            preds,
            source: row.source.tag(),
            note: row.note.clone(),
        });
        provider.push(main);
        let retire = dma.retire_view(&view, main, &mut nodes);
        plans.push(Plan { fetch, main, retire });
    }

    // Left-to-right prefix sum in expansion order: the fold order the
    // exact upper-bound proof relies on (f64 Sum adds in iteration
    // order).
    let serialized_bound_us: f64 = nodes.iter().map(|n| n.cost_us).sum();
    let stats = dma.stats();
    let schedule = finish_schedule(shape.module_name.clone(), config, nodes);

    let mut roofline = RooflineSummary::default();
    let mut ops: Vec<OpMemory> = Vec::with_capacity(plans.len());
    for (plan, row) in plans.iter().zip(rows) {
        let dma_us = plan.fetch.dma_us + plan.retire.dma_us;
        roofline.record(row.latency_us, dma_us);
        let first = plan.fetch.node.unwrap_or(plan.main);
        let last = plan.retire.node.unwrap_or(plan.main);
        ops.push(OpMemory {
            index: row.index,
            op_name: row.op_name.clone(),
            compute_us: row.latency_us,
            dma_in_us: plan.fetch.dma_us,
            dma_out_us: plan.retire.dma_us,
            cold_bytes: plan.fetch.cold_bytes,
            writeback_bytes: plan.fetch.writeback_bytes + plan.retire.bytes,
            hits: plan.fetch.hits,
            cold_fetches: plan.fetch.cold_fetches,
            start_us: schedule.ops[first].start_us,
            end_us: schedule.ops[last].end_us,
        });
    }
    MemorySchedule {
        schedule,
        memory: *memory,
        ops,
        serialized_bound_us,
        stats,
        roofline,
    }
}

/// One entry-function op's memory-aware row.
#[derive(Debug, Clone)]
pub struct OpMemory {
    /// Index of the source op within its function.
    pub index: usize,
    /// Display name of the op.
    pub op_name: String,
    /// Compute time carried over from the estimate row, µs.
    pub compute_us: f64,
    /// Inbound DMA time (cold fetches + forced eviction write-backs), µs.
    pub dma_in_us: f64,
    /// Outbound DMA time (spills, dirty evictions, escapes), µs.
    pub dma_out_us: f64,
    /// Bytes this op fetched cold from HBM.
    pub cold_bytes: u64,
    /// Bytes this op wrote back to HBM (both directions' nodes).
    pub writeback_bytes: u64,
    /// Operand accesses answered from the residency buffer.
    pub hits: usize,
    /// Operand accesses that paid an HBM fetch.
    pub cold_fetches: usize,
    /// Timeline start (the op's fetch node, or its compute node), µs.
    pub start_us: f64,
    /// Timeline end (the op's write-back node, or its compute node), µs.
    pub end_us: f64,
}

impl OpMemory {
    /// True when every operand was already resident (no cold fetch).
    pub fn resident(&self) -> bool {
        self.cold_fetches == 0
    }

    /// Roofline verdict for this op: `"compute"`, `"bandwidth"` or
    /// `"free"`.
    pub fn bound(&self) -> &'static str {
        op_bound(self.compute_us, self.dma_in_us + self.dma_out_us)
    }
}

/// A memory-aware module schedule: the expanded sub-node timeline plus
/// per-op DMA accounting, residency stats and the roofline summary.
#[derive(Debug, Clone)]
pub struct MemorySchedule {
    /// The placed schedule over the expanded (DMA-in / compute /
    /// DMA-out) node list: makespan, critical path, per-engine busy
    /// (including the DMA engine) and the renderable timeline.
    pub schedule: ModuleSchedule,
    /// The bandwidth/buffer configuration this timeline was built with.
    pub memory: MemoryConfig,
    /// One row per entry-function op, aligned with the estimate rows.
    pub ops: Vec<OpMemory>,
    /// Upper bound: every compute op and every cold transfer serialized
    /// back to back (prefix-sum in expansion order, so the makespan
    /// bound holds exactly in floating point).
    pub serialized_bound_us: f64,
    /// Aggregate traffic/residency counters.
    pub stats: MemoryStats,
    /// Aggregate compute-vs-bandwidth roofline.
    pub roofline: RooflineSummary,
}

impl MemorySchedule {
    /// Memory-aware makespan, µs.
    pub fn makespan_us(&self) -> f64 {
        self.schedule.makespan_us
    }

    /// Longest dependence chain over the expanded nodes, µs.
    pub fn critical_path_us(&self) -> f64 {
        self.schedule.critical_path_us
    }

    /// Total DMA busy time (inbound + outbound across all ops), µs.
    pub fn dma_busy_us(&self) -> f64 {
        self.ops.iter().map(|o| o.dma_in_us + o.dma_out_us).sum()
    }

    /// The memory-aware timeline as Chrome trace events.
    ///
    /// Delegates to [`ModuleSchedule::trace_events`] over the *expanded*
    /// node list, so the DMA lane shows each op's `<op>.dma_in` /
    /// `<op>.dma_out` sub-slices next to its compute slice — cold
    /// fetches, forced eviction write-backs and residency spills all
    /// carry their byte accounting in the slice note (e.g.
    /// `"write back 262144 B"`).
    pub fn trace_events(&self) -> Vec<TraceEvent> {
        self.schedule.trace_events()
    }

    /// The memory block of the `--json` payload: totals, config and
    /// residency counters.
    pub fn to_json(&self) -> Json {
        let finite_num = |v: f64| if v.is_finite() { Json::Num(v) } else { Json::Null };
        let mut j = Json::obj();
        j.set("makespan_us", Json::Num(self.makespan_us()))
            .set("critical_path_us", Json::Num(self.critical_path_us()))
            .set("serialized_bound_us", Json::Num(self.serialized_bound_us))
            .set("dma_busy_us", Json::Num(self.dma_busy_us()))
            .set("hbm_bytes_per_us", finite_num(self.memory.hbm_bytes_per_us))
            .set(
                "buffer_bytes",
                match self.memory.buffer_bytes {
                    Some(b) => Json::Num(b as f64),
                    None => Json::Null,
                },
            )
            .set("hits", Json::Num(self.stats.hits as f64))
            .set("cold_fetches", Json::Num(self.stats.cold_fetches as f64))
            .set("cold_bytes", Json::Num(self.stats.cold_bytes as f64))
            .set("writeback_bytes", Json::Num(self.stats.writeback_bytes as f64))
            .set("evictions", Json::Num(self.stats.evictions as f64))
            .set(
                "peak_resident_bytes",
                Json::Num(self.stats.peak_resident_bytes as f64),
            );
        j
    }

    /// The roofline payload: aggregate counters plus a per-op verdict
    /// (`"compute"` / `"bandwidth"` / `"free"`).
    pub fn roofline_json(&self) -> Json {
        let mut j = self.roofline.to_json();
        let ops: Vec<Json> = self
            .ops
            .iter()
            .map(|o| {
                let mut row = Json::obj();
                row.set("index", Json::Num(o.index as f64))
                    .set("op", Json::Str(o.op_name.clone()))
                    .set("bound", Json::Str(o.bound().to_string()))
                    .set("dma_us", Json::Num(o.dma_in_us + o.dma_out_us));
                row
            })
            .collect();
        j.set("ops", Json::Arr(ops));
        j
    }

    /// Human-readable summary block for the CLI (`compute_only_us` is
    /// the memory-blind scheduled makespan for comparison).
    pub fn render_summary(&self, compute_only_us: f64) -> String {
        let buffer = match self.memory.buffer_bytes {
            Some(b) => format!("{:.1} MiB", b as f64 / (1024.0 * 1024.0)),
            None => "unbounded".to_string(),
        };
        format!(
            "memory-aware: makespan {:.2} us (compute-only {:.2} us, serialized bound {:.2} us); dma busy {:.2} us\n\
             residency ({buffer} buffer): {} hits / {} cold fetches; {:.2} MB cold traffic, {:.2} MB written back, {} evictions\n\
             {}",
            self.makespan_us(),
            compute_only_us,
            self.serialized_bound_us,
            self.dma_busy_us(),
            self.stats.hits,
            self.stats.cold_fetches,
            self.stats.cold_bytes as f64 / 1e6,
            self.stats.writeback_bytes as f64 / 1e6,
            self.stats.evictions,
            self.roofline.render()
        )
    }
}

/// Build the memory-aware schedule for a module from its already-
/// computed unfused estimate (no re-estimation, no cache traffic — the
/// same contract as [`crate::graph::schedule_estimate`]).
///
/// Each estimate row becomes a compute node on its usual engine; the
/// [`DmaTimeline`] threads residency through the walk and adds the
/// DMA-in/DMA-out sub-nodes around it.
pub fn schedule_estimate_memory(
    module: &ModuleInfo,
    report: &ModelEstimate,
    config: EngineConfig,
    memory: &MemoryConfig,
) -> MemorySchedule {
    let Some(shape) = TimelineShape::capture(module) else {
        return MemorySchedule {
            schedule: finish_schedule(module.name.clone(), config, Vec::new()),
            memory: *memory,
            ops: Vec::new(),
            serialized_bound_us: 0.0,
            stats: MemoryStats::default(),
            roofline: RooflineSummary::default(),
        };
    };
    let func = module.entry().expect("capture implies an entry function");
    debug_assert_eq!(
        report.ops.len(),
        func.ops.len(),
        "estimate rows must align 1:1 with the entry function's ops"
    );
    // Engine routing is extent-sensitive (classify inspects shapes), so
    // it rides the per-cost side of the split, not the captured shape.
    let engines: Vec<Option<Engine>> = func
        .ops
        .iter()
        .map(|op| {
            if is_inlined_call(op) {
                call_engine(config)
            } else {
                config.engine_of(&classify(op))
            }
        })
        .collect();
    let bytes = shape.native_bytes();
    price_shape(&shape, &report.ops, &engines, config, memory, &bytes)
}

/// Estimate `module` through `est` and build its memory-aware schedule
/// in one call (one `estimate_module` walk, same as
/// [`crate::graph::schedule_module`]).
///
/// ```
/// use scalesim_tpu::calibrate::fit_regime_calibration;
/// use scalesim_tpu::coordinator::Estimator;
/// use scalesim_tpu::frontend::parse_module;
/// use scalesim_tpu::graph::EngineConfig;
/// use scalesim_tpu::memory::{schedule_module_memory, MemoryConfig};
/// use scalesim_tpu::scalesim::{GemmShape, ScaleConfig};
///
/// let obs: Vec<_> = [32usize, 64, 96, 128, 256, 512, 1024, 2048, 4096]
///     .iter()
///     .map(|&d| (GemmShape::new(d, d, d), (d * d) as u64, (d * d) as f64 * 1e-3 + 1.0))
///     .collect();
/// let est = Estimator::new(ScaleConfig::tpu_v4(), fit_regime_calibration(&obs).unwrap());
/// let module = parse_module(
///     r#"module @m { func.func @main(%x: tensor<256x256xf32>, %w: tensor<256x256xf32>) -> tensor<256x256xf32> {
///   %0 = stablehlo.dot_general %x, %w, contracting_dims = [1] x [0] : (tensor<256x256xf32>, tensor<256x256xf32>) -> tensor<256x256xf32>
///   %1 = stablehlo.add %0, %x : tensor<256x256xf32>
///   return %1 : tensor<256x256xf32>
/// } }"#,
/// )
/// .unwrap();
///
/// let mem = schedule_module_memory(&est, &module, EngineConfig::Tpu, &MemoryConfig::tpu_v4());
/// // The makespan sits inside its exact bracket.
/// assert!(mem.makespan_us() > 0.0);
/// assert!(mem.makespan_us() <= mem.serialized_bound_us);
/// // %0 is consumed immediately by the add: a residency hit.
/// assert!(mem.stats.hits >= 1);
/// ```
pub fn schedule_module_memory(
    est: &Estimator,
    module: &ModuleInfo,
    config: EngineConfig,
    memory: &MemoryConfig,
) -> MemorySchedule {
    let report = est.estimate_module(module);
    schedule_estimate_memory(module, &report, config, memory)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::calibrate::fit_regime_calibration;
    use crate::frontend::parse_module;
    use crate::graph::schedule_estimate;
    use crate::scalesim::{GemmShape, ScaleConfig};

    fn estimator() -> Estimator {
        let mut obs = Vec::new();
        for d in [32usize, 64, 96, 128, 256, 512, 1024, 2048, 4096] {
            let g = GemmShape::new(d, d, d);
            obs.push((g, (d * d) as u64, (d * d) as f64 * 1e-3 + 1.0));
        }
        Estimator::new(ScaleConfig::tpu_v4(), fit_regime_calibration(&obs).unwrap())
    }

    const CHAIN: &str = r#"
module @m { func.func @main(%x: tensor<256x256xf32>, %w: tensor<256x256xf32>) -> tensor<256x256xf32> {
  %0 = stablehlo.dot_general %x, %w, contracting_dims = [1] x [0] : (tensor<256x256xf32>, tensor<256x256xf32>) -> tensor<256x256xf32>
  %1 = stablehlo.add %0, %x : tensor<256x256xf32>
  return %1 : tensor<256x256xf32>
} }"#;

    #[test]
    fn chain_pays_cold_args_and_hits_the_intermediate() {
        let est = estimator();
        let module = parse_module(CHAIN).unwrap();
        let report = est.estimate_module(&module);
        let mem =
            schedule_estimate_memory(&module, &report, EngineConfig::Tpu, &MemoryConfig::tpu_v4());
        assert_eq!(mem.ops.len(), 3);
        // The dot fetches both arguments cold (2 x 256KiB).
        let dot = &mem.ops[0];
        assert_eq!(dot.cold_fetches, 2);
        assert_eq!(dot.cold_bytes, 2 * 256 * 256 * 4);
        assert!(dot.dma_in_us > 0.0);
        assert!(!dot.resident());
        // The add hits both %0 and the still-resident %x.
        let add = &mem.ops[1];
        assert_eq!(add.hits, 2);
        assert_eq!(add.cold_fetches, 0);
        assert!(add.resident());
        assert_eq!(add.dma_in_us, 0.0);
        // `return` escapes the dirty result: exactly one write-back.
        let ret = &mem.ops[2];
        assert_eq!(ret.writeback_bytes, 256 * 256 * 4);
        assert!(ret.dma_out_us > 0.0);
        // Totals line up.
        assert_eq!(mem.stats.hits, 2);
        assert_eq!(mem.stats.cold_fetches, 2);
        assert_eq!(mem.stats.writeback_bytes, 256 * 256 * 4);
    }

    #[test]
    fn infinite_config_is_bit_identical_to_compute_only() {
        let est = estimator();
        let module = parse_module(CHAIN).unwrap();
        let report = est.estimate_module(&module);
        let base = schedule_estimate(&module, &report, EngineConfig::Tpu);
        let mem = schedule_estimate_memory(
            &module,
            &report,
            EngineConfig::Tpu,
            &MemoryConfig::infinite(),
        );
        assert_eq!(mem.makespan_us().to_bits(), base.makespan_us.to_bits());
        assert_eq!(mem.dma_busy_us(), 0.0);
        // Residency still tracks (args are cold), but transfers are free.
        assert_eq!(mem.stats.cold_fetches, 2);
        assert_eq!(mem.ops[0].dma_in_us, 0.0);
    }

    #[test]
    fn zero_buffer_never_hits_and_still_brackets() {
        let est = estimator();
        let module = parse_module(CHAIN).unwrap();
        let report = est.estimate_module(&module);
        let base = schedule_estimate(&module, &report, EngineConfig::Tpu);
        let cfg = MemoryConfig::new(est.hbm_bytes_per_us(), Some(0));
        let mem = schedule_estimate_memory(&module, &report, EngineConfig::Tpu, &cfg);
        assert_eq!(mem.stats.hits, 0);
        // Every operand access is cold now: 2 for the dot, 2 for the add.
        assert_eq!(mem.stats.cold_fetches, 4);
        // Both results spill straight to HBM.
        assert!(mem.stats.writeback_bytes >= 2 * 256 * 256 * 4);
        assert!(base.makespan_us <= mem.makespan_us());
        assert!(mem.makespan_us() <= mem.serialized_bound_us);
    }

    #[test]
    fn roofline_flags_bandwidth_bound_ops() {
        let est = estimator();
        let module = parse_module(CHAIN).unwrap();
        let report = est.estimate_module(&module);
        // Starve the bandwidth so every costed op goes bandwidth-bound.
        let cfg = MemoryConfig::new(1.0, Some(0));
        let mem = schedule_estimate_memory(&module, &report, EngineConfig::Tpu, &cfg);
        assert_eq!(mem.ops[0].bound(), "bandwidth");
        assert!(mem.roofline.bandwidth_bound >= 2);
        assert_eq!(mem.roofline.verdict(), "bandwidth-bound");
        let j = mem.roofline_json();
        assert_eq!(j.req_str("verdict").unwrap(), "bandwidth-bound");
        assert_eq!(j.req_arr("ops").unwrap().len(), 3);
    }

    #[test]
    fn trace_events_show_dma_sub_slices() {
        let est = estimator();
        let module = parse_module(CHAIN).unwrap();
        let mem =
            schedule_module_memory(&est, &module, EngineConfig::Tpu, &MemoryConfig::tpu_v4());
        let events = mem.trace_events();
        // The expanded timeline surfaces the cold fetch and the escape
        // write-back as their own slices on the DMA lane.
        assert!(events
            .iter()
            .any(|e| e.name.ends_with(".dma_in") && e.cat.starts_with("dma")));
        assert!(events
            .iter()
            .any(|e| e.name.ends_with(".dma_out") && e.cat.starts_with("dma")));
    }

    #[test]
    fn json_and_summary_render() {
        let est = estimator();
        let module = parse_module(CHAIN).unwrap();
        let mem =
            schedule_module_memory(&est, &module, EngineConfig::Tpu, &MemoryConfig::tpu_v4());
        let j = mem.to_json();
        assert!(j.req_f64("makespan_us").unwrap() > 0.0);
        assert!(j.req_f64("cold_bytes").unwrap() > 0.0);
        assert_eq!(
            j.req_f64("buffer_bytes").unwrap(),
            MemoryConfig::DEFAULT_BUFFER_BYTES as f64
        );
        let text = mem.render_summary(0.0);
        assert!(text.contains("memory-aware:"));
        assert!(text.contains("residency"));
        assert!(text.contains("roofline:"));
    }
}
