//! The tensor-residency tracker: a bounded on-chip buffer with LRU
//! eviction.
//!
//! The tracker answers one question for the DMA timeline: *is this SSA
//! value already on chip?* Values are keyed by their SSA id, occupy
//! their tensor's byte footprint, and are evicted least-recently-used
//! when an insertion would overflow the buffer. Entries can be *pinned*
//! for the duration of one insertion (an op's live operands must not be
//! evicted to make room for each other), and carry a *dirty* bit so the
//! caller knows whether an eviction owes a write-back to HBM.
//!
//! The tracker is pure mechanism: it never touches the clock or the
//! schedule. All decisions depend only on the access order, so a given
//! program always produces the same residency trace — the property the
//! memory-model invariants in `tests/memory_model.rs` build on.

use std::collections::HashMap;

/// One value evicted by [`ResidencyTracker::insert`].
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Evicted {
    /// SSA id of the evicted value.
    pub id: String,
    /// Byte footprint it freed.
    pub bytes: u64,
    /// True if the on-chip copy was newer than HBM (write-back owed).
    pub dirty: bool,
}

/// Result of one [`ResidencyTracker::insert`] call.
#[derive(Debug, Clone, Default)]
pub struct InsertOutcome {
    /// False when the value could not fit (larger than the whole buffer,
    /// or everything evictable was pinned). Nothing is evicted then.
    pub inserted: bool,
    /// Values evicted (LRU first) to make room, empty unless `inserted`.
    pub evicted: Vec<Evicted>,
}

/// Aggregate counters over a tracker's lifetime.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ResidencyStats {
    /// Accesses that found the value resident.
    pub hits: usize,
    /// Accesses that missed (cold).
    pub misses: usize,
    /// Values evicted to make room for insertions.
    pub evictions: usize,
    /// Insertions refused because the value could not fit.
    pub rejected: usize,
    /// High-water mark of resident bytes.
    pub peak_resident_bytes: u64,
}

#[derive(Debug, Clone)]
struct Entry {
    bytes: u64,
    dirty: bool,
}

/// A bounded on-chip tensor buffer with LRU eviction.
///
/// ```
/// use scalesim_tpu::memory::ResidencyTracker;
///
/// let mut t = ResidencyTracker::new(Some(100));
/// assert!(!t.access("a"), "first touch is cold");
/// t.insert("a", 60, false, &[]);
/// assert!(t.access("a"), "now resident");
///
/// // Inserting 60 more bytes into the 100-byte buffer evicts `a`.
/// let out = t.insert("b", 60, true, &[]);
/// assert!(out.inserted);
/// assert_eq!(out.evicted.len(), 1);
/// assert_eq!(out.evicted[0].id, "a");
/// assert!(!t.access("a"), "evicted values are cold again");
///
/// // A pinned value cannot be evicted: the insert is refused instead.
/// let pins = ["b".to_string()];
/// let refused = t.insert("c", 60, false, &pins);
/// assert!(!refused.inserted);
/// assert!(refused.evicted.is_empty());
/// ```
#[derive(Debug, Clone)]
pub struct ResidencyTracker {
    /// Buffer capacity in bytes; `None` is unbounded.
    capacity: Option<u64>,
    /// Resident bytes right now.
    used: u64,
    /// Ids in recency order: front = least recently used.
    order: Vec<String>,
    entries: HashMap<String, Entry>,
    stats: ResidencyStats,
}

impl ResidencyTracker {
    /// New tracker with `capacity` bytes of on-chip buffer (`None` =
    /// unbounded).
    pub fn new(capacity: Option<u64>) -> ResidencyTracker {
        ResidencyTracker {
            capacity,
            used: 0,
            order: Vec::new(),
            entries: HashMap::new(),
            stats: ResidencyStats::default(),
        }
    }

    /// Is `id` resident? Does not touch recency or counters.
    pub fn contains(&self, id: &str) -> bool {
        self.entries.contains_key(id)
    }

    /// Resident bytes right now.
    pub fn resident_bytes(&self) -> u64 {
        self.used
    }

    /// Number of resident values.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    /// True when nothing is resident.
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Lifetime counters.
    pub fn stats(&self) -> ResidencyStats {
        self.stats
    }

    /// Record one access: returns true (and refreshes recency) on a hit,
    /// false on a miss. Misses do not insert — see [`Self::insert`].
    pub fn access(&mut self, id: &str) -> bool {
        if self.entries.contains_key(id) {
            self.stats.hits += 1;
            self.touch(id);
            true
        } else {
            self.stats.misses += 1;
            false
        }
    }

    /// Move `id` to the most-recently-used position (no counters).
    fn touch(&mut self, id: &str) {
        if let Some(pos) = self.order.iter().position(|x| x == id) {
            let v = self.order.remove(pos);
            self.order.push(v);
        }
    }

    /// Insert `id` (`bytes` wide), evicting least-recently-used unpinned
    /// values as needed. `dirty` marks the on-chip copy as newer than
    /// HBM. Re-inserting a resident value refreshes recency and ors the
    /// dirty bit. When the value cannot fit — it is larger than the
    /// whole buffer, or freeing enough would require evicting a pinned
    /// value — nothing is evicted and `inserted` is false.
    pub fn insert(
        &mut self,
        id: &str,
        bytes: u64,
        dirty: bool,
        pinned: &[String],
    ) -> InsertOutcome {
        if let Some(e) = self.entries.get_mut(id) {
            e.dirty = e.dirty || dirty;
            self.touch(id);
            return InsertOutcome {
                inserted: true,
                evicted: Vec::new(),
            };
        }
        if let Some(cap) = self.capacity {
            if bytes > cap {
                self.stats.rejected += 1;
                return InsertOutcome::default();
            }
            if self.used + bytes > cap {
                // Plan the eviction run LRU-first; commit only if it frees
                // enough without touching a pinned value's slot.
                let need = self.used + bytes - cap;
                let mut freed = 0u64;
                let mut victims: Vec<String> = Vec::new();
                for vid in &self.order {
                    if freed >= need {
                        break;
                    }
                    if pinned.iter().any(|p| p == vid) {
                        continue;
                    }
                    freed += self.entries[vid].bytes;
                    victims.push(vid.clone());
                }
                if freed < need {
                    self.stats.rejected += 1;
                    return InsertOutcome::default();
                }
                let mut evicted = Vec::with_capacity(victims.len());
                for vid in victims {
                    let entry = self.entries.remove(&vid).expect("victim resident");
                    self.used -= entry.bytes;
                    self.order.retain(|x| x != &vid);
                    self.stats.evictions += 1;
                    evicted.push(Evicted {
                        id: vid,
                        bytes: entry.bytes,
                        dirty: entry.dirty,
                    });
                }
                self.finish_insert(id, bytes, dirty);
                return InsertOutcome {
                    inserted: true,
                    evicted,
                };
            }
        }
        self.finish_insert(id, bytes, dirty);
        InsertOutcome {
            inserted: true,
            evicted: Vec::new(),
        }
    }

    fn finish_insert(&mut self, id: &str, bytes: u64, dirty: bool) {
        self.entries.insert(id.to_string(), Entry { bytes, dirty });
        self.order.push(id.to_string());
        self.used += bytes;
        self.stats.peak_resident_bytes = self.stats.peak_resident_bytes.max(self.used);
    }

    /// Drop `id` without eviction accounting (a dead value: its last
    /// consumer has run). Returns true if it was resident.
    pub fn remove(&mut self, id: &str) -> bool {
        match self.entries.remove(id) {
            Some(e) => {
                self.used -= e.bytes;
                self.order.retain(|x| x != id);
                true
            }
            None => false,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hit_miss_and_recency() {
        let mut t = ResidencyTracker::new(Some(100));
        assert!(!t.access("a"));
        assert!(t.insert("a", 40, false, &[]).inserted);
        assert!(t.access("a"));
        assert!(t.insert("b", 40, false, &[]).inserted);
        // Touch `a` so `b` becomes LRU; inserting 40 more evicts `b`.
        assert!(t.access("a"));
        let out = t.insert("c", 40, false, &[]);
        assert!(out.inserted);
        assert_eq!(out.evicted.len(), 1);
        assert_eq!(out.evicted[0].id, "b");
        assert!(t.contains("a") && t.contains("c") && !t.contains("b"));
        let s = t.stats();
        assert_eq!((s.hits, s.misses, s.evictions), (2, 1, 1));
        assert_eq!(s.peak_resident_bytes, 80);
    }

    #[test]
    fn oversized_value_is_rejected_without_evicting() {
        let mut t = ResidencyTracker::new(Some(64));
        t.insert("a", 32, true, &[]);
        let out = t.insert("huge", 128, false, &[]);
        assert!(!out.inserted);
        assert!(out.evicted.is_empty());
        assert!(t.contains("a"), "rejection must not evict");
        assert_eq!(t.stats().rejected, 1);
    }

    #[test]
    fn pinned_values_survive_and_block_insertion() {
        let mut t = ResidencyTracker::new(Some(100));
        t.insert("a", 60, true, &[]);
        let pins = ["a".to_string()];
        let out = t.insert("b", 60, false, &pins);
        assert!(!out.inserted, "only a pinned value could have made room");
        assert!(t.contains("a"));
        // Without the pin the same insert succeeds and reports the
        // dirty eviction.
        let out = t.insert("b", 60, false, &[]);
        assert!(out.inserted);
        assert!(out.evicted[0].dirty);
    }

    #[test]
    fn unbounded_never_evicts() {
        let mut t = ResidencyTracker::new(None);
        for i in 0..100 {
            assert!(t.insert(&format!("v{i}"), 1 << 20, true, &[]).inserted);
        }
        assert_eq!(t.len(), 100);
        assert_eq!(t.stats().evictions, 0);
        assert_eq!(t.resident_bytes(), 100 << 20);
    }

    #[test]
    fn reinsert_refreshes_and_ors_dirty() {
        let mut t = ResidencyTracker::new(Some(100));
        t.insert("a", 40, false, &[]);
        t.insert("b", 40, false, &[]);
        // Re-inserting `a` makes it MRU and dirty; the next eviction
        // takes `b` and reports it clean.
        let out = t.insert("a", 40, true, &[]);
        assert!(out.inserted && out.evicted.is_empty());
        let out = t.insert("c", 60, false, &[]);
        assert!(out.inserted);
        assert_eq!(out.evicted[0].id, "b");
        assert!(!out.evicted[0].dirty);
        assert!(t.contains("a"));
    }

    #[test]
    fn remove_frees_without_eviction_stats() {
        let mut t = ResidencyTracker::new(Some(64));
        t.insert("a", 64, true, &[]);
        assert!(t.remove("a"));
        assert!(!t.remove("a"));
        assert_eq!(t.resident_bytes(), 0);
        assert_eq!(t.stats().evictions, 0);
        assert!(t.insert("b", 64, false, &[]).inserted);
    }

    #[test]
    fn multi_victim_eviction_is_lru_ordered() {
        let mut t = ResidencyTracker::new(Some(100));
        t.insert("a", 30, true, &[]);
        t.insert("b", 30, false, &[]);
        t.insert("c", 30, false, &[]);
        let out = t.insert("d", 50, false, &[]);
        assert!(out.inserted);
        let ids: Vec<&str> = out.evicted.iter().map(|e| e.id.as_str()).collect();
        assert_eq!(ids, vec!["a", "b"], "LRU-first eviction order");
        assert_eq!(t.resident_bytes(), 30 + 50);
    }
}
