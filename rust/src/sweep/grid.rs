//! Deterministic per-class shape grids for the `sweep` harness.
//!
//! Every grid is a pure function of `(SweepOpClass, GridSize)` — no
//! clocks, no randomness — so a sweep over a fixed device preset is
//! byte-reproducible (the golden-CSV test in `tests/cli.rs` depends on
//! this). The `Paper` grids reuse the paper's own sweep generators from
//! [`crate::workloads`] where one exists; the `Small` grids are tight
//! hand-picked subsets meant for CI smoke runs and golden fixtures.

use crate::frontend::classify::{EwKind, OpClass};
use crate::frontend::types::{DType, TensorType};
use crate::scalesim::topology::{ConvLayer, GemmShape};
use crate::workloads::{elementwise_sweep, gemm_sweep};

use super::{GridSize, SweepCase, SweepOpClass};

/// The deterministic case list for one op class at one grid size.
pub fn cases_for(class: SweepOpClass, grid: GridSize) -> Vec<SweepCase> {
    match class {
        SweepOpClass::Matmul => matmul_cases(grid),
        SweepOpClass::Conv => conv_cases(grid),
        SweepOpClass::Elementwise => ew_cases(grid),
        SweepOpClass::Activation => activation_cases(grid),
        SweepOpClass::Normalization => normalization_cases(grid),
        SweepOpClass::Pooling => pooling_cases(grid),
        SweepOpClass::DataMovement => movement_cases(grid),
    }
}

fn dims_str(dims: &[usize]) -> String {
    dims.iter()
        .map(|d| d.to_string())
        .collect::<Vec<_>>()
        .join("x")
}

fn gemm_case(m: usize, k: usize, n: usize) -> SweepCase {
    let gemm = GemmShape::new(m, k, n);
    let dtype = DType::Bf16;
    SweepCase {
        op: "dot_general".to_string(),
        shape: format!("{m}x{k}x{n}"),
        dtype,
        // Operand + result footprint the MXU streams per GEMM.
        bytes: ((m * k + k * n + m * n) * dtype.bytes()) as u64,
        class: OpClass::SystolicGemm { gemm, count: 1 },
    }
}

fn matmul_cases(grid: GridSize) -> Vec<SweepCase> {
    match grid {
        GridSize::Small => vec![
            gemm_case(64, 64, 64),
            gemm_case(128, 128, 128),
            gemm_case(256, 256, 256),
            gemm_case(512, 512, 512),
            gemm_case(128, 1024, 128),
            gemm_case(1024, 128, 1024),
        ],
        GridSize::Paper => gemm_sweep::full_sweep()
            .into_iter()
            .map(|(_, g)| gemm_case(g.m, g.k, g.n))
            .collect(),
    }
}

fn conv_case(ih: usize, iw: usize, fh: usize, fw: usize, c: usize, nf: usize, s: usize) -> SweepCase {
    let conv = ConvLayer {
        name: format!("sweep_conv_{ih}x{iw}"),
        ifmap_h: ih,
        ifmap_w: iw,
        filter_h: fh,
        filter_w: fw,
        channels: c,
        num_filters: nf,
        stride_h: s,
        stride_w: s,
    };
    let gemm = conv.to_gemm();
    let dtype = DType::Bf16;
    SweepCase {
        op: "convolution".to_string(),
        shape: format!("{ih}x{iw}x{c}/{fh}x{fw}/f{nf}/s{s}"),
        dtype,
        bytes: ((gemm.m * gemm.k + gemm.k * gemm.n + gemm.m * gemm.n) * dtype.bytes()) as u64,
        class: OpClass::SystolicConv {
            conv,
            gemm,
            count: 1,
        },
    }
}

fn conv_cases(grid: GridSize) -> Vec<SweepCase> {
    match grid {
        GridSize::Small => vec![
            conv_case(32, 32, 3, 3, 16, 32, 1),
            conv_case(28, 28, 5, 5, 8, 16, 2),
        ],
        GridSize::Paper => vec![
            // A ResNet-style ladder: large spatial / few channels down to
            // small spatial / many channels.
            conv_case(224, 224, 7, 7, 3, 64, 2),
            conv_case(56, 56, 3, 3, 64, 64, 1),
            conv_case(28, 28, 3, 3, 128, 128, 1),
            conv_case(14, 14, 3, 3, 256, 256, 2),
            conv_case(7, 7, 3, 3, 512, 512, 1),
        ],
    }
}

fn ew_case(kind: EwKind, dims: &[usize], dtype: DType) -> SweepCase {
    let out = TensorType {
        dims: dims.to_vec(),
        dtype,
    };
    SweepCase {
        op: kind.name().to_string(),
        shape: dims_str(dims),
        dtype,
        // The fallback/learned elementwise model charges two reads plus
        // one write of the output footprint.
        bytes: out.size_bytes() * 3,
        class: OpClass::Elementwise { kind, out },
    }
}

fn ew_cases(grid: GridSize) -> Vec<SweepCase> {
    match grid {
        GridSize::Small => {
            let kinds = [EwKind::Add, EwKind::Multiply, EwKind::Maximum];
            let shapes: [&[usize]; 3] = [&[1024], &[128, 128], &[64, 512]];
            let mut out = Vec::new();
            for kind in kinds {
                for dims in shapes {
                    out.push(ew_case(kind, dims, DType::Bf16));
                }
            }
            out
        }
        GridSize::Paper => {
            let kinds = [
                EwKind::Add,
                EwKind::Subtract,
                EwKind::Multiply,
                EwKind::Divide,
                EwKind::Maximum,
                EwKind::Minimum,
            ];
            // Subsample the Fig. 3 sweeps: every 16th 1-D and 2-D shape.
            let mut shapes: Vec<Vec<usize>> =
                elementwise_sweep::sweep_1d().into_iter().step_by(16).collect();
            shapes.extend(elementwise_sweep::sweep_2d().into_iter().step_by(16));
            let mut out = Vec::new();
            for kind in kinds {
                for dims in &shapes {
                    out.push(ew_case(kind, dims, DType::Bf16));
                }
            }
            out
        }
    }
}

fn activation_cases(grid: GridSize) -> Vec<SweepCase> {
    match grid {
        GridSize::Small => {
            let kinds = [EwKind::Exp, EwKind::Tanh, EwKind::Logistic];
            let shapes: [&[usize]; 2] = [&[128, 128], &[32, 1024]];
            let mut out = Vec::new();
            for kind in kinds {
                for dims in shapes {
                    out.push(ew_case(kind, dims, DType::Bf16));
                }
            }
            out
        }
        GridSize::Paper => {
            let kinds = [
                EwKind::Exp,
                EwKind::Tanh,
                EwKind::Logistic,
                EwKind::Rsqrt,
                EwKind::Sqrt,
                EwKind::Log,
            ];
            let shapes: [&[usize]; 6] = [
                &[1024],
                &[128, 128],
                &[256, 256],
                &[512, 512],
                &[1024, 1024],
                &[64, 4096],
            ];
            let mut out = Vec::new();
            for kind in kinds {
                for dims in shapes {
                    out.push(ew_case(kind, dims, DType::Bf16));
                }
            }
            out
        }
    }
}

fn reduction_case(op: &str, in_dims: &[usize], out_dims: &[usize], dtype: DType) -> SweepCase {
    let input = TensorType {
        dims: in_dims.to_vec(),
        dtype,
    };
    let out = TensorType {
        dims: out_dims.to_vec(),
        dtype,
    };
    SweepCase {
        op: op.to_string(),
        shape: format!("{}->{}", dims_str(in_dims), dims_str(out_dims)),
        dtype,
        bytes: input.size_bytes() + out.size_bytes(),
        class: OpClass::Reduction { input, out },
    }
}

fn normalization_cases(grid: GridSize) -> Vec<SweepCase> {
    match grid {
        GridSize::Small => vec![
            reduction_case("reduce", &[128, 1024], &[128], DType::F32),
            reduction_case("reduce", &[256, 256], &[256], DType::F32),
        ],
        GridSize::Paper => {
            let mut out = Vec::new();
            for n in [128usize, 512, 2048] {
                for d in [256usize, 1024, 4096] {
                    out.push(reduction_case("reduce", &[n, d], &[n], DType::F32));
                }
            }
            out
        }
    }
}

fn pooling_cases(grid: GridSize) -> Vec<SweepCase> {
    let pool = |c: usize, h: usize, w: usize| {
        reduction_case(
            "reduce_window",
            &[c, h, w],
            &[c, h / 2, w / 2],
            DType::Bf16,
        )
    };
    match grid {
        GridSize::Small => vec![pool(32, 56, 56), pool(64, 28, 28)],
        GridSize::Paper => vec![
            pool(32, 112, 112),
            pool(64, 56, 56),
            pool(128, 28, 28),
            pool(256, 14, 14),
        ],
    }
}

fn movement_case(op: &str, dims: &[usize], dtype: DType) -> SweepCase {
    let out = TensorType {
        dims: dims.to_vec(),
        dtype,
    };
    let bytes = out.size_bytes();
    SweepCase {
        op: op.to_string(),
        shape: dims_str(dims),
        dtype,
        // Read + write of the moved footprint.
        bytes: bytes * 2,
        class: OpClass::DataMovement { bytes, out },
    }
}

fn movement_cases(grid: GridSize) -> Vec<SweepCase> {
    match grid {
        GridSize::Small => vec![
            movement_case("transpose", &[1024, 1024], DType::F32),
            movement_case("reshape", &[8, 4096], DType::Bf16),
        ],
        GridSize::Paper => vec![
            movement_case("transpose", &[256, 256], DType::F32),
            movement_case("transpose", &[1024, 1024], DType::F32),
            movement_case("transpose", &[4096, 4096], DType::F32),
            movement_case("broadcast_in_dim", &[128, 1024], DType::Bf16),
            movement_case("reshape", &[64, 64, 64], DType::Bf16),
            movement_case("concatenate", &[2048, 2048], DType::F32),
        ],
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grids_are_deterministic_and_nonempty() {
        for class in SweepOpClass::ALL {
            for grid in [GridSize::Small, GridSize::Paper] {
                let a = cases_for(class, grid);
                let b = cases_for(class, grid);
                assert!(!a.is_empty(), "{class:?}/{grid:?} grid is empty");
                assert_eq!(a.len(), b.len());
                for (x, y) in a.iter().zip(&b) {
                    assert_eq!(x.op, y.op);
                    assert_eq!(x.shape, y.shape);
                    assert_eq!(x.bytes, y.bytes);
                    assert_eq!(x.class, y.class);
                }
            }
        }
    }

    #[test]
    fn small_grids_stay_small() {
        for class in SweepOpClass::ALL {
            assert!(
                cases_for(class, GridSize::Small).len() <= 16,
                "{class:?} small grid too large"
            );
        }
    }

    #[test]
    fn paper_matmul_grid_matches_the_paper_sweep() {
        let cases = cases_for(SweepOpClass::Matmul, GridSize::Paper);
        assert_eq!(cases.len(), gemm_sweep::full_sweep().len());
    }

    #[test]
    fn conv_cases_carry_their_im2col_gemm() {
        for case in cases_for(SweepOpClass::Conv, GridSize::Small) {
            match &case.class {
                OpClass::SystolicConv { conv, gemm, .. } => {
                    assert_eq!(*gemm, conv.to_gemm());
                }
                other => panic!("expected conv class, got {other:?}"),
            }
        }
    }
}
