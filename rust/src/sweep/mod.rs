//! Op-coverage validation sweeps over the batched estimator core.
//!
//! The `scalesim-tpu sweep` subcommand drives deterministic generated
//! shape grids — one grid per op class ([`SweepOpClass`]) — through
//! [`Estimator::estimate_classes`], the structure-of-arrays batch entry
//! point, and reports per-class estimate distributions, cache hit rates
//! and estimation throughput. Each class runs **twice** over the same
//! batch: a cold pass (populates the sharded shape cache) and a warm
//! pass (served from it). The harness then checks the two passes
//! bit-for-bit against each other — the cached/uncached bit-identity
//! invariant of [`crate::coordinator::batch`], validated over every op
//! class the estimator models rather than just the fixtures.
//!
//! Determinism: [`sweep_estimator`] pins the cycle→latency calibration
//! to an exact synthetic fit (1e-3 µs per cycle, zero intercept) so the
//! whole sweep is a pure function of the device spec and grid. The
//! golden fixture `tests/fixtures/sweep_small_tpu-v4.csv` asserts
//! byte-identical regeneration in `tests/cli.rs`.

use std::time::Instant;

use anyhow::{bail, Result};

use crate::calibrate::{LinearFit, RegimeCalibration};
use crate::coordinator::{CachedCost, Estimator};
use crate::device::DeviceSpec;
use crate::frontend::classify::OpClass;
use crate::frontend::types::DType;
use crate::report::Table;
use crate::scalesim::topology::GemmShape;
use crate::tpu::{measure_gemm_batch_median, Hardware};
use crate::util::json::Json;

pub mod grid;

/// An op-coverage class the sweep can exercise. Each maps onto the
/// [`OpClass`] the estimator's cost models key on; `Activation` is the
/// transcendental slice of the elementwise family and `Normalization` /
/// `Pooling` are the two reduction idioms.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SweepOpClass {
    /// `dot_general` GEMMs on the systolic array.
    Matmul,
    /// 2-D convolutions (im2col-lowered onto the systolic array).
    Conv,
    /// Binary arithmetic elementwise ops (add, multiply, ...).
    Elementwise,
    /// Transcendental elementwise ops (exp, tanh, ...).
    Activation,
    /// Row reductions as in layer/batch norm statistics.
    Normalization,
    /// Windowed reductions (`reduce_window`).
    Pooling,
    /// Pure data relayout (transpose, reshape, ...).
    DataMovement,
}

impl SweepOpClass {
    /// Every class, in reporting order.
    pub const ALL: [SweepOpClass; 7] = [
        SweepOpClass::Matmul,
        SweepOpClass::Conv,
        SweepOpClass::Elementwise,
        SweepOpClass::Activation,
        SweepOpClass::Normalization,
        SweepOpClass::Pooling,
        SweepOpClass::DataMovement,
    ];

    /// Stable lowercase name (CLI `--ops` values, CSV/JSON keys).
    pub fn name(&self) -> &'static str {
        match self {
            SweepOpClass::Matmul => "matmul",
            SweepOpClass::Conv => "conv",
            SweepOpClass::Elementwise => "elementwise",
            SweepOpClass::Activation => "activation",
            SweepOpClass::Normalization => "normalization",
            SweepOpClass::Pooling => "pooling",
            SweepOpClass::DataMovement => "data-movement",
        }
    }

    /// Parse one `--ops` element.
    pub fn parse(s: &str) -> Result<SweepOpClass> {
        for class in SweepOpClass::ALL {
            if class.name() == s {
                return Ok(class);
            }
        }
        bail!(
            "unknown op class '{s}' (known: {})",
            SweepOpClass::ALL
                .iter()
                .map(|c| c.name())
                .collect::<Vec<_>>()
                .join(", ")
        )
    }

    /// Parse a comma-separated `--ops` list; `all` (the default) expands
    /// to every class.
    pub fn parse_list(spec: &str) -> Result<Vec<SweepOpClass>> {
        if spec.trim() == "all" {
            return Ok(SweepOpClass::ALL.to_vec());
        }
        let mut out = Vec::new();
        for part in spec.split(',') {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            let class = SweepOpClass::parse(part)?;
            if !out.contains(&class) {
                out.push(class);
            }
        }
        if out.is_empty() {
            bail!("--ops selected no op classes");
        }
        Ok(out)
    }
}

/// Which generated grid to sweep.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum GridSize {
    /// Tight CI/golden-fixture grid (a handful of cases per class).
    Small,
    /// The paper-scale grid (reuses the Fig. 2/3 sweep generators).
    Paper,
}

impl GridSize {
    /// Stable lowercase name.
    pub fn name(&self) -> &'static str {
        match self {
            GridSize::Small => "small",
            GridSize::Paper => "paper",
        }
    }

    /// Parse a `--grid` value.
    pub fn parse(s: &str) -> Result<GridSize> {
        match s {
            "small" => Ok(GridSize::Small),
            "paper" => Ok(GridSize::Paper),
            other => bail!("unknown grid '{other}' (expected small or paper)"),
        }
    }
}

/// One generated sweep case: a classified op plus the descriptive fields
/// the report prints for it.
#[derive(Debug, Clone)]
pub struct SweepCase {
    /// StableHLO-style op name (`dot_general`, `exponential`, ...).
    pub op: String,
    /// Compact shape descriptor (`256x256x256`, `128x1024->128`, ...).
    pub shape: String,
    /// Element type of the case's tensors.
    pub dtype: DType,
    /// Bytes the cost model charges for the case (model traffic for
    /// bandwidth-bound classes, operand+result footprint for systolic).
    pub bytes: u64,
    /// The classified op handed to the batched core.
    pub class: OpClass,
}

/// One case's resolved cost (from the cold pass).
#[derive(Debug, Clone)]
pub struct CaseResult {
    /// The generated case.
    pub case: SweepCase,
    /// Its position-independent cost.
    pub cost: CachedCost,
}

/// Cache and timing accounting for one pass over one class's batch.
#[derive(Debug, Clone, Copy, Default)]
pub struct PassStats {
    /// Cache hits the pass recorded.
    pub hits: u64,
    /// Cache misses the pass recorded.
    pub misses: u64,
    /// Wall-clock the `estimate_classes` call took, µs.
    pub elapsed_us: f64,
}

impl PassStats {
    /// Hits over lookups, in [0, 1].
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }

    /// Estimates per second this pass sustained over `cases` cases.
    pub fn estimates_per_sec(&self, cases: usize) -> f64 {
        if self.elapsed_us <= 0.0 {
            0.0
        } else {
            cases as f64 / (self.elapsed_us * 1e-6)
        }
    }
}

/// Agreement of the estimator with a [`Hardware`] measurement backend
/// over one systolic class (`--measure`).
#[derive(Debug, Clone, Copy)]
pub struct MeasuredStats {
    /// Cases compared.
    pub cases: usize,
    /// Mean absolute relative error of estimate vs measured median.
    pub mare: f64,
}

/// Everything the sweep learned about one op class.
#[derive(Debug, Clone)]
pub struct ClassReport {
    /// The class.
    pub class: SweepOpClass,
    /// Per-case results, grid order (cold-pass costs).
    pub results: Vec<CaseResult>,
    /// Cold-pass accounting (first batch; populates the cache).
    pub cold: PassStats,
    /// Warm-pass accounting (same batch again; served from cache).
    pub warm: PassStats,
    /// Did the warm pass reproduce the cold pass bit for bit?
    pub warm_identical: bool,
    /// Hardware-model agreement, when `--measure` ran.
    pub measured: Option<MeasuredStats>,
}

impl ClassReport {
    /// (min, mean, max, total) of the class's latencies, µs.
    pub fn latency_summary(&self) -> (f64, f64, f64, f64) {
        if self.results.is_empty() {
            return (0.0, 0.0, 0.0, 0.0);
        }
        let mut min = f64::INFINITY;
        let mut max = f64::NEG_INFINITY;
        let mut total = 0.0;
        for r in &self.results {
            min = min.min(r.cost.latency_us);
            max = max.max(r.cost.latency_us);
            total += r.cost.latency_us;
        }
        (min, total / self.results.len() as f64, max, total)
    }
}

/// A full sweep run: every requested class on one device.
#[derive(Debug, Clone)]
pub struct SweepReport {
    /// Device preset/spec name the estimator answered for.
    pub device: String,
    /// The grid that generated the cases.
    pub grid: GridSize,
    /// Per-class reports, in request order.
    pub classes: Vec<ClassReport>,
}

/// An estimator for sweeps: the device's systolic config and HBM
/// bandwidth, with the cycle→latency calibration pinned to an exact
/// synthetic fit (1e-3 µs per cycle, zero intercept, all regimes).
///
/// Pinning the fit makes every sweep number a pure function of the
/// device spec and grid — measured calibrations vary run to run, which
/// would break the golden-CSV fixture.
pub fn sweep_estimator(spec: &DeviceSpec) -> Estimator {
    let exact = LinearFit {
        alpha: 1e-3,
        beta: 0.0,
    };
    let calibration = RegimeCalibration {
        small: exact,
        medium: exact,
        large: exact,
        metrics: Vec::new(),
    };
    Estimator::for_device(spec.clone(), calibration)
}

fn cost_bits(c: &CachedCost) -> (u64, Option<u64>, &'static str, &str) {
    (c.latency_us.to_bits(), c.cycles, c.source.tag(), &c.note)
}

fn run_class(est: &Estimator, class: SweepOpClass, grid: GridSize) -> ClassReport {
    let cases = grid::cases_for(class, grid);
    let op_classes: Vec<OpClass> = cases.iter().map(|c| c.class.clone()).collect();

    let s0 = est.cache.stats();
    let t0 = Instant::now();
    let cold_costs = est.estimate_classes(&op_classes);
    let cold_elapsed = t0.elapsed().as_secs_f64() * 1e6;
    let s1 = est.cache.stats();

    let t1 = Instant::now();
    let warm_costs = est.estimate_classes(&op_classes);
    let warm_elapsed = t1.elapsed().as_secs_f64() * 1e6;
    let s2 = est.cache.stats();

    let warm_identical = cold_costs.len() == warm_costs.len()
        && cold_costs
            .iter()
            .zip(&warm_costs)
            .all(|(a, b)| cost_bits(a) == cost_bits(b));

    ClassReport {
        class,
        results: cases
            .into_iter()
            .zip(cold_costs)
            .map(|(case, cost)| CaseResult { case, cost })
            .collect(),
        cold: PassStats {
            hits: s1.hits - s0.hits,
            misses: s1.misses - s0.misses,
            elapsed_us: cold_elapsed,
        },
        warm: PassStats {
            hits: s2.hits - s1.hits,
            misses: s2.misses - s1.misses,
            elapsed_us: warm_elapsed,
        },
        warm_identical,
        measured: None,
    }
}

/// Run the sweep: every class in `classes`, cold pass then warm pass,
/// through the batched estimator core.
pub fn run_sweep(est: &Estimator, classes: &[SweepOpClass], grid: GridSize) -> SweepReport {
    SweepReport {
        device: est.device().name.clone(),
        grid,
        classes: classes.iter().map(|&c| run_class(est, c, grid)).collect(),
    }
}

/// Run the same sweep on several devices concurrently — one worker per
/// device, joined in input order. Each worker builds its *own*
/// [`sweep_estimator`] with its own cache, never a shared one: the
/// per-class [`PassStats`] are measured as cache-counter deltas and the
/// warm pass must show zero misses per class (CI asserts this), which
/// concurrent sharing would perturb. Every report is therefore
/// bit-identical to a serial [`run_sweep`] on that device alone.
pub fn run_sweep_devices(
    specs: &[DeviceSpec],
    classes: &[SweepOpClass],
    grid: GridSize,
    workers: usize,
) -> Vec<SweepReport> {
    crate::coordinator::parallel_map(specs, workers, |spec| {
        let est = sweep_estimator(spec);
        run_sweep(&est, classes, grid)
    })
}

fn case_gemm(class: &OpClass) -> Option<GemmShape> {
    match class {
        OpClass::SystolicGemm { gemm, .. } | OpClass::SystolicConv { gemm, .. } => Some(*gemm),
        _ => None,
    }
}

/// Attach hardware-model agreement to a finished report: for every
/// systolic case, measure the median GEMM latency on `hw` and record the
/// per-class mean absolute relative error of the estimates against it.
pub fn attach_measurements(report: &mut SweepReport, hw: &mut dyn Hardware, reps: usize) {
    for class_report in &mut report.classes {
        let gemms: Vec<GemmShape> = class_report
            .results
            .iter()
            .filter_map(|r| case_gemm(&r.case.class))
            .collect();
        if gemms.is_empty() {
            continue;
        }
        let measured = measure_gemm_batch_median(hw, &gemms, reps);
        let mut err_sum = 0.0;
        let mut n = 0usize;
        let mut mi = 0usize;
        for r in &class_report.results {
            if case_gemm(&r.case.class).is_none() {
                continue;
            }
            let m = measured[mi];
            mi += 1;
            if m > 0.0 {
                err_sum += (r.cost.latency_us - m).abs() / m;
                n += 1;
            }
        }
        if n > 0 {
            class_report.measured = Some(MeasuredStats {
                cases: n,
                mare: err_sum / n as f64,
            });
        }
    }
}

impl SweepReport {
    /// Deterministic per-case CSV (the golden-fixture format): one row
    /// per case from the cold pass, no timing columns.
    pub fn to_csv(&self) -> String {
        let mut out = String::from("class,op,shape,dtype,bytes,source,cycles,latency_us\n");
        for class_report in &self.classes {
            for r in &class_report.results {
                let cycles = match r.cost.cycles {
                    Some(c) => c.to_string(),
                    None => String::new(),
                };
                out.push_str(&format!(
                    "{},{},{},{},{},{},{},{:.6}\n",
                    class_report.class.name(),
                    r.case.op,
                    r.case.shape,
                    r.case.dtype.name(),
                    r.case.bytes,
                    r.cost.source.tag(),
                    cycles,
                    r.cost.latency_us,
                ));
            }
        }
        out
    }

    /// Full machine-readable report (includes the timing-dependent
    /// throughput numbers the CSV deliberately omits).
    pub fn to_json(&self) -> Json {
        let pass_json = |p: &PassStats, cases: usize| -> Json {
            let mut o = Json::obj();
            o.set("hits", Json::Num(p.hits as f64))
                .set("misses", Json::Num(p.misses as f64))
                .set("hit_rate", Json::Num(p.hit_rate()))
                .set("elapsed_us", Json::Num(p.elapsed_us))
                .set("estimates_per_sec", Json::Num(p.estimates_per_sec(cases)));
            o
        };
        let mut classes = Vec::new();
        for class_report in &self.classes {
            let cases = class_report.results.len();
            let (min, mean, max, total) = class_report.latency_summary();
            let mut sources = Json::obj();
            for r in &class_report.results {
                let tag = r.cost.source.tag();
                let prev = sources.get(tag).and_then(Json::as_f64).unwrap_or(0.0);
                sources.set(tag, Json::Num(prev + 1.0));
            }
            let mut latency = Json::obj();
            latency
                .set("min_us", Json::Num(min))
                .set("mean_us", Json::Num(mean))
                .set("max_us", Json::Num(max))
                .set("total_us", Json::Num(total));
            let mut o = Json::obj();
            o.set("class", Json::Str(class_report.class.name().to_string()))
                .set("cases", Json::Num(cases as f64))
                .set("cold", pass_json(&class_report.cold, cases))
                .set("warm", pass_json(&class_report.warm, cases))
                .set("warm_identical", Json::Bool(class_report.warm_identical))
                .set("latency_us", latency)
                .set("sources", sources);
            if let Some(m) = &class_report.measured {
                let mut mj = Json::obj();
                mj.set("cases", Json::Num(m.cases as f64))
                    .set("mare", Json::Num(m.mare));
                o.set("measured", mj);
            }
            classes.push(o);
        }
        let total_cases: usize = self.classes.iter().map(|c| c.results.len()).sum();
        let mut o = Json::obj();
        o.set("device", Json::Str(self.device.clone()))
            .set("grid", Json::Str(self.grid.name().to_string()))
            .set("total_cases", Json::Num(total_cases as f64))
            .set("classes", Json::Arr(classes));
        o
    }

    /// Human-readable per-class summary table.
    pub fn render(&self) -> String {
        let mut table = Table::new(&[
            "class", "cases", "cold hit%", "warm hit%", "min µs", "mean µs", "max µs",
            "cold est/s", "warm est/s", "bit-identical", "vs hw (MARE)",
        ]);
        for class_report in &self.classes {
            let cases = class_report.results.len();
            let (min, mean, max, _) = class_report.latency_summary();
            table.row(&[
                class_report.class.name().to_string(),
                cases.to_string(),
                format!("{:.1}", class_report.cold.hit_rate() * 100.0),
                format!("{:.1}", class_report.warm.hit_rate() * 100.0),
                format!("{min:.3}"),
                format!("{mean:.3}"),
                format!("{max:.3}"),
                format!("{:.0}", class_report.cold.estimates_per_sec(cases)),
                format!("{:.0}", class_report.warm.estimates_per_sec(cases)),
                if class_report.warm_identical { "yes" } else { "NO" }.to_string(),
                match &class_report.measured {
                    Some(m) => format!("{:.1}%", m.mare * 100.0),
                    None => "-".to_string(),
                },
            ]);
        }
        format!(
            "sweep: device={} grid={}\n{}",
            self.device,
            self.grid.name(),
            table.markdown()
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_list_accepts_all_and_rejects_unknown() {
        assert_eq!(SweepOpClass::parse_list("all").unwrap().len(), 7);
        let picked = SweepOpClass::parse_list("matmul,conv").unwrap();
        assert_eq!(picked, vec![SweepOpClass::Matmul, SweepOpClass::Conv]);
        let err = SweepOpClass::parse_list("matmul,bogus").unwrap_err();
        assert!(err.to_string().contains("unknown op class 'bogus'"));
        assert!(err.to_string().contains("matmul"));
    }

    #[test]
    fn grid_parse_round_trips() {
        assert_eq!(GridSize::parse("small").unwrap(), GridSize::Small);
        assert_eq!(GridSize::parse("paper").unwrap(), GridSize::Paper);
        assert!(GridSize::parse("huge").is_err());
    }

    #[test]
    fn small_sweep_is_deterministic_and_warm_identical() {
        let spec = DeviceSpec::tpu_v4();
        let est_a = sweep_estimator(&spec);
        let est_b = sweep_estimator(&spec);
        let a = run_sweep(&est_a, &SweepOpClass::ALL, GridSize::Small);
        let b = run_sweep(&est_b, &SweepOpClass::ALL, GridSize::Small);
        assert_eq!(a.to_csv(), b.to_csv());
        for class_report in &a.classes {
            assert!(
                class_report.warm_identical,
                "{:?} warm pass diverged",
                class_report.class
            );
            assert_eq!(class_report.warm.misses, 0, "warm pass missed the cache");
        }
    }

    #[test]
    fn cold_pass_misses_once_per_unique_systolic_shape() {
        let spec = DeviceSpec::tpu_v4();
        let est = sweep_estimator(&spec);
        let report = run_sweep(&est, &[SweepOpClass::Matmul], GridSize::Small);
        let class_report = &report.classes[0];
        let cases = class_report.results.len() as u64;
        assert_eq!(class_report.cold.misses, cases, "small matmul grid is dedup-free");
        assert_eq!(class_report.cold.hits, 0);
        assert_eq!(class_report.warm.hits, cases);
    }

    #[test]
    fn csv_has_one_row_per_case_and_stable_header() {
        let spec = DeviceSpec::tpu_v4();
        let est = sweep_estimator(&spec);
        let report = run_sweep(
            &est,
            &[SweepOpClass::Matmul, SweepOpClass::Pooling],
            GridSize::Small,
        );
        let csv = report.to_csv();
        let mut lines = csv.lines();
        assert_eq!(
            lines.next().unwrap(),
            "class,op,shape,dtype,bytes,source,cycles,latency_us"
        );
        let expected: usize = report.classes.iter().map(|c| c.results.len()).sum();
        assert_eq!(lines.count(), expected);
    }

    #[test]
    fn json_report_carries_hit_rates_and_sources() {
        let spec = DeviceSpec::tpu_v4();
        let est = sweep_estimator(&spec);
        let report = run_sweep(&est, &[SweepOpClass::Elementwise], GridSize::Small);
        let json = report.to_json();
        assert_eq!(json.get("grid").and_then(Json::as_str), Some("small"));
        let classes = json.get("classes").and_then(Json::as_arr).unwrap();
        assert_eq!(classes.len(), 1);
        let c = &classes[0];
        assert_eq!(c.get("class").and_then(Json::as_str), Some("elementwise"));
        assert_eq!(
            c.get("warm").and_then(|w| w.get("hit_rate")).and_then(Json::as_f64),
            Some(1.0)
        );
        // No learned models in the sweep estimator: everything falls back.
        assert!(c
            .get("sources")
            .and_then(|s| s.get("fallback"))
            .and_then(Json::as_f64)
            .is_some());
    }

    #[test]
    fn measured_stats_cover_systolic_classes() {
        let spec = DeviceSpec::tpu_v4();
        let est = sweep_estimator(&spec);
        let mut report = run_sweep(
            &est,
            &[SweepOpClass::Matmul, SweepOpClass::Elementwise],
            GridSize::Small,
        );
        let mut hw = crate::tpu::TpuV4Model::for_device(&spec, 7);
        attach_measurements(&mut report, &mut hw, 3);
        assert!(report.classes[0].measured.is_some(), "matmul gets measured");
        assert!(report.classes[1].measured.is_none(), "elementwise does not");
        let m = report.classes[0].measured.unwrap();
        assert_eq!(m.cases, report.classes[0].results.len());
        assert!(m.mare.is_finite());
    }
}
