//! Elementwise shape sweeps and training-set samplers (§4.2).
//!
//! * Fig. 3 exploratory sweeps: 1-D lengths 32–8192 (step 32) and 2-D
//!   shapes 64–1024 per dim (step 64).
//! * Training data: total sizes sampled log-uniformly up to ~16M
//!   elements, multiple factorizations per size, plus deliberate 2ⁿ
//!   boundary cases — exactly the dataset construction the paper
//!   describes.

use crate::util::prng::Prng;

/// Fig. 3a: 1-D lengths 32..=8192 step 32.
pub fn sweep_1d() -> Vec<Vec<usize>> {
    (32..=8192usize).step_by(32).map(|l| vec![l]).collect()
}

/// Fig. 3b: 2-D shapes, each dim 64..=1024 step 64.
pub fn sweep_2d() -> Vec<Vec<usize>> {
    let vals: Vec<usize> = (64..=1024).step_by(64).collect();
    let mut out = Vec::with_capacity(vals.len() * vals.len());
    for &a in &vals {
        for &b in &vals {
            out.push(vec![a, b]);
        }
    }
    out
}

/// Maximum training tensor size (~16M elements).
pub const MAX_TRAIN_ELEMS: u64 = 16 * 1024 * 1024;

/// Sample `n` training shapes: log-uniform sizes, varied factorizations,
/// and 2ⁿ boundary cases. Deterministic in `seed`.
pub fn sample_training_shapes(n: usize, seed: u64) -> Vec<Vec<usize>> {
    sample_training_shapes_bounded(n, seed, MAX_TRAIN_ELEMS)
}

/// As [`sample_training_shapes`] but with a custom size cap (the PJRT
/// backend uses a smaller cap to keep real executions fast).
pub fn sample_training_shapes_bounded(n: usize, seed: u64, max_elems: u64) -> Vec<Vec<usize>> {
    let mut prng = Prng::new(seed);
    let max_pow = (max_elems as f64).log2().floor() as i64;
    let mut shapes = Vec::with_capacity(n);
    for i in 0..n {
        // Pick a size: mostly log-uniform, with a slice of the budget on
        // power-of-two boundaries (and off-by-one neighbours).
        let size = match i % 5 {
            0 => 1u64 << prng.int_range(5, max_pow),
            1 => {
                let p = 1i64 << prng.int_range(5, max_pow);
                (p + prng.int_range(-1, 1)).max(16) as u64
            }
            _ => prng.log_uniform(32.0, max_elems as f64).round() as u64,
        };
        let size = size.clamp(16, max_elems);
        shapes.push(factorize(size, &mut prng));
    }
    shapes
}

/// Produce a random factorization of `size` into 1–3 dims.
///
/// Multiple calls with the same size can yield different shapes, giving
/// the dataset "multiple factorizations of the same total element count".
pub fn factorize(size: u64, prng: &mut Prng) -> Vec<usize> {
    let rank = 1 + prng.index(3); // 1..=3
    if rank == 1 || size < 4 {
        return vec![size as usize];
    }
    // Split a roughly-random divisor off for each extra dim.
    let mut dims: Vec<usize> = Vec::with_capacity(rank);
    let mut rest = size;
    for _ in 0..rank - 1 {
        let d = random_divisor(rest, prng);
        dims.push(d as usize);
        rest /= d;
    }
    dims.push(rest as usize);
    // Randomise which dim is minor (layout-relevant on TPU) — but mostly
    // keep a reasonably wide minor dim, as real ML tensors (and the
    // layouts XLA actually picks) do; a small fraction of degenerate
    // minors (1–2 wide) is retained as boundary cases.
    prng.shuffle(&mut dims);
    let keep_degenerate = size <= (1 << 16) && prng.uniform() < 0.2;
    if dims.last().copied().unwrap_or(1) < 8 && !keep_degenerate {
        let max_pos = dims
            .iter()
            .enumerate()
            .max_by_key(|(_, &d)| d)
            .map(|(i, _)| i)
            .unwrap();
        let last = dims.len() - 1;
        dims.swap(max_pos, last);
    }
    dims
}

/// A divisor of `n`, biased toward mid-sized factors.
fn random_divisor(n: u64, prng: &mut Prng) -> u64 {
    if n <= 3 {
        return 1;
    }
    // Try a few random candidates near sqrt-scale; fall back to small
    // divisors.
    let target = prng.log_uniform(2.0, (n as f64).sqrt().max(2.0)).round() as u64;
    // Scan outward from target for an actual divisor.
    for delta in 0..target.max(8) {
        for cand in [target.saturating_sub(delta), target + delta] {
            if cand >= 2 && cand <= n && n % cand == 0 {
                return cand;
            }
        }
    }
    1
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sweep_1d_matches_paper() {
        let s = sweep_1d();
        assert_eq!(s.len(), 256);
        assert_eq!(s[0], vec![32]);
        assert_eq!(s[255], vec![8192]);
    }

    #[test]
    fn sweep_2d_matches_paper() {
        let s = sweep_2d();
        assert_eq!(s.len(), 256);
        assert_eq!(s[0], vec![64, 64]);
        assert_eq!(s[255], vec![1024, 1024]);
    }

    #[test]
    fn factorize_preserves_size() {
        let mut prng = Prng::new(5);
        for size in [16u64, 97, 1024, 65_536, 16_777_216, 999_983] {
            for _ in 0..20 {
                let dims = factorize(size, &mut prng);
                let product: u64 = dims.iter().map(|&d| d as u64).product();
                assert_eq!(product, size, "{dims:?}");
                assert!(dims.len() <= 3);
                assert!(dims.iter().all(|&d| d >= 1));
            }
        }
    }

    #[test]
    fn training_sizes_bounded_and_diverse() {
        let shapes = sample_training_shapes(2000, 42);
        assert_eq!(shapes.len(), 2000);
        let mut sizes = std::collections::BTreeSet::new();
        let mut pow2 = 0usize;
        for s in &shapes {
            let n: u64 = s.iter().map(|&d| d as u64).product();
            assert!(n >= 16 && n <= MAX_TRAIN_ELEMS);
            sizes.insert(n);
            if n.is_power_of_two() {
                pow2 += 1;
            }
        }
        assert!(sizes.len() > 800, "distinct sizes {}", sizes.len());
        // ~20% of the budget targets 2^n exactly.
        assert!(pow2 > 200, "pow2 cases {pow2}");
    }

    #[test]
    fn training_has_repeated_sizes_with_different_shapes() {
        let shapes = sample_training_shapes(3000, 7);
        let mut by_size: std::collections::BTreeMap<u64, std::collections::BTreeSet<Vec<usize>>> =
            Default::default();
        for s in &shapes {
            let n: u64 = s.iter().map(|&d| d as u64).product();
            by_size.entry(n).or_default().insert(s.clone());
        }
        let multi = by_size.values().filter(|set| set.len() > 1).count();
        assert!(multi > 20, "sizes with multiple factorizations: {multi}");
    }

    #[test]
    fn deterministic_in_seed() {
        assert_eq!(sample_training_shapes(50, 1), sample_training_shapes(50, 1));
        assert_ne!(sample_training_shapes(50, 1), sample_training_shapes(50, 2));
    }
}
