//! Workload generators: the paper's GEMM sweeps ([`gemm_sweep`]), the
//! elementwise shape sweeps and training samplers ([`elementwise_sweep`]),
//! and whole-model topologies ([`models`]).

pub mod elementwise_sweep;
pub mod gemm_sweep;
pub mod models;
