//! The paper's GEMM parameter sweeps (§4.1.1).
//!
//! For each size regime the paper sweeps *each of the three dimensions*
//! (M, K, N) over the regime's range with a fixed step, holding the other
//! two at regime baselines. We generate exactly those per-dimension
//! sweeps at three baselines (low / mid / high) plus the cube diagonal,
//! de-duplicated — a few dozen distinct shapes per regime, matching the
//! paper's per-regime sample counts in spirit.

use std::collections::BTreeSet;

use crate::calibrate::Regime;
use crate::scalesim::topology::GemmShape;

/// All dimension values of a regime's sweep.
pub fn regime_values(regime: Regime) -> Vec<usize> {
    let (lo, hi, step) = regime.sweep_range();
    (lo..=hi).step_by(step).collect()
}

/// Baselines (low, mid, high) used for the two non-swept dims.
fn baselines(regime: Regime) -> [usize; 3] {
    let vals = regime_values(regime);
    [vals[0], vals[vals.len() / 2], vals[vals.len() - 1]]
}

/// The per-regime sweep: per-dimension sweeps at each baseline plus the
/// (d, d, d) diagonal; sorted and de-duplicated.
pub fn regime_sweep(regime: Regime) -> Vec<GemmShape> {
    let vals = regime_values(regime);
    let mut set: BTreeSet<(usize, usize, usize)> = BTreeSet::new();
    for &b in &baselines(regime) {
        for &v in &vals {
            set.insert((v, b, b)); // sweep M
            set.insert((b, v, b)); // sweep K
            set.insert((b, b, v)); // sweep N
        }
    }
    for &v in &vals {
        set.insert((v, v, v)); // diagonal
    }
    // Regime ranges share endpoints (128, 1024); keep only shapes that
    // classify back into this regime so the per-regime fits are clean.
    set.into_iter()
        .map(|(m, k, n)| GemmShape::new(m, k, n))
        .filter(|g| Regime::of_gemm(g) == regime)
        .collect()
}

/// The full three-regime sweep of Fig. 2.
pub fn full_sweep() -> Vec<(Regime, GemmShape)> {
    let mut out = Vec::new();
    for regime in Regime::ALL {
        for g in regime_sweep(regime) {
            out.push((regime, g));
        }
    }
    out
}

/// Held-out evaluation shapes for Fig. 4 (cycle-to-latency accuracy):
/// off-sweep shapes (midpoints between sweep steps, skewed aspect ratios)
/// across all regimes.
pub fn heldout_shapes() -> Vec<GemmShape> {
    let mut out = Vec::new();
    for regime in Regime::ALL {
        let (lo, hi, step) = regime.sweep_range();
        // Off-grid: midpoints between sweep values.
        let mid_step = step / 2;
        let mut v = lo + mid_step;
        while v < hi {
            out.push(GemmShape::new(v, v, v));
            v += step;
        }
        // Skewed aspect ratios inside the regime.
        let a = lo + step;
        let b = hi - step;
        out.push(GemmShape::new(b, a, a));
        out.push(GemmShape::new(a, b, a));
        out.push(GemmShape::new(a, a, b));
        out.push(GemmShape::new(b, b, a));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn regime_values_match_paper() {
        assert_eq!(regime_values(Regime::Small), vec![32, 48, 64, 80, 96, 112, 128]);
        let med = regime_values(Regime::Medium);
        assert_eq!(med.first(), Some(&128));
        assert_eq!(med.last(), Some(&1024));
        assert_eq!(med.len(), 8);
        let large = regime_values(Regime::Large);
        assert_eq!(large, vec![1024, 1536, 2048, 2560, 3072, 3584, 4096]);
    }

    #[test]
    fn sweep_shapes_stay_in_regime() {
        for regime in Regime::ALL {
            for g in regime_sweep(regime) {
                assert_eq!(Regime::of_gemm(&g), regime, "{g}");
            }
        }
    }

    #[test]
    fn sweep_has_reasonable_coverage() {
        for regime in Regime::ALL {
            let n = regime_sweep(regime).len();
            assert!(n >= 40, "{regime}: {n} shapes");
            assert!(n <= 80, "{regime}: {n} shapes");
        }
    }

    #[test]
    fn sweep_is_deduplicated() {
        let shapes = regime_sweep(Regime::Small);
        let mut sorted: Vec<_> = shapes.iter().map(|g| (g.m, g.k, g.n)).collect();
        sorted.sort_unstable();
        let len = sorted.len();
        sorted.dedup();
        assert_eq!(sorted.len(), len);
    }

    #[test]
    fn heldout_disjoint_from_sweep() {
        let sweep: std::collections::BTreeSet<(usize, usize, usize)> = full_sweep()
            .into_iter()
            .map(|(_, g)| (g.m, g.k, g.n))
            .collect();
        for g in heldout_shapes() {
            assert!(!sweep.contains(&(g.m, g.k, g.n)), "{g} leaked into held-out");
        }
    }

    #[test]
    fn heldout_covers_all_regimes() {
        let shapes = heldout_shapes();
        for regime in Regime::ALL {
            assert!(shapes.iter().any(|g| Regime::of_gemm(g) == regime));
        }
    }
}
