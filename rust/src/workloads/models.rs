//! Whole-model workload topologies used by examples and the end-to-end
//! evaluation: a small MLP and a transformer block, both also authored in
//! JAX on the Python side (python/compile/model.py) so the StableHLO
//! frontend can be fed the *compiler's* view of the same models.

use crate::scalesim::topology::{GemmShape, Layer, Topology};

/// A 3-layer MLP classifier head: 784 → 512 → 256 → 10 at batch `b`.
pub fn mlp(batch: usize) -> Topology {
    Topology {
        name: format!("mlp_b{batch}"),
        layers: vec![
            Layer::Gemm {
                name: "fc1".into(),
                shape: GemmShape::new(batch, 784, 512),
            },
            Layer::Gemm {
                name: "fc2".into(),
                shape: GemmShape::new(batch, 512, 256),
            },
            Layer::Gemm {
                name: "fc3".into(),
                shape: GemmShape::new(batch, 256, 10),
            },
        ],
    }
}

/// The GEMMs of one transformer block (d_model, heads, seq, ffn multiple
/// 4): QKV projections, attention scores and values, output projection,
/// and the two FFN matmuls. Elementwise/softmax ops are added by the
/// StableHLO path; this topology covers the systolic part.
pub fn transformer_block(seq: usize, d_model: usize, heads: usize) -> Topology {
    assert!(d_model % heads == 0);
    let d_head = d_model / heads;
    let mut layers = vec![
        Layer::Gemm {
            name: "qkv_proj".into(),
            shape: GemmShape::new(seq, d_model, 3 * d_model),
        },
        Layer::Gemm {
            name: "out_proj".into(),
            shape: GemmShape::new(seq, d_model, d_model),
        },
        Layer::Gemm {
            name: "ffn_up".into(),
            shape: GemmShape::new(seq, d_model, 4 * d_model),
        },
        Layer::Gemm {
            name: "ffn_down".into(),
            shape: GemmShape::new(seq, 4 * d_model, d_model),
        },
    ];
    // Per-head attention GEMMs (scores: seq×d_head×seq; values:
    // seq×seq×d_head), repeated `heads` times.
    for h in 0..heads {
        layers.push(Layer::Gemm {
            name: format!("attn_scores_h{h}"),
            shape: GemmShape::new(seq, d_head, seq),
        });
        layers.push(Layer::Gemm {
            name: format!("attn_values_h{h}"),
            shape: GemmShape::new(seq, seq, d_head),
        });
    }
    Topology {
        name: format!("transformer_s{seq}_d{d_model}_h{heads}"),
        layers,
    }
}

/// A ResNet-ish convolutional stem, in the classic SCALE-Sim CSV format.
pub fn resnet_stem_csv() -> &'static str {
    "Layer, IFMAP H, IFMAP W, Filt H, Filt W, Channels, Num Filters, Stride,\n\
     conv1, 224, 224, 7, 7, 3, 64, 2,\n\
     conv2_1, 56, 56, 3, 3, 64, 64, 1,\n\
     conv2_2, 56, 56, 3, 3, 64, 64, 1,\n\
     conv3_1, 56, 56, 1, 1, 64, 128, 2,\n\
     conv3_2, 28, 28, 3, 3, 128, 128, 1,\n"
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scalesim::topology::Topology as T;

    #[test]
    fn mlp_layer_shapes() {
        let t = mlp(32);
        assert_eq!(t.layers.len(), 3);
        assert_eq!(t.layers[0].as_gemm(), GemmShape::new(32, 784, 512));
        assert_eq!(t.total_macs(), 32 * (784 * 512 + 512 * 256 + 256 * 10));
    }

    #[test]
    fn transformer_block_macs() {
        let t = transformer_block(128, 256, 4);
        // 4 projection/FFN GEMMs + 2 per head.
        assert_eq!(t.layers.len(), 4 + 8);
        let expected: u64 = (128 * 256 * 768
            + 128 * 256 * 256
            + 128 * 256 * 1024
            + 128 * 1024 * 256) as u64
            + 4 * (128u64 * 64 * 128 + 128 * 128 * 64);
        assert_eq!(t.total_macs(), expected);
    }

    #[test]
    fn resnet_csv_parses() {
        let t = T::parse_csv("resnet_stem", resnet_stem_csv()).unwrap();
        assert_eq!(t.layers.len(), 5);
        assert!(t.total_macs() > 100_000_000);
    }
}
