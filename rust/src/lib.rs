//! # SCALE-Sim TPU
//!
//! A validated and extended SCALE-Sim for TPU-style accelerators,
//! reproducing *"SCALE-Sim TPU: Validating and Extending SCALE-Sim for
//! TPUs"* (Dang et al., 2026) as a three-layer Rust + JAX + Pallas system.
//!
//! The crate contains:
//!
//! * [`scalesim`] — the cycle-accurate systolic-array simulator substrate
//!   (SCALE-Sim v3 rebuilt in Rust): dataflows, SRAM/DRAM model, conv,
//!   multi-core partitioning.
//! * [`frontend`] — the StableHLO text parser and operator classifier
//!   (the paper's framework-agnostic interface).
//! * [`learned`] — histogram-based gradient-boosting regression, written
//!   from scratch, for non-systolic elementwise-operator latency.
//! * [`calibrate`] — the cycle→time linear calibration and fit metrics.
//! * [`device`] — the unified device-model layer: one [`device::DeviceSpec`]
//!   (presets + TOML/JSON loader) that every subsystem derives its
//!   hardware constants from.
//! * [`tpu`] — the measurement substrate: a synthetic TPU v4 device model
//!   (hardware substitute, see DESIGN.md) and a PJRT-backed harness that
//!   times real executions.
//! * [`runtime`] — the PJRT CPU client wrapper that loads AOT-compiled
//!   HLO artifacts produced by the Python build path.
//! * [`coordinator`] — the L3 orchestrator: job queue, worker pool,
//!   operator routing and whole-model latency aggregation.
//! * [`distributed`] — multi-chip slice simulation: the ICI collective
//!   cost model and the per-chip timeline that overlaps collectives
//!   with compute.
//! * [`graph`] — the SSA dependence DAG and the multi-engine list
//!   scheduler (MXU/VPU/DMA/ICI) with critical-path and slack analysis.
//! * [`memory`] — the memory-aware DMA timeline: HBM traffic behind
//!   every op, tensor residency (bounded buffer, LRU eviction) and the
//!   compute-vs-bandwidth roofline.
//! * [`inference`] — the request-level LLM serving simulator: decoder
//!   prefill/decode phase model, pinned growing KV-cache residency, and
//!   a continuous-batching scheduler reporting tokens/sec, TTFT, TPOT
//!   and latency percentiles per device preset.
//! * [`obs`] — dependency-free observability: atomic counter/gauge/
//!   histogram registry, injectable-clock span recorder, and Prometheus
//!   text / Chrome trace-event exporters.
//! * [`workloads`] — the paper's sweep generators.
//! * [`sweep`] — the op-coverage validation harness: deterministic
//!   per-class shape grids driven through the batched estimator core,
//!   with cache hit-rate, throughput and bit-identity reporting.
//! * [`report`] — tables, CSV and ASCII scatter plots for every figure.
//! * [`benchgate`] — the aggregated freshness gate over every published
//!   benchmark artifact (`bench --check-all`), with a perf-trajectory
//!   table.
//! * [`util`] — std-only infrastructure (JSON, PRNG, stats, args).

#![warn(missing_docs)]

pub mod benchgate;
pub mod calibrate;
pub mod coordinator;
pub mod device;
pub mod distributed;
pub mod experiments;
pub mod frontend;
pub mod graph;
pub mod inference;
pub mod learned;
pub mod memory;
pub mod obs;
pub mod report;
pub mod runtime;
pub mod scalesim;
pub mod sweep;
pub mod tpu;
pub mod workloads;
pub mod util;
