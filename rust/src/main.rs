//! SCALE-Sim TPU command-line interface (the L3 leader binary).
//!
//! Subcommands map 1:1 to the paper's artifacts and toolchain entry
//! points; run `scalesim-tpu help` for the full list.

use std::path::PathBuf;
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use scalesim_tpu::benchgate;
use scalesim_tpu::calibrate::Regime;
use scalesim_tpu::coordinator::{
    bench_serve, default_workers, install_sigint_drain, load_snapshot, parallel_map,
    save_snapshot, serve_lines, serve_stream, NetOptions, NetServer, ServeMetrics,
    StreamOptions,
};
use scalesim_tpu::device::{load_device_file, resolve_device, DeviceSpec, PRESET_NAMES};
use scalesim_tpu::distributed::{
    estimate_gemm_sliced, estimate_module_distributed, estimate_module_distributed_memory,
    DistributedEstimate, IciTopology, SliceConfig,
};
use scalesim_tpu::experiments::{assets, fig2, fig3, fig4, fig5, table1};
use scalesim_tpu::frontend::parse_module;
use scalesim_tpu::graph::{schedule_estimate, EngineConfig, ModuleSchedule};
use scalesim_tpu::inference::{
    self, generate_workload, phase_csv, simulate, KvCacheSpec, LlmBenchOptions, PhaseModel,
    SimConfig, WorkloadConfig,
};
use scalesim_tpu::memory::{schedule_estimate_memory, MemoryConfig, MemorySchedule};
use scalesim_tpu::obs::{MetricsScrape, MonotonicClock, TraceEvent, TraceFileWriter};
use scalesim_tpu::report::{write_output, Table};
use scalesim_tpu::util::json::Json;
use scalesim_tpu::scalesim::{simulate_gemm, simulate_topology, GemmShape, Topology};
use scalesim_tpu::sweep;
use scalesim_tpu::tpu::{Hardware, PjrtHardware, TpuV4Model};
use scalesim_tpu::util::args::Args;

const HELP: &str = "\
scalesim-tpu — validated & extended SCALE-Sim for TPUs (paper reproduction)

USAGE: scalesim-tpu <subcommand> [options]

Paper artifacts:
  table1                     print Table 1 (+ live capability check)
  fig2                       cycles→latency regressions, 3 regimes
  fig3                       elementwise-add latency sweeps (1D/2D)
  fig4                       held-out cycle-to-latency accuracy
  fig5                       learned elementwise models (add, ReLU)
  all                        run every artifact in sequence

Toolchain:
  simulate --m M --k K --n N     simulate one GEMM (cycles + latency)
           [--energy] [--sparsity D] [--trace out.csv]
  simulate --topology FILE.csv   simulate a SCALE-Sim CSV topology
  simulate --module FILE.txt     estimate a StableHLO module end to end:
                                   reports the unfused sum, the fused
                                   bracket and the overlap-aware multi-
                                   engine (MXU/VPU/DMA/ICI) schedule with
                                   critical path, per-op slack and
                                   per-engine utilization
           [--json]                emit the full per-op table (incl.
                                   schedule start/end and engine) as one
                                   JSON object
           [--timeline]            print the serialized schedule timeline
                                   (with --memory also the expanded
                                   DMA-in/compute/DMA-out timeline)
           [--fused]               (kept for compat; the fused total is
                                   always reported now)
           [--memory]              memory-aware DMA timeline: every op's
                                   cold operands pay HBM traffic on the
                                   DMA engine, values consumed while
                                   resident (bounded LRU buffer) skip the
                                   re-fetch; reports makespan, residency
                                   stats and the compute-vs-bandwidth
                                   roofline (works with --chips too)
           [--vmem-mb MB]          residency buffer for --memory; override
                                   applied on top of the --device spec
                                   (tpu-v4: 32 MiB)
           [--hbm-gbps G]          HBM bandwidth for --memory; override on
                                   top of the spec (tpu-v4: 1200 GB/s)
           [--chips N]             distribute across an N-chip slice:
           [--ici-gbps G]          per-link ICI bandwidth; override on top
                                   of the spec (tpu-v4: 100)
           [--ici-topology T]      ring | torus | XxY (default: the spec's
                                   wiring; tpu-v4: ring)
           [--ici-latency-us A]    per-hop latency; override on top of the
                                   spec (tpu-v4: 1.0); prints per-chip
                                   busy time, collective time and
                                   parallel efficiency
           [--trace-out FILE]      export the scheduled timeline as Chrome
                                   trace-event JSON (open in Perfetto or
                                   chrome://tracing): one lane per engine
                                   (MXU/VPU/DMA/ICI), critical-path ops
                                   flagged; with --memory the DMA lane
                                   shows each op's dma_in/dma_out
                                   sub-slices and spills; with --chips the
                                   per-chip compute/ici/dma lanes
  calibrate                      build + save modeling assets
  devices                        list the device presets; --check [--dir D]
                                 round-trips every rust/devices/*.toml|json
                                 file through the loader and verifies the
                                 preset-named ones match the registry
  compare --module FILE          estimate one module against several device
          --devices a,b,c          specs side by side (presets or device
          [--chips N] [--json]     files; default: every preset); reports
          [--trace-dir DIR]        unfused/scheduled/memory-aware totals
          [--llm]                  per device, plus the distributed slice
                                   when --chips is given; --llm adds the
                                   serving columns (prefill/decode step,
                                   tokens/sec, TTFT p50) from a fixed
                                   seeded stream per device; --trace-dir
                                   writes one Chrome trace per device
                                   (DIR/<device>.trace.json, memory-aware
                                   lanes; with --chips also
                                   DIR/<device>.slice.trace.json)
  sweep [--ops a,b,c]            op-coverage validation sweep: deterministic
        [--grid small|paper]       generated shape grids per op class, run
        [--json | --csv]           cold + warm through the batched estimator
        [--measure]                core; reports per-class latency
        [--devices a,b,c]          distributions, cache hit rates,
        [--workers N]              estimates/sec and cold/warm bit-identity.
                                   --ops picks classes (default all: matmul,
                                   conv, elementwise, activation,
                                   normalization, pooling, data-movement);
                                   --csv emits the deterministic per-case
                                   table (the golden-fixture format), --json
                                   the full report incl. throughput;
                                   --measure also scores systolic estimates
                                   against the --hardware backend (median of
                                   --reps, MARE per class); --devices fans
                                   the sweep out over several specs at once
                                   (one worker per device, per-device cache,
                                   reports in list order, byte-identical to
                                   serial runs; incompatible with --measure)
  llm --module FILE              request-level LLM serving simulation of a
      [--device P]                 decoder block: the module runs as prefill
      [--requests N] [--seed S]    (full-sequence) and decode (the sequence-1
      [--max-batch B]              lowering; verdicts pinned per preset)
      [--prompt-min/max T]         through the scheduler + memory timeline;
      [--output-min/max T]         a seeded arrival stream is served with
      [--gap-us G]                 continuous batching (prefills admitted
      [--layers L] [--kv-mb MB]    into the running decode batch) while each
      [--json]                     request's KV cache grows as a pinned
      [--trace-out FILE]           value in the residency tracker (spilling
      [--phase-csv]                to HBM when it outgrows --kv-mb, default
                                   the device VMEM). Reports tokens/sec,
                                   TTFT, TPOT and latency percentiles;
                                   --trace-out writes one Chrome-trace lane
                                   per request (queued/prefill/decode);
                                   --phase-csv prints the per-preset
                                   prefill/decode golden table instead
  serve [--input FILE.jsonl]     streaming request service (JSONL in/out);
        [--workers N]              reads stdin when no --input is given and
        [--queue N]                answers incrementally, in order, through
        [--batch] [--quiet]        a sharded shape cache. {"type":"stats"}
                                   requests report cache/routing counters;
                                   a summary goes to stderr on shutdown
                                   (--quiet suppresses it). --batch restores
                                   the legacy slurp-whole-input mode; --queue
                                   bounds the in-flight job queue (default
                                   4 x workers). Requests may carry a
                                   "device" field naming any preset; the
                                   shared shape cache keys on the device
                                   fingerprint so mixed streams never alias.
        [--listen ADDR:PORT]       serve over TCP instead: accepts many
                                   concurrent connections (JSONL per
                                   connection, same schema), answers each
                                   connection in its own request order over
                                   one shared worker pool + shape cache.
                                   Graceful drain on SIGINT or a
                                   {"type":"shutdown"} admin request: stop
                                   accepting, answer in-flight requests,
                                   emit the summary.
        [--inflight N]             per-connection in-flight cap (default 64);
                                   bounds each connection's write queue so a
                                   slow reader never stalls the others
        [--cache-snapshot FILE]    load the shape cache from FILE at startup
                                   (versioned + fingerprint-checked; corrupt
                                   or stale snapshots are rejected loudly and
                                   the server starts cold) and save it back
                                   on drain, so restarts answer warm
        [--metrics ADDR:PORT]      expose a plaintext Prometheus scrape
                                   endpoint (curl/nc it): request counters
                                   by type, per-phase latency histograms
                                   (parse/queue_wait/estimate hit|miss/
                                   reorder/write/total), pool queue-depth
                                   and occupancy gauges, per-shard cache
                                   traffic, per-device timings. Also
                                   enables the {"type":"metrics"} request
        [--trace FILE]             stream every completed request's span
                                   tree (parse -> queue-wait -> estimate ->
                                   reorder -> write) to FILE as Chrome
                                   trace-event JSON; one lane per
                                   connection, open in Perfetto
                                   (implies instrumentation, as --metrics)
  bench-serve                    load-generate against the TCP service and
        [--clients N]              report sustained throughput + p50/p95/p99
        [--requests M]             tail latency. Spins up an in-process
        [--rps R] [--addr A]       server unless --addr targets a remote one;
        [--workers N]              --rps paces the offered load (default:
        [--publish] [--check]      closed-loop flat out). In-process runs
                                   also report the queue-wait vs service-
                                   time breakdown from the serving stack's
                                   phase histograms. --publish writes
                                   BENCH_serve.json at the repo root
                                   (fingerprinted); --check verifies it is
                                   fresh against the bench source (CI gate)
  bench-llm                      run the decoder-block serving sweep over
        [--requests N] [--seed S]  every device preset and report tokens/sec
        [--max-batch B] [--json]   + TTFT + TPOT per preset (plus simulator
        [--publish] [--check]      wall-clock throughput). Presets fan out
        [--workers N]              over a worker pool (--workers, default
                                   auto; rows byte-identical to serial).
                                   --publish writes BENCH_llm.json at the
                                   repo root (fingerprinted); --check
                                   verifies it is fresh against the bench
                                   source (CI gate)
  bench --check-all              run every published-benchmark freshness
                                   gate (BENCH_estimator / BENCH_serve /
                                   BENCH_llm) in one pass and print the
                                   perf-trajectory table (the CI gate)

Common options:
  --device NAME|FILE         device spec every hardware constant derives
                             from: a preset (devices subcommand lists them;
                             default tpu-v4, which reproduces the historical
                             hard-coded constants bit for bit) or a
                             TOML/JSON device file
  --device-file FILE         explicit device-file form of --device
  --hardware model|pjrt      measurement backend (default: model; the
                             synthetic model takes its MXU/VPU constants
                             from --device)
  --seed N                   device-model noise seed (default 42)
  --reps N                   median-of-N measurement (default 5)
  --shapes N                 training shapes for learned models (default 2000)
  --assets DIR               modeling-asset directory (default artifacts/assets)
  --out DIR                  where to write CSV dumps (default results/)
  --dataflow os|ws|is        SCALE-Sim dataflow (default ws)
";

fn main() {
    let args = Args::from_env();
    if let Err(e) = run(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
    let unknown = args.unknown_keys();
    if !unknown.is_empty() {
        eprintln!("warning: unrecognised options: {unknown:?}");
    }
}

/// Resolve `--device <name|file>` / `--device-file FILE` (default: the
/// `tpu-v4` reference preset, which reproduces the historical hard-coded
/// constants bit for bit), folding the `--dataflow` override into the
/// spec so it participates in the cache fingerprint.
fn make_device(args: &Args) -> Result<DeviceSpec> {
    let device_arg = args.get("device").map(str::to_string);
    let device_file = args.get("device-file").map(str::to_string);
    let mut spec = match (device_arg, device_file) {
        (Some(_), Some(_)) => {
            bail!("--device and --device-file are mutually exclusive; pass one")
        }
        (Some(arg), None) => resolve_device(&arg)?,
        (None, Some(path)) => load_device_file(std::path::Path::new(&path))?,
        (None, None) => DeviceSpec::tpu_v4(),
    };
    if let Some(df) = args.get("dataflow") {
        spec.dataflow = scalesim_tpu::scalesim::Dataflow::parse(df)
            .with_context(|| format!("bad dataflow '{df}'"))?;
    }
    spec.validate()?;
    Ok(spec)
}

fn make_hardware(args: &Args, spec: &DeviceSpec) -> Result<Box<dyn Hardware>> {
    match args.str_or("hardware", "model").as_str() {
        "model" => Ok(Box::new(TpuV4Model::for_device(spec, args.u64_or("seed", 42)))),
        "pjrt" => Ok(Box::new(PjrtHardware::new()?)),
        other => bail!("unknown hardware backend '{other}' (model|pjrt)"),
    }
}

fn out_dir(args: &Args) -> PathBuf {
    PathBuf::from(args.str_or("out", "results"))
}

/// Memory config from `--memory/--vmem-mb/--hbm-gbps`; `None` when
/// `--memory` is absent. Precedence: explicit flag > device spec (the
/// `hbm_default` is the estimator's bandwidth, itself spec-derived).
/// The knobs are read unconditionally so they never trip the
/// unknown-option warning.
fn make_memory(
    args: &Args,
    spec: &DeviceSpec,
    hbm_default_bytes_per_us: f64,
) -> Result<Option<MemoryConfig>> {
    let vmem_mb = args.f64_or("vmem-mb", spec.vmem_bytes as f64 / (1024.0 * 1024.0));
    // 1 GB/s == 1e3 bytes/us.
    let bytes_per_us = args.f64_or("hbm-gbps", hbm_default_bytes_per_us / 1e3) * 1e3;
    if !args.flag("memory") {
        return Ok(None);
    }
    // Mirror SliceConfig::validate: a non-positive bandwidth would make
    // DMA costs negative/infinite and silently break the exact
    // compute-only <= memory-aware <= serialized-bound bracket.
    if !bytes_per_us.is_finite() || bytes_per_us <= 0.0 {
        bail!("--hbm-gbps must be a positive number");
    }
    if !vmem_mb.is_finite() || vmem_mb < 0.0 {
        bail!("--vmem-mb must be non-negative");
    }
    let buffer = (vmem_mb * 1024.0 * 1024.0) as u64;
    Ok(Some(MemoryConfig::new(bytes_per_us, Some(buffer))))
}

/// Slice config from `--chips/--ici-*`; `None` when `--chips` is absent.
/// Precedence: explicit flag > device spec.
fn make_slice(args: &Args, spec: &DeviceSpec) -> Result<Option<SliceConfig>> {
    let Some(chips) = args.get("chips") else {
        return Ok(None);
    };
    let chips: usize = chips
        .parse()
        .with_context(|| format!("--chips expects an integer, got '{chips}'"))?;
    let topology = match args.get("ici-topology") {
        Some(t) => IciTopology::parse(t, chips)?,
        None => spec.default_topology(chips),
    };
    let slice = SliceConfig {
        chips,
        topology,
        link_gbps: args.f64_or("ici-gbps", spec.ici_link_gbps),
        hop_latency_us: args.f64_or("ici-latency-us", spec.ici_hop_latency_us),
    };
    slice.validate()?;
    Ok(Some(slice))
}

fn run(args: &Args) -> Result<()> {
    match args.subcommand.as_deref() {
        None | Some("help") => {
            print!("{HELP}");
            Ok(())
        }
        Some("table1") => {
            println!("{}", table1::render());
            Ok(())
        }
        Some("fig2") => cmd_fig2(args),
        Some("fig3") => cmd_fig3(args),
        Some("fig4") => cmd_fig4(args),
        Some("fig5") => cmd_fig5(args),
        Some("all") => {
            println!("{}", table1::render());
            cmd_fig2(args)?;
            cmd_fig3(args)?;
            cmd_fig4(args)?;
            cmd_fig5(args)
        }
        Some("simulate") => cmd_simulate(args),
        Some("calibrate") => cmd_calibrate(args),
        Some("devices") => cmd_devices(args),
        Some("compare") => cmd_compare(args),
        Some("serve") => cmd_serve(args),
        Some("bench-serve") => cmd_bench_serve(args),
        Some("sweep") => cmd_sweep(args),
        Some("llm") => cmd_llm(args),
        Some("bench-llm") => cmd_bench_llm(args),
        Some("bench") => cmd_bench(args),
        Some(other) => bail!("unknown subcommand '{other}' (try 'help')"),
    }
}

fn cmd_fig2(args: &Args) -> Result<()> {
    let spec = make_device(args)?;
    let config = spec.scale_config();
    let mut hw = make_hardware(args, &spec)?;
    let reps = args.usize_or("reps", 5);
    let result = fig2::run(hw.as_mut(), &config, reps);
    println!("{}", fig2::render(&result, hw.name()));
    let csv_path = out_dir(args).join("fig2.csv");
    write_output(&csv_path, &fig2::to_csv(&result))?;
    println!("wrote {}", csv_path.display());
    Ok(())
}

fn cmd_fig3(args: &Args) -> Result<()> {
    let mut hw = make_hardware(args, &make_device(args)?)?;
    let reps = args.usize_or("reps", 5);
    let result = fig3::run(hw.as_mut(), reps);
    println!("{}", fig3::render(&result, hw.name()));
    let csv_path = out_dir(args).join("fig3.csv");
    write_output(&csv_path, &fig3::to_csv(&result))?;
    println!("wrote {}", csv_path.display());
    Ok(())
}

fn cmd_fig4(args: &Args) -> Result<()> {
    let spec = make_device(args)?;
    let config = spec.scale_config();
    let mut hw = make_hardware(args, &spec)?;
    let reps = args.usize_or("reps", 5);
    // Calibrate on the Fig. 2 sweep, evaluate on held-out shapes.
    let f2 = fig2::run(hw.as_mut(), &config, reps);
    let result = fig4::run(hw.as_mut(), &config, &f2.calibration, reps);
    println!("{}", fig4::render(&result, hw.name()));
    let csv_path = out_dir(args).join("fig4.csv");
    write_output(&csv_path, &fig4::to_csv(&result))?;
    println!("wrote {}", csv_path.display());
    Ok(())
}

fn cmd_fig5(args: &Args) -> Result<()> {
    let mut hw = make_hardware(args, &make_device(args)?)?;
    let reps = args.usize_or("reps", 5);
    let shapes = args.usize_or("shapes", 2000);
    let seed = args.u64_or("seed", 42);
    let result = fig5::run(hw.as_mut(), shapes, reps, seed);
    println!("{}", fig5::render(&result, hw.name()));
    let csv_path = out_dir(args).join("fig5.csv");
    write_output(&csv_path, &fig5::to_csv(&result))?;
    println!("wrote {}", csv_path.display());
    Ok(())
}

fn cmd_simulate(args: &Args) -> Result<()> {
    let spec = make_device(args)?;
    let config = spec.scale_config();
    // Read unconditionally so non-module invocations never trip the
    // unknown-option warning (the renderer only applies to --module).
    let _ = args.get("trace-out");

    if let Some(path) = args.get("module") {
        // StableHLO module → whole-model estimate via saved assets. The
        // assets are measured on the reference device; `retarget` then
        // re-derives the estimator for the selected spec (a no-op for
        // the default `tpu-v4`, bit for bit).
        let assets_dir = PathBuf::from(args.str_or("assets", "artifacts/assets"));
        let reference = DeviceSpec::tpu_v4();
        let mut hw = make_hardware(args, &reference)?;
        let est = assets::load_or_build(
            &assets_dir,
            hw.as_mut(),
            &reference,
            args.usize_or("shapes", 1200),
            args.usize_or("reps", 3),
            args.u64_or("seed", 42),
        )?;
        let est = est.retarget(&spec);
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading module {path}"))?;
        let module = parse_module(&text)?;

        if let Some(slice) = make_slice(args, &spec)? {
            let mem = make_memory(args, &spec, est.hbm_bytes_per_us())?;
            let d = match &mem {
                Some(m) => estimate_module_distributed_memory(&est, &module, &slice, m),
                None => estimate_module_distributed(&est, &module, &slice),
            };
            if let Some(tp) = args.get("trace-out") {
                write_trace(tp, &d.trace_events())?;
            }
            if args.flag("json") {
                println!("{}", distributed_json(&d, &spec, &slice, mem.is_some()).dump());
                return Ok(());
            }
            // The `dma us` column appears only under --memory (the
            // memory-blind table keeps its historical shape).
            let mut headers = vec!["#", "op", "compute us", "ici us"];
            if mem.is_some() {
                headers.push("dma us");
            }
            headers.extend(["start us", "finish us", "note"]);
            let mut t = Table::new(&headers);
            for op in &d.ops {
                let mut cells = vec![
                    op.index.to_string(),
                    op.op_name.clone(),
                    format!("{:.3}", op.compute_us),
                    format!("{:.3}", op.collective_us),
                ];
                if mem.is_some() {
                    cells.push(format!("{:.3}", op.dma_us));
                }
                cells.extend([
                    format!("{:.3}", op.start_us),
                    format!("{:.3}", op.finish_us),
                    op.note.clone(),
                ]);
                t.row(&cells);
            }
            println!("{}", t.markdown());
            println!("device: {spec}");
            println!(
                "slice: {} chips ({}, {} GB/s/link, {} us/hop)",
                slice.chips, slice.topology, slice.link_gbps, slice.hop_latency_us
            );
            println!(
                "per-chip busy time: {:.2} us compute, {:.2} us collective (ICI); {:.2} us overlapped",
                d.compute_us,
                d.collective_us,
                d.overlapped_us()
            );
            if mem.is_some() {
                println!(
                    "memory-aware: {:.2} us per-chip dma busy (HBM traffic behind the sharded ops)",
                    d.dma_us
                );
            }
            let util = |busy: f64| {
                if d.total_us > 0.0 {
                    100.0 * busy / d.total_us
                } else {
                    0.0
                }
            };
            println!(
                "critical path {:.2} us; engine utilization: compute {:.1}%, ici {:.1}%",
                d.critical_path_us,
                util(d.compute_us),
                util(d.collective_us)
            );
            println!(
                "module @{}: per-chip makespan {:.2} us; single-chip {:.2} us; speedup {:.2}x; parallel efficiency {:.1}%",
                d.module_name,
                d.total_us,
                d.single_chip_us,
                d.speedup(),
                d.parallel_efficiency() * 100.0
            );
            return Ok(());
        }

        let engines = EngineConfig::for_device(&spec);
        let report = est.estimate_module(&module);
        let fused = scalesim_tpu::coordinator::estimate_fused_with(&module, report.clone());
        let sched = schedule_estimate(&module, &report, engines);
        let mem = make_memory(args, &spec, est.hbm_bytes_per_us())?
            .map(|m| schedule_estimate_memory(&module, &report, engines, &m));
        // The fused total is always reported now; the old flag stays
        // accepted so existing invocations keep working.
        let _ = args.flag("fused");
        if let Some(tp) = args.get("trace-out") {
            // Under --memory the expanded timeline (DMA sub-slices,
            // spills) supersedes the compute-only one.
            let events = match &mem {
                Some(m) => m.trace_events(),
                None => sched.trace_events(),
            };
            write_trace(tp, &events)?;
        }
        if args.flag("json") {
            println!(
                "{}",
                module_json(&spec, &report, &fused, &sched, mem.as_ref()).dump()
            );
            return Ok(());
        }
        let mut t = Table::new(&[
            "#", "op", "source", "cycles", "latency us", "engine", "start us", "end us",
            "slack us", "note",
        ]);
        for (op, s) in report.ops.iter().zip(&sched.ops) {
            t.row(&[
                op.index.to_string(),
                op.op_name.clone(),
                op.source.tag().to_string(),
                op.cycles.map(|c| c.to_string()).unwrap_or_default(),
                format!("{:.3}", op.latency_us),
                s.engine
                    .map(|e| e.name().to_string())
                    .unwrap_or_else(|| "-".into()),
                format!("{:.3}", s.start_us),
                format!("{:.3}", s.end_us),
                format!("{:.3}", s.slack_us),
                op.note.clone(),
            ]);
        }
        println!("{}", t.markdown());
        if args.flag("timeline") {
            println!("{}", sched.render_timeline());
            if let Some(m) = &mem {
                println!("{}", m.schedule.render_timeline());
            }
        }
        println!("device: {spec}");
        println!(
            "module @{}: unfused {:.2} us (systolic {:.2}, elementwise {:.2}, other {:.2}); fused {:.2} us; scheduled {:.2} us (critical path {:.2} us); model coverage {:.0}%",
            report.module_name,
            report.total_us,
            report.systolic_us,
            report.elementwise_us,
            report.other_us,
            fused.total_us,
            sched.makespan_us,
            sched.critical_path_us,
            report.coverage() * 100.0
        );
        let engines: Vec<String> = sched
            .engines
            .iter()
            .map(|u| {
                format!(
                    "{} {:.2} us busy ({:.1}%, {} ops)",
                    u.engine.name(),
                    u.busy_us,
                    u.utilization() * 100.0,
                    u.ops
                )
            })
            .collect();
        println!("engine utilization: {}", engines.join("; "));
        if let Some(m) = &mem {
            println!("{}", m.render_summary(sched.makespan_us));
        }
        return Ok(());
    }

    if let Some(path) = args.get("topology") {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading topology {path}"))?;
        let topo = Topology::parse_csv(path, &text)?;
        let reports = simulate_topology(&config, &topo);
        let mut t = Table::new(&["layer", "GEMM (MxKxN)", "cycles", "util %", "DRAM words"]);
        let mut total: u64 = 0;
        for r in &reports {
            let g = r.report.gemm;
            t.row(&[
                r.layer_name.clone(),
                format!("{}x{}x{}", g.m, g.k, g.n),
                r.report.total_cycles().to_string(),
                format!("{:.1}", r.report.utilisation * 100.0),
                r.report.total_dram_words().to_string(),
            ]);
            total += r.report.total_cycles();
        }
        println!("{}", t.markdown());
        println!("total: {total} cycles");
        return Ok(());
    }

    // Single GEMM.
    let m = args.usize_or("m", 512);
    let k = args.usize_or("k", 512);
    let n = args.usize_or("n", 512);
    let g = GemmShape::new(m, k, n);
    let report = simulate_gemm(&config, g);
    println!("{report}");
    println!("regime: {}", Regime::of_gemm(&g));

    if let Some(slice) = make_slice(args, &spec)? {
        // Slice the GEMM without needing calibration assets: build a
        // cycle-proportional estimator so relative numbers are exact.
        let est = assets::load_assets(&PathBuf::from(args.str_or("assets", "artifacts/assets")))
            .unwrap_or_else(|_| {
                let obs: Vec<_> = [64usize, 128, 256, 512, 1024, 2048, 4096]
                    .iter()
                    .map(|&d| {
                        let gd = GemmShape::new(d, d, d);
                        let c = simulate_gemm(&config, gd).total_cycles();
                        (gd, c, c as f64 * 1e-3)
                    })
                    .collect();
                scalesim_tpu::coordinator::Estimator::for_device(
                    spec.clone(),
                    scalesim_tpu::calibrate::fit_regime_calibration(&obs)
                        .expect("synthetic calibration"),
                )
            });
        let est = est.retarget(&spec);
        let r = estimate_gemm_sliced(&est, g, &slice);
        println!(
            "slice: {} chips ({}, {} GB/s/link): per-chip busy time {:.3} us compute + {:.3} us collective = {:.3} us; parallel efficiency {:.1}%",
            slice.chips,
            slice.topology,
            slice.link_gbps,
            r.compute_us,
            r.collective_us,
            r.total_us(),
            r.parallel_efficiency() * 100.0
        );
    }

    // Optional extensions: energy, sparsity, fold trace.
    if args.flag("energy") {
        let e = scalesim_tpu::scalesim::estimate_energy(
            &scalesim_tpu::scalesim::EnergyParams::default(),
            &report,
        );
        println!(
            "energy: {:.2} uJ (mac {:.2} / sram {:.2} / dram {:.2} / leak {:.2}); data movement {:.0}%; {:.2} TOPS/W",
            e.total_uj(),
            e.mac_uj,
            e.sram_uj,
            e.dram_uj,
            e.leakage_uj,
            e.data_movement_fraction() * 100.0,
            e.tops_per_watt(&report)
        );
    }
    if let Some(d) = args.get("sparsity") {
        let density: f64 = d.parse().context("--sparsity expects a density in (0,1]")?;
        let sp = scalesim_tpu::scalesim::Sparsity {
            a_density: 1.0,
            b_density: density,
            gating_efficiency: 1.0,
        };
        let sr = scalesim_tpu::scalesim::simulate_sparse(&config, g, sp);
        println!(
            "sparse (B density {density}): {} cycles, speedup {:.2}x, dram {} words",
            sr.effective_cycles,
            sr.speedup(),
            sr.effective_dram_words
        );
    }
    if let Some(path) = args.get("trace") {
        let trace = scalesim_tpu::scalesim::trace_gemm(&config, g);
        write_output(std::path::Path::new(path), &trace.to_csv())?;
        println!("wrote fold trace ({} folds) to {path}", trace.records.len());
    }
    // If calibration assets exist, also report estimated TPU time
    // (transferred onto the selected device; identity for tpu-v4).
    let assets_dir = PathBuf::from(args.str_or("assets", "artifacts/assets"));
    if let Ok(est) = assets::load_assets(&assets_dir) {
        let est = est.retarget(&spec);
        println!(
            "calibrated TPU latency estimate: {:.3} us",
            est.calibration.cycles_to_us(&g, report.total_cycles())
        );
    }
    Ok(())
}

/// `devices`: list the preset registry, or (`--check`) round-trip every
/// checked-in device file through the loader and verify preset-named
/// files still match the registry (the CI smoke).
fn cmd_devices(args: &Args) -> Result<()> {
    if args.flag("check") {
        // An explicit --dir must exist — never fall back past a typo.
        // Without --dir, accept either the repo-root or the rust/ CWD.
        let dir = match args.get("dir") {
            Some(d) => {
                let p = PathBuf::from(d);
                if !p.is_dir() {
                    bail!("device-file directory '{d}' not found");
                }
                p
            }
            None => {
                if std::path::Path::new("rust/devices").is_dir() {
                    PathBuf::from("rust/devices")
                } else if std::path::Path::new("devices").is_dir() {
                    PathBuf::from("devices")
                } else {
                    bail!(
                        "no device-file directory found (looked for rust/devices and devices; pass --dir)"
                    );
                }
            }
        };
        let mut entries: Vec<PathBuf> = std::fs::read_dir(&dir)?
            .collect::<std::io::Result<Vec<_>>>()?
            .into_iter()
            .map(|e| e.path())
            .filter(|p| {
                matches!(
                    p.extension().and_then(|e| e.to_str()),
                    Some("toml") | Some("json")
                )
            })
            .collect();
        entries.sort();
        if entries.is_empty() {
            bail!("no .toml/.json device files under {}", dir.display());
        }
        for path in &entries {
            let spec = load_device_file(path)
                .with_context(|| format!("loading {}", path.display()))?;
            if let Some(preset) = DeviceSpec::preset(&spec.name) {
                if preset.fingerprint() != spec.fingerprint() {
                    bail!(
                        "{} names preset '{}' but its parameters drifted from the registry",
                        path.display(),
                        spec.name
                    );
                }
            }
            println!("{}: ok ({spec})", path.display());
        }
        println!("{} device files OK", entries.len());
        return Ok(());
    }
    let mut t = Table::new(&[
        "name",
        "array",
        "dataflow",
        "clock MHz",
        "HBM GB/s",
        "VMEM MiB",
        "DMA",
        "ICI GB/s/link",
        "hop us",
        "topology",
    ]);
    for spec in DeviceSpec::presets() {
        t.row(&[
            spec.name.clone(),
            format!("{}x{}", spec.array_rows, spec.array_cols),
            spec.dataflow.to_string(),
            format!("{:.0}", spec.clock_mhz),
            format!("{:.0}", spec.hbm_gbps),
            format!("{:.0}", spec.vmem_bytes as f64 / (1024.0 * 1024.0)),
            spec.dma_engines.to_string(),
            format!("{:.0}", spec.ici_link_gbps),
            format!("{:.2}", spec.ici_hop_latency_us),
            spec.ici_topology.to_string(),
        ]);
    }
    println!("{}", t.markdown());
    println!(
        "select with --device <name> or a TOML/JSON device file (--device FILE / --device-file FILE);"
    );
    println!(
        "unspecified device-file keys inherit the tpu-v4 reference values; serve requests take a \"device\" field."
    );
    Ok(())
}

/// Write Chrome trace events to `path` (the `--trace-out` /
/// `--trace-dir` renderers); reports the event count on stderr so
/// `--json` stdout stays machine-clean.
fn write_trace(path: &str, events: &[TraceEvent]) -> Result<()> {
    let w = TraceFileWriter::create(std::path::Path::new(path))
        .with_context(|| format!("creating trace file {path}"))?;
    w.write_all(events)?;
    let n = w.finish()?;
    eprintln!("wrote {n} trace events to {path} (open in Perfetto / chrome://tracing)");
    Ok(())
}

/// `compare`: estimate one module against several device specs and
/// print the totals side by side (or as one JSON object).
fn cmd_compare(args: &Args) -> Result<()> {
    let Some(path) = args.get("module") else {
        bail!("compare needs --module FILE");
    };
    let list = args.str_or("devices", &PRESET_NAMES.join(","));
    let mut specs = Vec::new();
    for token in list.split(',').map(str::trim).filter(|t| !t.is_empty()) {
        specs.push(resolve_device(token)?);
    }
    if specs.is_empty() {
        bail!("--devices needs at least one device");
    }
    let text =
        std::fs::read_to_string(path).with_context(|| format!("reading module {path}"))?;
    let module = parse_module(&text)?;
    let chips: Option<usize> = match args.get("chips") {
        Some(c) => Some(
            c.parse()
                .with_context(|| format!("--chips expects an integer, got '{c}'"))?,
        ),
        None => None,
    };

    // One reference asset build; every spec retargets it (so adding a
    // device to the comparison never re-measures anything).
    let assets_dir = PathBuf::from(args.str_or("assets", "artifacts/assets"));
    let reference = DeviceSpec::tpu_v4();
    let mut hw = make_hardware(args, &reference)?;
    let base = assets::load_or_build(
        &assets_dir,
        hw.as_mut(),
        &reference,
        args.usize_or("shapes", 1200),
        args.usize_or("reps", 3),
        args.u64_or("seed", 42),
    )?;

    let trace_dir = args.get("trace-dir").map(PathBuf::from);
    if let Some(dir) = &trace_dir {
        std::fs::create_dir_all(dir)
            .with_context(|| format!("creating trace dir {}", dir.display()))?;
    }

    let mut headers = vec!["device", "unfused us", "scheduled us", "memory us", "bound"];
    if chips.is_some() {
        headers.extend(["chips", "per-chip us", "speedup", "eff %"]);
    }
    // The llm knobs are read unconditionally so they never trip the
    // unknown-option warning; the same seeded stream is served on every
    // device so the rows are directly comparable.
    let llm_flag = args.flag("llm");
    let llm_workload = generate_workload(&WorkloadConfig {
        requests: args.usize_or("requests", 16),
        seed: args.u64_or("seed", 42),
        ..WorkloadConfig::default()
    });
    let llm_batch = args.usize_or("max-batch", 8);
    if llm_flag {
        headers.extend(["prefill us", "decode us", "tok/s", "ttft p50 us"]);
    }
    struct DeviceRun {
        report: scalesim_tpu::coordinator::ModelEstimate,
        sched: ModuleSchedule,
        mem: MemorySchedule,
        dist: Option<DistributedEstimate>,
        llm: Option<scalesim_tpu::inference::LlmReport>,
    }

    // Per-device costing fans out over the worker pool: every worker
    // retargets the shared reference assets (one Arc'd shape cache) and
    // simulates its device independently; rendering and trace writing
    // stay serial in --devices order, so the output is byte-identical
    // to a serial walk for any worker count.
    let workers = args.usize_or("workers", 0);
    let workers = if workers == 0 { default_workers() } else { workers };
    let runs = parallel_map(&specs, workers, |spec| -> Result<DeviceRun> {
        let est = base.retarget(spec);
        let engines = EngineConfig::for_device(spec);
        let report = est.estimate_module(&module);
        let sched = schedule_estimate(&module, &report, engines);
        let mem = schedule_estimate_memory(&module, &report, engines, &spec.memory_config());
        let dist = match chips {
            Some(n) => {
                let slice = spec.slice_config(n, None)?;
                Some(estimate_module_distributed(&est, &module, &slice))
            }
            None => None,
        };
        let llm = if llm_flag {
            let mut phase = PhaseModel::new(&est, &module)
                .ok_or_else(|| anyhow::anyhow!("--llm needs a module with a sequence extent"))?;
            let kv = KvCacheSpec::infer(&module, 1).ok_or_else(|| {
                anyhow::anyhow!("--llm could not infer a KV shape from the module")
            })?;
            let cfg = SimConfig {
                max_batch: llm_batch,
                kv_capacity: Some(spec.vmem_bytes),
            };
            Some(simulate(&est, &mut phase, &kv, &llm_workload, &cfg))
        } else {
            None
        };
        Ok(DeviceRun {
            report,
            sched,
            mem,
            dist,
            llm,
        })
    });

    let mut t = Table::new(&headers);
    let mut rows_json: Vec<Json> = Vec::new();
    for (spec, run) in specs.iter().zip(runs) {
        let DeviceRun {
            report,
            sched,
            mem,
            dist,
            llm,
        } = run?;
        if let Some(dir) = &trace_dir {
            // One memory-aware timeline per device; slice runs get a
            // second file so the two lane sets never share a pid.
            let safe = spec.name.replace(['/', ' '], "_");
            let path = dir.join(format!("{safe}.trace.json"));
            write_trace(&path.to_string_lossy(), &mem.trace_events())?;
            if let Some(d) = &dist {
                let path = dir.join(format!("{safe}.slice.trace.json"));
                write_trace(&path.to_string_lossy(), &d.trace_events())?;
            }
        }
        let mut cells = vec![
            spec.name.clone(),
            format!("{:.3}", report.total_us),
            format!("{:.3}", sched.makespan_us),
            format!("{:.3}", mem.makespan_us()),
            mem.roofline.verdict().to_string(),
        ];
        let mut row = Json::obj();
        row.set("device", Json::Str(spec.name.clone()))
            .set("unfused_us", Json::Num(report.total_us))
            .set("scheduled_us", Json::Num(sched.makespan_us))
            .set("critical_path_us", Json::Num(sched.critical_path_us))
            .set("memory_us", Json::Num(mem.makespan_us()))
            .set("serialized_bound_us", Json::Num(mem.serialized_bound_us))
            .set("bound", Json::Str(mem.roofline.verdict().to_string()))
            .set("coverage", Json::Num(report.coverage()));
        if let Some(d) = &dist {
            cells.extend([
                d.slice.chips.to_string(),
                format!("{:.3}", d.total_us),
                format!("{:.2}", d.speedup()),
                format!("{:.1}", d.parallel_efficiency() * 100.0),
            ]);
            row.set("chips", Json::Num(d.slice.chips as f64))
                .set("distributed_us", Json::Num(d.total_us))
                .set("speedup", Json::Num(d.speedup()))
                .set("parallel_efficiency", Json::Num(d.parallel_efficiency()));
        }
        if let Some(llm) = &llm {
            cells.extend([
                format!("{:.3}", llm.prefill_us),
                format!("{:.3}", llm.decode_step_us),
                format!("{:.1}", llm.tokens_per_sec),
                format!("{:.3}", llm.ttft_p50_us()),
            ]);
            row.set("prefill_us", Json::Num(llm.prefill_us))
                .set("decode_step_us", Json::Num(llm.decode_step_us))
                .set("tokens_per_sec", Json::Num(llm.tokens_per_sec))
                .set("ttft_p50_us", Json::Num(llm.ttft_p50_us()));
        }
        t.row(&cells);
        rows_json.push(row);
    }
    if args.flag("json") {
        let mut j = Json::obj();
        j.set("module", Json::Str(module.name.clone()))
            .set("devices", Json::Arr(rows_json));
        println!("{}", j.dump());
        return Ok(());
    }
    println!("module @{} across {} devices:", module.name, specs.len());
    println!("{}", t.markdown());
    for spec in &specs {
        println!("  {spec}");
    }
    Ok(())
}

/// The single-chip `simulate --module --json` payload: the full per-op
/// estimate table merged with the schedule (engine, start/end, slack)
/// and, under `--memory`, the per-op DMA/residency fields plus the
/// module-level memory and roofline blocks.
fn module_json(
    spec: &DeviceSpec,
    report: &scalesim_tpu::coordinator::ModelEstimate,
    fused: &scalesim_tpu::coordinator::ModelEstimate,
    sched: &ModuleSchedule,
    mem: Option<&MemorySchedule>,
) -> Json {
    // The schedule rows carry the estimate's cost/source/note verbatim
    // (schedule_estimate reuses them); only `cycles` is estimator-only.
    let mut ops = Vec::with_capacity(report.ops.len());
    for (i, (op, s)) in report.ops.iter().zip(&sched.ops).enumerate() {
        let mut o = s.to_json();
        if let Some(c) = op.cycles {
            o.set("cycles", Json::Num(c as f64));
        }
        if let Some(m) = mem {
            let row = &m.ops[i];
            o.set("dma_in_us", Json::Num(row.dma_in_us))
                .set("dma_out_us", Json::Num(row.dma_out_us))
                .set("resident", Json::Bool(row.resident()))
                .set("bound", Json::Str(row.bound().to_string()));
        }
        ops.push(o);
    }
    let mut j = Json::obj();
    j.set("module", Json::Str(report.module_name.clone()))
        .set("device", Json::Str(spec.name.clone()))
        .set("unfused_us", Json::Num(report.total_us))
        .set("systolic_us", Json::Num(report.systolic_us))
        .set("elementwise_us", Json::Num(report.elementwise_us))
        .set("other_us", Json::Num(report.other_us))
        .set("fused_us", Json::Num(fused.total_us))
        .set("scheduled_us", Json::Num(sched.makespan_us))
        .set("critical_path_us", Json::Num(sched.critical_path_us))
        .set("coverage", Json::Num(report.coverage()))
        .set("engines", sched.engines_to_json())
        .set("ops", Json::Arr(ops));
    if let Some(m) = mem {
        j.set("memory_us", Json::Num(m.makespan_us()))
            .set("memory", m.to_json())
            .set("roofline", m.roofline_json());
    }
    j
}

/// The distributed `simulate --module --chips N --json` payload. The
/// `dma_us` keys appear only for memory-aware runs, keeping the
/// memory-blind schema identical to the pre-memory one.
fn distributed_json(
    d: &DistributedEstimate,
    spec: &DeviceSpec,
    slice: &SliceConfig,
    with_memory: bool,
) -> Json {
    let mut ops = Vec::with_capacity(d.ops.len());
    for op in &d.ops {
        let mut o = Json::obj();
        o.set("index", Json::Num(op.index as f64))
            .set("op", Json::Str(op.op_name.clone()))
            .set("compute_us", Json::Num(op.compute_us))
            .set("collective_us", Json::Num(op.collective_us))
            .set("start_us", Json::Num(op.start_us))
            .set("finish_us", Json::Num(op.finish_us))
            .set("note", Json::Str(op.note.clone()));
        if with_memory {
            o.set("dma_us", Json::Num(op.dma_us));
        }
        ops.push(o);
    }
    let mut j = Json::obj();
    j.set("module", Json::Str(d.module_name.clone()))
        .set("device", Json::Str(spec.name.clone()))
        .set("chips", Json::Num(slice.chips as f64))
        .set("ici_topology", Json::Str(slice.topology.to_string()))
        .set("ici_gbps", Json::Num(slice.link_gbps))
        .set("ici_latency_us", Json::Num(slice.hop_latency_us))
        .set("total_us", Json::Num(d.total_us))
        .set("compute_us", Json::Num(d.compute_us))
        .set("collective_us", Json::Num(d.collective_us))
        .set("critical_path_us", Json::Num(d.critical_path_us))
        .set("single_chip_us", Json::Num(d.single_chip_us))
        .set("speedup", Json::Num(d.speedup()))
        .set("parallel_efficiency", Json::Num(d.parallel_efficiency()))
        .set("ops", Json::Arr(ops));
    if with_memory {
        j.set("dma_us", Json::Num(d.dma_us));
    }
    j
}

fn cmd_calibrate(args: &Args) -> Result<()> {
    // Calibrating with --device measures that device's synthetic model;
    // the saved assets record the spec (device.json), so loading them
    // later retargets from the right reference.
    let spec = make_device(args)?;
    let mut hw = make_hardware(args, &spec)?;
    let assets_dir = PathBuf::from(args.str_or("assets", "artifacts/assets"));
    let est = assets::build_estimator(
        hw.as_mut(),
        &spec,
        args.usize_or("shapes", 2000),
        args.usize_or("reps", 5),
        args.u64_or("seed", 42),
    );
    assets::save_assets(&assets_dir, &est)?;
    println!(
        "saved calibration + {} learned models to {}",
        est.learned.len(),
        assets_dir.display()
    );
    for (regime, metrics) in &est.calibration.metrics {
        println!("  {regime}: {metrics}");
    }
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    use std::io::{BufRead, Write};

    // Assets are measured on the reference device; `--device` retargets
    // the default estimator (requests can still name any preset via
    // their "device" field).
    let spec = make_device(args)?;
    let assets_dir = PathBuf::from(args.str_or("assets", "artifacts/assets"));
    let reference = DeviceSpec::tpu_v4();
    let mut hw = make_hardware(args, &reference)?;
    let est = assets::load_or_build(
        &assets_dir,
        hw.as_mut(),
        &reference,
        args.usize_or("shapes", 1200),
        args.usize_or("reps", 3),
        args.u64_or("seed", 42),
    )?;
    let est = Arc::new(est.retarget(&spec));
    let workers = args.usize_or("workers", default_workers());

    // Observability: `--trace FILE` streams one span tree per request,
    // `--metrics ADDR:PORT` serves Prometheus text to any scraper.
    // Either flag instruments the session; with neither, the answer
    // path stays uncounted (zero-cost-when-off).
    let trace_path = args.get("trace").map(str::to_string);
    let trace = match &trace_path {
        Some(p) => Some(Arc::new(
            TraceFileWriter::create(std::path::Path::new(p))
                .with_context(|| format!("creating trace file {p}"))?,
        )),
        None => None,
    };
    let metrics_addr = args.get("metrics").map(str::to_string);
    let metrics = if trace.is_some() || metrics_addr.is_some() {
        Some(Arc::new(ServeMetrics::new(
            Arc::new(MonotonicClock::new()),
            trace.clone(),
        )))
    } else {
        None
    };
    // Held (not just bound) so the scrape thread lives for the whole
    // serve run; dropping it joins the listener.
    let _scrape = match (&metrics_addr, &metrics) {
        (Some(addr), Some(m)) => {
            let render_m = Arc::clone(m);
            let render_est = Arc::clone(&est);
            let s = MetricsScrape::bind(
                addr,
                Arc::new(move || render_m.render(Some(&render_est.cache))),
            )
            .with_context(|| format!("binding metrics listener on {addr}"))?;
            eprintln!("serve: metrics scrape on http://{}/metrics", s.local_addr());
            Some(s)
        }
        _ => None,
    };
    let finish_trace = |trace: &Option<Arc<TraceFileWriter>>| -> Result<()> {
        if let (Some(t), Some(p)) = (trace, &trace_path) {
            let n = t.finish()?;
            eprintln!("serve: wrote {n} trace events to {p} (open in Perfetto)");
        }
        Ok(())
    };

    if let Some(listen) = args.get("listen") {
        // TCP mode: many concurrent connections over one shared worker
        // pool and shape cache; drains on SIGINT or an admin request.
        let snapshot_path = args.get("cache-snapshot").map(PathBuf::from);
        if let Some(path) = &snapshot_path {
            if path.exists() {
                match load_snapshot(path, &est) {
                    Ok(n) => eprintln!(
                        "serve: warm start, {n} cache entries from {}",
                        path.display()
                    ),
                    // Loud cold start: a corrupt/stale snapshot must
                    // never silently serve stale costs.
                    Err(e) => eprintln!("serve: cold start, snapshot rejected: {e:#}"),
                }
            } else {
                eprintln!("serve: cold start, no snapshot at {}", path.display());
            }
        }
        install_sigint_drain();
        let opts = NetOptions {
            workers,
            queue_cap: args.usize_or("queue", 0),
            inflight: args.usize_or("inflight", 0),
        };
        let server = NetServer::bind(listen, Arc::clone(&est), opts)
            .with_context(|| format!("binding {listen}"))?;
        if let Some(m) = &metrics {
            server.devices().attach_metrics(Arc::clone(m));
        }
        eprintln!("serve: listening on {}", server.local_addr()?);
        let summary = server.run()?;
        finish_trace(&trace)?;
        if let Some(path) = &snapshot_path {
            let n = save_snapshot(path, &est)?;
            eprintln!("serve: saved {n} cache entries to {}", path.display());
        }
        if !args.flag("quiet") {
            eprintln!("{}", summary.render());
        }
        // Knobs of the stdin path, read so they never trip the
        // unknown-option warning when mixed into a --listen invocation.
        let _ = args.get("input");
        let _ = args.flag("batch");
        return Ok(());
    }

    let input: Box<dyn BufRead> = match args.get("input") {
        Some(path) => Box::new(std::io::BufReader::new(
            std::fs::File::open(path).with_context(|| format!("opening {path}"))?,
        )),
        None => Box::new(std::io::stdin().lock()),
    };

    if args.flag("batch") {
        // Legacy mode: slurp the whole input, answer as one batch.
        let lines: Vec<String> = input
            .lines()
            .collect::<std::io::Result<Vec<_>>>()?
            .into_iter()
            .filter(|l| !l.trim().is_empty())
            .collect();
        for r in serve_lines(est, &lines, workers) {
            println!("{r}");
        }
        // Batch mode is uninstrumented; still close any `--trace` file
        // so it parses as (empty) valid JSON.
        finish_trace(&trace)?;
        let _ = args.flag("quiet");
        let _ = args.usize_or("queue", 0);
        return Ok(());
    }

    let opts = StreamOptions {
        workers,
        queue_cap: args.usize_or("queue", 0),
        metrics: metrics.clone(),
    };
    let mut out = std::io::BufWriter::new(std::io::stdout().lock());
    let summary = serve_stream(est, input, &mut out, &opts)?;
    out.flush()?;
    finish_trace(&trace)?;
    if !args.flag("quiet") {
        eprintln!("{}", summary.render());
    }
    Ok(())
}

/// `bench-serve`: the TCP-service load generator (see
/// [`bench_serve`]). `--check` is the CI freshness gate on
/// `BENCH_serve.json`; `--publish` (re)writes it.
fn cmd_bench_serve(args: &Args) -> Result<()> {
    if args.flag("check") {
        return bench_serve::check_published();
    }
    let rps = match args.get("rps") {
        Some(r) => {
            let r: f64 = r
                .parse()
                .with_context(|| format!("--rps expects a number, got '{r}'"))?;
            if !(r.is_finite() && r > 0.0) {
                bail!("--rps must be positive");
            }
            Some(r)
        }
        None => None,
    };
    let opts = bench_serve::BenchOptions {
        clients: args.usize_or("clients", 16),
        requests: args.usize_or("requests", 500),
        rps,
        addr: args.get("addr").map(str::to_string),
        workers: args.usize_or("workers", default_workers()),
    };
    let report = bench_serve::run_bench(&opts)?;
    if args.flag("json") {
        // JSON-only stdout (the CI smoke parses it); summary on stderr.
        println!("{}", report.to_json().dump());
        eprintln!("{}", report.render());
    } else {
        println!("{}", report.render());
    }
    if report.errors > 0 {
        bail!("{} error responses during the timed phase", report.errors);
    }
    if args.flag("publish") {
        report.publish()?;
    }
    Ok(())
}

fn cmd_sweep(args: &Args) -> Result<()> {
    let classes = sweep::SweepOpClass::parse_list(&args.str_or("ops", "all"))?;
    let grid = sweep::GridSize::parse(&args.str_or("grid", "small"))?;
    let workers = args.usize_or("workers", 0);

    if let Some(list) = args.get("devices") {
        // Multi-device fan-out: one worker per spec, each with its own
        // estimator + cache (the per-class warm-pass accounting must
        // stay exact per device), reports joined in list order.
        if args.flag("measure") {
            bail!("--measure is incompatible with --devices (one hardware backend per run)");
        }
        let mut specs = Vec::new();
        for token in list.split(',').map(str::trim).filter(|t| !t.is_empty()) {
            specs.push(resolve_device(token)?);
        }
        if specs.is_empty() {
            bail!("--devices needs at least one device");
        }
        let workers = if workers == 0 { default_workers() } else { workers };
        let reports = sweep::run_sweep_devices(&specs, &classes, grid, workers);
        if args.flag("json") {
            let mut j = Json::obj();
            j.set(
                "devices",
                Json::Arr(reports.iter().map(|r| r.to_json()).collect()),
            );
            println!("{}", j.dump());
        } else if args.flag("csv") {
            for r in &reports {
                print!("# device: {}\n{}", r.device, r.to_csv());
            }
        } else {
            for r in &reports {
                println!("{}", r.render());
            }
        }
        return Ok(());
    }

    let spec = make_device(args)?;

    // Exact synthetic calibration: the sweep is a pure function of the
    // device spec and grid (golden-CSV-testable), not of a measured fit.
    let est = sweep::sweep_estimator(&spec);
    let mut report = sweep::run_sweep(&est, &classes, grid);
    if args.flag("measure") {
        let mut hw = make_hardware(args, &spec)?;
        sweep::attach_measurements(&mut report, hw.as_mut(), args.usize_or("reps", 5));
    }

    if args.flag("json") {
        println!("{}", report.to_json().dump());
    } else if args.flag("csv") {
        print!("{}", report.to_csv());
    } else {
        println!("{}", report.render());
    }
    Ok(())
}

/// `llm`: the request-level serving simulation of one decoder-block
/// module. Uses the deterministic sweep estimator (a pure function of
/// the device spec, no calibration assets), so every number is
/// reproducible bit for bit from the command line alone.
fn cmd_llm(args: &Args) -> Result<()> {
    let spec = make_device(args)?;
    let Some(path) = args.get("module") else {
        bail!("llm needs --module FILE (e.g. rust/tests/fixtures/decoder_block.mlir)");
    };
    let text =
        std::fs::read_to_string(path).with_context(|| format!("reading module {path}"))?;
    let module = parse_module(&text)?;
    if args.flag("phase-csv") {
        print!("{}", phase_csv(&module));
        return Ok(());
    }
    let est = sweep::sweep_estimator(&spec);
    let mut phase = PhaseModel::new(&est, &module).ok_or_else(|| {
        anyhow::anyhow!("module @{} has no sequence extent to serve", module.name)
    })?;
    let kv = KvCacheSpec::infer(&module, args.usize_or("layers", 1)).ok_or_else(|| {
        anyhow::anyhow!("module @{} yields no KV-cache shape", module.name)
    })?;
    let workload = generate_workload(&WorkloadConfig {
        requests: args.usize_or("requests", 16),
        seed: args.u64_or("seed", 42),
        prompt_len: (
            args.usize_or("prompt-min", 32),
            args.usize_or("prompt-max", 256),
        ),
        output_len: (
            args.usize_or("output-min", 8),
            args.usize_or("output-max", 64),
        ),
        mean_gap_us: args.f64_or("gap-us", 200.0),
    });
    let kv_mb = args.f64_or("kv-mb", spec.vmem_bytes as f64 / (1024.0 * 1024.0));
    if !kv_mb.is_finite() || kv_mb < 0.0 {
        bail!("--kv-mb must be non-negative");
    }
    let cfg = SimConfig {
        max_batch: args.usize_or("max-batch", 8),
        kv_capacity: Some((kv_mb * 1024.0 * 1024.0) as u64),
    };
    let mut report = simulate(&est, &mut phase, &kv, &workload, &cfg);
    report.module = module.name.clone();
    if let Some(p) = args.get("trace-out") {
        write_trace(p, &report.trace_events())?;
    }
    if args.flag("json") {
        println!("{}", report.to_json().dump());
    } else {
        print!("{}", report.render());
    }
    Ok(())
}

/// `bench-llm`: the decoder-block serving sweep over every preset (see
/// [`inference::bench`](scalesim_tpu::inference::bench)). `--check` is
/// the CI freshness gate on `BENCH_llm.json`; `--publish` (re)writes it.
fn cmd_bench_llm(args: &Args) -> Result<()> {
    let workers = args.usize_or("workers", 0);
    if args.flag("check") {
        return inference::check_published();
    }
    let opts = LlmBenchOptions {
        requests: args.usize_or("requests", 64),
        seed: args.u64_or("seed", 42),
        max_batch: args.usize_or("max-batch", 8),
        workers,
    };
    let report = inference::run_llm_bench(&opts)?;
    if args.flag("json") {
        // JSON-only stdout (the CI smoke parses it); summary on stderr.
        println!("{}", report.to_json().dump());
        eprintln!("{}", report.render());
    } else {
        print!("{}", report.render());
    }
    if args.flag("publish") {
        report.publish()?;
    }
    Ok(())
}

/// `bench --check-all`: every published-benchmark freshness gate
/// (BENCH_estimator / BENCH_serve / BENCH_llm) in one pass, plus the
/// perf-trajectory table (see [`benchgate`]).
fn cmd_bench(args: &Args) -> Result<()> {
    if args.flag("check-all") {
        return benchgate::check_all();
    }
    bail!("bench: nothing to do — pass --check-all (per-bench runs live in bench-serve/bench-llm)");
}
