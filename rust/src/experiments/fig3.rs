//! Figure 3: exploratory bf16 elementwise-add latency sweeps.
//!
//! (a) 1-D lengths 32–8192 step 32; (b) 2-D dims 64–1024 step 64. The
//! claims to reproduce: latency is approximately linear in tensor size,
//! with small shape-dependent fluctuations (same size, different shape →
//! slightly different latency).

use crate::frontend::classify::EwKind;
use crate::report::Scatter;
use crate::tpu::traits::{measure_ew_median, Hardware};
use crate::util::stats;
use crate::workloads::elementwise_sweep::{sweep_1d, sweep_2d};

/// One measured sweep point.
#[derive(Debug, Clone)]
pub struct SweepPoint {
    /// Swept tensor shape.
    pub dims: Vec<usize>,
    /// Element count.
    pub elements: u64,
    /// Median measured latency, µs.
    pub latency_us: f64,
}

/// Figure 3: elementwise-add latency sweeps.
#[derive(Debug, Clone)]
pub struct Fig3Result {
    /// 1-D sweep points.
    pub one_d: Vec<SweepPoint>,
    /// 2-D sweep points.
    pub two_d: Vec<SweepPoint>,
    /// Pearson correlation of latency vs size for each sweep.
    pub linearity_1d: f64,
    /// Pearson r of latency vs elements on the 2-D sweep.
    pub linearity_2d: f64,
    /// Max relative spread among same-size 2-D shapes (the fluctuation).
    pub max_same_size_spread: f64,
}

fn measure_sweep(
    hw: &mut dyn Hardware,
    shapes: Vec<Vec<usize>>,
    reps: usize,
) -> Vec<SweepPoint> {
    shapes
        .into_iter()
        .map(|dims| {
            let latency_us = measure_ew_median(hw, EwKind::Add, &dims, reps);
            let elements = dims.iter().map(|&d| d as u64).product();
            SweepPoint {
                dims,
                elements,
                latency_us,
            }
        })
        .collect()
}

/// Run both sweeps on a backend.
pub fn run(hw: &mut dyn Hardware, reps: usize) -> Fig3Result {
    let one_d = measure_sweep(hw, sweep_1d(), reps);
    let two_d = measure_sweep(hw, sweep_2d(), reps);

    let corr = |pts: &[SweepPoint]| {
        let x: Vec<f64> = pts.iter().map(|p| p.elements as f64).collect();
        let y: Vec<f64> = pts.iter().map(|p| p.latency_us).collect();
        stats::pearson(&x, &y)
    };

    // Same-size spread in the 2-D sweep.
    let mut by_size: std::collections::BTreeMap<u64, Vec<f64>> = Default::default();
    for p in &two_d {
        by_size.entry(p.elements).or_default().push(p.latency_us);
    }
    let mut max_spread = 0.0f64;
    for (_, v) in by_size {
        if v.len() >= 2 {
            let lo = stats::min(&v);
            let hi = stats::max(&v);
            if lo > 0.0 {
                max_spread = max_spread.max((hi - lo) / lo);
            }
        }
    }

    Fig3Result {
        linearity_1d: corr(&one_d),
        linearity_2d: corr(&two_d),
        max_same_size_spread: max_spread,
        one_d,
        two_d,
    }
}

/// Human-readable Figure 3 report.
pub fn render(result: &Fig3Result, hw_name: &str) -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "Figure 3 — bf16 elementwise-add latency vs tensor size ({hw_name})\n\n"
    ));
    let mut a = Scatter::new(
        &format!(
            "(a) 1-D sweep 32–8192 step 32 — pearson r = {:.4}",
            result.linearity_1d
        ),
        "elements",
        "latency µs",
    );
    a.add_series(
        'o',
        result
            .one_d
            .iter()
            .map(|p| (p.elements as f64, p.latency_us))
            .collect(),
    );
    out.push_str(&a.render());
    out.push('\n');
    let mut b = Scatter::new(
        &format!(
            "(b) 2-D sweep 64–1024 step 64 per dim — pearson r = {:.4}",
            result.linearity_2d
        ),
        "elements",
        "latency µs",
    );
    b.add_series(
        'x',
        result
            .two_d
            .iter()
            .map(|p| (p.elements as f64, p.latency_us))
            .collect(),
    );
    out.push_str(&b.render());
    out.push_str(&format!(
        "\n  same-size shape fluctuation (max relative spread, 2-D): {:.2}%\n",
        result.max_same_size_spread * 100.0
    ));
    out
}

/// CSV dump of both sweeps.
pub fn to_csv(result: &Fig3Result) -> String {
    let mut out = String::from("sweep,shape,elements,latency_us\n");
    for (tag, pts) in [("1d", &result.one_d), ("2d", &result.two_d)] {
        for p in pts {
            let shape = p
                .dims
                .iter()
                .map(|d| d.to_string())
                .collect::<Vec<_>>()
                .join("x");
            out.push_str(&format!(
                "{tag},{shape},{},{:.4}\n",
                p.elements, p.latency_us
            ));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tpu::TpuV4Model;

    #[test]
    fn reproduces_linearity_and_fluctuation() {
        let mut hw = TpuV4Model::new(3);
        let r = run(&mut hw, 3);
        assert_eq!(r.one_d.len(), 256);
        assert_eq!(r.two_d.len(), 256);
        // Near-linear scaling (paper: "approximately linear").
        assert!(r.linearity_1d > 0.95, "1d r {}", r.linearity_1d);
        assert!(r.linearity_2d > 0.92, "2d r {}", r.linearity_2d);
        // But with measurable same-size shape fluctuations.
        assert!(
            r.max_same_size_spread > 0.005,
            "spread {}",
            r.max_same_size_spread
        );
    }

    #[test]
    fn render_csv_shapes() {
        let mut hw = TpuV4Model::new(3);
        let r = run(&mut hw, 1);
        assert!(render(&r, "model").contains("(a) 1-D sweep"));
        assert_eq!(to_csv(&r).lines().count(), 1 + 512);
    }
}
