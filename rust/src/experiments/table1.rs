//! Table 1: comparison of accelerator performance simulators along the
//! paper's three axes (real-hardware validation, elementwise support,
//! user interface) — plus a live capability check of *this* implementation
//! so the row we print for ourselves is backed by code, not prose.

use crate::frontend::parse_module;
use crate::report::Table;

/// The static comparison table (rows as in the paper).
pub fn build() -> Table {
    let mut t = Table::new(&[
        "Work",
        "Real Hardware Validation",
        "Elementwise Operations",
        "User Interface",
    ]);
    t.row_strs(&["SCALE-Sim v3 [9]", "No", "No", "CSV"]);
    t.row_strs(&["TimeLoop [8]", "No", "No", "YAML"]);
    t.row_strs(&["COCOSSim [1]", "Yes (TPU v3)", "No", "PyTorch"]);
    t.row_strs(&[
        "SCALE-Sim TPU (this work)",
        "Yes (TPU v4)",
        "Yes",
        "StableHLO",
    ]);
    t
}

/// Live capability check backing our row: the three claims of Table 1,
/// verified against the codebase at runtime.
pub struct CapabilityCheck {
    /// Frontend parses StableHLO end to end.
    pub stablehlo_interface: bool,
    /// Learned elementwise models train and predict.
    pub elementwise_models: bool,
    /// A hardware backend answers measurements.
    pub hardware_validation: bool,
}

/// Exercise each claimed capability live.
pub fn verify_capabilities() -> CapabilityCheck {
    // StableHLO interface: can we parse a module?
    let stablehlo_interface = parse_module(
        r#"module { func.func @main(%a: tensor<4xf32>) -> tensor<4xf32> {
              %0 = stablehlo.add %a, %a : tensor<4xf32>
              return %0 : tensor<4xf32>
           } }"#,
    )
    .is_ok();

    // Elementwise models: does the learned stack train and predict?
    let elementwise_models = {
        use crate::learned::{feature_names, featurize, Hgbr, HgbrParams};
        let shapes: Vec<Vec<usize>> = (1..60).map(|i| vec![i * 32]).collect();
        let rows: Vec<Vec<f64>> = shapes.iter().map(|s| featurize(s)).collect();
        let y: Vec<f64> = shapes.iter().map(|s| s[0] as f64 * 0.01 + 1.0).collect();
        let m = Hgbr::fit(
            &rows,
            &y,
            &feature_names(),
            &HgbrParams {
                max_iter: 20,
                ..Default::default()
            },
        );
        m.predict(&featurize(&[640])).is_finite()
    };

    // Hardware validation: does the measurement substrate produce a
    // usable calibration?
    let hardware_validation = {
        use crate::calibrate::fit_regime_calibration;
        use crate::scalesim::{simulate_gemm, GemmShape, ScaleConfig};
        use crate::tpu::{Hardware, TpuV4Model};
        let cfg = ScaleConfig::tpu_v4();
        let mut hw = TpuV4Model::new(1);
        let obs: Vec<_> = [64usize, 96, 128, 256, 512, 1024, 2048, 4096, 3072]
            .iter()
            .map(|&d| {
                let g = GemmShape::new(d, d, d);
                (
                    g,
                    simulate_gemm(&cfg, g).total_cycles(),
                    hw.gemm_latency_us(g),
                )
            })
            .collect();
        fit_regime_calibration(&obs).is_some()
    };

    CapabilityCheck {
        stablehlo_interface,
        elementwise_models,
        hardware_validation,
    }
}

/// The Table 1 comparison with the live check column.
pub fn render() -> String {
    let caps = verify_capabilities();
    let mut out = String::from("Table 1 — simulator / modeling framework comparison\n\n");
    out.push_str(&build().markdown());
    out.push_str(&format!(
        "\nlive capability check for this implementation:\n  \
         StableHLO interface parses JAX output : {}\n  \
         learned elementwise models train      : {}\n  \
         hardware calibration pipeline works   : {}\n",
        caps.stablehlo_interface, caps.elementwise_models, caps.hardware_validation
    ));
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_matches_paper_rows() {
        let t = build();
        assert_eq!(t.rows.len(), 4);
        let md = t.markdown();
        assert!(md.contains("COCOSSim"));
        assert!(md.contains("StableHLO"));
        assert!(md.contains("TPU v4"));
    }

    #[test]
    fn all_capabilities_verified() {
        let caps = verify_capabilities();
        assert!(caps.stablehlo_interface);
        assert!(caps.elementwise_models);
        assert!(caps.hardware_validation);
    }
}
