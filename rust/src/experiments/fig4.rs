//! Figure 4: predicted vs actual GEMM latency on held-out shapes.
//!
//! The regime calibrations from Fig. 2 are applied to SCALE-Sim cycle
//! counts for *held-out* GEMM shapes (off-sweep midpoints and skewed
//! aspect ratios), and compared against measured latency. The paper
//! reports R² = 0.893 with MAPE = 32.2%, with medium-size workloads
//! deviating most — the shape we must reproduce: good overall correlation,
//! visibly imperfect aggregate MAPE, worst in the mid range.

use crate::calibrate::{Regime, RegimeCalibration};
use crate::coordinator::pool::{default_workers, parallel_map};
use crate::report::{Scatter, Table};
use crate::scalesim::{simulate_gemm, GemmShape, ScaleConfig};
use crate::tpu::traits::{measure_gemm_median, Hardware};
use crate::util::stats::{self, FitMetrics};
use crate::workloads::gemm_sweep::heldout_shapes;

/// One held-out shape's prediction vs measurement.
#[derive(Debug, Clone)]
pub struct Fig4Point {
    /// The held-out GEMM.
    pub gemm: GemmShape,
    /// Size regime it falls in.
    pub regime: Regime,
    /// Calibrated prediction, µs.
    pub predicted_us: f64,
    /// Median measured latency, µs.
    pub measured_us: f64,
}

/// Figure 4: held-out cycle-to-latency accuracy.
#[derive(Debug, Clone)]
pub struct Fig4Result {
    /// All held-out points.
    pub points: Vec<Fig4Point>,
    /// Metrics over every point.
    pub overall: FitMetrics,
    /// MAPE split per regime.
    pub per_regime_mape: Vec<(Regime, f64)>,
}

/// Evaluate a fitted calibration on held-out shapes.
pub fn run(
    hw: &mut dyn Hardware,
    config: &ScaleConfig,
    calibration: &RegimeCalibration,
    reps: usize,
) -> Fig4Result {
    let shapes = heldout_shapes();
    let cycles: Vec<u64> = parallel_map(&shapes, default_workers(), |g| {
        simulate_gemm(config, *g).total_cycles()
    });
    let points: Vec<Fig4Point> = shapes
        .iter()
        .zip(cycles)
        .map(|(g, c)| Fig4Point {
            gemm: *g,
            regime: Regime::of_gemm(g),
            predicted_us: calibration.cycles_to_us(g, c),
            measured_us: measure_gemm_median(hw, *g, reps),
        })
        .collect();

    let truth: Vec<f64> = points.iter().map(|p| p.measured_us).collect();
    let pred: Vec<f64> = points.iter().map(|p| p.predicted_us).collect();
    let overall = FitMetrics::compute(&truth, &pred);

    let mut per_regime_mape = Vec::new();
    for regime in Regime::ALL {
        let (t, p): (Vec<f64>, Vec<f64>) = points
            .iter()
            .filter(|x| x.regime == regime)
            .map(|x| (x.measured_us, x.predicted_us))
            .unzip();
        per_regime_mape.push((regime, stats::mape(&t, &p)));
    }

    Fig4Result {
        points,
        overall,
        per_regime_mape,
    }
}

/// Human-readable Figure 4 report.
pub fn render(result: &Fig4Result, hw_name: &str) -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "Figure 4 — predicted vs actual GEMM latency on held-out shapes ({hw_name})\n\n"
    ));
    let mut sc = Scatter::new(
        &format!(
            "R² = {:.3}, MAPE = {:.1}% (paper: R² = 0.893, MAPE = 32.2%)",
            result.overall.r2, result.overall.mape_pct
        ),
        "measured µs",
        "predicted µs",
    );
    sc.log_log = true;
    sc.diagonal = true;
    for (regime, marker) in [
        (Regime::Small, 's'),
        (Regime::Medium, 'm'),
        (Regime::Large, 'L'),
    ] {
        sc.add_series(
            marker,
            result
                .points
                .iter()
                .filter(|p| p.regime == regime)
                .map(|p| (p.measured_us, p.predicted_us))
                .collect(),
        );
    }
    out.push_str(&sc.render());

    let mut t = Table::new(&["regime", "n", "MAPE %"]);
    for (regime, mape) in &result.per_regime_mape {
        let n = result
            .points
            .iter()
            .filter(|p| p.regime == *regime)
            .count();
        t.row(&[regime.to_string(), n.to_string(), format!("{mape:.1}")]);
    }
    out.push('\n');
    out.push_str(&t.markdown());
    out
}

/// CSV dump of predictions vs measurements.
pub fn to_csv(result: &Fig4Result) -> String {
    let mut t = Table::new(&["regime", "m", "k", "n", "predicted_us", "measured_us"]);
    for p in &result.points {
        t.row(&[
            p.regime.to_string(),
            p.gemm.m.to_string(),
            p.gemm.k.to_string(),
            p.gemm.n.to_string(),
            format!("{:.4}", p.predicted_us),
            format!("{:.4}", p.measured_us),
        ]);
    }
    t.csv()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::experiments::fig2;
    use crate::tpu::TpuV4Model;

    #[test]
    fn heldout_prediction_quality_matches_paper_shape() {
        let config = ScaleConfig::tpu_v4();
        let mut hw = TpuV4Model::new(42);
        let f2 = fig2::run(&mut hw, &config, 5);
        let r = run(&mut hw, &config, &f2.calibration, 5);
        // Strong-but-imperfect overall correlation, as in the paper.
        assert!(r.overall.r2 > 0.8, "R² {}", r.overall.r2);
        // Aggregate MAPE clearly nonzero (paper: 32.2%) but bounded.
        assert!(
            r.overall.mape_pct > 1.0 && r.overall.mape_pct < 60.0,
            "MAPE {}",
            r.overall.mape_pct
        );
        assert!(!r.points.is_empty());
    }

    #[test]
    fn render_mentions_paper_numbers() {
        let config = ScaleConfig::tpu_v4();
        let mut hw = TpuV4Model::new(1);
        let f2 = fig2::run(&mut hw, &config, 3);
        let r = run(&mut hw, &config, &f2.calibration, 3);
        let text = render(&r, "model");
        assert!(text.contains("paper: R² = 0.893"));
        assert!(to_csv(&r).lines().count() > 10);
    }
}
