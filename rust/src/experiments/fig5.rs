//! Figure 5: learned latency-model evaluation for elementwise add and
//! ReLU (maximum).
//!
//! Training data is collected per the paper's protocol (log-uniform sizes
//! to ~16M elements, multiple factorizations, 2ⁿ boundary cases,
//! median-of-N measurement), an HGBR is trained per operator, and
//! evaluation happens on *unseen sizes*. Paper targets: add R² = 0.9973 /
//! median rel err 1.78%; ReLU R² = 0.9980 / 2.55%; both < 3%.

use crate::frontend::classify::EwKind;
use crate::learned::{feature_names, featurize, Dataset, Hgbr, HgbrParams, LinearLatencyModel};
use crate::report::Scatter;
use crate::tpu::traits::{measure_ew_median, Hardware};
use crate::util::stats::FitMetrics;
use crate::workloads::elementwise_sweep::sample_training_shapes;

/// Result for one operator.
#[derive(Debug, Clone)]
pub struct OperatorEval {
    /// Operator the model was trained for.
    pub op: EwKind,
    /// The trained model.
    pub model: Hgbr,
    /// Training samples used.
    pub train_size: usize,
    /// Held-out (dims, measured, predicted) triples.
    pub test_points: Vec<(Vec<usize>, f64, f64)>, // (dims, measured, predicted)
    /// Held-out fit metrics.
    pub metrics: FitMetrics,
    /// Linear-in-size baseline metrics on the same test set (ablation).
    pub linear_baseline: FitMetrics,
}

/// Every operator's evaluation for Figure 5.
#[derive(Debug, Clone)]
pub struct Fig5Result {
    /// One evaluation per trained operator.
    pub evals: Vec<OperatorEval>,
}

/// Collect a measurement dataset for one operator.
pub fn collect_dataset(
    hw: &mut dyn Hardware,
    op: EwKind,
    num_shapes: usize,
    reps: usize,
    seed: u64,
) -> Dataset {
    let mut ds = Dataset::new(op.name());
    for dims in sample_training_shapes(num_shapes, seed) {
        let t = measure_ew_median(hw, op, &dims, reps);
        if t.is_finite() {
            ds.push(dims, t);
        }
    }
    ds
}

/// Train + evaluate one operator with the unseen-size split.
pub fn eval_operator(
    hw: &mut dyn Hardware,
    op: EwKind,
    num_shapes: usize,
    reps: usize,
    seed: u64,
    params: &HgbrParams,
) -> OperatorEval {
    let ds = collect_dataset(hw, op, num_shapes, reps, seed);
    let (train, test) = ds.split_by_unseen_sizes(0.8, seed ^ 0xf5);

    let (rows, y) = train.features_targets();
    let model = Hgbr::fit(&rows, &y, &feature_names(), params);

    let mut test_points = Vec::with_capacity(test.len());
    let mut truth = Vec::new();
    let mut pred = Vec::new();
    for s in &test.samples {
        let p = model.predict(&featurize(&s.dims));
        test_points.push((s.dims.clone(), s.latency_us, p));
        truth.push(s.latency_us);
        pred.push(p);
    }
    let metrics = FitMetrics::compute(&truth, &pred);

    // Ablation: a single linear model on element count.
    let linear = LinearLatencyModel::fit(&train).expect("linear baseline");
    let lin_pred: Vec<f64> = test.samples.iter().map(|s| linear.predict(&s.dims)).collect();
    let linear_baseline = FitMetrics::compute(&truth, &lin_pred);

    OperatorEval {
        op,
        model,
        train_size: train.len(),
        test_points,
        metrics,
        linear_baseline,
    }
}

/// Run Fig. 5 for the paper's two representative operators.
pub fn run(hw: &mut dyn Hardware, num_shapes: usize, reps: usize, seed: u64) -> Fig5Result {
    let params = HgbrParams::default();
    // Both operators are measured over the same shape sample (the paper
    // compares add and ReLU on a common sweep).
    let evals = vec![
        eval_operator(hw, EwKind::Add, num_shapes, reps, seed, &params),
        eval_operator(hw, EwKind::Maximum, num_shapes, reps, seed, &params),
    ];
    Fig5Result { evals }
}

/// Human-readable Figure 5 report.
pub fn render(result: &Fig5Result, hw_name: &str) -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "Figure 5 — learned elementwise latency models ({hw_name})\n\n"
    ));
    for e in &result.evals {
        let label = match e.op {
            EwKind::Add => "(a) elementwise addition",
            EwKind::Maximum => "(b) ReLU (maximum)",
            _ => "(?)",
        };
        let mut sc = Scatter::new(
            &format!(
                "{label}: R²={:.4} medAE={:.2}µs medRE={:.2}% (trees={})",
                e.metrics.r2,
                e.metrics.median_abs_err,
                e.metrics.median_rel_err_pct,
                e.model.num_trees()
            ),
            "measured µs",
            "estimated µs",
        );
        sc.log_log = true;
        sc.diagonal = true;
        sc.add_series(
            'o',
            e.test_points.iter().map(|(_, m, p)| (*m, *p)).collect(),
        );
        out.push_str(&sc.render());
        out.push_str(&format!(
            "  train n={}  test n={}  |  linear-baseline: R²={:.4} medRE={:.2}%\n\n",
            e.train_size,
            e.metrics.n,
            e.linear_baseline.r2,
            e.linear_baseline.median_rel_err_pct
        ));
    }
    out.push_str(
        "paper targets: add R²=0.9973 medAE=1.04µs medRE=1.78%; relu R²=0.9980 medAE=1.65µs medRE=2.55%\n",
    );
    out
}

/// CSV dump of the held-out points.
pub fn to_csv(result: &Fig5Result) -> String {
    let mut out = String::from("op,shape,measured_us,predicted_us\n");
    for e in &result.evals {
        for (dims, m, p) in &e.test_points {
            let shape = dims
                .iter()
                .map(|d| d.to_string())
                .collect::<Vec<_>>()
                .join("x");
            out.push_str(&format!("{},{shape},{m:.4},{p:.4}\n", e.op.name()));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tpu::TpuV4Model;

    #[test]
    fn reproduces_paper_accuracy() {
        let mut hw = TpuV4Model::new(7);
        // Smaller-than-default dataset keeps the test fast but must still
        // hit the paper's <3% median relative error band.
        let r = run(&mut hw, 900, 5, 11);
        for e in &r.evals {
            assert!(e.metrics.r2 > 0.99, "{}: R² {}", e.op.name(), e.metrics.r2);
            assert!(
                e.metrics.median_rel_err_pct < 3.0,
                "{}: medRE {}%",
                e.op.name(),
                e.metrics.median_rel_err_pct
            );
            assert!(e.metrics.n > 50);
        }
    }

    #[test]
    fn hgbr_beats_linear_baseline() {
        let mut hw = TpuV4Model::new(9);
        let e = eval_operator(
            &mut hw,
            EwKind::Add,
            700,
            3,
            5,
            &HgbrParams::default(),
        );
        // The paper's justification for trees: the single linear model is
        // clearly worse on relative error.
        assert!(
            e.metrics.median_rel_err_pct < e.linear_baseline.median_rel_err_pct,
            "hgbr {}% vs linear {}%",
            e.metrics.median_rel_err_pct,
            e.linear_baseline.median_rel_err_pct
        );
    }

    #[test]
    fn render_and_csv() {
        let mut hw = TpuV4Model::new(1);
        let r = run(&mut hw, 300, 1, 3);
        let text = render(&r, "model");
        assert!(text.contains("(a) elementwise addition"));
        assert!(text.contains("(b) ReLU"));
        assert!(to_csv(&r).lines().count() > 20);
    }
}

#[cfg(test)]
mod scratch {
    use super::*;
    use crate::tpu::TpuV4Model;

    #[test]
    #[ignore]
    fn worst_errors() {
        let mut hw = TpuV4Model::new(42);
        let e = eval_operator(&mut hw, EwKind::Add, 1500, 5, 42, &HgbrParams::default());
        let mut pts: Vec<_> = e.test_points.clone();
        pts.sort_by(|a, b| {
            let ea = (a.1 - a.2).abs();
            let eb = (b.1 - b.2).abs();
            eb.partial_cmp(&ea).unwrap()
        });
        println!("R2={:.4}", e.metrics.r2);
        for (dims, m, p) in pts.iter().take(12) {
            println!("{dims:?}: measured {m:.2} predicted {p:.2} ({:+.1}%)", 100.0*(p-m)/m);
        }
    }

    #[test]
    #[ignore]
    fn compare_target_transforms() {
        for (label, log_target) in [("log", true), ("raw", false)] {
            let mut hw = TpuV4Model::new(7);
            let params = HgbrParams { log_target, ..Default::default() };
            for op in [EwKind::Add, EwKind::Maximum] {
                let e = eval_operator(&mut hw, op, 900, 5, 11, &params);
                println!(
                    "{label} {}: R2={:.4} medAE={:.3} medRE={:.3}% trees={}",
                    op.name(), e.metrics.r2, e.metrics.median_abs_err,
                    e.metrics.median_rel_err_pct, e.model.num_trees()
                );
            }
        }
    }
}
