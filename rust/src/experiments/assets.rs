//! Modeling-asset management: build (calibration + learned models) from a
//! hardware backend, persist to disk, and load back into an
//! [`Estimator`]. The CLI and the end-to-end example use this so the
//! expensive measure/train steps run once and are reused.
//!
//! Assets record the [`DeviceSpec`] they were measured on
//! (`device.json`): the loaded estimator's retarget reference is that
//! device, so calibrating against a non-reference device and then
//! retargeting never double-applies a transfer. Asset directories
//! written before the device record existed load as reference
//! (`tpu-v4`) measurements, which is what they were.

use std::collections::HashMap;
use std::path::Path;

use anyhow::{Context, Result};

use crate::calibrate::RegimeCalibration;
use crate::coordinator::Estimator;
use crate::device::DeviceSpec;
use crate::frontend::classify::EwKind;
use crate::learned::{Hgbr, HgbrParams};
use crate::scalesim::ScaleConfig;
use crate::tpu::traits::Hardware;

use super::{fig2, fig5};

/// Operators we train first-class learned models for.
pub const LEARNED_OPS: [EwKind; 4] = [
    EwKind::Add,
    EwKind::Maximum,
    EwKind::Multiply,
    EwKind::Subtract,
];

/// Build a fully-populated estimator from scratch: run the Fig. 2
/// calibration sweep and train learned models for [`LEARNED_OPS`].
/// `spec` must be the device `hw` models — it becomes the estimator's
/// device tag and retarget reference.
pub fn build_estimator(
    hw: &mut dyn Hardware,
    spec: &DeviceSpec,
    num_shapes: usize,
    reps: usize,
    seed: u64,
) -> Estimator {
    let f2 = fig2::run(hw, &spec.scale_config(), reps);
    let mut est = Estimator::for_device(spec.clone(), f2.calibration);
    let params = HgbrParams::default();
    for (i, op) in LEARNED_OPS.iter().enumerate() {
        let ds = fig5::collect_dataset(hw, *op, num_shapes, reps, seed + i as u64);
        let (rows, y) = ds.features_targets();
        let model = Hgbr::fit(&rows, &y, &crate::learned::feature_names(), &params);
        est.add_learned(*op, model);
    }
    est
}

/// A *fast* estimator build for slow (real-execution) backends: a
/// reduced diagonal GEMM sweep spanning all three regimes, plus small
/// capped elementwise training sets for add/maximum only.
pub fn build_estimator_fast(
    hw: &mut dyn Hardware,
    spec: &DeviceSpec,
    reps: usize,
    seed: u64,
) -> Estimator {
    use crate::scalesim::{simulate_gemm, GemmShape};
    use crate::workloads::elementwise_sweep::sample_training_shapes_bounded;

    let config = &spec.scale_config();

    // Diagonal + lightly skewed shapes across the regimes (capped at 2048
    // so CPU-backed GEMMs stay sub-second).
    let mut dims: Vec<(usize, usize, usize)> = vec![
        (32, 32, 32),
        (48, 48, 48),
        (64, 64, 64),
        (96, 96, 96),
        (128, 128, 128),
        (64, 128, 96),
        (256, 256, 256),
        (384, 384, 384),
        (512, 512, 512),
        (768, 768, 768),
        (1024, 1024, 1024),
        (256, 512, 768),
        (1280, 1280, 1280),
        (1536, 1536, 1536),
        (2048, 2048, 2048),
        (1536, 1024, 2048),
        (2048, 1280, 1536),
    ];
    dims.dedup();
    let obs: Vec<(GemmShape, u64, f64)> = dims
        .into_iter()
        .map(|(m, k, n)| {
            let g = GemmShape::new(m, k, n);
            let cycles = simulate_gemm(config, g).total_cycles();
            let t = crate::tpu::traits::measure_gemm_median(hw, g, reps);
            (g, cycles, t)
        })
        .collect();
    let calibration =
        crate::calibrate::fit_regime_calibration(&obs).expect("fast calibration fit");
    let mut est = Estimator::for_device(spec.clone(), calibration);

    let params = HgbrParams {
        max_iter: 300,
        ..Default::default()
    };
    for (i, op) in [EwKind::Add, EwKind::Maximum].iter().enumerate() {
        let mut ds = crate::learned::Dataset::new(op.name());
        for shape in sample_training_shapes_bounded(240, seed + i as u64, 1 << 20) {
            let t = crate::tpu::traits::measure_ew_median(hw, *op, &shape, reps);
            if t.is_finite() {
                ds.push(shape, t);
            }
        }
        let (rows, y) = ds.features_targets();
        let model = Hgbr::fit(&rows, &y, &crate::learned::feature_names(), &params);
        est.add_learned(*op, model);
    }
    est
}

/// Persist calibration + learned models + the measurement device
/// under `dir`.
pub fn save_assets(dir: &Path, est: &Estimator) -> Result<()> {
    std::fs::create_dir_all(dir)?;
    est.calibration
        .save(&dir.join("calibration.json"))
        .context("saving calibration")?;
    std::fs::write(dir.join("device.json"), est.device().to_json().pretty())
        .context("saving device record")?;
    for (name, model) in &est.learned {
        model
            .save(&dir.join(format!("learned_{name}.json")))
            .with_context(|| format!("saving learned model '{name}'"))?;
    }
    std::fs::write(
        dir.join("config.json"),
        est.config.to_json().pretty(),
    )?;
    Ok(())
}

/// Load previously saved assets. The estimator's device tag (and
/// retarget reference) comes from the directory's `device.json`;
/// directories written before that record existed load as reference
/// (`tpu-v4`) measurements.
pub fn load_assets(dir: &Path) -> Result<Estimator> {
    let config_text = std::fs::read_to_string(dir.join("config.json"))
        .with_context(|| format!("no config.json under {}", dir.display()))?;
    let config = ScaleConfig::from_json(
        &crate::util::json::Json::parse(&config_text).map_err(|e| anyhow::anyhow!("{e}"))?,
    )
    .map_err(|e| anyhow::anyhow!("{e}"))?;
    let calibration = RegimeCalibration::load(&dir.join("calibration.json"))?;
    let device = match std::fs::read_to_string(dir.join("device.json")) {
        Ok(text) => {
            let j = crate::util::json::Json::parse(&text).map_err(|e| anyhow::anyhow!("{e}"))?;
            let spec = DeviceSpec::from_json(&j).map_err(|e| anyhow::anyhow!("{e}"))?;
            spec.validate()?;
            spec
        }
        Err(_) => DeviceSpec::tpu_v4(),
    };
    let mut est = Estimator::for_device(device, calibration);
    // The saved systolic config wins over the spec derivation: it is
    // exactly what the calibration cycles were simulated with (the
    // setter keeps the cache identity in sync).
    est.set_config(config);

    let mut learned = HashMap::new();
    for entry in std::fs::read_dir(dir)? {
        let path = entry?.path();
        let Some(name) = path.file_name().and_then(|n| n.to_str()) else {
            continue;
        };
        if let Some(op) = name
            .strip_prefix("learned_")
            .and_then(|s| s.strip_suffix(".json"))
        {
            learned.insert(op.to_string(), Hgbr::load(&path)?);
        }
    }
    est.learned = learned;
    Ok(est)
}

/// Load assets if present, otherwise build them against `spec` (the
/// device `hw` models) and save them.
pub fn load_or_build(
    dir: &Path,
    hw: &mut dyn Hardware,
    spec: &DeviceSpec,
    num_shapes: usize,
    reps: usize,
    seed: u64,
) -> Result<Estimator> {
    if dir.join("calibration.json").exists() && dir.join("config.json").exists() {
        if let Ok(est) = load_assets(dir) {
            crate::log_info!("loaded modeling assets from {}", dir.display());
            return Ok(est);
        }
    }
    crate::log_info!("building modeling assets (sweep + training)...");
    let est = build_estimator(hw, spec, num_shapes, reps, seed);
    save_assets(dir, &est)?;
    Ok(est)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tpu::TpuV4Model;

    #[test]
    fn build_save_load_roundtrip() {
        let mut hw = TpuV4Model::new(5);
        let est = build_estimator(&mut hw, &DeviceSpec::tpu_v4(), 150, 1, 3);
        assert_eq!(est.learned.len(), LEARNED_OPS.len());

        let dir = std::env::temp_dir().join("scalesim_tpu_assets_test");
        std::fs::remove_dir_all(&dir).ok();
        save_assets(&dir, &est).unwrap();
        let est2 = load_assets(&dir).unwrap();
        assert_eq!(est2.learned.len(), est.learned.len());
        assert_eq!(est2.config, est.config);
        // The device record round-trips: the loaded estimator knows
        // which device the calibration was measured on.
        assert_eq!(est2.device(), est.device());
        assert_eq!(est2.device_fingerprint(), est.device_fingerprint());
        // Same predictions after the roundtrip.
        let g = crate::scalesim::GemmShape::new(777, 333, 99);
        assert!(
            (est.calibration.cycles_to_us(&g, 12345) - est2.calibration.cycles_to_us(&g, 12345))
                .abs()
                < 1e-9
        );
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn load_or_build_uses_cache() {
        let mut hw = TpuV4Model::new(5);
        let spec = DeviceSpec::tpu_v4();
        let dir = std::env::temp_dir().join("scalesim_tpu_assets_cache_test");
        std::fs::remove_dir_all(&dir).ok();
        let _ = load_or_build(&dir, &mut hw, &spec, 120, 1, 3).unwrap();
        let t0 = std::time::Instant::now();
        let est2 = load_or_build(&dir, &mut hw, &spec, 120, 1, 3).unwrap();
        assert!(t0.elapsed().as_secs_f64() < 2.0, "cache path too slow");
        assert!(!est2.learned.is_empty());
        std::fs::remove_dir_all(&dir).ok();
    }
}
