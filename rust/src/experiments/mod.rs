//! Reproduction harnesses — one module per paper artifact.
//!
//! | Module  | Paper artifact | What it reproduces |
//! |---------|----------------|--------------------|
//! | [`table1`] | Table 1  | simulator comparison + live capability check |
//! | [`fig2`]   | Figure 2 | per-regime cycles→latency regressions |
//! | [`fig3`]   | Figure 3 | elementwise-add latency sweeps |
//! | [`fig4`]   | Figure 4 | held-out cycle-to-latency accuracy |
//! | [`fig5`]   | Figure 5 | learned elementwise models (add, ReLU) |
//! | [`assets`] | §4.1.2 / §4.3 | persisted calibration + learned models |

pub mod assets;
pub mod fig2;
pub mod fig3;
pub mod fig4;
pub mod fig5;
pub mod table1;
