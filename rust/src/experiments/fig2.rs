//! Figure 2: SCALE-Sim-to-TPU regression for systolic GEMM across three
//! size regimes.
//!
//! For every shape in the paper's sweep we record (1) SCALE-Sim's
//! predicted cycle count and (2) the measured hardware latency
//! (median-of-N), then fit a per-regime least-squares line and report the
//! inset metrics (R², RMSE, MAE, n).

use crate::calibrate::{fit_regime_calibration, LinearFit, Regime, RegimeCalibration};
use crate::coordinator::pool::{default_workers, parallel_map};
use crate::report::{fnum, Scatter, Table};
use crate::scalesim::{simulate_gemm, GemmShape, ScaleConfig};
use crate::tpu::traits::{measure_gemm_median, Hardware};
use crate::util::stats::FitMetrics;
use crate::workloads::gemm_sweep::regime_sweep;

/// One observed point.
#[derive(Debug, Clone)]
pub struct Observation {
    /// Swept GEMM shape.
    pub gemm: GemmShape,
    /// Simulated SCALE-Sim cycles.
    pub cycles: u64,
    /// Median measured latency, µs.
    pub measured_us: f64,
}

/// Per-regime regression panel.
#[derive(Debug, Clone)]
pub struct RegimePanel {
    /// The regime this panel covers.
    pub regime: Regime,
    /// (cycles, latency) observations.
    pub points: Vec<Observation>,
    /// OLS fit of latency on cycles.
    pub fit: LinearFit,
    /// Fit quality metrics.
    pub metrics: FitMetrics,
}

/// The full Fig. 2 result.
#[derive(Debug, Clone)]
pub struct Fig2Result {
    /// One regression panel per regime.
    pub panels: Vec<RegimePanel>,
    /// The calibration fitted from the panels.
    pub calibration: RegimeCalibration,
}

/// Collect observations for one regime.
pub fn observe_regime(
    hw: &mut dyn Hardware,
    config: &ScaleConfig,
    regime: Regime,
    reps: usize,
) -> Vec<Observation> {
    let shapes = regime_sweep(regime);
    // Simulation is deterministic and parallel-safe.
    let cycles: Vec<u64> = parallel_map(&shapes, default_workers(), |g| {
        simulate_gemm(config, *g).total_cycles()
    });
    // Measurement walks the hardware's noise stream sequentially.
    shapes
        .iter()
        .zip(cycles)
        .map(|(g, c)| Observation {
            gemm: *g,
            cycles: c,
            measured_us: measure_gemm_median(hw, *g, reps),
        })
        .collect()
}

/// Run the whole experiment.
pub fn run(hw: &mut dyn Hardware, config: &ScaleConfig, reps: usize) -> Fig2Result {
    let mut panels = Vec::new();
    let mut all_obs = Vec::new();
    for regime in Regime::ALL {
        let points = observe_regime(hw, config, regime, reps);
        let x: Vec<f64> = points.iter().map(|o| o.cycles as f64).collect();
        let y: Vec<f64> = points.iter().map(|o| o.measured_us).collect();
        let fit = LinearFit::fit(&x, &y).expect("regime fit");
        let metrics = fit.metrics(&x, &y);
        for o in &points {
            all_obs.push((o.gemm, o.cycles, o.measured_us));
        }
        panels.push(RegimePanel {
            regime,
            points,
            fit,
            metrics,
        });
    }
    let calibration = fit_regime_calibration(&all_obs).expect("calibration");
    Fig2Result {
        panels,
        calibration,
    }
}

/// Render the three panels (scatter + inset metrics) and a summary table.
pub fn render(result: &Fig2Result, hw_name: &str) -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "Figure 2 — SCALE-Sim cycles vs measured latency ({hw_name})\n\n"
    ));
    for p in &result.panels {
        let pts: Vec<(f64, f64)> = p
            .points
            .iter()
            .map(|o| (o.cycles as f64, o.measured_us))
            .collect();
        let mut sc = Scatter::new(
            &format!(
                "regime={} fit: t = {:.4e}·cycles + {:.3} µs",
                p.regime, p.fit.alpha, p.fit.beta
            ),
            "SCALE-Sim cycles",
            "measured µs",
        );
        sc.add_series('o', pts);
        sc.with_fit(p.fit.alpha, p.fit.beta);
        out.push_str(&sc.render());
        out.push_str(&format!(
            "  inset: R²={:.4}  RMSE={}µs  MAE={}µs  n={}\n\n",
            p.metrics.r2,
            fnum(p.metrics.rmse),
            fnum(p.metrics.mae),
            p.metrics.n
        ));
    }
    let mut table = Table::new(&[
        "regime",
        "n",
        "alpha (µs/cycle)",
        "alpha 95% CI",
        "beta (µs)",
        "R2",
        "R2 95% CI",
        "RMSE",
        "MAE",
    ]);
    for p in &result.panels {
        let x: Vec<f64> = p.points.iter().map(|o| o.cycles as f64).collect();
        let y: Vec<f64> = p.points.iter().map(|o| o.measured_us).collect();
        let boot = crate::calibrate::bootstrap_fit(&x, &y, 400, 0.95, 0xb007);
        let (a_ci, r_ci) = match &boot {
            Some(b) => (
                format!("[{:.2e}, {:.2e}]", b.alpha.lo, b.alpha.hi),
                format!("[{:.3}, {:.3}]", b.r2.lo, b.r2.hi),
            ),
            None => ("-".into(), "-".into()),
        };
        table.row(&[
            p.regime.to_string(),
            p.metrics.n.to_string(),
            format!("{:.5e}", p.fit.alpha),
            a_ci,
            fnum(p.fit.beta),
            format!("{:.4}", p.metrics.r2),
            r_ci,
            fnum(p.metrics.rmse),
            fnum(p.metrics.mae),
        ]);
    }
    out.push_str(&table.markdown());
    out
}

/// CSV of every observation (for external plotting).
pub fn to_csv(result: &Fig2Result) -> String {
    let mut t = Table::new(&["regime", "m", "k", "n", "cycles", "measured_us"]);
    for p in &result.panels {
        for o in &p.points {
            t.row(&[
                p.regime.to_string(),
                o.gemm.m.to_string(),
                o.gemm.k.to_string(),
                o.gemm.n.to_string(),
                o.cycles.to_string(),
                format!("{:.4}", o.measured_us),
            ]);
        }
    }
    t.csv()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tpu::TpuV4Model;

    fn run_default() -> Fig2Result {
        let mut hw = TpuV4Model::new(42);
        run(&mut hw, &ScaleConfig::tpu_v4(), 5)
    }

    #[test]
    fn reproduces_paper_regression_quality() {
        let r = run_default();
        assert_eq!(r.panels.len(), 3);
        let by: std::collections::HashMap<Regime, &RegimePanel> =
            r.panels.iter().map(|p| (p.regime, p)).collect();
        // Paper: R² ≈ 0.79 small, > 0.97 medium/large.
        let small = by[&Regime::Small].metrics.r2;
        let medium = by[&Regime::Medium].metrics.r2;
        let large = by[&Regime::Large].metrics.r2;
        assert!(small > 0.5 && small < 0.995, "small R² {small}");
        assert!(medium > 0.97, "medium R² {medium}");
        assert!(large > 0.9, "large R² {large}");
        // Small regime is the weakest fit, as in the paper.
        assert!(small < medium && small < large, "{small} {medium} {large}");
    }

    #[test]
    fn alpha_near_clock_period() {
        // The slope should be on the order of the 940 MHz cycle time
        // (1/940 µs per cycle ≈ 1.06e-3), at least in the medium regime.
        let r = run_default();
        let medium = r
            .panels
            .iter()
            .find(|p| p.regime == Regime::Medium)
            .unwrap();
        let period_us = 1.0 / 940.0 * 1e3 / 1e3; // 1.064e-3 µs
        let ratio = medium.fit.alpha / period_us;
        assert!(ratio > 0.3 && ratio < 3.0, "alpha ratio {ratio}");
    }

    #[test]
    fn render_and_csv_nonempty() {
        let r = run_default();
        let text = render(&r, "tpu_v4_model");
        assert!(text.contains("regime=small"));
        assert!(text.contains("R²="));
        let csv = to_csv(&r);
        assert!(csv.lines().count() > 100);
    }
}
