//! The aggregated benchmark freshness gate: `bench --check-all`.
//!
//! The repo publishes three benchmark artifacts at its root, each
//! stamped with an FNV-1a fingerprint of the sources that produced it:
//!
//! * `BENCH_estimator.json` — batched-estimator micro-benchmarks
//!   (`benches/estimator_batch.rs`, re-run via `make bench-estimator`);
//! * `BENCH_serve.json` — the concurrent TCP serve load generator
//!   ([`crate::coordinator::bench_serve`], `make bench-serve`);
//! * `BENCH_llm.json` — the LLM serving simulator sweep
//!   ([`crate::inference::bench`], `make bench-llm`).
//!
//! [`check_all`] runs all three gates in one pass (CI used to run them
//! as three separate steps) and, when every artifact is fresh, prints a
//! perf-trajectory table of the headline number each artifact carries,
//! so a reviewer sees the published performance state of the repo at a
//! glance.

use anyhow::{bail, Context, Result};

use crate::report::Table;
use crate::util::json::Json;

/// The estimator bench source, fingerprinted exactly like the bench
/// binary fingerprints itself (FNV-1a over its own bytes).
const ESTIMATOR_BENCH_SOURCE: &str = include_str!("../benches/estimator_batch.rs");

fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

fn repo_root() -> std::path::PathBuf {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("..")
}

/// Read and parse one published benchmark artifact, verifying its
/// fingerprint against `current`.
fn load_checked(file: &str, current: &str, rerun: &str) -> Result<Json> {
    let path = repo_root().join(file);
    let text = std::fs::read_to_string(&path)
        .with_context(|| format!("{file} missing at {}; run `{rerun}`", path.display()))?;
    let json = Json::parse(&text).map_err(|e| anyhow::anyhow!("{file}: {e}"))?;
    let published = json
        .get("source_fingerprint")
        .and_then(Json::as_str)
        .ok_or_else(|| anyhow::anyhow!("{file} lacks source_fingerprint"))?;
    if published != current {
        bail!(
            "{file} is stale: published fingerprint {published} != bench source {current}; \
             re-run `{rerun}` and commit the result"
        );
    }
    Ok(json)
}

fn num(json: &Json, key: &str) -> f64 {
    json.get(key).and_then(Json::as_f64).unwrap_or(0.0)
}

/// Run the three published-benchmark freshness gates in one pass and
/// print the perf-trajectory table. Fails on the first missing or stale
/// artifact with the same message the per-bench `--check` flags emit.
pub fn check_all() -> Result<()> {
    let estimator = load_checked(
        "BENCH_estimator.json",
        &format!("{:016x}", fnv1a(ESTIMATOR_BENCH_SOURCE.as_bytes())),
        "make bench-estimator",
    )?;
    let serve = load_checked(
        "BENCH_serve.json",
        &crate::coordinator::bench_serve::source_fingerprint(),
        "make bench-serve",
    )?;
    let llm = load_checked(
        "BENCH_llm.json",
        &crate::inference::bench::source_fingerprint(),
        "make bench-llm",
    )?;

    let mut t = Table::new(&["artifact", "headline", "value", "fingerprint"]);
    t.row(&[
        "BENCH_estimator.json".into(),
        "speedup_warm".into(),
        format!("{:.2}x", num(&estimator, "speedup_warm")),
        estimator
            .get("source_fingerprint")
            .and_then(Json::as_str)
            .unwrap_or("")
            .into(),
    ]);
    t.row(&[
        "BENCH_serve.json".into(),
        "throughput_rps".into(),
        format!("{:.0}", num(&serve, "throughput_rps")),
        serve
            .get("source_fingerprint")
            .and_then(Json::as_str)
            .unwrap_or("")
            .into(),
    ]);
    t.row(&[
        "BENCH_llm.json".into(),
        "sim_requests_per_sec".into(),
        format!("{:.0}", num(&llm, "sim_requests_per_sec")),
        llm.get("source_fingerprint")
            .and_then(Json::as_str)
            .unwrap_or("")
            .into(),
    ]);
    println!("all published benchmarks are fresh:");
    println!("{}", t.markdown());
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fnv_matches_the_bench_binary_idiom() {
        // FNV-1a of the empty string is the offset basis.
        assert_eq!(fnv1a(b""), 0xcbf2_9ce4_8422_2325);
        // Stable over the bench source (the actual gate value).
        assert_eq!(
            fnv1a(ESTIMATOR_BENCH_SOURCE.as_bytes()),
            fnv1a(ESTIMATOR_BENCH_SOURCE.as_bytes())
        );
    }

    #[test]
    fn check_all_passes_on_the_checked_in_artifacts() {
        // The three artifacts are committed and must stay fresh — this
        // is the same gate CI runs via `bench --check-all`.
        check_all().expect("published artifacts must be fresh");
    }
}
