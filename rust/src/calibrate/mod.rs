//! Cycle→time calibration: OLS linear fits ([`linreg`]), the paper's
//! three-regime calibration and routing ([`regime`]).

pub mod bootstrap;
pub mod linreg;
pub mod regime;

pub use bootstrap::{bootstrap_fit, BootstrapResult, Interval};
pub use linreg::LinearFit;
pub use regime::{fit_global, fit_regime_calibration, Regime, RegimeCalibration};
