//! The paper's three GEMM size regimes and the per-regime calibration
//! (§4.1): a separate linear cycle→time mapping is fitted per regime, and
//! the combined calibrator routes a GEMM to its regime's fit.

use crate::scalesim::topology::GemmShape;
use crate::util::json::{Json, JsonError};
use crate::util::stats::FitMetrics;

use super::linreg::LinearFit;

/// The paper's size regimes (dimension ranges of the sweep).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Regime {
    /// Dims 32–128: under-utilisation; fill/drain dominated.
    Small,
    /// Dims 128–1024: steady-state systolic execution.
    Medium,
    /// Dims 1024–4096: compiler tiling / scheduling dominated.
    Large,
}

impl Regime {
    /// Every regime, small to large.
    pub const ALL: [Regime; 3] = [Regime::Small, Regime::Medium, Regime::Large];

    /// Lowercase regime name.
    pub fn name(&self) -> &'static str {
        match self {
            Regime::Small => "small",
            Regime::Medium => "medium",
            Regime::Large => "large",
        }
    }

    /// Parse a lowercase regime name.
    pub fn parse(s: &str) -> Option<Regime> {
        match s {
            "small" => Some(Regime::Small),
            "medium" => Some(Regime::Medium),
            "large" => Some(Regime::Large),
            _ => None,
        }
    }

    /// Classify a GEMM by its *largest* dimension, mirroring the paper's
    /// sweep construction (each regime sweeps dims within its range).
    pub fn of_gemm(g: &GemmShape) -> Regime {
        let maxdim = g.m.max(g.k).max(g.n);
        if maxdim <= 128 {
            Regime::Small
        } else if maxdim <= 1024 {
            Regime::Medium
        } else {
            Regime::Large
        }
    }

    /// The sweep range (lo, hi, step) of this regime in the paper.
    pub fn sweep_range(&self) -> (usize, usize, usize) {
        match self {
            Regime::Small => (32, 128, 16),
            Regime::Medium => (128, 1024, 128),
            Regime::Large => (1024, 4096, 512),
        }
    }
}

impl std::fmt::Display for Regime {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// Per-regime linear cycle→time calibration (the paper's Fig. 2 fits,
/// reused by §4.1.2 to report TPU latency directly).
#[derive(Debug, Clone, PartialEq)]
pub struct RegimeCalibration {
    /// Fit for the small regime.
    pub small: LinearFit,
    /// Fit for the medium regime.
    pub medium: LinearFit,
    /// Fit for the large regime.
    pub large: LinearFit,
    /// Fit diagnostics per regime (as in Fig. 2's insets).
    pub metrics: Vec<(Regime, FitMetrics)>,
}

impl RegimeCalibration {
    /// The fit responsible for one regime.
    pub fn fit_for(&self, regime: Regime) -> &LinearFit {
        match regime {
            Regime::Small => &self.small,
            Regime::Medium => &self.medium,
            Regime::Large => &self.large,
        }
    }

    /// Map simulated cycles for `gemm` to estimated wall-clock µs.
    pub fn cycles_to_us(&self, gemm: &GemmShape, cycles: u64) -> f64 {
        self.fit_for(Regime::of_gemm(gemm)).predict(cycles as f64)
    }

    /// Serialize for the asset files.
    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("small", self.small.to_json())
            .set("medium", self.medium.to_json())
            .set("large", self.large.to_json());
        o
    }

    /// Deserialize from the asset files.
    pub fn from_json(j: &Json) -> Result<RegimeCalibration, JsonError> {
        Ok(RegimeCalibration {
            small: LinearFit::from_json(
                j.get("small").ok_or_else(|| JsonError::new("missing small"))?,
            )?,
            medium: LinearFit::from_json(
                j.get("medium")
                    .ok_or_else(|| JsonError::new("missing medium"))?,
            )?,
            large: LinearFit::from_json(
                j.get("large").ok_or_else(|| JsonError::new("missing large"))?,
            )?,
            metrics: Vec::new(),
        })
    }

    /// Write the calibration JSON to disk.
    pub fn save(&self, path: &std::path::Path) -> std::io::Result<()> {
        std::fs::write(path, self.to_json().pretty())
    }

    /// Read a calibration JSON from disk.
    pub fn load(path: &std::path::Path) -> anyhow::Result<RegimeCalibration> {
        let text = std::fs::read_to_string(path)?;
        let j = Json::parse(&text).map_err(|e| anyhow::anyhow!("{e}"))?;
        RegimeCalibration::from_json(&j).map_err(|e| anyhow::anyhow!("{e}"))
    }
}

/// Fit the per-regime calibration from paired (gemm, cycles, measured µs)
/// observations. Returns None if any regime has < 2 points.
pub fn fit_regime_calibration(
    observations: &[(GemmShape, u64, f64)],
) -> Option<RegimeCalibration> {
    let mut fits: Vec<Option<LinearFit>> = Vec::new();
    let mut metrics = Vec::new();
    for regime in Regime::ALL {
        let (x, y): (Vec<f64>, Vec<f64>) = observations
            .iter()
            .filter(|(g, _, _)| Regime::of_gemm(g) == regime)
            .map(|(_, c, t)| (*c as f64, *t))
            .unzip();
        let fit = LinearFit::fit(&x, &y)?;
        metrics.push((regime, fit.metrics(&x, &y)));
        fits.push(Some(fit));
    }
    Some(RegimeCalibration {
        small: fits[0].unwrap(),
        medium: fits[1].unwrap(),
        large: fits[2].unwrap(),
        metrics,
    })
}

/// A single *global* fit across all regimes (ablation baseline: the paper
/// shows per-regime fits behave differently — Fig. 2 vs Fig. 4).
pub fn fit_global(observations: &[(GemmShape, u64, f64)]) -> Option<LinearFit> {
    let (x, y): (Vec<f64>, Vec<f64>) = observations
        .iter()
        .map(|(_, c, t)| (*c as f64, *t))
        .unzip();
    LinearFit::fit(&x, &y)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn regime_classification() {
        assert_eq!(Regime::of_gemm(&GemmShape::new(32, 64, 128)), Regime::Small);
        assert_eq!(
            Regime::of_gemm(&GemmShape::new(128, 512, 256)),
            Regime::Medium
        );
        assert_eq!(
            Regime::of_gemm(&GemmShape::new(64, 64, 2048)),
            Regime::Large
        );
    }

    #[test]
    fn sweep_ranges_match_paper() {
        assert_eq!(Regime::Small.sweep_range(), (32, 128, 16));
        assert_eq!(Regime::Medium.sweep_range(), (128, 1024, 128));
        assert_eq!(Regime::Large.sweep_range(), (1024, 4096, 512));
    }

    fn synth_observations() -> Vec<(GemmShape, u64, f64)> {
        // Three clusters with different slopes.
        let mut obs = Vec::new();
        for i in 1..10usize {
            let d = 32 + i * 8; // small
            let cycles = (d * 10) as u64;
            obs.push((GemmShape::new(d, d, d), cycles, 1.0 * cycles as f64 + 5.0));
            let d = 128 + i * 64; // medium
            let cycles = (d * 10) as u64;
            obs.push((GemmShape::new(d, d, d), cycles, 2.0 * cycles as f64 + 1.0));
            let d = 1024 + i * 256; // large
            let cycles = (d * 10) as u64;
            obs.push((GemmShape::new(d, d, d), cycles, 3.0 * cycles as f64 + 2.0));
        }
        obs
    }

    #[test]
    fn per_regime_fit_recovers_slopes() {
        let obs = synth_observations();
        let cal = fit_regime_calibration(&obs).unwrap();
        assert!((cal.small.alpha - 1.0).abs() < 1e-9);
        assert!((cal.medium.alpha - 2.0).abs() < 1e-9);
        assert!((cal.large.alpha - 3.0).abs() < 1e-9);
        // Metrics recorded for all three regimes with perfect R².
        assert_eq!(cal.metrics.len(), 3);
        for (_, m) in &cal.metrics {
            assert!(m.r2 > 0.999999);
        }
    }

    #[test]
    fn routing_uses_correct_regime() {
        let obs = synth_observations();
        let cal = fit_regime_calibration(&obs).unwrap();
        let g_small = GemmShape::new(64, 64, 64);
        let g_large = GemmShape::new(2048, 2048, 2048);
        let t_small = cal.cycles_to_us(&g_small, 1000);
        let t_large = cal.cycles_to_us(&g_large, 1000);
        assert!((t_small - 1005.0).abs() < 1e-6);
        assert!((t_large - 3002.0).abs() < 1e-6);
    }

    #[test]
    fn global_fit_differs_from_regime_fits() {
        let obs = synth_observations();
        let global = fit_global(&obs).unwrap();
        let cal = fit_regime_calibration(&obs).unwrap();
        assert!((global.alpha - cal.small.alpha).abs() > 0.1);
    }

    #[test]
    fn json_roundtrip() {
        let obs = synth_observations();
        let cal = fit_regime_calibration(&obs).unwrap();
        let cal2 = RegimeCalibration::from_json(&cal.to_json()).unwrap();
        assert_eq!(cal.small, cal2.small);
        assert_eq!(cal.large, cal2.large);
    }
}
