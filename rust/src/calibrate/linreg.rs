//! Ordinary least squares y = α·x + β, used for the paper's
//! cycle-to-latency calibration (§4.1.1).

use crate::util::json::{Json, JsonError};
use crate::util::stats::{self, FitMetrics};

/// A fitted 1-D linear model.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LinearFit {
    /// Slope: effective seconds (or µs) per simulated cycle.
    pub alpha: f64,
    /// Intercept: fixed overheads not modeled by the simulator.
    pub beta: f64,
}

impl LinearFit {
    /// Least-squares fit. Requires at least two distinct x values.
    pub fn fit(x: &[f64], y: &[f64]) -> Option<LinearFit> {
        assert_eq!(x.len(), y.len());
        if x.len() < 2 {
            return None;
        }
        let n = x.len() as f64;
        let mx = x.iter().sum::<f64>() / n;
        let my = y.iter().sum::<f64>() / n;
        let mut sxx = 0.0;
        let mut sxy = 0.0;
        for (xi, yi) in x.iter().zip(y) {
            sxx += (xi - mx) * (xi - mx);
            sxy += (xi - mx) * (yi - my);
        }
        if sxx == 0.0 {
            return None;
        }
        let alpha = sxy / sxx;
        let beta = my - alpha * mx;
        Some(LinearFit { alpha, beta })
    }

    /// Predicted y at `x`.
    pub fn predict(&self, x: f64) -> f64 {
        self.alpha * x + self.beta
    }

    /// Predictions for a batch of x values.
    pub fn predict_batch(&self, xs: &[f64]) -> Vec<f64> {
        xs.iter().map(|&x| self.predict(x)).collect()
    }

    /// Fit-quality metrics of this model on (x, y).
    pub fn metrics(&self, x: &[f64], y: &[f64]) -> FitMetrics {
        let pred = self.predict_batch(x);
        FitMetrics::compute(y, &pred)
    }

    /// R² of this fit on (x, y).
    pub fn r2(&self, x: &[f64], y: &[f64]) -> f64 {
        stats::r2(y, &self.predict_batch(x))
    }

    /// Serialize for the asset files.
    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("alpha", Json::Num(self.alpha))
            .set("beta", Json::Num(self.beta));
        o
    }

    /// Deserialize from the asset files.
    pub fn from_json(j: &Json) -> Result<LinearFit, JsonError> {
        Ok(LinearFit {
            alpha: j.req_f64("alpha")?,
            beta: j.req_f64("beta")?,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_line_recovered() {
        let x: Vec<f64> = (0..50).map(|i| i as f64).collect();
        let y: Vec<f64> = x.iter().map(|&v| 2.5 * v + 7.0).collect();
        let f = LinearFit::fit(&x, &y).unwrap();
        assert!((f.alpha - 2.5).abs() < 1e-12);
        assert!((f.beta - 7.0).abs() < 1e-12);
        assert!((f.r2(&x, &y) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn noisy_line_close() {
        let x: Vec<f64> = (0..200).map(|i| i as f64).collect();
        // Deterministic "noise".
        let y: Vec<f64> = x
            .iter()
            .map(|&v| 3.0 * v + 10.0 + ((v * 12.9898).sin() * 2.0))
            .collect();
        let f = LinearFit::fit(&x, &y).unwrap();
        assert!((f.alpha - 3.0).abs() < 0.05);
        assert!((f.beta - 10.0).abs() < 2.0);
        assert!(f.r2(&x, &y) > 0.99);
    }

    #[test]
    fn degenerate_inputs() {
        assert!(LinearFit::fit(&[1.0], &[2.0]).is_none());
        assert!(LinearFit::fit(&[2.0, 2.0], &[1.0, 3.0]).is_none());
    }

    #[test]
    fn json_roundtrip() {
        let f = LinearFit {
            alpha: 1.25e-9,
            beta: 3.5e-6,
        };
        let f2 = LinearFit::from_json(&f.to_json()).unwrap();
        assert_eq!(f, f2);
    }
}
