//! Bootstrap confidence intervals for the calibration fits.
//!
//! The paper reports point estimates (α, β, R²) per regime; for a
//! *validated* simulator release the fits should carry uncertainty —
//! nonparametric bootstrap over the observation set gives percentile CIs
//! without distributional assumptions.

use crate::util::prng::Prng;
use crate::util::stats;

use super::linreg::LinearFit;

/// Percentile confidence interval.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Interval {
    /// Lower bound.
    pub lo: f64,
    /// Upper bound.
    pub hi: f64,
}

impl Interval {
    /// Is `v` inside the interval (inclusive)?
    pub fn contains(&self, v: f64) -> bool {
        v >= self.lo && v <= self.hi
    }

    /// Interval width.
    pub fn width(&self) -> f64 {
        self.hi - self.lo
    }
}

/// Bootstrap result for one linear fit.
#[derive(Debug, Clone)]
pub struct BootstrapResult {
    /// Confidence interval of the intercept.
    pub alpha: Interval,
    /// Confidence interval of the slope.
    pub beta: Interval,
    /// Confidence interval of R².
    pub r2: Interval,
    /// Bootstrap resamples drawn.
    pub resamples: usize,
}

/// Percentile bootstrap over (x, y) pairs.
///
/// `level` is the two-sided confidence level (e.g. 0.95). Resamples that
/// fail to fit (degenerate x) are skipped.
pub fn bootstrap_fit(
    x: &[f64],
    y: &[f64],
    resamples: usize,
    level: f64,
    seed: u64,
) -> Option<BootstrapResult> {
    assert_eq!(x.len(), y.len());
    if x.len() < 3 || resamples == 0 {
        return None;
    }
    let n = x.len();
    let mut prng = Prng::new(seed);
    let mut alphas = Vec::with_capacity(resamples);
    let mut betas = Vec::with_capacity(resamples);
    let mut r2s = Vec::with_capacity(resamples);

    for _ in 0..resamples {
        let mut bx = Vec::with_capacity(n);
        let mut by = Vec::with_capacity(n);
        for _ in 0..n {
            let i = prng.index(n);
            bx.push(x[i]);
            by.push(y[i]);
        }
        if let Some(fit) = LinearFit::fit(&bx, &by) {
            alphas.push(fit.alpha);
            betas.push(fit.beta);
            r2s.push(fit.r2(&bx, &by));
        }
    }
    if alphas.len() < resamples / 2 {
        return None;
    }

    let tail = (1.0 - level) / 2.0 * 100.0;
    let ci = |v: &[f64]| Interval {
        lo: stats::percentile(v, tail),
        hi: stats::percentile(v, 100.0 - tail),
    };
    Some(BootstrapResult {
        alpha: ci(&alphas),
        beta: ci(&betas),
        r2: ci(&r2s),
        resamples: alphas.len(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn noisy_line(n: usize, alpha: f64, beta: f64) -> (Vec<f64>, Vec<f64>) {
        let mut prng = Prng::new(5);
        let x: Vec<f64> = (0..n).map(|i| i as f64).collect();
        let y: Vec<f64> = x
            .iter()
            .map(|&v| alpha * v + beta + prng.normal_ms(0.0, 2.0))
            .collect();
        (x, y)
    }

    #[test]
    fn ci_contains_true_parameters() {
        let (x, y) = noisy_line(200, 3.0, 10.0);
        let b = bootstrap_fit(&x, &y, 500, 0.95, 42).unwrap();
        assert!(b.alpha.contains(3.0), "alpha CI {:?}", b.alpha);
        assert!(b.beta.contains(10.0), "beta CI {:?}", b.beta);
        assert!(b.r2.lo > 0.9);
        assert!(b.resamples >= 450);
    }

    #[test]
    fn more_data_narrows_ci() {
        let (x1, y1) = noisy_line(30, 2.0, 1.0);
        let (x2, y2) = noisy_line(500, 2.0, 1.0);
        let b1 = bootstrap_fit(&x1, &y1, 400, 0.95, 7).unwrap();
        let b2 = bootstrap_fit(&x2, &y2, 400, 0.95, 7).unwrap();
        assert!(b2.alpha.width() < b1.alpha.width());
    }

    #[test]
    fn degenerate_inputs_rejected() {
        assert!(bootstrap_fit(&[1.0, 2.0], &[1.0, 2.0], 100, 0.95, 1).is_none());
        let x = vec![5.0; 10];
        let y = vec![1.0; 10];
        assert!(bootstrap_fit(&x, &y, 100, 0.95, 1).is_none());
    }

    #[test]
    fn deterministic_in_seed() {
        let (x, y) = noisy_line(100, 1.0, 0.0);
        let a = bootstrap_fit(&x, &y, 200, 0.9, 3).unwrap();
        let b = bootstrap_fit(&x, &y, 200, 0.9, 3).unwrap();
        assert_eq!(a.alpha, b.alpha);
    }
}
