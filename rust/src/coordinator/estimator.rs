//! The whole-model latency estimator — the tool the paper ships.
//!
//! Holds the three modeling assets (SCALE-Sim config, cycle→time
//! calibration, per-operator learned models) and walks a parsed StableHLO
//! module: each op is classified and routed to its model; the result is a
//! per-op table plus totals and coverage statistics.

use std::collections::HashMap;
use std::sync::Arc;

use crate::calibrate::RegimeCalibration;
use crate::device::DeviceSpec;
use crate::frontend::classify::{classify, EwKind, OpClass};
use crate::frontend::opinfo::ModuleInfo;
use crate::learned::features::featurize;
use crate::learned::hgbr::CompiledHgbr;
use crate::learned::Hgbr;
use crate::scalesim::{simulate_gemm, ScaleConfig};

use super::cache::{CachedCost, ShapeKey, ShardedCache};

/// How one op's latency was obtained.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum EstimateSource {
    /// SCALE-Sim cycles + regime calibration.
    SystolicCalibrated,
    /// Learned (HGBR) elementwise model.
    Learned,
    /// Learned model for a *different* op kind used as proxy.
    LearnedProxy(String),
    /// Analytic bandwidth model (data movement / reductions).
    Bandwidth,
    /// Zero-cost op.
    Free,
    /// No model available; conservative elementwise fallback.
    Fallback,
}

/// Which whole-module estimation mode answered a request: the plain
/// unfused sum, the fusion bracket, or the dependence-graph schedule.
/// The service accounts module traffic per mode (see
/// [`ShardedCache::record_mode`](super::cache::ShardedCache::record_mode)).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum EstimateMode {
    /// Plain per-op program-order sum.
    Unfused,
    /// Fusion-bracket estimate (groups costed at their priciest member).
    Fused,
    /// Overlap-aware multi-engine schedule.
    Scheduled,
}

impl EstimateMode {
    /// Every mode, in reporting order.
    pub const ALL: [EstimateMode; 3] = [
        EstimateMode::Unfused,
        EstimateMode::Fused,
        EstimateMode::Scheduled,
    ];

    /// Stable lowercase name (stats keys, summaries).
    pub fn name(&self) -> &'static str {
        match self {
            EstimateMode::Unfused => "unfused",
            EstimateMode::Fused => "fused",
            EstimateMode::Scheduled => "scheduled",
        }
    }
}

impl EstimateSource {
    /// Stable lowercase tag (per-op tables, JSON `source` fields).
    pub fn tag(&self) -> &'static str {
        match self {
            EstimateSource::SystolicCalibrated => "systolic",
            EstimateSource::Learned => "learned",
            EstimateSource::LearnedProxy(_) => "learned-proxy",
            EstimateSource::Bandwidth => "bandwidth",
            EstimateSource::Free => "free",
            EstimateSource::Fallback => "fallback",
        }
    }
}

/// Per-op estimate row.
#[derive(Debug, Clone)]
pub struct OpEstimate {
    /// Index of the op within its function.
    pub index: usize,
    /// Fully qualified op name (calls render as `call @callee`).
    pub op_name: String,
    /// Which cost model answered.
    pub source: EstimateSource,
    /// Simulated cycles (systolic ops only).
    pub cycles: Option<u64>,
    /// Estimated latency, µs.
    pub latency_us: f64,
    /// Shape/context note for tables.
    pub note: String,
}

/// Whole-module estimate.
#[derive(Debug, Clone)]
pub struct ModelEstimate {
    /// Module the estimate covers.
    pub module_name: String,
    /// One row per entry-function op (calls inlined as single rows).
    pub ops: Vec<OpEstimate>,
    /// Unfused program-order sum, µs.
    pub total_us: f64,
    /// Share spent in systolic (MXU) ops, µs.
    pub systolic_us: f64,
    /// Share spent in elementwise (VPU) ops, µs.
    pub elementwise_us: f64,
    /// Share spent in everything else (bandwidth/fallback), µs.
    pub other_us: f64,
    /// Ops covered by a first-class model (systolic or learned).
    pub covered_ops: usize,
    /// Ops that carry any nonzero cost model.
    pub total_costed_ops: usize,
}

impl ModelEstimate {
    /// Fraction of costed ops covered by a first-class model, in [0, 1].
    pub fn coverage(&self) -> f64 {
        if self.total_costed_ops == 0 {
            return 1.0;
        }
        self.covered_ops as f64 / self.total_costed_ops as f64
    }
}

/// The estimator: device model + config + calibration + learned models.
pub struct Estimator {
    /// SCALE-Sim architecture config for systolic simulation. Prefer
    /// [`Estimator::set_config`] over assigning this field directly:
    /// the setter keeps the cache identity in sync, direct assignment
    /// on an estimator that already memoised estimates does not.
    pub config: ScaleConfig,
    /// Per-regime cycle-to-time linear calibration, already transferred
    /// onto this estimator's device.
    pub calibration: RegimeCalibration,
    /// Per-operator learned models (keyed by EwKind name).
    pub learned: HashMap<String, Hgbr>,
    /// Flattened inference forms (built lazily from `learned`; see
    /// EXPERIMENTS.md §Perf L3 — ~4x faster than tree walking).
    compiled: std::sync::RwLock<HashMap<String, CompiledHgbr>>,
    /// HBM bandwidth for the data-movement fallback, bytes/µs. Private
    /// and immutable: it feeds cached costs (it is part of `cache_fp`),
    /// so a different bandwidth means a different estimator
    /// ([`Estimator::for_device`] / [`Estimator::retarget`]).
    hbm_bytes_per_us: f64,
    /// The device this estimator answers for. Private: every derived
    /// field (`config`, `hbm_bytes_per_us`, the cache fingerprint, the
    /// elementwise transfer scale) must move with it, so switching
    /// devices goes through [`Estimator::retarget`].
    device: DeviceSpec,
    /// Cached [`DeviceSpec::fingerprint`] of `device` (the "same
    /// hardware?" identity [`Estimator::retarget`] compares).
    device_fp: u64,
    /// The cost-model identity folded into every [`ShapeKey`]: the
    /// device fingerprint mixed with the *active* systolic config and
    /// HBM bandwidth. Estimators sharing a cache can then never alias
    /// even if one was constructed with a config its device tag does
    /// not imply (e.g. an asset file's saved config).
    cache_fp: u64,
    /// Latency multiplier applied to learned elementwise predictions
    /// (the models are trained on `ref_device`); exactly 1 on the
    /// reference device.
    ew_scale: f64,
    /// The device the calibration + learned models were measured on
    /// (the retarget source; see [`Estimator::retarget`]).
    ref_device: DeviceSpec,
    /// The calibration as measured on `ref_device`, before any transfer.
    ref_calibration: RegimeCalibration,
    /// Sharded shape-keyed memo cache: repeated shapes (the common case
    /// when many models share layer dimensions) skip cycle-accurate
    /// re-simulation entirely. Behind an [`Arc`] so estimators
    /// retargeted onto other devices share one cache (and one set of
    /// hit/miss/mode counters). See [`super::cache`].
    pub cache: Arc<ShardedCache>,
}

impl Estimator {
    /// The [`ShapeKey`] fingerprint: the device identity mixed with the
    /// active systolic config and HBM bandwidth — everything a cached
    /// cost depends on besides the shape itself (the calibration and
    /// learned-model set are pure functions of the device within one
    /// retarget lineage, and [`Estimator::add_learned`] clears the
    /// cache).
    fn mix_cache_fp(device_fp: u64, config: &ScaleConfig, hbm_bytes_per_us: f64) -> u64 {
        let mut h = device_fp ^ 0x9e37_79b9_7f4a_7c15;
        let mut put = |v: u64| {
            for b in v.to_le_bytes() {
                h = (h ^ b as u64).wrapping_mul(0x0000_0100_0000_01b3);
            }
        };
        put(config.array_rows as u64);
        put(config.array_cols as u64);
        put(match config.dataflow {
            crate::scalesim::Dataflow::OutputStationary => 0,
            crate::scalesim::Dataflow::WeightStationary => 1,
            crate::scalesim::Dataflow::InputStationary => 2,
        });
        put(config.ifmap_sram_kb as u64);
        put(config.filter_sram_kb as u64);
        put(config.ofmap_sram_kb as u64);
        put(config.ifmap_dram_bw.to_bits());
        put(config.filter_dram_bw.to_bits());
        put(config.ofmap_dram_bw.to_bits());
        put(config.word_bytes as u64);
        put(config.freq_mhz.to_bits());
        put(hbm_bytes_per_us.to_bits());
        h
    }

    /// An estimator with no learned models and an empty cache, answering
    /// for the reference device ([`DeviceSpec::tpu_v4`]).
    pub fn new(config: ScaleConfig, calibration: RegimeCalibration) -> Estimator {
        let device = DeviceSpec::tpu_v4();
        let device_fp = device.fingerprint();
        let hbm_bytes_per_us = 1.2e6;
        let cache_fp = Estimator::mix_cache_fp(device_fp, &config, hbm_bytes_per_us);
        Estimator {
            config,
            calibration: calibration.clone(),
            learned: HashMap::new(),
            compiled: std::sync::RwLock::new(HashMap::new()),
            hbm_bytes_per_us,
            ref_device: device.clone(),
            ref_calibration: calibration,
            device,
            device_fp,
            cache_fp,
            ew_scale: 1.0,
            cache: Arc::new(ShardedCache::new()),
        }
    }

    /// An estimator answering for `device`, deriving its systolic config
    /// and HBM bandwidth from the spec. `calibration` must have been
    /// measured on this same device (it becomes the retarget reference).
    pub fn for_device(device: DeviceSpec, calibration: RegimeCalibration) -> Estimator {
        let device_fp = device.fingerprint();
        let config = device.scale_config();
        let hbm_bytes_per_us = device.hbm_bytes_per_us();
        let cache_fp = Estimator::mix_cache_fp(device_fp, &config, hbm_bytes_per_us);
        Estimator {
            config,
            calibration: calibration.clone(),
            learned: HashMap::new(),
            compiled: std::sync::RwLock::new(HashMap::new()),
            hbm_bytes_per_us,
            ref_device: device.clone(),
            ref_calibration: calibration,
            device,
            device_fp,
            cache_fp,
            ew_scale: 1.0,
            cache: Arc::new(ShardedCache::new()),
        }
    }

    /// A new estimator answering for `device`, sharing this estimator's
    /// learned models, reference calibration and shape cache.
    ///
    /// Retargeting always starts from the *reference* assets (the device
    /// the models were measured on), never from an already-transferred
    /// calibration, so retargets do not compound: `a.retarget(x)` and
    /// `a.retarget(y).retarget(x)` answer identically. Retargeting onto
    /// the estimator's own device is bit-identical to the original
    /// (tested in `tests/device_spec.rs`); the shared cache stays safe
    /// because every entry is keyed by the cost-model fingerprint
    /// (device + active config + bandwidth).
    pub fn retarget(&self, device: &DeviceSpec) -> Estimator {
        let device_fp = device.fingerprint();
        let compiled = self.compiled.read().unwrap().clone();
        if device_fp == self.device_fp {
            // Same hardware: keep the active config/calibration exactly
            // as they are (they may carry asset-file state the spec
            // derivation would normalize away). The cache identity is
            // copied too — identical cost model, shared entries.
            return Estimator {
                config: self.config.clone(),
                calibration: self.calibration.clone(),
                learned: self.learned.clone(),
                compiled: std::sync::RwLock::new(compiled),
                hbm_bytes_per_us: self.hbm_bytes_per_us,
                ref_device: self.ref_device.clone(),
                ref_calibration: self.ref_calibration.clone(),
                device: device.clone(),
                device_fp,
                cache_fp: self.cache_fp,
                ew_scale: self.ew_scale,
                cache: Arc::clone(&self.cache),
            };
        }
        let config = device.scale_config();
        let hbm_bytes_per_us = device.hbm_bytes_per_us();
        let cache_fp = Estimator::mix_cache_fp(device_fp, &config, hbm_bytes_per_us);
        Estimator {
            config,
            calibration: device.transfer_calibration(&self.ref_device, &self.ref_calibration),
            learned: self.learned.clone(),
            compiled: std::sync::RwLock::new(compiled),
            hbm_bytes_per_us,
            ref_device: self.ref_device.clone(),
            ref_calibration: self.ref_calibration.clone(),
            ew_scale: device.ew_scale(&self.ref_device),
            device: device.clone(),
            device_fp,
            cache_fp,
            cache: Arc::clone(&self.cache),
        }
    }

    /// The device this estimator answers for.
    pub fn device(&self) -> &DeviceSpec {
        &self.device
    }

    /// The cached fingerprint of [`Estimator::device`] (the hardware
    /// identity [`Estimator::retarget`] compares).
    pub fn device_fingerprint(&self) -> u64 {
        self.device_fp
    }

    /// The cost-model identity folded into every [`ShapeKey`] (device
    /// fingerprint + active config + HBM bandwidth).
    pub(crate) fn cache_fingerprint(&self) -> u64 {
        self.cache_fp
    }

    /// Register (and pre-compile) the learned model for one op kind.
    pub fn add_learned(&mut self, kind: EwKind, model: Hgbr) {
        self.compiled
            .write()
            .unwrap()
            .insert(kind.name().to_string(), model.compile());
        self.learned.insert(kind.name().to_string(), model);
        // Elementwise entries may have been memoised against the old model
        // set (e.g. as fallbacks); drop them rather than serve stale costs.
        self.cache.clear();
    }

    /// HBM bandwidth used by the bandwidth fallback (and the memory
    /// timeline). Immutable after construction: it is part of the cache
    /// identity, so changing it means building a new estimator
    /// ([`Estimator::for_device`] / [`Estimator::retarget`]).
    pub fn hbm_bytes_per_us(&self) -> f64 {
        self.hbm_bytes_per_us
    }

    /// Replace this estimator's memo cache with a shared one — the
    /// fan-out idiom: per-worker estimators built independently (e.g.
    /// one per device preset) pool their entries in a single
    /// [`ShardedCache`]. Safe by construction: every entry is keyed by
    /// the per-estimator cost-model fingerprint, so workers can never
    /// alias each other's costs, and every cached value is a pure
    /// function of its key, so results are independent of cache state.
    pub fn with_shared_cache(mut self, cache: Arc<ShardedCache>) -> Estimator {
        self.cache = cache;
        self
    }

    /// Replace the active systolic config (the asset loader installs
    /// the exact config the saved calibration was simulated with). The
    /// cache identity follows the config, so entries memoised by other
    /// estimators sharing this cache can never be aliased.
    pub fn set_config(&mut self, config: ScaleConfig) {
        self.config = config;
        self.cache_fp =
            Estimator::mix_cache_fp(self.device_fp, &self.config, self.hbm_bytes_per_us);
    }

    /// Predict via the flattened model for `name`, compiling on first use
    /// (models loaded from disk bypass `add_learned`).
    fn predict_compiled(&self, name: &str, row: &[f64]) -> f64 {
        if let Some(c) = self.compiled.read().unwrap().get(name) {
            return c.predict(row);
        }
        let model = &self.learned[name];
        let compiled = model.compile();
        let v = compiled.predict(row);
        self.compiled
            .write()
            .unwrap()
            .insert(name.to_string(), compiled);
        v
    }

    /// Predict a contiguous row-major feature batch through the model for
    /// `name` (compiling on first use), appending one value per row to
    /// `out`. One read-lock acquisition for the whole batch — the batched
    /// core's replacement for per-op `predict_compiled` calls; per-row
    /// arithmetic is identical, so results are bit-identical.
    pub(crate) fn predict_compiled_many(
        &self,
        name: &str,
        rows: &[f64],
        stride: usize,
        out: &mut Vec<f64>,
    ) {
        {
            let guard = self.compiled.read().unwrap();
            if let Some(c) = guard.get(name) {
                c.predict_many(rows, stride, out);
                return;
            }
        }
        let compiled = self.learned[name].compile();
        compiled.predict_many(rows, stride, out);
        self.compiled
            .write()
            .unwrap()
            .insert(name.to_string(), compiled);
    }

    /// Scale + clamp a raw learned-model prediction. The learned models
    /// were trained on the reference device; other devices scale the
    /// prediction by the elementwise roofline ratio (exactly 1 on the
    /// reference, so the skip preserves bit-identity). Shared by the
    /// scalar and batched paths so the arithmetic is literally the same
    /// code.
    pub(crate) fn finish_ew_prediction(&self, mut t: f64) -> f64 {
        if self.ew_scale != 1.0 {
            t *= self.ew_scale;
        }
        t.max(0.0)
    }

    /// Pick the learned model name for `kind`, falling back to a proxy of
    /// the same arity class.
    pub(crate) fn learned_for(&self, kind: EwKind) -> Option<(String, EstimateSource)> {
        if self.learned.contains_key(kind.name()) {
            return Some((kind.name().to_string(), EstimateSource::Learned));
        }
        // Proxy: prefer `add` for arithmetic, `maximum` for comparisons.
        let proxy_order: &[&str] = match kind {
            EwKind::Maximum | EwKind::Minimum | EwKind::Compare | EwKind::Select => {
                &["maximum", "add"]
            }
            _ => &["add", "maximum"],
        };
        for name in proxy_order {
            if self.learned.contains_key(*name) {
                return Some((name.to_string(), EstimateSource::LearnedProxy(name.to_string())));
            }
        }
        None
    }

    /// Estimate a whole module (entry function; `call` ops recurse into
    /// their callees so Pallas-lowered modules with private sub-functions
    /// are still costed).
    ///
    /// This is a thin wrapper over the batched core
    /// ([`super::batch::OpTable`]): the module is lowered once into a
    /// structure-of-arrays op table, the shape cache is probed with one
    /// lock acquisition per shard per batch, and misses are evaluated
    /// class-by-class over contiguous arrays. The result — rows, totals,
    /// and cache hit/miss/source counters — is bit-identical to the
    /// per-op reference walk kept as
    /// [`Estimator::estimate_module_scalar`] (property-tested across
    /// every device preset and fixture in `tests/estimator_batch.rs`).
    pub fn estimate_module(&self, module: &ModuleInfo) -> ModelEstimate {
        let table = self.lower_module(module);
        self.estimate_table(&table)
    }

    /// Lower `module` into a batched op table bound to this estimator's
    /// cache fingerprint: the classify / shape-key / dedup work is done
    /// once, so repeated estimates of the same module (the serve and
    /// bench hot paths) go straight to the grouped cache probe. See
    /// [`super::batch::OpTable`].
    pub fn lower_module<'m>(&self, module: &'m ModuleInfo) -> super::batch::OpTable<'m> {
        super::batch::OpTable::lower(self.cache_fp, module)
    }

    /// The per-op reference walk `estimate_module` used before the
    /// batched core existed: classify → [`Estimator::estimate_op`] for
    /// each op in program order. Kept as the bit-identity oracle for the
    /// batched path (property tests) and as the scalar baseline the
    /// `estimator_batch` bench measures against.
    pub fn estimate_module_scalar(&self, module: &ModuleInfo) -> ModelEstimate {
        self.estimate_func(module, module.entry().map(|f| f.name.as_str()), 0)
    }

    fn estimate_func(
        &self,
        module: &ModuleInfo,
        func_name: Option<&str>,
        depth: usize,
    ) -> ModelEstimate {
        let mut est = ModelEstimate {
            module_name: module.name.clone(),
            ops: Vec::new(),
            total_us: 0.0,
            systolic_us: 0.0,
            elementwise_us: 0.0,
            other_us: 0.0,
            covered_ops: 0,
            total_costed_ops: 0,
        };
        let Some(func) = func_name.and_then(|n| module.funcs.iter().find(|f| f.name == n))
        else {
            return est;
        };

        for op in &func.ops {
            // Follow calls into private sub-functions (depth-limited).
            if (op.short_name() == "call" || op.op_name == "func.call") && depth < 4 {
                if let Some(callee) = &op.callee {
                    let sub = self.estimate_func(module, Some(callee), depth + 1);
                    est.total_us += sub.total_us;
                    est.systolic_us += sub.systolic_us;
                    est.elementwise_us += sub.elementwise_us;
                    est.other_us += sub.other_us;
                    est.covered_ops += sub.covered_ops;
                    est.total_costed_ops += sub.total_costed_ops;
                    est.ops.push(OpEstimate {
                        index: op.index,
                        op_name: format!("call @{callee}"),
                        source: EstimateSource::SystolicCalibrated,
                        cycles: None,
                        latency_us: sub.total_us,
                        note: format!("inlined {} ops", sub.ops.len()),
                    });
                    continue;
                }
            }
            let class = classify(op);
            let row = self.estimate_op(op.index, &op.op_name, &class);
            match class {
                OpClass::SystolicGemm { .. } | OpClass::SystolicConv { .. } => {
                    est.systolic_us += row.latency_us;
                    est.covered_ops += 1;
                    est.total_costed_ops += 1;
                }
                OpClass::Elementwise { .. } => {
                    est.elementwise_us += row.latency_us;
                    if matches!(
                        row.source,
                        EstimateSource::Learned | EstimateSource::LearnedProxy(_)
                    ) {
                        est.covered_ops += 1;
                    }
                    est.total_costed_ops += 1;
                }
                // Free ops cost nothing; collectives are also free on a
                // single chip (XLA elides them) — the distributed
                // estimator costs them against a real slice.
                OpClass::Free | OpClass::Collective { .. } => {}
                _ => {
                    est.other_us += row.latency_us;
                    est.total_costed_ops += 1;
                }
            }
            est.total_us += row.latency_us;
            est.ops.push(row);
        }
        est
    }

    /// Estimate one classified op, memoising through the shape cache.
    ///
    /// The cost functions are deterministic in the [`ShapeKey`], so cached
    /// and freshly computed estimates are bit-identical.
    pub fn estimate_op(&self, index: usize, op_name: &str, class: &OpClass) -> OpEstimate {
        let est = match ShapeKey::of_class(self.cache_fp, class) {
            Some(key) => match self.cache.lookup(&key) {
                Some(hit) => hit.into_estimate(index, op_name),
                None => {
                    let cost = self.cost_class_uncached(class);
                    self.cache.store(key, cost.clone());
                    cost.into_estimate(index, op_name)
                }
            },
            None => self.cost_class_uncached(class).into_estimate(index, op_name),
        };
        self.cache.record_source(&est.source);
        est
    }

    /// The raw (un-memoised) per-class cost model, producing the
    /// position-independent [`CachedCost`] both the scalar and batched
    /// paths rehydrate into [`OpEstimate`] rows — one shared cost
    /// function, so the two paths cannot drift.
    pub(crate) fn cost_class_uncached(&self, class: &OpClass) -> CachedCost {
        match class {
            OpClass::SystolicGemm { gemm, count }
            | OpClass::SystolicConv { gemm, count, .. } => {
                let report = simulate_gemm(&self.config, *gemm);
                let cycles = report.total_cycles();
                let t = self.calibration.cycles_to_us(gemm, cycles) * *count as f64;
                CachedCost {
                    source: EstimateSource::SystolicCalibrated,
                    cycles: Some(cycles * count),
                    latency_us: t.max(0.0),
                    note: format!("{gemm} x{count}"),
                }
            }
            OpClass::Elementwise { kind, out } => match self.learned_for(*kind) {
                Some((model_name, source)) => {
                    let t = self
                        .finish_ew_prediction(self.predict_compiled(&model_name, &featurize(&out.dims)));
                    CachedCost {
                        source,
                        cycles: None,
                        latency_us: t,
                        note: format!("{out}"),
                    }
                }
                None => CachedCost {
                    source: EstimateSource::Fallback,
                    cycles: None,
                    latency_us: self.bandwidth_us(out.size_bytes() * 3),
                    note: format!("no learned model for {}", kind.name()),
                },
            },
            OpClass::Reduction { input, out } => CachedCost {
                source: EstimateSource::Bandwidth,
                cycles: None,
                latency_us: self.bandwidth_us(input.size_bytes() + out.size_bytes()),
                note: format!("reduce {input} -> {out}"),
            },
            OpClass::DataMovement { bytes, out } => CachedCost {
                source: EstimateSource::Bandwidth,
                cycles: None,
                // Read + write the moved bytes.
                latency_us: self.bandwidth_us(bytes * 2),
                note: format!("{out}"),
            },
            OpClass::Free => CachedCost {
                source: EstimateSource::Free,
                cycles: None,
                latency_us: 0.0,
                note: String::new(),
            },
            OpClass::Collective { kind, out, .. } => CachedCost {
                source: EstimateSource::Free,
                cycles: None,
                latency_us: 0.0,
                note: format!("{kind} {out}: zero-cost on one chip (use --chips)"),
            },
            OpClass::Unmodeled { reason, out } => CachedCost {
                source: EstimateSource::Fallback,
                cycles: None,
                latency_us: out
                    .as_ref()
                    .map(|t| self.bandwidth_us(t.size_bytes() * 3))
                    .unwrap_or(0.0),
                note: reason.clone(),
            },
        }
    }

    pub(crate) fn bandwidth_us(&self, bytes: u64) -> f64 {
        0.5 + bytes as f64 / self.hbm_bytes_per_us
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::calibrate::fit_regime_calibration;
    use crate::frontend::parse_module;
    use crate::learned::HgbrParams;
    use crate::scalesim::topology::GemmShape;

    fn trivial_calibration() -> RegimeCalibration {
        // One observation set per regime with slope 1e-3 µs/cycle.
        let mut obs = Vec::new();
        for d in [32usize, 64, 128, 256, 512, 1024, 2048, 4096] {
            let g = GemmShape::new(d, d, d);
            let cycles = (d * d) as u64;
            obs.push((g, cycles, cycles as f64 * 1e-3 + 2.0));
        }
        fit_regime_calibration(&obs).unwrap()
    }

    fn learned_add_model() -> Hgbr {
        // Train a tiny model: latency = 1 + n/1e5.
        let shapes: Vec<Vec<usize>> = (1..200).map(|i| vec![i * 64]).collect();
        let rows: Vec<Vec<f64>> = shapes.iter().map(|s| featurize(s)).collect();
        let y: Vec<f64> = shapes
            .iter()
            .map(|s| 1.0 + (s[0] as f64) / 1e5)
            .collect();
        Hgbr::fit(
            &rows,
            &y,
            &crate::learned::feature_names(),
            &HgbrParams {
                max_iter: 50,
                ..Default::default()
            },
        )
    }

    const MODULE: &str = r#"
module @test_model {
  func.func public @main(%a: tensor<128x256xbf16>, %b: tensor<256x512xbf16>, %c: tensor<128x512xbf16>) -> (tensor<128x512xbf16>) {
    %0 = stablehlo.dot_general %a, %b, contracting_dims = [1] x [0] : (tensor<128x256xbf16>, tensor<256x512xbf16>) -> tensor<128x512xbf16>
    %1 = stablehlo.add %0, %c : tensor<128x512xbf16>
    %cst = stablehlo.constant dense<0.0> : tensor<bf16>
    %2 = stablehlo.broadcast_in_dim %cst, dims = [] : (tensor<bf16>) -> tensor<128x512xbf16>
    %3 = stablehlo.maximum %1, %2 : tensor<128x512xbf16>
    return %3 : tensor<128x512xbf16>
  }
}
"#;

    #[test]
    fn estimates_whole_module() {
        let module = parse_module(MODULE).unwrap();
        let mut est = Estimator::new(ScaleConfig::tpu_v4(), trivial_calibration());
        est.add_learned(EwKind::Add, learned_add_model());
        let report = est.estimate_module(&module);

        assert_eq!(report.ops.len(), 5);
        assert!(report.total_us > 0.0);
        assert!(report.systolic_us > 0.0);
        assert!(report.elementwise_us > 0.0);
        // dot uses the calibrated path.
        assert_eq!(report.ops[0].source, EstimateSource::SystolicCalibrated);
        assert!(report.ops[0].cycles.is_some());
        // add uses the learned model; maximum proxies through add.
        assert_eq!(report.ops[1].source, EstimateSource::Learned);
        assert_eq!(
            report.ops[4].source,
            EstimateSource::LearnedProxy("add".to_string())
        );
        // constant is free.
        assert_eq!(report.ops[2].source, EstimateSource::Free);
        assert_eq!(report.ops[2].latency_us, 0.0);
        // totals decompose.
        let sum = report.systolic_us + report.elementwise_us + report.other_us;
        assert!((sum - report.total_us).abs() < 1e-9);
    }

    #[test]
    fn coverage_reflects_missing_models() {
        let module = parse_module(MODULE).unwrap();
        let est = Estimator::new(ScaleConfig::tpu_v4(), trivial_calibration());
        // No learned models at all: elementwise ops fall back.
        let report = est.estimate_module(&module);
        assert!(report.coverage() < 1.0);
        assert!(report
            .ops
            .iter()
            .any(|o| o.source == EstimateSource::Fallback));
    }

    #[test]
    fn cache_returns_bit_identical_estimates() {
        let est = Estimator::new(ScaleConfig::tpu_v4(), trivial_calibration());
        let class = OpClass::SystolicGemm {
            gemm: GemmShape::new(384, 384, 384),
            count: 2,
        };
        let cold = est.estimate_op(3, "dot", &class);
        let warm = est.estimate_op(9, "dot2", &class);
        assert_eq!(cold.latency_us.to_bits(), warm.latency_us.to_bits());
        assert_eq!(cold.cycles, warm.cycles);
        assert_eq!(cold.source, warm.source);
        assert_eq!(cold.note, warm.note);
        // Instance fields are rehydrated per call, not cached.
        assert_eq!(warm.index, 9);
        assert_eq!(warm.op_name, "dot2");
        let s = est.cache.stats();
        assert_eq!((s.hits, s.misses, s.entries), (1, 1, 1));
        assert_eq!(s.systolic, 2);
        // An uncached recomputation matches the memoised value exactly.
        est.cache.set_enabled(false);
        let raw = est.estimate_op(0, "dot", &class);
        assert_eq!(raw.latency_us.to_bits(), cold.latency_us.to_bits());
        assert_eq!(raw.cycles, cold.cycles);
    }

    #[test]
    fn add_learned_invalidates_cached_fallbacks() {
        let mut est = Estimator::new(ScaleConfig::tpu_v4(), trivial_calibration());
        let class = OpClass::Elementwise {
            kind: EwKind::Add,
            out: crate::frontend::types::TensorType::new(
                vec![512, 512],
                crate::frontend::types::DType::Bf16,
            ),
        };
        let before = est.estimate_op(0, "add", &class);
        assert_eq!(before.source, EstimateSource::Fallback);
        assert_eq!(est.cache.len(), 1);
        est.add_learned(EwKind::Add, learned_add_model());
        assert_eq!(est.cache.len(), 0, "stale entries must be dropped");
        let after = est.estimate_op(0, "add", &class);
        assert_eq!(after.source, EstimateSource::Learned);
    }

    #[test]
    fn retarget_onto_own_device_is_bit_identical_and_shares_the_cache() {
        let module = parse_module(MODULE).unwrap();
        let mut est = Estimator::new(ScaleConfig::tpu_v4(), trivial_calibration());
        est.add_learned(EwKind::Add, learned_add_model());
        let base = est.estimate_module(&module);
        let rt = est.retarget(&crate::device::DeviceSpec::tpu_v4());
        assert_eq!(rt.device_fingerprint(), est.device_fingerprint());
        let again = rt.estimate_module(&module);
        assert_eq!(base.total_us.to_bits(), again.total_us.to_bits());
        for (a, b) in base.ops.iter().zip(&again.ops) {
            assert_eq!(a.latency_us.to_bits(), b.latency_us.to_bits());
        }
        // One shared cache: the retargeted walk re-used the entries the
        // first walk stored (same device fingerprint).
        let s = est.cache.stats();
        assert!(s.hits >= 2, "retargeted walk missed the shared cache: {s:?}");
    }

    #[test]
    fn retarget_onto_another_device_differs_and_never_aliases() {
        let module = parse_module(MODULE).unwrap();
        let mut est = Estimator::new(ScaleConfig::tpu_v4(), trivial_calibration());
        est.add_learned(EwKind::Add, learned_add_model());
        let v5e = est.retarget(&crate::device::DeviceSpec::tpu_v5e());
        assert_ne!(v5e.device_fingerprint(), est.device_fingerprint());
        let base = est.estimate_module(&module);
        let other = v5e.estimate_module(&module);
        // v5e is slower on every axis in this module: smaller SRAM /
        // DRAM interface, scaled elementwise models.
        assert!(other.total_us > base.total_us);
        // Same shapes, two devices, one cache: entries never alias, and
        // re-asking the original device reproduces its answer exactly.
        let again = est.estimate_module(&module);
        assert_eq!(base.total_us.to_bits(), again.total_us.to_bits());
        // Retargets never compound: going v5e -> v5p equals v4 -> v5p.
        let via = v5e.retarget(&crate::device::DeviceSpec::tpu_v5p());
        let direct = est.retarget(&crate::device::DeviceSpec::tpu_v5p());
        assert_eq!(
            via.estimate_module(&module).total_us.to_bits(),
            direct.estimate_module(&module).total_us.to_bits()
        );
    }

    #[test]
    fn batched_dot_scales_count() {
        let text = r#"
module { func.func @main(%a: tensor<4x64x64xf32>, %b: tensor<4x64x64xf32>) -> tensor<4x64x64xf32> {
  %0 = stablehlo.dot_general %a, %b, batching_dims = [0] x [0], contracting_dims = [2] x [1] : (tensor<4x64x64xf32>, tensor<4x64x64xf32>) -> tensor<4x64x64xf32>
  return %0 : tensor<4x64x64xf32>
} }"#;
        let module = parse_module(text).unwrap();
        let est = Estimator::new(ScaleConfig::tpu_v4(), trivial_calibration());
        let report = est.estimate_module(&module);
        let single = {
            let class = OpClass::SystolicGemm {
                gemm: GemmShape::new(64, 64, 64),
                count: 1,
            };
            est.estimate_op(0, "dot", &class).latency_us
        };
        assert!((report.ops[0].latency_us - 4.0 * single).abs() < 1e-9);
    }
}
