//! The batched (structure-of-arrays) estimator core.
//!
//! [`Estimator::estimate_module`](super::Estimator::estimate_module)
//! used to walk a module op by op: classify, build a shape key, take a
//! cache-shard lock, probe, maybe simulate — per op. At fleet scale the
//! estimator's *throughput* is itself the product (NeuroScalar makes the
//! argument for simulation at large), so this module restructures the
//! hot path around whole-module batches:
//!
//! 1. **Lower** — [`Estimator::lower_module`](super::Estimator::lower_module)
//!    flattens the entry function (and
//!    its `call` tree, depth-limited exactly like the scalar walk) into
//!    parallel structure-of-arrays columns: op index, op name, class
//!    (dims/dtype/bytes), plus a deduplicated table of cacheable shape
//!    keys with occurrence counts. All classify/key/dedup work happens
//!    once per module, not once per estimate.
//! 2. **Grouped probe** — the unique keys are probed through
//!    [`ShardedCache::lookup_grouped`](super::ShardedCache::lookup_grouped):
//!    one lock acquisition per *shard* per batch instead of one per op.
//! 3. **Class-grouped evaluation** — misses are evaluated class by
//!    class: systolic shapes run through the cycle-accurate simulator;
//!    learned elementwise shapes are featurized into one contiguous
//!    row-major matrix per model and predicted in a single
//!    [`CompiledHgbr::predict_many`](crate::learned::hgbr::CompiledHgbr::predict_many)
//!    pass (one model-registry lock per batch).
//! 4. **Assemble** — the lowering's event stream is replayed to rebuild
//!    the per-op [`ModelEstimate`] in the exact program order — and the
//!    exact floating-point accumulation order — of the scalar walk.
//!
//! **Bit-identity invariant.** The batched path must be indistinguishable
//! from [`Estimator::estimate_module_scalar`](super::Estimator::estimate_module_scalar):
//! every row, every `f64` total (bit for bit — f64 addition is not
//! associative, hence the event replay), and every cache hit/miss/source
//! counter. Counter parity holds because a batch accounts each unique
//! shape as the scalar walk would have: the first occurrence of a fresh
//! shape misses, every further occurrence hits the just-stored entry.
//! The invariant is property-tested across every device preset × every
//! fixture × cold/warm/disabled cache in `tests/estimator_batch.rs`.

use std::collections::HashMap;

use crate::frontend::classify::{classify, OpClass};
use crate::frontend::opinfo::ModuleInfo;
use crate::frontend::types::TensorType;
use crate::learned::features::featurize;

use super::cache::{source_index, CachedCost, ShapeClass, ShapeKey};
use super::estimator::{EstimateSource, Estimator, ModelEstimate, OpEstimate};

/// One step of the lowered entry-function walk. Replaying the events in
/// order reproduces the scalar recursion's program order (and therefore
/// its floating-point accumulation order) exactly.
pub(crate) enum LowerEvent<'m> {
    /// Op table row `.0` is estimated in place.
    Leaf(u32),
    /// A `call` op entering its callee: everything until the matching
    /// [`LowerEvent::CallEnd`] belongs to the inlined sub-estimate.
    CallBegin {
        /// Index of the call op within its function.
        index: usize,
        /// Callee name (rendered as `call @callee`).
        callee: &'m str,
    },
    /// Close the innermost open call and fold its sub-estimate into the
    /// parent as one row.
    CallEnd,
}

/// A module lowered into structure-of-arrays form for batched
/// estimation, bound to the cache fingerprint of the estimator that
/// lowered it.
///
/// Build one with
/// [`Estimator::lower_module`](super::Estimator::lower_module) and
/// estimate it (repeatedly — that is the point) with
/// [`Estimator::estimate_table`]. The table borrows the module, so the
/// classify / shape-key / dedup work is paid once; a warm re-estimate is
/// just a grouped probe plus row rehydration. Estimating a table through
/// an estimator with a *different* cache fingerprint still works — the
/// unique keys are re-keyed on the fly — it only costs the rekeying.
pub struct OpTable<'m> {
    /// Module name for the assembled [`ModelEstimate`].
    module_name: String,
    /// The lowered walk (leaves + call brackets) in program order.
    events: Vec<LowerEvent<'m>>,
    /// SoA column: op index within its function, per leaf.
    indices: Vec<usize>,
    /// SoA column: op name, per leaf (borrowed from the module).
    names: Vec<&'m str>,
    /// SoA column: classified op (class, dims, dtype, bytes), per leaf.
    classes: Vec<OpClass>,
    /// SoA column: slot into `unique` for cacheable leaves.
    slots: Vec<Option<u32>>,
    /// Deduplicated cacheable shape keys, first-occurrence order.
    unique: Vec<ShapeKey>,
    /// Occurrences per unique key (for scalar-exact hit/miss counts).
    occurrences: Vec<u64>,
    /// The estimator cache fingerprint the keys were built against.
    cache_fp: u64,
}

impl<'m> OpTable<'m> {
    /// Lower `module`'s entry function (following `call` ops into their
    /// callees, depth-limited exactly like the scalar walk) into an op
    /// table keyed against `cache_fp`.
    pub(crate) fn lower(cache_fp: u64, module: &'m ModuleInfo) -> OpTable<'m> {
        let mut table = OpTable {
            module_name: module.name.clone(),
            events: Vec::new(),
            indices: Vec::new(),
            names: Vec::new(),
            classes: Vec::new(),
            slots: Vec::new(),
            unique: Vec::new(),
            occurrences: Vec::new(),
            cache_fp,
        };
        let mut seen: HashMap<ShapeKey, u32> = HashMap::new();
        if let Some(entry) = module.entry() {
            let name = entry.name.clone();
            table.lower_func(module, &name, 0, &mut seen);
        }
        table
    }

    fn lower_func(
        &mut self,
        module: &'m ModuleInfo,
        func_name: &str,
        depth: usize,
        seen: &mut HashMap<ShapeKey, u32>,
    ) {
        let Some(func) = module.funcs.iter().find(|f| f.name == func_name) else {
            return;
        };
        for op in &func.ops {
            // Follow calls into private sub-functions (depth-limited,
            // mirroring the scalar walk).
            if (op.short_name() == "call" || op.op_name == "func.call") && depth < 4 {
                if let Some(callee) = &op.callee {
                    self.events.push(LowerEvent::CallBegin {
                        index: op.index,
                        callee: callee.as_str(),
                    });
                    self.lower_func(module, callee, depth + 1, seen);
                    self.events.push(LowerEvent::CallEnd);
                    continue;
                }
            }
            let class = classify(op);
            let slot = ShapeKey::of_class(self.cache_fp, &class).map(|key| match seen.get(&key) {
                Some(&s) => {
                    self.occurrences[s as usize] += 1;
                    s
                }
                None => {
                    let s = self.unique.len() as u32;
                    self.unique.push(key.clone());
                    self.occurrences.push(1);
                    seen.insert(key, s);
                    s
                }
            });
            let leaf = self.indices.len() as u32;
            self.indices.push(op.index);
            self.names.push(op.op_name.as_str());
            self.classes.push(class);
            self.slots.push(slot);
            self.events.push(LowerEvent::Leaf(leaf));
        }
    }

    /// Number of estimated leaf ops (inlined callee ops included; `call`
    /// bracket rows excluded).
    pub fn len(&self) -> usize {
        self.indices.len()
    }

    /// True when the module lowered to no estimable ops.
    pub fn is_empty(&self) -> bool {
        self.indices.is_empty()
    }

    /// Number of distinct cacheable shapes in the table — the size of
    /// the grouped cache probe a warm estimate performs.
    pub fn unique_shapes(&self) -> usize {
        self.unique.len()
    }

    /// True when every cacheable shape in the table is already resident
    /// in `cache` — i.e. estimating it now would be a pure warm replay.
    /// Uses [`ShardedCache::peek`](super::ShardedCache::peek), so the
    /// check is invisible to the hit/miss accounting; the observability
    /// layer classifies each module request's estimate phase as
    /// cache-hit vs cache-miss with it.
    pub fn warm_in(&self, cache: &super::ShardedCache) -> bool {
        self.unique.iter().all(|key| cache.peek(key))
    }

    /// Replay the lowering events over the per-leaf costs, rebuilding
    /// the estimate in the scalar walk's exact accumulation order.
    fn assemble(&self, costs: Vec<CachedCost>) -> ModelEstimate {
        assemble_events(
            &self.module_name,
            &self.events,
            &self.indices,
            &self.names,
            &self.classes,
            costs,
        )
    }
}

/// Replay a lowering event stream over per-leaf costs, rebuilding the
/// [`ModelEstimate`] in the scalar walk's exact program order — and
/// therefore its exact floating-point accumulation order. Shared by
/// [`OpTable::assemble`] and the schedule template's re-cost path
/// ([`crate::graph::reuse`]): the two paths are bit-identical because
/// they run this very function, not replicas of it.
pub(crate) fn assemble_events(
    module_name: &str,
    events: &[LowerEvent<'_>],
    indices: &[usize],
    names: &[&str],
    classes: &[OpClass],
    costs: Vec<CachedCost>,
) -> ModelEstimate {
    let empty = |name: &str| ModelEstimate {
        module_name: name.to_string(),
        ops: Vec::new(),
        total_us: 0.0,
        systolic_us: 0.0,
        elementwise_us: 0.0,
        other_us: 0.0,
        covered_ops: 0,
        total_costed_ops: 0,
    };
    let mut costs: Vec<Option<CachedCost>> = costs.into_iter().map(Some).collect();
    let mut root = empty(module_name);
    let mut stack: Vec<(usize, &str, ModelEstimate)> = Vec::new();
    for ev in events {
        match ev {
            LowerEvent::Leaf(leaf) => {
                let i = *leaf as usize;
                let row = costs[i]
                    .take()
                    .expect("each leaf is costed exactly once")
                    .into_estimate(indices[i], names[i]);
                let est = stack.last_mut().map(|(_, _, e)| e).unwrap_or(&mut root);
                match &classes[i] {
                    OpClass::SystolicGemm { .. } | OpClass::SystolicConv { .. } => {
                        est.systolic_us += row.latency_us;
                        est.covered_ops += 1;
                        est.total_costed_ops += 1;
                    }
                    OpClass::Elementwise { .. } => {
                        est.elementwise_us += row.latency_us;
                        if matches!(
                            row.source,
                            EstimateSource::Learned | EstimateSource::LearnedProxy(_)
                        ) {
                            est.covered_ops += 1;
                        }
                        est.total_costed_ops += 1;
                    }
                    // Free ops cost nothing; collectives are free on
                    // a single chip (the distributed estimator costs
                    // them against a real slice).
                    OpClass::Free | OpClass::Collective { .. } => {}
                    _ => {
                        est.other_us += row.latency_us;
                        est.total_costed_ops += 1;
                    }
                }
                est.total_us += row.latency_us;
                est.ops.push(row);
            }
            LowerEvent::CallBegin { index, callee } => {
                stack.push((*index, callee, empty(module_name)));
            }
            LowerEvent::CallEnd => {
                let (index, callee, sub) = stack.pop().expect("balanced call events");
                let est = stack.last_mut().map(|(_, _, e)| e).unwrap_or(&mut root);
                est.total_us += sub.total_us;
                est.systolic_us += sub.systolic_us;
                est.elementwise_us += sub.elementwise_us;
                est.other_us += sub.other_us;
                est.covered_ops += sub.covered_ops;
                est.total_costed_ops += sub.total_costed_ops;
                est.ops.push(OpEstimate {
                    index,
                    op_name: format!("call @{callee}"),
                    source: EstimateSource::SystolicCalibrated,
                    cycles: None,
                    latency_us: sub.total_us,
                    note: format!("inlined {} ops", sub.ops.len()),
                });
            }
        }
    }
    debug_assert!(stack.is_empty(), "unbalanced call events");
    root
}

/// A group of elementwise cache misses sharing one learned model:
/// featurized into a contiguous row-major matrix for a single
/// `predict_many` pass.
struct EwGroup {
    model: String,
    stride: usize,
    rows: Vec<f64>,
    /// (unique-key slot, source, note) per row, in row order.
    entries: Vec<(usize, EstimateSource, String)>,
}

impl Estimator {
    /// Estimate a pre-lowered module through the batched core. Repeated
    /// estimates of the same table skip the classify / shape-key / dedup
    /// work entirely — this is the serve and bench hot path, and the
    /// reason [`Estimator::lower_module`](Estimator::lower_module) is a
    /// separate step.
    ///
    /// Bit-identical to
    /// [`Estimator::estimate_module_scalar`](Estimator::estimate_module_scalar),
    /// counters included (see the module docs).
    pub fn estimate_table(&self, table: &OpTable<'_>) -> ModelEstimate {
        let rekeyed: Vec<ShapeKey>;
        let unique: &[ShapeKey] = if table.cache_fp == self.cache_fingerprint() {
            &table.unique
        } else {
            // The table was lowered against a different cost-model
            // fingerprint (e.g. a retargeted estimator): re-key the
            // unique shapes, keep everything else.
            rekeyed = table
                .unique
                .iter()
                .map(|k| ShapeKey {
                    device: self.cache_fingerprint(),
                    shape: k.shape.clone(),
                })
                .collect();
            &rekeyed
        };
        let costs = self.resolve_costs(&table.classes, &table.slots, unique, &table.occurrences);
        table.assemble(costs)
    }

    /// Batched cost resolution for a flat slice of op classes — the
    /// `sweep` harness entry point. Deduplicates the cacheable shapes,
    /// does one grouped cache probe, evaluates misses class-by-class
    /// over contiguous arrays, and returns one position-independent
    /// [`CachedCost`] per input class (in input order).
    ///
    /// Accounting matches a scalar `estimate_op` loop exactly: same
    /// hit/miss totals, same per-source counts, same stored entries.
    pub fn estimate_classes(&self, classes: &[OpClass]) -> Vec<CachedCost> {
        let mut slots: Vec<Option<u32>> = Vec::with_capacity(classes.len());
        let mut unique: Vec<ShapeKey> = Vec::new();
        let mut occurrences: Vec<u64> = Vec::new();
        let mut seen: HashMap<ShapeKey, u32> = HashMap::new();
        for class in classes {
            let slot =
                ShapeKey::of_class(self.cache_fingerprint(), class).map(|key| match seen.get(&key)
                {
                    Some(&s) => {
                        occurrences[s as usize] += 1;
                        s
                    }
                    None => {
                        let s = unique.len() as u32;
                        unique.push(key.clone());
                        occurrences.push(1);
                        seen.insert(key, s);
                        s
                    }
                });
            slots.push(slot);
        }
        self.resolve_costs(classes, &slots, &unique, &occurrences)
    }

    /// The shared batched resolver: grouped probe → scalar-exact hit/miss
    /// accounting → class-grouped miss evaluation → grouped store →
    /// per-leaf rehydration with bulk source accounting.
    fn resolve_costs(
        &self,
        classes: &[OpClass],
        slots: &[Option<u32>],
        unique: &[ShapeKey],
        occurrences: &[u64],
    ) -> Vec<CachedCost> {
        let enabled = self.cache.is_enabled();
        let mut resolved: Vec<Option<CachedCost>> = if enabled {
            self.cache.lookup_grouped(unique)
        } else {
            // Disabled cache: the scalar walk recomputes every op without
            // touching the hit/miss counters; we compute once per unique
            // shape (the cost functions are deterministic in the key, so
            // the clones are bit-identical to recomputation).
            vec![None; unique.len()]
        };

        if enabled {
            // Scalar-exact accounting per unique shape: the first
            // occurrence of a fresh shape misses (and stores), every
            // further occurrence hits the just-stored entry.
            let mut hits = 0u64;
            let mut misses = 0u64;
            for (hit, &occ) in resolved.iter().zip(occurrences) {
                if hit.is_some() {
                    hits += occ;
                } else {
                    misses += 1;
                    hits += occ - 1;
                }
            }
            self.cache.record_lookups(hits, misses);
        }

        // Evaluate misses class by class: systolic shapes through the
        // cycle simulator, learned elementwise shapes batched per model
        // over one contiguous feature matrix.
        let miss: Vec<usize> = resolved
            .iter()
            .enumerate()
            .filter(|(_, c)| c.is_none())
            .map(|(u, _)| u)
            .collect();
        let mut ew_groups: Vec<EwGroup> = Vec::new();
        let mut group_of: HashMap<String, usize> = HashMap::new();
        for &u in &miss {
            match &unique[u].shape {
                ShapeClass::Gemm { gemm, count } => {
                    resolved[u] = Some(self.cost_class_uncached(&OpClass::SystolicGemm {
                        gemm: *gemm,
                        count: *count,
                    }));
                }
                ShapeClass::Elementwise { kind, dims, dtype } => match self.learned_for(*kind) {
                    Some((model, source)) => {
                        let row = featurize(dims);
                        let out = TensorType::new(dims.clone(), *dtype);
                        let gi = *group_of.entry(model.clone()).or_insert_with(|| {
                            ew_groups.push(EwGroup {
                                model,
                                stride: row.len(),
                                rows: Vec::new(),
                                entries: Vec::new(),
                            });
                            ew_groups.len() - 1
                        });
                        let group = &mut ew_groups[gi];
                        debug_assert_eq!(group.stride, row.len());
                        group.rows.extend_from_slice(&row);
                        group.entries.push((u, source, format!("{out}")));
                    }
                    None => {
                        resolved[u] = Some(self.cost_class_uncached(&OpClass::Elementwise {
                            kind: *kind,
                            out: TensorType::new(dims.clone(), *dtype),
                        }));
                    }
                },
                ShapeClass::Collective { .. } => {
                    unreachable!("collectives are keyed via ShapeKey::collective, never of_class")
                }
            }
        }
        for group in ew_groups {
            let mut raw = Vec::new();
            self.predict_compiled_many(&group.model, &group.rows, group.stride, &mut raw);
            for ((u, source, note), pred) in group.entries.into_iter().zip(raw) {
                resolved[u] = Some(CachedCost {
                    source,
                    cycles: None,
                    latency_us: self.finish_ew_prediction(pred),
                    note,
                });
            }
        }

        if enabled && !miss.is_empty() {
            let fresh: Vec<(ShapeKey, CachedCost)> = miss
                .iter()
                .map(|&u| {
                    (
                        unique[u].clone(),
                        resolved[u].clone().expect("every miss was evaluated"),
                    )
                })
                .collect();
            self.cache.store_grouped(fresh);
        }

        // Rehydrate one cost per input op (clone from the unique table
        // for cacheable classes, direct arithmetic for the bandwidth /
        // free classes) and account sources in one bulk update.
        let mut counts = [0u64; 6];
        let mut out = Vec::with_capacity(classes.len());
        for (class, slot) in classes.iter().zip(slots) {
            let cost = match slot {
                Some(u) => resolved[*u as usize]
                    .clone()
                    .expect("every unique shape was resolved"),
                None => self.cost_class_uncached(class),
            };
            counts[source_index(&cost.source)] += 1;
            out.push(cost);
        }
        self.cache.record_sources(&counts);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::calibrate::fit_regime_calibration;
    use crate::frontend::classify::EwKind;
    use crate::frontend::parse_module;
    use crate::frontend::types::DType;
    use crate::scalesim::topology::GemmShape;
    use crate::scalesim::{simulate_gemm, ScaleConfig};

    fn estimator() -> Estimator {
        let config = ScaleConfig::tpu_v4();
        let obs: Vec<_> = [64usize, 128, 256, 512, 1024, 2048, 4096]
            .iter()
            .map(|&d| {
                let g = GemmShape::new(d, d, d);
                let c = simulate_gemm(&config, g).total_cycles();
                (g, c, c as f64 * 1e-3)
            })
            .collect();
        Estimator::new(config, fit_regime_calibration(&obs).unwrap())
    }

    #[test]
    fn lowered_table_dedups_repeated_shapes() {
        let text = r#"
module @m { func.func public @main(%a: tensor<64x64xf32>, %b: tensor<64x64xf32>) -> tensor<64x64xf32> {
  %0 = stablehlo.dot_general %a, %b, contracting_dims = [1] x [0] : (tensor<64x64xf32>, tensor<64x64xf32>) -> tensor<64x64xf32>
  %1 = stablehlo.dot_general %a, %b, contracting_dims = [1] x [0] : (tensor<64x64xf32>, tensor<64x64xf32>) -> tensor<64x64xf32>
  %2 = stablehlo.add %0, %1 : tensor<64x64xf32>
  return %2 : tensor<64x64xf32>
} }"#;
        let module = parse_module(text).unwrap();
        let est = estimator();
        let table = est.lower_module(&module);
        assert_eq!(table.len(), 3);
        assert_eq!(table.unique_shapes(), 2, "two dots share one key");
        assert!(!table.is_empty());
    }

    #[test]
    fn estimate_table_reuse_is_bit_identical_to_estimate_module() {
        let text = r#"
module @m { func.func public @main(%a: tensor<128x256xbf16>, %b: tensor<256x512xbf16>) -> tensor<128x512xbf16> {
  %0 = stablehlo.dot_general %a, %b, contracting_dims = [1] x [0] : (tensor<128x256xbf16>, tensor<256x512xbf16>) -> tensor<128x512xbf16>
  %1 = stablehlo.exponential %0 : tensor<128x512xbf16>
  %2 = stablehlo.add %0, %0 : tensor<128x512xbf16>
  return %2 : tensor<128x512xbf16>
} }"#;
        let module = parse_module(text).unwrap();
        let est = estimator();
        let via_module = est.estimate_module(&module);
        let table = est.lower_module(&module);
        let a = est.estimate_table(&table);
        let b = est.estimate_table(&table);
        for got in [&a, &b] {
            assert_eq!(got.ops.len(), via_module.ops.len());
            assert_eq!(got.total_us.to_bits(), via_module.total_us.to_bits());
            for (x, y) in got.ops.iter().zip(&via_module.ops) {
                assert_eq!(x.latency_us.to_bits(), y.latency_us.to_bits());
                assert_eq!(x.op_name, y.op_name);
                assert_eq!(x.note, y.note);
            }
        }
    }

    #[test]
    fn estimate_classes_counts_duplicates_like_the_scalar_loop() {
        let est = estimator();
        let dot = OpClass::SystolicGemm {
            gemm: GemmShape::new(96, 96, 96),
            count: 1,
        };
        let add = OpClass::Elementwise {
            kind: EwKind::Add,
            out: TensorType::new(vec![96, 96], DType::Bf16),
        };
        // Cold batch with a duplicate: [dot, dot, add] must count one
        // miss + one hit for the repeated dot, one miss for add.
        let costs = est.estimate_classes(&[dot.clone(), dot.clone(), add.clone()]);
        assert_eq!(costs.len(), 3);
        assert_eq!(costs[0].latency_us.to_bits(), costs[1].latency_us.to_bits());
        let s = est.cache.stats();
        assert_eq!((s.hits, s.misses), (1, 2));
        assert_eq!(s.systolic, 2);
        assert_eq!(s.fallback, 1, "no learned model: add falls back");
        // Warm batch: everything hits.
        est.estimate_classes(&[dot, add]);
        let s = est.cache.stats();
        assert_eq!((s.hits, s.misses), (3, 2));
        // And the batched values match the scalar path bit for bit.
        let scalar = est.estimate_op(
            0,
            "dot",
            &OpClass::SystolicGemm {
                gemm: GemmShape::new(96, 96, 96),
                count: 1,
            },
        );
        assert_eq!(scalar.latency_us.to_bits(), costs[0].latency_us.to_bits());
    }

    #[test]
    fn warm_in_flips_after_first_estimate_without_counting() {
        let text = r#"
module @m { func.func public @main(%a: tensor<64x64xf32>, %b: tensor<64x64xf32>) -> tensor<64x64xf32> {
  %0 = stablehlo.dot_general %a, %b, contracting_dims = [1] x [0] : (tensor<64x64xf32>, tensor<64x64xf32>) -> tensor<64x64xf32>
  return %0 : tensor<64x64xf32>
} }"#;
        let module = parse_module(text).unwrap();
        let est = estimator();
        let table = est.lower_module(&module);
        assert!(!table.warm_in(&est.cache), "cold cache");
        let before = est.cache.stats();
        assert_eq!((before.hits, before.misses), (0, 0), "peek never counts");
        est.estimate_table(&table);
        assert!(table.warm_in(&est.cache), "warm after one estimate");
    }

    #[test]
    fn disabled_cache_matches_scalar_semantics() {
        let est = estimator();
        est.cache.set_enabled(false);
        let dot = OpClass::SystolicGemm {
            gemm: GemmShape::new(128, 128, 128),
            count: 1,
        };
        let costs = est.estimate_classes(&[dot.clone(), dot]);
        assert_eq!(costs[0].latency_us.to_bits(), costs[1].latency_us.to_bits());
        let s = est.cache.stats();
        assert_eq!((s.hits, s.misses, s.entries), (0, 0, 0));
        assert_eq!(s.systolic, 2, "sources are counted even when disabled");
    }
}
