//! Batch simulation service: the coordinator's request loop.
//!
//! Requests arrive as JSON objects (one per line — JSONL), are batched,
//! fanned out across the worker pool, and answered in order:
//!
//! ```json
//! {"type": "gemm", "m": 512, "k": 512, "n": 512}
//! {"type": "module", "path": "artifacts/mlp.stablehlo.txt"}
//! {"type": "elementwise", "op": "add", "dims": [1024, 1024]}
//! ```
//!
//! This is the "leader" entry point (`scalesim-tpu serve`): downstream
//! tooling pipes compiler output in and gets latency estimates back
//! without ever invoking Python.

use std::sync::Arc;

use anyhow::{bail, Result};

use crate::frontend::classify::{EwKind, OpClass};
use crate::frontend::parse_module;
use crate::frontend::types::{DType, TensorType};
use crate::scalesim::topology::GemmShape;
use crate::util::json::Json;

use super::estimator::Estimator;
use super::pool::parallel_map;

/// A parsed request.
#[derive(Debug, Clone, PartialEq)]
pub enum Request {
    Gemm(GemmShape),
    Elementwise { op: String, dims: Vec<usize> },
    Module { path: String },
}

impl Request {
    pub fn parse(line: &str) -> Result<Request> {
        let j = Json::parse(line).map_err(|e| anyhow::anyhow!("{e}"))?;
        match j.req_str("type").map_err(|e| anyhow::anyhow!("{e}"))? {
            "gemm" => {
                let m = j.req_f64("m").map_err(|e| anyhow::anyhow!("{e}"))? as usize;
                let k = j.req_f64("k").map_err(|e| anyhow::anyhow!("{e}"))? as usize;
                let n = j.req_f64("n").map_err(|e| anyhow::anyhow!("{e}"))? as usize;
                if m == 0 || k == 0 || n == 0 {
                    bail!("gemm dims must be positive");
                }
                Ok(Request::Gemm(GemmShape::new(m, k, n)))
            }
            "elementwise" => {
                let op = j.req_str("op").map_err(|e| anyhow::anyhow!("{e}"))?.to_string();
                let dims = j
                    .num_arr("dims")
                    .map_err(|e| anyhow::anyhow!("{e}"))?
                    .into_iter()
                    .map(|d| d as usize)
                    .collect();
                Ok(Request::Elementwise { op, dims })
            }
            "module" => Ok(Request::Module {
                path: j.req_str("path").map_err(|e| anyhow::anyhow!("{e}"))?.to_string(),
            }),
            other => bail!("unknown request type '{other}'"),
        }
    }
}

/// Serve a batch of JSONL requests; returns one JSON response line per
/// request, in order.
pub fn serve_lines(estimator: Arc<Estimator>, lines: &[String], workers: usize) -> Vec<String> {
    let items: Vec<(usize, String)> = lines
        .iter()
        .enumerate()
        .map(|(i, l)| (i, l.clone()))
        .collect();
    parallel_map(&items, workers, |(i, line)| {
        let resp = handle_line(&estimator, line);
        let mut obj = match resp {
            Ok(mut ok) => {
                ok.set("ok", Json::Bool(true));
                ok
            }
            Err(e) => {
                let mut o = Json::obj();
                o.set("ok", Json::Bool(false))
                    .set("error", Json::Str(format!("{e:#}")));
                o
            }
        };
        obj.set("id", Json::Num(*i as f64));
        obj.dump()
    })
}

fn handle_line(estimator: &Estimator, line: &str) -> Result<Json> {
    let req = Request::parse(line)?;
    match req {
        Request::Gemm(g) => {
            let class = OpClass::SystolicGemm { gemm: g, count: 1 };
            let est = estimator.estimate_op(0, "gemm", &class);
            let mut o = Json::obj();
            o.set("type", Json::Str("gemm".into()))
                .set("cycles", Json::Num(est.cycles.unwrap_or(0) as f64))
                .set("latency_us", Json::Num(est.latency_us));
            Ok(o)
        }
        Request::Elementwise { op, dims } => {
            let kind = EwKind::from_name(&op)
                .ok_or_else(|| anyhow::anyhow!("unknown elementwise op '{op}'"))?;
            let out = TensorType::new(dims.clone(), DType::Bf16);
            let class = OpClass::Elementwise { kind, out };
            let est = estimator.estimate_op(0, &op, &class);
            let mut o = Json::obj();
            o.set("type", Json::Str("elementwise".into()))
                .set("latency_us", Json::Num(est.latency_us))
                .set("source", Json::Str(est.source.tag().into()));
            Ok(o)
        }
        Request::Module { path } => {
            let text = std::fs::read_to_string(&path)?;
            let module = parse_module(&text)?;
            let report = estimator.estimate_module(&module);
            let mut o = Json::obj();
            o.set("type", Json::Str("module".into()))
                .set("module", Json::Str(report.module_name.clone()))
                .set("total_us", Json::Num(report.total_us))
                .set("systolic_us", Json::Num(report.systolic_us))
                .set("elementwise_us", Json::Num(report.elementwise_us))
                .set("other_us", Json::Num(report.other_us))
                .set("num_ops", Json::Num(report.ops.len() as f64))
                .set("coverage", Json::Num(report.coverage()));
            Ok(o)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::calibrate::fit_regime_calibration;
    use crate::scalesim::ScaleConfig;

    fn estimator() -> Arc<Estimator> {
        let mut obs = Vec::new();
        for d in [32usize, 64, 96, 128, 256, 512, 1024, 2048, 4096] {
            let g = GemmShape::new(d, d, d);
            obs.push((g, (d * d) as u64, (d * d) as f64 * 1e-3 + 1.0));
        }
        Arc::new(Estimator::new(
            ScaleConfig::tpu_v4(),
            fit_regime_calibration(&obs).unwrap(),
        ))
    }

    #[test]
    fn parse_requests() {
        assert_eq!(
            Request::parse(r#"{"type":"gemm","m":1,"k":2,"n":3}"#).unwrap(),
            Request::Gemm(GemmShape::new(1, 2, 3))
        );
        assert_eq!(
            Request::parse(r#"{"type":"elementwise","op":"add","dims":[8,128]}"#).unwrap(),
            Request::Elementwise {
                op: "add".into(),
                dims: vec![8, 128]
            }
        );
        assert!(Request::parse(r#"{"type":"gemm","m":0,"k":1,"n":1}"#).is_err());
        assert!(Request::parse("not json").is_err());
    }

    #[test]
    fn serve_batch_ordered_responses() {
        let est = estimator();
        let lines: Vec<String> = vec![
            r#"{"type":"gemm","m":128,"k":128,"n":128}"#.into(),
            r#"{"type":"bogus"}"#.into(),
            r#"{"type":"elementwise","op":"add","dims":[256,256]}"#.into(),
        ];
        let responses = serve_lines(est, &lines, 4);
        assert_eq!(responses.len(), 3);
        let r0 = Json::parse(&responses[0]).unwrap();
        assert_eq!(r0.get("ok"), Some(&Json::Bool(true)));
        assert_eq!(r0.req_f64("id").unwrap(), 0.0);
        assert!(r0.req_f64("latency_us").unwrap() > 0.0);
        let r1 = Json::parse(&responses[1]).unwrap();
        assert_eq!(r1.get("ok"), Some(&Json::Bool(false)));
        let r2 = Json::parse(&responses[2]).unwrap();
        assert_eq!(r2.req_str("type").unwrap(), "elementwise");
        // Fallback source since no learned models were registered.
        assert_eq!(r2.req_str("source").unwrap(), "fallback");
    }

    #[test]
    fn serve_module_request() {
        let est = estimator();
        let dir = std::env::temp_dir().join("scalesim_tpu_service_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("m.stablehlo.txt");
        std::fs::write(
            &path,
            r#"
module @m { func.func @main(%a: tensor<64x64xf32>, %b: tensor<64x64xf32>) -> tensor<64x64xf32> {
  %0 = stablehlo.dot_general %a, %b, contracting_dims = [1] x [0] : (tensor<64x64xf32>, tensor<64x64xf32>) -> tensor<64x64xf32>
  %1 = stablehlo.add %0, %a : tensor<64x64xf32>
  return %1 : tensor<64x64xf32>
} }"#,
        )
        .unwrap();
        let line = format!(r#"{{"type":"module","path":"{}"}}"#, path.display());
        let responses = serve_lines(est, &[line], 1);
        let r = Json::parse(&responses[0]).unwrap();
        assert_eq!(r.get("ok"), Some(&Json::Bool(true)), "{r:?}");
        assert_eq!(r.req_f64("num_ops").unwrap(), 2.0);
        assert!(r.req_f64("total_us").unwrap() > 0.0);
        std::fs::remove_file(&path).ok();
    }
}
