//! Streaming simulation service: the coordinator's request loop.
//!
//! Requests arrive as JSON objects (one per line — JSONL), are fanned out
//! across the worker pool, and answered incrementally *in submission
//! order*:
//!
//! ```json
//! {"type": "gemm", "m": 512, "k": 512, "n": 512}
//! {"type": "module", "path": "artifacts/mlp.stablehlo.txt"}
//! {"type": "elementwise", "op": "add", "dims": [1024, 1024]}
//! {"type": "stats"}
//! {"type": "metrics"}
//! ```
//!
//! This is the "leader" entry point (`scalesim-tpu serve`): downstream
//! tooling pipes compiler output in and gets latency estimates back
//! without ever invoking Python. Module requests resolve through the
//! batched estimator core ([`super::batch`]): `estimate_module` lowers
//! the whole module into a structure-of-arrays op table, probes the
//! sharded shape cache once per shard per batch, and evaluates the
//! misses class-by-class over contiguous arrays — bit-identical to the
//! old per-op walk, counters included. Two modes share one answer path:
//!
//! * [`serve_stream`] — persistent: reads the input line by line, pushes
//!   each request through a bounded-queue [`WorkerPool`] (backpressure on
//!   the producer), and emits responses as soon as their turn comes. A
//!   `{"type":"stats"}` request drains outstanding work and reports the
//!   shape-cache and routing counters.
//! * [`serve_lines`] — batch: answers a pre-collected slice of lines via
//!   the scoped `parallel_map` (used by tests and `serve --batch`).

use std::collections::{BTreeMap, HashMap};
use std::io::{BufRead, Write};
use std::sync::{Arc, OnceLock, RwLock};

use anyhow::{bail, Context, Result};

use crate::device::{DeviceSpec, PRESET_NAMES};
use crate::distributed::{
    estimate_gemm_sliced, estimate_module_distributed, IciTopology, SliceConfig,
};
use crate::frontend::classify::{EwKind, OpClass};
use crate::frontend::parse_module;
use crate::frontend::types::{DType, TensorType};
use crate::graph::{schedule_estimate, EngineConfig};
use crate::inference::{
    generate_workload, simulate, KvCacheSpec, PhaseModel, SimConfig, WorkloadConfig,
};
use crate::memory::{schedule_estimate_memory, MemoryConfig};
use crate::obs::{
    render_prometheus, Clock, Gauge, Histogram, HistogramSnapshot, MonotonicClock, Registry,
    RegistrySnapshot, TraceFileWriter,
};
use crate::scalesim::topology::GemmShape;
use crate::util::json::Json;

use super::cache::{CacheStats, ShapeKey, ShardedCache};
use super::estimator::{EstimateMode, Estimator};
use super::fusion::estimate_fused_with;
use super::pool::{default_workers, parallel_map, PoolGauges, WorkerPool};

/// A parsed request.
#[derive(Debug, Clone, PartialEq)]
pub enum Request {
    /// One GEMM (optionally sharded across a slice).
    Gemm {
        /// The GEMM dimensions.
        gemm: GemmShape,
        /// Multi-chip slice to shard across (`"chips"`, `"ici_gbps"`,
        /// `"ici_topology"`, `"ici_latency_us"` fields); `None` answers
        /// on a single chip. Unset knobs inherit the request's device
        /// spec at answer time.
        slice: Option<SliceRequest>,
        /// Device preset to answer for (`"device"` field); `None` uses
        /// the service's default device.
        device: Option<String>,
    },
    /// One elementwise op over a bf16 tensor.
    Elementwise {
        /// Short op name (e.g. `add`).
        op: String,
        /// Tensor shape.
        dims: Vec<usize>,
        /// Device preset to answer for; `None` uses the default.
        device: Option<String>,
    },
    /// A whole StableHLO module from a file path.
    Module {
        /// Path to the StableHLO text file.
        path: String,
        /// Optional multi-chip slice to estimate across (unset knobs
        /// inherit the request's device spec).
        slice: Option<SliceRequest>,
        /// Device preset to answer for; `None` uses the default.
        device: Option<String>,
    },
    /// A request-level LLM serving simulation of a decoder-block module
    /// from a file path: prefill/decode phases, pinned KV-cache
    /// residency, continuous batching over a seeded arrival stream.
    Llm {
        /// Path to the StableHLO text file.
        path: String,
        /// Requests in the seeded stream (`"requests"`, default 16).
        requests: usize,
        /// Workload seed (`"seed"`, default 42).
        seed: u64,
        /// Continuous-batching limit (`"max_batch"`, default 8).
        max_batch: usize,
        /// Device preset to answer for; `None` uses the default.
        device: Option<String>,
    },
    /// Report cache/routing counters for the requests answered so far.
    Stats,
    /// Report the observability registry (counters, gauges, phase
    /// histograms) attached to this service, as JSON. Answers
    /// `{"enabled": false}` when the service runs without metrics.
    Metrics,
}

/// A partially-specified slice from a request: `chips` is mandatory,
/// every other knob optional. Unset knobs inherit the request's device
/// spec at answer time ([`SliceRequest::resolve`]) — the same
/// flag > spec > default precedence the CLI applies, so a
/// `"device":"tpu-v5p"` request costs its collectives on v5p's links,
/// not on the reference defaults.
#[derive(Debug, Clone, PartialEq)]
pub struct SliceRequest {
    /// Chips in the slice.
    pub chips: usize,
    /// Explicit per-link bandwidth, GB/s.
    pub link_gbps: Option<f64>,
    /// Explicit per-hop latency, µs.
    pub hop_latency_us: Option<f64>,
    /// Explicit link wiring (already resolved against `chips`).
    pub topology: Option<IciTopology>,
}

impl SliceRequest {
    /// Resolve into a validated [`SliceConfig`]: explicit knobs win,
    /// the rest come from `spec`'s ICI parameters.
    pub fn resolve(&self, spec: &DeviceSpec) -> Result<SliceConfig> {
        let slice = SliceConfig {
            chips: self.chips,
            topology: self
                .topology
                .unwrap_or_else(|| spec.default_topology(self.chips)),
            link_gbps: self.link_gbps.unwrap_or(spec.ici_link_gbps),
            hop_latency_us: self.hop_latency_us.unwrap_or(spec.ici_hop_latency_us),
        };
        slice.validate()?;
        Ok(slice)
    }
}

/// Extract the optional slice request carried by a request object,
/// validating every explicitly-given knob.
fn parse_slice(j: &Json) -> Result<Option<SliceRequest>> {
    if j.get("chips").is_none() {
        // Refuse to silently drop distributed knobs on a request that
        // forgot the chip count — the caller would trust a single-chip
        // answer for a slice question.
        for key in ["ici_gbps", "ici_topology", "ici_latency_us"] {
            if j.get(key).is_some() {
                bail!("'{key}' given without 'chips'");
            }
        }
        return Ok(None);
    }
    let chips = j.req_usize("chips").map_err(|e| anyhow::anyhow!("{e}"))?;
    if chips == 0 {
        bail!("slice needs at least one chip");
    }
    let link_gbps = match j.get("ici_gbps") {
        Some(v) => {
            let g = v
                .as_f64()
                .ok_or_else(|| anyhow::anyhow!("'ici_gbps' must be a number"))?;
            if !(g.is_finite() && g > 0.0) {
                bail!("link bandwidth must be positive, got {g}");
            }
            Some(g)
        }
        None => None,
    };
    let hop_latency_us = match j.get("ici_latency_us") {
        Some(v) => {
            let a = v
                .as_f64()
                .ok_or_else(|| anyhow::anyhow!("'ici_latency_us' must be a number"))?;
            if !(a.is_finite() && a >= 0.0) {
                bail!("hop latency must be non-negative, got {a}");
            }
            Some(a)
        }
        None => None,
    };
    let topology = match j.get("ici_topology") {
        Some(v) => {
            let s = v
                .as_str()
                .ok_or_else(|| anyhow::anyhow!("'ici_topology' must be a string"))?;
            Some(IciTopology::parse(s, chips)?)
        }
        None => None,
    };
    Ok(Some(SliceRequest {
        chips,
        link_gbps,
        hop_latency_us,
        topology,
    }))
}

/// Extract the optional `"device"` field carried by a request object.
fn parse_device(j: &Json) -> Result<Option<String>> {
    match j.get("device") {
        None => Ok(None),
        Some(v) => Ok(Some(
            v.as_str()
                .ok_or_else(|| anyhow::anyhow!("'device' must be a string"))?
                .to_string(),
        )),
    }
}

impl Request {
    /// Parse one JSONL request line.
    pub fn parse(line: &str) -> Result<Request> {
        let j = Json::parse(line).map_err(|e| anyhow::anyhow!("{e}"))?;
        match j.req_str("type").map_err(|e| anyhow::anyhow!("{e}"))? {
            "gemm" => {
                let m = j.req_usize("m").map_err(|e| anyhow::anyhow!("{e}"))?;
                let k = j.req_usize("k").map_err(|e| anyhow::anyhow!("{e}"))?;
                let n = j.req_usize("n").map_err(|e| anyhow::anyhow!("{e}"))?;
                if m == 0 || k == 0 || n == 0 {
                    bail!("gemm dims must be positive");
                }
                Ok(Request::Gemm {
                    gemm: GemmShape::new(m, k, n),
                    slice: parse_slice(&j)?,
                    device: parse_device(&j)?,
                })
            }
            "elementwise" => {
                // No distributed elementwise path: refuse slice knobs
                // rather than silently answering for a single chip.
                if parse_slice(&j)?.is_some() {
                    bail!("distributed elementwise requests are not supported; wrap the op in a module request");
                }
                let op = j.req_str("op").map_err(|e| anyhow::anyhow!("{e}"))?.to_string();
                let dims = j
                    .num_arr("dims")
                    .map_err(|e| anyhow::anyhow!("{e}"))?
                    .into_iter()
                    .map(|d| {
                        if !d.is_finite() || d < 0.0 || d.fract() != 0.0 {
                            bail!("elementwise dims must be non-negative integers, got {d}");
                        }
                        Ok(d as usize)
                    })
                    .collect::<Result<Vec<usize>>>()?;
                Ok(Request::Elementwise {
                    op,
                    dims,
                    device: parse_device(&j)?,
                })
            }
            "module" => Ok(Request::Module {
                path: j.req_str("path").map_err(|e| anyhow::anyhow!("{e}"))?.to_string(),
                slice: parse_slice(&j)?,
                device: parse_device(&j)?,
            }),
            "llm" => {
                let opt_uint = |key: &str, default: u64| -> Result<u64> {
                    match j.get(key) {
                        None => Ok(default),
                        Some(v) => {
                            let n = v
                                .as_f64()
                                .ok_or_else(|| anyhow::anyhow!("'{key}' must be a number"))?;
                            if !n.is_finite() || n < 0.0 || n.fract() != 0.0 {
                                bail!("'{key}' must be a non-negative integer, got {n}");
                            }
                            Ok(n as u64)
                        }
                    }
                };
                let requests = opt_uint("requests", 16)? as usize;
                let seed = opt_uint("seed", 42)?;
                let max_batch = opt_uint("max_batch", 8)? as usize;
                if requests == 0 {
                    bail!("'requests' must be at least 1");
                }
                if max_batch == 0 {
                    bail!("'max_batch' must be at least 1");
                }
                Ok(Request::Llm {
                    path: j.req_str("path").map_err(|e| anyhow::anyhow!("{e}"))?.to_string(),
                    requests,
                    seed,
                    max_batch,
                    device: parse_device(&j)?,
                })
            }
            "stats" => Ok(Request::Stats),
            "metrics" => Ok(Request::Metrics),
            other => bail!("unknown request type '{other}'"),
        }
    }

    /// The device name the request asks for, if any.
    pub fn device(&self) -> Option<&str> {
        match self {
            Request::Gemm { device, .. }
            | Request::Elementwise { device, .. }
            | Request::Module { device, .. }
            | Request::Llm { device, .. } => device.as_deref(),
            Request::Stats | Request::Metrics => None,
        }
    }

    /// Stable tag naming the request kind — the `"type"` field of the
    /// response and the `type` label on `scalesim_requests_total`.
    pub fn type_tag(&self) -> &'static str {
        match self {
            Request::Gemm { .. } => "gemm",
            Request::Elementwise { .. } => "elementwise",
            Request::Module { .. } => "module",
            Request::Llm { .. } => "llm",
            Request::Stats => "stats",
            Request::Metrics => "metrics",
        }
    }
}

/// The serving stack's unified observability surface.
///
/// One instance per serve session, shared by every transport (stdin
/// stream, TCP dispatcher, bench harness). It owns the metric
/// [`Registry`], the injectable [`Clock`] phase timings are stamped
/// from ([`crate::obs::MonotonicClock`] in production,
/// [`crate::obs::LogicalClock`] in tests), and optionally the
/// streaming [`TraceFileWriter`] behind `serve --trace`.
///
/// Metric families (all prefixed `scalesim_`, durations in
/// nanoseconds):
///
/// * `scalesim_requests_total{type=...}` — requests answered, by kind
///   (`gemm`, `elementwise`, `module`, `llm`, `stats`, `metrics`,
///   `invalid`).
/// * `scalesim_request_errors_total` — requests answered with an error
///   object.
/// * `scalesim_request_phase_ns{phase=...}` — phase latency
///   histograms: `parse`, `queue_wait`, `estimate` (plus its
///   `estimate_hit` / `estimate_miss` sub-spans), `reorder`, `write`,
///   and end-to-end `total`.
/// * `scalesim_pool_queue_depth` / `scalesim_pool_busy_workers` —
///   worker-pool gauges (see [`PoolGauges`]).
/// * `scalesim_cache_shard_{hits,misses,contended}_total{shard=...}` —
///   per-shard shape-cache traffic, mirrored from the cache's own
///   atomics at snapshot time.
/// * `scalesim_device_request_ns{device=...}` — estimate durations per
///   answering device.
pub struct ServeMetrics {
    registry: Registry,
    clock: Arc<dyn Clock>,
    trace: Option<Arc<TraceFileWriter>>,
    pool_depth: Arc<Gauge>,
    pool_busy: Arc<Gauge>,
    phase_parse: Arc<Histogram>,
    phase_queue_wait: Arc<Histogram>,
    phase_estimate: Arc<Histogram>,
    phase_estimate_hit: Arc<Histogram>,
    phase_estimate_miss: Arc<Histogram>,
    phase_reorder: Arc<Histogram>,
    phase_write: Arc<Histogram>,
    phase_total: Arc<Histogram>,
}

impl std::fmt::Debug for ServeMetrics {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ServeMetrics")
            .field("trace", &self.trace.is_some())
            .finish()
    }
}

/// The log2 range of every latency histogram: nanosecond observations
/// from 1 µs (`2^10`) to ~17 s (`2^34`), matching
/// [`Histogram::for_latency_ns`].
const LATENCY_EXP: (u32, u32) = (10, 34);

impl ServeMetrics {
    /// A fresh registry stamping times from `clock`, optionally
    /// streaming completed request spans into `trace`.
    pub fn new(clock: Arc<dyn Clock>, trace: Option<Arc<TraceFileWriter>>) -> ServeMetrics {
        let registry = Registry::new();
        for (family, help) in [
            ("scalesim_requests_total", "Requests answered, by request type."),
            ("scalesim_request_errors_total", "Requests answered with an error object."),
            ("scalesim_request_phase_ns", "Per-request phase durations, nanoseconds."),
            ("scalesim_pool_queue_depth", "Jobs submitted to the worker pool and not yet claimed."),
            ("scalesim_pool_busy_workers", "Workers currently executing a request."),
            ("scalesim_cache_shard_hits_total", "Shape-cache probes answered, per shard."),
            ("scalesim_cache_shard_misses_total", "Shape-cache probes missed, per shard."),
            (
                "scalesim_cache_shard_contended_total",
                "Shape-cache probes that found their shard lock held.",
            ),
            ("scalesim_device_request_ns", "Estimate durations per answering device, nanoseconds."),
        ] {
            registry.set_help(family, help);
        }
        let (lo, hi) = LATENCY_EXP;
        let phase =
            |p: &str| registry.histogram("scalesim_request_phase_ns", &[("phase", p)], lo, hi);
        let phase_parse = phase("parse");
        let phase_queue_wait = phase("queue_wait");
        let phase_estimate = phase("estimate");
        let phase_estimate_hit = phase("estimate_hit");
        let phase_estimate_miss = phase("estimate_miss");
        let phase_reorder = phase("reorder");
        let phase_write = phase("write");
        let phase_total = phase("total");
        let pool_depth = registry.gauge("scalesim_pool_queue_depth", &[]);
        let pool_busy = registry.gauge("scalesim_pool_busy_workers", &[]);
        ServeMetrics {
            registry,
            clock,
            trace,
            pool_depth,
            pool_busy,
            phase_parse,
            phase_queue_wait,
            phase_estimate,
            phase_estimate_hit,
            phase_estimate_miss,
            phase_reorder,
            phase_write,
            phase_total,
        }
    }

    /// Production metrics: a [`MonotonicClock`], no trace stream.
    pub fn monotonic() -> Arc<ServeMetrics> {
        Arc::new(ServeMetrics::new(Arc::new(MonotonicClock::new()), None))
    }

    /// Current clock reading, nanoseconds.
    pub fn now_ns(&self) -> u64 {
        self.clock.now_ns()
    }

    /// The trace writer behind `serve --trace`, when attached.
    pub fn trace(&self) -> Option<&Arc<TraceFileWriter>> {
        self.trace.as_ref()
    }

    /// Handles onto the queue-depth / occupancy gauges, for wiring a
    /// [`WorkerPool`] via [`WorkerPool::with_gauges`].
    pub fn pool_gauges(&self) -> PoolGauges {
        PoolGauges {
            depth: Arc::clone(&self.pool_depth),
            busy: Arc::clone(&self.pool_busy),
        }
    }

    /// Record the line-to-[`Request`] parse duration.
    pub fn record_parse_ns(&self, ns: u64) {
        self.phase_parse.record(ns);
    }

    /// Record the submit-to-claim wait in the worker pool's job queue.
    pub fn record_queue_wait_ns(&self, ns: u64) {
        self.phase_queue_wait.record(ns);
    }

    /// Record the time a finished response waited in the reorder buffer
    /// for its in-order turn.
    pub fn record_reorder_ns(&self, ns: u64) {
        self.phase_reorder.record(ns);
    }

    /// Record the response serialization + socket/stream write time.
    pub fn record_write_ns(&self, ns: u64) {
        self.phase_write.record(ns);
    }

    /// Record a request's end-to-end (read-to-written) duration.
    pub fn record_total_ns(&self, ns: u64) {
        self.phase_total.record(ns);
    }

    /// Record one answered request: the `type` counter, the error
    /// counter when `!ok`, the `estimate` phase histogram (with its
    /// hit/miss sub-histogram when the shape cache's verdict is known),
    /// and the per-device histogram.
    pub fn record_request(
        &self,
        type_tag: &str,
        ok: bool,
        cache_hit: Option<bool>,
        estimate_ns: u64,
        device: Option<&str>,
    ) {
        self.registry
            .counter("scalesim_requests_total", &[("type", type_tag)])
            .inc();
        if !ok {
            self.registry
                .counter("scalesim_request_errors_total", &[])
                .inc();
        }
        self.phase_estimate.record(estimate_ns);
        match cache_hit {
            Some(true) => self.phase_estimate_hit.record(estimate_ns),
            Some(false) => self.phase_estimate_miss.record(estimate_ns),
            None => {}
        }
        if let Some(d) = device {
            let (lo, hi) = LATENCY_EXP;
            self.registry
                .histogram("scalesim_device_request_ns", &[("device", d)], lo, hi)
                .record(estimate_ns);
        }
    }

    /// Mirror the shape cache's per-shard hit/miss/contention atomics
    /// into registry counters (monotonic, so repeated observations are
    /// safe).
    pub fn observe_cache(&self, cache: &ShardedCache) {
        for (i, t) in cache.shard_traffic().iter().enumerate() {
            let shard = i.to_string();
            let labels = [("shard", shard.as_str())];
            self.registry
                .counter("scalesim_cache_shard_hits_total", &labels)
                .observe_total(t.hits);
            self.registry
                .counter("scalesim_cache_shard_misses_total", &labels)
                .observe_total(t.misses);
            self.registry
                .counter("scalesim_cache_shard_contended_total", &labels)
                .observe_total(t.contended);
        }
    }

    /// A point-in-time copy of every instrument, refreshing the cache
    /// mirror first when a cache is given.
    pub fn snapshot(&self, cache: Option<&ShardedCache>) -> RegistrySnapshot {
        if let Some(c) = cache {
            self.observe_cache(c);
        }
        self.registry.snapshot()
    }

    /// The snapshot in Prometheus text exposition, for the scrape
    /// listener behind `serve --metrics`.
    pub fn render(&self, cache: Option<&ShardedCache>) -> String {
        render_prometheus(&self.snapshot(cache))
    }

    /// Snapshot of one request-phase histogram by its `phase` label
    /// (`None` for an unknown phase name).
    pub fn phase_snapshot(&self, phase: &str) -> Option<HistogramSnapshot> {
        let h = match phase {
            "parse" => &self.phase_parse,
            "queue_wait" => &self.phase_queue_wait,
            "estimate" => &self.phase_estimate,
            "estimate_hit" => &self.phase_estimate_hit,
            "estimate_miss" => &self.phase_estimate_miss,
            "reorder" => &self.phase_reorder,
            "write" => &self.phase_write,
            "total" => &self.phase_total,
            _ => return None,
        };
        Some(h.snapshot())
    }
}

/// The service's per-device estimator registry.
///
/// One default estimator answers requests without a `"device"` field;
/// requests that name another preset get a lazily-built
/// [`Estimator::retarget`] clone. All of them share the default
/// estimator's shape cache (safe: every cache key carries the device
/// fingerprint), so the `{"type":"stats"}` counters and the shutdown
/// summary stay unified across devices.
pub struct DeviceEstimators {
    default: Arc<Estimator>,
    retargeted: RwLock<HashMap<String, Arc<Estimator>>>,
    metrics: OnceLock<Arc<ServeMetrics>>,
}

impl DeviceEstimators {
    /// A registry answering for `default` when no device is named.
    pub fn new(default: Arc<Estimator>) -> DeviceEstimators {
        DeviceEstimators {
            default,
            retargeted: RwLock::new(HashMap::new()),
            metrics: OnceLock::new(),
        }
    }

    /// The default-device estimator.
    pub fn default_estimator(&self) -> &Arc<Estimator> {
        &self.default
    }

    /// Attach the serve session's observability surface. First caller
    /// wins; later calls are ignored. When never called, the answer
    /// path records nothing — instrumentation is zero-cost-when-off.
    pub fn attach_metrics(&self, metrics: Arc<ServeMetrics>) {
        let _ = self.metrics.set(metrics);
    }

    /// The attached observability surface, if any.
    pub fn metrics(&self) -> Option<&Arc<ServeMetrics>> {
        self.metrics.get()
    }

    /// The estimator for `name` (the default when `None`), retargeting
    /// and memoising on first use. Unknown names are an error.
    ///
    /// The memoised path takes only a read lock, and the first-use
    /// retarget (which clones the learned-model set) runs *outside* the
    /// write lock so one cold device never stalls the others; two
    /// workers racing on the same cold name both retarget and the first
    /// insert wins (retargets are deterministic, so the loser's work is
    /// identical, merely wasted).
    pub fn get(&self, name: Option<&str>) -> Result<Arc<Estimator>> {
        let Some(name) = name else {
            return Ok(Arc::clone(&self.default));
        };
        if name == self.default.device().name {
            return Ok(Arc::clone(&self.default));
        }
        if let Some(est) = self.retargeted.read().unwrap().get(name) {
            return Ok(Arc::clone(est));
        }
        let Some(spec) = DeviceSpec::preset(name) else {
            bail!(
                "unknown device '{name}' (presets: {})",
                PRESET_NAMES.join(", ")
            );
        };
        let est = Arc::new(self.default.retarget(&spec));
        let mut map = self.retargeted.write().unwrap();
        let entry = map.entry(name.to_string()).or_insert(est);
        Ok(Arc::clone(entry))
    }
}

/// Serve a batch of JSONL requests; returns one JSON response line per
/// request, in order.
///
/// `{"type":"stats"}` requests are answered *after* the rest of the
/// batch completes (the whole batch is their prefix), so the counters
/// are deterministic rather than racing the in-flight workers. All
/// three transports give stats drain-barrier semantics over a
/// well-defined prefix: here the whole batch, on the streaming path
/// every earlier request in the stream ([`serve_stream`]), and on the
/// TCP dispatcher every earlier request *of the same connection*
/// ([`super::net`] — connections are independent, so cross-connection
/// traffic keeps flowing).
pub fn serve_lines(estimator: Arc<Estimator>, lines: &[String], workers: usize) -> Vec<String> {
    let devices = DeviceEstimators::new(estimator);
    let items: Vec<(usize, String)> = lines
        .iter()
        .enumerate()
        .map(|(i, l)| (i, l.clone()))
        .collect();
    let mut responses: Vec<Option<String>> = parallel_map(&items, workers, |(i, line)| {
        match Request::parse(line) {
            Ok(Request::Stats) => None, // deferred below
            parsed => Some(respond(&devices, *i as u64, parsed).1),
        }
    });
    for (i, slot) in responses.iter_mut().enumerate() {
        if slot.is_none() {
            *slot = Some(respond(&devices, i as u64, Ok(Request::Stats)).1);
        }
    }
    responses.into_iter().map(Option::unwrap).collect()
}

/// Answer one (possibly failed-to-parse) request; returns `(ok, line)`.
/// Shared by the in-process batch/stream paths and the TCP service
/// ([`super::net`]), so a request is answered bit-identically no matter
/// which transport carried it.
pub(crate) fn respond(devices: &DeviceEstimators, id: u64, req: Result<Request>) -> (bool, String) {
    let error_obj = |e: anyhow::Error| {
        let mut o = Json::obj();
        o.set("error", Json::Str(format!("{e:#}")));
        o
    };
    let metrics = devices.metrics().map(Arc::clone);
    let (ok, mut obj) = match req {
        Ok(r) => {
            let started = metrics.as_ref().map(|m| m.now_ns());
            let tag = r.type_tag();
            match handle_request(devices, &r) {
                Ok((o, cache_hit)) => {
                    if let (Some(m), Some(t0)) = (&metrics, started) {
                        let device = o.get("device").and_then(|d| d.as_str());
                        m.record_request(
                            tag,
                            true,
                            cache_hit,
                            m.now_ns().saturating_sub(t0),
                            device,
                        );
                    }
                    (true, o)
                }
                Err(e) => {
                    if let (Some(m), Some(t0)) = (&metrics, started) {
                        m.record_request(tag, false, None, m.now_ns().saturating_sub(t0), None);
                    }
                    (false, error_obj(e))
                }
            }
        }
        Err(e) => {
            if let Some(m) = &metrics {
                m.record_request("invalid", false, None, 0, None);
            }
            (false, error_obj(e))
        }
    };
    obj.set("ok", Json::Bool(ok));
    obj.set("id", Json::Num(id as f64));
    (ok, obj.dump())
}

/// Answer a request; besides the response object, reports whether the
/// shape cache already held everything the request needed (`None` when
/// the question does not apply: stats/metrics requests, distributed
/// answers, failed classification). The verdict is probed *before*
/// estimating (via the counter-invisible [`ShardedCache::peek`]), and
/// only when metrics are attached — the uninstrumented path skips the
/// probe entirely.
fn handle_request(devices: &DeviceEstimators, req: &Request) -> Result<(Json, Option<bool>)> {
    // Resolve the estimator for the request's device up front: an
    // unknown device name is an error response, never a silent
    // default-device answer.
    let est = devices.get(req.device())?;
    let estimator: &Estimator = &est;
    let device_name = || Json::Str(estimator.device().name.clone());
    let classify = devices.metrics().is_some();
    let peek_class = |class: &OpClass| -> Option<bool> {
        if !classify {
            return None;
        }
        ShapeKey::of_class(estimator.cache_fingerprint(), class)
            .map(|key| estimator.cache.peek(&key))
    };
    match req {
        Request::Gemm {
            gemm, slice: None, ..
        } => {
            let class = OpClass::SystolicGemm { gemm: *gemm, count: 1 };
            let hit = peek_class(&class);
            let est = estimator.estimate_op(0, "gemm", &class);
            let mut o = Json::obj();
            o.set("type", Json::Str("gemm".into()))
                .set("device", device_name())
                .set("cycles", Json::Num(est.cycles.unwrap_or(0) as f64))
                .set("latency_us", Json::Num(est.latency_us));
            Ok((o, hit))
        }
        Request::Gemm {
            gemm,
            slice: Some(slice),
            ..
        } => {
            let slice = slice.resolve(estimator.device())?;
            let r = estimate_gemm_sliced(estimator, *gemm, &slice);
            let mut o = Json::obj();
            o.set("type", Json::Str("gemm".into()))
                .set("device", device_name())
                .set("chips", Json::Num(slice.chips as f64))
                .set("latency_us", Json::Num(r.total_us()))
                .set("compute_us", Json::Num(r.compute_us))
                .set("collective_us", Json::Num(r.collective_us))
                .set("single_chip_us", Json::Num(r.single_chip_us))
                .set("parallel_efficiency", Json::Num(r.parallel_efficiency()));
            // The sharded walk estimates per-chip shards, not the
            // request shape — no single cache verdict applies.
            Ok((o, None))
        }
        Request::Elementwise { op, dims, .. } => {
            let kind = EwKind::from_name(op)
                .ok_or_else(|| anyhow::anyhow!("unknown elementwise op '{op}'"))?;
            let out = TensorType::new(dims.clone(), DType::Bf16);
            let class = OpClass::Elementwise { kind, out };
            let hit = peek_class(&class);
            let est = estimator.estimate_op(0, op, &class);
            let mut o = Json::obj();
            o.set("type", Json::Str("elementwise".into()))
                .set("device", device_name())
                .set("latency_us", Json::Num(est.latency_us))
                .set("source", Json::Str(est.source.tag().into()));
            Ok((o, hit))
        }
        Request::Module { path, slice, .. } => {
            let text = std::fs::read_to_string(path)?;
            let module = parse_module(&text)?;
            let slice = match slice {
                Some(s) => Some(s.resolve(estimator.device())?),
                None => None,
            };
            match slice {
                None => {
                    // Single-chip module answers carry all three
                    // estimation modes: the unfused sum, the fusion
                    // bracket, and the overlap-aware schedule — each
                    // recorded so stats can attribute traffic per mode.
                    // Fused and scheduled both reuse the one unfused
                    // walk's per-op costs, so the cache counters see the
                    // module exactly once. A module counts as a cache
                    // hit when every unique shape it lowers to is
                    // already warm.
                    let table = estimator.lower_module(&module);
                    let hit = classify.then(|| table.warm_in(&estimator.cache));
                    let report = estimator.estimate_table(&table);
                    let fused = estimate_fused_with(&module, report.clone());
                    let sched = schedule_estimate(
                        &module,
                        &report,
                        EngineConfig::for_device(estimator.device()),
                    );
                    // Memory-aware makespan + roofline: reuses the one
                    // unfused walk's rows, so no extra cache traffic.
                    // The residency buffer and bandwidth both come from
                    // the request's device.
                    let mem = schedule_estimate_memory(
                        &module,
                        &report,
                        EngineConfig::for_device(estimator.device()),
                        &MemoryConfig::new(
                            estimator.hbm_bytes_per_us(),
                            Some(estimator.device().vmem_bytes),
                        ),
                    );
                    estimator
                        .cache
                        .record_mode(EstimateMode::Unfused, report.total_us);
                    estimator
                        .cache
                        .record_mode(EstimateMode::Fused, fused.total_us);
                    estimator
                        .cache
                        .record_mode(EstimateMode::Scheduled, sched.makespan_us);
                    let mut o = Json::obj();
                    o.set("type", Json::Str("module".into()))
                        .set("device", device_name())
                        .set("module", Json::Str(report.module_name.clone()))
                        .set("total_us", Json::Num(report.total_us))
                        .set("systolic_us", Json::Num(report.systolic_us))
                        .set("elementwise_us", Json::Num(report.elementwise_us))
                        .set("other_us", Json::Num(report.other_us))
                        .set("fused_us", Json::Num(fused.total_us))
                        .set("scheduled_us", Json::Num(sched.makespan_us))
                        .set("critical_path_us", Json::Num(sched.critical_path_us))
                        .set("memory_us", Json::Num(mem.makespan_us()))
                        .set("roofline", mem.roofline_json())
                        .set("engines", sched.engines_to_json())
                        .set("num_ops", Json::Num(report.ops.len() as f64))
                        .set("coverage", Json::Num(report.coverage()));
                    Ok((o, hit))
                }
                Some(slice) => {
                    let d = estimate_module_distributed(estimator, &module, &slice);
                    estimator.cache.record_mode(EstimateMode::Scheduled, d.total_us);
                    let mut o = Json::obj();
                    o.set("type", Json::Str("module".into()))
                        .set("device", device_name())
                        .set("module", Json::Str(d.module_name.clone()))
                        .set("chips", Json::Num(slice.chips as f64))
                        .set("total_us", Json::Num(d.total_us))
                        .set("compute_us", Json::Num(d.compute_us))
                        .set("collective_us", Json::Num(d.collective_us))
                        .set("critical_path_us", Json::Num(d.critical_path_us))
                        .set("single_chip_us", Json::Num(d.single_chip_us))
                        .set("parallel_efficiency", Json::Num(d.parallel_efficiency()))
                        .set("num_ops", Json::Num(d.ops.len() as f64));
                    Ok((o, None))
                }
            }
        }
        Request::Llm {
            path,
            requests,
            seed,
            max_batch,
            ..
        } => {
            let text = std::fs::read_to_string(path)?;
            let module = parse_module(&text)?;
            let mut phase = PhaseModel::new(estimator, &module).ok_or_else(|| {
                anyhow::anyhow!("module @{} has no sequence extent to serve", module.name)
            })?;
            let kv = KvCacheSpec::infer(&module, 1).ok_or_else(|| {
                anyhow::anyhow!("module @{} yields no KV-cache shape", module.name)
            })?;
            let workload = generate_workload(&WorkloadConfig {
                requests: *requests,
                seed: *seed,
                ..WorkloadConfig::default()
            });
            let cfg = SimConfig {
                max_batch: *max_batch,
                kv_capacity: Some(estimator.device().vmem_bytes),
            };
            let mut report = simulate(estimator, &mut phase, &kv, &workload, &cfg);
            report.module = module.name.clone();
            // The per-phase schedules estimate through the shared cache,
            // but a serving run touches many rewritten shapes — no single
            // warm/cold verdict applies.
            let mut o = report.summary_json();
            o.set("type", Json::Str("llm".into()));
            Ok((o, None))
        }
        Request::Stats => {
            let mut o = estimator.cache.stats().to_json();
            o.set("type", Json::Str("stats".into()));
            Ok((o, None))
        }
        Request::Metrics => {
            let mut o = Json::obj();
            o.set("type", Json::Str("metrics".into()));
            match devices.metrics() {
                Some(m) => {
                    o.set("enabled", Json::Bool(true))
                        .set("metrics", m.snapshot(Some(&estimator.cache)).to_json());
                }
                None => {
                    o.set("enabled", Json::Bool(false));
                }
            }
            Ok((o, None))
        }
    }
}

/// Knobs for [`serve_stream`].
#[derive(Debug, Clone)]
pub struct StreamOptions {
    /// Worker threads answering requests.
    pub workers: usize,
    /// Bounded job-queue depth; 0 means `workers * 4`.
    pub queue_cap: usize,
    /// Observability surface to record into; `None` (the default) runs
    /// fully uninstrumented.
    pub metrics: Option<Arc<ServeMetrics>>,
}

impl Default for StreamOptions {
    fn default() -> StreamOptions {
        StreamOptions {
            workers: default_workers(),
            queue_cap: 0,
            metrics: None,
        }
    }
}

/// End-of-stream accounting, rendered on shutdown.
#[derive(Debug, Clone, Default)]
pub struct StreamSummary {
    /// Total requests read.
    pub requests: u64,
    /// Requests answered successfully.
    pub ok: u64,
    /// Requests answered with an error object.
    pub errors: u64,
    /// `gemm` requests.
    pub gemm: u64,
    /// `elementwise` requests.
    pub elementwise: u64,
    /// `module` requests.
    pub module: u64,
    /// `llm` serving-simulation requests.
    pub llm: u64,
    /// `stats` barrier requests.
    pub stats_requests: u64,
    /// `metrics` snapshot requests.
    pub metrics_requests: u64,
    /// Final cache/routing counters.
    pub cache: CacheStats,
}

impl StreamSummary {
    /// One-line human summary (written to stderr so stdout stays JSONL).
    pub fn render(&self) -> String {
        let [unfused, fused, scheduled] = self.cache.modes;
        format!(
            "serve: {} requests ({} ok, {} errors; {} gemm / {} elementwise / {} module / {} llm / {} stats / {} metrics); \
             cache: {} hits, {} misses ({:.1}% hit rate, {} entries); \
             sources: {} systolic, {} learned, {} learned-proxy, {} bandwidth, {} free, {} fallback; \
             modes: {} unfused ({:.1} us), {} fused ({:.1} us), {} scheduled ({:.1} us)",
            self.requests,
            self.ok,
            self.errors,
            self.gemm,
            self.elementwise,
            self.module,
            self.llm,
            self.stats_requests,
            self.metrics_requests,
            self.cache.hits,
            self.cache.misses,
            self.cache.hit_rate() * 100.0,
            self.cache.entries,
            self.cache.systolic,
            self.cache.learned,
            self.cache.learned_proxy,
            self.cache.bandwidth,
            self.cache.free,
            self.cache.fallback,
            unfused.requests,
            unfused.total_us,
            fused.requests,
            fused.total_us,
            scheduled.requests,
            scheduled.total_us,
        )
    }
}

/// Serve an open-ended JSONL stream incrementally.
///
/// Reads `input` line by line and answers onto `output`, one JSON line
/// per request, **in input order** — a completion reorder buffer bridges
/// the gap between out-of-order workers and the in-order contract. Memory
/// stays bounded for arbitrarily long streams: the job queue blocks the
/// reader when workers fall behind, which also caps the reorder buffer at
/// `queue_cap + workers` entries.
pub fn serve_stream<In: BufRead, Out: Write>(
    estimator: Arc<Estimator>,
    input: In,
    output: &mut Out,
    opts: &StreamOptions,
) -> Result<StreamSummary> {
    let workers = opts.workers.max(1);
    let queue_cap = if opts.queue_cap == 0 {
        workers * 4
    } else {
        opts.queue_cap
    };
    let devices = Arc::new(DeviceEstimators::new(Arc::clone(&estimator)));
    let metrics = opts.metrics.clone();
    if let Some(m) = &metrics {
        devices.attach_metrics(Arc::clone(m));
    }
    let pool_devices = Arc::clone(&devices);
    let worker_metrics = metrics.clone();
    // Jobs carry their submit timestamp so the worker can credit the
    // queue-wait phase before estimating (0 when uninstrumented).
    let mut pool: WorkerPool<(Request, u64), (bool, String)> = WorkerPool::with_gauges(
        workers,
        queue_cap,
        metrics.as_ref().map(|m| m.pool_gauges()),
        move |seq, (req, submit_ns)| {
            if let Some(m) = &worker_metrics {
                m.record_queue_wait_ns(m.now_ns().saturating_sub(submit_ns));
            }
            respond(&pool_devices, seq, Ok(req))
        },
    );

    let mut summary = StreamSummary::default();
    // Completed-but-not-yet-emitted responses, keyed by sequence number.
    let mut pending: BTreeMap<u64, String> = BTreeMap::new();
    let mut next_seq: u64 = 0; // next sequence number to assign
    let mut emitted: u64 = 0; // responses written so far == next seq to emit

    for line in input.lines() {
        let line = line.context("reading request stream")?;
        if line.trim().is_empty() {
            continue;
        }
        let seq = next_seq;
        next_seq += 1;
        summary.requests += 1;
        let parse_started = metrics.as_ref().map(|m| m.now_ns());
        let parsed = Request::parse(&line);
        if let (Some(m), Some(t0)) = (&metrics, parse_started) {
            m.record_parse_ns(m.now_ns().saturating_sub(t0));
        }
        match parsed {
            Ok(Request::Stats) => {
                // Stats are a barrier: every earlier request is answered
                // first, so the counters reflect the full prefix. Each gap
                // member is either already in `pending` or in flight in
                // the pool, so recv() below can never block indefinitely.
                emit_ready(output, &mut pending, &mut emitted)?;
                while emitted < seq {
                    let Some((s, (ok, resp))) = pool.recv() else {
                        bail!("worker pool terminated with requests outstanding");
                    };
                    tally(&mut summary, ok);
                    pending.insert(s, resp);
                    emit_ready(output, &mut pending, &mut emitted)?;
                }
                summary.stats_requests += 1;
                let (ok, resp) = respond(&devices, seq, Ok(Request::Stats));
                tally(&mut summary, ok);
                writeln!(output, "{resp}")?;
                output.flush()?;
                emitted += 1;
            }
            Ok(req) => {
                match &req {
                    Request::Gemm { .. } => summary.gemm += 1,
                    Request::Elementwise { .. } => summary.elementwise += 1,
                    Request::Module { .. } => summary.module += 1,
                    Request::Llm { .. } => summary.llm += 1,
                    Request::Metrics => summary.metrics_requests += 1,
                    Request::Stats => unreachable!(),
                }
                let submit_ns = metrics.as_ref().map_or(0, |m| m.now_ns());
                // Blocks while the queue is full: backpressure.
                pool.submit(seq, (req, submit_ns));
            }
            Err(e) => {
                let (ok, resp) = respond(&devices, seq, Err(e));
                tally(&mut summary, ok);
                pending.insert(seq, resp);
            }
        }
        // Collect whatever finished while we were reading, then flush the
        // in-order prefix so responses stream out incrementally.
        while let Some((s, (ok, resp))) = pool.try_recv() {
            tally(&mut summary, ok);
            pending.insert(s, resp);
        }
        emit_ready(output, &mut pending, &mut emitted)?;
        // Second half of the backpressure: if the head-of-line response
        // is slow, fast completions behind it pile up in `pending` (the
        // job-queue bound alone does not cap them — workers keep
        // draining). Wait for the head of line instead of reading more
        // input, keeping `pending` at O(queue_cap + workers).
        while pending.len() > queue_cap + workers {
            let Some((s, (ok, resp))) = pool.recv() else {
                bail!("worker pool terminated with requests outstanding");
            };
            tally(&mut summary, ok);
            pending.insert(s, resp);
            emit_ready(output, &mut pending, &mut emitted)?;
        }
    }

    // End of input: finish the tail in order.
    pool.close();
    while let Some((s, (ok, resp))) = pool.recv() {
        tally(&mut summary, ok);
        pending.insert(s, resp);
        emit_ready(output, &mut pending, &mut emitted)?;
    }
    emit_ready(output, &mut pending, &mut emitted)?;
    if emitted != next_seq {
        bail!(
            "worker pool lost {} of {} responses",
            next_seq - emitted,
            next_seq
        );
    }
    summary.cache = estimator.cache.stats();
    Ok(summary)
}

fn tally(summary: &mut StreamSummary, ok: bool) {
    if ok {
        summary.ok += 1;
    } else {
        summary.errors += 1;
    }
}

/// Write the contiguous run of completed responses starting at `emitted`.
fn emit_ready<Out: Write>(
    output: &mut Out,
    pending: &mut BTreeMap<u64, String>,
    emitted: &mut u64,
) -> Result<()> {
    let mut wrote = false;
    while let Some(resp) = pending.remove(emitted) {
        writeln!(output, "{resp}")?;
        *emitted += 1;
        wrote = true;
    }
    if wrote {
        output.flush()?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::calibrate::fit_regime_calibration;
    use crate::scalesim::ScaleConfig;

    fn estimator() -> Arc<Estimator> {
        let mut obs = Vec::new();
        for d in [32usize, 64, 96, 128, 256, 512, 1024, 2048, 4096] {
            let g = GemmShape::new(d, d, d);
            obs.push((g, (d * d) as u64, (d * d) as f64 * 1e-3 + 1.0));
        }
        Arc::new(Estimator::new(
            ScaleConfig::tpu_v4(),
            fit_regime_calibration(&obs).unwrap(),
        ))
    }

    #[test]
    fn parse_requests() {
        assert_eq!(
            Request::parse(r#"{"type":"gemm","m":1,"k":2,"n":3}"#).unwrap(),
            Request::Gemm {
                gemm: GemmShape::new(1, 2, 3),
                slice: None,
                device: None
            }
        );
        assert_eq!(
            Request::parse(r#"{"type":"gemm","m":1,"k":2,"n":3,"device":"tpu-v5e"}"#).unwrap(),
            Request::Gemm {
                gemm: GemmShape::new(1, 2, 3),
                slice: None,
                device: Some("tpu-v5e".into())
            }
        );
        assert!(Request::parse(r#"{"type":"gemm","m":1,"k":2,"n":3,"device":7}"#).is_err());
        assert_eq!(
            Request::parse(r#"{"type":"gemm","m":1,"k":2,"n":3,"chips":4,"ici_gbps":50}"#)
                .unwrap(),
            Request::Gemm {
                gemm: GemmShape::new(1, 2, 3),
                slice: Some(SliceRequest {
                    chips: 4,
                    link_gbps: Some(50.0),
                    hop_latency_us: None,
                    topology: None,
                }),
                device: None
            }
        );
        assert_eq!(
            Request::parse(
                r#"{"type":"module","path":"x.mlir","chips":8,"ici_topology":"torus"}"#
            )
            .unwrap(),
            Request::Module {
                path: "x.mlir".into(),
                slice: Some(SliceRequest {
                    chips: 8,
                    link_gbps: None,
                    hop_latency_us: None,
                    topology: Some(IciTopology::Torus2D { x: 2, y: 4 }),
                }),
                device: None
            }
        );
        // Unset slice knobs resolve against the request's device spec
        // (flag > spec > default, same as the CLI).
        let sreq = SliceRequest {
            chips: 4,
            link_gbps: None,
            hop_latency_us: None,
            topology: None,
        };
        let v4 = sreq.resolve(&DeviceSpec::tpu_v4()).unwrap();
        assert_eq!(v4, SliceConfig::ring(4, 100.0));
        let v5e = sreq.resolve(&DeviceSpec::tpu_v5e()).unwrap();
        assert_eq!(v5e.topology, IciTopology::Torus2D { x: 2, y: 2 });
        assert_eq!(v5e.link_gbps, 50.0);
        let forced = SliceRequest {
            link_gbps: Some(400.0),
            ..sreq
        }
        .resolve(&DeviceSpec::tpu_v5e())
        .unwrap();
        assert_eq!(forced.link_gbps, 400.0);
        assert!(Request::parse(r#"{"type":"gemm","m":1,"k":2,"n":3,"chips":0}"#).is_err());
        // Distributed knobs without a chip count are an error, not a
        // silent single-chip answer — and elementwise has no distributed
        // path at all.
        assert!(Request::parse(r#"{"type":"gemm","m":1,"k":2,"n":3,"ici_gbps":50}"#).is_err());
        assert!(
            Request::parse(r#"{"type":"elementwise","op":"add","dims":[8,8],"chips":4}"#)
                .is_err()
        );
        assert!(
            Request::parse(r#"{"type":"gemm","m":1,"k":2,"n":3,"chips":4,"ici_gbps":0}"#)
                .is_err()
        );
        assert!(Request::parse(
            r#"{"type":"gemm","m":1,"k":2,"n":3,"chips":4,"ici_topology":"3x5"}"#
        )
        .is_err());
        assert_eq!(
            Request::parse(r#"{"type":"elementwise","op":"add","dims":[8,128]}"#).unwrap(),
            Request::Elementwise {
                op: "add".into(),
                dims: vec![8, 128],
                device: None
            }
        );
        assert_eq!(Request::parse(r#"{"type":"stats"}"#).unwrap(), Request::Stats);
        assert!(Request::parse(r#"{"type":"gemm","m":0,"k":1,"n":1}"#).is_err());
        assert!(Request::parse(r#"{"type":"gemm","m":-1,"k":1,"n":1}"#).is_err());
        assert!(Request::parse(r#"{"type":"gemm","m":2.5,"k":1,"n":1}"#).is_err());
        assert!(Request::parse(r#"{"type":"elementwise","op":"add","dims":[-1,256]}"#).is_err());
        assert!(Request::parse(r#"{"type":"elementwise","op":"add","dims":[2.5]}"#).is_err());
        assert!(Request::parse("not json").is_err());
    }

    #[test]
    fn serve_batch_ordered_responses() {
        let est = estimator();
        let lines: Vec<String> = vec![
            r#"{"type":"gemm","m":128,"k":128,"n":128}"#.into(),
            r#"{"type":"bogus"}"#.into(),
            r#"{"type":"elementwise","op":"add","dims":[256,256]}"#.into(),
        ];
        let responses = serve_lines(est, &lines, 4);
        assert_eq!(responses.len(), 3);
        let r0 = Json::parse(&responses[0]).unwrap();
        assert_eq!(r0.get("ok"), Some(&Json::Bool(true)));
        assert_eq!(r0.req_f64("id").unwrap(), 0.0);
        assert!(r0.req_f64("latency_us").unwrap() > 0.0);
        let r1 = Json::parse(&responses[1]).unwrap();
        assert_eq!(r1.get("ok"), Some(&Json::Bool(false)));
        let r2 = Json::parse(&responses[2]).unwrap();
        assert_eq!(r2.req_str("type").unwrap(), "elementwise");
        // Fallback source since no learned models were registered.
        assert_eq!(r2.req_str("source").unwrap(), "fallback");
    }

    #[test]
    fn distributed_and_single_chip_gemm_do_not_alias() {
        // Regression: same shape through a single-chip request, a 4-chip
        // slice, and a fatter-linked 4-chip slice must hit distinct cache
        // entries and produce distinct answers.
        let est = estimator();
        let lines: Vec<String> = vec![
            r#"{"type":"gemm","m":64,"k":512,"n":2048}"#.into(),
            r#"{"type":"gemm","m":64,"k":512,"n":2048,"chips":4,"ici_gbps":50}"#.into(),
            r#"{"type":"gemm","m":64,"k":512,"n":2048,"chips":4,"ici_gbps":200}"#.into(),
            r#"{"type":"gemm","m":64,"k":512,"n":2048}"#.into(),
        ];
        let responses = serve_lines(Arc::clone(&est), &lines, 2);
        let lat: Vec<f64> = responses
            .iter()
            .map(|r| Json::parse(r).unwrap().req_f64("latency_us").unwrap())
            .collect();
        // Single-chip answers are bit-identical (cache hit)...
        assert_eq!(lat[0].to_bits(), lat[3].to_bits());
        // ...but never alias the distributed answers, and slices with
        // different link bandwidth differ from each other (the N-sharded
        // GEMM pays a bandwidth-dependent all-gather).
        assert_ne!(lat[0].to_bits(), lat[1].to_bits());
        assert_ne!(lat[1].to_bits(), lat[2].to_bits());
        let dist = Json::parse(&responses[1]).unwrap();
        assert_eq!(dist.req_f64("chips").unwrap(), 4.0);
        assert!(dist.req_f64("collective_us").unwrap() > 0.0);
        let eff = dist.req_f64("parallel_efficiency").unwrap();
        assert!(eff > 0.0 && eff <= 1.0);
    }

    #[test]
    fn mixed_device_requests_never_alias_and_report_their_device() {
        // The cache-aliasing regression behind the DeviceSpec refactor:
        // one serve stream mixing devices on the SAME shape must answer
        // each device from its own cache entries.
        let est = estimator();
        let lines: Vec<String> = vec![
            r#"{"type":"gemm","m":512,"k":512,"n":512}"#.into(),
            r#"{"type":"gemm","m":512,"k":512,"n":512,"device":"generic-256x256"}"#.into(),
            r#"{"type":"gemm","m":512,"k":512,"n":512,"device":"tpu-v4"}"#.into(),
            r#"{"type":"gemm","m":512,"k":512,"n":512}"#.into(),
            r#"{"type":"gemm","m":512,"k":512,"n":512,"device":"nope"}"#.into(),
        ];
        let responses = serve_lines(Arc::clone(&est), &lines, 1);
        let parsed: Vec<Json> = responses.iter().map(|r| Json::parse(r).unwrap()).collect();
        let lat = |i: usize| parsed[i].req_f64("latency_us").unwrap();
        // The default device IS tpu-v4: naming it explicitly must hit
        // the same cache entry bit for bit.
        assert_eq!(lat(0).to_bits(), lat(2).to_bits());
        assert_eq!(lat(0).to_bits(), lat(3).to_bits());
        // A different device answers differently (256x256 array at a
        // slower clock simulates different cycles).
        assert_ne!(lat(0).to_bits(), lat(1).to_bits());
        assert_eq!(parsed[0].req_str("device").unwrap(), "tpu-v4");
        assert_eq!(parsed[1].req_str("device").unwrap(), "generic-256x256");
        // Unknown devices are an error response, not a default answer.
        assert_eq!(parsed[4].get("ok"), Some(&Json::Bool(false)));
        assert!(parsed[4].req_str("error").unwrap().contains("unknown device"));
        // Two devices x one shape = two cache entries; the second v4
        // request and the repeat were hits on the first entry.
        let s = est.cache.stats();
        assert_eq!(s.entries, 2);
        assert_eq!(s.hits, 2);
        assert_eq!(s.misses, 2);
    }

    #[test]
    fn request_slice_defaults_come_from_the_request_device() {
        // Regression: a "device" request with "chips" must cost its
        // collectives on THAT device's ICI (torus, 50 GB/s for v5e),
        // not on the reference defaults (ring, 100 GB/s). If defaults
        // leaked from the reference, the first two answers would match.
        let est = estimator();
        let shape = r#""m":128,"k":1024,"n":8192"#; // N-sharded: pays an all-gather
        let lines: Vec<String> = vec![
            format!(r#"{{"type":"gemm",{shape},"chips":4,"device":"tpu-v5e"}}"#),
            format!(
                r#"{{"type":"gemm",{shape},"chips":4,"device":"tpu-v5e","ici_gbps":100,"ici_topology":"ring","ici_latency_us":1}}"#
            ),
        ];
        let responses = serve_lines(est, &lines, 1);
        let coll: Vec<f64> = responses
            .iter()
            .map(|r| Json::parse(r).unwrap().req_f64("collective_us").unwrap())
            .collect();
        assert!(coll[0] > 0.0 && coll[1] > 0.0);
        assert_ne!(
            coll[0].to_bits(),
            coll[1].to_bits(),
            "spec ICI defaults did not apply: {coll:?}"
        );
    }

    #[test]
    fn serve_module_request() {
        let est = estimator();
        let dir = std::env::temp_dir().join("scalesim_tpu_service_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("m.stablehlo.txt");
        std::fs::write(
            &path,
            r#"
module @m { func.func @main(%a: tensor<64x64xf32>, %b: tensor<64x64xf32>) -> tensor<64x64xf32> {
  %0 = stablehlo.dot_general %a, %b, contracting_dims = [1] x [0] : (tensor<64x64xf32>, tensor<64x64xf32>) -> tensor<64x64xf32>
  %1 = stablehlo.add %0, %a : tensor<64x64xf32>
  return %1 : tensor<64x64xf32>
} }"#,
        )
        .unwrap();
        let line = format!(r#"{{"type":"module","path":"{}"}}"#, path.display());
        let stats_line = r#"{"type":"stats"}"#.to_string();
        let responses = serve_lines(est, &[line, stats_line], 1);
        let r = Json::parse(&responses[0]).unwrap();
        assert_eq!(r.get("ok"), Some(&Json::Bool(true)), "{r:?}");
        assert_eq!(r.req_f64("num_ops").unwrap(), 2.0);
        let total = r.req_f64("total_us").unwrap();
        assert!(total > 0.0);
        // The module answer carries all three estimation modes and the
        // scheduler's analyses.
        let fused = r.req_f64("fused_us").unwrap();
        let scheduled = r.req_f64("scheduled_us").unwrap();
        let critical = r.req_f64("critical_path_us").unwrap();
        assert!(fused <= total + 1e-9);
        assert!(critical <= scheduled + 1e-9);
        assert!(scheduled <= total + 1e-9);
        assert!(r.get("engines").unwrap().get("mxu").is_some());
        // Memory-aware makespan and the per-op roofline verdicts ride
        // along on every single-chip module answer.
        let memory_us = r.req_f64("memory_us").unwrap();
        assert!(
            memory_us >= scheduled,
            "memory-aware {memory_us} beat compute-only {scheduled}"
        );
        let roofline = r.get("roofline").expect("roofline summary");
        assert!(roofline.req_str("verdict").is_ok());
        let verdict_ops = roofline.req_arr("ops").unwrap();
        assert_eq!(verdict_ops.len(), 2);
        for vo in verdict_ops {
            let bound = vo.req_str("bound").unwrap();
            assert!(["compute", "bandwidth", "free"].contains(&bound), "{bound}");
        }
        // Stats attribute the module answer to every mode it computed.
        let stats = Json::parse(&responses[1]).unwrap();
        let modes = stats.get("modes").expect("stats carry per-mode counters");
        for mode in ["unfused", "fused", "scheduled"] {
            assert_eq!(
                modes.get(mode).unwrap().req_f64("requests").unwrap(),
                1.0,
                "{mode} not recorded"
            );
        }
        assert_eq!(
            modes.get("unfused").unwrap().req_f64("total_us").unwrap(),
            total
        );
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn batch_stats_are_deterministic_over_the_whole_batch() {
        let mut lines: Vec<String> = (0..40)
            .map(|i| {
                let d = 64 * (1 + i % 2);
                format!(r#"{{"type":"gemm","m":{d},"k":{d},"n":{d}}}"#)
            })
            .collect();
        lines.insert(10, r#"{"type":"stats"}"#.to_string());
        let run = || {
            let responses = serve_lines(estimator(), &lines, 8);
            let stats = Json::parse(&responses[10]).unwrap();
            assert_eq!(stats.req_str("type").unwrap(), "stats");
            assert_eq!(stats.req_f64("id").unwrap(), 10.0);
            stats.req_f64("cache_hits").unwrap() + stats.req_f64("cache_misses").unwrap()
        };
        // Stats are answered after the batch drains: counters always
        // cover all 40 costed requests, run after run.
        assert_eq!(run(), 40.0);
        assert_eq!(run(), 40.0);
    }

    #[test]
    fn stream_answers_in_order_with_stats() {
        let est = estimator();
        let mut input = String::new();
        for i in 0..200 {
            let d = 64 + 64 * (i % 4);
            input.push_str(&format!(r#"{{"type":"gemm","m":{d},"k":{d},"n":{d}}}"#));
            input.push('\n');
        }
        input.push_str("{\"type\":\"stats\"}\n");
        input.push_str("garbage\n");
        let mut out = Vec::new();
        let summary = serve_stream(
            Arc::clone(&est),
            input.as_bytes(),
            &mut out,
            &StreamOptions {
                workers: 8,
                queue_cap: 4,
                metrics: None,
            },
        )
        .unwrap();
        let text = String::from_utf8(out).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 202);
        for (i, line) in lines.iter().enumerate() {
            let j = Json::parse(line).expect("valid json");
            assert_eq!(j.req_f64("id").unwrap(), i as f64, "out of order: {line}");
        }
        // The stats barrier saw all 200 gemm answers: 4 distinct shapes.
        // Two workers racing on the same fresh key may both miss, so the
        // miss count is bounded, not exact.
        let stats = Json::parse(lines[200]).unwrap();
        assert_eq!(stats.req_str("type").unwrap(), "stats");
        let misses = stats.req_f64("cache_misses").unwrap();
        let hits = stats.req_f64("cache_hits").unwrap();
        assert_eq!(hits + misses, 200.0);
        assert!((4.0..=32.0).contains(&misses), "misses {misses}");
        assert_eq!(stats.req_f64("cache_entries").unwrap(), 4.0);
        // The garbage line is an error but still answered in order.
        let last = Json::parse(lines[201]).unwrap();
        assert_eq!(last.get("ok"), Some(&Json::Bool(false)));
        assert_eq!(summary.requests, 202);
        assert_eq!(summary.errors, 1);
        assert_eq!(summary.gemm, 200);
        assert_eq!(summary.stats_requests, 1);
    }

    #[test]
    fn metrics_request_without_instrumentation_reports_disabled() {
        let responses = serve_lines(estimator(), &[r#"{"type":"metrics"}"#.to_string()], 1);
        let r = Json::parse(&responses[0]).unwrap();
        assert_eq!(r.get("ok"), Some(&Json::Bool(true)));
        assert_eq!(r.req_str("type").unwrap(), "metrics");
        assert_eq!(r.get("enabled"), Some(&Json::Bool(false)));
        assert!(r.get("metrics").is_none());
    }

    #[test]
    fn instrumented_stream_classifies_hits_and_snapshots_over_the_wire() {
        use crate::obs::LogicalClock;
        let est = estimator();
        let metrics = Arc::new(ServeMetrics::new(Arc::new(LogicalClock::new()), None));
        let input = concat!(
            r#"{"type":"gemm","m":96,"k":96,"n":96}"#,
            "\n",
            r#"{"type":"gemm","m":96,"k":96,"n":96}"#,
            "\n",
            r#"{"type":"stats"}"#,
            "\n",
            r#"{"type":"metrics"}"#,
            "\n",
        );
        let mut out = Vec::new();
        let summary = serve_stream(
            Arc::clone(&est),
            input.as_bytes(),
            &mut out,
            &StreamOptions {
                workers: 1,
                queue_cap: 1,
                metrics: Some(Arc::clone(&metrics)),
            },
        )
        .unwrap();
        assert_eq!(summary.metrics_requests, 1);
        assert!(summary.render().contains("1 metrics"));
        // Identical shapes one worker apart: first is a classified
        // miss, second a classified hit.
        assert_eq!(metrics.phase_snapshot("estimate_miss").unwrap().count, 1);
        assert_eq!(metrics.phase_snapshot("estimate_hit").unwrap().count, 1);
        // Every pool-routed request waited in the queue and estimated.
        assert_eq!(metrics.phase_snapshot("queue_wait").unwrap().count, 3);
        assert_eq!(metrics.phase_snapshot("parse").unwrap().count, 4);
        // stats + metrics recorded without a cache verdict.
        assert_eq!(metrics.phase_snapshot("estimate").unwrap().count, 4);
        // The wire response embeds a parseable registry snapshot with
        // the per-type counters and per-shard cache traffic.
        let lines: Vec<&str> = std::str::from_utf8(&out).unwrap().lines().collect();
        let m = Json::parse(lines[3]).unwrap();
        assert_eq!(m.get("enabled"), Some(&Json::Bool(true)));
        let snap = RegistrySnapshot::from_json(m.get("metrics").unwrap()).unwrap();
        let counter = |family: &str, label: Option<(&str, &str)>| {
            snap.counters
                .iter()
                .find(|(f, l, _)| {
                    f == family
                        && match label {
                            None => true,
                            Some((k, v)) => l.iter().any(|(lk, lv)| lk == k && lv == v),
                        }
                })
                .map(|(_, _, v)| *v)
        };
        assert_eq!(
            counter("scalesim_requests_total", Some(("type", "gemm"))),
            Some(2)
        );
        assert_eq!(
            counter("scalesim_requests_total", Some(("type", "stats"))),
            Some(1)
        );
        let shard_hits: u64 = snap
            .counters
            .iter()
            .filter(|(f, _, _)| f == "scalesim_cache_shard_hits_total")
            .map(|(_, _, v)| *v)
            .sum();
        assert_eq!(shard_hits, 1, "one warm gemm probe");
        // Pool gauges drained back to zero and made it into the export.
        assert!(snap.gauges.iter().any(|(f, _, v)| {
            f == "scalesim_pool_queue_depth" && *v == 0
        }));
        // The Prometheus rendering of the same registry parses as
        // text exposition with the phase families present.
        let text = metrics.render(Some(&est.cache));
        assert!(text.contains("# TYPE scalesim_requests_total counter"));
        assert!(
            text.contains("scalesim_request_phase_ns_bucket{phase=\"estimate_hit\",le=\"+Inf\"} 1")
        );
    }

    #[test]
    fn stream_and_batch_agree() {
        let lines: Vec<String> = (0..50)
            .map(|i| match i % 3 {
                0 => r#"{"type":"gemm","m":256,"k":256,"n":256}"#.to_string(),
                1 => r#"{"type":"elementwise","op":"add","dims":[512,512]}"#.to_string(),
                _ => r#"{"type":"gemm","m":128,"k":512,"n":64}"#.to_string(),
            })
            .collect();
        let batch = serve_lines(estimator(), &lines, 4);
        let mut out = Vec::new();
        serve_stream(
            estimator(),
            lines.join("\n").as_bytes(),
            &mut out,
            &StreamOptions::default(),
        )
        .unwrap();
        let stream: Vec<String> = String::from_utf8(out)
            .unwrap()
            .lines()
            .map(str::to_string)
            .collect();
        assert_eq!(batch, stream);
    }
}
