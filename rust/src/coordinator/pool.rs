//! Scoped worker pool for parallel sweeps.
//!
//! The measurement and simulation sweeps are embarrassingly parallel over
//! shapes; this module provides an ordered `parallel_map` on top of
//! `std::thread::scope` (no external executor in the offline registry).
//! Work is handed out via an atomic cursor, so uneven per-item costs
//! (e.g. large vs small GEMMs) balance automatically.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Number of workers: respects `SCALESIM_THREADS`, defaulting to the
/// available parallelism (capped at 16).
pub fn default_workers() -> usize {
    if let Ok(v) = std::env::var("SCALESIM_THREADS") {
        if let Ok(n) = v.parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism()
        .map(|n| n.get().min(16))
        .unwrap_or(4)
}

/// Apply `f` to every item on `workers` threads; results keep input order.
///
/// Work is claimed in contiguous *chunks* via an atomic cursor and each
/// chunk's results are buffered thread-locally, so the shared collection
/// lock is taken once per chunk instead of once per item (the per-item
/// Mutex version was slower than serial for µs-scale items — see
/// EXPERIMENTS.md §Perf).
pub fn parallel_map<T, R, F>(items: &[T], workers: usize, f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(&T) -> R + Sync,
{
    let n = items.len();
    if n == 0 {
        return Vec::new();
    }
    let workers = workers.max(1).min(n);
    if workers == 1 {
        return items.iter().map(|t| f(t)).collect();
    }

    // ~4 chunks per worker balances load without locking per item.
    let chunk = (n / (workers * 4)).max(1);
    let cursor = AtomicUsize::new(0);
    let collected: Mutex<Vec<(usize, Vec<R>)>> = Mutex::new(Vec::with_capacity(workers * 5));

    std::thread::scope(|scope| {
        for _ in 0..workers {
            scope.spawn(|| loop {
                let start = cursor.fetch_add(chunk, Ordering::Relaxed);
                if start >= n {
                    break;
                }
                let end = (start + chunk).min(n);
                let buf: Vec<R> = items[start..end].iter().map(&f).collect();
                collected.lock().unwrap().push((start, buf));
            });
        }
    });

    let mut chunks = collected.into_inner().unwrap();
    chunks.sort_unstable_by_key(|(start, _)| *start);
    let mut out = Vec::with_capacity(n);
    for (_, buf) in chunks {
        out.extend(buf);
    }
    debug_assert_eq!(out.len(), n);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_order() {
        let items: Vec<usize> = (0..500).collect();
        let out = parallel_map(&items, 8, |&i| i * 2);
        assert_eq!(out, (0..500).map(|i| i * 2).collect::<Vec<_>>());
    }

    #[test]
    fn single_worker_and_empty() {
        let out = parallel_map(&[1, 2, 3], 1, |&i| i + 1);
        assert_eq!(out, vec![2, 3, 4]);
        let empty: Vec<i32> = parallel_map(&[] as &[i32], 4, |&i| i);
        assert!(empty.is_empty());
    }

    #[test]
    fn balances_uneven_work() {
        // Items with wildly different costs still all complete.
        let items: Vec<u64> = (0..64).map(|i| if i % 7 == 0 { 200_000 } else { 10 }).collect();
        let out = parallel_map(&items, 8, |&n| (0..n).fold(0u64, |a, b| a.wrapping_add(b)));
        assert_eq!(out.len(), 64);
    }

    #[test]
    fn workers_capped_to_items() {
        let out = parallel_map(&[5], 32, |&i| i);
        assert_eq!(out, vec![5]);
    }
}
