//! Worker pools: a scoped ordered `parallel_map` for batch sweeps, and a
//! long-lived [`WorkerPool`] for the streaming service.
//!
//! The measurement and simulation sweeps are embarrassingly parallel over
//! shapes; `parallel_map` runs them on top of `std::thread::scope` (no
//! external executor in the offline registry). Work is handed out via an
//! atomic cursor, so uneven per-item costs (e.g. large vs small GEMMs)
//! balance automatically.
//!
//! `WorkerPool` complements it for open-ended streams: jobs are submitted
//! one at a time through a *bounded* queue (submission blocks when the
//! workers fall behind — backpressure on the producer), results come back
//! tagged with their sequence number for reordering at the consumer.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

use crate::obs::Gauge;

/// Number of workers: respects `SCALESIM_THREADS`, defaulting to the
/// available parallelism (capped at 16).
pub fn default_workers() -> usize {
    if let Ok(v) = std::env::var("SCALESIM_THREADS") {
        if let Ok(n) = v.parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism()
        .map(|n| n.get().min(16))
        .unwrap_or(4)
}

/// Apply `f` to every item on `workers` threads; results keep input order.
///
/// Work is claimed in contiguous *chunks* via an atomic cursor and each
/// chunk's results are buffered thread-locally, so the shared collection
/// lock is taken once per chunk instead of once per item (the per-item
/// Mutex version was slower than serial for µs-scale items — see
/// EXPERIMENTS.md §Perf).
pub fn parallel_map<T, R, F>(items: &[T], workers: usize, f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(&T) -> R + Sync,
{
    let n = items.len();
    if n == 0 {
        return Vec::new();
    }
    let workers = workers.max(1).min(n);
    if workers == 1 {
        return items.iter().map(|t| f(t)).collect();
    }

    // ~4 chunks per worker balances load without locking per item.
    let chunk = (n / (workers * 4)).max(1);
    let cursor = AtomicUsize::new(0);
    let collected: Mutex<Vec<(usize, Vec<R>)>> = Mutex::new(Vec::with_capacity(workers * 5));

    std::thread::scope(|scope| {
        for _ in 0..workers {
            scope.spawn(|| loop {
                let start = cursor.fetch_add(chunk, Ordering::Relaxed);
                if start >= n {
                    break;
                }
                let end = (start + chunk).min(n);
                let buf: Vec<R> = items[start..end].iter().map(&f).collect();
                collected.lock().unwrap().push((start, buf));
            });
        }
    });

    let mut chunks = collected.into_inner().unwrap();
    chunks.sort_unstable_by_key(|(start, _)| *start);
    let mut out = Vec::with_capacity(n);
    for (_, buf) in chunks {
        out.extend(buf);
    }
    debug_assert_eq!(out.len(), n);
    out
}

/// A long-lived pool of worker threads fed by a bounded job queue.
///
/// Each job carries a caller-chosen sequence number; the worker function
/// receives it along with the payload and the result comes back tagged
/// with it, so an in-order consumer can reorder completions (see
/// `service::serve_stream`). [`WorkerPool::submit`] blocks while the
/// queue is full, which is the backpressure that keeps an arbitrarily
/// long input stream from ballooning memory.
///
/// Multi-producer use (the TCP service, where every connection reader
/// submits into one shared pool) goes through cloneable [`PoolHandle`]s
/// instead: grab handles with [`WorkerPool::handle`], call
/// [`WorkerPool::close`] to drop the pool's own sender, and the job
/// queue stays open exactly as long as any handle is alive.
pub struct WorkerPool<T: Send + 'static, R: Send + 'static> {
    job_tx: Option<mpsc::SyncSender<(u64, T)>>,
    result_rx: mpsc::Receiver<(u64, R)>,
    handles: Vec<JoinHandle<()>>,
    gauges: Option<PoolGauges>,
}

/// Observability gauges a pool keeps current when instrumented via
/// [`WorkerPool::with_gauges`]: instantaneous queue depth (submitted,
/// not yet claimed by a worker — blocked submitters included) and
/// worker occupancy (workers currently running a job). `None`-free
/// zero-cost when the pool is built through [`WorkerPool::new`].
#[derive(Clone, Debug)]
pub struct PoolGauges {
    /// Jobs submitted and not yet claimed by a worker.
    pub depth: Arc<Gauge>,
    /// Workers currently executing a job.
    pub busy: Arc<Gauge>,
}

/// A cloneable submission handle onto a [`WorkerPool`]'s bounded job
/// queue. Each connection reader of the TCP service owns one; the job
/// queue closes (and the workers drain and exit) once every handle and
/// the pool's own sender are dropped.
pub struct PoolHandle<T: Send + 'static> {
    job_tx: mpsc::SyncSender<(u64, T)>,
    gauges: Option<PoolGauges>,
}

impl<T: Send + 'static> Clone for PoolHandle<T> {
    fn clone(&self) -> PoolHandle<T> {
        PoolHandle {
            job_tx: self.job_tx.clone(),
            gauges: self.gauges.clone(),
        }
    }
}

fn submit_gauged<T: Send + 'static>(
    tx: &mpsc::SyncSender<(u64, T)>,
    gauges: &Option<PoolGauges>,
    seq: u64,
    job: T,
) -> bool {
    // Count the job as queued before the (possibly blocking) send, so a
    // submitter stalled on backpressure is visible as queue depth.
    if let Some(g) = gauges {
        g.depth.inc();
    }
    let ok = tx.send((seq, job)).is_ok();
    if !ok {
        if let Some(g) = gauges {
            g.depth.dec();
        }
    }
    ok
}

impl<T: Send + 'static> PoolHandle<T> {
    /// Enqueue a job; blocks while the queue is full (backpressure).
    /// Returns `false` if the pool's workers are all gone.
    pub fn submit(&self, seq: u64, job: T) -> bool {
        submit_gauged(&self.job_tx, &self.gauges, seq, job)
    }
}

impl<T: Send + 'static, R: Send + 'static> WorkerPool<T, R> {
    /// Spawn `workers` threads running `f` over submitted jobs, with at
    /// most `queue_cap` jobs waiting unclaimed.
    pub fn new<F>(workers: usize, queue_cap: usize, f: F) -> WorkerPool<T, R>
    where
        F: Fn(u64, T) -> R + Send + Sync + 'static,
    {
        WorkerPool::with_gauges(workers, queue_cap, None, f)
    }

    /// [`WorkerPool::new`] with optional queue-depth / occupancy gauges
    /// (see [`PoolGauges`]). The uninstrumented path stays gauge-free —
    /// no atomics are touched when `gauges` is `None`.
    pub fn with_gauges<F>(
        workers: usize,
        queue_cap: usize,
        gauges: Option<PoolGauges>,
        f: F,
    ) -> WorkerPool<T, R>
    where
        F: Fn(u64, T) -> R + Send + Sync + 'static,
    {
        let workers = workers.max(1);
        let (job_tx, job_rx) = mpsc::sync_channel::<(u64, T)>(queue_cap.max(1));
        let (result_tx, result_rx) = mpsc::channel::<(u64, R)>();
        let job_rx = Arc::new(Mutex::new(job_rx));
        let f = Arc::new(f);
        let mut handles = Vec::with_capacity(workers);
        for _ in 0..workers {
            let job_rx = Arc::clone(&job_rx);
            let result_tx = result_tx.clone();
            let f = Arc::clone(&f);
            let gauges = gauges.clone();
            handles.push(std::thread::spawn(move || loop {
                // Holding the lock across the blocking recv is fine: the
                // holder wakes with a job, releases, and the next worker
                // takes its place waiting.
                let job = job_rx.lock().unwrap().recv();
                match job {
                    Ok((seq, item)) => {
                        if let Some(g) = &gauges {
                            g.depth.dec();
                            g.busy.inc();
                        }
                        let result = f(seq, item);
                        if let Some(g) = &gauges {
                            g.busy.dec();
                        }
                        if result_tx.send((seq, result)).is_err() {
                            break; // consumer gone
                        }
                    }
                    Err(_) => break, // queue closed
                }
            }));
        }
        WorkerPool {
            job_tx: Some(job_tx),
            result_rx,
            handles,
            gauges,
        }
    }

    /// A cloneable submission handle feeding this pool's job queue (for
    /// multi-producer setups like the TCP service). Panics if called
    /// after [`WorkerPool::close`].
    pub fn handle(&self) -> PoolHandle<T> {
        PoolHandle {
            job_tx: self.job_tx.as_ref().expect("handle after close").clone(),
            gauges: self.gauges.clone(),
        }
    }

    /// Enqueue a job; blocks while the queue is full (backpressure).
    pub fn submit(&self, seq: u64, job: T) {
        let tx = self.job_tx.as_ref().expect("submit after close");
        assert!(
            submit_gauged(tx, &self.gauges, seq, job),
            "worker pool died"
        );
    }

    /// Collect one finished result without blocking.
    pub fn try_recv(&self) -> Option<(u64, R)> {
        self.result_rx.try_recv().ok()
    }

    /// Collect one finished result, blocking; `None` once the pool is
    /// closed and fully drained.
    pub fn recv(&self) -> Option<(u64, R)> {
        self.result_rx.recv().ok()
    }

    /// Stop accepting jobs. Workers finish what is queued; drain the
    /// remaining results with [`WorkerPool::recv`] until it yields `None`.
    pub fn close(&mut self) {
        self.job_tx.take();
    }
}

impl<T: Send + 'static, R: Send + 'static> Drop for WorkerPool<T, R> {
    fn drop(&mut self) {
        self.job_tx.take();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_order() {
        let items: Vec<usize> = (0..500).collect();
        let out = parallel_map(&items, 8, |&i| i * 2);
        assert_eq!(out, (0..500).map(|i| i * 2).collect::<Vec<_>>());
    }

    #[test]
    fn single_worker_and_empty() {
        let out = parallel_map(&[1, 2, 3], 1, |&i| i + 1);
        assert_eq!(out, vec![2, 3, 4]);
        let empty: Vec<i32> = parallel_map(&[] as &[i32], 4, |&i| i);
        assert!(empty.is_empty());
    }

    #[test]
    fn balances_uneven_work() {
        // Items with wildly different costs still all complete.
        let items: Vec<u64> = (0..64).map(|i| if i % 7 == 0 { 200_000 } else { 10 }).collect();
        let out = parallel_map(&items, 8, |&n| (0..n).fold(0u64, |a, b| a.wrapping_add(b)));
        assert_eq!(out.len(), 64);
    }

    #[test]
    fn workers_capped_to_items() {
        let out = parallel_map(&[5], 32, |&i| i);
        assert_eq!(out, vec![5]);
    }

    #[test]
    fn worker_pool_processes_all_jobs_with_tiny_queue() {
        // queue_cap 1 forces submit() to block repeatedly (backpressure);
        // every job must still complete exactly once.
        let mut pool: WorkerPool<u64, u64> = WorkerPool::new(4, 1, |_seq, x| x * 2);
        for i in 0..200u64 {
            pool.submit(i, i);
        }
        pool.close();
        let mut got = std::collections::BTreeMap::new();
        while let Some((seq, r)) = pool.recv() {
            got.insert(seq, r);
        }
        assert_eq!(got.len(), 200);
        for (seq, r) in got {
            assert_eq!(r, seq * 2);
        }
    }

    #[test]
    fn worker_pool_results_reorderable_by_seq() {
        // Uneven job costs scramble completion order; seq tags restore it.
        let mut pool: WorkerPool<u64, u64> =
            WorkerPool::new(8, 16, |seq, cost| {
                std::thread::sleep(std::time::Duration::from_micros(cost));
                seq
            });
        for i in 0..64u64 {
            pool.submit(i, (64 - i) * 50);
        }
        pool.close();
        let mut seqs: Vec<u64> = Vec::new();
        while let Some((seq, r)) = pool.recv() {
            assert_eq!(seq, r);
            seqs.push(seq);
        }
        seqs.sort_unstable();
        assert_eq!(seqs, (0..64).collect::<Vec<_>>());
    }

    #[test]
    fn pool_handles_keep_the_queue_open_after_close() {
        // The TCP-service shape: the pool's own sender is closed up
        // front, cloneable handles feed it from several producer
        // threads, and the result stream ends exactly when the last
        // handle drops.
        let mut pool: WorkerPool<u64, u64> = WorkerPool::new(4, 2, |_seq, x| x + 1);
        let h = pool.handle();
        pool.close();
        let producers: Vec<_> = (0..3u64)
            .map(|p| {
                let h = h.clone();
                std::thread::spawn(move || {
                    for i in 0..50u64 {
                        assert!(h.submit(p * 100 + i, p * 100 + i));
                    }
                })
            })
            .collect();
        drop(h);
        for p in producers {
            p.join().unwrap();
        }
        let mut seen = std::collections::BTreeMap::new();
        while let Some((seq, r)) = pool.recv() {
            seen.insert(seq, r);
        }
        assert_eq!(seen.len(), 150);
        for (seq, r) in seen {
            assert_eq!(r, seq + 1);
        }
    }

    #[test]
    fn worker_pool_drop_without_drain_does_not_hang() {
        let pool: WorkerPool<u64, u64> = WorkerPool::new(2, 4, |_s, x| x);
        pool.submit(0, 1);
        pool.submit(1, 2);
        drop(pool);
    }

    #[test]
    fn gauges_settle_to_zero_after_drain() {
        let gauges = PoolGauges {
            depth: Arc::new(Gauge::new()),
            busy: Arc::new(Gauge::new()),
        };
        let mut pool: WorkerPool<u64, u64> =
            WorkerPool::with_gauges(4, 2, Some(gauges.clone()), |_s, x| x * 3);
        let h = pool.handle();
        for i in 0..100u64 {
            if i % 2 == 0 {
                pool.submit(i, i);
            } else {
                assert!(h.submit(i, i));
            }
        }
        drop(h);
        pool.close();
        let mut n = 0;
        while let Some((seq, r)) = pool.recv() {
            assert_eq!(r, seq * 3);
            n += 1;
        }
        assert_eq!(n, 100);
        // Every submitted job was claimed (depth back to 0) and every
        // worker finished its last job (busy back to 0).
        assert_eq!(gauges.depth.get(), 0);
        assert_eq!(gauges.busy.get(), 0);
    }
}
