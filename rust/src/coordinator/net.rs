//! Network-native serve: a concurrent TCP front end for the estimation
//! service.
//!
//! `scalesim-tpu serve --listen <addr:port>` accepts many simultaneous
//! client connections speaking the same newline-delimited JSONL request
//! schema as the stdin/file loop ([`super::service`]). Every connection
//! gets:
//!
//! * **In-order responses.** Requests are answered over one *shared*
//!   [`WorkerPool`] (so concurrent connections contend on the sharded
//!   shape cache exactly the way it was built for), and a per-connection
//!   reorder buffer in the writer thread restores each connection's own
//!   submission order. Response `id`s are per-connection sequence
//!   numbers, so a response line is bit-identical to the same request at
//!   the same position of a `serve --input` stream.
//! * **Error isolation.** A malformed line becomes an `{"ok":false}`
//!   response and the connection continues; an I/O error (client gone,
//!   reset, …) tears down only that connection — its already-submitted
//!   work completes and is dropped on the floor, never wedging the pool
//!   or poisoning the cache.
//! * **Bounded buffering.** Each connection caps its in-flight requests
//!   (submitted but not yet written back) at
//!   [`NetOptions::inflight`]; the reader blocks at the cap, so a slow
//!   or stalled reader on one connection can never back memory or the
//!   shared result dispatcher up — other connections keep streaming.
//!
//! **Drain.** A `{"type":"shutdown"}` admin request (answered with an
//! acknowledgement) or SIGINT (see [`install_sigint_drain`]) triggers a
//! graceful drain: the listener stops accepting, every connection's read
//! half is shut down (in-flight requests are still answered and
//! written), and [`NetServer::run`] returns a [`NetSummary`] that counts
//! every accepted request exactly once. With a snapshot path configured
//! the CLI then persists the warm shape cache (see [`super::snapshot`]).

use std::collections::{BTreeMap, HashMap};
use std::io::{BufWriter, ErrorKind, Read, Write};
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{self, TrySendError};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use anyhow::{Context, Result};

use crate::util::json::Json;

use super::estimator::Estimator;
use super::pool::{default_workers, PoolHandle, WorkerPool};
use super::service::{respond, DeviceEstimators, Request, StreamSummary};

/// Global SIGINT latch: set by the signal handler installed with
/// [`install_sigint_drain`], polled by every running [`NetServer`].
static SIGINT_FLAG: AtomicBool = AtomicBool::new(false);

/// Install a SIGINT handler that requests a graceful drain of every
/// running [`NetServer`] (stop accepting, answer in-flight requests,
/// emit the summary) instead of killing the process.
///
/// Storing an atomic flag is async-signal-safe; the accept loop polls
/// it. On non-Unix targets this is a no-op (Ctrl-C falls back to the
/// default process kill).
pub fn install_sigint_drain() {
    #[cfg(unix)]
    {
        extern "C" fn on_sigint(_signum: i32) {
            SIGINT_FLAG.store(true, Ordering::SeqCst);
        }
        extern "C" {
            // libc is always linked on unix targets; avoid a crate
            // dependency for one call.
            fn signal(signum: i32, handler: usize) -> usize;
        }
        const SIGINT: i32 = 2;
        unsafe {
            signal(SIGINT, on_sigint as extern "C" fn(i32) as usize);
        }
    }
}

/// Knobs for [`NetServer`].
#[derive(Debug, Clone)]
pub struct NetOptions {
    /// Worker threads answering requests (shared by all connections).
    pub workers: usize,
    /// Bounded job-queue depth; 0 means `workers * 4`.
    pub queue_cap: usize,
    /// Per-connection in-flight cap (submitted but not yet written
    /// back); 0 means 64. This bounds each connection's write queue, so
    /// one slow reader never stalls the shared dispatcher.
    pub inflight: usize,
}

impl Default for NetOptions {
    fn default() -> NetOptions {
        NetOptions {
            workers: default_workers(),
            queue_cap: 0,
            inflight: 0,
        }
    }
}

/// End-of-run accounting for a TCP serve, rendered on drain.
#[derive(Debug, Clone, Default)]
pub struct NetSummary {
    /// Connections accepted over the server's lifetime.
    pub connections: u64,
    /// Request/response/cache accounting, same shape as the stream loop.
    pub stream: StreamSummary,
}

impl NetSummary {
    /// One-line human summary (written to stderr so stdout stays clean).
    pub fn render(&self) -> String {
        format!("{} connections; {}", self.connections, self.stream.render())
    }
}

/// Lock-free request/response tallies shared by readers and workers.
#[derive(Default)]
struct NetCounters {
    requests: AtomicU64,
    ok: AtomicU64,
    errors: AtomicU64,
    gemm: AtomicU64,
    elementwise: AtomicU64,
    module: AtomicU64,
    stats: AtomicU64,
}

impl NetCounters {
    fn tally(&self, ok: bool) {
        if ok {
            self.ok.fetch_add(1, Ordering::Relaxed);
        } else {
            self.errors.fetch_add(1, Ordering::Relaxed);
        }
    }

    fn count_type(&self, req: &Request) {
        match req {
            Request::Gemm { .. } => self.gemm.fetch_add(1, Ordering::Relaxed),
            Request::Elementwise { .. } => self.elementwise.fetch_add(1, Ordering::Relaxed),
            Request::Module { .. } => self.module.fetch_add(1, Ordering::Relaxed),
            Request::Stats => self.stats.fetch_add(1, Ordering::Relaxed),
        };
    }
}

/// One job on the shared pool: a raw request line from one connection.
/// Parsing happens on the worker, so malformed lines cost reader time
/// proportional only to their length.
struct NetJob {
    conn: u64,
    seq: u64,
    line: String,
}

/// A completed response routed back to its connection's writer.
enum ConnMsg {
    /// One answered request (per-connection sequence number + JSON line).
    Done { seq: u64, ok: bool, resp: String },
    /// The reader is done; exactly `total` responses will exist.
    Eof { total: u64 },
}

/// Per-connection in-flight gate: the reader blocks at the cap, the
/// writer releases one slot per response written (or discarded). `dead`
/// short-circuits the wait when the writer lost its socket, so a reader
/// never blocks forever on a connection that can no longer answer.
struct Gate {
    state: Mutex<(usize, bool)>,
    cv: Condvar,
}

impl Gate {
    fn new() -> Gate {
        Gate {
            state: Mutex::new((0, false)),
            cv: Condvar::new(),
        }
    }

    /// Take one in-flight slot, blocking at `cap`; `false` if the
    /// connection's writer is dead (stop reading).
    fn acquire(&self, cap: usize) -> bool {
        let mut st = self.state.lock().unwrap();
        while st.0 >= cap && !st.1 {
            st = self.cv.wait(st).unwrap();
        }
        if st.1 {
            return false;
        }
        st.0 += 1;
        true
    }

    fn release(&self) {
        let mut st = self.state.lock().unwrap();
        st.0 = st.0.saturating_sub(1);
        self.cv.notify_all();
    }

    fn kill(&self) {
        let mut st = self.state.lock().unwrap();
        st.1 = true;
        self.cv.notify_all();
    }
}

/// What the dispatcher needs to reach one live connection.
struct ConnEntry {
    tx: mpsc::SyncSender<ConnMsg>,
    gate: Arc<Gate>,
    /// Clone of the connection's stream, used by the drain sweep to shut
    /// the read half down (wakes a reader blocked in `read`).
    stream: TcpStream,
}

/// Registry of live connections, shared by the accept loop (insert), the
/// dispatcher (route), connection threads (remove) and the drain sweep.
#[derive(Default)]
struct Registry {
    conns: Mutex<HashMap<u64, ConnEntry>>,
}

/// A handle that requests a graceful drain of a running [`NetServer`]
/// from another thread (tests, embedding).
#[derive(Clone)]
pub struct ShutdownHandle(Arc<AtomicBool>);

impl ShutdownHandle {
    /// Request the drain: stop accepting, answer in-flight requests,
    /// return the summary from [`NetServer::run`].
    pub fn shutdown(&self) {
        self.0.store(true, Ordering::SeqCst);
    }
}

/// The concurrent TCP estimation service. Bind with [`NetServer::bind`],
/// then [`NetServer::run`] blocks until a drain is requested.
pub struct NetServer {
    listener: TcpListener,
    devices: Arc<DeviceEstimators>,
    estimator: Arc<Estimator>,
    opts: NetOptions,
    shutdown: Arc<AtomicBool>,
}

impl NetServer {
    /// Bind the listener and prepare the service around `estimator`
    /// (whose shape cache and device registry are shared by every
    /// connection). Use port 0 to let the OS pick (see
    /// [`NetServer::local_addr`]).
    pub fn bind<A: ToSocketAddrs>(
        addr: A,
        estimator: Arc<Estimator>,
        opts: NetOptions,
    ) -> Result<NetServer> {
        let listener = TcpListener::bind(addr).context("binding serve listener")?;
        let devices = Arc::new(DeviceEstimators::new(Arc::clone(&estimator)));
        Ok(NetServer {
            listener,
            devices,
            estimator,
            opts,
            shutdown: Arc::new(AtomicBool::new(false)),
        })
    }

    /// The bound address (useful with port 0).
    pub fn local_addr(&self) -> Result<SocketAddr> {
        Ok(self.listener.local_addr()?)
    }

    /// A handle that triggers a graceful drain from another thread.
    pub fn shutdown_handle(&self) -> ShutdownHandle {
        ShutdownHandle(Arc::clone(&self.shutdown))
    }

    fn drain_requested(&self) -> bool {
        self.shutdown.load(Ordering::SeqCst) || SIGINT_FLAG.load(Ordering::SeqCst)
    }

    /// Accept and serve connections until a drain is requested (admin
    /// `{"type":"shutdown"}` request, [`ShutdownHandle::shutdown`], or
    /// SIGINT after [`install_sigint_drain`]); then stop accepting,
    /// finish every in-flight request, and return the summary.
    pub fn run(self) -> Result<NetSummary> {
        let workers = self.opts.workers.max(1);
        let queue_cap = if self.opts.queue_cap == 0 {
            workers * 4
        } else {
            self.opts.queue_cap
        };
        let inflight = if self.opts.inflight == 0 {
            64
        } else {
            self.opts.inflight
        };

        let counters = Arc::new(NetCounters::default());
        let registry = Arc::new(Registry::default());

        // The shared pool: workers parse + answer; results are tagged
        // with their connection and routed by the dispatcher below.
        let pool_devices = Arc::clone(&self.devices);
        let pool_counters = Arc::clone(&counters);
        let mut pool: WorkerPool<NetJob, (u64, u64, bool, String)> =
            WorkerPool::new(workers, queue_cap, move |_gseq, job: NetJob| {
                let parsed = Request::parse(&job.line);
                if let Ok(req) = &parsed {
                    pool_counters.count_type(req);
                }
                let (ok, resp) = respond(&pool_devices, job.seq, parsed);
                pool_counters.tally(ok);
                (job.conn, job.seq, ok, resp)
            });
        let submit = pool.handle();
        // Drop the pool's own sender: from here the job queue lives
        // exactly as long as the connection readers' handles.
        pool.close();

        // Dispatcher: the only consumer of pool results; routes each to
        // its connection's bounded write queue. try_send never blocks,
        // so one stalled connection cannot stall the others; capacity is
        // sized so Full is unreachable while the in-flight gate holds.
        let disp_registry = Arc::clone(&registry);
        let dispatcher: JoinHandle<()> = std::thread::spawn(move || {
            while let Some((_gseq, (conn, seq, ok, resp))) = pool.recv() {
                let entry = {
                    let map = disp_registry.conns.lock().unwrap();
                    map.get(&conn).map(|e| (e.tx.clone(), Arc::clone(&e.gate)))
                };
                let Some((tx, gate)) = entry else {
                    continue; // connection already torn down
                };
                match tx.try_send(ConnMsg::Done { seq, ok, resp }) {
                    Ok(()) => {}
                    Err(TrySendError::Full(_)) => {
                        // Unreachable by construction (queue capacity >
                        // in-flight cap); poison the connection rather
                        // than stall every other one.
                        gate.kill();
                        disp_registry.conns.lock().unwrap().remove(&conn);
                    }
                    Err(TrySendError::Disconnected(_)) => {}
                }
            }
        });

        // Accept loop: non-blocking + poll so a drain request (flag or
        // SIGINT) is noticed within ~25 ms even with no traffic.
        self.listener
            .set_nonblocking(true)
            .context("setting listener non-blocking")?;
        let mut conn_handles: Vec<JoinHandle<()>> = Vec::new();
        let mut connections: u64 = 0;
        while !self.drain_requested() {
            match self.listener.accept() {
                Ok((stream, _peer)) => {
                    let conn_id = connections;
                    connections += 1;
                    if let Err(e) = self.spawn_conn(
                        conn_id,
                        stream,
                        submit.clone(),
                        Arc::clone(&registry),
                        Arc::clone(&counters),
                        inflight,
                        &mut conn_handles,
                    ) {
                        eprintln!("serve: connection {conn_id} setup failed: {e:#}");
                    }
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => {
                    std::thread::sleep(Duration::from_millis(25));
                }
                Err(e) if e.kind() == ErrorKind::Interrupted => {}
                Err(e) => {
                    // Listener broke: drain what we have and report.
                    eprintln!("serve: accept failed, draining: {e:#}");
                    break;
                }
            }
        }

        // Drain: refuse new connections, wake every reader (EOF on the
        // read half; responses still flow on the write half), and wait
        // for all in-flight work to be answered and written.
        drop(self.listener);
        {
            let map = registry.conns.lock().unwrap();
            for entry in map.values() {
                let _ = entry.stream.shutdown(Shutdown::Read);
            }
        }
        for h in conn_handles {
            let _ = h.join();
        }
        drop(submit); // last job sender: workers drain and exit
        let _ = dispatcher.join();

        let stream = StreamSummary {
            requests: counters.requests.load(Ordering::Relaxed),
            ok: counters.ok.load(Ordering::Relaxed),
            errors: counters.errors.load(Ordering::Relaxed),
            gemm: counters.gemm.load(Ordering::Relaxed),
            elementwise: counters.elementwise.load(Ordering::Relaxed),
            module: counters.module.load(Ordering::Relaxed),
            stats_requests: counters.stats.load(Ordering::Relaxed),
            cache: self.estimator.cache.stats(),
        };
        Ok(NetSummary {
            connections,
            stream,
        })
    }

    /// Register and spawn one connection's reader + writer threads.
    #[allow(clippy::too_many_arguments)]
    fn spawn_conn(
        &self,
        conn_id: u64,
        stream: TcpStream,
        submit: PoolHandle<NetJob>,
        registry: Arc<Registry>,
        counters: Arc<NetCounters>,
        inflight: usize,
        conn_handles: &mut Vec<JoinHandle<()>>,
    ) -> Result<()> {
        // Accepted sockets must be blocking regardless of what they
        // inherit from the non-blocking listener on some platforms.
        stream.set_nonblocking(false)?;
        let _ = stream.set_nodelay(true);
        let write_half = stream.try_clone().context("cloning connection stream")?;
        let sweep_half = stream.try_clone().context("cloning connection stream")?;
        // Queue capacity: in-flight cap (gate-bounded Done messages) + 1
        // Eof + slack, so the dispatcher's try_send can never see Full.
        let (tx, rx) = mpsc::sync_channel::<ConnMsg>(inflight + 8);
        let gate = Arc::new(Gate::new());
        registry.conns.lock().unwrap().insert(
            conn_id,
            ConnEntry {
                tx: tx.clone(),
                gate: Arc::clone(&gate),
                stream: sweep_half,
            },
        );
        let shutdown = Arc::clone(&self.shutdown);
        conn_handles.push(std::thread::spawn(move || {
            let writer_gate = Arc::clone(&gate);
            let writer = std::thread::spawn(move || writer_loop(write_half, rx, &writer_gate));
            let total = reader_loop(
                &stream, &submit, &tx, &gate, &counters, &shutdown, conn_id, inflight,
            );
            let _ = tx.send(ConnMsg::Eof { total });
            drop(tx);
            drop(submit);
            let _ = writer.join();
            registry.conns.lock().unwrap().remove(&conn_id);
            let _ = stream.shutdown(Shutdown::Both);
        }));
        Ok(())
    }
}

/// Read newline-delimited requests off one connection, submitting each to
/// the shared pool (or acknowledging the `shutdown` admin request
/// directly). Returns the number of responses that will exist for this
/// connection.
#[allow(clippy::too_many_arguments)]
fn reader_loop(
    stream: &TcpStream,
    submit: &PoolHandle<NetJob>,
    tx: &mpsc::SyncSender<ConnMsg>,
    gate: &Gate,
    counters: &NetCounters,
    shutdown: &AtomicBool,
    conn_id: u64,
    inflight: usize,
) -> u64 {
    let mut stream = stream;
    let mut buf: Vec<u8> = Vec::with_capacity(8 * 1024);
    let mut chunk = [0u8; 8 * 1024];
    let mut next_seq: u64 = 0;
    let mut eof = false;
    'outer: loop {
        // Drain every complete line currently buffered.
        while let Some(pos) = buf.iter().position(|&b| b == b'\n') {
            let raw: Vec<u8> = buf.drain(..=pos).collect();
            let line = String::from_utf8_lossy(&raw[..pos]);
            match handle_line(
                line.trim(),
                submit,
                tx,
                gate,
                counters,
                shutdown,
                conn_id,
                &mut next_seq,
                inflight,
            ) {
                LineOutcome::Continue => {}
                LineOutcome::Stop => break 'outer,
            }
        }
        if eof {
            break;
        }
        match stream.read(&mut chunk) {
            Ok(0) => {
                // EOF (client closed, or the drain sweep shut our read
                // half down). Flush a trailing unterminated line first.
                eof = true;
                if !buf.is_empty() {
                    buf.push(b'\n');
                }
            }
            Ok(n) => buf.extend_from_slice(&chunk[..n]),
            Err(e) if e.kind() == ErrorKind::Interrupted => {}
            Err(_) => break, // connection error: isolate and tear down
        }
        if buf.is_empty() && eof {
            break;
        }
    }
    next_seq
}

/// What a handled request line means for the reader loop.
enum LineOutcome {
    Continue,
    Stop,
}

/// Handle one request line: submit it to the pool, or answer the
/// `{"type":"shutdown"}` admin request inline and trigger the drain.
#[allow(clippy::too_many_arguments)]
fn handle_line(
    line: &str,
    submit: &PoolHandle<NetJob>,
    tx: &mpsc::SyncSender<ConnMsg>,
    gate: &Gate,
    counters: &NetCounters,
    shutdown: &AtomicBool,
    conn_id: u64,
    next_seq: &mut u64,
    inflight: usize,
) -> LineOutcome {
    if line.is_empty() {
        return LineOutcome::Continue;
    }
    // `next_seq` must count exactly the responses the writer will
    // receive (it becomes `Eof { total }`), so it is only advanced once
    // a response is guaranteed — never on the dead-writer/dead-pool
    // early exits below.
    let seq = *next_seq;
    counters.requests.fetch_add(1, Ordering::Relaxed);
    if is_shutdown_request(line) {
        // Admin drain: acknowledge on this connection (in order), then
        // flip the flag; the supervisor stops accepting and sweeps.
        let mut ack = Json::obj();
        ack.set("type", Json::Str("shutdown".into()))
            .set("draining", Json::Bool(true))
            .set("ok", Json::Bool(true))
            .set("id", Json::Num(seq as f64));
        counters.tally(true);
        if gate.acquire(inflight) {
            *next_seq += 1;
            let _ = tx.send(ConnMsg::Done {
                seq,
                ok: true,
                resp: ack.dump(),
            });
        }
        shutdown.store(true, Ordering::SeqCst);
        return LineOutcome::Stop;
    }
    if !gate.acquire(inflight) {
        // Writer lost its socket: every further answer would be
        // undeliverable, so stop reading. The submitted prefix still
        // completes on the pool (and is discarded by the dead writer).
        counters.tally(false);
        return LineOutcome::Stop;
    }
    if !submit.submit(
        seq,
        NetJob {
            conn: conn_id,
            seq,
            line: line.to_string(),
        },
    ) {
        counters.tally(false);
        gate.release();
        return LineOutcome::Stop;
    }
    *next_seq += 1;
    LineOutcome::Continue
}

/// Cheap admin-request probe: avoids JSON-parsing every line twice by
/// only parsing lines that literally contain `"shutdown"`.
fn is_shutdown_request(line: &str) -> bool {
    if !line.contains("\"shutdown\"") {
        return false;
    }
    match Json::parse(line) {
        Ok(j) => j.get("type").and_then(Json::as_str) == Some("shutdown"),
        Err(_) => false,
    }
}

/// Write one connection's responses back in request order. Receives
/// completions (in any order) plus the reader's final `Eof { total }`,
/// reorders via a bounded buffer (the in-flight gate caps it), and exits
/// once `total` responses have been written — or keeps draining with the
/// socket gone so the reader and dispatcher never block on a dead
/// connection.
fn writer_loop(stream: TcpStream, rx: mpsc::Receiver<ConnMsg>, gate: &Gate) {
    let mut out = BufWriter::new(stream);
    let mut pending: BTreeMap<u64, String> = BTreeMap::new();
    let mut next_write: u64 = 0;
    let mut emitted: u64 = 0;
    let mut total: Option<u64> = None;
    let mut dead = false;
    loop {
        if total == Some(emitted) {
            break;
        }
        let msg = match rx.recv() {
            Ok(m) => m,
            Err(_) => break, // reader gone without Eof (setup failure)
        };
        match msg {
            ConnMsg::Eof { total: t } => total = Some(t),
            ConnMsg::Done { seq, resp, .. } => {
                pending.insert(seq, resp);
                let mut wrote = false;
                while let Some(resp) = pending.remove(&next_write) {
                    if !dead && writeln!(out, "{resp}").is_err() {
                        dead = true;
                        gate.kill();
                    }
                    next_write += 1;
                    emitted += 1;
                    wrote = true;
                    gate.release();
                }
                if wrote && !dead && out.flush().is_err() {
                    dead = true;
                    gate.kill();
                }
            }
        }
    }
    let _ = out.flush();
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::DeviceSpec;
    use crate::sweep::sweep_estimator;
    use std::io::{BufRead, BufReader};

    fn spawn_server() -> (SocketAddr, ShutdownHandle, std::thread::JoinHandle<NetSummary>) {
        let est = Arc::new(sweep_estimator(&DeviceSpec::tpu_v4()));
        let server = NetServer::bind("127.0.0.1:0", est, NetOptions::default()).unwrap();
        let addr = server.local_addr().unwrap();
        let handle = server.shutdown_handle();
        let join = std::thread::spawn(move || server.run().unwrap());
        (addr, handle, join)
    }

    #[test]
    fn single_connection_in_order_and_admin_shutdown() {
        let (addr, _handle, join) = spawn_server();
        let mut conn = TcpStream::connect(addr).unwrap();
        for d in [64, 128, 256, 128, 64] {
            writeln!(conn, r#"{{"type":"gemm","m":{d},"k":{d},"n":{d}}}"#).unwrap();
        }
        writeln!(conn, "{{\"type\":\"shutdown\"}}").unwrap();
        conn.flush().unwrap();
        let reader = BufReader::new(conn.try_clone().unwrap());
        let lines: Vec<String> = reader.lines().map(|l| l.unwrap()).collect();
        assert_eq!(lines.len(), 6);
        for (i, line) in lines.iter().enumerate() {
            let j = Json::parse(line).unwrap();
            assert_eq!(j.req_f64("id").unwrap(), i as f64, "out of order: {line}");
            assert_eq!(j.get("ok"), Some(&Json::Bool(true)), "{line}");
        }
        assert_eq!(Json::parse(&lines[5]).unwrap().req_str("type").unwrap(), "shutdown");
        // Same shape answered bit-identically on repeat (cache hit).
        let lat = |i: usize| {
            Json::parse(&lines[i]).unwrap().req_f64("latency_us").unwrap()
        };
        assert_eq!(lat(0).to_bits(), lat(4).to_bits());
        assert_eq!(lat(1).to_bits(), lat(3).to_bits());
        let summary = join.join().unwrap();
        assert_eq!(summary.connections, 1);
        assert_eq!(summary.stream.requests, 6);
        assert_eq!(summary.stream.ok, 6);
        assert_eq!(summary.stream.errors, 0);
    }

    #[test]
    fn shutdown_handle_drains_idle_connections() {
        let (addr, handle, join) = spawn_server();
        // An idle connection whose reader is blocked in read() must be
        // woken by the drain sweep, not hang the server.
        let conn = TcpStream::connect(addr).unwrap();
        std::thread::sleep(Duration::from_millis(50));
        handle.shutdown();
        let summary = join.join().unwrap();
        assert_eq!(summary.connections, 1);
        assert_eq!(summary.stream.requests, 0);
        drop(conn);
    }
}
