//! Network-native serve: a concurrent TCP front end for the estimation
//! service.
//!
//! `scalesim-tpu serve --listen <addr:port>` accepts many simultaneous
//! client connections speaking the same newline-delimited JSONL request
//! schema as the stdin/file loop ([`super::service`]). Every connection
//! gets:
//!
//! * **In-order responses.** Requests are answered over one *shared*
//!   [`WorkerPool`] (so concurrent connections contend on the sharded
//!   shape cache exactly the way it was built for), and a per-connection
//!   reorder buffer in the writer thread restores each connection's own
//!   submission order. Response `id`s are per-connection sequence
//!   numbers, so a response line is bit-identical to the same request at
//!   the same position of a `serve --input` stream.
//! * **Error isolation.** A malformed line becomes an `{"ok":false}`
//!   response and the connection continues; an I/O error (client gone,
//!   reset, …) tears down only that connection — its already-submitted
//!   work completes and is dropped on the floor, never wedging the pool
//!   or poisoning the cache.
//! * **Bounded buffering.** Each connection caps its in-flight requests
//!   (submitted but not yet written back) at
//!   [`NetOptions::inflight`]; the reader blocks at the cap, so a slow
//!   or stalled reader on one connection can never back memory or the
//!   shared result dispatcher up — other connections keep streaming.
//!
//! **Drain.** A `{"type":"shutdown"}` admin request (answered with an
//! acknowledgement) or SIGINT (see [`install_sigint_drain`]) triggers a
//! graceful drain: the listener stops accepting, every connection's read
//! half is shut down (in-flight requests are still answered and
//! written), and [`NetServer::run`] returns a [`NetSummary`] that counts
//! every accepted request exactly once. With a snapshot path configured
//! the CLI then persists the warm shape cache (see [`super::snapshot`]).

use std::collections::{BTreeMap, HashMap};
use std::io::{BufWriter, ErrorKind, Read, Write};
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{self, TrySendError};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use anyhow::{Context, Result};

use crate::obs::TraceEvent;
use crate::util::json::Json;

use super::estimator::Estimator;
use super::pool::{default_workers, PoolHandle, WorkerPool};
use super::service::{respond, DeviceEstimators, Request, ServeMetrics, StreamSummary};

/// Global SIGINT latch: set by the signal handler installed with
/// [`install_sigint_drain`], polled by every running [`NetServer`].
static SIGINT_FLAG: AtomicBool = AtomicBool::new(false);

/// Install a SIGINT handler that requests a graceful drain of every
/// running [`NetServer`] (stop accepting, answer in-flight requests,
/// emit the summary) instead of killing the process.
///
/// Storing an atomic flag is async-signal-safe; the accept loop polls
/// it. On non-Unix targets this is a no-op (Ctrl-C falls back to the
/// default process kill).
pub fn install_sigint_drain() {
    #[cfg(unix)]
    {
        extern "C" fn on_sigint(_signum: i32) {
            SIGINT_FLAG.store(true, Ordering::SeqCst);
        }
        extern "C" {
            // libc is always linked on unix targets; avoid a crate
            // dependency for one call.
            fn signal(signum: i32, handler: usize) -> usize;
        }
        const SIGINT: i32 = 2;
        unsafe {
            signal(SIGINT, on_sigint as extern "C" fn(i32) as usize);
        }
    }
}

/// Knobs for [`NetServer`].
#[derive(Debug, Clone)]
pub struct NetOptions {
    /// Worker threads answering requests (shared by all connections).
    pub workers: usize,
    /// Bounded job-queue depth; 0 means `workers * 4`.
    pub queue_cap: usize,
    /// Per-connection in-flight cap (submitted but not yet written
    /// back); 0 means 64. This bounds each connection's write queue, so
    /// one slow reader never stalls the shared dispatcher.
    pub inflight: usize,
}

impl Default for NetOptions {
    fn default() -> NetOptions {
        NetOptions {
            workers: default_workers(),
            queue_cap: 0,
            inflight: 0,
        }
    }
}

/// End-of-run accounting for a TCP serve, rendered on drain.
#[derive(Debug, Clone, Default)]
pub struct NetSummary {
    /// Connections accepted over the server's lifetime.
    pub connections: u64,
    /// Request/response/cache accounting, same shape as the stream loop.
    pub stream: StreamSummary,
}

impl NetSummary {
    /// One-line human summary (written to stderr so stdout stays clean).
    pub fn render(&self) -> String {
        format!("{} connections; {}", self.connections, self.stream.render())
    }
}

/// Lock-free request/response tallies shared by readers and workers.
#[derive(Default)]
struct NetCounters {
    requests: AtomicU64,
    ok: AtomicU64,
    errors: AtomicU64,
    gemm: AtomicU64,
    elementwise: AtomicU64,
    module: AtomicU64,
    llm: AtomicU64,
    stats: AtomicU64,
    metrics: AtomicU64,
}

impl NetCounters {
    fn tally(&self, ok: bool) {
        if ok {
            self.ok.fetch_add(1, Ordering::Relaxed);
        } else {
            self.errors.fetch_add(1, Ordering::Relaxed);
        }
    }

    fn count_type(&self, req: &Request) {
        match req {
            Request::Gemm { .. } => self.gemm.fetch_add(1, Ordering::Relaxed),
            Request::Elementwise { .. } => self.elementwise.fetch_add(1, Ordering::Relaxed),
            Request::Module { .. } => self.module.fetch_add(1, Ordering::Relaxed),
            Request::Llm { .. } => self.llm.fetch_add(1, Ordering::Relaxed),
            Request::Stats => self.stats.fetch_add(1, Ordering::Relaxed),
            Request::Metrics => self.metrics.fetch_add(1, Ordering::Relaxed),
        };
    }
}

/// One job on the shared pool: a raw request line from one connection.
/// Parsing happens on the worker, so malformed lines cost reader time
/// proportional only to their length.
struct NetJob {
    conn: u64,
    seq: u64,
    line: String,
    /// Clock reading when the reader submitted the job (0 when
    /// uninstrumented); the worker credits `queue_wait` against it.
    submit_ns: u64,
}

/// Phase timestamps a worker stamps onto its answer when metrics are
/// attached; the writer turns the gaps into the `reorder`/`write`/
/// `total` histograms and a per-request trace span tree.
#[derive(Clone, Copy)]
struct PhaseStamps {
    submit_ns: u64,
    start_ns: u64,
    parse_done_ns: u64,
    done_ns: u64,
}

/// One answered request heading back to its connection's writer.
struct NetDone {
    conn: u64,
    seq: u64,
    ok: bool,
    resp: String,
    phases: Option<PhaseStamps>,
}

/// A completed response routed back to its connection's writer.
enum ConnMsg {
    /// One answered request (per-connection sequence number + JSON line).
    Done(NetDone),
    /// The reader is done; exactly `total` responses will exist.
    Eof { total: u64 },
}

/// Per-connection in-flight gate: the reader blocks at the cap, the
/// writer releases one slot per response written (or discarded). `dead`
/// short-circuits the wait when the writer lost its socket, so a reader
/// never blocks forever on a connection that can no longer answer.
struct Gate {
    state: Mutex<(usize, bool)>,
    cv: Condvar,
}

impl Gate {
    fn new() -> Gate {
        Gate {
            state: Mutex::new((0, false)),
            cv: Condvar::new(),
        }
    }

    /// Take one in-flight slot, blocking at `cap`; `false` if the
    /// connection's writer is dead (stop reading).
    fn acquire(&self, cap: usize) -> bool {
        let mut st = self.state.lock().unwrap();
        while st.0 >= cap && !st.1 {
            st = self.cv.wait(st).unwrap();
        }
        if st.1 {
            return false;
        }
        st.0 += 1;
        true
    }

    /// Block until every in-flight slot has been released — i.e. every
    /// previously submitted request on this connection has been written
    /// back (or discarded by a dead writer). The `{"type":"stats"}`
    /// drain barrier.
    fn wait_empty(&self) {
        let mut st = self.state.lock().unwrap();
        while st.0 > 0 && !st.1 {
            st = self.cv.wait(st).unwrap();
        }
    }

    fn release(&self) {
        let mut st = self.state.lock().unwrap();
        st.0 = st.0.saturating_sub(1);
        self.cv.notify_all();
    }

    fn kill(&self) {
        let mut st = self.state.lock().unwrap();
        st.1 = true;
        self.cv.notify_all();
    }
}

/// What the dispatcher needs to reach one live connection.
struct ConnEntry {
    tx: mpsc::SyncSender<ConnMsg>,
    gate: Arc<Gate>,
    /// Clone of the connection's stream, used by the drain sweep to shut
    /// the read half down (wakes a reader blocked in `read`).
    stream: TcpStream,
}

/// Registry of live connections, shared by the accept loop (insert), the
/// dispatcher (route), connection threads (remove) and the drain sweep.
#[derive(Default)]
struct Registry {
    conns: Mutex<HashMap<u64, ConnEntry>>,
}

/// A handle that requests a graceful drain of a running [`NetServer`]
/// from another thread (tests, embedding).
#[derive(Clone)]
pub struct ShutdownHandle(Arc<AtomicBool>);

impl ShutdownHandle {
    /// Request the drain: stop accepting, answer in-flight requests,
    /// return the summary from [`NetServer::run`].
    pub fn shutdown(&self) {
        self.0.store(true, Ordering::SeqCst);
    }
}

/// The concurrent TCP estimation service. Bind with [`NetServer::bind`],
/// then [`NetServer::run`] blocks until a drain is requested.
pub struct NetServer {
    listener: TcpListener,
    devices: Arc<DeviceEstimators>,
    estimator: Arc<Estimator>,
    opts: NetOptions,
    shutdown: Arc<AtomicBool>,
}

impl NetServer {
    /// Bind the listener and prepare the service around `estimator`
    /// (whose shape cache and device registry are shared by every
    /// connection). Use port 0 to let the OS pick (see
    /// [`NetServer::local_addr`]).
    pub fn bind<A: ToSocketAddrs>(
        addr: A,
        estimator: Arc<Estimator>,
        opts: NetOptions,
    ) -> Result<NetServer> {
        let listener = TcpListener::bind(addr).context("binding serve listener")?;
        let devices = Arc::new(DeviceEstimators::new(Arc::clone(&estimator)));
        Ok(NetServer {
            listener,
            devices,
            estimator,
            opts,
            shutdown: Arc::new(AtomicBool::new(false)),
        })
    }

    /// The bound address (useful with port 0).
    pub fn local_addr(&self) -> Result<SocketAddr> {
        Ok(self.listener.local_addr()?)
    }

    /// The per-device estimator registry every connection answers from.
    /// Attach a [`ServeMetrics`] here (before [`NetServer::run`]) to
    /// instrument the whole serving stack.
    pub fn devices(&self) -> &Arc<DeviceEstimators> {
        &self.devices
    }

    /// A handle that triggers a graceful drain from another thread.
    pub fn shutdown_handle(&self) -> ShutdownHandle {
        ShutdownHandle(Arc::clone(&self.shutdown))
    }

    fn drain_requested(&self) -> bool {
        self.shutdown.load(Ordering::SeqCst) || SIGINT_FLAG.load(Ordering::SeqCst)
    }

    /// Accept and serve connections until a drain is requested (admin
    /// `{"type":"shutdown"}` request, [`ShutdownHandle::shutdown`], or
    /// SIGINT after [`install_sigint_drain`]); then stop accepting,
    /// finish every in-flight request, and return the summary.
    pub fn run(self) -> Result<NetSummary> {
        let workers = self.opts.workers.max(1);
        let queue_cap = if self.opts.queue_cap == 0 {
            workers * 4
        } else {
            self.opts.queue_cap
        };
        let inflight = if self.opts.inflight == 0 {
            64
        } else {
            self.opts.inflight
        };

        let counters = Arc::new(NetCounters::default());
        let registry = Arc::new(Registry::default());

        // The shared pool: workers parse + answer; results are tagged
        // with their connection and routed by the dispatcher below.
        // When metrics are attached the worker stamps queue-wait/parse
        // phases here and hands the timestamps to the writer.
        let metrics = self.devices.metrics().map(Arc::clone);
        let pool_devices = Arc::clone(&self.devices);
        let pool_counters = Arc::clone(&counters);
        let mut pool: WorkerPool<NetJob, NetDone> = WorkerPool::with_gauges(
            workers,
            queue_cap,
            metrics.as_ref().map(|m| m.pool_gauges()),
            move |_gseq, job: NetJob| {
                let metrics = pool_devices.metrics().map(Arc::clone);
                let start_ns = metrics.as_ref().map_or(0, |m| m.now_ns());
                if let Some(m) = &metrics {
                    m.record_queue_wait_ns(start_ns.saturating_sub(job.submit_ns));
                }
                let parsed = Request::parse(&job.line);
                let parse_done_ns = metrics.as_ref().map_or(0, |m| m.now_ns());
                if let Some(m) = &metrics {
                    m.record_parse_ns(parse_done_ns.saturating_sub(start_ns));
                }
                if let Ok(req) = &parsed {
                    pool_counters.count_type(req);
                }
                let (ok, resp) = respond(&pool_devices, job.seq, parsed);
                pool_counters.tally(ok);
                let phases = metrics.as_ref().map(|m| PhaseStamps {
                    submit_ns: job.submit_ns,
                    start_ns,
                    parse_done_ns,
                    done_ns: m.now_ns(),
                });
                NetDone {
                    conn: job.conn,
                    seq: job.seq,
                    ok,
                    resp,
                    phases,
                }
            },
        );
        let submit = pool.handle();
        // Drop the pool's own sender: from here the job queue lives
        // exactly as long as the connection readers' handles.
        pool.close();

        // Dispatcher: the only consumer of pool results; routes each to
        // its connection's bounded write queue. try_send never blocks,
        // so one stalled connection cannot stall the others; capacity is
        // sized so Full is unreachable while the in-flight gate holds.
        let disp_registry = Arc::clone(&registry);
        let dispatcher: JoinHandle<()> = std::thread::spawn(move || {
            while let Some((_gseq, done)) = pool.recv() {
                let conn = done.conn;
                let entry = {
                    let map = disp_registry.conns.lock().unwrap();
                    map.get(&conn).map(|e| (e.tx.clone(), Arc::clone(&e.gate)))
                };
                let Some((tx, gate)) = entry else {
                    continue; // connection already torn down
                };
                match tx.try_send(ConnMsg::Done(done)) {
                    Ok(()) => {}
                    Err(TrySendError::Full(_)) => {
                        // Unreachable by construction (queue capacity >
                        // in-flight cap); poison the connection rather
                        // than stall every other one.
                        gate.kill();
                        disp_registry.conns.lock().unwrap().remove(&conn);
                    }
                    Err(TrySendError::Disconnected(_)) => {}
                }
            }
        });

        // Accept loop: non-blocking + poll so a drain request (flag or
        // SIGINT) is noticed within ~25 ms even with no traffic.
        self.listener
            .set_nonblocking(true)
            .context("setting listener non-blocking")?;
        let mut conn_handles: Vec<JoinHandle<()>> = Vec::new();
        let mut connections: u64 = 0;
        while !self.drain_requested() {
            match self.listener.accept() {
                Ok((stream, _peer)) => {
                    let conn_id = connections;
                    connections += 1;
                    if let Err(e) = self.spawn_conn(
                        conn_id,
                        stream,
                        submit.clone(),
                        Arc::clone(&registry),
                        Arc::clone(&counters),
                        inflight,
                        &mut conn_handles,
                    ) {
                        eprintln!("serve: connection {conn_id} setup failed: {e:#}");
                    }
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => {
                    std::thread::sleep(Duration::from_millis(25));
                }
                Err(e) if e.kind() == ErrorKind::Interrupted => {}
                Err(e) => {
                    // Listener broke: drain what we have and report.
                    eprintln!("serve: accept failed, draining: {e:#}");
                    break;
                }
            }
        }

        // Drain: refuse new connections, wake every reader (EOF on the
        // read half; responses still flow on the write half), and wait
        // for all in-flight work to be answered and written.
        drop(self.listener);
        {
            let map = registry.conns.lock().unwrap();
            for entry in map.values() {
                let _ = entry.stream.shutdown(Shutdown::Read);
            }
        }
        for h in conn_handles {
            let _ = h.join();
        }
        drop(submit); // last job sender: workers drain and exit
        let _ = dispatcher.join();

        let stream = StreamSummary {
            requests: counters.requests.load(Ordering::Relaxed),
            ok: counters.ok.load(Ordering::Relaxed),
            errors: counters.errors.load(Ordering::Relaxed),
            gemm: counters.gemm.load(Ordering::Relaxed),
            elementwise: counters.elementwise.load(Ordering::Relaxed),
            module: counters.module.load(Ordering::Relaxed),
            llm: counters.llm.load(Ordering::Relaxed),
            stats_requests: counters.stats.load(Ordering::Relaxed),
            metrics_requests: counters.metrics.load(Ordering::Relaxed),
            cache: self.estimator.cache.stats(),
        };
        Ok(NetSummary {
            connections,
            stream,
        })
    }

    /// Register and spawn one connection's reader + writer threads.
    #[allow(clippy::too_many_arguments)]
    fn spawn_conn(
        &self,
        conn_id: u64,
        stream: TcpStream,
        submit: PoolHandle<NetJob>,
        registry: Arc<Registry>,
        counters: Arc<NetCounters>,
        inflight: usize,
        conn_handles: &mut Vec<JoinHandle<()>>,
    ) -> Result<()> {
        // Accepted sockets must be blocking regardless of what they
        // inherit from the non-blocking listener on some platforms.
        stream.set_nonblocking(false)?;
        let _ = stream.set_nodelay(true);
        let write_half = stream.try_clone().context("cloning connection stream")?;
        let sweep_half = stream.try_clone().context("cloning connection stream")?;
        // Queue capacity: in-flight cap (gate-bounded Done messages) + 1
        // Eof + slack, so the dispatcher's try_send can never see Full.
        let (tx, rx) = mpsc::sync_channel::<ConnMsg>(inflight + 8);
        let gate = Arc::new(Gate::new());
        registry.conns.lock().unwrap().insert(
            conn_id,
            ConnEntry {
                tx: tx.clone(),
                gate: Arc::clone(&gate),
                stream: sweep_half,
            },
        );
        let shutdown = Arc::clone(&self.shutdown);
        let metrics = self.devices.metrics().map(Arc::clone);
        conn_handles.push(std::thread::spawn(move || {
            let writer_gate = Arc::clone(&gate);
            let writer_metrics = metrics.clone();
            let writer = std::thread::spawn(move || {
                writer_loop(write_half, rx, &writer_gate, writer_metrics, conn_id)
            });
            let total = reader_loop(
                &stream, &submit, &tx, &gate, &counters, &shutdown, conn_id, inflight, &metrics,
            );
            let _ = tx.send(ConnMsg::Eof { total });
            drop(tx);
            drop(submit);
            let _ = writer.join();
            registry.conns.lock().unwrap().remove(&conn_id);
            let _ = stream.shutdown(Shutdown::Both);
        }));
        Ok(())
    }
}

/// Read newline-delimited requests off one connection, submitting each to
/// the shared pool (or acknowledging the `shutdown` admin request
/// directly). Returns the number of responses that will exist for this
/// connection.
#[allow(clippy::too_many_arguments)]
fn reader_loop(
    stream: &TcpStream,
    submit: &PoolHandle<NetJob>,
    tx: &mpsc::SyncSender<ConnMsg>,
    gate: &Gate,
    counters: &NetCounters,
    shutdown: &AtomicBool,
    conn_id: u64,
    inflight: usize,
    metrics: &Option<Arc<ServeMetrics>>,
) -> u64 {
    let mut stream = stream;
    let mut buf: Vec<u8> = Vec::with_capacity(8 * 1024);
    let mut chunk = [0u8; 8 * 1024];
    let mut next_seq: u64 = 0;
    let mut eof = false;
    'outer: loop {
        // Drain every complete line currently buffered.
        while let Some(pos) = buf.iter().position(|&b| b == b'\n') {
            let raw: Vec<u8> = buf.drain(..=pos).collect();
            let line = String::from_utf8_lossy(&raw[..pos]);
            match handle_line(
                line.trim(),
                submit,
                tx,
                gate,
                counters,
                shutdown,
                conn_id,
                &mut next_seq,
                inflight,
                metrics,
            ) {
                LineOutcome::Continue => {}
                LineOutcome::Stop => break 'outer,
            }
        }
        if eof {
            break;
        }
        match stream.read(&mut chunk) {
            Ok(0) => {
                // EOF (client closed, or the drain sweep shut our read
                // half down). Flush a trailing unterminated line first.
                eof = true;
                if !buf.is_empty() {
                    buf.push(b'\n');
                }
            }
            Ok(n) => buf.extend_from_slice(&chunk[..n]),
            Err(e) if e.kind() == ErrorKind::Interrupted => {}
            Err(_) => break, // connection error: isolate and tear down
        }
        if buf.is_empty() && eof {
            break;
        }
    }
    next_seq
}

/// What a handled request line means for the reader loop.
enum LineOutcome {
    Continue,
    Stop,
}

/// Handle one request line: submit it to the pool, or answer the
/// `{"type":"shutdown"}` admin request inline and trigger the drain.
///
/// A `{"type":"stats"}` request first waits for every earlier request
/// on this connection to be answered and written (the drain barrier the
/// batch and stream paths already guarantee, scoped to the connection's
/// own prefix — see [`super::serve_lines`]). Other connections keep
/// flowing, so the counters a stats answer reports may additionally
/// include their concurrent traffic.
#[allow(clippy::too_many_arguments)]
fn handle_line(
    line: &str,
    submit: &PoolHandle<NetJob>,
    tx: &mpsc::SyncSender<ConnMsg>,
    gate: &Gate,
    counters: &NetCounters,
    shutdown: &AtomicBool,
    conn_id: u64,
    next_seq: &mut u64,
    inflight: usize,
    metrics: &Option<Arc<ServeMetrics>>,
) -> LineOutcome {
    if line.is_empty() {
        return LineOutcome::Continue;
    }
    // `next_seq` must count exactly the responses the writer will
    // receive (it becomes `Eof { total }`), so it is only advanced once
    // a response is guaranteed — never on the dead-writer/dead-pool
    // early exits below.
    let seq = *next_seq;
    counters.requests.fetch_add(1, Ordering::Relaxed);
    if is_admin_request(line, "\"shutdown\"", "shutdown") {
        // Admin drain: acknowledge on this connection (in order), then
        // flip the flag; the supervisor stops accepting and sweeps.
        let mut ack = Json::obj();
        ack.set("type", Json::Str("shutdown".into()))
            .set("draining", Json::Bool(true))
            .set("ok", Json::Bool(true))
            .set("id", Json::Num(seq as f64));
        counters.tally(true);
        if gate.acquire(inflight) {
            *next_seq += 1;
            let _ = tx.send(ConnMsg::Done(NetDone {
                conn: conn_id,
                seq,
                ok: true,
                resp: ack.dump(),
                phases: None,
            }));
        }
        shutdown.store(true, Ordering::SeqCst);
        return LineOutcome::Stop;
    }
    if is_admin_request(line, "\"stats\"", "stats") {
        // Drain barrier: block until the connection's in-flight window
        // is empty, so the submitted stats request observes counters
        // covering this connection's entire answered prefix.
        gate.wait_empty();
    }
    if !gate.acquire(inflight) {
        // Writer lost its socket: every further answer would be
        // undeliverable, so stop reading. The submitted prefix still
        // completes on the pool (and is discarded by the dead writer).
        counters.tally(false);
        return LineOutcome::Stop;
    }
    let submit_ns = metrics.as_ref().map_or(0, |m| m.now_ns());
    if !submit.submit(
        seq,
        NetJob {
            conn: conn_id,
            seq,
            line: line.to_string(),
            submit_ns,
        },
    ) {
        counters.tally(false);
        gate.release();
        return LineOutcome::Stop;
    }
    *next_seq += 1;
    LineOutcome::Continue
}

/// Cheap admin-request probe: avoids JSON-parsing every line twice by
/// only parsing lines that literally contain the pre-quoted type name
/// (`quoted` is `ty` wrapped in `"` — passed separately so the hot path
/// never allocates).
fn is_admin_request(line: &str, quoted: &str, ty: &str) -> bool {
    if !line.contains(quoted) {
        return false;
    }
    match Json::parse(line) {
        Ok(j) => j.get("type").and_then(Json::as_str) == Some(ty),
        Err(_) => false,
    }
}

/// Write one connection's responses back in request order. Receives
/// completions (in any order) plus the reader's final `Eof { total }`,
/// reorders via a bounded buffer (the in-flight gate caps it), and exits
/// once `total` responses have been written — or keeps draining with the
/// socket gone so the reader and dispatcher never block on a dead
/// connection.
///
/// When instrumented this is also where the request's lifetime closes:
/// the writer records the `reorder`/`write`/`total` phase histograms and
/// emits the request's span tree (one `request` slice with
/// `queue_wait`/`parse`/`estimate`/`reorder`/`write` children nested by
/// time containment on lane `(pid 1, tid = connection id)`) to the
/// attached trace file.
fn writer_loop(
    stream: TcpStream,
    rx: mpsc::Receiver<ConnMsg>,
    gate: &Gate,
    metrics: Option<Arc<ServeMetrics>>,
    conn_id: u64,
) {
    let mut out = BufWriter::new(stream);
    let mut pending: BTreeMap<u64, NetDone> = BTreeMap::new();
    let mut next_write: u64 = 0;
    let mut emitted: u64 = 0;
    let mut total: Option<u64> = None;
    let mut dead = false;
    let mut lane_named = false;
    loop {
        if total == Some(emitted) {
            break;
        }
        let msg = match rx.recv() {
            Ok(m) => m,
            Err(_) => break, // reader gone without Eof (setup failure)
        };
        match msg {
            ConnMsg::Eof { total: t } => total = Some(t),
            ConnMsg::Done(done) => {
                pending.insert(done.seq, done);
                let mut wrote = false;
                while let Some(done) = pending.remove(&next_write) {
                    let write_start_ns = match (&metrics, &done.phases) {
                        (Some(m), Some(_)) => m.now_ns(),
                        _ => 0,
                    };
                    if !dead && writeln!(out, "{}", done.resp).is_err() {
                        dead = true;
                        gate.kill();
                    }
                    if let (Some(m), Some(ph)) = (&metrics, &done.phases) {
                        let write_done_ns = m.now_ns();
                        m.record_reorder_ns(write_start_ns.saturating_sub(ph.done_ns));
                        m.record_write_ns(write_done_ns.saturating_sub(write_start_ns));
                        m.record_total_ns(write_done_ns.saturating_sub(ph.submit_ns));
                        if let Some(tw) = m.trace() {
                            if !lane_named {
                                lane_named = true;
                                let _ = tw.write(&TraceEvent::thread_name(
                                    1,
                                    conn_id,
                                    &format!("conn {conn_id}"),
                                ));
                            }
                            let _ = tw.write_all(&request_span_tree(
                                &done,
                                ph,
                                write_start_ns,
                                write_done_ns,
                                conn_id,
                            ));
                        }
                    }
                    next_write += 1;
                    emitted += 1;
                    wrote = true;
                    gate.release();
                }
                if wrote && !dead && out.flush().is_err() {
                    dead = true;
                    gate.kill();
                }
            }
        }
    }
    let _ = out.flush();
}

/// Build one request's completed span tree: a parent `request` slice
/// covering submit → written, with one child slice per phase. All on
/// `(pid 1, tid = connection id)`, so viewers nest the children inside
/// the parent by time containment.
fn request_span_tree(
    done: &NetDone,
    ph: &PhaseStamps,
    write_start_ns: u64,
    write_done_ns: u64,
    conn_id: u64,
) -> Vec<TraceEvent> {
    let slice = |name: &str, from_ns: u64, to_ns: u64| {
        TraceEvent::complete(
            name,
            "serve",
            from_ns as f64 / 1000.0,
            to_ns.saturating_sub(from_ns) as f64 / 1000.0,
            1,
            conn_id,
        )
    };
    vec![
        slice("request", ph.submit_ns, write_done_ns)
            .arg("id", Json::Num(done.seq as f64))
            .arg("ok", Json::Bool(done.ok)),
        slice("queue_wait", ph.submit_ns, ph.start_ns),
        slice("parse", ph.start_ns, ph.parse_done_ns),
        slice("estimate", ph.parse_done_ns, ph.done_ns),
        slice("reorder", ph.done_ns, write_start_ns),
        slice("write", write_start_ns, write_done_ns),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::DeviceSpec;
    use crate::sweep::sweep_estimator;
    use std::io::{BufRead, BufReader};

    fn spawn_server() -> (SocketAddr, ShutdownHandle, std::thread::JoinHandle<NetSummary>) {
        let est = Arc::new(sweep_estimator(&DeviceSpec::tpu_v4()));
        let server = NetServer::bind("127.0.0.1:0", est, NetOptions::default()).unwrap();
        let addr = server.local_addr().unwrap();
        let handle = server.shutdown_handle();
        let join = std::thread::spawn(move || server.run().unwrap());
        (addr, handle, join)
    }

    #[test]
    fn single_connection_in_order_and_admin_shutdown() {
        let (addr, _handle, join) = spawn_server();
        let mut conn = TcpStream::connect(addr).unwrap();
        for d in [64, 128, 256, 128, 64] {
            writeln!(conn, r#"{{"type":"gemm","m":{d},"k":{d},"n":{d}}}"#).unwrap();
        }
        writeln!(conn, "{{\"type\":\"shutdown\"}}").unwrap();
        conn.flush().unwrap();
        let reader = BufReader::new(conn.try_clone().unwrap());
        let lines: Vec<String> = reader.lines().map(|l| l.unwrap()).collect();
        assert_eq!(lines.len(), 6);
        for (i, line) in lines.iter().enumerate() {
            let j = Json::parse(line).unwrap();
            assert_eq!(j.req_f64("id").unwrap(), i as f64, "out of order: {line}");
            assert_eq!(j.get("ok"), Some(&Json::Bool(true)), "{line}");
        }
        assert_eq!(Json::parse(&lines[5]).unwrap().req_str("type").unwrap(), "shutdown");
        // Same shape answered bit-identically on repeat (cache hit).
        let lat = |i: usize| {
            Json::parse(&lines[i]).unwrap().req_f64("latency_us").unwrap()
        };
        assert_eq!(lat(0).to_bits(), lat(4).to_bits());
        assert_eq!(lat(1).to_bits(), lat(3).to_bits());
        let summary = join.join().unwrap();
        assert_eq!(summary.connections, 1);
        assert_eq!(summary.stream.requests, 6);
        assert_eq!(summary.stream.ok, 6);
        assert_eq!(summary.stream.errors, 0);
    }

    #[test]
    fn stats_barrier_covers_the_connection_prefix() {
        // Regression (stats drain-barrier unification): the TCP path
        // must answer `{"type":"stats"}` only after every earlier
        // request on the same connection has been answered and written,
        // matching the batch/stream semantics documented on
        // `serve_lines`. Without the barrier the stats request races
        // the gemms through the shared pool and undercounts.
        let (addr, _handle, join) = spawn_server();
        let mut conn = TcpStream::connect(addr).unwrap();
        let n = 40usize;
        for i in 0..n {
            let d = 64 + (i % 4) * 32;
            writeln!(conn, r#"{{"type":"gemm","m":{d},"k":{d},"n":{d}}}"#).unwrap();
        }
        writeln!(conn, "{{\"type\":\"stats\"}}").unwrap();
        writeln!(conn, "{{\"type\":\"shutdown\"}}").unwrap();
        conn.flush().unwrap();
        let reader = BufReader::new(conn.try_clone().unwrap());
        let lines: Vec<String> = reader.lines().map(|l| l.unwrap()).collect();
        assert_eq!(lines.len(), n + 2);
        let stats = Json::parse(&lines[n]).unwrap();
        assert_eq!(stats.req_str("type").unwrap(), "stats");
        assert_eq!(stats.req_f64("id").unwrap(), n as f64);
        // The barrier saw all 40 gemm probes — no more, no fewer. Two
        // workers racing on the same fresh key may both miss, so the
        // split is bounded, not exact.
        let hits = stats.req_f64("cache_hits").unwrap();
        let misses = stats.req_f64("cache_misses").unwrap();
        assert_eq!(hits + misses, n as f64);
        assert_eq!(stats.req_f64("cache_entries").unwrap(), 4.0);
        let summary = join.join().unwrap();
        assert_eq!(summary.stream.requests, (n + 2) as u64);
        assert_eq!(summary.stream.stats_requests, 1);
    }

    #[test]
    fn instrumented_tcp_serve_emits_phase_metrics_and_trace_spans() {
        use crate::obs::{MonotonicClock, RegistrySnapshot, TraceFileWriter};
        let dir = std::env::temp_dir().join("scalesim_net_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join(format!("serve-{}.trace.json", std::process::id()));
        let trace = Arc::new(TraceFileWriter::create(&path).unwrap());
        let metrics = Arc::new(ServeMetrics::new(
            Arc::new(MonotonicClock::new()),
            Some(Arc::clone(&trace)),
        ));
        let est = Arc::new(sweep_estimator(&DeviceSpec::tpu_v4()));
        let server = NetServer::bind(
            "127.0.0.1:0",
            est,
            NetOptions {
                workers: 1,
                queue_cap: 4,
                inflight: 0,
            },
        )
        .unwrap();
        server.devices().attach_metrics(Arc::clone(&metrics));
        let addr = server.local_addr().unwrap();
        let join = std::thread::spawn(move || server.run().unwrap());

        let mut conn = TcpStream::connect(addr).unwrap();
        for _ in 0..3 {
            writeln!(conn, r#"{{"type":"gemm","m":64,"k":64,"n":64}}"#).unwrap();
        }
        writeln!(conn, "{{\"type\":\"metrics\"}}").unwrap();
        writeln!(conn, "{{\"type\":\"shutdown\"}}").unwrap();
        conn.flush().unwrap();
        let reader = BufReader::new(conn.try_clone().unwrap());
        let lines: Vec<String> = reader.lines().map(|l| l.unwrap()).collect();
        assert_eq!(lines.len(), 5);
        let summary = join.join().unwrap();
        assert_eq!(summary.stream.metrics_requests, 1);

        // One worker answers in submission order, so the wire snapshot
        // taken by the metrics request has seen all three gemms.
        let m = Json::parse(&lines[3]).unwrap();
        assert_eq!(m.get("enabled"), Some(&Json::Bool(true)));
        let snap = RegistrySnapshot::from_json(m.get("metrics").unwrap()).unwrap();
        let gemms = snap
            .counters
            .iter()
            .find(|(f, l, _)| {
                f == "scalesim_requests_total"
                    && l.iter().any(|(k, v)| k == "type" && v == "gemm")
            })
            .map(|(_, _, v)| *v);
        assert_eq!(gemms, Some(3));

        // The writer closed every pooled request's lifetime: 4 totals
        // (the inline shutdown ack is not phase-stamped), with the
        // identical gemms classified one miss + two hits.
        assert_eq!(metrics.phase_snapshot("total").unwrap().count, 4);
        assert_eq!(metrics.phase_snapshot("reorder").unwrap().count, 4);
        assert_eq!(metrics.phase_snapshot("write").unwrap().count, 4);
        assert_eq!(metrics.phase_snapshot("queue_wait").unwrap().count, 4);
        assert_eq!(metrics.phase_snapshot("estimate_miss").unwrap().count, 1);
        assert_eq!(metrics.phase_snapshot("estimate_hit").unwrap().count, 2);

        // The trace holds the connection lane name plus one span tree
        // (request + 5 phase children) per pooled request.
        assert_eq!(trace.finish().unwrap(), 1 + 4 * 6);
        let text = std::fs::read_to_string(&path).unwrap();
        let parsed = Json::parse(&text).unwrap();
        let events = parsed.req_arr("traceEvents").unwrap();
        assert!(events
            .iter()
            .any(|e| e.get("name").and_then(Json::as_str) == Some("thread_name")));
        let requests = events
            .iter()
            .filter(|e| e.get("name").and_then(Json::as_str) == Some("request"))
            .count();
        assert_eq!(requests, 4);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn shutdown_handle_drains_idle_connections() {
        let (addr, handle, join) = spawn_server();
        // An idle connection whose reader is blocked in read() must be
        // woken by the drain sweep, not hang the server.
        let conn = TcpStream::connect(addr).unwrap();
        std::thread::sleep(Duration::from_millis(50));
        handle.shutdown();
        let summary = join.join().unwrap();
        assert_eq!(summary.connections, 1);
        assert_eq!(summary.stream.requests, 0);
        drop(conn);
    }
}
