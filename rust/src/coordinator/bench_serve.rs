//! `bench-serve`: a self-contained load generator for the TCP service.
//!
//! Spawns N closed-loop client connections (each sends a request, waits
//! for its response, repeats) against either an in-process
//! [`NetServer`](super::net::NetServer) or a remote `--addr`, and
//! reports sustained throughput plus p50/p95/p99 tail latency. The
//! request mix cycles through a fixed set of distinct GEMM shapes and a
//! warm-up pass primes the shared shape cache first, so the measured
//! regime is the one the service is built for: warm-cache hits under
//! real connection concurrency. In-process runs also attach a
//! [`ServeMetrics`] surface and report the in-pool queue-wait vs
//! worker service-time breakdown from its phase histograms.
//!
//! `--publish` writes `BENCH_serve.json` at the repo root with an FNV-1a
//! fingerprint of this source file; `--check` re-reads it and fails when
//! it is missing or stale against the source — the same freshness-gate
//! idiom as `BENCH_estimator.json` (`benches/estimator_batch.rs`), wired
//! into `make check`. The serve perf trajectory is tracked across PRs in
//! EXPERIMENTS.md §Perf bench-serve.

use std::io::{BufRead, BufReader, BufWriter, Write};
use std::net::TcpStream;
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::device::DeviceSpec;
use crate::obs::MonotonicClock;
use crate::sweep::sweep_estimator;
use crate::util::json::Json;

use super::net::{NetOptions, NetServer};
use super::pool::default_workers;
use super::service::ServeMetrics;

const SOURCE: &str = include_str!("bench_serve.rs");

/// Distinct GEMM shapes the clients cycle through (kept small so the
/// timed phase runs warm; the warm-up pass touches each one first).
const SHAPE_DIMS: [usize; 8] = [64, 96, 128, 160, 192, 224, 256, 320];

fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Fingerprint of this source file, stamped into `BENCH_serve.json`.
pub fn source_fingerprint() -> String {
    format!("{:016x}", fnv1a(SOURCE.as_bytes()))
}

/// `BENCH_serve.json` at the repo root.
pub fn bench_json_path() -> std::path::PathBuf {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("../BENCH_serve.json")
}

/// Knobs for [`run_bench`].
#[derive(Debug, Clone)]
pub struct BenchOptions {
    /// Concurrent client connections.
    pub clients: usize,
    /// Requests sent per client (timed phase).
    pub requests: usize,
    /// Optional paced offered load, requests/sec across all clients;
    /// `None` runs closed-loop flat out.
    pub rps: Option<f64>,
    /// Remote server to target; `None` spins an in-process server up.
    pub addr: Option<String>,
    /// Worker threads for the in-process server.
    pub workers: usize,
}

impl Default for BenchOptions {
    fn default() -> BenchOptions {
        BenchOptions {
            clients: 16,
            requests: 500,
            rps: None,
            addr: None,
            workers: default_workers(),
        }
    }
}

/// What one bench run measured.
#[derive(Debug, Clone)]
pub struct BenchReport {
    /// Concurrent client connections.
    pub clients: usize,
    /// Requests per client in the timed phase.
    pub requests_per_client: usize,
    /// Total timed requests (`clients * requests_per_client`).
    pub total_requests: u64,
    /// Error responses observed (must be 0 on a healthy run).
    pub errors: u64,
    /// Timed-phase wall clock, seconds.
    pub elapsed_s: f64,
    /// Sustained throughput, requests/sec.
    pub throughput_rps: f64,
    /// Median request latency, µs.
    pub p50_us: f64,
    /// 95th-percentile request latency, µs.
    pub p95_us: f64,
    /// 99th-percentile request latency, µs.
    pub p99_us: f64,
    /// Shape-cache hit rate over the whole run (in-process server only).
    pub cache_hit_rate: Option<f64>,
    /// Paced offered load, if any.
    pub rps_target: Option<f64>,
    /// Mean in-pool queue wait per request, µs — time between slot
    /// submission and a worker claiming the job (in-process server
    /// only; from the serve `queue_wait` phase histogram).
    pub queue_wait_mean_us: Option<f64>,
    /// p95 in-pool queue wait, µs (bucketed, so an upper bound).
    pub queue_wait_p95_us: Option<f64>,
    /// Mean estimate-phase service time per request, µs — the worker's
    /// answer computation, queue wait excluded (in-process only).
    pub service_mean_us: Option<f64>,
    /// p95 estimate-phase service time, µs (bucketed upper bound).
    pub service_p95_us: Option<f64>,
}

impl BenchReport {
    /// Human-readable summary lines.
    pub fn render(&self) -> String {
        let mut s = format!(
            "bench-serve: {} clients x {} requests -> {:.0} req/s \
             (p50 {:.1} us, p95 {:.1} us, p99 {:.1} us; {} errors; {:.2}s)",
            self.clients,
            self.requests_per_client,
            self.throughput_rps,
            self.p50_us,
            self.p95_us,
            self.p99_us,
            self.errors,
            self.elapsed_s,
        );
        if let Some(hr) = self.cache_hit_rate {
            s.push_str(&format!("; cache hit rate {:.1}%", hr * 100.0));
        }
        if let (Some(qm), Some(qp), Some(sm), Some(sp)) = (
            self.queue_wait_mean_us,
            self.queue_wait_p95_us,
            self.service_mean_us,
            self.service_p95_us,
        ) {
            s.push_str(&format!(
                "\n  breakdown: queue wait mean {qm:.1} us (p95 <= {qp:.1}) vs \
                 service mean {sm:.1} us (p95 <= {sp:.1})"
            ));
        }
        if let Some(r) = self.rps_target {
            s.push_str(&format!("; paced at {r:.0} req/s offered"));
        }
        s
    }

    /// The `BENCH_serve.json` payload.
    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("bench", Json::Str("serve".into()))
            .set("clients", Json::Num(self.clients as f64))
            .set("requests_per_client", Json::Num(self.requests_per_client as f64))
            .set("total_requests", Json::Num(self.total_requests as f64))
            .set("errors", Json::Num(self.errors as f64))
            .set("elapsed_s", Json::Num(self.elapsed_s))
            .set("throughput_rps", Json::Num(self.throughput_rps))
            .set("p50_us", Json::Num(self.p50_us))
            .set("p95_us", Json::Num(self.p95_us))
            .set("p99_us", Json::Num(self.p99_us))
            .set("source_fingerprint", Json::Str(source_fingerprint()));
        if let Some(hr) = self.cache_hit_rate {
            o.set("cache_hit_rate", Json::Num(hr));
        }
        if let Some(r) = self.rps_target {
            o.set("rps_target", Json::Num(r));
        }
        if let Some(v) = self.queue_wait_mean_us {
            o.set("queue_wait_mean_us", Json::Num(v));
        }
        if let Some(v) = self.queue_wait_p95_us {
            o.set("queue_wait_p95_us", Json::Num(v));
        }
        if let Some(v) = self.service_mean_us {
            o.set("service_mean_us", Json::Num(v));
        }
        if let Some(v) = self.service_p95_us {
            o.set("service_p95_us", Json::Num(v));
        }
        o
    }

    /// Write `BENCH_serve.json` at the repo root.
    pub fn publish(&self) -> Result<()> {
        let path = bench_json_path();
        std::fs::write(&path, format!("{}\n", self.to_json().dump()))
            .with_context(|| format!("writing {}", path.display()))?;
        println!("wrote {}", path.display());
        Ok(())
    }
}

/// The request line for the i-th send of any client (cycles the shape
/// set so the timed phase is all warm hits after the warm-up pass).
fn request_line(i: usize) -> String {
    let d = SHAPE_DIMS[i % SHAPE_DIMS.len()];
    format!(r#"{{"type":"gemm","m":{d},"k":{d},"n":{d}}}"#)
}

/// Nearest-rank percentile of an ascending-sorted slice, `q` in [0, 1].
fn percentile(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let idx = ((sorted.len() - 1) as f64 * q).round() as usize;
    sorted[idx.min(sorted.len() - 1)]
}

/// One client's closed loop: send `requests` lines, awaiting each
/// response before the next send; returns per-request latencies (µs)
/// and the number of error responses.
fn client_loop(
    addr: &str,
    requests: usize,
    pace: Option<Duration>,
) -> Result<(Vec<f64>, u64)> {
    let stream = TcpStream::connect(addr).with_context(|| format!("connecting to {addr}"))?;
    stream.set_nodelay(true).ok();
    let mut writer = BufWriter::new(stream.try_clone()?);
    let mut reader = BufReader::new(stream);
    let mut latencies = Vec::with_capacity(requests);
    let mut errors = 0u64;
    let mut line = String::new();
    let started = Instant::now();
    for i in 0..requests {
        if let Some(interval) = pace {
            // Paced mode: hold each send to its schedule slot (send k
            // happens no earlier than k * interval after the start).
            let due = interval * i as u32;
            let now = started.elapsed();
            if due > now {
                std::thread::sleep(due - now);
            }
        }
        let t0 = Instant::now();
        writeln!(writer, "{}", request_line(i))?;
        writer.flush()?;
        line.clear();
        if reader.read_line(&mut line)? == 0 {
            bail!("server closed the connection after {i} responses");
        }
        latencies.push(t0.elapsed().as_secs_f64() * 1e6);
        if !line.contains("\"ok\":true") {
            errors += 1;
        }
    }
    Ok((latencies, errors))
}

/// Run the load generator per `opts` and return the measurements.
///
/// Without `opts.addr` an in-process [`NetServer`] (sweep-calibrated
/// tpu-v4, so runs are self-contained and deterministic in shape) is
/// started on a loopback port and drained afterwards; its cache hit
/// rate rides along in the report.
pub fn run_bench(opts: &BenchOptions) -> Result<BenchReport> {
    if opts.clients == 0 || opts.requests == 0 {
        bail!("bench-serve needs at least one client and one request");
    }
    // In-process server (unless a remote --addr was given).
    let mut server_thread = None;
    let mut shutdown = None;
    let mut metrics: Option<Arc<ServeMetrics>> = None;
    let addr = match &opts.addr {
        Some(a) => a.clone(),
        None => {
            let est = Arc::new(sweep_estimator(&DeviceSpec::tpu_v4()));
            let server = NetServer::bind(
                "127.0.0.1:0",
                est,
                NetOptions {
                    workers: opts.workers,
                    ..NetOptions::default()
                },
            )?;
            // Instrument the in-process server (histograms only, no
            // trace) so the report can split in-pool queue wait from
            // worker service time; a clock read plus an atomic bucket
            // increment per phase is noise next to the ~100 µs
            // request round-trip.
            let m = Arc::new(ServeMetrics::new(Arc::new(MonotonicClock::new()), None));
            server.devices().attach_metrics(Arc::clone(&m));
            metrics = Some(m);
            let addr = server.local_addr()?.to_string();
            shutdown = Some(server.shutdown_handle());
            server_thread = Some(std::thread::spawn(move || server.run()));
            addr
        }
    };

    // Warm-up: touch every distinct shape once so the timed phase
    // measures the warm regime (untimed).
    let (_lat, warm_errors) = client_loop(&addr, SHAPE_DIMS.len(), None)?;
    if warm_errors > 0 {
        bail!("{warm_errors} error responses during warm-up");
    }

    // Timed phase: N concurrent closed-loop clients.
    let pace = opts.rps.map(|r| {
        // Offered load is split evenly: each client paces at rps/clients.
        Duration::from_secs_f64(opts.clients as f64 / r.max(1e-9))
    });
    let t0 = Instant::now();
    let threads: Vec<_> = (0..opts.clients)
        .map(|_| {
            let addr = addr.clone();
            let requests = opts.requests;
            std::thread::spawn(move || client_loop(&addr, requests, pace))
        })
        .collect();
    let mut latencies: Vec<f64> = Vec::with_capacity(opts.clients * opts.requests);
    let mut errors = 0u64;
    for t in threads {
        let (lat, err) = t.join().expect("bench client panicked")?;
        latencies.extend(lat);
        errors += err;
    }
    let elapsed_s = t0.elapsed().as_secs_f64();

    // Drain the in-process server and pull its cache stats.
    let mut cache_hit_rate = None;
    if let (Some(handle), Some(thread)) = (shutdown, server_thread) {
        handle.shutdown();
        let summary = thread.join().expect("server thread panicked")?;
        cache_hit_rate = Some(summary.stream.cache.hit_rate());
    }

    // Queue-wait vs service-time breakdown from the phase histograms
    // (ns-valued; the warm-up pass is included, which is fine — it is
    // 8 requests against thousands).
    let phase_us = |phase: &str, q: Option<f64>| -> Option<f64> {
        let snap = metrics.as_ref()?.phase_snapshot(phase)?;
        Some(match q {
            Some(q) => snap.quantile(q) / 1e3,
            None => snap.mean() / 1e3,
        })
    };
    let queue_wait_mean_us = phase_us("queue_wait", None);
    let queue_wait_p95_us = phase_us("queue_wait", Some(0.95));
    let service_mean_us = phase_us("estimate", None);
    let service_p95_us = phase_us("estimate", Some(0.95));

    latencies.sort_unstable_by(|a, b| a.partial_cmp(b).unwrap());
    let total_requests = latencies.len() as u64;
    Ok(BenchReport {
        clients: opts.clients,
        requests_per_client: opts.requests,
        total_requests,
        errors,
        elapsed_s,
        throughput_rps: total_requests as f64 / elapsed_s.max(1e-12),
        p50_us: percentile(&latencies, 0.50),
        p95_us: percentile(&latencies, 0.95),
        p99_us: percentile(&latencies, 0.99),
        cache_hit_rate,
        rps_target: opts.rps,
        queue_wait_mean_us,
        queue_wait_p95_us,
        service_mean_us,
        service_p95_us,
    })
}

/// `--check`: the published numbers must exist and match this source.
pub fn check_published() -> Result<()> {
    let path = bench_json_path();
    let text = std::fs::read_to_string(&path).with_context(|| {
        format!(
            "BENCH_serve.json missing at {}; run `make bench-serve`",
            path.display()
        )
    })?;
    let json = Json::parse(&text).map_err(|e| anyhow::anyhow!("BENCH_serve.json: {e}"))?;
    let published = json
        .get("source_fingerprint")
        .and_then(Json::as_str)
        .ok_or_else(|| anyhow::anyhow!("BENCH_serve.json lacks source_fingerprint"))?;
    let current = source_fingerprint();
    if published != current {
        bail!(
            "BENCH_serve.json is stale: published fingerprint {published} != bench source \
             {current}; re-run `make bench-serve` and commit the result"
        );
    }
    println!(
        "BENCH_serve.json is fresh (source fingerprint {current}, throughput_rps {:.0})",
        json.get("throughput_rps").and_then(Json::as_f64).unwrap_or(0.0)
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentile_nearest_rank() {
        let v: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert_eq!(percentile(&v, 0.0), 1.0);
        assert_eq!(percentile(&v, 1.0), 100.0);
        assert_eq!(percentile(&v, 0.5), 51.0); // round((100-1)*0.5)=50 -> v[50]
        assert!(percentile(&[], 0.5) == 0.0);
    }

    #[test]
    fn small_in_process_bench_reports_sane_numbers() {
        let report = run_bench(&BenchOptions {
            clients: 4,
            requests: 25,
            workers: 4,
            ..BenchOptions::default()
        })
        .unwrap();
        assert_eq!(report.total_requests, 100);
        assert_eq!(report.errors, 0);
        assert!(report.throughput_rps > 0.0);
        assert!(report.p50_us > 0.0);
        assert!(report.p50_us <= report.p95_us && report.p95_us <= report.p99_us);
        // Warm-up covered every shape: the timed phase is all hits.
        assert!(report.cache_hit_rate.unwrap() > 0.5);
        // In-process runs are instrumented: the queue-wait vs service
        // breakdown must be present, with real work on the service side.
        // (No mean-vs-p95 ordering asserted: the mean is exact but the
        // quantile is a bucket upper bound, so a long-tailed phase can
        // legitimately have mean > p95.)
        assert!(report.service_mean_us.unwrap() > 0.0);
        assert!(report.service_p95_us.unwrap() > 0.0);
        assert!(report.queue_wait_mean_us.unwrap() >= 0.0);
        assert!(report.queue_wait_p95_us.unwrap() >= 0.0);
        let j = report.to_json();
        assert_eq!(j.req_str("bench").unwrap(), "serve");
        assert_eq!(j.req_str("source_fingerprint").unwrap(), source_fingerprint());
        assert!(j.get("queue_wait_mean_us").is_some());
        assert!(j.get("service_mean_us").is_some());
    }
}
