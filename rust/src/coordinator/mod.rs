//! L3 coordinator: the whole-model estimator ([`estimator`]), the scoped
//! worker pool driving parallel sweeps ([`pool`]), and the JSONL batch
//! service loop ([`service`]).

pub mod estimator;
pub mod fusion;
pub mod pool;
pub mod service;

pub use estimator::{Estimator, EstimateSource, ModelEstimate, OpEstimate};
pub use fusion::estimate_fused;
pub use pool::{default_workers, parallel_map};
pub use service::{serve_lines, Request};
