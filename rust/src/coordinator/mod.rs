//! L3 coordinator: the whole-model estimator ([`estimator`]), its batched
//! structure-of-arrays core ([`batch`]), its sharded shape-keyed memo
//! cache ([`cache`]), the worker pools driving parallel sweeps and the
//! streaming service ([`pool`]), and the JSONL request loop itself
//! ([`service`]).

pub mod batch;
pub mod cache;
pub mod estimator;
pub mod fusion;
pub mod pool;
pub mod service;

pub use batch::OpTable;
pub use cache::{CacheStats, CachedCost, ModeStat, ShapeClass, ShapeKey, ShardedCache};
pub use estimator::{EstimateMode, Estimator, EstimateSource, ModelEstimate, OpEstimate};
pub use fusion::{estimate_fused, estimate_fused_with};
pub use pool::{default_workers, parallel_map, WorkerPool};
pub use service::{
    serve_lines, serve_stream, DeviceEstimators, Request, SliceRequest, StreamOptions,
    StreamSummary,
};
