//! L3 coordinator: the whole-model estimator ([`estimator`]), its batched
//! structure-of-arrays core ([`batch`]), its sharded shape-keyed memo
//! cache ([`cache`]), the worker pools driving parallel sweeps and the
//! streaming service ([`pool`]), the JSONL request loop itself
//! ([`service`]), its concurrent TCP front end ([`net`]) with warm-cache
//! persistence ([`snapshot`]), and the serve load generator
//! ([`bench_serve`]).

pub mod batch;
pub mod bench_serve;
pub mod cache;
pub mod estimator;
pub mod fusion;
pub mod net;
pub mod pool;
pub mod service;
pub mod snapshot;

pub use batch::OpTable;
pub use bench_serve::{run_bench, BenchOptions, BenchReport};
pub use cache::{
    CacheStats, CachedCost, CounterSnapshot, ModeStat, ShapeClass, ShapeKey, ShardTraffic,
    ShardedCache,
};
pub use estimator::{EstimateMode, Estimator, EstimateSource, ModelEstimate, OpEstimate};
pub use fusion::{estimate_fused, estimate_fused_with};
pub use net::{install_sigint_drain, NetOptions, NetServer, NetSummary, ShutdownHandle};
pub use pool::{default_workers, parallel_map, PoolGauges, PoolHandle, WorkerPool};
pub use service::{
    serve_lines, serve_stream, DeviceEstimators, Request, ServeMetrics, SliceRequest,
    StreamOptions, StreamSummary,
};
pub use snapshot::{load_snapshot, save_snapshot, SNAPSHOT_FORMAT, SNAPSHOT_VERSION};
