//! Fusion-aware whole-model estimation.
//!
//! The paper's motivation section cites NonGEMM-bench: non-GEMM ops cost
//! 11–74% of inference time, "and still contribute 15–48% even after
//! operator fusion" — i.e. real compilers fold elementwise epilogues into
//! the producing kernel. The plain estimator sums every op; this pass
//! models what XLA actually does before costing:
//!
//! * an elementwise / broadcast / reduction op whose input chain reaches
//!   a systolic producer (dot_general / convolution) within the fusion
//!   window is *absorbed* into that producer (zero marginal cost for
//!   compute-bound producers; epilogues ride the output stream);
//! * chains of pure elementwise ops fuse into one loop — only the first
//!   op in the chain pays the launch + memory cost;
//! * systolic ops and unfusable ops (other systolic ops, unmodeled)
//!   start new fusion groups.
//!
//! The result is a second estimate (`fused_total_us`) bracketing the real
//! latency from below, with the unfused sum bracketing from above.

use crate::frontend::classify::{classify, OpClass};
use crate::frontend::opinfo::{FuncInfo, ModuleInfo};
use crate::graph::producer_map;

use super::estimator::{Estimator, ModelEstimate};

/// Which fusion group each op landed in, plus the group roots.
#[derive(Debug, Clone)]
pub struct FusionPlan {
    /// op index -> group id.
    pub group_of: Vec<usize>,
    /// group id -> index of the op that pays the group's cost.
    pub group_root: Vec<usize>,
    /// Fusion groups the planner formed.
    pub num_groups: usize,
}

/// Build a fusion plan over the entry function.
pub fn plan(func: &FuncInfo) -> FusionPlan {
    // SSA result id -> producing op index (shared with the scheduler's
    // dependence-DAG builder in `crate::graph::dag`).
    let producer = producer_map(func);

    let classes: Vec<OpClass> = func.ops.iter().map(classify).collect();
    let mut group_of = vec![usize::MAX; func.ops.len()];
    let mut group_root: Vec<usize> = Vec::new();

    for (i, op) in func.ops.iter().enumerate() {
        let fusable_into_producer = matches!(
            classes[i],
            OpClass::Elementwise { .. } | OpClass::DataMovement { .. } | OpClass::Free
        );
        let mut assigned = None;
        if fusable_into_producer {
            // Join the group of any operand producer that is systolic or
            // elementwise (XLA loop/output fusion).
            for operand in &op.operands {
                if let Some(&p) = producer.get(operand.as_str()) {
                    let joinable = matches!(
                        classes[p],
                        OpClass::SystolicGemm { .. }
                            | OpClass::SystolicConv { .. }
                            | OpClass::Elementwise { .. }
                            | OpClass::DataMovement { .. }
                    );
                    if joinable && group_of[p] != usize::MAX {
                        assigned = Some(group_of[p]);
                        break;
                    }
                }
            }
        }
        match assigned {
            Some(g) => group_of[i] = g,
            None => {
                let g = group_root.len();
                group_root.push(i);
                group_of[i] = g;
            }
        }
    }

    FusionPlan {
        num_groups: group_root.len(),
        group_of,
        group_root,
    }
}

/// Estimate a module with fusion: each group costs the max of its
/// members' standalone costs (the fused kernel is bound by its most
/// expensive member, not the sum). Device-aware for free: the per-op
/// costs come from `est`, which answers for whatever
/// [`DeviceSpec`](crate::device::DeviceSpec) it was built or
/// [retargeted](Estimator::retarget) for.
pub fn estimate_fused(est: &Estimator, module: &ModuleInfo) -> ModelEstimate {
    let unfused = est.estimate_module(module);
    estimate_fused_with(module, unfused)
}

/// Fusion estimate from an already-computed unfused estimate — callers
/// that hold one (the serve module path computes unfused, fused and
/// scheduled from the same walk) avoid a second `estimate_module` pass
/// and the cache-counter traffic it generates.
pub fn estimate_fused_with(module: &ModuleInfo, unfused: ModelEstimate) -> ModelEstimate {
    let Some(func) = module.entry() else {
        return unfused;
    };
    if unfused.ops.len() != func.ops.len() {
        // Call-bearing modules: fusion analysis works on the flat entry
        // function only; fall back to the unfused estimate.
        return unfused;
    }
    let plan = plan(func);

    let mut group_cost = vec![0.0f64; plan.num_groups];
    let mut group_systolic = vec![false; plan.num_groups];
    for (i, op_est) in unfused.ops.iter().enumerate() {
        let g = plan.group_of[i];
        group_cost[g] = group_cost[g].max(op_est.latency_us);
        if op_est.cycles.is_some() {
            group_systolic[g] = true;
        }
    }

    let mut fused = unfused.clone();
    fused.total_us = group_cost.iter().sum();
    fused.systolic_us = group_cost
        .iter()
        .zip(&group_systolic)
        .filter(|(_, s)| **s)
        .map(|(c, _)| c)
        .sum();
    fused.elementwise_us = fused.total_us - fused.systolic_us;
    fused.other_us = 0.0;
    fused
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::calibrate::fit_regime_calibration;
    use crate::frontend::parse_module;
    use crate::scalesim::{GemmShape, ScaleConfig};

    fn estimator() -> Estimator {
        let mut obs = Vec::new();
        for d in [32usize, 64, 96, 128, 256, 512, 1024, 2048, 4096] {
            let g = GemmShape::new(d, d, d);
            obs.push((g, (d * d) as u64, (d * d) as f64 * 1e-3 + 1.0));
        }
        Estimator::new(ScaleConfig::tpu_v4(), fit_regime_calibration(&obs).unwrap())
    }

    const MLP: &str = r#"
module @m { func.func @main(%x: tensor<32x784xf32>, %w: tensor<784x512xf32>, %b: tensor<32x512xf32>) -> tensor<32x512xf32> {
  %0 = stablehlo.dot_general %x, %w, contracting_dims = [1] x [0] : (tensor<32x784xf32>, tensor<784x512xf32>) -> tensor<32x512xf32>
  %1 = stablehlo.add %0, %b : tensor<32x512xf32>
  %cst = stablehlo.constant dense<0.0> : tensor<f32>
  %2 = stablehlo.broadcast_in_dim %cst, dims = [] : (tensor<f32>) -> tensor<32x512xf32>
  %3 = stablehlo.maximum %1, %2 : tensor<32x512xf32>
  return %3 : tensor<32x512xf32>
} }"#;

    #[test]
    fn epilogue_fuses_into_gemm() {
        let module = parse_module(MLP).unwrap();
        let func = module.entry().unwrap();
        let p = plan(func);
        // dot starts group 0; add/maximum chain joins it; the broadcast
        // of the constant forms its own group (its producer is a free
        // constant) but the maximum joins the dot-rooted chain through
        // %1.
        assert_eq!(p.group_of[0], 0); // dot
        assert_eq!(p.group_of[1], 0); // add -> fused into dot group
        assert_eq!(p.group_of[4], 0); // maximum -> fused through add
        assert!(p.num_groups < func.ops.len());
    }

    #[test]
    fn fused_estimate_bounded_by_unfused() {
        let est = estimator();
        let module = parse_module(MLP).unwrap();
        let unfused = est.estimate_module(&module);
        let fused = estimate_fused(&est, &module);
        assert!(fused.total_us <= unfused.total_us + 1e-9);
        assert!(fused.total_us > 0.0);
        // The GEMM cost is preserved (it's the max of its group).
        assert!(fused.total_us >= unfused.ops[0].latency_us - 1e-9);
    }

    #[test]
    fn independent_gemms_do_not_fuse() {
        let text = r#"
module { func.func @main(%a: tensor<128x128xf32>, %b: tensor<128x128xf32>) -> tensor<128x128xf32> {
  %0 = stablehlo.dot_general %a, %b, contracting_dims = [1] x [0] : (tensor<128x128xf32>, tensor<128x128xf32>) -> tensor<128x128xf32>
  %1 = stablehlo.dot_general %0, %b, contracting_dims = [1] x [0] : (tensor<128x128xf32>, tensor<128x128xf32>) -> tensor<128x128xf32>
  return %1 : tensor<128x128xf32>
} }"#;
        let module = parse_module(text).unwrap();
        let p = plan(module.entry().unwrap());
        assert_ne!(p.group_of[0], p.group_of[1]);
        let est = estimator();
        let fused = estimate_fused(&est, &module);
        let unfused = est.estimate_module(&module);
        // Two systolic groups: no elementwise to save.
        assert!((fused.total_us - unfused.total_us).abs() < 1e-9);
    }

    #[test]
    fn elementwise_chain_collapses_to_max() {
        let text = r#"
module { func.func @main(%a: tensor<1024x1024xf32>) -> tensor<1024x1024xf32> {
  %0 = stablehlo.add %a, %a : tensor<1024x1024xf32>
  %1 = stablehlo.multiply %0, %a : tensor<1024x1024xf32>
  %2 = stablehlo.subtract %1, %a : tensor<1024x1024xf32>
  return %2 : tensor<1024x1024xf32>
} }"#;
        let module = parse_module(text).unwrap();
        let est = estimator();
        let unfused = est.estimate_module(&module);
        let fused = estimate_fused(&est, &module);
        // All three fuse into one loop: cost = max, not sum.
        let max_op = unfused
            .ops
            .iter()
            .map(|o| o.latency_us)
            .fold(0.0f64, f64::max);
        assert!((fused.total_us - max_op).abs() < 1e-9);
    }
}
