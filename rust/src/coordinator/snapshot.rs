//! Warm-cache persistence for the network service.
//!
//! `serve --cache-snapshot <path>` saves the shape cache on drain and
//! reloads it on the next startup, so a restarted server answers its
//! first requests warm instead of recomputing every shape from zero.
//!
//! The on-disk form is JSONL: one header line, then one line per cache
//! entry, sorted, so the file is deterministic for a given cache content
//! and diffs cleanly. Every 64-bit quantity (counters, cycle counts,
//! `f64` bit patterns) is stored as a hex string — JSON numbers are
//! `f64` in our parser and cannot carry a full `u64` exactly, and the
//! whole point of the snapshot is *bit-identical* warm answers and
//! counters (regression-tested in `tests/serve_net.rs`).
//!
//! The header is versioned and keyed by the serving estimator's cost-
//! model fingerprint (device spec + systolic config + HBM bandwidth).
//! A corrupt file, a version mismatch, or a fingerprint mismatch each
//! **fail loudly** ([`load_snapshot`] returns the error); the CLI logs
//! it and starts cold rather than serving stale costs. Entries keep
//! their own per-device fingerprints, so caches warmed by mixed-device
//! traffic (`"device"` request fields) restore completely.
//!
//! Saves are atomic (write to `<path>.tmp`, then rename), so a crash
//! mid-save never truncates the previous good snapshot.

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::frontend::classify::{CollectiveKind, EwKind};
use crate::frontend::types::DType;
use crate::scalesim::topology::GemmShape;
use crate::util::json::Json;

use crate::distributed::ici::IciTopology;

use super::cache::{CachedCost, CounterSnapshot, ShapeClass, ShapeKey};
use super::estimator::{EstimateSource, Estimator};

/// Magic string identifying a snapshot file.
pub const SNAPSHOT_FORMAT: &str = "scalesim-tpu-cache-snapshot";
/// Current snapshot layout version; bump on any incompatible change.
pub const SNAPSHOT_VERSION: u64 = 1;

fn hex(v: u64) -> Json {
    Json::Str(format!("{v:016x}"))
}

fn req_hex(j: &Json, key: &str) -> Result<u64> {
    let s = j.req_str(key).map_err(|e| anyhow::anyhow!("{e}"))?;
    u64::from_str_radix(s, 16).with_context(|| format!("field '{key}' is not a hex u64: '{s}'"))
}

fn hex_arr(vals: &[u64]) -> Json {
    Json::Arr(vals.iter().map(|&v| hex(v)).collect())
}

fn req_hex_arr<const N: usize>(j: &Json, key: &str) -> Result<[u64; N]> {
    let arr = j.req_arr(key).map_err(|e| anyhow::anyhow!("{e}"))?;
    if arr.len() != N {
        bail!("field '{key}' must have {N} elements, got {}", arr.len());
    }
    let mut out = [0u64; N];
    for (slot, v) in out.iter_mut().zip(arr) {
        let s = v
            .as_str()
            .ok_or_else(|| anyhow::anyhow!("field '{key}' must hold hex strings"))?;
        *slot = u64::from_str_radix(s, 16)
            .with_context(|| format!("field '{key}' holds a non-hex value '{s}'"))?;
    }
    Ok(out)
}

fn usize_field(j: &Json, key: &str) -> Result<usize> {
    j.req_usize(key).map_err(|e| anyhow::anyhow!("{e}"))
}

fn counters_to_json(c: &CounterSnapshot) -> Json {
    let mut o = Json::obj();
    o.set("hits", hex(c.hits))
        .set("misses", hex(c.misses))
        .set("sources", hex_arr(&c.sources))
        .set("mode_requests", hex_arr(&c.mode_requests))
        .set("mode_total_us_bits", hex_arr(&c.mode_total_us_bits));
    o
}

fn counters_from_json(j: &Json) -> Result<CounterSnapshot> {
    Ok(CounterSnapshot {
        hits: req_hex(j, "hits")?,
        misses: req_hex(j, "misses")?,
        sources: req_hex_arr(j, "sources")?,
        mode_requests: req_hex_arr(j, "mode_requests")?,
        mode_total_us_bits: req_hex_arr(j, "mode_total_us_bits")?,
    })
}

fn source_to_json(o: &mut Json, source: &EstimateSource) {
    o.set("source", Json::Str(source.tag().into()));
    if let EstimateSource::LearnedProxy(name) = source {
        o.set("proxy", Json::Str(name.clone()));
    }
}

fn source_from_json(j: &Json) -> Result<EstimateSource> {
    Ok(match j.req_str("source").map_err(|e| anyhow::anyhow!("{e}"))? {
        "systolic" => EstimateSource::SystolicCalibrated,
        "learned" => EstimateSource::Learned,
        "learned-proxy" => EstimateSource::LearnedProxy(
            j.req_str("proxy")
                .map_err(|e| anyhow::anyhow!("{e}"))?
                .to_string(),
        ),
        "bandwidth" => EstimateSource::Bandwidth,
        "free" => EstimateSource::Free,
        "fallback" => EstimateSource::Fallback,
        other => bail!("unknown estimate source '{other}'"),
    })
}

/// `EwKind::from_name` deliberately has no inverse for the bucket
/// variant (`name()` says "other" but many op names map *to* Other), so
/// the snapshot spells it out.
fn ew_kind_from_name(name: &str) -> Result<EwKind> {
    if name == "other" {
        return Ok(EwKind::Other);
    }
    EwKind::from_name(name).ok_or_else(|| anyhow::anyhow!("unknown elementwise kind '{name}'"))
}

fn entry_to_json(key: &ShapeKey, cost: &CachedCost) -> Json {
    let mut o = Json::obj();
    o.set("device_fp", hex(key.device));
    match &key.shape {
        ShapeClass::Gemm { gemm, count } => {
            o.set("class", Json::Str("gemm".into()))
                .set("m", Json::Num(gemm.m as f64))
                .set("k", Json::Num(gemm.k as f64))
                .set("n", Json::Num(gemm.n as f64))
                .set("count", hex(*count));
        }
        ShapeClass::Elementwise { kind, dims, dtype } => {
            o.set("class", Json::Str("elementwise".into()))
                .set("kind", Json::Str(kind.name().into()))
                .set(
                    "dims",
                    Json::Arr(dims.iter().map(|&d| Json::Num(d as f64)).collect()),
                )
                .set("dtype", Json::Str(dtype.name().into()));
        }
        ShapeClass::Collective {
            kind,
            bytes_in,
            bytes_out,
            chips,
            topology,
            link_gbps_bits,
            hop_us_bits,
        } => {
            o.set("class", Json::Str("collective".into()))
                .set("kind", Json::Str(kind.name().into()))
                .set("bytes_in", hex(*bytes_in))
                .set("bytes_out", hex(*bytes_out))
                .set("chips", Json::Num(*chips as f64))
                .set("link_gbps_bits", hex(*link_gbps_bits))
                .set("hop_us_bits", hex(*hop_us_bits));
            match topology {
                IciTopology::Ring => {
                    o.set("topology", Json::Str("ring".into()));
                }
                IciTopology::Torus2D { x, y } => {
                    o.set("topology", Json::Str("torus".into()))
                        .set("torus_x", Json::Num(*x as f64))
                        .set("torus_y", Json::Num(*y as f64));
                }
            }
        }
    }
    let mut c = Json::obj();
    source_to_json(&mut c, &cost.source);
    match cost.cycles {
        Some(cy) => c.set("cycles", hex(cy)),
        None => c.set("cycles", Json::Null),
    };
    c.set("latency_us_bits", hex(cost.latency_us.to_bits()))
        .set("note", Json::Str(cost.note.clone()));
    o.set("cost", c);
    o
}

fn entry_from_json(j: &Json) -> Result<(ShapeKey, CachedCost)> {
    let device = req_hex(j, "device_fp")?;
    let shape = match j.req_str("class").map_err(|e| anyhow::anyhow!("{e}"))? {
        "gemm" => ShapeClass::Gemm {
            gemm: GemmShape::new(
                usize_field(j, "m")?,
                usize_field(j, "k")?,
                usize_field(j, "n")?,
            ),
            count: req_hex(j, "count")?,
        },
        "elementwise" => {
            let dims = j
                .req_arr("dims")
                .map_err(|e| anyhow::anyhow!("{e}"))?
                .iter()
                .map(|d| {
                    d.as_usize()
                        .ok_or_else(|| anyhow::anyhow!("non-integer dim in snapshot entry"))
                })
                .collect::<Result<Vec<usize>>>()?;
            let dtype_name = j.req_str("dtype").map_err(|e| anyhow::anyhow!("{e}"))?;
            ShapeClass::Elementwise {
                kind: ew_kind_from_name(j.req_str("kind").map_err(|e| anyhow::anyhow!("{e}"))?)?,
                dims,
                dtype: DType::parse(dtype_name)
                    .ok_or_else(|| anyhow::anyhow!("unknown dtype '{dtype_name}'"))?,
            }
        }
        "collective" => {
            let kind_name = j.req_str("kind").map_err(|e| anyhow::anyhow!("{e}"))?;
            let topology = match j.req_str("topology").map_err(|e| anyhow::anyhow!("{e}"))? {
                "ring" => IciTopology::Ring,
                "torus" => IciTopology::Torus2D {
                    x: usize_field(j, "torus_x")?,
                    y: usize_field(j, "torus_y")?,
                },
                other => bail!("unknown topology '{other}'"),
            };
            ShapeClass::Collective {
                kind: CollectiveKind::from_name(kind_name)
                    .ok_or_else(|| anyhow::anyhow!("unknown collective kind '{kind_name}'"))?,
                bytes_in: req_hex(j, "bytes_in")?,
                bytes_out: req_hex(j, "bytes_out")?,
                chips: usize_field(j, "chips")?,
                topology,
                link_gbps_bits: req_hex(j, "link_gbps_bits")?,
                hop_us_bits: req_hex(j, "hop_us_bits")?,
            }
        }
        other => bail!("unknown entry class '{other}'"),
    };
    let c = j
        .get("cost")
        .ok_or_else(|| anyhow::anyhow!("entry missing 'cost'"))?;
    let cycles = match c.get("cycles") {
        None | Some(Json::Null) => None,
        Some(_) => Some(req_hex(c, "cycles")?),
    };
    let cost = CachedCost {
        source: source_from_json(c)?,
        cycles,
        latency_us: f64::from_bits(req_hex(c, "latency_us_bits")?),
        note: c.req_str("note").map_err(|e| anyhow::anyhow!("{e}"))?.to_string(),
    };
    Ok((ShapeKey { device, shape }, cost))
}

/// Persist `estimator`'s shape cache (entries + counters) to `path`,
/// atomically (`<path>.tmp` then rename). The header is keyed by the
/// estimator's cost-model fingerprint; entries carry their own
/// per-device fingerprints so mixed-device caches restore completely.
pub fn save_snapshot(path: &Path, estimator: &Estimator) -> Result<u64> {
    let entries = estimator.cache.export_entries();
    let mut lines: Vec<String> = entries
        .iter()
        .map(|(k, c)| entry_to_json(k, c).dump())
        .collect();
    lines.sort_unstable();

    let mut header = Json::obj();
    header
        .set("format", Json::Str(SNAPSHOT_FORMAT.into()))
        .set("version", Json::Num(SNAPSHOT_VERSION as f64))
        .set("device", Json::Str(estimator.device().name.clone()))
        .set("device_fp", hex(estimator.cache_fingerprint()))
        .set("entries", Json::Num(lines.len() as f64))
        .set(
            "counters",
            counters_to_json(&estimator.cache.counter_snapshot()),
        );

    let mut out = String::with_capacity(64 + lines.len() * 128);
    out.push_str(&header.dump());
    out.push('\n');
    for line in &lines {
        out.push_str(line);
        out.push('\n');
    }

    let tmp = path.with_extension("tmp");
    std::fs::write(&tmp, out)
        .with_context(|| format!("writing cache snapshot to {}", tmp.display()))?;
    std::fs::rename(&tmp, path)
        .with_context(|| format!("publishing cache snapshot at {}", path.display()))?;
    Ok(lines.len() as u64)
}

/// Load a snapshot previously written by [`save_snapshot`] into
/// `estimator`'s (freshly built) cache, restoring entries *and*
/// counters, and return the entry count.
///
/// Fails loudly — corrupt file, wrong [`SNAPSHOT_VERSION`], or a
/// cost-model fingerprint that does not match `estimator` — instead of
/// silently serving stale costs; the caller logs the error and starts
/// cold.
pub fn load_snapshot(path: &Path, estimator: &Estimator) -> Result<u64> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading cache snapshot {}", path.display()))?;
    let mut lines = text.lines();
    let header_line = lines
        .next()
        .ok_or_else(|| anyhow::anyhow!("snapshot {} is empty", path.display()))?;
    let header = Json::parse(header_line)
        .map_err(|e| anyhow::anyhow!("snapshot {} header is not JSON: {e}", path.display()))?;
    let format = header.req_str("format").map_err(|e| anyhow::anyhow!("{e}"))?;
    if format != SNAPSHOT_FORMAT {
        bail!(
            "snapshot {}: unrecognised format '{format}' (want '{SNAPSHOT_FORMAT}')",
            path.display()
        );
    }
    let version = header.req_f64("version").map_err(|e| anyhow::anyhow!("{e}"))?;
    if version != SNAPSHOT_VERSION as f64 {
        bail!(
            "snapshot {}: version {version} is not supported (this build reads version {SNAPSHOT_VERSION})",
            path.display()
        );
    }
    let fp = req_hex(&header, "device_fp")?;
    if fp != estimator.cache_fingerprint() {
        bail!(
            "snapshot {}: cost-model fingerprint {fp:016x} does not match this server's {:016x} \
             (device '{}'); refusing stale costs",
            path.display(),
            estimator.cache_fingerprint(),
            estimator.device().name,
        );
    }
    let declared = header.req_f64("entries").map_err(|e| anyhow::anyhow!("{e}"))? as u64;
    let counters = counters_from_json(
        header
            .get("counters")
            .ok_or_else(|| anyhow::anyhow!("snapshot {} header lacks counters", path.display()))?,
    )?;

    let mut loaded: Vec<(ShapeKey, CachedCost)> = Vec::new();
    for (i, line) in lines.enumerate() {
        if line.trim().is_empty() {
            continue;
        }
        let j = Json::parse(line).map_err(|e| {
            anyhow::anyhow!("snapshot {} line {}: not JSON: {e}", path.display(), i + 2)
        })?;
        let entry = entry_from_json(&j)
            .with_context(|| format!("snapshot {} line {}", path.display(), i + 2))?;
        loaded.push(entry);
    }
    if loaded.len() as u64 != declared {
        bail!(
            "snapshot {}: header declares {declared} entries but file holds {} (truncated?)",
            path.display(),
            loaded.len()
        );
    }
    estimator.cache.store_grouped(loaded);
    estimator.cache.restore_counters(&counters);
    Ok(declared)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::DeviceSpec;
    use crate::sweep::sweep_estimator;

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("scalesim_tpu_snapshot_unit");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn entry_round_trip_all_classes() {
        let cost = CachedCost {
            source: EstimateSource::LearnedProxy("add".into()),
            cycles: Some(u64::MAX - 3), // not representable as f64
            latency_us: 0.1 + 0.2,      // bit pattern must survive
            note: "\"quoted\" note\n".into(),
        };
        let keys = [
            ShapeKey {
                device: 0xdead_beef_0102_0304,
                shape: ShapeClass::Gemm {
                    gemm: GemmShape::new(128, 256, 512),
                    count: 7,
                },
            },
            ShapeKey {
                device: 1,
                shape: ShapeClass::Elementwise {
                    kind: EwKind::Other,
                    dims: vec![3, 5, 7],
                    dtype: DType::U16,
                },
            },
            ShapeKey {
                device: 2,
                shape: ShapeClass::Collective {
                    kind: CollectiveKind::ReduceScatter,
                    bytes_in: 1 << 40,
                    bytes_out: 12345,
                    chips: 16,
                    topology: IciTopology::Torus2D { x: 4, y: 4 },
                    link_gbps_bits: 100.0f64.to_bits(),
                    hop_us_bits: 1.5f64.to_bits(),
                },
            },
        ];
        for key in keys {
            let line = entry_to_json(&key, &cost).dump();
            let (k2, c2) = entry_from_json(&Json::parse(&line).unwrap()).unwrap();
            assert_eq!(k2, key);
            assert_eq!(c2.cycles, cost.cycles);
            assert_eq!(c2.latency_us.to_bits(), cost.latency_us.to_bits());
            assert_eq!(c2.note, cost.note);
            assert_eq!(c2.source, cost.source);
        }
    }

    #[test]
    fn save_load_round_trip_and_loud_failures() {
        let est = sweep_estimator(&DeviceSpec::tpu_v4());
        // Warm the cache through the public request path.
        use crate::coordinator::service::serve_lines;
        use std::sync::Arc;
        let est = Arc::new(est);
        serve_lines(
            Arc::clone(&est),
            &[
                r#"{"type":"gemm","m":64,"k":64,"n":64}"#.into(),
                r#"{"type":"gemm","m":64,"k":64,"n":64}"#.into(),
                r#"{"type":"elementwise","op":"add","dims":[256,256]}"#.into(),
            ],
            2,
        );
        let path = tmp("round_trip.jsonl");
        let n = save_snapshot(&path, &est).unwrap();
        assert_eq!(n, est.cache.len() as u64);

        let fresh = sweep_estimator(&DeviceSpec::tpu_v4());
        assert_eq!(load_snapshot(&path, &fresh).unwrap(), n);
        assert_eq!(fresh.cache.stats(), est.cache.stats());

        // Wrong device fingerprint → loud failure.
        let v5e = sweep_estimator(&DeviceSpec::tpu_v5e());
        let err = load_snapshot(&path, &v5e).unwrap_err().to_string();
        assert!(err.contains("fingerprint"), "{err}");

        // Wrong version → loud failure.
        let vpath = tmp("bad_version.jsonl");
        let text = std::fs::read_to_string(&path).unwrap();
        std::fs::write(&vpath, text.replacen("\"version\":1", "\"version\":999", 1)).unwrap();
        let fresh2 = sweep_estimator(&DeviceSpec::tpu_v4());
        let err = load_snapshot(&vpath, &fresh2).unwrap_err().to_string();
        assert!(err.contains("version"), "{err}");
        assert!(fresh2.cache.is_empty(), "failed load must leave cache cold");

        // Corrupt / truncated → loud failure.
        let cpath = tmp("corrupt.jsonl");
        std::fs::write(&cpath, "not json\n").unwrap();
        assert!(load_snapshot(&cpath, &fresh2).is_err());
        let tpath = tmp("truncated.jsonl");
        let full = std::fs::read_to_string(&path).unwrap();
        let mut truncated: Vec<&str> = full.lines().collect();
        truncated.pop();
        std::fs::write(&tpath, truncated.join("\n")).unwrap();
        let err = load_snapshot(&tpath, &fresh2).unwrap_err().to_string();
        assert!(err.contains("declares"), "{err}");
    }

    #[test]
    fn snapshot_bytes_are_deterministic() {
        let est = sweep_estimator(&DeviceSpec::tpu_v4());
        use crate::coordinator::service::serve_lines;
        use std::sync::Arc;
        let est = Arc::new(est);
        serve_lines(
            Arc::clone(&est),
            &[
                r#"{"type":"gemm","m":32,"k":32,"n":32}"#.into(),
                r#"{"type":"gemm","m":48,"k":48,"n":48}"#.into(),
            ],
            2,
        );
        let (p1, p2) = (tmp("det_a.jsonl"), tmp("det_b.jsonl"));
        save_snapshot(&p1, &est).unwrap();
        save_snapshot(&p2, &est).unwrap();
        assert_eq!(
            std::fs::read(&p1).unwrap(),
            std::fs::read(&p2).unwrap(),
            "snapshot bytes must be deterministic"
        );
    }
}
