//! Sharded, shape-keyed memo cache for operator cost estimates.
//!
//! Downstream compiler tooling queries the service with heavy shape
//! repetition — many models share layer dimensions — so the estimator
//! memoises per-op results keyed by (device fingerprint, op class,
//! shape, dtype). The map is
//! striped over N mutex-guarded shards (the key hash picks the shard) so
//! concurrent workers rarely contend on the same lock, and hit/miss plus
//! per-source counters are lock-free atomics. Cached and uncached
//! estimates are bit-identical: every input of the cost functions is part
//! of [`ShapeKey`]. Measurements live in EXPERIMENTS.md §Perf Cache.

use std::collections::hash_map::DefaultHasher;
use std::collections::HashMap;
use std::hash::{Hash, Hasher};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Mutex, MutexGuard, TryLockError};

use crate::distributed::ici::{IciTopology, SliceConfig};
use crate::frontend::classify::{CollectiveKind, EwKind, OpClass};
use crate::frontend::types::DType;
use crate::scalesim::topology::GemmShape;
use crate::util::json::Json;

use super::estimator::{EstimateMode, EstimateSource, OpEstimate};

/// Default stripe count: enough shards that the default worker pool (up
/// to 16 threads) rarely collides on one lock.
pub const DEFAULT_SHARDS: usize = 16;

/// The identity of an op's cost: which cost model it was computed
/// against (the estimator's cache fingerprint — its
/// [`DeviceSpec::fingerprint`](crate::device::DeviceSpec::fingerprint)
/// mixed with the active systolic config and HBM bandwidth) plus the
/// device-independent [`ShapeClass`].
///
/// The fingerprint is part of the key so estimators retargeted onto
/// different [`DeviceSpec`](crate::device::DeviceSpec)s can share one
/// cache — a serve stream mixing `"device"` fields must never alias
/// entries for the same shape (regression-tested in
/// `tests/device_spec.rs`).
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct ShapeKey {
    /// Fingerprint of the cost model the entry was computed against.
    pub device: u64,
    /// The device-independent shape identity.
    pub shape: ShapeClass,
}

/// The shape-level identity of an op's cost.
///
/// Everything the estimator's cost functions read — besides the device
/// spec, which the wrapping [`ShapeKey`] carries — is captured here, so
/// an entry is valid for any op instance with the same class/shape/dtype
/// regardless of its position or SSA name in the module.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub enum ShapeClass {
    /// Systolic GEMM (dot_general, or convolution after im2col lowering).
    Gemm {
        /// The GEMM dimensions.
        gemm: GemmShape,
        /// Sequential repetitions (batch count).
        count: u64,
    },
    /// Elementwise op over an output tensor.
    Elementwise {
        /// The operator kind.
        kind: EwKind,
        /// Output dimensions.
        dims: Vec<usize>,
        /// Output element type.
        dtype: DType,
    },
    /// An ICI collective on a multi-chip slice. The full slice config is
    /// part of the key so requests against different slices — or the
    /// single-chip path, which never produces this variant — can never
    /// alias, even for identical payloads.
    Collective {
        /// The collective kind.
        kind: CollectiveKind,
        /// Input payload bytes per chip.
        bytes_in: u64,
        /// Output payload bytes.
        bytes_out: u64,
        /// Chips in the slice.
        chips: usize,
        /// Slice topology.
        topology: IciTopology,
        /// Bit patterns of the slice's f64 knobs (exact identity).
        link_gbps_bits: u64,
        /// Bit pattern of the per-hop latency (exact identity).
        hop_us_bits: u64,
    },
}

impl ShapeKey {
    /// The cache identity of one collective on one slice, on the device
    /// with fingerprint `device`.
    pub fn collective(
        device: u64,
        kind: CollectiveKind,
        bytes_in: u64,
        bytes_out: u64,
        slice: &SliceConfig,
    ) -> ShapeKey {
        ShapeKey {
            device,
            shape: ShapeClass::Collective {
                kind,
                bytes_in,
                bytes_out,
                chips: slice.chips,
                topology: slice.topology,
                link_gbps_bits: slice.link_gbps.to_bits(),
                hop_us_bits: slice.hop_latency_us.to_bits(),
            },
        }
    }

    /// The cacheable identity of a classified op on the device with
    /// fingerprint `device`, if it has one. The bandwidth/free classes
    /// are a handful of arithmetic ops — cheaper than the map probe they
    /// would save.
    pub fn of_class(device: u64, class: &OpClass) -> Option<ShapeKey> {
        ShapeClass::of_class(class).map(|shape| ShapeKey { device, shape })
    }
}

impl ShapeClass {
    /// The device-independent identity of a classified op, if it has one.
    pub fn of_class(class: &OpClass) -> Option<ShapeClass> {
        match class {
            OpClass::SystolicGemm { gemm, count }
            | OpClass::SystolicConv { gemm, count, .. } => Some(ShapeClass::Gemm {
                gemm: *gemm,
                count: *count,
            }),
            OpClass::Elementwise { kind, out } => Some(ShapeClass::Elementwise {
                kind: *kind,
                dims: out.dims.clone(),
                dtype: out.dtype,
            }),
            _ => None,
        }
    }
}

/// The cached cost of one shape: every [`OpEstimate`] field that does not
/// depend on the op's position in its module.
#[derive(Debug, Clone)]
pub struct CachedCost {
    /// Which cost model produced the entry.
    pub source: EstimateSource,
    /// Simulated cycles (systolic entries only).
    pub cycles: Option<u64>,
    /// Estimated latency, µs.
    pub latency_us: f64,
    /// Human-readable shape/context note.
    pub note: String,
}

impl CachedCost {
    /// Strip an estimate row down to its cacheable fields.
    pub fn of(est: &OpEstimate) -> CachedCost {
        CachedCost {
            source: est.source.clone(),
            cycles: est.cycles,
            latency_us: est.latency_us,
            note: est.note.clone(),
        }
    }

    /// Rehydrate a full estimate row for a concrete op instance.
    pub fn into_estimate(self, index: usize, op_name: &str) -> OpEstimate {
        OpEstimate {
            index,
            op_name: op_name.to_string(),
            source: self.source,
            cycles: self.cycles,
            latency_us: self.latency_us,
            note: self.note,
        }
    }
}

/// Per-estimation-mode accounting: how many whole-module answers were
/// served in one mode, and the total estimated time they reported.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct ModeStat {
    /// Module requests answered in this mode.
    pub requests: u64,
    /// Accumulated estimated time across those requests, µs.
    pub total_us: f64,
}

/// A monotonic snapshot of the cache and routing counters.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct CacheStats {
    /// Lookups answered from the cache.
    pub hits: u64,
    /// Lookups that computed fresh.
    pub misses: u64,
    /// Entries currently resident.
    pub entries: u64,
    /// Ops routed to the calibrated systolic model.
    pub systolic: u64,
    /// Ops answered by their own learned model.
    pub learned: u64,
    /// Ops answered by a proxy learned model.
    pub learned_proxy: u64,
    /// Ops costed by the analytic bandwidth model.
    pub bandwidth: u64,
    /// Zero-cost ops.
    pub free: u64,
    /// Ops with no model (conservative fallback).
    pub fallback: u64,
    /// Indexed like [`EstimateMode::ALL`]: unfused, fused, scheduled.
    pub modes: [ModeStat; 3],
}

impl CacheStats {
    /// Hits over lookups, in [0, 1].
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }

    /// Serialise for the service's `{"type":"stats"}` response.
    pub fn to_json(&self) -> Json {
        let mut sources = Json::obj();
        sources
            .set("systolic", Json::Num(self.systolic as f64))
            .set("learned", Json::Num(self.learned as f64))
            .set("learned-proxy", Json::Num(self.learned_proxy as f64))
            .set("bandwidth", Json::Num(self.bandwidth as f64))
            .set("free", Json::Num(self.free as f64))
            .set("fallback", Json::Num(self.fallback as f64));
        let mut modes = Json::obj();
        for (mode, stat) in EstimateMode::ALL.iter().zip(&self.modes) {
            let mut m = Json::obj();
            m.set("requests", Json::Num(stat.requests as f64))
                .set("total_us", Json::Num(stat.total_us));
            modes.set(mode.name(), m);
        }
        let mut o = Json::obj();
        o.set("cache_hits", Json::Num(self.hits as f64))
            .set("cache_misses", Json::Num(self.misses as f64))
            .set("cache_entries", Json::Num(self.entries as f64))
            .set("hit_rate", Json::Num(self.hit_rate()))
            .set("sources", sources)
            .set("modes", modes);
        o
    }
}

/// A bit-exact copy of the cache's monotonic counters, as persisted by
/// the warm snapshot ([`super::snapshot`]) and restored on startup so a
/// restarted server's `{"type":"stats"}` answers are indistinguishable
/// from a continuously-warm one.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct CounterSnapshot {
    /// Lookups answered from the cache.
    pub hits: u64,
    /// Lookups that computed fresh.
    pub misses: u64,
    /// Per-source routing counters, indexed like [`CacheStats`]:
    /// systolic, learned, learned-proxy, bandwidth, free, fallback.
    pub sources: [u64; 6],
    /// Whole-module answer counts, indexed like `EstimateMode::ALL`.
    pub mode_requests: [u64; 3],
    /// Accumulated per-mode time as raw `f64` bit patterns (exact).
    pub mode_total_us_bits: [u64; 3],
}

/// Index of a source in the per-source counter array (and in the
/// `[u64; 6]` batches [`ShardedCache::record_sources`] takes): systolic,
/// learned, learned-proxy, bandwidth, free, fallback.
pub(crate) fn source_index(src: &EstimateSource) -> usize {
    match src {
        EstimateSource::SystolicCalibrated => 0,
        EstimateSource::Learned => 1,
        EstimateSource::LearnedProxy(_) => 2,
        EstimateSource::Bandwidth => 3,
        EstimateSource::Free => 4,
        EstimateSource::Fallback => 5,
    }
}

/// Per-shard traffic counters, exposed to the observability layer as
/// `scalesim_cache_shard_*` metric families.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct ShardTraffic {
    /// Probes this shard answered from its map.
    pub hits: u64,
    /// Probes this shard could not answer. Grouped (batched) probes
    /// count each *unique* shape once, matching the actual map traffic.
    pub misses: u64,
    /// Lock acquisitions that found the shard's mutex already held.
    pub contended: u64,
}

/// One shard's lock-free counters (next to, not under, its mutex).
#[derive(Debug, Default)]
struct ShardCounters {
    hits: AtomicU64,
    misses: AtomicU64,
    contended: AtomicU64,
}

/// The mutex-striped shape cache itself.
pub struct ShardedCache {
    shards: Vec<Mutex<HashMap<ShapeKey, CachedCost>>>,
    /// Per-shard traffic counters, indexed like `shards`.
    shard_stats: Vec<ShardCounters>,
    enabled: AtomicBool,
    hits: AtomicU64,
    misses: AtomicU64,
    /// Indexed by [`source_index`]: systolic, learned, learned-proxy,
    /// bandwidth, free, fallback.
    sources: [AtomicU64; 6],
    /// Indexed like [`EstimateMode::ALL`]: whole-module answer counts.
    mode_requests: [AtomicU64; 3],
    /// Indexed like [`EstimateMode::ALL`]: accumulated estimated time
    /// per mode, stored as `f64` bit patterns.
    mode_total_us: [AtomicU64; 3],
}

impl ShardedCache {
    /// A cache with the default 16 shards.
    pub fn new() -> ShardedCache {
        ShardedCache::with_shards(DEFAULT_SHARDS)
    }

    /// A cache with `n` mutex-striped shards (rounded up to 1).
    pub fn with_shards(n: usize) -> ShardedCache {
        let n = n.max(1);
        ShardedCache {
            shards: (0..n).map(|_| Mutex::new(HashMap::new())).collect(),
            shard_stats: (0..n).map(|_| ShardCounters::default()).collect(),
            enabled: AtomicBool::new(true),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            sources: Default::default(),
            mode_requests: Default::default(),
            mode_total_us: Default::default(),
        }
    }

    /// Turn memoisation on/off (off = every lookup misses silently; used
    /// by the uncached baseline in benches and tests).
    pub fn set_enabled(&self, on: bool) {
        self.enabled.store(on, Ordering::Relaxed);
    }

    /// Is memoisation currently on?
    pub fn is_enabled(&self) -> bool {
        self.enabled.load(Ordering::Relaxed)
    }

    fn shard_of(&self, key: &ShapeKey) -> usize {
        let mut h = DefaultHasher::new();
        key.hash(&mut h);
        (h.finish() as usize) % self.shards.len()
    }

    /// Lock shard `i`, counting the acquisition as contended if the
    /// mutex was already held (a cheap `try_lock` probe; the slow path
    /// then blocks normally).
    fn lock_shard(&self, i: usize) -> MutexGuard<'_, HashMap<ShapeKey, CachedCost>> {
        match self.shards[i].try_lock() {
            Ok(guard) => guard,
            Err(TryLockError::WouldBlock) => {
                self.shard_stats[i].contended.fetch_add(1, Ordering::Relaxed);
                self.shards[i].lock().unwrap()
            }
            Err(TryLockError::Poisoned(_)) => self.shards[i].lock().unwrap(),
        }
    }

    fn record_shard_probe(&self, i: usize, hit: bool) {
        if hit {
            self.shard_stats[i].hits.fetch_add(1, Ordering::Relaxed);
        } else {
            self.shard_stats[i].misses.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Probe the cache, counting a hit or a miss.
    pub fn lookup(&self, key: &ShapeKey) -> Option<CachedCost> {
        if !self.is_enabled() {
            return None;
        }
        let shard = self.shard_of(key);
        let got = self.lock_shard(shard).get(key).cloned();
        self.record_shard_probe(shard, got.is_some());
        if got.is_some() {
            self.hits.fetch_add(1, Ordering::Relaxed);
        } else {
            self.misses.fetch_add(1, Ordering::Relaxed);
        }
        got
    }

    /// Probe without counting a hit or a miss anywhere: the
    /// observability layer's pre-flight "is this shape warm?" check,
    /// which must not perturb the hit/miss totals the stats responses
    /// and the batched path account exactly.
    pub fn peek(&self, key: &ShapeKey) -> bool {
        if !self.is_enabled() {
            return false;
        }
        let shard = self.shard_of(key);
        self.lock_shard(shard).contains_key(key)
    }

    /// Store a computed cost. Two workers racing on the same fresh key
    /// both compute and both store — the values are identical because the
    /// cost functions are deterministic in the key.
    pub fn store(&self, key: ShapeKey, cost: CachedCost) {
        if !self.is_enabled() {
            return;
        }
        let shard = self.shard_of(&key);
        self.lock_shard(shard).insert(key, cost);
    }

    /// Probe a batch of keys with one lock acquisition per *touched
    /// shard* instead of one per key — the grouped half of the batched
    /// estimator core (see [`super::batch`]).
    ///
    /// Unlike [`ShardedCache::lookup`] this does **not** touch the
    /// hit/miss counters: the batched path probes each *unique* shape
    /// once and then accounts all its occurrences in one shot through
    /// [`ShardedCache::record_lookups`], so the totals match the per-op
    /// scalar walk exactly. Returns all-`None` (still without counting)
    /// when memoisation is disabled.
    pub fn lookup_grouped(&self, keys: &[ShapeKey]) -> Vec<Option<CachedCost>> {
        let mut out: Vec<Option<CachedCost>> = vec![None; keys.len()];
        if !self.is_enabled() || keys.is_empty() {
            return out;
        }
        let mut by_shard: Vec<Vec<usize>> = vec![Vec::new(); self.shards.len()];
        for (i, key) in keys.iter().enumerate() {
            by_shard[self.shard_of(key)].push(i);
        }
        for (s, idxs) in by_shard.iter().enumerate() {
            if idxs.is_empty() {
                continue;
            }
            let map = self.lock_shard(s);
            for &i in idxs {
                out[i] = map.get(&keys[i]).cloned();
            }
            drop(map);
            for &i in idxs {
                self.record_shard_probe(s, out[i].is_some());
            }
        }
        out
    }

    /// Store a batch of freshly computed costs with one lock acquisition
    /// per touched shard. No-op when memoisation is disabled (mirroring
    /// [`ShardedCache::store`]).
    pub fn store_grouped(&self, items: Vec<(ShapeKey, CachedCost)>) {
        if !self.is_enabled() || items.is_empty() {
            return;
        }
        let mut by_shard: Vec<Vec<(ShapeKey, CachedCost)>> =
            (0..self.shards.len()).map(|_| Vec::new()).collect();
        for (key, cost) in items {
            let shard = self.shard_of(&key);
            by_shard[shard].push((key, cost));
        }
        for (s, group) in by_shard.into_iter().enumerate() {
            if group.is_empty() {
                continue;
            }
            let mut map = self.lock_shard(s);
            for (key, cost) in group {
                map.insert(key, cost);
            }
        }
    }

    /// Bulk hit/miss accounting for a grouped probe: two `fetch_add`s
    /// for a whole batch instead of one per op.
    pub fn record_lookups(&self, hits: u64, misses: u64) {
        if hits > 0 {
            self.hits.fetch_add(hits, Ordering::Relaxed);
        }
        if misses > 0 {
            self.misses.fetch_add(misses, Ordering::Relaxed);
        }
    }

    /// Count which model answered an op (hit or miss).
    pub fn record_source(&self, src: &EstimateSource) {
        self.sources[source_index(src)].fetch_add(1, Ordering::Relaxed);
    }

    /// Bulk per-source accounting (indexed systolic, learned,
    /// learned-proxy, bandwidth, free, fallback — the [`CacheStats`]
    /// order): six `fetch_add`s for a whole batch instead of one per op.
    pub fn record_sources(&self, counts: &[u64; 6]) {
        for (cell, &n) in self.sources.iter().zip(counts) {
            if n > 0 {
                cell.fetch_add(n, Ordering::Relaxed);
            }
        }
    }

    /// Account one whole-module answer under its estimation mode, so
    /// service traffic is attributable per mode (unfused / fused /
    /// scheduled) in `{"type":"stats"}` responses and the shutdown
    /// summary.
    pub fn record_mode(&self, mode: EstimateMode, total_us: f64) {
        let i = mode as usize;
        self.mode_requests[i].fetch_add(1, Ordering::Relaxed);
        // f64 accumulation over an AtomicU64 bit pattern (no AtomicF64
        // in std): a plain CAS loop — contention here is a handful of
        // module requests, not the per-op hot path.
        let cell = &self.mode_total_us[i];
        let mut cur = cell.load(Ordering::Relaxed);
        loop {
            let next = (f64::from_bits(cur) + total_us).to_bits();
            match cell.compare_exchange_weak(cur, next, Ordering::Relaxed, Ordering::Relaxed)
            {
                Ok(_) => break,
                Err(seen) => cur = seen,
            }
        }
    }

    /// Total entries across all shards.
    pub fn len(&self) -> usize {
        self.shards.iter().map(|s| s.lock().unwrap().len()).sum()
    }

    /// True when no entry is cached.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Drop all entries (counters are kept; they are monotonic).
    pub fn clear(&self) {
        for s in &self.shards {
            s.lock().unwrap().clear();
        }
    }

    /// Every resident entry, for persistence ([`super::snapshot`]).
    /// Order is shard-major and therefore stable for a given content
    /// set; snapshot files sort entries again before writing so the
    /// on-disk form is fully deterministic.
    pub fn export_entries(&self) -> Vec<(ShapeKey, CachedCost)> {
        let mut out = Vec::with_capacity(self.len());
        for s in &self.shards {
            let map = s.lock().unwrap();
            out.extend(map.iter().map(|(k, v)| (k.clone(), v.clone())));
        }
        out
    }

    /// A raw copy of every monotonic counter, exact to the bit (mode
    /// totals stay in their `f64` bit-pattern form). Used by the warm
    /// snapshot so a restarted server reports hit/miss/source/mode
    /// counters identical to one that never went down.
    pub fn counter_snapshot(&self) -> CounterSnapshot {
        let load = |cell: &AtomicU64| cell.load(Ordering::Relaxed);
        CounterSnapshot {
            hits: load(&self.hits),
            misses: load(&self.misses),
            sources: [
                load(&self.sources[0]),
                load(&self.sources[1]),
                load(&self.sources[2]),
                load(&self.sources[3]),
                load(&self.sources[4]),
                load(&self.sources[5]),
            ],
            mode_requests: [
                load(&self.mode_requests[0]),
                load(&self.mode_requests[1]),
                load(&self.mode_requests[2]),
            ],
            mode_total_us_bits: [
                load(&self.mode_total_us[0]),
                load(&self.mode_total_us[1]),
                load(&self.mode_total_us[2]),
            ],
        }
    }

    /// Overwrite every counter from a [`CounterSnapshot`]. Only sane on
    /// a freshly built cache (snapshot load happens before the listener
    /// accepts its first connection); concurrent traffic would be lost.
    pub fn restore_counters(&self, snap: &CounterSnapshot) {
        self.hits.store(snap.hits, Ordering::Relaxed);
        self.misses.store(snap.misses, Ordering::Relaxed);
        for (cell, &v) in self.sources.iter().zip(&snap.sources) {
            cell.store(v, Ordering::Relaxed);
        }
        for (cell, &v) in self.mode_requests.iter().zip(&snap.mode_requests) {
            cell.store(v, Ordering::Relaxed);
        }
        for (cell, &v) in self.mode_total_us.iter().zip(&snap.mode_total_us_bits) {
            cell.store(v, Ordering::Relaxed);
        }
    }

    /// Per-shard traffic counters in shard order, for the
    /// `scalesim_cache_shard_{hits,misses,contended}_total` metric
    /// families. Independent of the global hit/miss totals: grouped
    /// probes count per unique shape here but per occurrence there.
    pub fn shard_traffic(&self) -> Vec<ShardTraffic> {
        self.shard_stats
            .iter()
            .map(|s| ShardTraffic {
                hits: s.hits.load(Ordering::Relaxed),
                misses: s.misses.load(Ordering::Relaxed),
                contended: s.contended.load(Ordering::Relaxed),
            })
            .collect()
    }

    /// Snapshot of every counter (entries counted live).
    pub fn stats(&self) -> CacheStats {
        let mut modes = [ModeStat::default(); 3];
        for (i, slot) in modes.iter_mut().enumerate() {
            slot.requests = self.mode_requests[i].load(Ordering::Relaxed);
            slot.total_us = f64::from_bits(self.mode_total_us[i].load(Ordering::Relaxed));
        }
        CacheStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            entries: self.len() as u64,
            systolic: self.sources[0].load(Ordering::Relaxed),
            learned: self.sources[1].load(Ordering::Relaxed),
            learned_proxy: self.sources[2].load(Ordering::Relaxed),
            bandwidth: self.sources[3].load(Ordering::Relaxed),
            free: self.sources[4].load(Ordering::Relaxed),
            fallback: self.sources[5].load(Ordering::Relaxed),
            modes,
        }
    }
}

impl Default for ShardedCache {
    fn default() -> Self {
        ShardedCache::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn gemm_key(d: usize) -> ShapeKey {
        gemm_key_on(0, d)
    }

    fn gemm_key_on(device: u64, d: usize) -> ShapeKey {
        ShapeKey {
            device,
            shape: ShapeClass::Gemm {
                gemm: GemmShape::new(d, d, d),
                count: 1,
            },
        }
    }

    fn cost(us: f64) -> CachedCost {
        CachedCost {
            source: EstimateSource::SystolicCalibrated,
            cycles: Some(42),
            latency_us: us,
            note: "t".into(),
        }
    }

    #[test]
    fn hit_miss_accounting() {
        let c = ShardedCache::with_shards(4);
        assert!(c.lookup(&gemm_key(64)).is_none());
        c.store(gemm_key(64), cost(1.5));
        let hit = c.lookup(&gemm_key(64)).expect("hit");
        assert_eq!(hit.latency_us, 1.5);
        assert!(c.lookup(&gemm_key(128)).is_none());
        let s = c.stats();
        assert_eq!((s.hits, s.misses, s.entries), (1, 2, 1));
        assert!((s.hit_rate() - 1.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn disabled_cache_never_hits_or_counts() {
        let c = ShardedCache::new();
        c.set_enabled(false);
        c.store(gemm_key(64), cost(1.0));
        assert!(c.lookup(&gemm_key(64)).is_none());
        let s = c.stats();
        assert_eq!((s.hits, s.misses, s.entries), (0, 0, 0));
        c.set_enabled(true);
        c.store(gemm_key(64), cost(1.0));
        assert!(c.lookup(&gemm_key(64)).is_some());
    }

    #[test]
    fn distinct_keys_do_not_collide() {
        let c = ShardedCache::with_shards(2);
        for d in [8usize, 16, 32, 64, 128, 256] {
            c.store(gemm_key(d), cost(d as f64));
        }
        assert_eq!(c.len(), 6);
        for d in [8usize, 16, 32, 64, 128, 256] {
            assert_eq!(c.lookup(&gemm_key(d)).unwrap().latency_us, d as f64);
        }
        // Same dims, different count → different key.
        let k2 = ShapeKey {
            device: 0,
            shape: ShapeClass::Gemm {
                gemm: GemmShape::new(8, 8, 8),
                count: 2,
            },
        };
        assert!(c.lookup(&k2).is_none());
    }

    #[test]
    fn same_shape_on_different_devices_does_not_alias() {
        // The regression behind the device refactor: one shared cache
        // serving estimators for several devices must keep their entries
        // apart even for identical shapes.
        let c = ShardedCache::new();
        c.store(gemm_key_on(1, 64), cost(1.0));
        c.store(gemm_key_on(2, 64), cost(2.0));
        assert_eq!(c.len(), 2);
        assert_eq!(c.lookup(&gemm_key_on(1, 64)).unwrap().latency_us, 1.0);
        assert_eq!(c.lookup(&gemm_key_on(2, 64)).unwrap().latency_us, 2.0);
        assert!(c.lookup(&gemm_key_on(3, 64)).is_none());
    }

    #[test]
    fn elementwise_keys_include_dtype() {
        let a = ShapeKey {
            device: 0,
            shape: ShapeClass::Elementwise {
                kind: EwKind::Add,
                dims: vec![128, 128],
                dtype: DType::Bf16,
            },
        };
        let b = ShapeKey {
            device: 0,
            shape: ShapeClass::Elementwise {
                kind: EwKind::Add,
                dims: vec![128, 128],
                dtype: DType::F32,
            },
        };
        assert_ne!(a, b);
        let c = ShardedCache::new();
        c.store(a.clone(), cost(1.0));
        assert!(c.lookup(&b).is_none());
        assert!(c.lookup(&a).is_some());
    }

    #[test]
    fn collective_keys_carry_the_slice_config() {
        let slice4 = SliceConfig::ring(4, 100.0);
        let a = ShapeKey::collective(0, CollectiveKind::AllReduce, 1 << 20, 1 << 20, &slice4);
        // Different chip count, bandwidth, hop latency, topology or
        // device each produce a distinct key.
        let slice8 = SliceConfig::ring(8, 100.0);
        assert_ne!(
            a,
            ShapeKey::collective(0, CollectiveKind::AllReduce, 1 << 20, 1 << 20, &slice8)
        );
        let fat = SliceConfig::ring(4, 200.0);
        assert_ne!(
            a,
            ShapeKey::collective(0, CollectiveKind::AllReduce, 1 << 20, 1 << 20, &fat)
        );
        let torus = SliceConfig {
            chips: 4,
            topology: IciTopology::Torus2D { x: 2, y: 2 },
            link_gbps: 100.0,
            hop_latency_us: 1.0,
        };
        assert_ne!(
            a,
            ShapeKey::collective(0, CollectiveKind::AllReduce, 1 << 20, 1 << 20, &torus)
        );
        assert_ne!(
            a,
            ShapeKey::collective(7, CollectiveKind::AllReduce, 1 << 20, 1 << 20, &slice4)
        );
        // And collective entries never collide with plain gemm entries.
        let c = ShardedCache::new();
        c.store(a.clone(), cost(7.0));
        assert!(c.lookup(&gemm_key(64)).is_none());
        assert_eq!(c.lookup(&a).unwrap().latency_us, 7.0);
    }

    #[test]
    fn grouped_lookup_matches_scalar_probes_without_counting() {
        let c = ShardedCache::with_shards(4);
        c.store(gemm_key(64), cost(1.0));
        c.store(gemm_key(256), cost(2.0));
        let keys: Vec<ShapeKey> = [64usize, 128, 256, 512].iter().map(|&d| gemm_key(d)).collect();
        let got = c.lookup_grouped(&keys);
        assert_eq!(got.len(), 4);
        assert_eq!(got[0].as_ref().map(|h| h.latency_us), Some(1.0));
        assert!(got[1].is_none());
        assert_eq!(got[2].as_ref().map(|h| h.latency_us), Some(2.0));
        assert!(got[3].is_none());
        // The grouped probe leaves hit/miss accounting to the caller.
        let s = c.stats();
        assert_eq!((s.hits, s.misses), (0, 0));
        c.record_lookups(3, 2);
        let s = c.stats();
        assert_eq!((s.hits, s.misses), (3, 2));
    }

    #[test]
    fn grouped_store_and_disabled_semantics() {
        let c = ShardedCache::with_shards(2);
        c.store_grouped(vec![(gemm_key(8), cost(8.0)), (gemm_key(16), cost(16.0))]);
        assert_eq!(c.len(), 2);
        assert_eq!(c.lookup(&gemm_key(8)).unwrap().latency_us, 8.0);
        c.set_enabled(false);
        c.store_grouped(vec![(gemm_key(32), cost(32.0))]);
        assert_eq!(c.len(), 2, "disabled store_grouped must be a no-op");
        let got = c.lookup_grouped(&[gemm_key(8)]);
        assert!(got[0].is_none(), "disabled grouped probe returns all-None");
    }

    #[test]
    fn record_sources_bulk_matches_per_op_counting() {
        let a = ShardedCache::new();
        let b = ShardedCache::new();
        let seq = [
            EstimateSource::SystolicCalibrated,
            EstimateSource::Learned,
            EstimateSource::Learned,
            EstimateSource::LearnedProxy("add".into()),
            EstimateSource::Bandwidth,
            EstimateSource::Free,
            EstimateSource::Fallback,
            EstimateSource::Fallback,
        ];
        let mut counts = [0u64; 6];
        for s in &seq {
            a.record_source(s);
            counts[source_index(s)] += 1;
        }
        b.record_sources(&counts);
        let (sa, sb) = (a.stats(), b.stats());
        assert_eq!(
            (sa.systolic, sa.learned, sa.learned_proxy, sa.bandwidth, sa.free, sa.fallback),
            (sb.systolic, sb.learned, sb.learned_proxy, sb.bandwidth, sb.free, sb.fallback)
        );
    }

    #[test]
    fn peek_is_invisible_to_every_counter() {
        let c = ShardedCache::with_shards(1);
        assert!(!c.peek(&gemm_key(64)));
        c.store(gemm_key(64), cost(1.0));
        assert!(c.peek(&gemm_key(64)));
        let s = c.stats();
        assert_eq!((s.hits, s.misses), (0, 0));
        let traffic = c.shard_traffic();
        assert_eq!(traffic.len(), 1);
        assert_eq!((traffic[0].hits, traffic[0].misses), (0, 0));
        c.set_enabled(false);
        assert!(!c.peek(&gemm_key(64)), "disabled peek always cold");
    }

    #[test]
    fn shard_traffic_tracks_probes_per_shard() {
        let c = ShardedCache::with_shards(2);
        c.lookup(&gemm_key(64)); // miss
        c.store(gemm_key(64), cost(1.0));
        c.lookup(&gemm_key(64)); // hit
        let traffic = c.shard_traffic();
        assert_eq!(traffic.len(), 2);
        let hits: u64 = traffic.iter().map(|t| t.hits).sum();
        let misses: u64 = traffic.iter().map(|t| t.misses).sum();
        assert_eq!((hits, misses), (1, 1));
        // Grouped probes count once per unique shape on the owning shard.
        c.lookup_grouped(&[gemm_key(64), gemm_key(128)]);
        let traffic = c.shard_traffic();
        let hits: u64 = traffic.iter().map(|t| t.hits).sum();
        let misses: u64 = traffic.iter().map(|t| t.misses).sum();
        assert_eq!((hits, misses), (2, 2));
        // The single-threaded walk above never contends.
        assert_eq!(traffic.iter().map(|t| t.contended).sum::<u64>(), 0);
    }

    #[test]
    fn stats_json_shape() {
        let c = ShardedCache::new();
        c.record_source(&EstimateSource::Learned);
        c.record_source(&EstimateSource::Fallback);
        let j = c.stats().to_json();
        assert_eq!(j.req_f64("cache_hits").unwrap(), 0.0);
        let sources = j.get("sources").unwrap();
        assert_eq!(sources.req_f64("learned").unwrap(), 1.0);
        assert_eq!(sources.req_f64("fallback").unwrap(), 1.0);
    }

    #[test]
    fn counter_snapshot_round_trips_bit_exactly() {
        let a = ShardedCache::new();
        a.lookup(&gemm_key(64)); // miss
        a.store(gemm_key(64), cost(1.0));
        a.lookup(&gemm_key(64)); // hit
        a.record_source(&EstimateSource::Learned);
        a.record_source(&EstimateSource::Fallback);
        // 0.1 is not exactly representable: only a bit-pattern copy
        // reproduces the accumulated total exactly.
        a.record_mode(EstimateMode::Fused, 0.1);
        a.record_mode(EstimateMode::Fused, 0.2);
        let snap = a.counter_snapshot();
        let b = ShardedCache::new();
        b.restore_counters(&snap);
        for (k, v) in a.export_entries() {
            b.store(k, v);
        }
        let (sa, sb) = (a.stats(), b.stats());
        assert_eq!(sa, sb);
        assert_eq!(
            sa.modes[1].total_us.to_bits(),
            sb.modes[1].total_us.to_bits()
        );
        assert_eq!(b.export_entries().len(), 1);
    }

    #[test]
    fn mode_accounting_accumulates_per_mode() {
        let c = ShardedCache::new();
        c.record_mode(EstimateMode::Unfused, 10.0);
        c.record_mode(EstimateMode::Unfused, 2.5);
        c.record_mode(EstimateMode::Scheduled, 7.0);
        let s = c.stats();
        assert_eq!(s.modes[0].requests, 2);
        assert_eq!(s.modes[0].total_us, 12.5);
        assert_eq!(s.modes[1].requests, 0);
        assert_eq!(s.modes[1].total_us, 0.0);
        assert_eq!(s.modes[2].requests, 1);
        assert_eq!(s.modes[2].total_us, 7.0);
        let j = s.to_json();
        let modes = j.get("modes").unwrap();
        assert_eq!(
            modes.get("unfused").unwrap().req_f64("requests").unwrap(),
            2.0
        );
        assert_eq!(
            modes.get("scheduled").unwrap().req_f64("total_us").unwrap(),
            7.0
        );
        assert_eq!(modes.get("fused").unwrap().req_f64("requests").unwrap(), 0.0);
    }
}
