//! ASCII scatter plots — the terminal rendition of the paper's figures.
//!
//! Each figure in the paper is a scatter of (x, y) points, optionally with
//! a fitted line or the y = x diagonal. We render the same data as a
//! character grid so every figure harness can *show* its result, not just
//! print metrics.

/// A scatter plot specification.
pub struct Scatter {
    /// Plot title.
    pub title: String,
    /// X-axis label.
    pub x_label: String,
    /// Y-axis label.
    pub y_label: String,
    /// Plot width in characters.
    pub width: usize,
    /// Plot height in characters.
    pub height: usize,
    /// Point series: (marker, points).
    pub series: Vec<(char, Vec<(f64, f64)>)>,
    /// Optional line y = a·x + b drawn with '·'.
    pub line: Option<(f64, f64)>,
    /// Draw the y = x diagonal.
    pub diagonal: bool,
    /// Log-scale both axes.
    pub log_log: bool,
}

impl Scatter {
    /// An empty plot with default dimensions.
    pub fn new(title: &str, x_label: &str, y_label: &str) -> Scatter {
        Scatter {
            title: title.to_string(),
            x_label: x_label.to_string(),
            y_label: y_label.to_string(),
            width: 72,
            height: 24,
            series: Vec::new(),
            line: None,
            diagonal: false,
            log_log: false,
        }
    }

    /// Add one point series drawn with `marker`.
    pub fn add_series(&mut self, marker: char, points: Vec<(f64, f64)>) -> &mut Self {
        self.series.push((marker, points));
        self
    }

    /// Overlay the line `y = alpha + beta * x`.
    pub fn with_fit(&mut self, alpha: f64, beta: f64) -> &mut Self {
        self.line = Some((alpha, beta));
        self
    }

    /// Render the ASCII plot.
    pub fn render(&self) -> String {
        let mut pts: Vec<(f64, f64)> = Vec::new();
        for (_, s) in &self.series {
            pts.extend_from_slice(s);
        }
        if pts.is_empty() {
            return format!("{} (no data)\n", self.title);
        }
        let tf = |v: f64| -> f64 {
            if self.log_log {
                v.max(1e-12).log10()
            } else {
                v
            }
        };
        let xs: Vec<f64> = pts.iter().map(|p| tf(p.0)).collect();
        let ys: Vec<f64> = pts.iter().map(|p| tf(p.1)).collect();
        let (xmin, xmax) = bounds(&xs);
        let (ymin, ymax) = bounds(&ys);
        let xspan = (xmax - xmin).max(1e-12);
        let yspan = (ymax - ymin).max(1e-12);

        let mut grid = vec![vec![' '; self.width]; self.height];

        // Fitted line / diagonal, drawn first so points overwrite.
        for col in 0..self.width {
            let x = xmin + xspan * (col as f64 + 0.5) / self.width as f64;
            let raw_x = if self.log_log { 10f64.powf(x) } else { x };
            let mut marks: Vec<f64> = Vec::new();
            if let Some((a, b)) = self.line {
                marks.push(tf(a * raw_x + b));
            }
            if self.diagonal {
                marks.push(tf(raw_x));
            }
            for y in marks {
                if y.is_finite() {
                    let row = to_row(y, ymin, yspan, self.height);
                    if row < self.height {
                        grid[row][col] = '·';
                    }
                }
            }
        }

        for (marker, series) in &self.series {
            for &(px, py) in series {
                let col = ((tf(px) - xmin) / xspan * (self.width as f64 - 1.0)).round() as usize;
                let row = to_row(tf(py), ymin, yspan, self.height);
                if row < self.height && col < self.width {
                    grid[row][col] = *marker;
                }
            }
        }

        let mut out = String::new();
        out.push_str(&format!("  {}\n", self.title));
        let ylab = |v: f64| -> String {
            let raw = if self.log_log { 10f64.powf(v) } else { v };
            format!("{raw:>10.3}")
        };
        for (r, row) in grid.iter().enumerate() {
            let label = if r == 0 {
                ylab(ymax)
            } else if r == self.height - 1 {
                ylab(ymin)
            } else if r == self.height / 2 {
                ylab(ymin + yspan / 2.0)
            } else {
                " ".repeat(10)
            };
            out.push_str(&format!("{label} |{}\n", row.iter().collect::<String>()));
        }
        out.push_str(&format!(
            "{} +{}\n",
            " ".repeat(10),
            "-".repeat(self.width)
        ));
        let xlo = if self.log_log { 10f64.powf(xmin) } else { xmin };
        let xhi = if self.log_log { 10f64.powf(xmax) } else { xmax };
        out.push_str(&format!(
            "{} {:<12.3}{:^width$}{:>12.3}\n",
            " ".repeat(9),
            xlo,
            format!("{} → {}", self.x_label, self.y_label),
            xhi,
            width = self.width.saturating_sub(24)
        ));
        out
    }
}

fn bounds(v: &[f64]) -> (f64, f64) {
    let mut lo = f64::INFINITY;
    let mut hi = f64::NEG_INFINITY;
    for &x in v {
        if x.is_finite() {
            lo = lo.min(x);
            hi = hi.max(x);
        }
    }
    if !lo.is_finite() {
        (0.0, 1.0)
    } else {
        (lo, hi)
    }
}

/// y grows upward: top row = ymax.
fn to_row(y: f64, ymin: f64, yspan: f64, height: usize) -> usize {
    let frac = ((y - ymin) / yspan).clamp(0.0, 1.0);
    ((1.0 - frac) * (height as f64 - 1.0)).round() as usize
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_points_and_line() {
        let mut s = Scatter::new("test", "x", "y");
        s.add_series('o', vec![(0.0, 0.0), (10.0, 10.0), (5.0, 5.0)]);
        s.with_fit(1.0, 0.0);
        let out = s.render();
        assert!(out.contains("test"));
        assert!(out.contains('o'));
        assert!(out.contains('·'));
    }

    #[test]
    fn empty_plot_is_graceful() {
        let s = Scatter::new("empty", "x", "y");
        assert!(s.render().contains("no data"));
    }

    #[test]
    fn log_log_handles_decades() {
        let mut s = Scatter::new("ll", "n", "t");
        s.log_log = true;
        s.add_series('x', vec![(10.0, 1.0), (1e6, 1e3)]);
        let out = s.render();
        assert!(out.contains('x'));
    }

    #[test]
    fn corner_points_inside_grid() {
        let mut s = Scatter::new("c", "x", "y");
        s.width = 10;
        s.height = 5;
        s.add_series('*', vec![(0.0, 0.0), (1.0, 1.0)]);
        let out = s.render();
        // Top row contains the max point, bottom data row the min.
        let lines: Vec<&str> = out.lines().collect();
        assert!(lines[1].contains('*'));
        assert!(lines[5].contains('*'));
    }
}
