//! Reporting: markdown/CSV tables ([`table`]) and ASCII scatter plots
//! ([`scatter`]) used by every figure harness.

pub mod scatter;
pub mod table;

pub use scatter::Scatter;
pub use table::{fnum, Table};

use std::path::Path;

/// Write a string to a file, creating parent directories.
pub fn write_output(path: &Path, content: &str) -> std::io::Result<()> {
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    std::fs::write(path, content)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn write_output_creates_dirs() {
        let dir = std::env::temp_dir().join("scalesim_tpu_report_test/nested");
        let path = dir.join("out.csv");
        write_output(&path, "a,b\n").unwrap();
        assert_eq!(std::fs::read_to_string(&path).unwrap(), "a,b\n");
        std::fs::remove_dir_all(dir.parent().unwrap()).ok();
    }
}
