//! Markdown/ASCII table rendering for experiment reports.

/// A simple column-aligned table builder.
#[derive(Debug, Clone, Default)]
pub struct Table {
    /// Column headers.
    pub header: Vec<String>,
    /// Row cells (same arity as the header).
    pub rows: Vec<Vec<String>>,
}

impl Table {
    /// An empty table with the given columns.
    pub fn new(header: &[&str]) -> Table {
        Table {
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    /// Append one row of owned cells.
    pub fn row(&mut self, cells: &[String]) -> &mut Self {
        assert_eq!(
            cells.len(),
            self.header.len(),
            "row width mismatch: {cells:?}"
        );
        self.rows.push(cells.to_vec());
        self
    }

    /// Append one row of string literals.
    pub fn row_strs(&mut self, cells: &[&str]) -> &mut Self {
        let owned: Vec<String> = cells.iter().map(|s| s.to_string()).collect();
        self.row(&owned)
    }

    fn widths(&self) -> Vec<usize> {
        let mut w: Vec<usize> = self.header.iter().map(|h| h.chars().count()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                w[i] = w[i].max(c.chars().count());
            }
        }
        w
    }

    /// Render as a GitHub-markdown table.
    pub fn markdown(&self) -> String {
        let w = self.widths();
        let mut out = String::new();
        let fmt_row = |cells: &[String], w: &[usize]| -> String {
            let mut line = String::from("|");
            for (i, c) in cells.iter().enumerate() {
                line.push_str(&format!(" {:<width$} |", c, width = w[i]));
            }
            line.push('\n');
            line
        };
        out.push_str(&fmt_row(&self.header, &w));
        out.push('|');
        for wi in &w {
            out.push_str(&format!("{}|", "-".repeat(wi + 2)));
        }
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &w));
        }
        out
    }

    /// Render as CSV.
    pub fn csv(&self) -> String {
        let esc = |s: &str| -> String {
            if s.contains(',') || s.contains('"') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.to_string()
            }
        };
        let mut out = String::new();
        out.push_str(
            &self
                .header
                .iter()
                .map(|h| esc(h))
                .collect::<Vec<_>>()
                .join(","),
        );
        out.push('\n');
        for row in &self.rows {
            out.push_str(&row.iter().map(|c| esc(c)).collect::<Vec<_>>().join(","));
            out.push('\n');
        }
        out
    }
}

/// Format a float with sensible precision for tables.
pub fn fnum(x: f64) -> String {
    if x == 0.0 {
        "0".to_string()
    } else if x.abs() >= 1000.0 {
        format!("{x:.0}")
    } else if x.abs() >= 10.0 {
        format!("{x:.2}")
    } else {
        format!("{x:.4}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn markdown_renders_aligned() {
        let mut t = Table::new(&["name", "value"]);
        t.row_strs(&["alpha", "1.0"]).row_strs(&["b", "123456"]);
        let md = t.markdown();
        assert!(md.contains("| name  | value  |"));
        assert!(md.lines().count() == 4);
        assert!(md.lines().nth(1).unwrap().starts_with("|--"));
    }

    #[test]
    fn csv_escapes_commas() {
        let mut t = Table::new(&["a", "b"]);
        t.row_strs(&["x,y", "plain"]);
        assert!(t.csv().contains("\"x,y\",plain"));
    }

    #[test]
    #[should_panic]
    fn row_width_checked() {
        let mut t = Table::new(&["a", "b"]);
        t.row_strs(&["only-one"]);
    }

    #[test]
    fn fnum_ranges() {
        assert_eq!(fnum(0.0), "0");
        assert_eq!(fnum(0.12345), "0.1235");
        assert_eq!(fnum(12.345), "12.35");
        assert_eq!(fnum(12345.6), "12346");
    }
}
