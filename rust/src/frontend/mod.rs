//! StableHLO frontend: the paper's framework-agnostic user interface.
//!
//! JAX / PyTorch programs are exported to StableHLO text; this module
//! lexes and parses that text into uniform [`opinfo::OpInfo`] records
//! ([`lexer`], [`parser`]), then classifies each op by execution resource
//! ([`classify`]): systolic ops go to the validated SCALE-Sim model,
//! elementwise ops to the learned latency models, data movement to a
//! bandwidth model, and the rest are flagged.

pub mod classify;
pub mod lexer;
pub mod opinfo;
pub mod parser;
pub mod types;

pub use classify::{classify, conv_to_gemm, dot_to_gemm, CollectiveKind, EwKind, OpClass};
pub use opinfo::{ConvAttrs, DotDims, FuncInfo, ModuleInfo, OpInfo, ShardingAttr};
pub use parser::parse_module;
pub use types::{DType, TensorType};
