//! Tokenizer for the StableHLO / MLIR textual subset the frontend parses.
//!
//! Design notes:
//!
//! * `tensor<...>` and `dense<...>` / `#dialect<...>` payloads are consumed
//!   as single raw tokens (with `<>` nesting tracked), so the parser never
//!   sees the `x`-separated shape syntax as individual tokens.
//! * SSA ids (`%0`, `%arg0`, `%cst_1`) and symbol refs (`@main`) are
//!   dedicated token kinds.
//! * Everything else lexes into identifiers, numbers, strings and single
//!   punctuation characters; `->` is one token.

use anyhow::{bail, Result};

/// One lexed StableHLO token.
#[derive(Debug, Clone, PartialEq)]
pub enum Tok {
    /// Identifier or keyword, possibly dotted: `stablehlo.dot_general`,
    /// `func.func`, `dim_numbers`, `x`.
    Ident(String),
    /// `%`-prefixed SSA value id, without the `%`.
    SsaId(String),
    /// `@`-prefixed symbol, without the `@`.
    Symbol(String),
    /// Integer literal (possibly negative).
    Int(i64),
    /// Float literal.
    Float(f64),
    /// Quoted string contents.
    Str(String),
    /// `tensor<...>` — the raw inner text.
    TensorType(String),
    /// `dense<...>`, `#stablehlo<...>`, `array<...>` etc. — raw payload
    /// with the sigil/keyword preserved in `head`.
    RawAngle {
        /// The sigil/keyword before `<`.
        head: String,
        /// The raw text inside the angle brackets.
        body: String,
    },
    /// `->`
    Arrow,
    /// Single punctuation: ( ) [ ] { } < > = , : ^
    Punct(char),
}

impl Tok {
    /// Is this the punctuation character `c`?
    pub fn is_punct(&self, c: char) -> bool {
        matches!(self, Tok::Punct(p) if *p == c)
    }

    /// The identifier text, if this is an identifier.
    pub fn ident(&self) -> Option<&str> {
        match self {
            Tok::Ident(s) => Some(s),
            _ => None,
        }
    }
}

/// A token plus its 1-based source line (for diagnostics).
#[derive(Debug, Clone, PartialEq)]
pub struct SpannedTok {
    /// The token.
    pub tok: Tok,
    /// 1-based source line.
    pub line: usize,
}

/// Lex StableHLO text into spanned tokens.
pub fn lex(text: &str) -> Result<Vec<SpannedTok>> {
    let bytes = text.as_bytes();
    let mut toks = Vec::new();
    let mut i = 0usize;
    let mut line = 1usize;
    let n = bytes.len();

    while i < n {
        let b = bytes[i];
        match b {
            b'\n' => {
                line += 1;
                i += 1;
            }
            b' ' | b'\t' | b'\r' => i += 1,
            b'/' if i + 1 < n && bytes[i + 1] == b'/' => {
                // Line comment.
                while i < n && bytes[i] != b'\n' {
                    i += 1;
                }
            }
            b'%' => {
                let start = i + 1;
                let mut j = start;
                while j < n && (bytes[j].is_ascii_alphanumeric() || bytes[j] == b'_') {
                    j += 1;
                }
                if j == start {
                    bail!("line {line}: bare '%'");
                }
                toks.push(SpannedTok {
                    tok: Tok::SsaId(text[start..j].to_string()),
                    line,
                });
                // `%0:2` multi-result syntax: lex the `:N` too (as Punct+Int).
                i = j;
            }
            b'@' => {
                let start = i + 1;
                let mut j = start;
                while j < n
                    && (bytes[j].is_ascii_alphanumeric() || bytes[j] == b'_' || bytes[j] == b'.')
                {
                    j += 1;
                }
                toks.push(SpannedTok {
                    tok: Tok::Symbol(text[start..j].to_string()),
                    line,
                });
                i = j;
            }
            b'"' => {
                let start = i + 1;
                let mut j = start;
                while j < n && bytes[j] != b'"' {
                    if bytes[j] == b'\\' {
                        j += 1;
                    }
                    j += 1;
                }
                if j >= n {
                    bail!("line {line}: unterminated string");
                }
                toks.push(SpannedTok {
                    tok: Tok::Str(text[start..j].to_string()),
                    line,
                });
                i = j + 1;
            }
            b'#' => {
                // Dialect attribute: `#stablehlo<precision DEFAULT>` or
                // `#stablehlo.dot<...>` or a plain `#map` ref.
                let start = i + 1;
                let mut j = start;
                while j < n
                    && (bytes[j].is_ascii_alphanumeric() || bytes[j] == b'_' || bytes[j] == b'.')
                {
                    j += 1;
                }
                let head = format!("#{}", &text[start..j]);
                if j < n && bytes[j] == b'<' {
                    let (body, nj, nl) = raw_angle(text, j, line)?;
                    toks.push(SpannedTok {
                        tok: Tok::RawAngle { head, body },
                        line,
                    });
                    i = nj;
                    line = nl;
                } else {
                    toks.push(SpannedTok {
                        tok: Tok::Ident(head),
                        line,
                    });
                    i = j;
                }
            }
            b'-' if i + 1 < n && bytes[i + 1] == b'>' => {
                toks.push(SpannedTok {
                    tok: Tok::Arrow,
                    line,
                });
                i += 2;
            }
            b'-' | b'0'..=b'9' => {
                let start = i;
                let mut j = i + usize::from(b == b'-');
                let mut is_float = false;
                while j < n {
                    let c = bytes[j];
                    if c.is_ascii_digit() {
                        j += 1;
                    } else if c == b'.' && j + 1 < n && bytes[j + 1].is_ascii_digit() {
                        is_float = true;
                        j += 1;
                    } else if (c == b'e' || c == b'E')
                        && j + 1 < n
                        && (bytes[j + 1].is_ascii_digit()
                            || bytes[j + 1] == b'+'
                            || bytes[j + 1] == b'-')
                    {
                        is_float = true;
                        j += 2;
                    } else {
                        break;
                    }
                }
                let s = &text[start..j];
                let tok = if is_float {
                    Tok::Float(s.parse::<f64>().map_err(|_| {
                        anyhow::anyhow!("line {line}: bad float '{s}'")
                    })?)
                } else {
                    Tok::Int(s.parse::<i64>().map_err(|_| {
                        anyhow::anyhow!("line {line}: bad int '{s}'")
                    })?)
                };
                toks.push(SpannedTok { tok, line });
                i = j;
            }
            c if c.is_ascii_alphabetic() || c == b'_' => {
                let start = i;
                let mut j = i;
                while j < n
                    && (bytes[j].is_ascii_alphanumeric() || bytes[j] == b'_' || bytes[j] == b'.')
                {
                    j += 1;
                }
                let word = &text[start..j];
                // Raw-consume angle payloads for shape-bearing keywords.
                if (word == "tensor" || word == "dense" || word == "array")
                    && j < n
                    && bytes[j] == b'<'
                {
                    let (body, nj, nl) = raw_angle(text, j, line)?;
                    let tok = if word == "tensor" {
                        Tok::TensorType(body)
                    } else {
                        Tok::RawAngle {
                            head: word.to_string(),
                            body,
                        }
                    };
                    toks.push(SpannedTok { tok, line });
                    i = nj;
                    line = nl;
                } else {
                    toks.push(SpannedTok {
                        tok: Tok::Ident(word.to_string()),
                        line,
                    });
                    i = j;
                }
            }
            b'(' | b')' | b'[' | b']' | b'{' | b'}' | b'<' | b'>' | b'=' | b',' | b':' | b'^'
            | b'*' | b'|' | b'.' | b'?' | b'+' | b'!' | b';' => {
                toks.push(SpannedTok {
                    tok: Tok::Punct(b as char),
                    line,
                });
                i += 1;
            }
            other => bail!("line {line}: unexpected character '{}'", other as char),
        }
    }
    Ok(toks)
}

/// Consume `<...>` starting at the `<` at byte `open`, tracking nesting.
/// Returns (inner text, index past closing '>', updated line number).
fn raw_angle(text: &str, open: usize, mut line: usize) -> Result<(String, usize, usize)> {
    let bytes = text.as_bytes();
    debug_assert_eq!(bytes[open], b'<');
    let mut depth = 1usize;
    let mut j = open + 1;
    while j < bytes.len() {
        match bytes[j] {
            b'<' => depth += 1,
            b'>' => {
                depth -= 1;
                if depth == 0 {
                    return Ok((text[open + 1..j].to_string(), j + 1, line));
                }
            }
            b'\n' => line += 1,
            _ => {}
        }
        j += 1;
    }
    bail!("line {line}: unterminated '<...>'")
}

#[cfg(test)]
mod tests {
    use super::*;

    fn kinds(text: &str) -> Vec<Tok> {
        lex(text).unwrap().into_iter().map(|t| t.tok).collect()
    }

    #[test]
    fn lex_simple_op() {
        let toks = kinds("%1 = stablehlo.add %0, %arg2 : tensor<128x512xbf16>");
        assert_eq!(
            toks,
            vec![
                Tok::SsaId("1".into()),
                Tok::Punct('='),
                Tok::Ident("stablehlo.add".into()),
                Tok::SsaId("0".into()),
                Tok::Punct(','),
                Tok::SsaId("arg2".into()),
                Tok::Punct(':'),
                Tok::TensorType("128x512xbf16".into()),
            ]
        );
    }

    #[test]
    fn lex_dense_and_dialect_attr() {
        let toks = kinds("dense<0.000000e+00> : tensor<bf16>, #stablehlo<precision DEFAULT>");
        assert!(matches!(&toks[0], Tok::RawAngle { head, body }
            if head == "dense" && body == "0.000000e+00"));
        assert!(matches!(&toks[4], Tok::RawAngle { head, .. } if head == "#stablehlo"));
    }

    #[test]
    fn lex_dot_general_pretty() {
        let toks = kinds("contracting_dims = [1] x [0]");
        assert_eq!(toks[0], Tok::Ident("contracting_dims".into()));
        assert_eq!(toks[2], Tok::Punct('['));
        assert_eq!(toks[3], Tok::Int(1));
        assert_eq!(toks[5], Tok::Ident("x".into()));
    }

    #[test]
    fn lex_conv_dim_numbers() {
        let toks = kinds("[b, f, 0, 1]x[o, i, 0, 1]->[b, f, 0, 1]");
        // ...]x[... : the x between brackets must be an ident
        let x_pos = toks
            .iter()
            .position(|t| matches!(t, Tok::Ident(s) if s == "x"))
            .unwrap();
        assert!(toks[x_pos - 1].is_punct(']'));
        assert!(toks[x_pos + 1].is_punct('['));
        assert!(toks.contains(&Tok::Arrow));
    }

    #[test]
    fn lex_func_header() {
        let toks = kinds(
            "func.func public @main(%arg0: tensor<2x2xf32> {jax.arg_info = \"x\"}) -> (tensor<2x2xf32>)",
        );
        assert_eq!(toks[0], Tok::Ident("func.func".into()));
        assert_eq!(toks[2], Tok::Symbol("main".into()));
        assert!(toks.iter().any(|t| matches!(t, Tok::Str(s) if s == "x")));
    }

    #[test]
    fn lex_numbers() {
        assert_eq!(
            kinds("42 -7 3.5 1.0e-3"),
            vec![
                Tok::Int(42),
                Tok::Int(-7),
                Tok::Float(3.5),
                Tok::Float(1.0e-3)
            ]
        );
    }

    #[test]
    fn lex_nested_angles() {
        let toks = kinds("dense<[<1>, <2>]> : tensor<2xi8>");
        assert!(matches!(&toks[0], Tok::RawAngle { body, .. } if body == "[<1>, <2>]"));
    }

    #[test]
    fn line_numbers_tracked() {
        let toks = lex("a\nb\nc").unwrap();
        assert_eq!(toks[0].line, 1);
        assert_eq!(toks[1].line, 2);
        assert_eq!(toks[2].line, 3);
    }

    #[test]
    fn unterminated_angle_fails() {
        assert!(lex("tensor<2x2xf32").is_err());
        assert!(lex("\"abc").is_err());
    }
}
